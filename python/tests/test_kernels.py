"""L1 kernel correctness: Pallas vs pure-jnp oracle (the CORE signal).

Hypothesis sweeps shapes and dtypes; every case asserts allclose against
``ref.py``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gqa_decode, mla_decode, ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return x.astype(dtype)


def tol(dtype):
    return dict(rtol=2e-5, atol=2e-5) if dtype == jnp.float32 else dict(
        rtol=2e-2, atol=2e-2
    )


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 4),
    k=st.integers(1, 4),
    group=st.sampled_from([1, 2, 4, 8]),
    e=st.sampled_from([8, 16, 32, 64]),
    t=st.sampled_from([16, 64, 128, 256, 384]),
)
def test_gqa_matches_ref(b, k, group, e, t):
    h = k * group
    q = rand(1, (b, h, e), jnp.float32)
    kc = rand(2, (b, t, k, e), jnp.float32)
    vc = rand(3, (b, t, k, e), jnp.float32)
    got = gqa_decode(q, kc, vc)
    want = ref.gqa_decode_ref(q, kc, vc)
    np.testing.assert_allclose(got, want, **tol(jnp.float32))


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.sampled_from([1, 4, 8]),
    c=st.sampled_from([16, 48, 96]),
    g=st.sampled_from([8, 32]),
    t=st.sampled_from([32, 128, 320]),
)
def test_mla_matches_ref(b, h, c, g, t):
    if g >= c:
        g = c // 2
    ql = rand(4, (b, h, c), jnp.float32)
    cc = rand(5, (b, t, c), jnp.float32)
    got = mla_decode(ql, cc, g)
    want = ref.mla_decode_ref(ql, cc, g)
    np.testing.assert_allclose(got, want, **tol(jnp.float32))


@settings(max_examples=15, deadline=None)
@given(
    t=st.sampled_from([128, 256]),
    pos=st.integers(1, 256),
)
def test_gqa_masking_matches_truncated_ref(t, pos):
    pos = min(pos, t)
    b, k, group, e = 2, 2, 4, 32
    h = k * group
    q = rand(6, (b, h, e), jnp.float32)
    kc = rand(7, (b, t, k, e), jnp.float32)
    vc = rand(8, (b, t, k, e), jnp.float32)
    got = gqa_decode(q, kc, vc, pos=pos)
    want = ref.gqa_decode_ref(q, kc[:, :pos], vc[:, :pos])
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@settings(max_examples=10, deadline=None)
@given(pos=st.integers(1, 320))
def test_mla_masking_matches_truncated_ref(pos):
    b, h, c, g, t = 2, 4, 48, 32, 320
    pos = min(pos, t)
    ql = rand(9, (b, h, c), jnp.float32)
    cc = rand(10, (b, t, c), jnp.float32)
    got = mla_decode(ql, cc, g, pos=pos)
    want = ref.mla_decode_ref(ql, cc[:, :pos], g)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gqa_dtypes(dtype):
    b, k, group, e, t = 2, 2, 4, 32, 128
    h = k * group
    q = rand(11, (b, h, e), dtype)
    kc = rand(12, (b, t, k, e), dtype)
    vc = rand(13, (b, t, k, e), dtype)
    got = gqa_decode(q, kc, vc)
    want = ref.gqa_decode_ref(q, kc, vc)
    assert got.dtype == dtype
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), **tol(dtype)
    )


def test_gqa_block_boundary_independence():
    """Result must not depend on the tiling choice."""
    b, k, group, e, t = 1, 2, 2, 16, 384
    h = k * group
    q = rand(14, (b, h, e), jnp.float32)
    kc = rand(15, (b, t, k, e), jnp.float32)
    vc = rand(16, (b, t, k, e), jnp.float32)
    full = gqa_decode(q, kc, vc, block_t=384)
    tiled = gqa_decode(q, kc, vc, block_t=128)
    odd = gqa_decode(q, kc, vc, block_t=96)
    np.testing.assert_allclose(full, tiled, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(full, odd, rtol=2e-5, atol=2e-5)


def test_gqa_softmax_rows_sum_to_one_property():
    """With all-equal values V=c, attention output must be exactly c."""
    b, k, group, e, t = 1, 1, 2, 8, 64
    h = k * group
    q = rand(17, (b, h, e), jnp.float32)
    kc = rand(18, (b, t, k, e), jnp.float32)
    vc = jnp.full((b, t, k, e), 3.25, jnp.float32)
    got = gqa_decode(q, kc, vc)
    np.testing.assert_allclose(got, jnp.full_like(got, 3.25), rtol=1e-5)


def test_gqa_rejects_bad_head_grouping():
    q = jnp.zeros((1, 6, 8), jnp.float32)
    kc = jnp.zeros((1, 16, 4, 8), jnp.float32)
    with pytest.raises(AssertionError):
        gqa_decode(q, kc, kc)
