"""L2 decode-step tests: shapes, cache semantics, Pallas-vs-oracle parity,
and multi-step autoregression consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (DecodeConfig, decode_step, init_params,
                           liminal_grid_eval, make_decode_fn)

jax.config.update("jax_platform_name", "cpu")

CFG = DecodeConfig(num_layers=2, embed_dim=64, heads=4, kv_heads=2,
                   head_dim=16, intermediate_dim=128, vocab=97, context=32)


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, jax.random.PRNGKey(42))


def caches(batch):
    shape = (CFG.num_layers, batch, CFG.context, CFG.kv_heads, CFG.head_dim)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def test_shapes(params):
    kc, vc = caches(3)
    toks = jnp.asarray([1, 2, 3], jnp.int32)
    logits, kc2, vc2 = decode_step(CFG, params, toks, kc, vc,
                                   jnp.asarray(0, jnp.int32))
    assert logits.shape == (3, CFG.vocab)
    assert kc2.shape == kc.shape and vc2.shape == vc.shape


def test_cache_updated_only_at_pos(params):
    kc, vc = caches(2)
    toks = jnp.asarray([5, 6], jnp.int32)
    pos = jnp.asarray(7, jnp.int32)
    _, kc2, vc2 = decode_step(CFG, params, toks, kc, vc, pos)
    changed = np.any(np.asarray(kc2) != 0.0, axis=(0, 1, 3, 4))
    assert changed[7]
    assert not changed[:7].any() and not changed[8:].any()


def test_pallas_and_oracle_paths_agree(params):
    kc, vc = caches(2)
    toks = jnp.asarray([10, 20], jnp.int32)
    for pos in [0, 1, 5, CFG.context - 1]:
        p = jnp.asarray(pos, jnp.int32)
        lp, kp, vp = decode_step(CFG, params, toks, kc, vc, p, use_pallas=True)
        lo, ko, vo = decode_step(CFG, params, toks, kc, vc, p, use_pallas=False)
        np.testing.assert_allclose(lp, lo, rtol=3e-5, atol=3e-5)
        np.testing.assert_allclose(kp, ko, rtol=3e-5, atol=3e-5)
        np.testing.assert_allclose(vp, vo, rtol=3e-5, atol=3e-5)


def test_autoregressive_rollout_is_deterministic(params):
    """Greedy decode twice -> identical token streams."""

    def rollout():
        kc, vc = caches(1)
        tok = jnp.asarray([3], jnp.int32)
        toks = []
        for pos in range(8):
            logits, kc, vc = decode_step(CFG, params, tok, kc, vc,
                                         jnp.asarray(pos, jnp.int32))
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            toks.append(int(tok[0]))
        return toks

    assert rollout() == rollout()


def test_prefix_independence(params):
    """Step at pos p must not depend on garbage beyond p in the cache."""
    kc, vc = caches(1)
    tok = jnp.asarray([7], jnp.int32)
    pos = jnp.asarray(3, jnp.int32)
    logits_clean, _, _ = decode_step(CFG, params, tok, kc, vc, pos)
    noise_k = kc.at[:, :, 10:].set(99.0)
    noise_v = vc.at[:, :, 10:].set(-99.0)
    logits_noisy, _, _ = decode_step(CFG, params, tok, noise_k, noise_v, pos)
    np.testing.assert_allclose(logits_clean, logits_noisy, rtol=3e-5,
                               atol=3e-5)


def test_make_decode_fn_jits(params):
    fn, ex = make_decode_fn(CFG, batch=2)
    out = jax.jit(fn)(*ex)
    assert out[0].shape == (2, CFG.vocab)


def test_grid_eval_matches_scalar_math():
    n = 16
    ones = jnp.ones((n,), jnp.float32)
    t_batch, utps = liminal_grid_eval(
        bytes_moved=ones * 4e9, tensor_flops=ones * 1e9,
        scalar_flops=ones * 1e6, mem_bw=ones * 4e12,
        tensor_peak=ones * 2.25e15, scalar_peak=ones * 2e14,
        exposed=ones * 5e-4,
    )
    want_mem = 4e9 / 4e12
    want_comp = 1e9 / 2.25e15 + 1e6 / 2e14
    want = max(want_mem, want_comp) + 5e-4
    np.testing.assert_allclose(t_batch, want, rtol=1e-6)
    np.testing.assert_allclose(utps, 1.0 / want, rtol=1e-6)


def test_weight_count_matches_param_tree(params):
    n = sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))
    assert n == CFG.weight_count()


# --- MLA decode step -------------------------------------------------------

from compile.model import (MlaDecodeConfig, init_mla_params,  # noqa: E402
                           mla_decode_step)

MLA_CFG = MlaDecodeConfig(num_layers=2, embed_dim=64, heads=4, q_latent=32,
                          kv_latent=24, rope_dim=8, intermediate_dim=128,
                          vocab=97, context=32)


@pytest.fixture(scope="module")
def mla_params():
    return init_mla_params(MLA_CFG, jax.random.PRNGKey(7))


def mla_cache(batch):
    return jnp.zeros((MLA_CFG.num_layers, batch, MLA_CFG.context,
                      MLA_CFG.latent_dim), jnp.float32)


def test_mla_shapes_and_single_cache(mla_params):
    toks = jnp.asarray([1, 2, 3], jnp.int32)
    logits, cache = mla_decode_step(MLA_CFG, mla_params, toks, mla_cache(3),
                                    jnp.asarray(0, jnp.int32))
    assert logits.shape == (3, MLA_CFG.vocab)
    # One latent cache, [L, B, T, C] — not separate K and V.
    assert cache.shape == (2, 3, 32, MLA_CFG.latent_dim)


def test_mla_pallas_oracle_parity(mla_params):
    toks = jnp.asarray([5, 9], jnp.int32)
    for pos in [0, 3, MLA_CFG.context - 1]:
        p = jnp.asarray(pos, jnp.int32)
        lp, cp = mla_decode_step(MLA_CFG, mla_params, toks, mla_cache(2), p,
                                 use_pallas=True)
        lo, co = mla_decode_step(MLA_CFG, mla_params, toks, mla_cache(2), p,
                                 use_pallas=False)
        np.testing.assert_allclose(lp, lo, rtol=3e-5, atol=3e-5)
        np.testing.assert_allclose(cp, co, rtol=3e-5, atol=3e-5)


def test_mla_cache_is_smaller_than_gqa():
    # The architectural point: per-token cache bytes shrink (paper A.2).
    gqa = CFG.kv_bytes_per_token / CFG.num_layers
    mla = MLA_CFG.kv_bytes_per_token / MLA_CFG.num_layers
    assert mla < gqa


def test_mla_cache_updated_only_at_pos(mla_params):
    toks = jnp.asarray([5, 6], jnp.int32)
    pos = jnp.asarray(9, jnp.int32)
    _, cache = mla_decode_step(MLA_CFG, mla_params, toks, mla_cache(2), pos)
    changed = np.any(np.asarray(cache) != 0.0, axis=(0, 1, 3))
    assert changed[9] and not changed[:9].any() and not changed[10:].any()
