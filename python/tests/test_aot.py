"""AOT path tests: lowering produces loadable HLO text + sound manifest."""

import json
import os
import subprocess
import sys

import jax
import pytest

from compile.aot import build_entries, lower_entry, to_hlo_text
from compile.model import DecodeConfig, make_decode_fn

jax.config.update("jax_platform_name", "cpu")

TINY = DecodeConfig(num_layers=1, embed_dim=32, heads=2, kv_heads=1,
                    head_dim=16, intermediate_dim=64, vocab=32, context=16)


def test_hlo_text_is_parseable_module():
    fn, ex = make_decode_fn(TINY, batch=1)
    text = to_hlo_text(jax.jit(fn).lower(*ex))
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # Tuple return convention the Rust loader relies on.
    assert "ROOT" in text


def test_lower_entry_manifest_record():
    fn, ex = make_decode_fn(TINY, batch=1)
    text, rec = lower_entry("decode_test", fn, ex)
    assert rec["file"] == "decode_test.hlo.txt"
    assert len(rec["sha256"]) == 64
    # Flattened inputs: 12 params + tokens + 2 caches + pos = 16.
    assert len(rec["inputs"]) == 16
    shapes = [tuple(i["shape"]) for i in rec["inputs"]]
    assert (1,) in shapes  # token_ids
    assert () in shapes  # pos scalar


def test_build_entries_cover_all_kinds():
    entries = build_entries(TINY)
    kinds = set()
    for _, (_, _, extra) in entries.items():
        kinds.add(extra["kind"])
    assert kinds == {"decode_step", "mla_decode_step", "grid_eval", "gemv", "gemm"}


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__),
                                    "../../artifacts/manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_built_manifest_is_consistent():
    root = os.path.join(os.path.dirname(__file__), "../../artifacts")
    with open(os.path.join(root, "manifest.json")) as f:
        manifest = json.load(f)
    assert "decode_b1" in manifest["entries"]
    for name, rec in manifest["entries"].items():
        path = os.path.join(root, rec["file"])
        assert os.path.exists(path), f"{name}: missing {rec['file']}"
        with open(path) as fh:
            head = fh.read(64)
        assert head.startswith("HloModule"), name


def test_aot_cli_smoke(tmp_path):
    """Run the module CLI end-to-end into a temp dir (tiny context)."""
    env = dict(os.environ)
    out = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path),
         "--context", "16"],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert out.returncode == 0, out.stderr
    assert (tmp_path / "manifest.json").exists()
    assert (tmp_path / "decode_b1.hlo.txt").exists()
