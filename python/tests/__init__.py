"""Tests for the build-time python layer."""
