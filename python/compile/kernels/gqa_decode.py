"""Pallas flash-decode kernel for grouped-query attention.

The paper's hot loop is exactly this: one query token per user against a
``T``-long KV cache — a bandwidth-bound, GEMV-like access pattern whose
bytes-moved is the ``batch_kv_rd_bytes`` term of the LIMINAL model.

TPU mapping (DESIGN.md §Hardware-Adaptation):

* Grid is ``(B, K)``: one program instance per (sequence, KV head). All
  ``H/K`` query heads of the group share the program's KV tile, so each
  cached byte is read from HBM exactly once — the kernel realizes the
  GQA reuse factor (``2H/K`` FLOPs/byte) that Appendix A.3 derives as
  the attention AMI asymptote.
* The context axis is walked in ``block_t`` chunks with an online-softmax
  (m, l, acc) carry, so only one ``[block_t, E]`` K tile and one V tile
  are live in VMEM at a time; ``block_t`` is chosen so double-buffered
  tiles fit comfortably (see ``vmem_bytes``).
* ``interpret=True`` everywhere: the CPU PJRT plugin cannot execute
  Mosaic custom-calls; correctness is validated through this path and
  TPU efficiency is *estimated* from the BlockSpec (DESIGN.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_T = 128


def _gqa_kernel(q_ref, pos_ref, k_ref, v_ref, o_ref, *, block_t: int,
                t_total: int):
    """One (sequence, KV-head) program: online-softmax over T tiles.

    Refs (leading block dims of size 1 squeezed below):
      q_ref: [1, 1, GH, E]   queries for this head group
      pos_ref: [1]           number of valid cache positions (<= T)
      k_ref: [1, T, 1, E]    full K stripe for this kv head
      v_ref: [1, T, 1, E]    full V stripe
      o_ref: [1, 1, GH, E]   output
    """
    gh = q_ref.shape[2]
    e = q_ref.shape[3]
    q = q_ref[0, 0, :, :] * (1.0 / jnp.sqrt(jnp.asarray(e, jnp.float32)).astype(
        q_ref.dtype
    ))
    pos = pos_ref[0]

    n_blocks = t_total // block_t

    def body(i, carry):
        m_prev, l_prev, acc_prev = carry
        k_tile = k_ref[0, pl.ds(i * block_t, block_t), 0, :]  # [bt, E]
        v_tile = v_ref[0, pl.ds(i * block_t, block_t), 0, :]  # [bt, E]
        s = jnp.dot(
            q, k_tile.T, preferred_element_type=jnp.float32
        )  # [GH, bt]
        # Mask cache slots beyond the sequence's valid length.
        idx = i * block_t + jax.lax.iota(jnp.int32, block_t)
        s = jnp.where((idx < pos)[None, :], s, -jnp.inf)
        m_cur = jnp.max(s, axis=-1)  # [GH]
        m_new = jnp.maximum(m_prev, m_cur)
        # Rescale previous accumulator into the new max frame.
        scale = jnp.exp(m_prev - m_new)  # [GH]
        p = jnp.exp(s - m_new[:, None])  # [GH, bt]
        l_new = l_prev * scale + p.sum(axis=-1)
        acc_new = acc_prev * scale[:, None] + jnp.dot(
            p.astype(v_tile.dtype), v_tile, preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc_new

    m0 = jnp.full((gh,), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((gh,), jnp.float32)
    acc0 = jnp.zeros((gh, e), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_blocks, body, (m0, l0, acc0))

    o_ref[0, 0, :, :] = (acc / l[:, None]).astype(o_ref.dtype)


def gqa_decode(q, k_cache, v_cache, pos=None, *,
               block_t: int = DEFAULT_BLOCK_T, interpret: bool = True):
    """Flash-decode GQA attention via Pallas.

    Args/returns exactly as :func:`..ref.gqa_decode_ref`, plus ``pos``:
    an optional scalar count of valid cache positions (``1 <= pos <= T``;
    defaults to the full cache). Slots at index >= ``pos`` are masked, so
    a serving engine can run with a pre-allocated fixed-``T`` cache.
    """
    b, h, e = q.shape
    _, t, k, _ = k_cache.shape
    assert h % k == 0, f"H={h} not a multiple of K={k}"
    if t % block_t != 0:
        # Fall back to one block spanning the entire (short) context.
        block_t = t
    gh = h // k
    qg = q.reshape(b, k, gh, e)
    pos_arr = jnp.asarray(
        [t if pos is None else pos], jnp.int32
    ).reshape((1,))

    kernel = functools.partial(_gqa_kernel, block_t=block_t, t_total=t)
    out = pl.pallas_call(
        kernel,
        grid=(b, k),
        in_specs=[
            pl.BlockSpec((1, 1, gh, e), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((1, t, 1, e), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, t, 1, e), lambda i, j: (i, 0, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, gh, e), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k, gh, e), q.dtype),
        interpret=interpret,
    )(qg, pos_arr, k_cache, v_cache)
    return out.reshape(b, h, e)


def vmem_bytes(block_t: int, e: int, gh: int, dtype_bytes: int = 4) -> int:
    """Estimated live VMEM per program instance (K tile + V tile, double
    buffered, plus q/acc). Used by DESIGN.md §Perf to size ``block_t``."""
    tile = block_t * e * dtype_bytes
    qacc = gh * e * dtype_bytes * 2
    return 2 * 2 * tile + qacc  # 2 operands x 2 buffers + q/acc


def mxu_utilization_estimate(t: int, e: int, gh: int,
                             peak_macs_per_cycle: int = 128 * 128) -> float:
    """Crude MXU duty estimate for one decode step: the QK^T and PV
    matmuls have inner dim E and only ``gh`` rows, so at S=1 the systolic
    array is mostly idle — the kernel is bandwidth-bound, matching the
    paper's §4.8 observation (<=1% tensor utilization at low batch)."""
    useful = 2 * gh * t * e  # MACs
    # Cycles to stream the KV tile through a 128x128 MXU at one tile/cycle
    # lower bound (weights-stationary): T * E / 128 per matmul.
    cycles = 2 * t * max(e, 128) / 128
    return min(1.0, useful / (cycles * peak_macs_per_cycle))
