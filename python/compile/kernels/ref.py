"""Pure-jnp oracles for the Pallas kernels and the L2 decode step.

These are the correctness ground truth: every Pallas kernel must match
its ``*_ref`` here to float tolerance (pytest + hypothesis enforce it),
and the end-to-end decode step in ``model.py`` is built from the same
pieces.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gqa_decode_ref(q, k_cache, v_cache):
    """Grouped-query decode attention, one new token per sequence.

    Args:
      q: ``[B, H, E]`` queries for the new token.
      k_cache: ``[B, T, K, E]`` cached keys.
      v_cache: ``[B, T, K, E]`` cached values.

    Returns:
      ``[B, H, E]`` attention output. ``H`` must be a multiple of ``K``;
      query head ``h`` attends through KV head ``h // (H // K)``.
    """
    b, h, e = q.shape
    _, t, k, _ = k_cache.shape
    assert h % k == 0, f"H={h} not a multiple of K={k}"
    group = h // k
    qg = q.reshape(b, k, group, e)
    scores = jnp.einsum("bkge,btke->bkgt", qg, k_cache) / jnp.sqrt(
        jnp.asarray(e, jnp.float32)
    ).astype(q.dtype)
    s32 = scores.astype(jnp.float32)
    p = jnp.exp(s32 - s32.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bkgt,btke->bkge", p.astype(q.dtype), v_cache)
    return out.reshape(b, h, e)


def mla_decode_ref(q_latent, kv_cache, kv_latent_dim):
    """Multi-head latent (absorbed) decode attention, DeepSeek style.

    Args:
      q_latent: ``[B, H, C]`` queries projected into the shared latent +
        rope space (``C = G + R``).
      kv_cache: ``[B, T, C]`` per-token latent cache, shared by all heads
        — this sharing is what makes MLA's KV cache ~28x smaller than GQA
        at DeepSeekV3 dimensions (paper Appendix A.2).
      kv_latent_dim: ``G`` — the first ``G`` channels of the cache are
        the value payload.

    Returns:
      ``[B, H, G]`` attention output in latent space (the up-projection
      back to model dim is absorbed into the layer's output matmul).
    """
    b, h, c = q_latent.shape
    _, t, c2 = kv_cache.shape
    assert c == c2
    scores = jnp.einsum("bhc,btc->bht", q_latent, kv_cache) / jnp.sqrt(
        jnp.asarray(c, jnp.float32)
    ).astype(q_latent.dtype)
    s32 = scores.astype(jnp.float32)
    p = jnp.exp(s32 - s32.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum(
        "bht,btg->bhg", p.astype(q_latent.dtype), kv_cache[:, :, :kv_latent_dim]
    )


def rmsnorm_ref(x, w, eps: float = 1e-6):
    """RMSNorm over the last axis."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    return (x.astype(jnp.float32) * inv).astype(x.dtype) * w


def swiglu_ref(x, w_gate, w_up, w_down):
    """SwiGLU feed-forward: ``down(silu(x @ gate) * (x @ up))``."""
    return (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down


def softmax_ref(x, axis: int = -1):
    """Numerically-stable softmax (fp32 accumulation)."""
    x32 = x.astype(jnp.float32)
    p = jnp.exp(x32 - x32.max(axis=axis, keepdims=True))
    return (p / p.sum(axis=axis, keepdims=True)).astype(x.dtype)
