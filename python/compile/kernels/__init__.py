"""L1 Pallas kernels for the decode hot path, plus pure-jnp oracles."""

from . import ref  # noqa: F401
from .gqa_decode import gqa_decode  # noqa: F401
from .mla_decode import mla_decode  # noqa: F401
