"""Pallas decode kernel for multi-head latent attention (DeepSeekV3).

In the absorbed MLA formulation every head attends over the *same*
``[T, C]`` latent cache (``C = G + R``), so the kernel's memory traffic
is ``T * C`` bytes per sequence regardless of head count — the reason
DeepSeekV3's attention AMI *rises* with context (Appendix A.3: converges
to ~512 FLOPs/byte) while GQA's falls.

Grid is ``(B,)``: one program per sequence; heads are processed together
as the row dimension of the score matmul (``H x C @ C x T``), so the MXU
sees a tall-skinny GEMM instead of H separate GEMVs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_T = 128


def _mla_kernel(q_ref, pos_ref, c_ref, o_ref, *, block_t: int, t_total: int,
                g: int):
    """One sequence: online-softmax over latent-cache tiles.

    Refs:
      q_ref: [1, H, C]  latent-space queries
      pos_ref: [1]      number of valid cache positions (<= T)
      c_ref: [1, T, C]  latent KV cache
      o_ref: [1, H, G]  latent-space output
    """
    h = q_ref.shape[1]
    c = q_ref.shape[2]
    q = q_ref[0, :, :] * (1.0 / jnp.sqrt(jnp.asarray(c, jnp.float32)).astype(
        q_ref.dtype
    ))
    pos = pos_ref[0]

    n_blocks = t_total // block_t

    def body(i, carry):
        m_prev, l_prev, acc_prev = carry
        c_tile = c_ref[0, pl.ds(i * block_t, block_t), :]  # [bt, C]
        s = jnp.dot(q, c_tile.T, preferred_element_type=jnp.float32)  # [H, bt]
        idx = i * block_t + jax.lax.iota(jnp.int32, block_t)
        s = jnp.where((idx < pos)[None, :], s, -jnp.inf)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        scale = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_prev * scale + p.sum(axis=-1)
        # Value payload = first G channels of the latent tile.
        acc_new = acc_prev * scale[:, None] + jnp.dot(
            p.astype(c_tile.dtype),
            c_tile[:, :g],
            preferred_element_type=jnp.float32,
        )
        return m_new, l_new, acc_new

    m0 = jnp.full((h,), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((h,), jnp.float32)
    acc0 = jnp.zeros((h, g), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_blocks, body, (m0, l0, acc0))

    o_ref[0, :, :] = (acc / l[:, None]).astype(o_ref.dtype)


def mla_decode(q_latent, kv_cache, kv_latent_dim: int, pos=None, *,
               block_t: int = DEFAULT_BLOCK_T, interpret: bool = True):
    """Absorbed-MLA decode attention via Pallas.

    Args/returns exactly as :func:`..ref.mla_decode_ref`, plus ``pos``:
    optional scalar count of valid cache positions (defaults to full).
    """
    b, h, c = q_latent.shape
    _, t, c2 = kv_cache.shape
    assert c == c2
    g = kv_latent_dim
    if t % block_t != 0:
        block_t = t
    pos_arr = jnp.asarray([t if pos is None else pos], jnp.int32).reshape((1,))

    kernel = functools.partial(_mla_kernel, block_t=block_t, t_total=t, g=g)
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, h, c), lambda i: (i, 0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1, t, c), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, g), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, g), q_latent.dtype),
        interpret=interpret,
    )(q_latent, pos_arr, kv_cache)
