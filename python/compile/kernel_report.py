"""Structural perf report for the L1 kernels (DESIGN.md §Perf).

interpret=True gives CPU-numpy timings which are NOT a TPU proxy, so the
optimization loop for L1 is structural: VMEM footprint of the BlockSpec
tiling, bytes moved vs. the analytic minimum, and an MXU duty estimate.
This script prints those for a sweep of block sizes; run it when tuning
``DEFAULT_BLOCK_T``.

Usage: ``python -m compile.kernel_report``
"""

from __future__ import annotations

from .kernels.gqa_decode import mxu_utilization_estimate, vmem_bytes

VMEM_BUDGET = 16 << 20  # 16 MiB VMEM per TPU core
E = 128  # head dim of the paper's models
GH = 8  # query heads per KV head (Llama3-70B grouping)


def analytic_kv_bytes(t: int, e: int, dtype_bytes: int) -> int:
    """LIMINAL's batch_kv_rd_bytes for one (sequence, kv-head) program."""
    return 2 * t * e * dtype_bytes  # K and V stripes read once


def main() -> None:
    print(f"{'block_t':>8} {'VMEM':>12} {'fits 2x?':>9} {'MXU est':>9}")
    for block_t in [64, 128, 256, 512, 1024, 2048]:
        v = vmem_bytes(block_t, E, GH, dtype_bytes=2)  # bf16 on TPU
        fits = "yes" if 2 * v <= VMEM_BUDGET else "NO"
        mxu = mxu_utilization_estimate(131072, E, GH)
        print(f"{block_t:>8} {v:>12,} {fits:>9} {mxu:>9.4f}")
    t = 131072
    print(
        f"\nbytes moved per (seq, kv-head) at T={t}: "
        f"{analytic_kv_bytes(t, E, 2):,} (analytic minimum; the kernel "
        "reads each KV byte exactly once by construction)"
    )
    print(
        "MXU duty at S=1 is <1%: decode attention is bandwidth-bound, "
        "matching paper §4.8."
    )


if __name__ == "__main__":
    main()
