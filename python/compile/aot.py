"""AOT compile path: lower the L2 graphs to HLO *text* artifacts.

HLO text (NOT ``lowered.compile()`` / serialized protos) is the
interchange format: jax >= 0.5 emits HloModuleProtos with 64-bit
instruction ids which the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Python runs exactly once, at build time (``make artifacts``); the Rust
binary is self-contained afterwards.

Usage: ``python -m compile.aot --out-dir ../artifacts``
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import (DecodeConfig, MlaDecodeConfig, make_decode_fn,
                    make_gemv_fn, make_grid_eval_fn, make_mla_decode_fn)

# GEMV sizes for the Appendix E validation artifact. The paper uses
# 1x16384x16384 (512 MB at fp16); we scale to 4096 (64 MB fp32) so the
# CPU run finishes quickly while staying firmly memory-bound.
GEMV_M = 4096
GEMV_N = 4096

# GEMM size for the compute-calibration artifact (square, compute-bound:
# 2*512^3 = 268 MFLOP over ~3 MB of operands).
GEMM_N = 512

# Grid-evaluator width (number of working points per call).
GRID_N = 1024

# Decode-step batch variants exported (one executable per batch size, as
# a real serving engine would pre-compile its batch buckets).
DECODE_BATCHES = (1, 2, 4, 8)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _flatten_example(example):
    """Flatten an example-arg pytree to the positional parameter list the
    HLO module will expect, recording shapes/dtypes for the manifest."""
    flat, _ = jax.tree_util.tree_flatten(example)
    return [
        {"shape": list(x.shape), "dtype": str(x.dtype)} for x in flat
    ]


def lower_entry(name, fn, example):
    """Lower one entry point; return (hlo_text, manifest_record)."""
    lowered = jax.jit(fn).lower(*example)
    text = to_hlo_text(lowered)
    record = {
        "file": f"{name}.hlo.txt",
        "inputs": _flatten_example(example),
        "sha256": hashlib.sha256(text.encode()).hexdigest(),
    }
    return text, record


def build_entries(cfg: DecodeConfig):
    """All AOT entry points: name -> (fn, example, extra-manifest)."""
    entries = {}
    for b in DECODE_BATCHES:
        fn, ex = make_decode_fn(cfg, b)
        entries[f"decode_b{b}"] = (fn, ex, {
            "kind": "decode_step",
            "batch": b,
            "config": {
                "num_layers": cfg.num_layers,
                "embed_dim": cfg.embed_dim,
                "heads": cfg.heads,
                "kv_heads": cfg.kv_heads,
                "head_dim": cfg.head_dim,
                "intermediate_dim": cfg.intermediate_dim,
                "vocab": cfg.vocab,
                "context": cfg.context,
                "weight_count": cfg.weight_count(),
                "kv_bytes_per_token": cfg.kv_bytes_per_token,
            },
        })
    mla_cfg = MlaDecodeConfig(context=cfg.context)
    for b in (1, 4):
        fn, ex = make_mla_decode_fn(mla_cfg, b)
        entries[f"mla_decode_b{b}"] = (fn, ex, {
            "kind": "mla_decode_step",
            "batch": b,
            "config": {
                "num_layers": mla_cfg.num_layers,
                "embed_dim": mla_cfg.embed_dim,
                "heads": mla_cfg.heads,
                "latent_dim": mla_cfg.latent_dim,
                "kv_latent": mla_cfg.kv_latent,
                "vocab": mla_cfg.vocab,
                "context": mla_cfg.context,
                "kv_bytes_per_token": mla_cfg.kv_bytes_per_token,
            },
        })
    gfn, gex = make_grid_eval_fn(GRID_N)
    entries["grid_eval"] = (gfn, gex, {"kind": "grid_eval", "n": GRID_N})
    vfn, vex = make_gemv_fn(GEMV_M, GEMV_N)
    entries["gemv"] = (vfn, vex, {
        "kind": "gemv",
        "m": GEMV_M,
        "n": GEMV_N,
        "bytes": GEMV_M * GEMV_N * 4,
        "flops": 2 * GEMV_M * GEMV_N,
    })

    def gemm(a, b):
        return (a @ b,)

    gemm_ex = (jnp.zeros((GEMM_N, GEMM_N), jnp.float32),
               jnp.zeros((GEMM_N, GEMM_N), jnp.float32))
    entries["gemm"] = (gemm, gemm_ex, {
        "kind": "gemm",
        "n": GEMM_N,
        "flops": 2 * GEMM_N ** 3,
        "bytes": 3 * GEMM_N * GEMM_N * 4,
    })
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--context", type=int, default=DecodeConfig.context)
    args = ap.parse_args()

    cfg = DecodeConfig(context=args.context)
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"entries": {}}
    for name, (fn, example, extra) in build_entries(cfg).items():
        text, record = lower_entry(name, fn, example)
        record.update(extra)
        path = os.path.join(args.out_dir, record["file"])
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"][name] = record
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
