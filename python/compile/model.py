"""L2: the transformer decode step in JAX, calling the L1 Pallas kernels.

This is the *executable* analog of the paper's Figure 1 transformer
block: RMSNorm -> GQA attention (Pallas flash-decode kernel) -> residual
-> RMSNorm -> SwiGLU FFN -> residual, scanned over layers, with a KV
cache updated in place at the current position. ``aot.py`` lowers it once
to HLO text; the Rust coordinator executes it via PJRT with Python never
on the request path.

Also defined here: ``liminal_grid_eval``, a vectorized form of the
LIMINAL latency equations (paper §2.2) used to offload large sweep grids
to XLA, and ``gemv``, the Appendix E validation microbenchmark.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from .kernels import gqa_decode
from .kernels import ref


@dataclasses.dataclass(frozen=True)
class DecodeConfig:
    """A scaled-down Llama-style architecture (same topology as paper
    Table 3, sized to execute quickly on the CPU PJRT backend)."""

    num_layers: int = 4
    embed_dim: int = 256
    heads: int = 8
    kv_heads: int = 2
    head_dim: int = 32
    intermediate_dim: int = 512
    vocab: int = 512
    context: int = 128  # fixed cache length T

    @property
    def kv_bytes_per_token(self) -> int:
        """FP32 KV bytes per token across all layers (the LIMINAL
        ``kv_bytes_per_token`` quantity for this executable model)."""
        return 2 * self.kv_heads * self.head_dim * 4 * self.num_layers

    def weight_count(self) -> int:
        """Total parameter count (mirrors ``apps::Llama3::weight_bytes``)."""
        d, h, k, e, v = (
            self.embed_dim,
            self.heads,
            self.kv_heads,
            self.head_dim,
            self.intermediate_dim,
        )
        per_layer = d * h * e + 2 * d * k * e + h * e * d + 3 * d * v + 2 * d
        return per_layer * self.num_layers + 2 * self.vocab * d + d


def init_params(cfg: DecodeConfig, key) -> Dict[str, jax.Array]:
    """Random parameters, stacked per layer so the step can ``lax.scan``."""
    d, h, k, e, v = (
        cfg.embed_dim,
        cfg.heads,
        cfg.kv_heads,
        cfg.head_dim,
        cfg.intermediate_dim,
    )
    n = cfg.num_layers
    keys = jax.random.split(key, 9)

    def normal(kk, shape, fan_in):
        scale = 1.0 / jnp.sqrt(jnp.asarray(fan_in, jnp.float32))
        return jax.random.normal(kk, shape, jnp.float32) * scale

    return {
        "tok_embed": normal(keys[0], (cfg.vocab, d), d),
        "wq": normal(keys[1], (n, d, h * e), d),
        "wk": normal(keys[2], (n, d, k * e), d),
        "wv": normal(keys[3], (n, d, k * e), d),
        "wo": normal(keys[4], (n, h * e, d), h * e),
        "w_gate": normal(keys[5], (n, d, v), d),
        "w_up": normal(keys[6], (n, d, v), d),
        "w_down": normal(keys[7], (n, v, d), v),
        "norm_attn": jnp.ones((n, d), jnp.float32),
        "norm_ffn": jnp.ones((n, d), jnp.float32),
        "norm_final": jnp.ones((d,), jnp.float32),
        "lm_head": normal(keys[8], (d, cfg.vocab), d),
    }


def _masked_gqa_ref(cfg: DecodeConfig, q, kc, vc, pos):
    """Oracle attention with dynamic length mask (used when
    ``use_pallas=False`` to isolate kernel bugs from model bugs)."""
    b, h, e = q.shape
    k = cfg.kv_heads
    group = h // k
    qg = q.reshape(b, k, group, e)
    s = jnp.einsum("bkge,btke->bkgt", qg, kc) / jnp.sqrt(
        jnp.asarray(e, jnp.float32)
    )
    mask = jnp.arange(cfg.context) < pos
    s = jnp.where(mask[None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgt,btke->bkge", p, vc).reshape(b, h, e)


def decode_step(cfg: DecodeConfig, params, token_ids, k_cache, v_cache, pos,
                *, use_pallas: bool = True):
    """One auto-regressive decode step for a whole batch.

    Args:
      token_ids: ``[B]`` int32 current tokens.
      k_cache / v_cache: ``[L, B, T, K, E]`` fp32 caches.
      pos: scalar int32 — number of tokens already in the cache. The new
        token's KV is written at index ``pos``; attention spans
        ``pos + 1`` positions.
      use_pallas: route attention through the L1 kernel (True — the AOT
        path) or the pure-jnp oracle (False — test path).

    Returns:
      ``(logits [B, vocab], k_cache, v_cache)`` with caches updated.
    """
    b = token_ids.shape[0]
    h, k, e = cfg.heads, cfg.kv_heads, cfg.head_dim

    x = params["tok_embed"][token_ids]  # [B, D]

    def layer(x, layer_params):
        (wq, wk, wv, wo, w_gate, w_up, w_down, norm_attn, norm_ffn,
         kc, vc) = layer_params

        # --- Attention ---
        xa = ref.rmsnorm_ref(x, norm_attn)
        q = (xa @ wq).reshape(b, h, e)
        new_k = (xa @ wk).reshape(b, k, e)
        new_v = (xa @ wv).reshape(b, k, e)
        kc = jax.lax.dynamic_update_slice(kc, new_k[:, None], (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, new_v[:, None], (0, pos, 0, 0))
        if use_pallas:
            attn = gqa_decode(q, kc, vc, pos=pos + 1)
        else:
            attn = _masked_gqa_ref(cfg, q, kc, vc, pos + 1)
        x = x + attn.reshape(b, h * e) @ wo

        # --- FFN ---
        xf = ref.rmsnorm_ref(x, norm_ffn)
        x = x + ref.swiglu_ref(xf, w_gate, w_up, w_down)
        return x, (kc, vc)

    layer_params = (
        params["wq"], params["wk"], params["wv"], params["wo"],
        params["w_gate"], params["w_up"], params["w_down"],
        params["norm_attn"], params["norm_ffn"],
        k_cache, v_cache,
    )
    x, (k_cache, v_cache) = jax.lax.scan(layer, x, layer_params)

    logits = ref.rmsnorm_ref(x, params["norm_final"]) @ params["lm_head"]
    return logits, k_cache, v_cache


def make_decode_fn(cfg: DecodeConfig, batch: int, *, use_pallas: bool = True):
    """Build the jit-able decode function plus concrete example args
    (what ``aot.py`` lowers). Returns ``(fn, example_args)``."""

    def fn(params, token_ids, k_cache, v_cache, pos):
        return decode_step(cfg, params, token_ids, k_cache, v_cache, pos,
                           use_pallas=use_pallas)

    params = init_params(cfg, jax.random.PRNGKey(0))
    cache_shape = (cfg.num_layers, batch, cfg.context, cfg.kv_heads,
                   cfg.head_dim)
    example = (
        params,
        jnp.zeros((batch,), jnp.int32),
        jnp.zeros(cache_shape, jnp.float32),
        jnp.zeros(cache_shape, jnp.float32),
        jnp.asarray(0, jnp.int32),
    )
    return fn, example


def liminal_grid_eval(bytes_moved, tensor_flops, scalar_flops, mem_bw,
                      tensor_peak, scalar_peak, exposed):
    """Vectorized LIMINAL §2.2: ``max(T_compute, T_mem) + T_exposed`` and
    UTPS over N working points at once. All inputs ``[N]`` fp32; returns
    ``(t_batch [N], utps [N])``."""
    t_mem = bytes_moved / mem_bw
    t_compute = tensor_flops / tensor_peak + scalar_flops / scalar_peak
    t_batch = jnp.maximum(t_mem, t_compute) + exposed
    return t_batch, 1.0 / t_batch


def make_grid_eval_fn(n: int):
    """Jit-able grid evaluator over ``n`` points + example args."""

    def fn(bytes_moved, tensor_flops, scalar_flops, mem_bw, tensor_peak,
           scalar_peak, exposed):
        return liminal_grid_eval(bytes_moved, tensor_flops, scalar_flops,
                                 mem_bw, tensor_peak, scalar_peak, exposed)

    ex = tuple(jnp.ones((n,), jnp.float32) for _ in range(7))
    return fn, ex


def make_gemv_fn(m: int, n: int):
    """The Appendix E validation microbenchmark: ``x[1,m] @ W[m,n]``.

    LIMINAL predicts its latency as memory-bound (``m*n*4`` bytes over
    the measured stream bandwidth); the Rust runtime measures the real
    wall-clock through PJRT, reproducing the paper's H100 GEMV gap study
    on our CPU substrate.
    """

    def fn(x, w):
        return (x @ w,)

    ex = (jnp.zeros((1, m), jnp.float32), jnp.zeros((m, n), jnp.float32))
    return fn, ex


# ---------------------------------------------------------------------------
# DeepSeek-style MLA decode step (absorbed latent attention, dense MLP).
# MoE routing is a coordinator-level (L3) concern in this repo; the
# executable model exercises the MLA cache mechanics the paper's capacity
# analysis hinges on: the per-token cache entry is a single [C = G + R]
# latent shared by all heads.
# ---------------------------------------------------------------------------

from .kernels import mla_decode  # noqa: E402


@dataclasses.dataclass(frozen=True)
class MlaDecodeConfig:
    """Scaled-down DeepSeek-style architecture."""

    num_layers: int = 4
    embed_dim: int = 256
    heads: int = 8
    q_latent: int = 64   # F
    kv_latent: int = 48  # G
    rope_dim: int = 16   # R
    intermediate_dim: int = 512
    vocab: int = 512
    context: int = 128

    @property
    def latent_dim(self) -> int:
        """C = G + R, the per-token cache width."""
        return self.kv_latent + self.rope_dim

    @property
    def kv_bytes_per_token(self) -> int:
        """FP32 latent-cache bytes per token across all layers — compare
        with ``DecodeConfig.kv_bytes_per_token`` to see MLA's shrink."""
        return self.latent_dim * 4 * self.num_layers


def init_mla_params(cfg: MlaDecodeConfig, key):
    """Random parameters, stacked per layer."""
    d, h, f, c, g, v = (cfg.embed_dim, cfg.heads, cfg.q_latent,
                        cfg.latent_dim, cfg.kv_latent, cfg.intermediate_dim)
    n = cfg.num_layers
    keys = jax.random.split(key, 9)

    def normal(kk, shape, fan_in):
        scale = 1.0 / jnp.sqrt(jnp.asarray(fan_in, jnp.float32))
        return jax.random.normal(kk, shape, jnp.float32) * scale

    return {
        "tok_embed": normal(keys[0], (cfg.vocab, d), d),
        "w_dq": normal(keys[1], (n, d, f), d),        # query down-proj
        "w_uq": normal(keys[2], (n, f, h * c), f),    # query up-proj (latent space)
        "w_dkv": normal(keys[3], (n, d, c), d),       # latent cache projection
        "w_o": normal(keys[4], (n, h * g, d), h * g), # output projection
        "w_gate": normal(keys[5], (n, d, v), d),
        "w_up": normal(keys[6], (n, d, v), d),
        "w_down": normal(keys[7], (n, v, d), v),
        "norm_attn": jnp.ones((n, d), jnp.float32),
        "norm_ffn": jnp.ones((n, d), jnp.float32),
        "norm_final": jnp.ones((d,), jnp.float32),
        "lm_head": normal(keys[8], (d, cfg.vocab), d),
    }


def _masked_mla_ref(cfg: MlaDecodeConfig, q_lat, cache, pos):
    """Oracle MLA attention with a dynamic length mask."""
    c = cfg.latent_dim
    s = jnp.einsum("bhc,btc->bht", q_lat, cache) / jnp.sqrt(
        jnp.asarray(c, jnp.float32)
    )
    mask = jnp.arange(cfg.context) < pos
    s = jnp.where(mask[None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bht,btg->bhg", p, cache[:, :, :cfg.kv_latent])


def mla_decode_step(cfg: MlaDecodeConfig, params, token_ids, latent_cache,
                    pos, *, use_pallas: bool = True):
    """One MLA decode step.

    Args:
      token_ids: ``[B]`` int32.
      latent_cache: ``[L, B, T, C]`` fp32 — note there is ONE cache (not
        K and V), the whole point of MLA.
      pos: scalar int32 tokens already cached.

    Returns:
      ``(logits [B, vocab], latent_cache)``.
    """
    b = token_ids.shape[0]
    h, g = cfg.heads, cfg.kv_latent

    x = params["tok_embed"][token_ids]

    def layer(x, layer_params):
        (w_dq, w_uq, w_dkv, w_o, w_gate, w_up, w_down, norm_attn, norm_ffn,
         cache) = layer_params
        xa = ref.rmsnorm_ref(x, norm_attn)
        q_lat = ((xa @ w_dq) @ w_uq).reshape(b, h, cfg.latent_dim)
        new_latent = xa @ w_dkv  # [B, C]
        cache = jax.lax.dynamic_update_slice(cache, new_latent[:, None],
                                             (0, pos, 0))
        if use_pallas:
            attn = mla_decode(q_lat, cache, g, pos=pos + 1)
        else:
            attn = _masked_mla_ref(cfg, q_lat, cache, pos + 1)
        x = x + attn.reshape(b, h * g) @ w_o
        xf = ref.rmsnorm_ref(x, norm_ffn)
        x = x + ref.swiglu_ref(xf, w_gate, w_up, w_down)
        return x, cache

    layer_params = (
        params["w_dq"], params["w_uq"], params["w_dkv"], params["w_o"],
        params["w_gate"], params["w_up"], params["w_down"],
        params["norm_attn"], params["norm_ffn"],
        latent_cache,
    )
    x, latent_cache = jax.lax.scan(layer, x, layer_params)
    logits = ref.rmsnorm_ref(x, params["norm_final"]) @ params["lm_head"]
    return logits, latent_cache


def make_mla_decode_fn(cfg: MlaDecodeConfig, batch: int, *,
                       use_pallas: bool = True):
    """Jit-able MLA decode fn + example args (for ``aot.py``)."""

    def fn(params, token_ids, latent_cache, pos):
        return mla_decode_step(cfg, params, token_ids, latent_cache, pos,
                               use_pallas=use_pallas)

    params = init_mla_params(cfg, jax.random.PRNGKey(1))
    example = (
        params,
        jnp.zeros((batch,), jnp.int32),
        jnp.zeros((cfg.num_layers, batch, cfg.context, cfg.latent_dim),
                  jnp.float32),
        jnp.asarray(0, jnp.int32),
    )
    return fn, example
