//! `perf-report`: the macro half of the tracked performance suite.
//!
//! Runs the cluster simulator end to end on fixed, seeded scenarios,
//! measures wall-clock per run, and reports DES throughput as
//! **events/second** plus the time-compression ratio
//! (**simulated seconds per wall second**). The workload is identical
//! across trials (same seed), so trial-to-trial spread is pure
//! machine noise and the p50 is a stable tracking number.
//!
//! Three scenario kinds separate the things this PR sequence
//! optimizes:
//!
//! * **Colocated 1/8/64-instance cells** run a single DES on one core —
//!   they track the scheduler itself (`jobs` is always 1, so a
//!   calendar-queue win shows here undiluted).
//! * **`grid-2r-124x`** runs a whole cluster-sweep grid
//!   (`run_cluster_grid`: instance counts 1/2/4 x two routers) through
//!   the `parallel_map` fan-out — it tracks grid-level parallel
//!   scaling on top of the scheduler (`jobs` records the worker
//!   count, and `sim_s_per_wall_s` aggregates across concurrent
//!   cells, so it exceeds the single-cell ratio when the fan-out is
//!   actually running cells concurrently).
//! * **`autoscaled-2to8x`** runs one elastic colocated cell (2
//!   instances growing toward an 8-instance ceiling under
//!   ceiling-level load, SLO router) — it tracks the autoscale path:
//!   per-window scale decisions, warm-up events, and billed
//!   instance-seconds accounting layered on the same DES.
//!
//! Output is the `liminal-perf/v2` JSON schema documented in
//! `perf/README.md`. Modes:
//!
//! * `perf-report --out BENCH_perf.json` — refresh the baseline.
//! * `perf-report --short --check BENCH_perf.json` — CI smoke: fewer
//!   and smaller trials, then fail if p50 events/sec regressed more
//!   than `--tolerance` (default 0.25) against the baseline. A
//!   baseline marked `"provisional": true` (recorded on a machine
//!   other than the CI runner class) warns instead of failing.

use std::time::Instant;

use liminal::apps::Registry;
use liminal::cluster::{
    AutoscalePolicy, ClusterMode, ClusterReport, ClusterSim, ClusterSpec,
    RoundRobin,
};
use liminal::coordinator::{default_cluster_job, serve_cluster, ClusterJob, RouterPolicy};
use liminal::hw::{presets, SystemConfig};
use liminal::serving::{
    percentile, AnalyticEngine, KvBudget, PreemptionConfig, SimConfig,
    StepEngine, WorkloadGen, WorkloadSpec,
};
use liminal::sweep::{run_cluster_grid, ClusterGrid};
use liminal::util::json::Json;
use liminal::util::par::default_jobs;

struct Opts {
    short: bool,
    check: Option<String>,
    out: Option<String>,
    tolerance: f64,
}

fn parse_args() -> Opts {
    let mut opts =
        Opts { short: false, check: None, out: None, tolerance: 0.25 };
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--short" => opts.short = true,
            "--check" => {
                opts.check = Some(args.next().expect("--check needs a path"))
            }
            "--out" => {
                opts.out = Some(args.next().expect("--out needs a path"))
            }
            "--tolerance" => {
                opts.tolerance = args
                    .next()
                    .expect("--tolerance needs a fraction")
                    .parse()
                    .expect("--tolerance needs a number")
            }
            other => {
                eprintln!(
                    "unknown argument {other}\n\
                     usage: perf-report [--short] [--check BASELINE] \
                     [--out PATH] [--tolerance FRAC]"
                );
                std::process::exit(2);
            }
        }
    }
    opts
}

/// What one macro scenario runs per trial.
enum Kind {
    /// One colocated cluster cell on one core: tracks the scheduler.
    Colocated { instances: usize },
    /// A full `run_cluster_grid` sweep through the parallel fan-out:
    /// tracks grid throughput and parallel scaling.
    Grid,
    /// An elastic colocated fleet (2 instances growing toward an
    /// 8-instance ceiling under ceiling-level load, SLO router): tracks
    /// the autoscale path — scale decisions, warm-up events, and
    /// billed-seconds accounting — on top of the scheduler.
    Autoscaled,
    /// A KV-starved 2-instance cell with a mixed-priority stream and
    /// preemption enabled: tracks the priority admission queue plus the
    /// evict/restore machinery under sustained KV pressure (the budget
    /// is clamped so evictions actually fire every trial).
    PreemptMix,
}

struct Scenario {
    name: &'static str,
    kind: Kind,
}

const SCENARIOS: [Scenario; 6] = [
    Scenario { name: "colocated-1x", kind: Kind::Colocated { instances: 1 } },
    Scenario { name: "colocated-8x", kind: Kind::Colocated { instances: 8 } },
    Scenario { name: "colocated-64x", kind: Kind::Colocated { instances: 64 } },
    Scenario { name: "grid-2r-124x", kind: Kind::Grid },
    Scenario { name: "autoscaled-2to8x", kind: Kind::Autoscaled },
    Scenario { name: "preempt-mix", kind: Kind::PreemptMix },
];

/// Instance counts and router count of the grid scenario.
const GRID_COUNTS: [usize; 3] = [1, 2, 4];
const GRID_ROUTERS: [RouterPolicy; 2] =
    [RouterPolicy::RoundRobin, RouterPolicy::LeastTokens];

/// A colocated cluster cell at a fixed request rate per instance, so
/// every size runs at the same per-instance load and the scaling axis
/// isolates the simulator's own overhead.
fn scenario_job(instances: usize, reqs_per_instance: u64) -> ClusterJob {
    let mut job = default_cluster_job(
        "llama3-70b",
        SystemConfig::new(presets::hbm3(), 8, 1),
    );
    job.instances = instances;
    job.max_batch = 16;
    job.prefill_chunk = 512;
    job.workload = WorkloadSpec {
        arrival_rate: 40.0 * instances as f64,
        n_requests: reqs_per_instance * instances as u64,
        context: (256, 1024),
        gen: (64, 192),
        priority_mix: Vec::new(),
        seed: 7,
    };
    job
}

/// The grid scenario: scale-load cells over `GRID_COUNTS x GRID_ROUTERS`
/// with the same per-instance pressure as the colocated scenarios.
fn scenario_grid(reqs_per_instance: u64) -> ClusterGrid {
    ClusterGrid {
        base: scenario_job(1, reqs_per_instance),
        instance_counts: GRID_COUNTS.to_vec(),
        routers: GRID_ROUTERS.to_vec(),
        autoscale: vec![None],
        priority_mixes: vec![Vec::new()],
        scale_load: true,
    }
}

/// The preempt-mix scenario: a 2-instance colocated cell whose KV
/// budget holds only a couple of concurrent requests per instance,
/// offered a mixed-priority stream with preemption enabled. The
/// coordinator path keeps the model's real (never-binding) KV budget,
/// so this scenario builds the sim directly with a clamped budget —
/// urgent arrivals hit a full budget every trial and the evict/restore
/// path runs inside the measured loop, not just the priority queue.
fn scenario_preempt(reqs_per_instance: u64) -> (ClusterReport, u64) {
    let registry = Registry::builtin();
    let app = registry.app("llama3-70b").expect("registry model");
    let sys = SystemConfig::new(presets::hbm3(), 8, 1);
    let bpt = app.kv_bytes_per_token();
    let instances = 2usize;
    let engines: Vec<Box<dyn StepEngine>> = (0..instances)
        .map(|_| {
            Box::new(AnalyticEngine::new(app.clone(), sys.clone()))
                as Box<dyn StepEngine>
        })
        .collect();
    let mut sim = ClusterSim::new(
        engines,
        KvBudget::new(4096.0 * bpt, 0.0, bpt),
        Box::new(RoundRobin::new()),
        ClusterSpec {
            mode: ClusterMode::Colocated,
            max_batch: 16,
            prefill_chunk: 512,
            kv_link_bw: f64::INFINITY,
            autoscale: None,
            sim: SimConfig::default(),
        },
    );
    sim.set_preemption(PreemptionConfig {
        enabled: true,
        evict_cost: 0.002,
        restore_cost: 0.005,
    });
    let n = reqs_per_instance * instances as u64;
    let workload = WorkloadGen::new(WorkloadSpec {
        arrival_rate: 8.0 * instances as f64,
        n_requests: n,
        context: (512, 2048),
        gen: (32, 128),
        priority_mix: vec![(0, 4.0), (2, 1.0)],
        seed: 23,
    })
    .generate();
    (sim.run(workload), n)
}

/// The autoscale scenario: ceiling-level load offered to a fleet that
/// starts at 2 instances, so the run exercises growth, warm-up, and
/// (after the arrival tail) idle shrink on every trial.
fn scenario_autoscaled(reqs_per_instance: u64) -> ClusterJob {
    let mut job = scenario_job(8, reqs_per_instance);
    job.instances = 2;
    job.router = RouterPolicy::SloAware;
    job.autoscale = Some(AutoscalePolicy {
        min_instances: 2,
        max_instances: 8,
        warmup_delay: 0.5,
        ..AutoscalePolicy::default()
    });
    job
}

struct ScenarioResult {
    name: &'static str,
    instances: usize,
    requests: u64,
    /// Workers driving the scenario (1 for single-cell scenarios, the
    /// `parallel_map` worker count for the grid fan-out).
    jobs: usize,
    /// DES events applied per run (identical across trials: the
    /// workload is seeded and the simulator is deterministic).
    events: u64,
    wall_s: Vec<f64>,
    events_per_sec: Vec<f64>,
    sim_s_per_wall_s: Vec<f64>,
}

fn run_scenario(s: &Scenario, trials: usize, reqs_per_instance: u64) -> ScenarioResult {
    let mut res = ScenarioResult {
        name: s.name,
        instances: 0,
        requests: 0,
        jobs: 1,
        events: 0,
        wall_s: Vec::with_capacity(trials),
        events_per_sec: Vec::with_capacity(trials),
        sim_s_per_wall_s: Vec::with_capacity(trials),
    };
    for _ in 0..trials {
        match s.kind {
            Kind::Colocated { instances } => {
                let job = scenario_job(instances, reqs_per_instance);
                res.instances = instances;
                res.requests = job.workload.n_requests;
                let t0 = Instant::now();
                let rep = serve_cluster(&job).expect("scenario job runs");
                let wall = t0.elapsed().as_secs_f64().max(1e-9);
                res.events = rep.events;
                res.wall_s.push(wall);
                res.events_per_sec.push(rep.events as f64 / wall);
                res.sim_s_per_wall_s.push(rep.cluster.span / wall);
            }
            Kind::Autoscaled => {
                let job = scenario_autoscaled(reqs_per_instance);
                res.instances = job.instances;
                res.requests = job.workload.n_requests;
                let t0 = Instant::now();
                let rep = serve_cluster(&job).expect("autoscale scenario runs");
                let wall = t0.elapsed().as_secs_f64().max(1e-9);
                res.events = rep.events;
                res.wall_s.push(wall);
                res.events_per_sec.push(rep.events as f64 / wall);
                res.sim_s_per_wall_s.push(rep.cluster.span / wall);
            }
            Kind::PreemptMix => {
                let t0 = Instant::now();
                let (rep, n) = scenario_preempt(reqs_per_instance);
                let wall = t0.elapsed().as_secs_f64().max(1e-9);
                assert!(
                    rep.cluster.preemptions > 0,
                    "preempt-mix scenario ran without a single eviction; \
                     it is no longer measuring the preemption path"
                );
                res.instances = 2;
                res.requests = n;
                res.events = rep.events;
                res.wall_s.push(wall);
                res.events_per_sec.push(rep.events as f64 / wall);
                res.sim_s_per_wall_s.push(rep.cluster.span / wall);
            }
            Kind::Grid => {
                let grid = scenario_grid(reqs_per_instance);
                let cells: usize = GRID_COUNTS.len() * GRID_ROUTERS.len();
                res.instances = GRID_COUNTS.iter().sum();
                res.jobs = default_jobs().min(cells);
                let t0 = Instant::now();
                let recs = run_cluster_grid(&grid).expect("grid scenario runs");
                let wall = t0.elapsed().as_secs_f64().max(1e-9);
                res.requests = recs.iter().map(|r| r.completed + r.shed).sum();
                res.events = recs.iter().map(|r| r.events).sum();
                let span: f64 = recs.iter().map(|r| r.span).sum();
                res.wall_s.push(wall);
                res.events_per_sec.push(res.events as f64 / wall);
                res.sim_s_per_wall_s.push(span / wall);
            }
        }
    }
    res
}

fn dist_json(samples: &[f64]) -> Json {
    let mut v = samples.to_vec();
    let p50 = percentile(&mut v, 50.0);
    let p99 = percentile(&mut v, 99.0);
    Json::obj(vec![("p50", Json::Num(p50)), ("p99", Json::Num(p99))])
}

fn report_json(results: &[ScenarioResult], short: bool) -> Json {
    Json::obj(vec![
        ("schema", Json::Str("liminal-perf/v2".into())),
        ("mode", Json::Str(if short { "short" } else { "full" }.into())),
        ("provisional", Json::Bool(false)),
        (
            "scenarios",
            Json::Arr(
                results
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("name", Json::Str(r.name.into())),
                            ("instances", Json::Num(r.instances as f64)),
                            ("requests", Json::Num(r.requests as f64)),
                            (
                                "trials",
                                Json::Num(r.events_per_sec.len() as f64),
                            ),
                            ("jobs", Json::Num(r.jobs as f64)),
                            ("events", Json::Num(r.events as f64)),
                            ("wall_s", dist_json(&r.wall_s)),
                            ("events_per_sec", dist_json(&r.events_per_sec)),
                            (
                                "sim_s_per_wall_s",
                                dist_json(&r.sim_s_per_wall_s),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Compare current p50 events/sec per scenario against a baseline
/// report. Returns the failure messages (empty = pass). A provisional
/// baseline downgrades failures to warnings.
fn check_against(
    baseline: &Json,
    results: &[ScenarioResult],
    tolerance: f64,
) -> (Vec<String>, bool) {
    let provisional = matches!(
        baseline.get("provisional"),
        Some(Json::Bool(true))
    );
    let mut failures = Vec::new();
    let empty: [Json; 0] = [];
    let base_scenarios = baseline
        .get("scenarios")
        .and_then(|s| s.as_arr())
        .unwrap_or(&empty);
    for r in results {
        let base = base_scenarios.iter().find(|b| {
            b.get("name").and_then(|n| n.as_str()) == Some(r.name)
        });
        let Some(base) = base else {
            eprintln!("warning: scenario {} missing from baseline", r.name);
            continue;
        };
        let Some(base_p50) = base
            .get("events_per_sec")
            .and_then(|d| d.get("p50"))
            .and_then(|p| p.as_f64())
        else {
            eprintln!("warning: baseline {} has no events_per_sec.p50", r.name);
            continue;
        };
        let mut v = r.events_per_sec.clone();
        let cur_p50 = percentile(&mut v, 50.0);
        if cur_p50 < base_p50 * (1.0 - tolerance) {
            failures.push(format!(
                "{}: p50 {:.0} events/s is {:.0}% below baseline {:.0} \
                 (tolerance {:.0}%)",
                r.name,
                cur_p50,
                (1.0 - cur_p50 / base_p50) * 100.0,
                base_p50,
                tolerance * 100.0,
            ));
        }
    }
    (failures, provisional)
}

fn main() {
    let opts = parse_args();
    let (trials, reqs_per_instance) =
        if opts.short { (3, 50) } else { (7, 150) };

    let mut results = Vec::new();
    for s in &SCENARIOS {
        let r = run_scenario(s, trials, reqs_per_instance);
        let mut eps = r.events_per_sec.clone();
        let mut spw = r.sim_s_per_wall_s.clone();
        let mut wall = r.wall_s.clone();
        println!(
            "{:<14} {:>3} inst  {:>6} reqs  {:>9} events  jobs {:>2}  \
             wall_s {:>7.3}  p50 {:>10.0} events/s  p99 {:>10.0}  \
             {:>8.1} sim-s/wall-s",
            r.name,
            r.instances,
            r.requests,
            r.events,
            r.jobs,
            percentile(&mut wall, 50.0),
            percentile(&mut eps, 50.0),
            percentile(&mut eps, 99.0),
            percentile(&mut spw, 50.0),
        );
        results.push(r);
    }

    let report = report_json(&results, opts.short);
    if let Some(path) = &opts.out {
        std::fs::write(path, format!("{report}\n"))
            .unwrap_or_else(|e| panic!("writing {path}: {e}"));
        println!("wrote {path}");
    }

    if let Some(path) = &opts.check {
        let text = std::fs::read_to_string(path)
            .unwrap_or_else(|e| panic!("reading baseline {path}: {e}"));
        let baseline = Json::parse(&text)
            .unwrap_or_else(|e| panic!("parsing baseline {path}: {e}"));
        let (failures, provisional) =
            check_against(&baseline, &results, opts.tolerance);
        if failures.is_empty() {
            println!("perf check vs {path}: ok");
        } else if provisional {
            for f in &failures {
                eprintln!("warning (provisional baseline): {f}");
            }
            println!(
                "perf check vs {path}: {} regression(s) ignored — baseline \
                 is provisional; refresh it on this runner class",
                failures.len()
            );
        } else {
            for f in &failures {
                eprintln!("perf regression: {f}");
            }
            std::process::exit(1);
        }
    }
}
