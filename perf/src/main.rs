//! `perf-report`: the macro half of the tracked performance suite.
//!
//! Runs the cluster simulator end to end on fixed, seeded scenarios
//! (1, 8, and 64 colocated instances of llama3-70b on an HBM3 TP-8
//! system), measures wall-clock per run, and reports DES throughput as
//! **events/second** plus the time-compression ratio
//! (**simulated seconds per wall second**). The workload is identical
//! across trials (same seed), so trial-to-trial spread is pure
//! machine noise and the p50 is a stable tracking number.
//!
//! Output is the `liminal-perf/v1` JSON schema documented in
//! `perf/README.md`. Modes:
//!
//! * `perf-report --out BENCH_perf.json` — refresh the baseline.
//! * `perf-report --short --check BENCH_perf.json` — CI smoke: fewer
//!   and smaller trials, then fail if p50 events/sec regressed more
//!   than `--tolerance` (default 0.25) against the baseline. A
//!   baseline marked `"provisional": true` (recorded on a machine
//!   other than the CI runner class) warns instead of failing.

use std::time::Instant;

use liminal::coordinator::{default_cluster_job, serve_cluster, ClusterJob};
use liminal::hw::{presets, SystemConfig};
use liminal::serving::{percentile, WorkloadSpec};
use liminal::util::json::Json;

struct Opts {
    short: bool,
    check: Option<String>,
    out: Option<String>,
    tolerance: f64,
}

fn parse_args() -> Opts {
    let mut opts =
        Opts { short: false, check: None, out: None, tolerance: 0.25 };
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--short" => opts.short = true,
            "--check" => {
                opts.check = Some(args.next().expect("--check needs a path"))
            }
            "--out" => {
                opts.out = Some(args.next().expect("--out needs a path"))
            }
            "--tolerance" => {
                opts.tolerance = args
                    .next()
                    .expect("--tolerance needs a fraction")
                    .parse()
                    .expect("--tolerance needs a number")
            }
            other => {
                eprintln!(
                    "unknown argument {other}\n\
                     usage: perf-report [--short] [--check BASELINE] \
                     [--out PATH] [--tolerance FRAC]"
                );
                std::process::exit(2);
            }
        }
    }
    opts
}

/// One macro scenario: a colocated cluster at a fixed request rate per
/// instance, so every size runs at the same per-instance load and the
/// scaling axis isolates the simulator's own overhead.
struct Scenario {
    name: &'static str,
    instances: usize,
}

const SCENARIOS: [Scenario; 3] = [
    Scenario { name: "colocated-1x", instances: 1 },
    Scenario { name: "colocated-8x", instances: 8 },
    Scenario { name: "colocated-64x", instances: 64 },
];

fn scenario_job(instances: usize, reqs_per_instance: u64) -> ClusterJob {
    let mut job = default_cluster_job(
        "llama3-70b",
        SystemConfig::new(presets::hbm3(), 8, 1),
    );
    job.instances = instances;
    job.max_batch = 16;
    job.prefill_chunk = 512;
    job.workload = WorkloadSpec {
        arrival_rate: 40.0 * instances as f64,
        n_requests: reqs_per_instance * instances as u64,
        context: (256, 1024),
        gen: (64, 192),
        seed: 7,
    };
    job
}

struct ScenarioResult {
    name: &'static str,
    instances: usize,
    requests: u64,
    /// DES events applied per run (identical across trials: the
    /// workload is seeded and the simulator is deterministic).
    events: u64,
    events_per_sec: Vec<f64>,
    sim_s_per_wall_s: Vec<f64>,
}

fn run_scenario(s: &Scenario, trials: usize, reqs_per_instance: u64) -> ScenarioResult {
    let mut res = ScenarioResult {
        name: s.name,
        instances: s.instances,
        requests: reqs_per_instance * s.instances as u64,
        events: 0,
        events_per_sec: Vec::with_capacity(trials),
        sim_s_per_wall_s: Vec::with_capacity(trials),
    };
    for _ in 0..trials {
        let job = scenario_job(s.instances, reqs_per_instance);
        let t0 = Instant::now();
        let rep = serve_cluster(&job).expect("scenario job runs");
        let wall = t0.elapsed().as_secs_f64().max(1e-9);
        res.events = rep.events;
        res.events_per_sec.push(rep.events as f64 / wall);
        res.sim_s_per_wall_s.push(rep.cluster.span / wall);
    }
    res
}

fn dist_json(samples: &[f64]) -> Json {
    let mut v = samples.to_vec();
    let p50 = percentile(&mut v, 50.0);
    let p99 = percentile(&mut v, 99.0);
    Json::obj(vec![("p50", Json::Num(p50)), ("p99", Json::Num(p99))])
}

fn report_json(results: &[ScenarioResult], short: bool) -> Json {
    Json::obj(vec![
        ("schema", Json::Str("liminal-perf/v1".into())),
        ("mode", Json::Str(if short { "short" } else { "full" }.into())),
        ("provisional", Json::Bool(false)),
        (
            "scenarios",
            Json::Arr(
                results
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("name", Json::Str(r.name.into())),
                            ("instances", Json::Num(r.instances as f64)),
                            ("requests", Json::Num(r.requests as f64)),
                            (
                                "trials",
                                Json::Num(r.events_per_sec.len() as f64),
                            ),
                            ("events", Json::Num(r.events as f64)),
                            ("events_per_sec", dist_json(&r.events_per_sec)),
                            (
                                "sim_s_per_wall_s",
                                dist_json(&r.sim_s_per_wall_s),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Compare current p50 events/sec per scenario against a baseline
/// report. Returns the failure messages (empty = pass). A provisional
/// baseline downgrades failures to warnings.
fn check_against(
    baseline: &Json,
    results: &[ScenarioResult],
    tolerance: f64,
) -> (Vec<String>, bool) {
    let provisional = matches!(
        baseline.get("provisional"),
        Some(Json::Bool(true))
    );
    let mut failures = Vec::new();
    let empty: [Json; 0] = [];
    let base_scenarios = baseline
        .get("scenarios")
        .and_then(|s| s.as_arr())
        .unwrap_or(&empty);
    for r in results {
        let base = base_scenarios.iter().find(|b| {
            b.get("name").and_then(|n| n.as_str()) == Some(r.name)
        });
        let Some(base) = base else {
            eprintln!("warning: scenario {} missing from baseline", r.name);
            continue;
        };
        let Some(base_p50) = base
            .get("events_per_sec")
            .and_then(|d| d.get("p50"))
            .and_then(|p| p.as_f64())
        else {
            eprintln!("warning: baseline {} has no events_per_sec.p50", r.name);
            continue;
        };
        let mut v = r.events_per_sec.clone();
        let cur_p50 = percentile(&mut v, 50.0);
        if cur_p50 < base_p50 * (1.0 - tolerance) {
            failures.push(format!(
                "{}: p50 {:.0} events/s is {:.0}% below baseline {:.0} \
                 (tolerance {:.0}%)",
                r.name,
                cur_p50,
                (1.0 - cur_p50 / base_p50) * 100.0,
                base_p50,
                tolerance * 100.0,
            ));
        }
    }
    (failures, provisional)
}

fn main() {
    let opts = parse_args();
    let (trials, reqs_per_instance) =
        if opts.short { (3, 50) } else { (7, 150) };

    let mut results = Vec::new();
    for s in &SCENARIOS {
        let r = run_scenario(s, trials, reqs_per_instance);
        let mut eps = r.events_per_sec.clone();
        let mut spw = r.sim_s_per_wall_s.clone();
        println!(
            "{:<14} {:>3} inst  {:>6} reqs  {:>9} events  \
             p50 {:>10.0} events/s  p99 {:>10.0}  {:>8.1} sim-s/wall-s",
            r.name,
            r.instances,
            r.requests,
            r.events,
            percentile(&mut eps, 50.0),
            percentile(&mut eps, 99.0),
            percentile(&mut spw, 50.0),
        );
        results.push(r);
    }

    let report = report_json(&results, opts.short);
    if let Some(path) = &opts.out {
        std::fs::write(path, format!("{report}\n"))
            .unwrap_or_else(|e| panic!("writing {path}: {e}"));
        println!("wrote {path}");
    }

    if let Some(path) = &opts.check {
        let text = std::fs::read_to_string(path)
            .unwrap_or_else(|e| panic!("reading baseline {path}: {e}"));
        let baseline = Json::parse(&text)
            .unwrap_or_else(|e| panic!("parsing baseline {path}: {e}"));
        let (failures, provisional) =
            check_against(&baseline, &results, opts.tolerance);
        if failures.is_empty() {
            println!("perf check vs {path}: ok");
        } else if provisional {
            for f in &failures {
                eprintln!("warning (provisional baseline): {f}");
            }
            println!(
                "perf check vs {path}: {} regression(s) ignored — baseline \
                 is provisional; refresh it on this runner class",
                failures.len()
            );
        } else {
            for f in &failures {
                eprintln!("perf regression: {f}");
            }
            std::process::exit(1);
        }
    }
}
