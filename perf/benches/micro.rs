//! Micro-benchmarks over the simulator hot path, on the in-repo
//! harness ([`liminal::util::bench::Suite`], `harness = false`). Run
//! with `cargo bench -p liminal-perf [filter]`; each line reports
//! min/median/mean per iteration and appends a JSON row to
//! `target/liminal-bench.jsonl`.
//!
//! These isolate the costs the arena and calendar-queue refactors
//! target: calendar push/pop (including a side-by-side binary-heap
//! reference and a bimodal-schedule-time stress), batch planning,
//! analytic step pricing, and request-state churn. The macro numbers
//! (whole cluster runs) live in `perf-report`; regressions caught here
//! localize which layer moved.

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::hint::black_box;

use liminal::apps::Registry;
use liminal::des::EventQueue;
use liminal::hw::{presets, SystemConfig};
use liminal::serving::{
    AnalyticEngine, Batcher, KvBudget, Request, RequestArena, StepEngine,
};
use liminal::util::bench::Suite;

/// The pre-calendar binary-heap scheduler, kept verbatim as the
/// comparison baseline for the `des/*` benches (the property test in
/// `rust/tests/property_des.rs` pins the two to identical behavior;
/// this pins the speed ratio).
struct HeapScheduled {
    at: f64,
    seq: u64,
    event: u32,
}
impl PartialEq for HeapScheduled {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for HeapScheduled {}
impl PartialOrd for HeapScheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapScheduled {
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .at
            .total_cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

struct HeapQueue {
    heap: BinaryHeap<HeapScheduled>,
    now: f64,
    seq: u64,
}
impl HeapQueue {
    fn new() -> HeapQueue {
        HeapQueue { heap: BinaryHeap::new(), now: 0.0, seq: 0 }
    }
    fn schedule_at(&mut self, at: f64, event: u32) {
        self.heap.push(HeapScheduled {
            at: at.max(self.now),
            seq: self.seq,
            event,
        });
        self.seq += 1;
    }
    fn next(&mut self) -> Option<(f64, u32)> {
        let s = self.heap.pop()?;
        self.now = s.at;
        Some((s.at, s.event))
    }
}

/// Bimodal schedule times — most events a short hop past now, a tail
/// two decades farther out — the shape that stresses the calendar's
/// overflow rung and respan policy, interleaved schedule/pop like a
/// live DES.
fn bimodal_at(now: f64, i: u32) -> f64 {
    if i % 16 == 0 {
        now + 100.0 + f64::from(i % 7)
    } else {
        now + 0.001 * f64::from(i % 97)
    }
}

fn req(id: u64, ctx: u64, gen: u64) -> Request {
    Request {
        id,
        arrival: 0.0,
        context_len: ctx,
        gen_len: gen,
        priority: 0,
        generated: 0,
        prefilled: 0,
        scheduled_prefill: 0,
        admitted_at: None,
        first_token_at: None,
        completed_at: None,
    }
}

fn main() {
    let mut suite = Suite::from_args();

    suite.bench("des/event_queue_push_pop_1k", || {
        let mut q: EventQueue<u32> = EventQueue::new();
        for i in 0..1000u32 {
            q.schedule_at(f64::from(i % 97), i);
        }
        while let Some(ev) = q.next() {
            black_box(ev);
        }
    });

    // The same workload on the old binary heap: the heap-vs-calendar
    // ratio is the headline number of the scheduler swap.
    suite.bench("des/heap_reference_push_pop_1k", || {
        let mut q = HeapQueue::new();
        for i in 0..1000u32 {
            q.schedule_at(f64::from(i % 97), i);
        }
        while let Some(ev) = q.next() {
            black_box(ev);
        }
    });

    // Bimodal interleaved schedule/pop: ~500 resident events, every pop
    // schedules a successor, 1 in 16 lands far out on the overflow rung.
    suite.bench("des/event_queue_bimodal_interleaved_8k", || {
        let mut q: EventQueue<u32> = EventQueue::new();
        for i in 0..500u32 {
            q.schedule_at(bimodal_at(0.0, i), i);
        }
        let mut i = 500u32;
        while let Some((t, e)) = q.next() {
            black_box(e);
            if i < 8000 {
                q.schedule_at(bimodal_at(t, i), i);
                i += 1;
            }
        }
    });

    suite.bench("des/heap_reference_bimodal_interleaved_8k", || {
        let mut q = HeapQueue::new();
        for i in 0..500u32 {
            q.schedule_at(bimodal_at(0.0, i), i);
        }
        let mut i = 500u32;
        while let Some((t, e)) = q.next() {
            black_box(e);
            if i < 8000 {
                q.schedule_at(bimodal_at(t, i), i);
                i += 1;
            }
        }
    });

    // A full decode batch that never retires: plan_step runs the
    // steady-state planning path every iteration.
    let mut arena = RequestArena::new();
    let mut batcher = Batcher::new(64, KvBudget::new(f64::INFINITY, 0.0, 1.0));
    for i in 0..64 {
        let id = arena.alloc(req(i, 512, 1_000_000));
        batcher.enqueue(id);
    }
    batcher.admit(0.0, &mut arena);
    suite.bench("serving/batcher_plan_64_decode_lanes", || {
        black_box(batcher.plan_step(&mut arena));
    });

    let app = Registry::builtin().app("llama3-70b").expect("builtin model");
    let mut engine =
        AnalyticEngine::new(app, SystemConfig::new(presets::hbm3(), 8, 1));
    let plan = batcher.plan_step(&mut arena);
    suite.bench("serving/analytic_step_price_64_lanes", || {
        black_box(engine.mixed_step_latency(black_box(&plan)));
    });

    suite.bench("serving/arena_alloc_touch_1k", || {
        let mut a = RequestArena::with_capacity(1000);
        for i in 0..1000u64 {
            let id = a.alloc(req(i, 128, 16));
            a[id].generated += 1;
        }
        black_box(a.len());
    });
}
