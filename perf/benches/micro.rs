//! Micro-benchmarks over the simulator hot path, on the in-repo
//! harness ([`liminal::util::bench::Suite`], `harness = false`). Run
//! with `cargo bench -p liminal-perf [filter]`; each line reports
//! min/median/mean per iteration and appends a JSON row to
//! `target/liminal-bench.jsonl`.
//!
//! These isolate the four costs the arena refactor targets: calendar
//! push/pop, batch planning, analytic step pricing, and request-state
//! churn. The macro numbers (whole cluster runs) live in
//! `perf-report`; regressions caught here localize which layer moved.

use std::hint::black_box;

use liminal::apps::Registry;
use liminal::des::EventQueue;
use liminal::hw::{presets, SystemConfig};
use liminal::serving::{
    AnalyticEngine, Batcher, KvBudget, Request, RequestArena, StepEngine,
};
use liminal::util::bench::Suite;

fn req(id: u64, ctx: u64, gen: u64) -> Request {
    Request {
        id,
        arrival: 0.0,
        context_len: ctx,
        gen_len: gen,
        generated: 0,
        prefilled: 0,
        scheduled_prefill: 0,
        admitted_at: None,
        first_token_at: None,
        completed_at: None,
    }
}

fn main() {
    let mut suite = Suite::from_args();

    suite.bench("des/event_queue_push_pop_1k", || {
        let mut q: EventQueue<u32> = EventQueue::new();
        for i in 0..1000u32 {
            q.schedule_at(f64::from(i % 97), i);
        }
        while let Some(ev) = q.next() {
            black_box(ev);
        }
    });

    // A full decode batch that never retires: plan_step runs the
    // steady-state planning path every iteration.
    let mut arena = RequestArena::new();
    let mut batcher = Batcher::new(64, KvBudget::new(f64::INFINITY, 0.0, 1.0));
    for i in 0..64 {
        let id = arena.alloc(req(i, 512, 1_000_000));
        batcher.enqueue(id);
    }
    batcher.admit(0.0, &mut arena);
    suite.bench("serving/batcher_plan_64_decode_lanes", || {
        black_box(batcher.plan_step(&mut arena));
    });

    let app = Registry::builtin().app("llama3-70b").expect("builtin model");
    let mut engine =
        AnalyticEngine::new(app, SystemConfig::new(presets::hbm3(), 8, 1));
    let plan = batcher.plan_step(&mut arena);
    suite.bench("serving/analytic_step_price_64_lanes", || {
        black_box(engine.mixed_step_latency(black_box(&plan)));
    });

    suite.bench("serving/arena_alloc_touch_1k", || {
        let mut a = RequestArena::with_capacity(1000);
        for i in 0..1000u64 {
            let id = a.alloc(req(i, 128, 16));
            a[id].generated += 1;
        }
        black_box(a.len());
    });
}
