//! Hardware design explorer: the paper's core what-if loop — pick a
//! hypothetical chip (bandwidth, capacity, sync fabric) and see what it
//! buys across models, before anyone tapes anything out.
//!
//! Sweeps a small design space and prints the Pareto frontier of
//! (UTPS, STPS/W) for Llama3-405B at 128K context.

use liminal::apps::{DecodePoint, Registry};
use liminal::hw::{presets, SyncModel};
use liminal::model::{evaluate, EvalOptions};
use liminal::parallel::{fit_system, FitRequest};
use liminal::power::PowerModel;

fn main() -> anyhow::Result<()> {
    let registry = Registry::builtin();
    let app = registry.app("llama3-405b").unwrap();
    let pt = DecodePoint { batch: 1, context: 131072 };
    let power = PowerModel::default();

    println!("design space: bandwidth x capacity x sync fabric (TP128)");
    println!(
        "{:<34} {:>9} {:>12} {:>10}",
        "design", "UTPS", "STPS/W @max", "chips"
    );

    let mut frontier: Vec<(String, f64, f64)> = Vec::new();
    for bw_tbps in [4.4, 9.0, 18.0, 33.0, 117.0] {
        for cap_gib in [16.0, 96.0, 192.0] {
            for (fabric, sync) in [
                ("cxl", SyncModel::Tiered { le16: 200e-9, gt16: 1.5e-6 }),
                ("optical", SyncModel::Flat(400e-9)),
            ] {
                let mut chip = presets::hbm3();
                chip.name = format!("x{bw_tbps:.0}T-{cap_gib:.0}G-{fabric}");
                chip.mem_bw = bw_tbps * liminal::TBPS;
                chip.mem_capacity = cap_gib * liminal::GIB;
                chip.sync = sync;

                let Ok(sys) = fit_system(
                    app.as_ref(),
                    &FitRequest { tp: Some(128), ..FitRequest::new(chip, pt) },
                ) else {
                    continue;
                };
                let Ok(p1) = evaluate(app.as_ref(), &sys, &pt, &EvalOptions::default())
                else {
                    continue;
                };
                // Efficiency at the capacity-max batch.
                let bmax =
                    liminal::model::max_batch_for_system(app.as_ref(), &sys, pt.context)
                        .unwrap_or(1);
                let pmax = evaluate(
                    app.as_ref(),
                    &sys,
                    &DecodePoint { batch: bmax, context: pt.context },
                    &EvalOptions::default(),
                )?;
                let spw = pmax.stps / power.system_power(&sys).total_watts;
                println!(
                    "{:<34} {:>9.0} {:>12.3} {:>10}",
                    sys.label(),
                    p1.utps,
                    spw,
                    sys.n_chips()
                );
                frontier.push((sys.label(), p1.utps, spw));
            }
        }
    }

    // Pareto: keep designs not dominated in (UTPS, STPS/W).
    frontier.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    let mut best_spw = f64::MIN;
    println!("\nPareto frontier (UTPS vs STPS/W):");
    for (name, utps, spw) in frontier {
        if spw > best_spw {
            println!("  {name}: {utps:.0} UTPS, {spw:.3} STPS/W");
            best_spw = spw;
        }
    }
    Ok(())
}
