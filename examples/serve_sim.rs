//! Serving simulation: dynamic continuous batching on a paper-scale
//! system, showing how queueing + batching turn the paper's steady-state
//! numbers into user-visible behavior — and, if AOT artifacts exist, the
//! same scheduler driving the real PJRT decode engine.
//!
//! Run with: cargo run --release --example serve_sim

use liminal::coordinator::{default_job, serve, Backend};
use liminal::hw::{presets, SystemConfig};

fn main() -> anyhow::Result<()> {
    // Analytic backend: Llama3-70B on HBM3-TP128 under rising load.
    for rate in [50.0, 200.0, 800.0] {
        let sys = SystemConfig::new(presets::hbm3(), 128, 1);
        let mut job = default_job("llama3-70b", sys);
        job.workload.arrival_rate = rate;
        job.workload.n_requests = 300;
        job.max_batch = 64;
        let rep = serve(&job)?;
        println!("rate {rate:>5.0} req/s -> {}", rep.summary());
    }

    // PJRT backend: the real AOT decode step, if artifacts are built.
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let sys = SystemConfig::new(presets::hbm3(), 1, 1); // ignored by pjrt
        let mut job = default_job("llama3-70b", sys);
        job.backend = Backend::Pjrt;
        job.max_batch = 8;
        job.workload.n_requests = 24;
        job.workload.arrival_rate = 100.0;
        let rep = serve(&job)?;
        println!("pjrt backend -> {}", rep.summary());
    } else {
        println!("(skipping PJRT backend: run `make artifacts` first)");
    }
    Ok(())
}
