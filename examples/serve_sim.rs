//! Serving simulation: dynamic continuous batching on a paper-scale
//! system, covering the full request lifecycle — prompts are ingested
//! in prefill chunks before decode, and the report carries the
//! TTFT / TPOT / E2E SLO percentiles that steady-state tables cannot
//! express. The same instance state machine then scales out: N
//! instances behind a router on one event calendar, colocated or
//! disaggregated into prefill/decode pools with modeled KV shipment.
//! If AOT artifacts exist, the scheduler also drives the real PJRT
//! decode engine.
//!
//! Run with: cargo run --release --example serve_sim

use liminal::coordinator::{
    default_cluster_job, default_job, serve, serve_cluster, Backend,
    RouterPolicy,
};
use liminal::hw::{presets, SystemConfig};

fn main() -> anyhow::Result<()> {
    // Analytic backend: Llama3-70B on HBM3-TP128 under rising load,
    // prefill-aware (1024-token chunks by default).
    for rate in [50.0, 200.0, 800.0] {
        let sys = SystemConfig::new(presets::hbm3(), 128, 1);
        let mut job = default_job("llama3-70b", sys);
        job.workload.arrival_rate = rate;
        job.workload.n_requests = 300;
        job.max_batch = 64;
        let rep = serve(&job)?;
        println!("rate {rate:>5.0} req/s -> {}", rep.summary());
        for line in rep.slo_summary().lines() {
            println!("    {line}");
        }
    }

    // The same load with prefill disabled shows what the decode-only
    // idealization hides: TTFT collapses to a single queue+step delay.
    let sys = SystemConfig::new(presets::hbm3(), 128, 1);
    let mut job = default_job("llama3-70b", sys);
    job.workload.arrival_rate = 200.0;
    job.workload.n_requests = 300;
    job.max_batch = 64;
    job.prefill_chunk = 0;
    let rep = serve(&job)?;
    println!("decode-only baseline  -> {}", rep.summary());
    println!("    TTFT p50 {:.4}s (no prefill modeled)", rep.ttft.p50);

    // Scale-out: the same workload shape on 1/2/4/8 TP8 instances
    // behind a round-robin router, load proportional to the cluster.
    println!("\n== scale-out (colocated, round-robin, TP8 instances) ==");
    for n in [1usize, 2, 4, 8] {
        let sys = SystemConfig::new(presets::hbm3(), 8, 1);
        let mut job = default_cluster_job("llama3-70b", sys);
        job.instances = n;
        job.max_batch = 16;
        job.prefill_chunk = 512;
        job.workload.arrival_rate = 10.0 * n as f64;
        job.workload.n_requests = 50 * n as u64;
        job.workload.context = (512, 2048);
        job.workload.gen = (64, 128);
        let rep = serve_cluster(&job)?;
        println!("{}", rep.summary());
    }

    // Routers under skewed overload: least-tokens balances work,
    // SLO-aware admission sheds to hold the TTFT tail.
    println!("\n== routers at skewed overload (8 colocated instances) ==");
    for policy in [
        RouterPolicy::RoundRobin,
        RouterPolicy::LeastTokens,
        RouterPolicy::SloAware,
    ] {
        let sys = SystemConfig::new(presets::hbm3(), 8, 1);
        let mut job = default_cluster_job("llama3-70b", sys);
        job.instances = 8;
        job.max_batch = 16;
        job.prefill_chunk = 512;
        job.router = policy;
        job.ttft_target = 0.2;
        job.workload.arrival_rate = 300.0;
        job.workload.n_requests = 200;
        job.workload.context = (256, 8192);
        job.workload.gen = (16, 512);
        let rep = serve_cluster(&job)?;
        println!("{}", rep.summary());
    }

    // Disaggregated prefill/decode pools: KV ships over the modeled
    // interconnect before decode admission, so TTFT sees the stall and
    // decode steps never carry prefill chunks.
    println!("\n== colocated x8 vs disaggregated 4P+4D at 300 req/s ==");
    for prefill_instances in [0usize, 4] {
        let sys = SystemConfig::new(presets::hbm3(), 8, 1);
        let mut job = default_cluster_job("llama3-70b", sys);
        job.instances = 8;
        job.prefill_instances = prefill_instances;
        job.max_batch = 16;
        job.prefill_chunk = 512;
        job.workload.arrival_rate = 300.0;
        job.workload.n_requests = 200;
        job.workload.context = (512, 2048);
        job.workload.gen = (128, 256);
        let rep = serve_cluster(&job)?;
        println!("{}", rep.summary());
        print!("{}", rep.pool_summary());
        for line in rep.slo_summary().lines() {
            println!("    {line}");
        }
    }

    // PJRT backend: the real AOT decode step, if artifacts are built.
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let sys = SystemConfig::new(presets::hbm3(), 1, 1); // ignored by pjrt
        let mut job = default_job("llama3-70b", sys);
        job.backend = Backend::Pjrt;
        job.max_batch = 8;
        job.workload.n_requests = 24;
        job.workload.arrival_rate = 100.0;
        let rep = serve(&job)?;
        println!("pjrt backend -> {}", rep.summary());
    } else {
        println!("(skipping PJRT backend: run `make artifacts` first)");
    }
    Ok(())
}
