//! Serving simulation: dynamic continuous batching on a paper-scale
//! system, now covering the full request lifecycle — prompts are
//! ingested in prefill chunks before decode, and the report carries the
//! TTFT / TPOT / E2E SLO percentiles that steady-state tables cannot
//! express. If AOT artifacts exist, the same scheduler also drives the
//! real PJRT decode engine.
//!
//! Run with: cargo run --release --example serve_sim

use liminal::coordinator::{default_job, serve, Backend};
use liminal::hw::{presets, SystemConfig};

fn main() -> anyhow::Result<()> {
    // Analytic backend: Llama3-70B on HBM3-TP128 under rising load,
    // prefill-aware (1024-token chunks by default).
    for rate in [50.0, 200.0, 800.0] {
        let sys = SystemConfig::new(presets::hbm3(), 128, 1);
        let mut job = default_job("llama3-70b", sys);
        job.workload.arrival_rate = rate;
        job.workload.n_requests = 300;
        job.max_batch = 64;
        let rep = serve(&job)?;
        println!("rate {rate:>5.0} req/s -> {}", rep.summary());
        for line in rep.slo_summary().lines() {
            println!("    {line}");
        }
    }

    // The same load with prefill disabled shows what the decode-only
    // idealization hides: TTFT collapses to a single queue+step delay.
    let sys = SystemConfig::new(presets::hbm3(), 128, 1);
    let mut job = default_job("llama3-70b", sys);
    job.workload.arrival_rate = 200.0;
    job.workload.n_requests = 300;
    job.max_batch = 64;
    job.prefill_chunk = 0;
    let rep = serve(&job)?;
    println!("decode-only baseline  -> {}", rep.summary());
    println!("    TTFT p50 {:.4}s (no prefill modeled)", rep.ttft.p50);

    // PJRT backend: the real AOT decode step, if artifacts are built.
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let sys = SystemConfig::new(presets::hbm3(), 1, 1); // ignored by pjrt
        let mut job = default_job("llama3-70b", sys);
        job.backend = Backend::Pjrt;
        job.max_batch = 8;
        job.workload.n_requests = 24;
        job.workload.arrival_rate = 100.0;
        let rep = serve(&job)?;
        println!("pjrt backend -> {}", rep.summary());
    } else {
        println!("(skipping PJRT backend: run `make artifacts` first)");
    }
    Ok(())
}
