//! Quickstart: evaluate the headline working points of the paper in a
//! few lines of library code.
//!
//! Run with: `cargo run --release --example quickstart`

use liminal::prelude::*;

fn main() -> anyhow::Result<()> {
    let registry = Registry::builtin();

    // 1. How fast can one user decode Llama3-405B on a 128-chip HBM3
    //    system at 4K context? (Paper Table 2: 776 tokens/s.)
    let app = registry.app("llama3-405b").unwrap();
    let sys = SystemConfig::new(presets::hbm3(), 128, 1);
    let perf = evaluate(
        app.as_ref(),
        &sys,
        &EvalPoint { batch: 1, context: 4096 },
        &EvalOptions::default(),
    )?;
    println!("llama3-405b on {}: {:.0} tokens/s/user", sys.label(), perf.utps);

    // 2. What does the latency breakdown look like at 128K context?
    let perf = evaluate(
        app.as_ref(),
        &sys,
        &EvalPoint { batch: 1, context: 131072 },
        &EvalOptions::default(),
    )?;
    println!(
        "  at 128K: {:.0} tok/s — mem {:.0}µs, sync {:.0}µs ({}-bound)",
        perf.utps,
        perf.lat.t_mem * 1e6,
        perf.lat.t_tp_sync * 1e6,
        match perf.lat.bound {
            liminal::model::Boundedness::Memory => "memory",
            liminal::model::Boundedness::Compute => "compute",
        }
    );

    // 3. Fill the machine with users: what is the system throughput?
    let b = max_batch(app.as_ref(), &sys, 4096).unwrap();
    let perf = evaluate(
        app.as_ref(),
        &sys,
        &EvalPoint { batch: b, context: 4096 },
        &EvalOptions::default(),
    )?;
    let watts = PowerModel::default().system_power(&sys).total_watts;
    println!(
        "  batch {b}: {:.0} system tok/s at {:.1} tok/s/user, {:.2} tok/s/W",
        perf.stps,
        perf.utps,
        perf.stps / watts
    );

    // 4. Would a wafer-scale SRAM design serve faster?
    let cows = SystemConfig::new(presets::cows(), 37, 1); // 37 wafers hold 405B+KV
    let perf = evaluate(
        app.as_ref(),
        &cows,
        &EvalPoint { batch: 1, context: 4096 },
        &EvalOptions::default(),
    )?;
    println!("on {}: {:.0} tokens/s/user", cows.label(), perf.utps);
    Ok(())
}
