//! Capacity planner: size a serving system for a target workload — the
//! deployment question Key Finding 1 poses ("memory capacity is the
//! first challenge").
//!
//! Run with:
//!   cargo run --release --example capacity_planner -- \
//!       llama3-405b --context 65536 --users 32 [--chip hbm3]

use liminal::apps::{DecodePoint, Registry};
use liminal::hw::presets;
use liminal::model::{evaluate, EvalOptions};
use liminal::parallel::{fit_system, FitRequest};
use liminal::power::PowerModel;
use liminal::util::cli::Args;
use liminal::GIB;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let model = args
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| "llama3-405b".to_string());
    let context = args.get_parsed("context", 65536u64);
    let users = args.get_parsed("users", 32u64);
    let chip_name = args.get("chip").unwrap_or("hbm3").to_string();

    let registry = Registry::builtin();
    let app = registry
        .app(&model)
        .ok_or_else(|| anyhow::anyhow!("unknown model {model}"))?;
    let chip = presets::by_name(&chip_name)
        .ok_or_else(|| anyhow::anyhow!("unknown chip {chip_name}"))?;

    let pt = DecodePoint { batch: users, context };
    let need = app.capacity_bytes(&pt);
    println!("== capacity plan: {model}, {users} users @ {}K context ==", context / 1024);
    println!("weights        {:>10.1} GiB", app.weight_bytes() / GIB);
    println!(
        "KV cache       {:>10.1} GiB ({:.2} GiB/user)",
        (need - app.weight_bytes()) / GIB,
        context as f64 * app.kv_bytes_per_token() / GIB
    );
    println!("total          {:>10.1} GiB", need / GIB);

    // Size the system: TP up to 128, then PP.
    for tp in [8u64, 32, 128] {
        match fit_system(app.as_ref(), &FitRequest { tp: Some(tp), ..FitRequest::new(chip.clone(), pt) }) {
            Ok(sys) => {
                let perf = evaluate(app.as_ref(), &sys, &pt, &EvalOptions::default())?;
                let power = PowerModel::default().system_power(&sys);
                println!(
                    "{:<26} {:>4} chips  UTPS {:>7.1}  STPS {:>10.0}  {:>7.1} kW  {:.2} tok/s/W",
                    sys.label(),
                    sys.n_chips(),
                    perf.utps,
                    perf.stps,
                    power.total_watts / 1e3,
                    perf.stps / power.total_watts
                );
            }
            Err(e) => println!("TP{tp}: cannot serve ({e})"),
        }
    }
    Ok(())
}
