//! The fuzz harness: run one [`FuzzCase`] under the
//! [`InvariantChecker`], cross-check the final report against the
//! checker's independent books (and, where eligible, against the
//! single-instance simulator as an oracle), and shrink failures to a
//! minimal reproducer.

use crate::cluster::ClusterReport;
use crate::serving::{Batcher, ServingSim, SimConfig};
use crate::util::par::parallel_map_jobs;

use super::gen::{gen_case, gen_preempt_case, FuzzCase, RouterKind};
use super::invariant::InvariantChecker;

/// Everything one case run produced: the report and any violations
/// (empty = the case passed).
#[derive(Debug)]
pub struct CaseOutcome {
    /// The cluster report of the run.
    pub report: ClusterReport,
    /// Invariant and cross-check violations, in discovery order.
    pub violations: Vec<String>,
}

/// One failing seed, with its shrunk reproducer.
#[derive(Debug)]
pub struct FuzzFailure {
    /// The seed that failed.
    pub seed: u64,
    /// Violations from the original (unshrunk) case.
    pub violations: Vec<String>,
    /// The smallest case found that still fails.
    pub minimized: FuzzCase,
}

/// Run a case under the invariant checker and all report cross-checks.
pub fn run_case(case: &FuzzCase) -> CaseOutcome {
    let mut chk = InvariantChecker::new(case.expect_drained());
    let report =
        case.build_sim().run_with(case.requests.clone(), &mut chk);
    let mut violations: Vec<String> = chk.violations().to_vec();
    if chk.suppressed() > 0 {
        violations.push(format!("... and {} more", chk.suppressed()));
    }
    report_checks(case, &chk, &report, &mut violations);
    if violations.is_empty() && case.oracle_eligible() {
        oracle_check(case, &report, &mut violations);
    }
    CaseOutcome { report, violations }
}

/// Generate and run the case a seed names.
pub fn run_seed(seed: u64) -> CaseOutcome {
    run_case(&gen_case(seed))
}

/// Generate and run the preemption-family case a seed names.
pub fn run_preempt_seed(seed: u64) -> CaseOutcome {
    run_case(&gen_preempt_case(seed))
}

/// Fuzz `count` consecutive seeds starting at `start`; returns the
/// failures, each with a shrunk reproducer, in ascending seed order.
pub fn fuzz_range(start: u64, count: u64) -> Vec<FuzzFailure> {
    fuzz_scan(start, count, 1)
        .into_iter()
        .filter_map(|s| s.failure)
        .collect()
}

/// One fuzzed seed's result: the run's headline counters plus the
/// failure (with shrunk reproducer) if the seed violated anything.
#[derive(Debug)]
pub struct SeedSummary {
    /// The seed.
    pub seed: u64,
    /// Requests offered by the generated case.
    pub offered: u64,
    /// Requests the run completed.
    pub completed: u64,
    /// Requests shed by admission control.
    pub shed: u64,
    /// DES events the run applied.
    pub events: u64,
    /// Present iff the seed failed.
    pub failure: Option<FuzzFailure>,
}

/// Fuzz `count` consecutive seeds starting at `start`, sharded over
/// `jobs` workers ([`parallel_map_jobs`]; `jobs == 1` runs inline).
///
/// Each seed is an independent pure function of its own value —
/// generation, simulation, invariant checking, and shrinking consult
/// nothing shared — so sharding cannot change any seed's outcome. The
/// map is order-preserving, so the summaries (and therefore the
/// failures) come back in ascending seed order for every worker
/// count: the smallest failing seed wins deterministically.
pub fn fuzz_scan(start: u64, count: u64, jobs: usize) -> Vec<SeedSummary> {
    fuzz_scan_with(start, count, jobs, gen_case)
}

/// [`fuzz_scan`] over an arbitrary seed-to-case generator — the
/// preemption family runs the same harness with [`gen_preempt_case`].
pub fn fuzz_scan_with(
    start: u64,
    count: u64,
    jobs: usize,
    gen: fn(u64) -> FuzzCase,
) -> Vec<SeedSummary> {
    let seeds: Vec<u64> = (start..start.saturating_add(count)).collect();
    parallel_map_jobs(seeds, jobs, |&seed| {
        let case = gen(seed);
        let out = run_case(&case);
        let failure = if out.violations.is_empty() {
            None
        } else {
            Some(FuzzFailure {
                seed,
                violations: out.violations,
                minimized: shrink(&case),
            })
        };
        SeedSummary {
            seed,
            offered: out.report.offered,
            completed: out.report.cluster.completed,
            shed: out.report.shed,
            events: out.report.events,
            failure,
        }
    })
}

/// Relative-plus-absolute float closeness for accounting cross-checks.
fn close(a: f64, b: f64) -> bool {
    (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1.0)
}

fn check_finite(tag: &str, v: f64, out: &mut Vec<String>) {
    if !v.is_finite() {
        out.push(format!("report field {tag} is not finite: {v}"));
    }
}

fn check_report_finite(
    prefix: &str,
    rep: &crate::serving::ServingReport,
    out: &mut Vec<String>,
) {
    let fields = [
        ("span", rep.span),
        ("stps", rep.stps),
        ("utps_mean", rep.utps_mean),
        ("utps_p50", rep.utps_p50),
        ("utps_p99_low", rep.utps_p99_low),
        ("queue_delay_mean", rep.queue_delay_mean),
        ("mean_batch", rep.mean_batch),
        ("ttft.mean", rep.ttft.mean),
        ("ttft.p50", rep.ttft.p50),
        ("ttft.p90", rep.ttft.p90),
        ("ttft.p99", rep.ttft.p99),
        ("tpot.mean", rep.tpot.mean),
        ("tpot.p99", rep.tpot.p99),
        ("e2e.mean", rep.e2e.mean),
        ("e2e.p99", rep.e2e.p99),
    ];
    for (name, v) in fields {
        check_finite(&format!("{prefix}.{name}"), v, out);
    }
}

/// Cross-check the cluster report against the checker's independent
/// books. Every number a user reads must reconcile with what the
/// observer saw happen, event by event.
fn report_checks(
    case: &FuzzCase,
    chk: &InvariantChecker,
    report: &ClusterReport,
    out: &mut Vec<String>,
) {
    // Finiteness: NaN/inf in any float a report exposes is a bug even
    // on degenerate runs (zero completions, zero steps).
    check_report_finite("cluster", &report.cluster, out);
    for (i, rep) in report.per_instance.iter().enumerate() {
        check_report_finite(&format!("i{i}"), rep, out);
    }
    for p in &report.pools {
        check_finite(&format!("pool.{}.busy_frac", p.label), p.busy_frac, out);
        check_finite(&format!("pool.{}.mean_batch", p.label), p.mean_batch, out);
    }
    check_finite("kv_shipped_bytes", report.kv_shipped_bytes, out);
    check_finite("kv_transfer_mean", report.kv_transfer_mean, out);
    check_finite("instance_seconds", report.instance_seconds, out);
    if case.autoscale.is_none() && (report.scale_ups | report.scale_downs) != 0
    {
        out.push(format!(
            "fixed fleet reported scale actions (+{} / -{})",
            report.scale_ups, report.scale_downs
        ));
    }

    if report.offered != case.requests.len() as u64 {
        out.push(format!(
            "offered {} != workload size {}",
            report.offered,
            case.requests.len()
        ));
    }
    if report.shed != chk.shed() {
        out.push(format!(
            "report shed {} != checker shed {}",
            report.shed,
            chk.shed()
        ));
    }
    if report.cluster.completed != chk.finished() {
        out.push(format!(
            "report completed {} != checker finished {}",
            report.cluster.completed,
            chk.finished()
        ));
    }
    if report.cluster.tokens != chk.tokens_out() {
        out.push(format!(
            "report tokens {} != checker tokens {}",
            report.cluster.tokens,
            chk.tokens_out()
        ));
    }
    if report.cluster.preemptions != chk.preemptions() {
        out.push(format!(
            "report preemptions {} != checker preemptions {}",
            report.cluster.preemptions,
            chk.preemptions()
        ));
    }
    if report.cluster.restores != chk.restores() {
        out.push(format!(
            "report restores {} != checker restores {}",
            report.cluster.restores,
            chk.restores()
        ));
    }
    if !case.preempt.enabled
        && (report.cluster.preemptions | report.cluster.restores) != 0
    {
        out.push(format!(
            "preemption disabled but report counts {} evictions / {} \
             restores",
            report.cluster.preemptions, report.cluster.restores
        ));
    }
    let instance_steps: u64 = report.per_instance.iter().map(|r| r.steps).sum();
    if report.cluster.steps != instance_steps {
        out.push(format!(
            "cluster steps {} != sum of per-instance steps {instance_steps}",
            report.cluster.steps
        ));
    }
    let pool_steps: u64 = report.pools.iter().map(|p| p.steps).sum();
    if report.cluster.steps != pool_steps {
        out.push(format!(
            "cluster steps {} != sum of pool steps {pool_steps}",
            report.cluster.steps
        ));
    }
    // Pools count tokens only where lifecycles retire (prefill pools
    // emit none), so the pool totals must re-add to the cluster total.
    let pool_tokens: u64 = report.pools.iter().map(|p| p.tokens).sum();
    if report.cluster.tokens != pool_tokens {
        out.push(format!(
            "cluster tokens {} != sum of pool tokens {pool_tokens}",
            report.cluster.tokens
        ));
    }
    // Pooled-vs-merged percentiles: the checker collected the same
    // per-request samples in the same retirement order the report
    // merges, so the distributions must match bit-for-bit.
    let (ttft, tpot, e2e) = chk.latency_stats();
    if report.cluster.ttft != ttft {
        out.push(format!(
            "pooled TTFT {:?} != checker-merged {ttft:?}",
            report.cluster.ttft
        ));
    }
    if report.cluster.tpot != tpot {
        out.push(format!(
            "pooled TPOT {:?} != checker-merged {tpot:?}",
            report.cluster.tpot
        ));
    }
    if report.cluster.e2e != e2e {
        out.push(format!(
            "pooled E2E {:?} != checker-merged {e2e:?}",
            report.cluster.e2e
        ));
    }
    if case.expect_drained() {
        if report.cluster.completed + report.shed != report.offered {
            out.push(format!(
                "drained run: completed {} + shed {} != offered {}",
                report.cluster.completed, report.shed, report.offered
            ));
        }
        let expect_prefill = if case.prefill_chunk == 0 {
            0
        } else {
            chk.ctx_finished()
        };
        if report.cluster.prefill_tokens != expect_prefill {
            out.push(format!(
                "drained run: prefill tokens {} != finished prompt tokens \
                 {expect_prefill}",
                report.cluster.prefill_tokens
            ));
        }
    }
}

/// For a one-instance colocated case behind a pass-through router, the
/// single-instance serving simulator is an exact oracle: same batcher,
/// same engine, same limits must give the same report.
fn oracle_check(case: &FuzzCase, report: &ClusterReport, out: &mut Vec<String>) {
    let mut engine = case.engine.clone();
    let mut batcher =
        Batcher::with_prefill(case.max_batch, case.kv_budget(), case.prefill_chunk);
    batcher.set_preemption(case.preempt);
    let sim = ServingSim::new(
        batcher,
        &mut engine,
        SimConfig { max_time: case.max_time, max_steps: case.max_steps },
    );
    let single = sim.run(case.requests.clone());
    let cl = &report.cluster;
    let exact = [
        ("completed", cl.completed, single.completed),
        ("tokens", cl.tokens, single.tokens),
        ("prefill_tokens", cl.prefill_tokens, single.prefill_tokens),
        ("steps", cl.steps, single.steps),
        ("preemptions", cl.preemptions, single.preemptions),
        ("restores", cl.restores, single.restores),
    ];
    for (name, a, b) in exact {
        if a != b {
            out.push(format!("oracle: cluster {name} {a} != single {b}"));
        }
    }
    let floats = [
        ("span", cl.span, single.span),
        ("stps", cl.stps, single.stps),
        ("utps_mean", cl.utps_mean, single.utps_mean),
        ("utps_p50", cl.utps_p50, single.utps_p50),
        ("utps_p99_low", cl.utps_p99_low, single.utps_p99_low),
        ("queue_delay_mean", cl.queue_delay_mean, single.queue_delay_mean),
        ("mean_batch", cl.mean_batch, single.mean_batch),
        ("ttft.mean", cl.ttft.mean, single.ttft.mean),
        ("ttft.p99", cl.ttft.p99, single.ttft.p99),
        ("tpot.mean", cl.tpot.mean, single.tpot.mean),
        ("tpot.p99", cl.tpot.p99, single.tpot.p99),
        ("e2e.mean", cl.e2e.mean, single.e2e.mean),
        ("e2e.p99", cl.e2e.p99, single.e2e.p99),
    ];
    for (name, a, b) in floats {
        if !close(a, b) {
            out.push(format!("oracle: cluster {name} {a} != single {b}"));
        }
    }
}

/// Greedy shrink: try structurally smaller variants of a failing case,
/// keeping any that still fail, until no candidate fails or the run
/// budget (200 re-executions) is spent. Every candidate stays within
/// the simulator's validity envelope (positive instance counts, prefill
/// pool smaller than the cluster, chunked prefill wherever a prefill
/// pool exists).
pub fn shrink(case: &FuzzCase) -> FuzzCase {
    let mut best = case.clone();
    let mut budget = 200u32;
    loop {
        let mut improved = false;
        for cand in shrink_candidates(&best) {
            if budget == 0 {
                break;
            }
            budget -= 1;
            if !run_case(&cand).violations.is_empty() {
                best = cand;
                improved = true;
                break;
            }
        }
        if !improved || budget == 0 {
            return best;
        }
    }
}

fn shrink_candidates(c: &FuzzCase) -> Vec<FuzzCase> {
    let mut out = Vec::new();
    let n = c.requests.len();
    if n >= 2 {
        // First half, second half, drop-last.
        let mut first = c.clone();
        first.requests.truncate(n / 2);
        out.push(first);
        let mut second = c.clone();
        second.requests.drain(..n / 2);
        out.push(second);
    }
    if n >= 1 {
        let mut drop_last = c.clone();
        drop_last.requests.pop();
        out.push(drop_last);
    }
    if c.instances > 1 {
        let mut cand = c.clone();
        cand.instances = (c.instances / 2).max(1);
        if cand.instances == 1 {
            cand.prefill_instances = 0;
        } else if cand.prefill_instances >= cand.instances {
            cand.prefill_instances = cand.instances - 1;
        }
        out.push(cand);
    }
    if c.prefill_instances > 0 {
        let mut cand = c.clone();
        cand.prefill_instances = 0;
        out.push(cand);
    }
    if c.router != RouterKind::RoundRobin {
        let mut cand = c.clone();
        cand.router = RouterKind::RoundRobin;
        out.push(cand);
    }
    if c.autoscale.is_some() {
        // A fixed fleet is structurally simpler than an elastic one:
        // if the failure survives without scale transitions, the
        // autoscaler is exonerated from the reproducer.
        let mut cand = c.clone();
        cand.autoscale = None;
        out.push(cand);
    }
    if c.kv_link_bw.is_finite() {
        let mut cand = c.clone();
        cand.kv_link_bw = f64::INFINITY;
        out.push(cand);
    }
    if c.max_time.is_finite() {
        let mut cand = c.clone();
        cand.max_time = f64::INFINITY;
        out.push(cand);
    }
    if c.max_batch > 1 {
        let mut cand = c.clone();
        cand.max_batch = 1;
        out.push(cand);
    }
    if c.preempt.enabled {
        // The FIFO run-to-completion batcher is structurally simpler:
        // if the failure survives with preemption off, eviction and
        // restore are exonerated from the reproducer.
        let mut cand = c.clone();
        cand.preempt = Default::default();
        out.push(cand);
    }
    if c.requests.iter().any(|r| r.priority != 0) {
        // Likewise a single-class stream: priority admission
        // degenerates to FIFO.
        let mut cand = c.clone();
        for r in &mut cand.requests {
            r.priority = 0;
        }
        out.push(cand);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn the_first_seed_of_every_family_passes() {
        for seed in 0..8u64 {
            let out = run_seed(seed);
            assert!(
                out.violations.is_empty(),
                "seed {seed} violated:\n{}",
                out.violations.join("\n")
            );
        }
    }

    #[test]
    fn the_first_seed_of_every_preempt_family_passes() {
        // The preempt overlay keeps the base family stratification
        // (seed % 8), so 0..8 covers every regime with preemption
        // armed over a near-full budget.
        for seed in 0..8u64 {
            let out = run_preempt_seed(seed);
            assert!(
                out.violations.is_empty(),
                "preempt seed {seed} violated:\n{}",
                out.violations.join("\n")
            );
        }
    }

    #[test]
    fn preempt_shrink_candidates_include_disabling_preemption() {
        let case = gen_preempt_case(1);
        let cands = shrink_candidates(&case);
        assert!(
            cands.iter().any(|c| !c.preempt.enabled),
            "no candidate disables preemption"
        );
        assert!(
            cands
                .iter()
                .any(|c| c.requests.iter().all(|r| r.priority == 0)),
            "no candidate collapses to a single class"
        );
        for cand in cands {
            let _ = cand.build_sim();
        }
    }

    #[test]
    fn preempt_scans_shard_deterministically() {
        let serial = fuzz_scan_with(0, 8, 1, gen_preempt_case);
        let sharded = fuzz_scan_with(0, 8, 4, gen_preempt_case);
        assert_eq!(serial.len(), sharded.len());
        for (a, b) in serial.iter().zip(&sharded) {
            assert_eq!(a.seed, b.seed);
            assert_eq!(a.completed, b.completed);
            assert_eq!(a.events, b.events);
            assert_eq!(a.failure.is_some(), b.failure.is_some());
        }
    }

    #[test]
    fn shrinking_a_passing_case_returns_it_unchanged() {
        let case = gen_case(7);
        let shrunk = shrink(&case);
        assert_eq!(shrunk.requests.len(), case.requests.len());
        assert_eq!(shrunk.instances, case.instances);
    }

    #[test]
    fn shrink_candidates_stay_within_the_validity_envelope() {
        for seed in 0..16u64 {
            let case = gen_case(seed);
            for cand in shrink_candidates(&case) {
                assert!(cand.instances >= 1);
                assert!(
                    cand.prefill_instances == 0
                        || cand.prefill_instances < cand.instances
                );
                if cand.prefill_instances > 0 {
                    assert!(cand.prefill_chunk > 0);
                }
                // Constructive proof each candidate builds.
                let _ = cand.build_sim();
            }
        }
    }

    #[test]
    fn sharded_scans_match_the_serial_scan_for_every_job_count() {
        // Seeds are pure functions of their value, so the worker count
        // must be unobservable: same seeds, same order, same counters.
        let serial = fuzz_scan(0, 12, 1);
        assert_eq!(serial.len(), 12);
        for jobs in [2, 4, 32] {
            let sharded = fuzz_scan(0, 12, jobs);
            assert_eq!(sharded.len(), serial.len(), "jobs={jobs}");
            for (a, b) in serial.iter().zip(&sharded) {
                assert_eq!(a.seed, b.seed, "jobs={jobs}");
                assert_eq!(a.offered, b.offered, "jobs={jobs} seed {}", a.seed);
                assert_eq!(a.completed, b.completed, "jobs={jobs} seed {}", a.seed);
                assert_eq!(a.shed, b.shed, "jobs={jobs} seed {}", a.seed);
                assert_eq!(a.events, b.events, "jobs={jobs} seed {}", a.seed);
                assert_eq!(
                    a.failure.is_some(),
                    b.failure.is_some(),
                    "jobs={jobs} seed {}",
                    a.seed
                );
            }
        }
    }

    #[test]
    fn an_empty_workload_passes_cleanly() {
        let mut case = gen_case(7);
        case.requests.clear();
        let out = run_case(&case);
        assert!(
            out.violations.is_empty(),
            "{}",
            out.violations.join("\n")
        );
        assert_eq!(out.report.offered, 0);
        assert_eq!(out.report.cluster.completed, 0);
    }
}
