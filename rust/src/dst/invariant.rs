//! The per-event invariant checker: a [`SimObserver`] that shadows a
//! run with its own books and flags any state the simulators must never
//! reach.
//!
//! The checker keeps a tiny state machine per arena slot (routed,
//! parked behind a prefill sub-request, in KV transit, retired, shed)
//! plus conservation counters, and audits after **every applied event**:
//!
//! * the simulated clock never runs backwards;
//! * per instance, KV bytes reserved never exceed the budget and busy
//!   time never exceeds the clock;
//! * requests are conserved — everything routed is in exactly one
//!   instance queue/batch, parked, in transit, retired, or shed;
//! * the preempted lifecycle is sound — a request is evicted only while
//!   enqueued, never twice without an intervening restore, never
//!   retired while still evicted, and per instance the KV bytes
//!   reserved always equal the sum over *active* requests (so an
//!   evicted request provably holds zero reserved KV);
//! * at retirement, token accounting closed out exactly
//!   (`generated == gen_len`, `prefilled == context_len`) and the
//!   lifecycle stamps are ordered
//!   (`arrival <= admitted <= first_token <= completed == now`);
//! * scale lifecycles are ordered (spawn -> warm-up -> retire, each
//!   phase entered exactly once) and a warming or retired instance
//!   never holds work — conservation stays auditable across pool-size
//!   changes;
//! * after a fully drained run, every queue is empty, no KV is
//!   reserved, and the arena reconciles against routed + subs + shed.
//!
//! Violations are collected as human-readable strings (never panics),
//! so the harness can report all of them alongside the seed.

use crate::cluster::InstanceState;
use crate::serving::{
    Instance, InstanceEvent, LatencyStats, ReqId, RequestArena, SimObserver,
};

/// Where one arena slot sits in the request lifecycle, per the
/// checker's books.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SlotState {
    /// Allocated, not yet routed (or never offered: a workload request
    /// whose arrival fell past the deadline).
    Fresh,
    /// In some instance's queue or active batch.
    Enqueued,
    /// A disaggregated original, parked while its prefill sub-request
    /// runs.
    Parked,
    /// A disaggregated original, KV shipping to its decode instance.
    InTransit,
    /// Retired (lifecycle complete, or a finished prefill sub-request).
    Retired,
    /// Shed by admission control.
    Shed,
}

/// Cap on recorded violations; everything past it is only counted, so a
/// hot loop of failures cannot balloon memory.
const MAX_RECORDED: usize = 32;

/// The invariant checker. Build one per run with
/// [`InvariantChecker::new`], pass it to `run_with`, then read
/// [`violations`](InvariantChecker::violations) and the counters.
#[derive(Debug, Default)]
pub struct InvariantChecker {
    expect_drained: bool,
    last_time: f64,
    state: Vec<SlotState>,
    /// For a prefill sub-request's slot: the original it ingests for.
    sub_of: Vec<Option<ReqId>>,
    /// Slots currently evicted (KV dropped, waiting to restore).
    evicted: Vec<bool>,
    routed: u64,
    subs: u64,
    shed: u64,
    finished: u64,
    sub_retired: u64,
    /// Requests currently in some instance queue or active batch.
    live: u64,
    parked: u64,
    in_transit: u64,
    /// Requests currently evicted (a subset of `live`: an evicted
    /// request sits in its instance's queue awaiting restore).
    evicted_now: u64,
    preempts: u64,
    restores: u64,
    tokens_out: u64,
    /// Prompt tokens of lifecycle-finished requests.
    ctx_finished: u64,
    events: u64,
    /// Per-instance membership phase, mirrored from the scale hooks.
    /// Grown lazily (construction-time instances default to `Active`),
    /// so fixed fleets never touch it.
    fleet: Vec<InstanceState>,
    ttft: Vec<f64>,
    tpot: Vec<f64>,
    e2e: Vec<f64>,
    violations: Vec<String>,
    suppressed: u64,
}

impl InvariantChecker {
    /// New checker. `expect_drained` arms the end-of-run checks that
    /// only hold when nothing truncated the run (no deadline, no step
    /// limit): empty queues, zero KV reserved, closed conservation.
    pub fn new(expect_drained: bool) -> InvariantChecker {
        InvariantChecker { expect_drained, ..InvariantChecker::default() }
    }

    /// Violations found so far (capped; see [`suppressed`](Self::suppressed)).
    pub fn violations(&self) -> &[String] {
        &self.violations
    }

    /// Violations found past the recording cap.
    pub fn suppressed(&self) -> u64 {
        self.suppressed
    }

    /// Requests routed through the front door.
    pub fn routed(&self) -> u64 {
        self.routed
    }

    /// Prefill sub-requests minted (disaggregated mode).
    pub fn subs(&self) -> u64 {
        self.subs
    }

    /// Requests shed by admission control.
    pub fn shed(&self) -> u64 {
        self.shed
    }

    /// Full request lifecycles completed.
    pub fn finished(&self) -> u64 {
        self.finished
    }

    /// Output tokens across finished lifecycles.
    pub fn tokens_out(&self) -> u64 {
        self.tokens_out
    }

    /// Prompt tokens across finished lifecycles.
    pub fn ctx_finished(&self) -> u64 {
        self.ctx_finished
    }

    /// Events observed.
    pub fn events(&self) -> u64 {
        self.events
    }

    /// KV evictions observed via [`SimObserver::on_preempt`].
    pub fn preemptions(&self) -> u64 {
        self.preempts
    }

    /// Evicted-request restores observed via [`SimObserver::on_restore`].
    pub fn restores(&self) -> u64 {
        self.restores
    }

    /// TTFT / TPOT / E2E over the finished lifecycles, aggregated
    /// exactly like the report does (same samples, same order), so the
    /// harness can cross-check the pooled percentiles bit-for-bit.
    pub fn latency_stats(&self) -> (LatencyStats, LatencyStats, LatencyStats) {
        (
            LatencyStats::from_samples(&mut self.ttft.clone()),
            LatencyStats::from_samples(&mut self.tpot.clone()),
            LatencyStats::from_samples(&mut self.e2e.clone()),
        )
    }

    fn violate(&mut self, msg: String) {
        if self.violations.len() < MAX_RECORDED {
            self.violations.push(msg);
        } else {
            self.suppressed += 1;
        }
    }

    fn grow(&mut self, id: ReqId) {
        let need = id.index() + 1;
        if self.state.len() < need {
            self.state.resize(need, SlotState::Fresh);
            self.sub_of.resize(need, None);
            self.evicted.resize(need, false);
        }
    }

    fn slot(&mut self, id: ReqId) -> SlotState {
        self.grow(id);
        self.state[id.index()]
    }

    fn set_slot(&mut self, id: ReqId, s: SlotState) {
        self.grow(id);
        self.state[id.index()] = s;
    }

    /// Grow the fleet books to cover instance `i`; slots the scale
    /// hooks never announced are construction-time instances, `Active`
    /// from t=0.
    fn grow_fleet(&mut self, i: usize) {
        if self.fleet.len() <= i {
            self.fleet.resize(i + 1, InstanceState::Active);
        }
    }

    /// Audit a lifecycle retirement's request state.
    fn check_lifecycle(&mut self, now: f64, id: ReqId, arena: &RequestArena) {
        let r = arena[id].clone();
        if r.generated != r.gen_len {
            self.violate(format!(
                "req {id:?}: retired with {} of {} tokens generated",
                r.generated, r.gen_len
            ));
        }
        if r.prefilled != r.context_len {
            self.violate(format!(
                "req {id:?}: retired with {} of {} prompt tokens prefilled",
                r.prefilled, r.context_len
            ));
        }
        match (r.admitted_at, r.first_token_at, r.completed_at) {
            (Some(adm), Some(ftok), Some(comp)) => {
                if !(r.arrival <= adm && adm <= ftok && ftok <= comp) {
                    self.violate(format!(
                        "req {id:?}: lifecycle stamps out of order \
                         (arrival {} admitted {adm} first_token {ftok} \
                         completed {comp})",
                        r.arrival
                    ));
                }
                if comp != now {
                    self.violate(format!(
                        "req {id:?}: completed_at {comp} != retirement time {now}"
                    ));
                }
            }
            _ => self.violate(format!(
                "req {id:?}: retired with missing lifecycle stamps {:?}/{:?}/{:?}",
                r.admitted_at, r.first_token_at, r.completed_at
            )),
        }
        self.finished += 1;
        self.tokens_out += r.generated;
        self.ctx_finished += r.context_len;
        // Mirror the report's sample collection exactly (same
        // filter_map, same retirement order).
        if let Some(t) = r.ttft() {
            self.ttft.push(t);
        }
        if let Some(t) = r.tpot() {
            self.tpot.push(t);
        }
        if let Some(t) = r.e2e() {
            self.e2e.push(t);
        }
    }
}

impl SimObserver for InvariantChecker {
    fn on_route(&mut self, _now: f64, id: ReqId, _instance: usize) {
        match self.slot(id) {
            SlotState::Fresh => {
                self.set_slot(id, SlotState::Enqueued);
                self.routed += 1;
                self.live += 1;
            }
            other => self.violate(format!(
                "req {id:?}: routed while already {other:?}"
            )),
        }
    }

    fn on_shed(&mut self, _now: f64, id: ReqId) {
        match self.slot(id) {
            SlotState::Fresh => {
                self.set_slot(id, SlotState::Shed);
                self.shed += 1;
            }
            other => self.violate(format!(
                "req {id:?}: shed while already {other:?}"
            )),
        }
    }

    fn on_sub_request(&mut self, _now: f64, orig: ReqId, sub: ReqId) {
        match self.slot(orig) {
            SlotState::Enqueued => {
                self.set_slot(orig, SlotState::Parked);
                self.live -= 1;
                self.parked += 1;
            }
            other => self.violate(format!(
                "req {orig:?}: sub-request minted while original is {other:?}"
            )),
        }
        match self.slot(sub) {
            SlotState::Fresh => {
                self.set_slot(sub, SlotState::Enqueued);
                self.subs += 1;
                self.live += 1;
                self.sub_of[sub.index()] = Some(orig);
            }
            other => self.violate(format!(
                "sub {sub:?}: minted into non-fresh slot ({other:?})"
            )),
        }
    }

    fn on_retire(
        &mut self,
        now: f64,
        _instance: usize,
        id: ReqId,
        lifecycle_done: bool,
        arena: &RequestArena,
    ) {
        match self.slot(id) {
            SlotState::Enqueued => {
                self.set_slot(id, SlotState::Retired);
                self.live -= 1;
            }
            other => self.violate(format!(
                "req {id:?}: retired while {other:?} (never enqueued?)"
            )),
        }
        if self.evicted[id.index()] {
            // A retirement closes the lifecycle (or a prefill
            // sub-request); an evicted request must be restored — and
            // its KV re-reserved — before it can generate again.
            self.violate(format!(
                "req {id:?}: retired while still evicted (never restored)"
            ));
            self.evicted[id.index()] = false;
            self.evicted_now -= 1;
        }
        if lifecycle_done {
            self.check_lifecycle(now, id, arena);
        } else {
            // A prefill sub-request finishing moves its original from
            // parked into KV transit.
            self.sub_retired += 1;
            match self.sub_of[id.index()] {
                Some(orig) => match self.slot(orig) {
                    SlotState::Parked => {
                        self.set_slot(orig, SlotState::InTransit);
                        self.parked -= 1;
                        self.in_transit += 1;
                    }
                    other => self.violate(format!(
                        "sub {id:?} retired but original {orig:?} is {other:?}"
                    )),
                },
                None => self.violate(format!(
                    "sub-request retirement for {id:?} with no recorded original"
                )),
            }
        }
    }

    fn on_preempt(&mut self, now: f64, instance: usize, id: ReqId) {
        match self.slot(id) {
            // The batcher re-queues a victim as it evicts, so by the
            // time the action is drained the victim is back in a
            // queue: still Enqueued on the checker's books.
            SlotState::Enqueued => {}
            other => self.violate(format!(
                "req {id:?}: evicted from instance {instance} while \
                 {other:?} at t={now}"
            )),
        }
        if self.evicted[id.index()] {
            self.violate(format!(
                "req {id:?}: double-evicted (no restore in between) \
                 at t={now}"
            ));
        } else {
            self.evicted[id.index()] = true;
            self.evicted_now += 1;
        }
        self.preempts += 1;
    }

    fn on_restore(&mut self, now: f64, instance: usize, id: ReqId) {
        match self.slot(id) {
            SlotState::Enqueued => {}
            other => self.violate(format!(
                "req {id:?}: restored on instance {instance} while \
                 {other:?} at t={now}"
            )),
        }
        if !self.evicted[id.index()] {
            self.violate(format!(
                "req {id:?}: restored without a prior eviction at t={now}"
            ));
        } else {
            self.evicted[id.index()] = false;
            self.evicted_now -= 1;
        }
        self.restores += 1;
    }

    fn on_scale_up(&mut self, now: f64, instance: usize) {
        let existed = instance < self.fleet.len();
        self.grow_fleet(instance);
        if existed {
            self.violate(format!(
                "instance {instance}: scale-up into an already-tracked \
                 slot ({:?}) at t={now}",
                self.fleet[instance]
            ));
        } else {
            self.fleet[instance] = InstanceState::Warming;
        }
    }

    fn on_warmup_done(&mut self, now: f64, instance: usize) {
        self.grow_fleet(instance);
        if self.fleet[instance] != InstanceState::Warming {
            self.violate(format!(
                "instance {instance}: warm-up completed while {:?} \
                 (not warming) at t={now}",
                self.fleet[instance]
            ));
        } else {
            self.fleet[instance] = InstanceState::Active;
        }
    }

    fn on_scale_down(&mut self, now: f64, instance: usize) {
        self.grow_fleet(instance);
        if self.fleet[instance] != InstanceState::Active {
            self.violate(format!(
                "instance {instance}: retired while {:?} (not active) \
                 at t={now}",
                self.fleet[instance]
            ));
        } else {
            self.fleet[instance] = InstanceState::Retired;
        }
    }

    fn post_event(
        &mut self,
        now: f64,
        ev: &InstanceEvent,
        instances: &[Instance<'_>],
        arena: &RequestArena,
    ) {
        self.events += 1;
        if now < self.last_time {
            self.violate(format!(
                "clock ran backwards: {} -> {now} at {ev:?}",
                self.last_time
            ));
        }
        self.last_time = now;
        if let InstanceEvent::KvArrive(_, id) = ev {
            match self.slot(*id) {
                SlotState::InTransit => {
                    self.set_slot(*id, SlotState::Enqueued);
                    self.in_transit -= 1;
                    self.live += 1;
                }
                // A shipment landing after its request retired is legal
                // and must be a no-op; conservation below catches the
                // sim enqueueing it anyway.
                SlotState::Retired => {}
                other => self.violate(format!(
                    "KvArrive for req {id:?} in state {other:?}"
                )),
            }
        }
        for (i, inst) in instances.iter().enumerate() {
            let used = inst.kv_used_bytes();
            let budget = inst.kv_budget_bytes();
            if used > budget * (1.0 + 1e-9) + 1e-6 {
                self.violate(format!(
                    "instance {i}: KV reserved {used} exceeds budget {budget} \
                     after {ev:?} at t={now}"
                ));
            }
            if used < -1e-6 {
                self.violate(format!(
                    "instance {i}: negative KV reservation {used} at t={now}"
                ));
            }
            // KV conservation through evict/restore: the reservation
            // counter must equal the sum over currently-active
            // requests — an evicted (queued) request therefore holds
            // exactly zero reserved bytes.
            let active = inst.active_kv_bytes(arena);
            if (used - active).abs() > 1e-6 + 1e-9 * active.abs() {
                self.violate(format!(
                    "instance {i}: KV reserved {used} != {active} summed \
                     over active requests after {ev:?} at t={now}"
                ));
            }
            let busy = inst.stats(now).busy_time;
            if busy > now * (1.0 + 1e-9) + 1e-9 {
                self.violate(format!(
                    "instance {i}: busy time {busy} exceeds clock {now}"
                ));
            }
            // A warming instance holds no work yet and a retired one
            // never holds work again — the property that makes
            // conservation trivial across pool-size changes.
            if i < self.fleet.len() && self.fleet[i] != InstanceState::Active {
                let phase = self.fleet[i];
                if inst.queued_len() != 0 || inst.active_len() != 0 {
                    self.violate(format!(
                        "instance {i}: {phase:?} but holds {} queued / \
                         {} active at t={now}",
                        inst.queued_len(),
                        inst.active_len()
                    ));
                }
                if inst.busy() {
                    self.violate(format!(
                        "instance {i}: {phase:?} but has a step in \
                         flight at t={now}"
                    ));
                }
            }
        }
        let in_instances: u64 = instances
            .iter()
            .map(|inst| (inst.queued_len() + inst.active_len()) as u64)
            .sum();
        if in_instances != self.live {
            self.violate(format!(
                "conservation: {in_instances} requests across instance \
                 queues/batches but books say {} after {ev:?} at t={now}",
                self.live
            ));
        }
    }

    fn on_done(
        &mut self,
        end_time: f64,
        instances: &[Instance<'_>],
        arena: &RequestArena,
    ) {
        if end_time + 1e-9 < self.last_time {
            self.violate(format!(
                "end time {end_time} precedes last event at {}",
                self.last_time
            ));
        }
        for (id, r) in arena.iter() {
            if r.generated > r.gen_len {
                self.violate(format!(
                    "req {id:?}: over-generated ({} of {})",
                    r.generated, r.gen_len
                ));
            }
            if let Some(c) = r.completed_at {
                if r.generated != r.gen_len {
                    self.violate(format!(
                        "req {id:?}: completed at {c} with {} of {} tokens",
                        r.generated, r.gen_len
                    ));
                }
            }
        }
        if !self.expect_drained {
            return;
        }
        if self.live != 0 || self.parked != 0 || self.in_transit != 0 {
            self.violate(format!(
                "drained run left {} live / {} parked / {} in transit",
                self.live, self.parked, self.in_transit
            ));
        }
        if self.evicted_now != 0 {
            self.violate(format!(
                "drained run left {} requests evicted (never restored)",
                self.evicted_now
            ));
        }
        if self.preempts != self.restores {
            self.violate(format!(
                "drained run: {} evictions but {} restores",
                self.preempts, self.restores
            ));
        }
        for (i, inst) in instances.iter().enumerate() {
            if inst.queued_len() != 0 || inst.active_len() != 0 {
                self.violate(format!(
                    "instance {i}: {} queued / {} active after drain",
                    inst.queued_len(),
                    inst.active_len()
                ));
            }
            if inst.busy() {
                self.violate(format!("instance {i}: still busy after drain"));
            }
            if inst.kv_used_bytes().abs() > 1e-6 {
                self.violate(format!(
                    "instance {i}: {} KV bytes still reserved after drain",
                    inst.kv_used_bytes()
                ));
            }
            if inst.outstanding_kv_bytes().abs() > 1e-6 {
                self.violate(format!(
                    "instance {i}: {} KV bytes still outstanding after drain",
                    inst.outstanding_kv_bytes()
                ));
            }
            if inst.outstanding_gen_tokens() != 0 {
                self.violate(format!(
                    "instance {i}: {} gen tokens still outstanding after drain",
                    inst.outstanding_gen_tokens()
                ));
            }
        }
        let accounted = self.routed + self.subs + self.shed;
        if arena.len() as u64 != accounted {
            self.violate(format!(
                "arena holds {} slots but only {accounted} were \
                 routed/minted/shed",
                arena.len()
            ));
        }
        if self.finished + self.shed != self.routed {
            self.violate(format!(
                "drained run: routed {} != finished {} + shed {}",
                self.routed, self.finished, self.shed
            ));
        }
        if self.sub_retired != self.subs {
            self.violate(format!(
                "drained run: {} sub-requests minted, {} retired",
                self.subs, self.sub_retired
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::testutil::{mk_req, open_budget, FixedEngine};
    use crate::serving::{Batcher, RequestArena};

    #[test]
    fn double_route_is_a_violation() {
        let mut a = RequestArena::new();
        let id = a.alloc(mk_req(0, 0.0, 8, 2));
        let mut chk = InvariantChecker::new(false);
        chk.on_route(0.0, id, 0);
        assert!(chk.violations().is_empty());
        chk.on_route(0.1, id, 1);
        assert_eq!(chk.violations().len(), 1);
        assert!(chk.violations()[0].contains("routed while already"));
    }

    #[test]
    fn retiring_an_unrouted_request_is_a_violation() {
        let mut a = RequestArena::new();
        let id = a.alloc(mk_req(0, 0.0, 8, 2));
        let mut chk = InvariantChecker::new(false);
        chk.on_retire(1.0, 0, id, false, &a);
        assert!(chk.violations().iter().any(|v| v.contains("never enqueued")));
    }

    #[test]
    fn a_backwards_clock_is_a_violation() {
        let a = RequestArena::new();
        let inst = [crate::serving::Instance::new(
            Batcher::new(1, open_budget()),
            Box::new(FixedEngine(0.1)),
        )];
        let mut chk = InvariantChecker::new(false);
        chk.post_event(1.0, &InstanceEvent::StepDone(0), &inst, &a);
        assert!(chk.violations().is_empty());
        chk.post_event(0.5, &InstanceEvent::StepDone(0), &inst, &a);
        assert!(chk.violations().iter().any(|v| v.contains("backwards")));
        assert_eq!(chk.events(), 2);
    }

    #[test]
    fn scale_lifecycle_transitions_are_audited() {
        // Proper spawn -> warm-up -> retire sequence: clean books.
        let mut chk = InvariantChecker::new(false);
        chk.on_scale_up(0.0, 1);
        chk.on_warmup_done(0.5, 1);
        chk.on_scale_down(3.0, 1);
        assert!(chk.violations().is_empty(), "{:?}", chk.violations());

        // Warm-up for an instance that was never spawned (slot 0 is a
        // construction-time, already-active instance).
        let mut chk = InvariantChecker::new(false);
        chk.on_warmup_done(0.5, 0);
        assert!(chk.violations().iter().any(|v| v.contains("not warming")));

        // Retiring an instance that never finished warming.
        let mut chk = InvariantChecker::new(false);
        chk.on_scale_up(0.0, 1);
        chk.on_scale_down(0.5, 1);
        assert!(chk.violations().iter().any(|v| v.contains("not active")));
    }

    #[test]
    fn work_on_a_warming_instance_is_a_violation() {
        let mut a = RequestArena::new();
        let id = a.alloc(mk_req(0, 0.0, 8, 2));
        let mut inst = crate::serving::Instance::new(
            Batcher::new(1, open_budget()),
            Box::new(FixedEngine(0.1)),
        );
        let mut chk = InvariantChecker::new(false);
        chk.on_scale_up(0.0, 0);
        chk.on_route(0.0, id, 0);
        inst.enqueue(id, &a);
        let insts = [inst];
        chk.post_event(0.0, &InstanceEvent::Arrival(id), &insts, &a);
        assert!(
            chk.violations().iter().any(|v| v.contains("Warming")),
            "{:?}",
            chk.violations()
        );
    }

    #[test]
    fn the_preempted_lifecycle_is_audited() {
        let mut a = RequestArena::new();
        let id = a.alloc(mk_req(0, 0.0, 8, 2));

        // Evict -> restore on an enqueued request: clean books.
        let mut chk = InvariantChecker::new(false);
        chk.on_route(0.0, id, 0);
        chk.on_preempt(1.0, 0, id);
        chk.on_restore(2.0, 0, id);
        assert!(chk.violations().is_empty(), "{:?}", chk.violations());
        assert_eq!(chk.preemptions(), 1);
        assert_eq!(chk.restores(), 1);

        // Double eviction without an intervening restore.
        let mut chk = InvariantChecker::new(false);
        chk.on_route(0.0, id, 0);
        chk.on_preempt(1.0, 0, id);
        chk.on_preempt(1.5, 0, id);
        assert!(chk.violations().iter().any(|v| v.contains("double-evicted")));

        // Restore with no prior eviction.
        let mut chk = InvariantChecker::new(false);
        chk.on_route(0.0, id, 0);
        chk.on_restore(1.0, 0, id);
        assert!(chk
            .violations()
            .iter()
            .any(|v| v.contains("without a prior eviction")));

        // Evicting a request that was never routed.
        let mut chk = InvariantChecker::new(false);
        chk.on_preempt(1.0, 0, id);
        assert!(chk.violations().iter().any(|v| v.contains("evicted from")));
    }

    #[test]
    fn retiring_while_evicted_is_a_violation() {
        let mut a = RequestArena::new();
        let id = a.alloc(mk_req(0, 0.0, 8, 2));
        let mut chk = InvariantChecker::new(false);
        chk.on_route(0.0, id, 0);
        chk.on_preempt(1.0, 0, id);
        chk.on_retire(2.0, 0, id, false, &a);
        assert!(chk
            .violations()
            .iter()
            .any(|v| v.contains("still evicted")));
    }

    #[test]
    fn a_drained_run_must_restore_every_eviction() {
        let mut a = RequestArena::new();
        let id = a.alloc(mk_req(0, 0.0, 8, 2));
        let mut chk = InvariantChecker::new(true);
        chk.on_route(0.0, id, 0);
        chk.on_preempt(1.0, 0, id);
        // Force the books to look otherwise-clean at drain.
        chk.on_restore(1.5, 0, id);
        chk.on_preempt(2.0, 0, id);
        let insts: [crate::serving::Instance<'_>; 0] = [];
        chk.on_done(3.0, &insts, &a);
        assert!(chk
            .violations()
            .iter()
            .any(|v| v.contains("evicted (never restored)")));
        assert!(chk
            .violations()
            .iter()
            .any(|v| v.contains("evictions but")));
    }

    #[test]
    fn kv_books_must_match_the_active_set() {
        // An instance whose KV counter disagrees with its active set:
        // reserve happens via admission, so enqueue-without-kick keeps
        // them consistent; simulate the mismatch by reserving through
        // admission and then checking against an arena whose request
        // was mutated to a different footprint.
        let mut a = RequestArena::new();
        let id = a.alloc(mk_req(0, 0.0, 8, 2));
        let mut inst = crate::serving::Instance::new(
            Batcher::new(4, crate::serving::testutil::budget(100)),
            Box::new(FixedEngine(0.1)),
        );
        inst.enqueue(id, &a);
        let _ = inst.kick(0.0, &mut a);
        // Books now agree; no violation.
        let insts = [inst];
        let mut chk = InvariantChecker::new(false);
        chk.on_route(0.0, id, 0);
        chk.post_event(0.0, &InstanceEvent::Arrival(id), &insts, &a);
        assert!(chk.violations().is_empty(), "{:?}", chk.violations());
        // Grow the request's footprint behind the batcher's back: the
        // active sum drifts from the reservation counter.
        a[id].gen_len += 50;
        chk.post_event(0.1, &InstanceEvent::Arrival(id), &insts, &a);
        assert!(
            chk.violations().iter().any(|v| v.contains("summed over active")),
            "{:?}",
            chk.violations()
        );
    }

    #[test]
    fn conservation_flags_a_phantom_enqueue() {
        // The sim enqueues a request the checker never saw routed: the
        // books disagree with the instance queues.
        let mut a = RequestArena::new();
        let id = a.alloc(mk_req(0, 0.0, 8, 2));
        let mut inst = crate::serving::Instance::new(
            Batcher::new(1, open_budget()),
            Box::new(FixedEngine(0.1)),
        );
        inst.enqueue(id, &a);
        let insts = [inst];
        let mut chk = InvariantChecker::new(false);
        chk.post_event(0.0, &InstanceEvent::Arrival(id), &insts, &a);
        assert!(chk.violations().iter().any(|v| v.contains("conservation")));
    }
}
