//! Seeded generation of fuzz cases: workload + topology + limits, all
//! derived deterministically from one `u64` seed via [`Pcg32`].
//!
//! A [`FuzzCase`] is everything needed to build and run one cluster
//! simulation: the request stream, the instance count and
//! colocated/disaggregated split, the router policy, per-step engine
//! costs, the KV budget, the KV interconnect bandwidth, and the
//! `max_time`/`max_steps` limits. [`gen_case`] maps `seed -> FuzzCase`
//! as a pure function, so any failure replays from its seed alone.
//!
//! Seeds are stratified into eight families (`seed % 8`) so every batch
//! of seeds is guaranteed to cover the regimes that historically hide
//! bugs — a deadline landing before the first arrival (zero
//! completions), near-full KV budgets (head-of-line blocking),
//! disaggregated pools over finite and ideal links, exact `max_steps`
//! truncation, mid-run deadline clamps, an SLO router tight enough to
//! shed, and autoscaled fleets (warm-up, scale transitions, retirement
//! mid-run) — rather than sampling them by luck.
//!
//! A second generator, [`gen_preempt_case`], overlays any base seed
//! with a mixed-priority stream, a near-full KV budget, and preemption
//! enabled — the regime where priority scheduling must evict and
//! restore under pressure. The overlay draws from its own
//! seed-transformed RNG, so the base family replays unchanged.

use crate::cluster::{
    AutoscalePolicy, ClusterMode, ClusterSim, ClusterSpec,
    LeastOutstandingTokens, RoundRobin, Router, SloAdmission,
};
use crate::serving::{
    KvBudget, PreemptionConfig, Request, SimConfig, StepBatch, StepEngine,
    WorkloadGen, WorkloadSpec,
};
use crate::util::rng::Pcg32;

/// A deterministic, affine step-cost engine for fuzzing:
/// `base + per_lane * lanes + per_prefill_token * prefill_tokens`.
/// Cheap, order-free (no internal state), and strictly positive, so
/// every fuzz case terminates and replays exactly.
#[derive(Debug, Clone)]
pub struct FuzzEngine {
    /// Fixed cost per step, seconds.
    pub base: f64,
    /// Marginal cost per active lane, seconds.
    pub per_lane: f64,
    /// Marginal cost per prefilled prompt token, seconds.
    pub per_prefill_token: f64,
}

impl StepEngine for FuzzEngine {
    fn step_latency(&mut self, batch: u64, _max_context: u64) -> f64 {
        self.base + self.per_lane * batch as f64
    }

    fn mixed_step_latency(&mut self, step: &StepBatch) -> f64 {
        self.base
            + self.per_lane * step.lanes() as f64
            + self.per_prefill_token * step.prefill_tokens as f64
    }

    fn name(&self) -> String {
        "fuzz".into()
    }
}

/// Router policy of a fuzz case (a seed-friendly mirror of the
/// [`Router`] implementations).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterKind {
    /// Cycle arrivals across the front door.
    RoundRobin,
    /// Fewest outstanding tokens wins.
    LeastTokens,
    /// TTFT-predictive admission; sheds above the target.
    SloAware,
}

impl RouterKind {
    /// Build the boxed router this kind names.
    pub fn build(&self, ttft_target: f64) -> Box<dyn Router> {
        match self {
            RouterKind::RoundRobin => Box::new(RoundRobin::new()),
            RouterKind::LeastTokens => Box::new(LeastOutstandingTokens),
            RouterKind::SloAware => Box::new(SloAdmission::new(ttft_target)),
        }
    }
}

/// One self-contained fuzz scenario; see the module docs. `Debug` is
/// the replay artifact: a failing case is printed in full next to its
/// seed.
#[derive(Debug, Clone)]
pub struct FuzzCase {
    /// The seed this case was generated from (0 for hand-built cases).
    pub seed: u64,
    /// The offered request stream (arrival-sorted).
    pub requests: Vec<Request>,
    /// Total instances.
    pub instances: usize,
    /// Dedicated prefill instances (0 = colocated mode).
    pub prefill_instances: usize,
    /// Front-door routing policy.
    pub router: RouterKind,
    /// TTFT admission target for [`RouterKind::SloAware`], seconds.
    pub ttft_target: f64,
    /// Max concurrent sequences per instance.
    pub max_batch: usize,
    /// Prefill chunk tokens per step (0 = decode-only).
    pub prefill_chunk: u64,
    /// KV interconnect bandwidth, bytes/s (may be `f64::INFINITY`).
    pub kv_link_bw: f64,
    /// Per-instance KV capacity in tokens (the budget runs at one byte
    /// per token, so token and byte accounting coincide).
    pub kv_budget_tokens: f64,
    /// Step pricing.
    pub engine: FuzzEngine,
    /// Priority-preemption policy applied to every instance (disabled
    /// by default; [`gen_preempt_case`] enables it over a near-full
    /// budget and a mixed-priority stream).
    pub preempt: PreemptionConfig,
    /// Elastic-fleet policy (`None` = fixed fleet). Family 7 cases set
    /// this, exercising warm-up and scale transitions under fuzz.
    pub autoscale: Option<AutoscalePolicy>,
    /// Deadline clamp, seconds (`f64::INFINITY` to drain).
    pub max_time: f64,
    /// Global step limit.
    pub max_steps: u64,
}

/// `max_steps` at or above this is treated as "unlimited" when deciding
/// whether a case should fully drain.
pub const DRAIN_STEPS_FLOOR: u64 = 1_000_000;

impl FuzzCase {
    /// Whether this case must run to full drain — no deadline, no step
    /// limit — so the end-state invariants (empty queues, zero KV
    /// reserved, conservation closed) are required to hold.
    pub fn expect_drained(&self) -> bool {
        self.max_time.is_infinite() && self.max_steps >= DRAIN_STEPS_FLOOR
    }

    /// Whether the single-instance serving simulator is an exact oracle
    /// for this case: one colocated instance behind a router that
    /// degenerates to pass-through (the SLO router can shed, which the
    /// single simulator cannot).
    pub fn oracle_eligible(&self) -> bool {
        self.instances == 1
            && self.prefill_instances == 0
            && self.router != RouterKind::SloAware
            && self.autoscale.is_none()
    }

    /// The per-instance KV budget (one byte per token).
    pub fn kv_budget(&self) -> KvBudget {
        KvBudget::new(self.kv_budget_tokens, 0.0, 1.0)
    }

    /// The cluster spec this case describes.
    pub fn spec(&self) -> ClusterSpec {
        ClusterSpec {
            mode: if self.prefill_instances == 0 {
                ClusterMode::Colocated
            } else {
                ClusterMode::Disaggregated { prefill: self.prefill_instances }
            },
            max_batch: self.max_batch,
            prefill_chunk: self.prefill_chunk,
            kv_link_bw: self.kv_link_bw,
            autoscale: self.autoscale.clone(),
            sim: SimConfig { max_time: self.max_time, max_steps: self.max_steps },
        }
    }

    /// Build the cluster simulator for this case.
    pub fn build_sim(&self) -> ClusterSim {
        let engines: Vec<Box<dyn StepEngine>> = (0..self.instances)
            .map(|_| Box::new(self.engine.clone()) as Box<dyn StepEngine>)
            .collect();
        let mut sim = if self.autoscale.is_some() {
            // Spawned instances price steps exactly like the initial
            // fleet, so scale transitions change membership, never
            // step economics — failures isolate to the autoscaler.
            let proto = self.engine.clone();
            ClusterSim::with_factory(
                engines,
                self.kv_budget(),
                self.router.build(self.ttft_target),
                self.spec(),
                Box::new(move |_role| {
                    Box::new(proto.clone()) as Box<dyn StepEngine>
                }),
            )
        } else {
            ClusterSim::new(
                engines,
                self.kv_budget(),
                self.router.build(self.ttft_target),
                self.spec(),
            )
        };
        sim.set_preemption(self.preempt);
        sim
    }
}

/// Generate the fuzz case a seed names (pure: same seed, same case).
pub fn gen_case(seed: u64) -> FuzzCase {
    let mut rng = Pcg32::seed_from(seed);

    let n_requests = rng.range(3, 41) as u64;
    let arrival_rate = 2.0 + rng.f64() * 198.0;
    let clo = if rng.below(4) == 0 { 0 } else { rng.range(1, 65) as u64 };
    let chi = clo + rng.range(1, 129) as u64;
    let glo = 1 + rng.below(8) as u64;
    let ghi = glo + rng.range(1, 17) as u64;
    let requests = WorkloadGen::new(WorkloadSpec {
        arrival_rate,
        n_requests,
        context: (clo, chi),
        gen: (glo, ghi),
        priority_mix: Vec::new(),
        seed: rng.next_u64(),
    })
    .generate();

    let mut instances = rng.range(1, 7) as usize;
    let mut router = match rng.below(3) {
        0 => RouterKind::RoundRobin,
        1 => RouterKind::LeastTokens,
        _ => RouterKind::SloAware,
    };
    let max_batch = rng.range(1, 9) as usize;
    let mut prefill_chunk =
        if rng.below(3) == 0 { 0 } else { rng.range(4, 65) as u64 };
    let mut prefill_instances = 0usize;
    if instances >= 2 && rng.below(2) == 0 {
        prefill_instances = rng.range(1, instances as u32) as usize;
    }
    let mut kv_link_bw = if rng.below(3) == 0 {
        f64::INFINITY
    } else {
        10.0 + rng.f64() * 9990.0
    };
    // The budget always fits the largest single request, so FIFO
    // head-of-line admission can always eventually make progress and
    // drain-mode cases really drain.
    let max_footprint = requests
        .iter()
        .map(|r| r.context_len + r.gen_len)
        .max()
        .unwrap_or(1) as f64;
    let mut kv_budget_tokens = max_footprint * (1.0 + rng.f64() * 7.0);
    let engine = FuzzEngine {
        base: 0.001 + rng.f64() * 0.049,
        per_lane: rng.f64() * 0.01,
        per_prefill_token: rng.f64() * 0.001,
    };
    let mut ttft_target = 0.05 + rng.f64() * 1.95;
    let mut autoscale: Option<AutoscalePolicy> = None;
    let mut max_time = f64::INFINITY;
    let mut max_steps = 10_000_000u64;

    // Seed families: deterministic coverage of the historically buggy
    // regimes (see the module docs).
    match seed % 8 {
        0 => {
            // Deadline before the first arrival: zero events apply,
            // zero completions — the empty-report regime.
            max_time =
                requests.first().map(|r| r.arrival).unwrap_or(0.0) * 0.5;
        }
        1 => {
            // Near-full KV budget: head-of-line blocking under churn.
            kv_budget_tokens = max_footprint * (1.0 + rng.f64() * 0.25);
        }
        2 | 3 => {
            // Disaggregated pools; family 2 over a finite link (KV
            // shipment stalls), family 3 over an ideal one.
            if instances < 2 {
                instances = 2;
            }
            prefill_instances = rng.range(1, instances as u32) as usize;
            kv_link_bw = if seed % 8 == 2 {
                10.0 + rng.f64() * 990.0
            } else {
                f64::INFINITY
            };
        }
        4 => {
            // Exact max_steps truncation, mid-flight.
            max_steps = 1 + rng.below(20) as u64;
        }
        5 => {
            // Mid-run deadline clamp.
            max_time = rng.f64() * 2.0;
        }
        6 => {
            // SLO router tight enough to shed.
            router = RouterKind::SloAware;
            ttft_target = 0.01 + rng.f64() * 0.19;
        }
        _ => {
            // Autoscaled fleet: aggressive thresholds and short
            // warm-ups/cooldowns so real workloads trigger scale
            // transitions inside the (short) fuzz runs — warm-up
            // events, retirements, and membership churn under every
            // router and both cluster modes.
            autoscale = Some(AutoscalePolicy {
                shed_rate_up: rng.f64() * 0.2,
                ttft_headroom: 0.02 + rng.f64() * 0.48,
                idle_shrink_after: 0.05 + rng.f64() * 0.95,
                warmup_delay: rng.f64() * 0.5,
                cooldown: rng.f64() * 0.2,
                decision_window: 2 + rng.below(11) as u64,
                min_instances: 1,
                max_instances: instances + rng.range(1, 5) as usize,
            });
        }
    }
    if prefill_instances > 0 && prefill_chunk == 0 {
        // Disaggregation requires chunked prefill.
        prefill_chunk = rng.range(4, 65) as u64;
    }

    FuzzCase {
        seed,
        requests,
        instances,
        prefill_instances,
        router,
        ttft_target,
        max_batch,
        prefill_chunk,
        kv_link_bw,
        kv_budget_tokens,
        engine,
        preempt: PreemptionConfig::default(),
        autoscale,
        max_time,
        max_steps,
    }
}

/// Generate the preemption-family case a seed names: the base
/// [`gen_case`] scenario overlaid with a mixed-priority request stream,
/// a near-full KV budget, and preemption enabled — the regime where
/// priority scheduling must actually evict and restore under pressure.
///
/// The overlay draws from a fresh, seed-transformed RNG and re-tags the
/// base case's requests in place, so `gen_case(seed)` itself stays a
/// byte-identical pure function and both families replay from the same
/// seed number. Pure like the base generator: same seed, same case.
pub fn gen_preempt_case(seed: u64) -> FuzzCase {
    let mut case = gen_case(seed);
    let mut rng = Pcg32::seed_from(seed ^ 0x9e37_79b9_7f4a_7c15);

    // 2..=4 priority classes with random positive weights; every
    // request redraws its class, arrivals and lengths untouched.
    let classes = 2 + rng.below(3) as u8;
    let mix: Vec<(u8, f64)> =
        (0..classes).map(|c| (c, 0.2 + rng.f64())).collect();
    let total: f64 = mix.iter().map(|&(_, w)| w).sum();
    for r in &mut case.requests {
        let mut x = rng.f64() * total;
        r.priority = mix.last().unwrap().0;
        for &(class, w) in &mix {
            x -= w;
            if x < 0.0 {
                r.priority = class;
                break;
            }
        }
    }

    // Near-full budget (still fitting the largest single request, so
    // drain-mode cases really drain) forces eviction decisions instead
    // of leaving preemption latent.
    let max_footprint = case
        .requests
        .iter()
        .map(|r| r.context_len + r.gen_len)
        .max()
        .unwrap_or(1) as f64;
    case.kv_budget_tokens = max_footprint * (1.0 + rng.f64() * 0.5);
    case.preempt = PreemptionConfig {
        enabled: true,
        evict_cost: rng.f64() * 0.05,
        restore_cost: rng.f64() * 0.05,
    };
    case
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_a_pure_function_of_the_seed() {
        for seed in [0u64, 1, 7, 1088, 54321] {
            let a = gen_case(seed);
            let b = gen_case(seed);
            assert_eq!(format!("{a:?}"), format!("{b:?}"), "seed {seed}");
        }
    }

    #[test]
    fn every_seed_family_builds_a_valid_sim() {
        // ClusterSim::new panics on invalid topologies; building every
        // family is the constructive proof the generator never emits
        // one. Seeds 0..16 cover each family twice.
        for seed in 0..16u64 {
            let case = gen_case(seed);
            assert!(case.instances >= 1, "seed {seed}");
            assert!(case.prefill_instances < case.instances || case.prefill_instances == 0);
            let _ = case.build_sim();
        }
    }

    #[test]
    fn deadline_family_lands_before_the_first_arrival() {
        for k in 0..5u64 {
            let case = gen_case(k * 8);
            let first = case.requests.first().unwrap().arrival;
            assert!(
                case.max_time < first || first == 0.0,
                "seed {}: deadline {} vs first arrival {first}",
                k * 8,
                case.max_time
            );
            assert!(!case.expect_drained());
        }
    }

    #[test]
    fn disagg_families_split_pools_and_honor_chunking() {
        for k in 0..5u64 {
            for fam in [2u64, 3] {
                let case = gen_case(k * 8 + fam);
                assert!(case.prefill_instances >= 1);
                assert!(case.prefill_instances < case.instances);
                assert!(case.prefill_chunk > 0);
                if fam == 3 {
                    assert!(case.kv_link_bw.is_infinite());
                } else {
                    assert!(case.kv_link_bw.is_finite());
                }
            }
        }
    }

    #[test]
    fn autoscale_family_emits_valid_elastic_policies() {
        for k in 0..5u64 {
            let case = gen_case(k * 8 + 7);
            let policy = case.autoscale.as_ref().expect("family 7 autoscales");
            policy.validate();
            assert!(
                policy.max_instances > case.instances,
                "seed {}: ceiling {} leaves no room to grow past {}",
                k * 8 + 7,
                policy.max_instances,
                case.instances
            );
            assert!(!case.oracle_eligible(), "the single-instance oracle cannot scale");
            assert_eq!(case.spec().autoscale, case.autoscale);
            let _ = case.build_sim();
        }
        // Every other family keeps a fixed fleet.
        for fam in 0..7u64 {
            assert!(gen_case(fam).autoscale.is_none(), "family {fam}");
        }
    }

    #[test]
    fn preempt_generation_is_a_pure_function_of_the_seed() {
        for seed in [0u64, 3, 9, 1088] {
            let a = gen_preempt_case(seed);
            let b = gen_preempt_case(seed);
            assert_eq!(format!("{a:?}"), format!("{b:?}"), "seed {seed}");
        }
    }

    #[test]
    fn preempt_overlay_enables_eviction_without_touching_the_base() {
        for seed in 0..16u64 {
            let base = gen_case(seed);
            let over = gen_preempt_case(seed);
            // The overlay only re-tags: arrivals and lengths are the
            // base case's, bit for bit.
            assert_eq!(base.requests.len(), over.requests.len());
            for (b, o) in base.requests.iter().zip(&over.requests) {
                assert_eq!(b.arrival.to_bits(), o.arrival.to_bits());
                assert_eq!(b.context_len, o.context_len);
                assert_eq!(b.gen_len, o.gen_len);
                assert_eq!(b.priority, 0, "base stays single-class");
            }
            assert!(over.preempt.enabled, "seed {seed}");
            assert!(over.preempt.evict_cost >= 0.0);
            assert!(over.preempt.restore_cost >= 0.0);
            // Near-full but still admitting the largest request.
            let max_foot = over
                .requests
                .iter()
                .map(|r| r.context_len + r.gen_len)
                .max()
                .unwrap_or(1) as f64;
            assert!(over.kv_budget_tokens >= max_foot, "seed {seed}");
            assert!(over.kv_budget_tokens <= max_foot * 1.5 + 1e-9);
            let _ = over.build_sim();
        }
        // Across a seed batch the mix really is mixed: at least one
        // request lands outside class 0.
        let any_tagged = (0..16u64).any(|s| {
            gen_preempt_case(s).requests.iter().any(|r| r.priority > 0)
        });
        assert!(any_tagged);
    }

    #[test]
    fn base_cases_keep_preemption_disabled() {
        for seed in 0..8u64 {
            let case = gen_case(seed);
            assert!(!case.preempt.enabled, "seed {seed}");
            assert_eq!(case.preempt, PreemptionConfig::default());
        }
    }

    #[test]
    fn fuzz_engine_prices_mixed_steps_affinely() {
        let mut e = FuzzEngine {
            base: 0.01,
            per_lane: 0.002,
            per_prefill_token: 0.0001,
        };
        let step = StepBatch {
            decode_batch: 3,
            max_context: 100,
            prefill_seqs: 1,
            prefill_tokens: 50,
            prefill_past: 0,
        };
        let dt = e.mixed_step_latency(&step);
        assert!((dt - (0.01 + 0.002 * 4.0 + 0.0001 * 50.0)).abs() < 1e-12);
        assert!((e.step_latency(2, 10) - 0.014).abs() < 1e-12);
    }
}
