//! Deterministic simulation testing (DST) of the serving and cluster
//! simulators: seeded fuzz-case generation, per-event invariant
//! checking, report cross-checks, an exact single-instance oracle, and
//! greedy shrinking of failures.
//!
//! # How it works
//!
//! One `u64` seed names one complete scenario ([`gen_case`]): a Poisson
//! workload, a cluster topology (instance count, colocated or
//! disaggregated split, router policy, KV link bandwidth, optionally an
//! autoscale policy — family `seed % 8 == 7` runs an elastic fleet),
//! engine step costs, KV budget, and run limits. The case runs through
//! the real [`ClusterSim`](crate::cluster::ClusterSim) event loop with
//! an [`InvariantChecker`] — a
//! [`SimObserver`](crate::serving::SimObserver)
//! — auditing every applied event: monotonic clock, KV budget never
//! exceeded, busy time never exceeding the clock, request conservation
//! across queues/batches/transit (including across pool-size changes:
//! scale lifecycles must be ordered and warming/retired instances must
//! hold no work), exact token accounting and ordered lifecycle stamps
//! at every retirement, and closed books after a drained run. The final [`ClusterReport`](crate::cluster::ClusterReport)
//! is then reconciled against the checker's independent counts (and the
//! pooled latency percentiles against a bit-identical re-aggregation);
//! one-instance colocated cases are additionally diffed field-by-field
//! against [`ServingSim`](crate::serving::ServingSim) as an exact
//! oracle.
//!
//! Everything is deterministic — the DES is seeded, the generator is a
//! pure function of the seed, and no wall clock is consulted — so every
//! failure is replayable from its seed alone, and [`shrink`] greedily
//! minimizes the failing case before reporting it.
//!
//! # Reproducing a failing seed
//!
//! ```text
//! cargo run --release -- dst --seed 1088
//! ```
//!
//! runs exactly the case that failed (CI prints the seed on failure),
//! re-checks every invariant, and prints the violations plus the
//! shrunk case. `cargo run --release -- dst --seeds 200` sweeps a seed
//! range, and `--family preempt` sweeps the preemption overlay
//! ([`gen_preempt_case`]: mixed priorities, near-full KV, preemption
//! enabled — the checker additionally audits the evicted lifecycle and
//! exact KV conservation through evict/restore); see
//! `rust/src/dst/README.md` for the workflow and the bug catalog this
//! harness has flushed out.

mod gen;
mod harness;
mod invariant;

pub use gen::{gen_case, gen_preempt_case, FuzzCase, FuzzEngine, RouterKind};
pub use harness::{
    fuzz_range, fuzz_scan, fuzz_scan_with, run_case, run_preempt_seed,
    run_seed, shrink, CaseOutcome, FuzzFailure, SeedSummary,
};
pub use invariant::InvariantChecker;
