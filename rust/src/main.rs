//! `liminal` — the LIMINAL limit-study launcher.
//!
//! ```text
//! liminal list                         # models + chips
//! liminal eval  <model> [--chip hbm3] [--tp 128] [--pp 1] [--batch 1]
//!               [--context 4096] [--json]
//! liminal sweep <model> [--chip hbm3] [--contexts 4096,131072]
//!               [--tps 8,32,128] [--max-batch] [--csv out.csv]
//! liminal experiment <id|all> [--out results] [--artifacts artifacts]
//! liminal findings                     # Key Findings 1-10 pass/fail
//! liminal serve <model> [--chip hbm3] [--tp 128] [--backend analytic|pjrt]
//!               [--requests 100] [--rate 10] [--max-batch 32]
//!               [--prefill-chunk 1024] [--trace requests.jsonl]
//!               [--instances 4] [--router round-robin|least-tokens|slo]
//!               [--disagg-prefill 2] [--kv-link-gbps 100]
//!               [--autoscale --scale-max 8 --warmup 5] [--prefill-chip sram]
//!               [--priority-mix 0:4,2:1] [--preempt]
//! liminal validate [--artifacts artifacts]
//! liminal dst [--seeds 50] [--start 0] [--jobs N] [--seed N]
//!             [--family preempt] [--verbose]
//! ```

use std::path::{Path, PathBuf};

use liminal::apps::DecodePoint;
use liminal::cluster::AutoscalePolicy;
use liminal::config::ConfigFile;
use liminal::coordinator::{self, Backend};
use liminal::hw::{presets, SystemConfig};
use liminal::model::{evaluate, max_batch_for_system, EvalOptions};
use liminal::power::PowerModel;
use liminal::report::fmt_tps;
use liminal::sweep::{BatchSpec, Grid, SweepRunner};
use liminal::util::cli::Args;
use liminal::util::json::Json;

fn main() {
    let args = Args::from_env();
    let code = match args.subcommand() {
        Some("list") => cmd_list(&args),
        Some("eval") => cmd_eval(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("experiment") => cmd_experiment(&args),
        Some("findings") => cmd_findings(),
        Some("serve") => cmd_serve(&args),
        Some("validate") => cmd_validate(&args),
        Some("dst") => cmd_dst(&args),
        _ => {
            eprint!("{}", USAGE);
            2
        }
    };
    std::process::exit(code);
}

const USAGE: &str = "liminal — LLM decode limit-study framework

USAGE:
  liminal list
  liminal eval <model> [--chip hbm3] [--tp N] [--pp N] [--batch B|max]
               [--context T] [--config file.json] [--json]
  liminal sweep <model...> [--chip hbm3] [--tps 8,32,128]
               [--contexts 4096,...] [--max-batch] [--fit-pp] [--csv FILE]
  liminal experiment <table1|table2|table4|table5|table6|table7|
                      fig2|fig3|fig4|fig5|fig6|findings|moe-imbalance|
                      compute-role|software-gap|cluster-scaling|
                      autoscale-fleet|preemption|all>
               [--out DIR] [--artifacts DIR]
  liminal findings
  liminal serve <model> [--chip hbm3] [--tp N] [--backend analytic|pjrt]
               [--requests N] [--rate R] [--max-batch B] [--artifacts DIR]
               [--prefill-chunk N  (0 = decode-only)]
               [--trace FILE  (JSONL/CSV: arrival,context_len,gen_len)]
               [--instances N  (N > 1 serves a cluster)]
               [--router round-robin|least-tokens|slo] [--ttft-target SECONDS]
               [--disagg-prefill P  (dedicated prefill instances; 0 = colocated)]
               [--kv-link-gbps G  (KV shipment bandwidth, gigabits/s; inf = ideal)]
               [--prefill-chip NAME] [--prefill-tp N  (heterogeneous pools: the
                prefill pool serves on its own hardware; decode stays on --chip)]
               [--autoscale  (elastic fleet; --instances is the starting size)]
               [--scale-min N] [--scale-max N  (fleet size bounds; 1..8 default)]
               [--warmup SECONDS  (a spawned instance joins routing only after
                its warm-up elapses on the simulated clock; warm-up is billed)]
               [--scale-up-shed FRAC  (grow when the shed fraction over the
                decision window exceeds FRAC)]
               [--scale-up-ttft SECONDS  (grow when the best predicted TTFT
                across the front door exceeds SECONDS)]
               [--scale-idle SECONDS  (retire an instance idle this long)]
               [--scale-cooldown SECONDS] [--scale-window ARRIVALS]
               [--priority-mix CLASS:WEIGHT,...  (tag synthetic requests with
                priority classes, e.g. 0:4,2:1; higher class = more urgent)]
               [--preempt  (priority admission + KV preemption: an urgent
                arrival may evict the lowest-class active request)]
               [--preempt-evict SECONDS] [--preempt-restore SECONDS
                (step-time cost of dropping / re-materializing evicted KV;
                 either implies --preempt)]
  liminal validate [--artifacts DIR]
  liminal dst [--seeds N  (default 50)] [--start S] [--seed X  (replay one)]
               [--jobs N  (seed-shard workers; default: available cores)]
               [--family preempt  (overlay every scenario with a mixed-priority
                stream, a near-full KV budget, and preemption enabled)]
               [--verbose]
";

fn load_config(args: &Args) -> ConfigFile {
    match args.get("config") {
        Some(path) => ConfigFile::load(Path::new(path)).unwrap_or_else(|e| {
            eprintln!("error: {e:#}");
            std::process::exit(2);
        }),
        None => ConfigFile::default(),
    }
}

fn resolve_chip(cfg: &ConfigFile, args: &Args) -> liminal::hw::Chip {
    let name = args.get("chip").unwrap_or("hbm3");
    cfg.chip(name).unwrap_or_else(|| {
        eprintln!("error: unknown chip '{name}' (try hbm3, hbm4, 3d-dram, sram, cows, cent)");
        std::process::exit(2);
    })
}

fn cmd_list(args: &Args) -> i32 {
    let cfg = load_config(args);
    println!("models:");
    for name in cfg.registry().names() {
        println!("  {name}");
    }
    println!("chips:");
    for chip in presets::table1() {
        println!(
            "  {:<12} {:>7.1} TB/s  {:>6.2} PFLOPS  {:>8.1} GiB  ({})",
            chip.name,
            chip.mem_bw / liminal::TBPS,
            chip.tensor_flops / liminal::PFLOPS,
            chip.mem_capacity / liminal::GIB,
            chip.notes
        );
    }
    println!("  {:<12} (Appendix C PIM comparator)", "CENT");
    0
}

fn cmd_eval(args: &Args) -> i32 {
    let Some(model) = args.positional.get(1) else {
        eprintln!("usage: liminal eval <model> [options]");
        return 2;
    };
    let cfg = load_config(args);
    let registry = cfg.registry();
    let Some(app) = registry.app(model) else {
        eprintln!("error: unknown model '{model}'");
        return 2;
    };
    let chip = resolve_chip(&cfg, args);
    let tp = args.get_parsed("tp", 128u64);
    let pp = args.get_parsed("pp", 1u64);
    let context = args.get_parsed("context", 4096u64);
    let sys = SystemConfig::new(chip, tp, pp);

    let batch = match args.get("batch") {
        Some("max") => match max_batch_for_system(app.as_ref(), &sys, context) {
            Some(b) => b,
            None => {
                eprintln!("error: model does not fit on {}", sys.label());
                return 1;
            }
        },
        Some(b) => b.parse().unwrap_or(1),
        None => 1,
    };

    let pt = DecodePoint { batch, context };
    match evaluate(app.as_ref(), &sys, &pt, &EvalOptions::default()) {
        Ok(perf) => {
            let power = PowerModel::default().system_power(&sys);
            if args.flag("json") {
                let j = Json::obj(vec![
                    ("model", Json::Str(model.clone())),
                    ("system", Json::Str(sys.label())),
                    ("batch", Json::Num(batch as f64)),
                    ("context", Json::Num(context as f64)),
                    ("utps", Json::Num(perf.utps)),
                    ("stps", Json::Num(perf.stps)),
                    ("stps_per_watt", Json::Num(perf.stps / power.total_watts)),
                    ("t_batch_s", Json::Num(perf.lat.t_batch)),
                    ("t_mem_s", Json::Num(perf.lat.t_mem)),
                    ("t_compute_s", Json::Num(perf.lat.t_compute)),
                    ("t_exposed_s", Json::Num(perf.lat.t_exposed)),
                    ("bound", Json::Str(format!("{:?}", perf.lat.bound))),
                    ("capacity_gib", Json::Num(perf.capacity_bytes / liminal::GIB)),
                    ("watts", Json::Num(power.total_watts)),
                ]);
                println!("{j}");
            } else {
                println!("{} serving {model}  B={batch} T={context}", sys.label());
                println!(
                    "  UTPS {:>10}    STPS {:>10}    STPS/W {:.3}",
                    fmt_tps(perf.utps),
                    fmt_tps(perf.stps),
                    perf.stps / power.total_watts
                );
                println!(
                    "  t_batch {:.3} ms = max(mem {:.3} ms, compute {:.3} ms) + exposed {:.3} ms [{}-bound]",
                    perf.lat.t_batch * 1e3,
                    perf.lat.t_mem * 1e3,
                    perf.lat.t_compute * 1e3,
                    perf.lat.t_exposed * 1e3,
                    match perf.lat.bound {
                        liminal::model::Boundedness::Memory => "memory",
                        liminal::model::Boundedness::Compute => "compute",
                    }
                );
                println!(
                    "  capacity {:.1} GiB / {:.1} GiB   power {:.1} kW",
                    perf.capacity_bytes / liminal::GIB,
                    sys.total_capacity() / liminal::GIB,
                    power.total_watts / 1e3
                );
            }
            0
        }
        Err(e) => {
            eprintln!("unservable: {e}");
            1
        }
    }
}

fn parse_list(s: &str) -> Vec<u64> {
    s.split(',').filter_map(|x| x.trim().parse().ok()).collect()
}

fn cmd_sweep(args: &Args) -> i32 {
    let models: Vec<String> = if args.positional.len() > 1 {
        args.positional[1..].to_vec()
    } else {
        vec!["llama3-70b".into(), "llama3-405b".into(), "deepseek-v3".into()]
    };
    let cfg = load_config(args);
    let chip = resolve_chip(&cfg, args);
    let grid = Grid {
        models,
        chips: vec![chip],
        tps: args.get("tps").map(parse_list).unwrap_or(vec![8, 32, 128]),
        contexts: args
            .get("contexts")
            .map(parse_list)
            .unwrap_or(liminal::sweep::TABLE_CONTEXTS.to_vec()),
        batch: if args.flag("max-batch") {
            BatchSpec::MaxFit
        } else {
            BatchSpec::OneAndMaxFit
        },
        fit_pp: args.flag("fit-pp"),
    };
    let runner = SweepRunner { registry: cfg.registry(), ..Default::default() };
    let records = runner.run(&grid);

    let mut table = liminal::report::Table::new(
        "sweep",
        &["model", "system", "context", "batch", "utps", "stps", "stps_per_watt"],
    );
    for r in &records {
        table.push_row(vec![
            r.model.clone(),
            r.system.clone(),
            r.context.to_string(),
            r.batch.map(|b| b.to_string()).unwrap_or_else(|| "-".into()),
            r.utps.map(|v| format!("{v:.1}")).unwrap_or_else(|| "-".into()),
            r.stps.map(|v| format!("{v:.1}")).unwrap_or_else(|| "-".into()),
            r.stps_per_watt
                .map(|v| format!("{v:.4}"))
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    if let Some(path) = args.get("csv") {
        if let Err(e) = std::fs::write(path, table.to_csv()) {
            eprintln!("error writing {path}: {e}");
            return 1;
        }
        println!("wrote {} rows to {path}", records.len());
    } else {
        print!("{}", table.to_markdown());
    }
    0
}

fn cmd_experiment(args: &Args) -> i32 {
    let Some(id) = args.positional.get(1) else {
        eprintln!("usage: liminal experiment <id|all>");
        return 2;
    };
    let out_dir = PathBuf::from(args.get("out").unwrap_or("results"));
    let artifacts = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    let ids: Vec<&str> = if id == "all" {
        liminal::experiments::ALL.to_vec()
    } else {
        vec![id.as_str()]
    };
    if std::fs::create_dir_all(&out_dir).is_err() {
        eprintln!("error: cannot create {}", out_dir.display());
        return 1;
    }
    let mut failures = 0;
    for id in ids {
        match liminal::experiments::run(id, &artifacts) {
            Ok(report) => {
                let path = out_dir.join(format!("{id}.md"));
                let mut err = std::fs::write(&path, report.to_markdown()).err();
                if args.flag("json") {
                    let jpath = out_dir.join(format!("{id}.json"));
                    err = err.or(std::fs::write(&jpath, report.to_json().to_string()).err());
                }
                match err {
                    Some(e) => {
                        eprintln!("{id}: write failed: {e}");
                        failures += 1;
                    }
                    None => println!("{id}: wrote {}", path.display()),
                }
            }
            Err(e) => {
                eprintln!("{id}: FAILED: {e:#}");
                failures += 1;
            }
        }
    }
    if failures > 0 {
        1
    } else {
        0
    }
}

fn cmd_findings() -> i32 {
    match liminal::experiments::run_findings() {
        Ok(r) => {
            print!("{}", r.to_markdown());
            if r.notes.iter().any(|n| n.contains("FAILURES")) {
                1
            } else {
                0
            }
        }
        Err(e) => {
            eprintln!("findings failed: {e:#}");
            1
        }
    }
}

/// Parse a `CLASS:WEIGHT,...` priority-mix spec (e.g. `0:4,2:1`).
/// Returns `None` on malformed entries, non-finite/non-positive
/// weights, or classes outside `u8`.
fn parse_priority_mix(s: &str) -> Option<Vec<(u8, f64)>> {
    let mut mix = Vec::new();
    for entry in s.split(',') {
        let (class, weight) = entry.trim().split_once(':')?;
        let class: u8 = class.trim().parse().ok()?;
        let weight: f64 = weight.trim().parse().ok()?;
        if !weight.is_finite() || weight <= 0.0 {
            return None;
        }
        mix.push((class, weight));
    }
    if mix.is_empty() { None } else { Some(mix) }
}

fn cmd_serve(args: &Args) -> i32 {
    let Some(model) = args.positional.get(1) else {
        eprintln!("usage: liminal serve <model> [options]");
        return 2;
    };
    let cfg = load_config(args);
    let chip = resolve_chip(&cfg, args);
    let tp = args.get_parsed("tp", 128u64);
    let pp = args.get_parsed("pp", 1u64);
    let sys = SystemConfig::new(chip.clone(), tp, pp);
    let instances = args.get_parsed("instances", 1usize);
    let disagg_prefill = args.get_parsed("disagg-prefill", 0usize);
    let trace = args.get("trace").map(PathBuf::from);

    // Any scale knob implies an elastic fleet; the bare --autoscale
    // flag runs the policy defaults.
    const SCALE_KNOBS: [&str; 8] = [
        "scale-min",
        "scale-max",
        "warmup",
        "scale-up-shed",
        "scale-up-ttft",
        "scale-idle",
        "scale-cooldown",
        "scale-window",
    ];
    let autoscale_on = args.flag("autoscale")
        || SCALE_KNOBS.iter().any(|k| args.get(k).is_some());
    let hetero_prefill =
        args.get("prefill-chip").is_some() || args.get("prefill-tp").is_some();

    // A cost knob implies --preempt, the way a scale knob implies
    // --autoscale.
    let preempt_on = args.flag("preempt")
        || args.get("preempt-evict").is_some()
        || args.get("preempt-restore").is_some();
    let preempt = liminal::serving::PreemptionConfig {
        enabled: preempt_on,
        evict_cost: args.get_parsed("preempt-evict", 0.0f64),
        restore_cost: args.get_parsed("preempt-restore", 0.0f64),
    };
    let priority_mix = match args.get("priority-mix") {
        Some(s) => match parse_priority_mix(s) {
            Some(mix) => mix,
            None => {
                eprintln!(
                    "error: --priority-mix expects CLASS:WEIGHT,... with \
                     positive weights (e.g. 0:4,2:1)"
                );
                return 2;
            }
        },
        None => Vec::new(),
    };

    // Any cluster-only flag routes through the cluster simulator — a
    // one-instance cluster is behavior-identical to the plain
    // simulator (pinned by the equivalence test), and silently
    // ignoring `--router slo` on a single instance would fake
    // admission control the user asked for. With no cluster flags, one
    // instance keeps the plain simulator's leaner report.
    let cluster_requested = instances > 1
        || disagg_prefill > 0
        || args.get("router").is_some()
        || args.get("ttft-target").is_some()
        || args.get("kv-link-gbps").is_some()
        || autoscale_on
        || hetero_prefill;
    if cluster_requested {
        let mut job = coordinator::default_cluster_job(model, sys);
        job.instances = instances;
        job.prefill_instances = disagg_prefill;
        job.max_batch = args.get_parsed("max-batch", 32usize);
        job.prefill_chunk = args.get_parsed("prefill-chunk", job.prefill_chunk);
        job.ttft_target = args.get_parsed("ttft-target", job.ttft_target);
        job.workload.n_requests = args.get_parsed("requests", 100u64);
        job.workload.arrival_rate = args.get_parsed("rate", 10.0f64);
        job.workload.priority_mix = priority_mix;
        job.preempt = preempt;
        job.trace = trace;
        if let Some(gbps) = args.get("kv-link-gbps") {
            match gbps.parse::<f64>() {
                // Gbps = gigaBITS/s, the conventional network unit:
                // divide by 8 for bytes/s.
                Ok(g) if g > 0.0 => job.kv_link_bw = Some(g * 1e9 / 8.0),
                _ => {
                    eprintln!("error: --kv-link-gbps expects a positive number or inf");
                    return 2;
                }
            }
        }
        if let Some(name) = args.get("router") {
            match coordinator::RouterPolicy::parse(name) {
                Some(p) => job.router = p,
                None => {
                    eprintln!(
                        "error: unknown router '{name}' (try round-robin, least-tokens, slo)"
                    );
                    return 2;
                }
            }
        }
        if hetero_prefill {
            let pchip = match args.get("prefill-chip") {
                Some(name) => match cfg.chip(name) {
                    Some(c) => c,
                    None => {
                        eprintln!(
                            "error: unknown prefill chip '{name}' (try hbm3, hbm4, 3d-dram, sram, cows, cent)"
                        );
                        return 2;
                    }
                },
                None => chip,
            };
            job.prefill_sys = Some(SystemConfig::new(
                pchip,
                args.get_parsed("prefill-tp", tp),
                pp,
            ));
        }
        if autoscale_on {
            let d = AutoscalePolicy::default();
            job.autoscale = Some(AutoscalePolicy {
                min_instances: args.get_parsed("scale-min", d.min_instances),
                // The fleet can always hold its starting size.
                max_instances: args
                    .get_parsed("scale-max", d.max_instances.max(instances)),
                warmup_delay: args.get_parsed("warmup", d.warmup_delay),
                shed_rate_up: args.get_parsed("scale-up-shed", d.shed_rate_up),
                ttft_headroom: args
                    .get_parsed("scale-up-ttft", d.ttft_headroom),
                idle_shrink_after: args
                    .get_parsed("scale-idle", d.idle_shrink_after),
                cooldown: args.get_parsed("scale-cooldown", d.cooldown),
                decision_window: args
                    .get_parsed("scale-window", d.decision_window),
            });
        }
        if args.get("backend") == Some("pjrt") {
            eprintln!("error: cluster serving supports the analytic backend only");
            return 2;
        }
        let t0 = std::time::Instant::now();
        return match coordinator::serve_cluster(&job) {
            Ok(report) => {
                let wall = t0.elapsed().as_secs_f64().max(1e-9);
                println!("{}", report.summary());
                print!("{}", report.pool_summary());
                println!("{}", report.slo_summary());
                // jobs is always 1 here: one serve run is one DES on
                // one core — the number tracks single-core scheduler
                // throughput, while grid fan-outs (sweep/perf-report)
                // report their parallel worker count in the same slot.
                println!(
                    "des: {} events, wall_s {:.3}, jobs 1 -> {:.0} events/s, \
                     {:.1} sim-s/wall-s",
                    report.events,
                    wall,
                    report.events as f64 / wall,
                    report.cluster.span / wall,
                );
                0
            }
            Err(e) => {
                eprintln!("serve failed: {e:#}");
                1
            }
        };
    }

    let mut job = coordinator::default_job(model, sys);
    job.max_batch = args.get_parsed("max-batch", 32usize);
    job.prefill_chunk = args.get_parsed("prefill-chunk", job.prefill_chunk);
    job.workload.n_requests = args.get_parsed("requests", 100u64);
    job.workload.arrival_rate = args.get_parsed("rate", 10.0f64);
    job.workload.priority_mix = priority_mix;
    job.preempt = preempt;
    job.trace = trace;
    job.artifact_dir = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    job.backend = match args.get("backend").unwrap_or("analytic") {
        "pjrt" => Backend::Pjrt,
        _ => Backend::Analytic,
    };
    match coordinator::serve(&job) {
        Ok(report) => {
            println!("{}", report.summary());
            println!("{}", report.slo_summary());
            0
        }
        Err(e) => {
            eprintln!("serve failed: {e:#}");
            1
        }
    }
}

fn cmd_dst(args: &Args) -> i32 {
    use liminal::dst;
    // `--family preempt` swaps the generator: same seed numbers, each
    // base scenario overlaid with mixed priorities, a near-full KV
    // budget, and preemption enabled.
    let gen: fn(u64) -> dst::FuzzCase = match args.get("family") {
        None | Some("base") => dst::gen_case,
        Some("preempt") => dst::gen_preempt_case,
        Some(other) => {
            eprintln!("error: unknown family '{other}' (try base, preempt)");
            return 2;
        }
    };
    if args.get("seed").is_some() {
        // Replay a single seed (the CI-failure reproduction path).
        let seed = args.get_parsed("seed", 0u64);
        let case = gen(seed);
        let out = dst::run_case(&case);
        if out.violations.is_empty() {
            println!(
                "seed {seed}: ok ({} offered, {} completed, {} shed, {} events)",
                out.report.offered,
                out.report.cluster.completed,
                out.report.shed,
                out.report.events,
            );
            return 0;
        }
        println!("seed {seed}: FAILED");
        for v in &out.violations {
            println!("  violation: {v}");
        }
        let min = dst::shrink(&case);
        println!("shrunk case:\n{min:#?}");
        return 1;
    }
    let seeds = args.get_parsed("seeds", 50u64);
    let start = args.get_parsed("start", 0u64);
    let jobs = args.get_parsed("jobs", liminal::util::par::default_jobs());
    let verbose = args.flag("verbose");
    let t0 = std::time::Instant::now();
    // The scan shards seeds across workers; summaries come back in
    // ascending seed order regardless of `jobs`, so the output (and
    // which failing seed prints first) is deterministic.
    let summaries = dst::fuzz_scan_with(start, seeds, jobs, gen);
    let wall = t0.elapsed().as_secs_f64();
    if verbose {
        for s in &summaries {
            println!(
                "seed {}: {} ({} offered, {} completed, {} events)",
                s.seed,
                if s.failure.is_none() { "ok" } else { "FAILED" },
                s.offered,
                s.completed,
                s.events,
            );
        }
    }
    let failures: Vec<_> =
        summaries.iter().filter_map(|s| s.failure.as_ref()).collect();
    if failures.is_empty() {
        println!(
            "dst: {seeds} seeds passed (start {start}, jobs {jobs}) in {wall:.2}s"
        );
        return 0;
    }
    let family_flag = match args.get("family") {
        Some("preempt") => " --family preempt",
        _ => "",
    };
    for f in &failures {
        println!("seed {} failed:", f.seed);
        for v in &f.violations {
            println!("  violation: {v}");
        }
        println!(
            "  replay with: cargo run --release -- dst --seed {}{}",
            f.seed, family_flag
        );
        println!("  shrunk case:\n{:#?}", f.minimized);
    }
    println!("dst: {}/{seeds} seeds FAILED in {wall:.2}s", failures.len());
    1
}

fn cmd_validate(args: &Args) -> i32 {
    let opts = liminal::experiments::ValidationOptions {
        artifact_dir: PathBuf::from(args.get("artifacts").unwrap_or("artifacts")),
        ..Default::default()
    };
    match liminal::experiments::run_validation(&opts) {
        Ok(r) => {
            print!("{}", r.to_markdown());
            0
        }
        Err(e) => {
            eprintln!("validate failed: {e:#}");
            1
        }
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn usage_mentions_every_subcommand() {
        for sub in
            ["list", "eval", "sweep", "experiment", "findings", "serve", "validate", "dst"]
        {
            assert!(super::USAGE.contains(sub), "usage missing {sub}");
        }
    }

    #[test]
    fn usage_documents_the_autoscale_and_pool_knobs() {
        for flag in [
            "--autoscale",
            "--scale-min",
            "--scale-max",
            "--warmup",
            "--scale-up-shed",
            "--scale-up-ttft",
            "--scale-idle",
            "--scale-cooldown",
            "--scale-window",
            "--prefill-chip",
            "--prefill-tp",
        ] {
            assert!(super::USAGE.contains(flag), "usage missing {flag}");
        }
    }

    #[test]
    fn parse_list_handles_spaces() {
        assert_eq!(super::parse_list("8, 32 ,128"), vec![8, 32, 128]);
    }

    #[test]
    fn usage_documents_the_priority_and_preemption_knobs() {
        for flag in [
            "--priority-mix",
            "--preempt",
            "--preempt-evict",
            "--preempt-restore",
            "--family preempt",
            "preemption",
        ] {
            assert!(super::USAGE.contains(flag), "usage missing {flag}");
        }
    }

    #[test]
    fn priority_mix_specs_parse_or_reject() {
        assert_eq!(
            super::parse_priority_mix("0:4,2:1"),
            Some(vec![(0, 4.0), (2, 1.0)])
        );
        assert_eq!(
            super::parse_priority_mix(" 1 : 2.5 "),
            Some(vec![(1, 2.5)])
        );
        for bad in ["", "0", "0:", ":1", "0:0", "0:-1", "0:inf", "300:1", "0:1,"] {
            assert_eq!(super::parse_priority_mix(bad), None, "accepted {bad:?}");
        }
    }
}
