//! MoE routing-imbalance model (paper Appendix A.2).
//!
//! The learned router picks `MA` distinct experts out of `MR` per token.
//! Assuming a uniform router, the number of tokens landing on the most
//! loaded expert exceeds the mean, and *the whole batch waits for that
//! expert* — a tail-latency ("skew") effect. The paper defines the
//! imbalance factor `MI = max-loaded / average` and estimates it by
//! Monte-Carlo sampling (1M trials); e.g. `MI ≈ 3` for DeepSeekV3 at
//! batch 64. There is no closed form because experts are drawn *without*
//! replacement within a token.

mod imbalance;

pub use imbalance::{imbalance_factor, ImbalanceEstimator, ImbalanceSample};
