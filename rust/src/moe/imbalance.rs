//! Monte-Carlo estimation of the MoE imbalance factor `MI`.

use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};

use crate::util::rng::Pcg32;

/// One Monte-Carlo estimate of the imbalance factor.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ImbalanceSample {
    /// Mean over trials of `max_tokens_per_expert / avg_tokens_per_expert`.
    pub mi: f64,
    /// Number of trials averaged.
    pub trials: u32,
}

/// Seeded Monte-Carlo estimator for `MI(B; MR, MA)`.
///
/// Each trial routes `B` tokens: every token draws `MA` *distinct* experts
/// uniformly from `MR` (partial Fisher-Yates). The trial's statistic is
/// `max_e load(e) / (B * MA / MR)`. `MI` is the mean over trials.
#[derive(Debug, Clone)]
pub struct ImbalanceEstimator {
    /// `MR` — number of routed experts.
    pub routed_experts: u32,
    /// `MA` — experts activated per token.
    pub activated_experts: u32,
    /// Trials per estimate. The paper uses 1e6; 64K trials give the same
    /// value to three digits (see tests) and keep sweeps fast.
    pub trials: u32,
    /// RNG seed (estimates are fully deterministic given the seed).
    pub seed: u64,
}

impl Default for ImbalanceEstimator {
    fn default() -> Self {
        ImbalanceEstimator {
            routed_experts: 256,
            activated_experts: 8,
            trials: 65_536,
            seed: 0x11A1_1A1,
        }
    }
}

impl ImbalanceEstimator {
    /// Batch size beyond which the Gumbel/Gaussian closed form is used
    /// instead of Monte Carlo. At `B*MA/MR >= 128` the per-expert load is
    /// effectively Gaussian and the max-of-MR approximation matches the
    /// MC estimate to <1% at a tiny fraction of the cost (the sweeps in
    /// Fig. 5 / Table 6 push B past 10^5, where MC costs seconds).
    pub const CLOSED_FORM_MIN_BATCH: u64 = 4096;

    /// Estimate `MI` for batch size `batch`.
    pub fn estimate(&self, batch: u64) -> ImbalanceSample {
        let mr = self.routed_experts as usize;
        let ma = self.activated_experts as usize;
        assert!(ma <= mr, "cannot activate {ma} of {mr} experts");

        if batch >= Self::CLOSED_FORM_MIN_BATCH {
            return ImbalanceSample { mi: self.closed_form(batch), trials: 0 };
        }

        // With B*MA <= MR and distinct draws per token... the max load can
        // still exceed 1 across tokens; only B=1 is exactly balanced.
        if batch == 0 {
            return ImbalanceSample { mi: 1.0, trials: 0 };
        }
        if batch == 1 {
            // One token activates MA distinct experts: max load == 1 and
            // the paper's avg is floored at 1 token/expert -> MI == 1.
            return ImbalanceSample { mi: 1.0, trials: 0 };
        }

        let mut rng = Pcg32::seed_from(self.seed ^ batch);
        let avg = (batch as f64) * (ma as f64) / (mr as f64);
        // The paper floors the average at 1 token per expert (every
        // expert's weights must be touched anyway).
        let avg = avg.max(1.0);

        let mut loads = vec![0u32; mr];
        let mut experts: Vec<u32> = (0..mr as u32).collect();
        let mut acc = 0.0f64;
        // Adapt trial count: large batches concentrate sharply, so fewer
        // trials are needed for the same CI; this keeps B~1e5 tractable.
        let trials = self.trials_for(batch);
        for _ in 0..trials {
            loads.iter_mut().for_each(|l| *l = 0);
            for _tok in 0..batch {
                // Partial Fisher-Yates: pick MA distinct experts.
                for i in 0..ma {
                    let j = rng.range(i as u32, mr as u32) as usize;
                    experts.swap(i, j);
                    loads[experts[i] as usize] += 1;
                }
            }
            let max = *loads.iter().max().unwrap() as f64;
            acc += max / avg;
        }
        ImbalanceSample { mi: acc / trials as f64, trials }
    }

    /// Trials used for a given batch (shrinks as B grows; the statistic's
    /// relative variance decays roughly like 1/B).
    fn trials_for(&self, batch: u64) -> u32 {
        let scale = (batch as f64 / 8.0).max(1.0);
        ((self.trials as f64 / scale) as u32).clamp(256, self.trials)
    }

    /// Gaussian max-order-statistic approximation for large batches:
    /// per-expert load is ~Binomial(B, MA/MR) (tokens pick MA *distinct*
    /// experts, which only tightens the variance); the expected maximum
    /// of MR such variables is `mu + sigma * (sqrt(2 ln MR) - (ln ln MR +
    /// ln 4pi) / (2 sqrt(2 ln MR)))` (Gumbel correction).
    fn closed_form(&self, batch: u64) -> f64 {
        let mr = self.routed_experts as f64;
        let p = self.activated_experts as f64 / mr;
        let mu = batch as f64 * p;
        let sigma = (batch as f64 * p * (1.0 - p)).sqrt();
        let l = (2.0 * mr.ln()).sqrt();
        let gumbel = l - ((mr.ln().ln()) + (4.0 * std::f64::consts::PI).ln()) / (2.0 * l);
        (mu + sigma * gumbel) / mu.max(1.0)
    }
}

type Key = (u32, u32, u64);

fn cache() -> &'static Mutex<HashMap<Key, f64>> {
    static CACHE: OnceLock<Mutex<HashMap<Key, f64>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Cached, seeded `MI(B)` with the default estimator parameters for the
/// given expert configuration. This is the entry point the latency model
/// uses; repeated sweeps over the same batch sizes hit the cache.
pub fn imbalance_factor(routed_experts: u32, activated_experts: u32, batch: u64) -> f64 {
    let key = (routed_experts, activated_experts, batch);
    if let Some(&mi) = cache().lock().unwrap().get(&key) {
        return mi;
    }
    let est = ImbalanceEstimator {
        routed_experts,
        activated_experts,
        ..Default::default()
    };
    let mi = est.estimate(batch).mi;
    cache().lock().unwrap().insert(key, mi);
    mi
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_one_is_perfectly_balanced() {
        assert_eq!(imbalance_factor(256, 8, 1), 1.0);
    }

    #[test]
    fn deepseek_batch64_is_about_3x() {
        // Paper A.2: "for DeepSeekV3 with batch size 64, this imbalance
        // factor (MI) is 3x".
        let mi = imbalance_factor(256, 8, 64);
        assert!(mi > 2.5 && mi < 3.7, "got {mi}");
    }

    #[test]
    fn imbalance_decays_toward_one_at_large_batch() {
        let mi_64 = imbalance_factor(256, 8, 64);
        let mi_4096 = imbalance_factor(256, 8, 4096);
        assert!(mi_4096 < mi_64);
        assert!(mi_4096 < 1.35, "got {mi_4096}");
    }

    #[test]
    fn estimates_are_deterministic() {
        let est = ImbalanceEstimator::default();
        assert_eq!(est.estimate(48).mi, est.estimate(48).mi);
    }

    #[test]
    fn closed_form_is_continuous_with_monte_carlo() {
        // At the switchover batch the two estimators must agree closely.
        let est = ImbalanceEstimator::default();
        let b = ImbalanceEstimator::CLOSED_FORM_MIN_BATCH;
        let mc = {
            // Force the MC path just below the threshold.
            est.estimate(b - 1).mi
        };
        let cf = est.estimate(b).mi;
        assert!((mc - cf).abs() / mc < 0.05, "mc {mc} vs closed-form {cf}");
        // Closed form keeps decaying toward 1.
        assert!(est.estimate(1 << 20).mi < cf);
    }

    #[test]
    fn small_batches_have_max_load_capped_by_tokens() {
        // With B tokens, no expert can see more than B tokens; with the
        // floored average of 1, MI <= B.
        for b in [2u64, 4, 8] {
            let mi = imbalance_factor(256, 8, b);
            assert!(mi >= 1.0 && mi <= b as f64, "B={b} MI={mi}");
        }
    }
}
