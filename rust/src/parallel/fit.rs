//! System sizing: given a chip, a model, and a working point, compose
//! TP and PP so the model actually fits.
//!
//! The paper's policy (§2.1): strong-scale (TP) as far as useful — up to
//! the 128-chip collective limit — then weak-scale (PP) until the
//! weights + KV cache fit. "In all experiments, the system is sized to
//! serve at least 1 user" (§3).

use std::fmt;

use crate::apps::{Application, DecodePoint};
use crate::hw::{Chip, SystemConfig, MAX_TP};

/// Request to size a system.
#[derive(Debug, Clone)]
pub struct FitRequest {
    /// The chip to build from.
    pub chip: Chip,
    /// Fixed TP degree, or `None` to use the largest allowed (128).
    pub tp: Option<u64>,
    /// Working point that must fit.
    pub point: DecodePoint,
    /// Upper bound on pipeline stages (sanity guard; a model that needs
    /// more stages than this is declared unservable).
    pub max_pp: u64,
}

impl FitRequest {
    /// Fit `point` on `chip` with defaults (TP=128, PP up to 4096).
    pub fn new(chip: Chip, point: DecodePoint) -> Self {
        FitRequest { chip, tp: None, point, max_pp: 4096 }
    }
}

/// Why a system could not be sized.
#[derive(Debug, Clone, PartialEq)]
pub enum FitError {
    /// Even `max_pp` stages of `MAX_TP` chips cannot hold the workload.
    CapacityExceeded {
        /// Bytes required by the working point.
        required_bytes: f64,
        /// Bytes available at the largest permitted system.
        max_system_bytes: f64,
    },
}

impl fmt::Display for FitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FitError::CapacityExceeded { required_bytes, max_system_bytes } => write!(
                f,
                "workload needs {:.1} GiB, largest permitted system holds {:.1} GiB",
                required_bytes / crate::GIB,
                max_system_bytes / crate::GIB
            ),
        }
    }
}

impl std::error::Error for FitError {}

/// Minimum number of pipeline stages of `tp` chips needed to hold the
/// working point.
pub fn min_pp(app: &dyn Application, chip: &Chip, tp: u64, pt: &DecodePoint) -> u64 {
    let per_stage = chip.mem_capacity * tp as f64;
    (app.capacity_bytes(pt) / per_stage).ceil().max(1.0) as u64
}

/// Compose a system that serves `req.point`: TP as requested (or 128),
/// PP grown until capacity fits.
pub fn fit_system(app: &dyn Application, req: &FitRequest) -> Result<SystemConfig, FitError> {
    let tp = req.tp.unwrap_or(MAX_TP).min(MAX_TP).max(1);
    let pp = min_pp(app, &req.chip, tp, &req.point);
    if pp > req.max_pp {
        return Err(FitError::CapacityExceeded {
            required_bytes: app.capacity_bytes(&req.point),
            max_system_bytes: req.chip.mem_capacity * tp as f64 * req.max_pp as f64,
        });
    }
    Ok(SystemConfig::new(req.chip.clone(), tp, pp))
}

/// Largest batch that fits on an already-sized system; see
/// [`crate::model::max_batch_for_system`]. Re-exported here because batch
/// search is logically part of system sizing.
pub fn max_batch(app: &dyn Application, sys: &SystemConfig, context: u64) -> Option<u64> {
    crate::model::max_batch_for_system(app, sys, context)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{DeepSeekV3, Llama3};
    use crate::hw::presets;

    #[test]
    fn hbm3_fits_all_models_in_one_stage() {
        for tp in [8u64, 32, 128] {
            let pt = DecodePoint { batch: 1, context: 4096 };
            assert_eq!(min_pp(&Llama3::llama3_405b(), &presets::hbm3(), tp, &pt), 1);
        }
        // DeepSeek needs 671 GiB; TP8 x 96 GiB = 768 GiB — just fits.
        let pt = DecodePoint { batch: 1, context: 4096 };
        assert_eq!(min_pp(&DeepSeekV3::v3(), &presets::hbm3(), 8, &pt), 1);
    }

    #[test]
    fn sram_systems_need_many_stages() {
        // Llama3-405B + 128K KV on 0.5 GiB chips: TP128 holds 64 GiB per
        // stage, so ~7 stages (paper §4.7's "capacity challenges").
        let pt = DecodePoint { batch: 1, context: 131072 };
        let sys = fit_system(
            &Llama3::llama3_405b(),
            &FitRequest::new(presets::sram(), pt),
        )
        .unwrap();
        assert_eq!(sys.tp, 128);
        assert!(sys.pp >= 6 && sys.pp <= 8, "pp = {}", sys.pp);
    }

    #[test]
    fn impossible_fits_are_reported() {
        let pt = DecodePoint { batch: 1, context: 4096 };
        let req = FitRequest {
            max_pp: 1,
            tp: Some(8),
            ..FitRequest::new(presets::sram(), pt)
        };
        let err = fit_system(&Llama3::llama3_70b(), &req).unwrap_err();
        assert!(err.to_string().contains("GiB"));
    }

    #[test]
    fn cows_wafer_count_for_llama70b() {
        // 70.55e9 weights + small KV over 11 GiB wafers -> 6-7 wafers.
        let pt = DecodePoint { batch: 1, context: 4096 };
        let pp = min_pp(&Llama3::llama3_70b(), &presets::cows(), 1, &pt);
        assert!(pp >= 6 && pp <= 7, "pp = {pp}");
    }
}
