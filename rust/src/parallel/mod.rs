//! Parallelism composition: strong scaling (TP) to cut latency, weak
//! scaling (PP) to fit capacity and multiply throughput (paper §2.1,
//! "Distributed Execution").

mod fit;

pub use fit::{fit_system, max_batch, min_pp, FitError, FitRequest};
