//! L3 coordinator: wires workload generation, the continuous batcher,
//! and a step engine into a runnable serving instance.
//!
//! For this paper the "coordination contribution" is the limit-study
//! harness itself, so the coordinator is deliberately thin (per the
//! architecture guide): CLI-driven process lifecycle around the serving
//! simulator and the experiment registry. It supports both backends —
//! analytic (paper-scale what-if serving) and PJRT (real execution of
//! the AOT decode step).

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::Context;

use crate::apps::Registry;
use crate::cluster::{
    AutoscalePolicy, ClusterMode, ClusterReport, ClusterSim, ClusterSpec,
    LeastOutstandingTokens, Role, RoundRobin, Router, SloAdmission,
};
use crate::hw::SystemConfig;
use crate::serving::{
    AnalyticEngine, Batcher, KvBudget, PjrtEngine, PreemptionConfig, Request,
    ServingReport, ServingSim, SimConfig, StepEngine, WorkloadGen,
    WorkloadSpec, WorkloadTrace,
};
use crate::Result;

/// What backend prices each decode step.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Backend {
    /// LIMINAL analytical latency (paper-scale systems).
    Analytic,
    /// Real PJRT execution of the AOT artifacts.
    Pjrt,
}

/// A serve job description.
#[derive(Debug, Clone)]
pub struct ServeJob {
    /// Model name (registry key) — analytic backend only.
    pub model: String,
    /// System to serve on — analytic backend only.
    pub sys: SystemConfig,
    /// Synthetic workload (ignored when `trace` is set).
    pub workload: WorkloadSpec,
    /// Replay a recorded trace (JSONL/CSV: `arrival, context_len,
    /// gen_len`) instead of generating the synthetic workload.
    pub trace: Option<PathBuf>,
    /// Max concurrent sequences.
    pub max_batch: usize,
    /// Prefill chunk size in tokens; 0 reverts to the decode-only
    /// assumption (prompts prefilled elsewhere).
    pub prefill_chunk: u64,
    /// Backend choice.
    pub backend: Backend,
    /// Artifact directory (PJRT backend).
    pub artifact_dir: std::path::PathBuf,
    /// Priority-preemption policy for the instance's batcher (disabled
    /// by default, which is bit-identical to FIFO run-to-completion).
    pub preempt: PreemptionConfig,
}

/// Resolve a job's request stream: replay the trace if one is set, else
/// generate the synthetic workload.
fn resolve_workload(
    spec: &WorkloadSpec,
    trace: &Option<PathBuf>,
) -> Result<Vec<Request>> {
    match trace {
        Some(path) => WorkloadTrace::load(path),
        None => Ok(WorkloadGen::new(spec.clone()).generate()),
    }
}

/// Run a serve job to completion and return its report.
pub fn serve(job: &ServeJob) -> Result<ServingReport> {
    let registry = Registry::builtin();
    let app = registry
        .app(&job.model)
        .with_context(|| format!("unknown model {}", job.model))?;

    let workload = resolve_workload(&job.workload, &job.trace)?;
    // prefill_chunk = 0 degrades to the decode-only batcher.
    let make_batcher = |max_batch: usize, kv: KvBudget| {
        let mut b = Batcher::with_prefill(max_batch, kv, job.prefill_chunk);
        b.set_preemption(job.preempt);
        b
    };
    match job.backend {
        Backend::Analytic => {
            let kv = KvBudget::new(
                job.sys.total_capacity(),
                app.weight_bytes(),
                app.kv_bytes_per_token(),
            );
            let batcher = make_batcher(job.max_batch, kv);
            let mut engine = AnalyticEngine::new(app, job.sys.clone());
            Ok(ServingSim::new(batcher, &mut engine, SimConfig::default())
                .run(workload))
        }
        Backend::Pjrt => {
            let mut rt = crate::runtime::Runtime::new(&job.artifact_dir)?;
            let mut engine = PjrtEngine::new(&mut rt, job.max_batch as u64)?;
            engine.randomize_params(42)?;
            // The executable model has a small fixed context; scale the
            // synthetic workload into its window.
            let t = engine.context;
            let mut wl = workload;
            for r in &mut wl {
                r.context_len = r.context_len.min(t / 4).max(1);
                r.gen_len = r.gen_len.min(t / 4).max(1);
            }
            let kv = KvBudget::new(
                (engine.batch * t + 1) as f64, // token-slot budget
                0.0,
                1.0,
            );
            let batcher = make_batcher(engine.batch as usize, kv);
            let dyn_engine: &mut dyn StepEngine = &mut engine;
            Ok(ServingSim::new(batcher, dyn_engine, SimConfig::default())
                .run(wl))
        }
    }
}

/// Convenience builder used by the CLI and examples. Prefill-aware by
/// default; set `prefill_chunk = 0` for the decode-only legacy mode.
pub fn default_job(model: &str, sys: SystemConfig) -> ServeJob {
    ServeJob {
        model: model.to_string(),
        sys,
        workload: WorkloadSpec::default(),
        trace: None,
        max_batch: 32,
        prefill_chunk: crate::model::DEFAULT_PREFILL_CHUNK,
        backend: Backend::Analytic,
        artifact_dir: std::path::PathBuf::from("artifacts"),
        preempt: PreemptionConfig::default(),
    }
}

/// Routing policy selector for cluster jobs (CLI-friendly mirror of the
/// [`Router`] implementations).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterPolicy {
    /// Cycle arrivals across the front-door pool.
    RoundRobin,
    /// Send each arrival to the instance with the fewest outstanding
    /// tokens (pending prefill + generation backlog).
    LeastTokens,
    /// Admit to the lowest predicted TTFT; shed above the target.
    SloAware,
}

impl RouterPolicy {
    /// Parse a CLI spelling (`round-robin`, `least-tokens`, `slo`).
    pub fn parse(s: &str) -> Option<RouterPolicy> {
        match s {
            "round-robin" | "rr" => Some(RouterPolicy::RoundRobin),
            "least-tokens" | "lt" => Some(RouterPolicy::LeastTokens),
            "slo" | "slo-aware" => Some(RouterPolicy::SloAware),
            _ => None,
        }
    }

    /// Build the boxed router this policy names.
    pub fn build(&self, ttft_target: f64) -> Box<dyn Router> {
        match self {
            RouterPolicy::RoundRobin => Box::new(RoundRobin::new()),
            RouterPolicy::LeastTokens => Box::new(LeastOutstandingTokens),
            RouterPolicy::SloAware => Box::new(SloAdmission::new(ttft_target)),
        }
    }
}

/// A cluster serve job: N identical analytic instances behind a router,
/// optionally split into disaggregated prefill/decode pools.
#[derive(Debug, Clone)]
pub struct ClusterJob {
    /// Model name (registry key).
    pub model: String,
    /// Per-instance system (each instance is an independent copy).
    pub sys: SystemConfig,
    /// Synthetic workload offered to the cluster front door (ignored
    /// when `trace` is set).
    pub workload: WorkloadSpec,
    /// Replay a recorded trace instead of the synthetic workload.
    pub trace: Option<PathBuf>,
    /// Max concurrent sequences per instance.
    pub max_batch: usize,
    /// Prefill chunk tokens per step on prefill-capable instances.
    pub prefill_chunk: u64,
    /// Total instances.
    pub instances: usize,
    /// Dedicated prefill instances (0 = colocated mode).
    pub prefill_instances: usize,
    /// Front-door routing policy.
    pub router: RouterPolicy,
    /// TTFT admission target for [`RouterPolicy::SloAware`], seconds.
    pub ttft_target: f64,
    /// KV interconnect bandwidth override, bytes/s (`None` uses the
    /// per-instance system's [`SystemConfig::interconnect_bw`];
    /// `f64::INFINITY` models an ideal link).
    pub kv_link_bw: Option<f64>,
    /// Dedicated hardware for the prefill pool (heterogeneous pools);
    /// `None` serves both pools on `sys`. Only meaningful when
    /// `prefill_instances > 0` — prefill is compute-bound while decode
    /// is bandwidth-bound, so the pools often want different chips.
    pub prefill_sys: Option<SystemConfig>,
    /// Elastic fleet policy; `None` runs the fixed fleet. When set, the
    /// cluster grows toward `max_instances` on shed pressure or TTFT
    /// headroom exhaustion and shrinks idle instances toward
    /// `min_instances`; spawned instances serve only after the warm-up
    /// delay elapses on the simulated clock.
    pub autoscale: Option<AutoscalePolicy>,
    /// Priority-preemption policy applied to every instance's batcher
    /// (autoscale-spawned instances inherit it). Disabled by default —
    /// bit-identical to the FIFO run-to-completion cluster.
    pub preempt: PreemptionConfig,
}

/// Convenience builder for cluster jobs: 4 colocated instances,
/// round-robin routing, prefill-aware, hardware-derived KV link.
pub fn default_cluster_job(model: &str, sys: SystemConfig) -> ClusterJob {
    ClusterJob {
        model: model.to_string(),
        sys,
        workload: WorkloadSpec::default(),
        trace: None,
        max_batch: 32,
        prefill_chunk: crate::model::DEFAULT_PREFILL_CHUNK,
        instances: 4,
        prefill_instances: 0,
        router: RouterPolicy::RoundRobin,
        ttft_target: 0.5,
        kv_link_bw: None,
        prefill_sys: None,
        autoscale: None,
        preempt: PreemptionConfig::default(),
    }
}

/// Validate a cluster job and build its simulator (N analytic
/// instances, KV budget, router, spec) without running it. Split out of
/// [`serve_cluster`] so callers that need the simulator itself — the
/// DST harness runs it under [`crate::serving::SimObserver`] hooks via
/// [`crate::cluster::ClusterSim::run_with`] — share the exact
/// production wiring.
pub fn build_cluster_sim(job: &ClusterJob) -> Result<ClusterSim> {
    let registry = Registry::builtin();
    let app = registry
        .app(&job.model)
        .with_context(|| format!("unknown model {}", job.model))?;
    anyhow::ensure!(job.instances >= 1, "cluster needs at least one instance");
    anyhow::ensure!(
        job.prefill_instances < job.instances,
        "prefill pool ({}) must leave at least one decode instance of {}",
        job.prefill_instances,
        job.instances
    );
    anyhow::ensure!(
        job.prefill_instances == 0 || job.prefill_chunk > 0,
        "disaggregated mode needs a nonzero prefill chunk"
    );
    anyhow::ensure!(
        job.prefill_sys.is_none() || job.prefill_instances > 0,
        "a dedicated prefill system needs a prefill pool (prefill_instances > 0)"
    );
    if let Some(p) = &job.autoscale {
        anyhow::ensure!(
            p.min_instances >= 1 && p.min_instances <= p.max_instances,
            "autoscale bounds must satisfy 1 <= min ({}) <= max ({})",
            p.min_instances,
            p.max_instances
        );
    }
    let kv_link_bw = job.kv_link_bw.unwrap_or_else(|| job.sys.interconnect_bw());
    anyhow::ensure!(
        kv_link_bw > 0.0,
        "kv link bandwidth must be positive (got {kv_link_bw})"
    );

    // Heterogeneous pools: the first `prefill_instances` engines (the
    // prefill pool) price on `prefill_sys` when one is set; everything
    // else — and the KV budget, which lives decode-side — on `sys`.
    let sys_for = |role: Role| match (role, &job.prefill_sys) {
        (Role::Prefill, Some(p)) => p.clone(),
        _ => job.sys.clone(),
    };
    let engines: Vec<Box<dyn StepEngine>> = (0..job.instances)
        .map(|i| {
            let role = if job.prefill_instances > 0 && i < job.prefill_instances
            {
                Role::Prefill
            } else if job.prefill_instances > 0 {
                Role::Decode
            } else {
                Role::Colocated
            };
            Box::new(AnalyticEngine::new(Arc::clone(&app), sys_for(role)))
                as Box<dyn StepEngine>
        })
        .collect();
    let kv = KvBudget::new(
        job.sys.total_capacity(),
        app.weight_bytes(),
        app.kv_bytes_per_token(),
    );
    let mode = if job.prefill_instances == 0 {
        ClusterMode::Colocated
    } else {
        ClusterMode::Disaggregated { prefill: job.prefill_instances }
    };
    let spec = ClusterSpec {
        mode,
        max_batch: job.max_batch,
        prefill_chunk: job.prefill_chunk,
        kv_link_bw,
        sim: SimConfig::default(),
        autoscale: job.autoscale.clone(),
    };
    let router = job.router.build(job.ttft_target);
    let mut sim = if job.autoscale.is_some() {
        // Spawned instances get the same role-matched analytic pricing
        // as the initial fleet.
        let app = Arc::clone(&app);
        let sys = job.sys.clone();
        let prefill_sys = job.prefill_sys.clone();
        let factory = Box::new(move |role: Role| {
            let s = match (role, &prefill_sys) {
                (Role::Prefill, Some(p)) => p.clone(),
                _ => sys.clone(),
            };
            Box::new(AnalyticEngine::new(Arc::clone(&app), s))
                as Box<dyn StepEngine>
        });
        ClusterSim::with_factory(engines, kv, router, spec, factory)
    } else {
        ClusterSim::new(engines, kv, router, spec)
    };
    sim.set_preemption(job.preempt);
    Ok(sim)
}

/// Run a cluster job to completion and return its merged report.
pub fn serve_cluster(job: &ClusterJob) -> Result<ClusterReport> {
    let sim = build_cluster_sim(job)?;
    let workload = resolve_workload(&job.workload, &job.trace)?;
    Ok(sim.run(workload))
}

/// Re-exported so `main.rs` needn't reach into serving directly.
pub use crate::serving::ServingReport as Report;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::presets;

    #[test]
    fn analytic_serve_end_to_end() {
        let sys = SystemConfig::new(presets::hbm3(), 128, 1);
        let mut job = default_job("llama3-70b", sys);
        job.workload.n_requests = 40;
        job.workload.arrival_rate = 200.0;
        let rep = serve(&job).unwrap();
        assert_eq!(rep.completed, 40);
        // Each user's decode rate is bounded by the single-user UTPS.
        assert!(rep.utps_mean <= 2100.0);
        assert!(rep.stps > rep.utps_mean * 0.9);
        // Prefill-aware by default: prompts were actually ingested and
        // every request saw a strictly positive TTFT.
        assert!(rep.prefill_tokens > 0);
        assert!(rep.ttft.p50 > 0.0);
        assert!(rep.e2e.p99 >= rep.ttft.p99);
    }

    #[test]
    fn decode_only_mode_still_supported() {
        let sys = SystemConfig::new(presets::hbm3(), 128, 1);
        let mut job = default_job("llama3-70b", sys);
        job.prefill_chunk = 0;
        job.workload.n_requests = 10;
        let rep = serve(&job).unwrap();
        assert_eq!(rep.completed, 10);
        assert_eq!(rep.prefill_tokens, 0);
    }

    #[test]
    fn unknown_model_is_an_error() {
        let sys = SystemConfig::new(presets::hbm3(), 8, 1);
        let job = default_job("not-a-model", sys);
        assert!(serve(&job).is_err());
    }

    #[test]
    fn trace_driven_serve_replays_the_sample_trace() {
        let sys = SystemConfig::new(presets::hbm3(), 8, 1);
        let mut job = default_job("llama3-70b", sys);
        job.trace = Some(PathBuf::from(concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/tests/data/sample_trace.jsonl"
        )));
        // The synthetic spec is ignored when a trace is set.
        job.workload.n_requests = 3;
        let rep = serve(&job).unwrap();
        assert_eq!(rep.completed, 20);
        // The sample trace carries 32256 prompt tokens; all ingested.
        assert_eq!(rep.prefill_tokens, 32256);
        assert!(rep.ttft.p50 > 0.0);
    }

    #[test]
    fn missing_trace_file_is_an_error() {
        let sys = SystemConfig::new(presets::hbm3(), 8, 1);
        let mut job = default_job("llama3-70b", sys);
        job.trace = Some(PathBuf::from("/nonexistent/trace.jsonl"));
        let err = serve(&job).unwrap_err().to_string();
        assert!(err.contains("trace"), "{err}");
    }

    #[test]
    fn cluster_serve_end_to_end() {
        let sys = SystemConfig::new(presets::hbm3(), 8, 1);
        let mut job = default_cluster_job("llama3-70b", sys);
        job.instances = 2;
        job.workload.n_requests = 20;
        job.workload.arrival_rate = 100.0;
        let rep = serve_cluster(&job).unwrap();
        assert_eq!(rep.offered, 20);
        assert_eq!(rep.cluster.completed, 20);
        assert_eq!(rep.shed, 0);
        assert!(rep.cluster.ttft.p50 > 0.0);
        assert_eq!(rep.per_instance.len(), 2);
    }

    #[test]
    fn cluster_disaggregated_split_is_validated() {
        let sys = SystemConfig::new(presets::hbm3(), 8, 1);
        let mut job = default_cluster_job("llama3-70b", sys);
        job.instances = 2;
        job.prefill_instances = 2; // no decode pool left
        assert!(serve_cluster(&job).is_err());
    }

    #[test]
    fn cluster_disaggregation_requires_prefill_chunk() {
        let sys = SystemConfig::new(presets::hbm3(), 8, 1);
        let mut job = default_cluster_job("llama3-70b", sys);
        job.instances = 2;
        job.prefill_instances = 1;
        job.prefill_chunk = 0; // CLI-reachable: --prefill-chunk 0
        assert!(serve_cluster(&job).is_err());
    }

    #[test]
    fn autoscaled_cluster_job_runs_end_to_end() {
        let sys = SystemConfig::new(presets::hbm3(), 8, 1);
        let mut job = default_cluster_job("llama3-70b", sys);
        job.instances = 1;
        job.router = RouterPolicy::SloAware;
        job.workload.n_requests = 30;
        job.workload.arrival_rate = 50.0;
        job.autoscale = Some(AutoscalePolicy {
            min_instances: 1,
            max_instances: 4,
            ..AutoscalePolicy::default()
        });
        let rep = serve_cluster(&job).unwrap();
        assert!(rep.mode.contains("autoscaled"), "{}", rep.mode);
        assert_eq!(rep.cluster.completed + rep.shed, 30);
        assert!(rep.instance_seconds > 0.0);
        // The fleet never exceeds the policy ceiling.
        assert!(rep.per_instance.len() <= 4);
    }

    #[test]
    fn autoscale_bounds_are_validated() {
        let sys = SystemConfig::new(presets::hbm3(), 8, 1);
        let mut job = default_cluster_job("llama3-70b", sys);
        job.autoscale = Some(AutoscalePolicy {
            min_instances: 5,
            max_instances: 2,
            ..AutoscalePolicy::default()
        });
        let err = serve_cluster(&job).unwrap_err().to_string();
        assert!(err.contains("min"), "{err}");
    }

    #[test]
    fn heterogeneous_prefill_pool_serves_on_its_own_hardware() {
        let sys = SystemConfig::new(presets::hbm3(), 8, 1);
        let mut homo = default_cluster_job("llama3-70b", sys.clone());
        homo.instances = 2;
        homo.prefill_instances = 1;
        homo.workload.n_requests = 20;
        homo.workload.arrival_rate = 20.0;
        let mut hetero = homo.clone();
        // A prefill pool with 4x the chips ingests prompts faster;
        // decode pricing and the KV budget stay on the decode system.
        hetero.prefill_sys = Some(SystemConfig::new(presets::hbm3(), 32, 1));
        let rep_homo = serve_cluster(&homo).unwrap();
        let rep_hetero = serve_cluster(&hetero).unwrap();
        assert_eq!(rep_hetero.cluster.completed, 20);
        assert!(rep_hetero.cluster.ttft.p50 > 0.0);
        assert!(rep_hetero.cluster.ttft.p50 <= rep_homo.cluster.ttft.p50);
    }

    #[test]
    fn prefill_sys_without_a_prefill_pool_is_an_error() {
        let sys = SystemConfig::new(presets::hbm3(), 8, 1);
        let mut job = default_cluster_job("llama3-70b", sys.clone());
        job.prefill_sys = Some(sys); // colocated: no pool to serve it
        let err = serve_cluster(&job).unwrap_err().to_string();
        assert!(err.contains("prefill pool"), "{err}");
    }

    #[test]
    fn cluster_job_threads_priority_and_preemption() {
        let sys = SystemConfig::new(presets::hbm3(), 8, 1);
        let mut job = default_cluster_job("llama3-70b", sys);
        job.instances = 2;
        job.workload.n_requests = 30;
        job.workload.arrival_rate = 100.0;
        job.workload.priority_mix = vec![(0, 3.0), (2, 1.0)];
        job.preempt = PreemptionConfig {
            enabled: true,
            evict_cost: 0.001,
            restore_cost: 0.001,
        };
        let rep = serve_cluster(&job).unwrap();
        // The run drains, so every request completes regardless of how
        // many evict/restore cycles it took, and the preemption books
        // close: every eviction was eventually restored.
        assert_eq!(rep.cluster.completed, 30);
        assert_eq!(rep.cluster.preemptions, rep.cluster.restores);
    }

    #[test]
    fn router_policy_parses_cli_spellings() {
        assert_eq!(
            RouterPolicy::parse("round-robin"),
            Some(RouterPolicy::RoundRobin)
        );
        assert_eq!(
            RouterPolicy::parse("least-tokens"),
            Some(RouterPolicy::LeastTokens)
        );
        assert_eq!(RouterPolicy::parse("slo"), Some(RouterPolicy::SloAware));
        assert_eq!(RouterPolicy::parse("hash"), None);
    }
}
