//! L3 coordinator: wires workload generation, the continuous batcher,
//! and a step engine into a runnable serving instance.
//!
//! For this paper the "coordination contribution" is the limit-study
//! harness itself, so the coordinator is deliberately thin (per the
//! architecture guide): CLI-driven process lifecycle around the serving
//! simulator and the experiment registry. It supports both backends —
//! analytic (paper-scale what-if serving) and PJRT (real execution of
//! the AOT decode step).

use anyhow::Context;

use crate::apps::Registry;
use crate::hw::SystemConfig;
use crate::serving::{
    AnalyticEngine, Batcher, KvBudget, PjrtEngine, ServingReport, ServingSim,
    SimConfig, StepEngine, WorkloadGen, WorkloadSpec,
};
use crate::Result;

/// What backend prices each decode step.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Backend {
    /// LIMINAL analytical latency (paper-scale systems).
    Analytic,
    /// Real PJRT execution of the AOT artifacts.
    Pjrt,
}

/// A serve job description.
#[derive(Debug, Clone)]
pub struct ServeJob {
    /// Model name (registry key) — analytic backend only.
    pub model: String,
    /// System to serve on — analytic backend only.
    pub sys: SystemConfig,
    /// Synthetic workload.
    pub workload: WorkloadSpec,
    /// Max concurrent sequences.
    pub max_batch: usize,
    /// Prefill chunk size in tokens; 0 reverts to the decode-only
    /// assumption (prompts prefilled elsewhere).
    pub prefill_chunk: u64,
    /// Backend choice.
    pub backend: Backend,
    /// Artifact directory (PJRT backend).
    pub artifact_dir: std::path::PathBuf,
}

/// Run a serve job to completion and return its report.
pub fn serve(job: &ServeJob) -> Result<ServingReport> {
    let registry = Registry::builtin();
    let app = registry
        .app(&job.model)
        .with_context(|| format!("unknown model {}", job.model))?;

    let workload = WorkloadGen::new(job.workload.clone()).generate();
    // prefill_chunk = 0 degrades to the decode-only batcher.
    let make_batcher =
        |max_batch: usize, kv: KvBudget| Batcher::with_prefill(max_batch, kv, job.prefill_chunk);
    match job.backend {
        Backend::Analytic => {
            let kv = KvBudget::new(
                job.sys.total_capacity(),
                app.weight_bytes(),
                app.kv_bytes_per_token(),
            );
            let batcher = make_batcher(job.max_batch, kv);
            let mut engine = AnalyticEngine::new(app, job.sys.clone());
            Ok(ServingSim::new(batcher, &mut engine, SimConfig::default())
                .run(workload))
        }
        Backend::Pjrt => {
            let mut rt = crate::runtime::Runtime::new(&job.artifact_dir)?;
            let mut engine = PjrtEngine::new(&mut rt, job.max_batch as u64)?;
            engine.randomize_params(42)?;
            // The executable model has a small fixed context; scale the
            // synthetic workload into its window.
            let t = engine.context;
            let mut wl = workload;
            for r in &mut wl {
                r.context_len = r.context_len.min(t / 4).max(1);
                r.gen_len = r.gen_len.min(t / 4).max(1);
            }
            let kv = KvBudget::new(
                (engine.batch * t + 1) as f64, // token-slot budget
                0.0,
                1.0,
            );
            let batcher = make_batcher(engine.batch as usize, kv);
            let dyn_engine: &mut dyn StepEngine = &mut engine;
            Ok(ServingSim::new(batcher, dyn_engine, SimConfig::default())
                .run(wl))
        }
    }
}

/// Convenience builder used by the CLI and examples. Prefill-aware by
/// default; set `prefill_chunk = 0` for the decode-only legacy mode.
pub fn default_job(model: &str, sys: SystemConfig) -> ServeJob {
    ServeJob {
        model: model.to_string(),
        sys,
        workload: WorkloadSpec::default(),
        max_batch: 32,
        prefill_chunk: crate::model::DEFAULT_PREFILL_CHUNK,
        backend: Backend::Analytic,
        artifact_dir: std::path::PathBuf::from("artifacts"),
    }
}

/// Re-exported so `main.rs` needn't reach into serving directly.
pub use crate::serving::ServingReport as Report;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::presets;

    #[test]
    fn analytic_serve_end_to_end() {
        let sys = SystemConfig::new(presets::hbm3(), 128, 1);
        let mut job = default_job("llama3-70b", sys);
        job.workload.n_requests = 40;
        job.workload.arrival_rate = 200.0;
        let rep = serve(&job).unwrap();
        assert_eq!(rep.completed, 40);
        // Each user's decode rate is bounded by the single-user UTPS.
        assert!(rep.utps_mean <= 2100.0);
        assert!(rep.stps > rep.utps_mean * 0.9);
        // Prefill-aware by default: prompts were actually ingested and
        // every request saw a strictly positive TTFT.
        assert!(rep.prefill_tokens > 0);
        assert!(rep.ttft.p50 > 0.0);
        assert!(rep.e2e.p99 >= rep.ttft.p99);
    }

    #[test]
    fn decode_only_mode_still_supported() {
        let sys = SystemConfig::new(presets::hbm3(), 128, 1);
        let mut job = default_job("llama3-70b", sys);
        job.prefill_chunk = 0;
        job.workload.n_requests = 10;
        let rep = serve(&job).unwrap();
        assert_eq!(rep.completed, 10);
        assert_eq!(rep.prefill_tokens, 0);
    }

    #[test]
    fn unknown_model_is_an_error() {
        let sys = SystemConfig::new(presets::hbm3(), 8, 1);
        let job = default_job("not-a-model", sys);
        assert!(serve(&job).is_err());
    }
}
