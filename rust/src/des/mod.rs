//! Discrete-event simulation substrate for the serving simulator.
//!
//! A classic event-calendar design: a monotonically non-decreasing
//! simulated clock and a binary-heap calendar of `(time, seq, event)`
//! entries. The `seq` tiebreaker makes simultaneous events fire in
//! insertion order, so runs are fully deterministic.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulated time in seconds.
pub type SimTime = f64;

/// One scheduled event.
struct Scheduled<E> {
    at: SimTime,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse: BinaryHeap is a max-heap, we want earliest-first.
        // `total_cmp` keeps the order total even for exotic floats; a
        // `partial_cmp().unwrap_or(Equal)` fallback would silently
        // corrupt the heap invariant if a NaN ever reached it. NaN is
        // additionally rejected at the `schedule_at` boundary.
        other
            .at
            .total_cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The event calendar.
pub struct EventQueue<E> {
    heap: BinaryHeap<Scheduled<E>>,
    now: SimTime,
    seq: u64,
    fired: u64,
}

impl<E> EventQueue<E> {
    /// Empty calendar at t = 0.
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), now: 0.0, seq: 0, fired: 0 }
    }

    /// Current simulated time.
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Total events fired so far.
    pub fn fired(&self) -> u64 {
        self.fired
    }

    /// Schedule `event` at absolute time `at`.
    ///
    /// Panics on NaN or negative times: both indicate a latency model
    /// returning garbage, and admitting them would corrupt the calendar
    /// order (`+inf` is allowed — it models "never", and the driver's
    /// `max_time` guard handles it).
    ///
    /// A time slightly in the past (`at < now`) is clamped to `now`, in
    /// every build. Drivers schedule at `now + dt` where `dt` falls out
    /// of a floating-point latency chain, so roundoff can land the sum
    /// an epsilon behind the clock; clamping keeps the calendar
    /// monotone. (This used to `debug_assert!`, making debug builds
    /// panic on inputs release builds silently accepted — one behavior,
    /// documented and tested, beats a build-dependent split.)
    pub fn schedule_at(&mut self, at: SimTime, event: E) {
        assert!(!at.is_nan(), "schedule_at: NaN event time");
        assert!(at >= 0.0, "schedule_at: negative event time {at}");
        self.heap.push(Scheduled { at: at.max(self.now), seq: self.seq, event });
        self.seq += 1;
    }

    /// Schedule `event` `delay` seconds from now.
    pub fn schedule_in(&mut self, delay: SimTime, event: E) {
        self.schedule_at(self.now + delay.max(0.0), event);
    }

    /// Pop the next event, advancing the clock to its timestamp.
    pub fn next(&mut self) -> Option<(SimTime, E)> {
        let s = self.heap.pop()?;
        self.now = s.at;
        self.fired += 1;
        Some((s.at, s.event))
    }

    /// Timestamp of the earliest pending event, without popping it
    /// (`None` when the calendar is empty). The clock does not advance.
    /// Lets a driver enforce a deadline *before* consuming the event —
    /// `max_time` clamping without pop-and-discard.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|s| s.at)
    }

    /// Whether anything is pending.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Number of pending events.
    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_fire_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(3.0, "c");
        q.schedule_at(1.0, "a");
        q.schedule_at(2.0, "b");
        let order: Vec<_> = std::iter::from_fn(|| q.next()).map(|(_, e)| e).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_fire_in_insertion_order() {
        let mut q = EventQueue::new();
        q.schedule_at(1.0, 1);
        q.schedule_at(1.0, 2);
        q.schedule_at(1.0, 3);
        let order: Vec<_> = std::iter::from_fn(|| q.next()).map(|(_, e)| e).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.schedule_in(5.0, ());
        q.schedule_in(1.0, ());
        let (t1, _) = q.next().unwrap();
        let (t2, _) = q.next().unwrap();
        assert!(t1 <= t2);
        assert_eq!(q.now(), 5.0);
        assert_eq!(q.fired(), 2);
    }

    #[test]
    #[should_panic(expected = "NaN")]
    fn nan_times_are_rejected() {
        let mut q = EventQueue::new();
        q.schedule_at(f64::NAN, ());
    }

    #[test]
    #[should_panic(expected = "negative")]
    fn negative_times_are_rejected() {
        let mut q = EventQueue::new();
        q.schedule_at(-1.0, ());
    }

    #[test]
    fn infinite_times_sort_last() {
        // +inf is a legal "never" sentinel; it must sort after every
        // finite event instead of corrupting the heap.
        let mut q = EventQueue::new();
        q.schedule_at(f64::INFINITY, "never");
        q.schedule_at(1.0, "a");
        q.schedule_at(2.0, "b");
        let order: Vec<_> = std::iter::from_fn(|| q.next()).map(|(_, e)| e).collect();
        assert_eq!(order, vec!["a", "b", "never"]);
    }

    #[test]
    fn slightly_past_times_clamp_to_now() {
        // A latency chain rounding an epsilon behind the clock must not
        // reverse time: the event fires at `now`, after anything already
        // scheduled there, and the clock stays monotone.
        let mut q = EventQueue::new();
        q.schedule_at(5.0, "tick");
        q.next();
        assert_eq!(q.now(), 5.0);
        q.schedule_at(4.9999999, "late");
        let (t, e) = q.next().unwrap();
        assert_eq!(t, 5.0);
        assert_eq!(e, "late");
        assert_eq!(q.now(), 5.0);
    }

    #[test]
    fn exactly_now_timestamps_fire_at_now() {
        let mut q = EventQueue::new();
        q.schedule_at(2.0, "tick");
        q.next();
        q.schedule_at(2.0, "again");
        q.schedule_at(3.0, "later");
        let (t, e) = q.next().unwrap();
        assert_eq!((t, e), (2.0, "again"));
        let (t, e) = q.next().unwrap();
        assert_eq!((t, e), (3.0, "later"));
    }

    #[test]
    fn peek_time_sees_the_next_event_without_advancing() {
        let mut q = EventQueue::new();
        assert_eq!(q.peek_time(), None);
        q.schedule_at(2.0, "b");
        q.schedule_at(1.0, "a");
        assert_eq!(q.peek_time(), Some(1.0));
        // Peeking is pure: no clock movement, no fired count, no pop.
        assert_eq!(q.now(), 0.0);
        assert_eq!(q.fired(), 0);
        assert_eq!(q.len(), 2);
        let (t, e) = q.next().unwrap();
        assert_eq!((t, e), (1.0, "a"));
        assert_eq!(q.peek_time(), Some(2.0));
        q.next();
        assert_eq!(q.peek_time(), None);
    }

    #[test]
    fn schedule_during_drain_works() {
        let mut q = EventQueue::new();
        q.schedule_at(1.0, 0u32);
        let mut seen = 0;
        while let Some((_, e)) = q.next() {
            seen += 1;
            if e < 3 {
                q.schedule_in(1.0, e + 1);
            }
        }
        assert_eq!(seen, 4);
        assert_eq!(q.now(), 4.0);
    }
}
