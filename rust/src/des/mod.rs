//! Discrete-event simulation substrate for the serving simulator.
//!
//! # The calendar-queue scheduler
//!
//! The calendar is an index-addressed ladder/calendar queue instead of
//! a comparison heap: a **near-future wheel** of equal-width time
//! buckets plus a **sorted-on-demand overflow rung** for events beyond
//! the wheel's horizon, and a FIFO **never list** for `+inf` ("never")
//! sentinels. A DES schedules almost every event a short, clustered
//! distance past `now`, so the wheel absorbs nearly all traffic at
//! O(1) amortized per schedule/pop — an index computation and a push —
//! where a binary heap pays an O(log n) sift both ways.
//!
//! * **Wheel** — `buckets[i]` holds events with
//!   `bucket_start + i*width <= at < bucket_start + (i+1)*width`.
//!   Buckets are unsorted until the drain cursor reaches them; the
//!   current bucket is kept sorted (descending, so `pop()` from the
//!   back yields the minimum) and in-cursor inserts use a binary
//!   search. Events landing at or before the cursor's bucket (the
//!   clamp-to-`now` path) are folded into the current bucket — the
//!   sort order inside it, not the bucket index, is what fires them
//!   first.
//! * **Overflow rung** — events at or past the horizon wait in an
//!   unsorted vector. When the wheel drains, the queue **respans**:
//!   the wheel is rebuilt over the overflow's `[min, max]` time range
//!   with `bucket_count = next_power_of_two(pending)` clamped to
//!   `[4, 65536]` and `width = span / bucket_count` (1.0 when the span
//!   degenerates to a point). This is the whole resize policy: bucket
//!   count and width adapt to the live population once per respan, so
//!   a million pre-scheduled arrivals and a lone timer both get a
//!   sensibly-sized wheel, and there is no incremental re-hashing on
//!   the hot path.
//! * **Never list** — `+inf` models "never"; those events go to a FIFO
//!   queue drained only after every finite event, in insertion order.
//!
//! # Determinism
//!
//! Ordering is *identical* to the previous binary-heap calendar: the
//! global firing order is `(at, seq)` lexicographic, where `seq` is
//! the insertion counter. Within a bucket that order is enforced by
//! the descending `(at, seq)` sort (ties keep insertion order because
//! `seq` is unique and monotone); across buckets it holds because the
//! bucket index is monotone in `at` and cursor-clamped inserts are
//! binary-searched into the sorted current bucket. `total_cmp` keeps
//! the sort total for exotic floats, and NaN is rejected at the
//! `schedule_at` boundary, so no unordered value ever reaches a
//! comparison. The bit-identical-report regression tests and the DST
//! harness pin this equivalence.

use std::cmp::Ordering;
use std::collections::VecDeque;

/// Simulated time in seconds.
pub type SimTime = f64;

/// Smallest wheel built by a respan.
const MIN_BUCKETS: usize = 4;
/// Largest wheel built by a respan (caps memory and empty-bucket scan
/// cost; beyond this, buckets just hold more than one event each).
const MAX_BUCKETS: usize = 1 << 16;

/// One scheduled event.
struct Scheduled<E> {
    at: SimTime,
    seq: u64,
    event: E,
}

/// Descending `(at, seq)` order, so a sorted bucket pops its minimum
/// from the back in O(1).
fn desc<E>(a: &Scheduled<E>, b: &Scheduled<E>) -> Ordering {
    b.at.total_cmp(&a.at).then_with(|| b.seq.cmp(&a.seq))
}

/// The event calendar.
pub struct EventQueue<E> {
    /// Near-future wheel; see the module docs.
    buckets: Vec<Vec<Scheduled<E>>>,
    /// Lower time edge of `buckets[0]`.
    bucket_start: SimTime,
    /// Bucket width in simulated seconds (`> 0` once spanned).
    width: SimTime,
    /// First time not covered by the wheel (`-inf` before any respan,
    /// so everything routes to the overflow rung).
    horizon: SimTime,
    /// Drain cursor: when `wheel_len > 0`, `buckets[cur]` is nonempty
    /// and sorted descending, and every bucket before it is empty.
    cur: usize,
    /// Events currently in the wheel.
    wheel_len: usize,
    /// Finite events at or past the horizon, unsorted.
    overflow: Vec<Scheduled<E>>,
    /// `+inf` events, FIFO.
    never: VecDeque<Scheduled<E>>,
    now: SimTime,
    seq: u64,
    fired: u64,
}

impl<E> EventQueue<E> {
    /// Empty calendar at t = 0.
    pub fn new() -> Self {
        EventQueue {
            buckets: Vec::new(),
            bucket_start: 0.0,
            width: 0.0,
            horizon: f64::NEG_INFINITY,
            cur: 0,
            wheel_len: 0,
            overflow: Vec::new(),
            never: VecDeque::new(),
            now: 0.0,
            seq: 0,
            fired: 0,
        }
    }

    /// Current simulated time.
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Total events fired so far.
    pub fn fired(&self) -> u64 {
        self.fired
    }

    /// Schedule `event` at absolute time `at`.
    ///
    /// Panics on NaN or negative times: both indicate a latency model
    /// returning garbage, and admitting them would corrupt the calendar
    /// order (`+inf` is allowed — it models "never", and the driver's
    /// `max_time` guard handles it).
    ///
    /// A time slightly in the past (`at < now`) is clamped to `now`, in
    /// every build. Drivers schedule at `now + dt` where `dt` falls out
    /// of a floating-point latency chain, so roundoff can land the sum
    /// an epsilon behind the clock; clamping keeps the calendar
    /// monotone. (This used to `debug_assert!`, making debug builds
    /// panic on inputs release builds silently accepted — one behavior,
    /// documented and tested, beats a build-dependent split.)
    pub fn schedule_at(&mut self, at: SimTime, event: E) {
        assert!(!at.is_nan(), "schedule_at: NaN event time");
        assert!(at >= 0.0, "schedule_at: negative event time {at}");
        let at = at.max(self.now);
        let seq = self.seq;
        self.seq += 1;
        let s = Scheduled { at, seq, event };
        if at == f64::INFINITY {
            // "Never" sentinels: after every finite event, in insertion
            // order — exactly the (at, seq) order with at = +inf.
            self.never.push_back(s);
        } else if at < self.horizon {
            self.insert_wheel(s);
        } else {
            self.overflow.push(s);
        }
    }

    /// Schedule `event` `delay` seconds from now.
    pub fn schedule_in(&mut self, delay: SimTime, event: E) {
        self.schedule_at(self.now + delay.max(0.0), event);
    }

    /// Pop the next event, advancing the clock to its timestamp.
    pub fn next(&mut self) -> Option<(SimTime, E)> {
        if self.wheel_len == 0 && !self.overflow.is_empty() {
            self.respan();
        }
        let s = if self.wheel_len > 0 {
            let s = self.buckets[self.cur].pop().expect("cursor bucket nonempty");
            self.wheel_len -= 1;
            if self.buckets[self.cur].is_empty() && self.wheel_len > 0 {
                self.advance_cursor();
            }
            s
        } else {
            self.never.pop_front()?
        };
        self.now = s.at;
        self.fired += 1;
        Some((s.at, s.event))
    }

    /// Timestamp of the earliest pending event, without popping it
    /// (`None` when the calendar is empty). The clock does not advance.
    /// Lets a driver enforce a deadline *before* consuming the event —
    /// `max_time` clamping without pop-and-discard.
    pub fn peek_time(&self) -> Option<SimTime> {
        if self.wheel_len > 0 {
            // Cursor invariant: sorted descending, min at the back.
            return self.buckets[self.cur].last().map(|s| s.at);
        }
        if !self.overflow.is_empty() {
            // Wheel drained, rung not yet respanned: one O(n) scan at
            // most per respan (the following `next` rebuilds the wheel
            // and restores O(1) peeks).
            return self
                .overflow
                .iter()
                .map(|s| s.at)
                .min_by(f64::total_cmp);
        }
        if !self.never.is_empty() {
            return Some(f64::INFINITY);
        }
        None
    }

    /// Whether anything is pending.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Number of pending events.
    pub fn len(&self) -> usize {
        self.wheel_len + self.overflow.len() + self.never.len()
    }

    /// Bucket index for a wheel-bound time (`at < horizon`). Monotone
    /// in `at`; float-edge roundoff is clamped into range, and times at
    /// or before the cursor's bucket fold into the current bucket,
    /// where the sort (not the index) orders them.
    fn bucket_index(&self, at: SimTime) -> usize {
        // A negative offset (at < bucket_start, possible right after a
        // respan scheduled from an earlier `now`) saturates to 0.
        let idx = ((at - self.bucket_start) / self.width) as usize;
        let idx = idx.min(self.buckets.len() - 1);
        if self.wheel_len > 0 {
            idx.max(self.cur)
        } else {
            idx
        }
    }

    fn insert_wheel(&mut self, s: Scheduled<E>) {
        let idx = self.bucket_index(s.at);
        if self.wheel_len == 0 {
            // Empty wheel: re-aim the cursor; a single event is
            // trivially sorted.
            self.cur = idx;
            self.buckets[idx].push(s);
        } else if idx == self.cur {
            // The current bucket is sorted descending; binary-insert.
            // `seq` is monotone, so among equal times the new event
            // belongs in front of (= pops after) its elders.
            let pos = self.buckets[idx]
                .partition_point(|e| e.at.total_cmp(&s.at) == Ordering::Greater);
            self.buckets[idx].insert(pos, s);
        } else {
            self.buckets[idx].push(s);
        }
        self.wheel_len += 1;
    }

    /// Move the cursor to the next nonempty bucket and sort it. Only
    /// called with `wheel_len > 0`, so termination is guaranteed.
    fn advance_cursor(&mut self) {
        loop {
            self.cur += 1;
            if !self.buckets[self.cur].is_empty() {
                break;
            }
        }
        self.buckets[self.cur].sort_unstable_by(desc);
    }

    /// Rebuild the wheel over the overflow rung's time range; see the
    /// module docs for the sizing policy.
    fn respan(&mut self) {
        debug_assert!(self.wheel_len == 0 && !self.overflow.is_empty());
        let m = self.overflow.len();
        let n = m.next_power_of_two().clamp(MIN_BUCKETS, MAX_BUCKETS);
        let mut t_min = f64::INFINITY;
        let mut t_max = f64::NEG_INFINITY;
        for s in &self.overflow {
            t_min = t_min.min(s.at);
            t_max = t_max.max(s.at);
        }
        self.bucket_start = t_min;
        let span = t_max - t_min;
        self.width = span / n as f64;
        if !(self.width.is_finite() && self.width > 0.0) {
            // Point span (or underflow): any positive width works, all
            // events land in bucket 0.
            self.width = 1.0;
        }
        self.horizon = self.bucket_start + self.width * n as f64;
        if self.buckets.len() != n {
            self.buckets.resize_with(n, Vec::new);
        }
        let mut ov = std::mem::take(&mut self.overflow);
        for s in ov.drain(..) {
            let idx = (((s.at - self.bucket_start) / self.width) as usize).min(n - 1);
            self.buckets[idx].push(s);
        }
        self.overflow = ov; // keep the rung's allocation
        self.wheel_len = m;
        self.cur = self
            .buckets
            .iter()
            .position(|b| !b.is_empty())
            .expect("respan moved events into the wheel");
        self.buckets[self.cur].sort_unstable_by(desc);
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_fire_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(3.0, "c");
        q.schedule_at(1.0, "a");
        q.schedule_at(2.0, "b");
        let order: Vec<_> = std::iter::from_fn(|| q.next()).map(|(_, e)| e).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_fire_in_insertion_order() {
        let mut q = EventQueue::new();
        q.schedule_at(1.0, 1);
        q.schedule_at(1.0, 2);
        q.schedule_at(1.0, 3);
        let order: Vec<_> = std::iter::from_fn(|| q.next()).map(|(_, e)| e).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.schedule_in(5.0, ());
        q.schedule_in(1.0, ());
        let (t1, _) = q.next().unwrap();
        let (t2, _) = q.next().unwrap();
        assert!(t1 <= t2);
        assert_eq!(q.now(), 5.0);
        assert_eq!(q.fired(), 2);
    }

    #[test]
    #[should_panic(expected = "NaN")]
    fn nan_times_are_rejected() {
        let mut q = EventQueue::new();
        q.schedule_at(f64::NAN, ());
    }

    #[test]
    #[should_panic(expected = "negative")]
    fn negative_times_are_rejected() {
        let mut q = EventQueue::new();
        q.schedule_at(-1.0, ());
    }

    #[test]
    fn infinite_times_sort_last() {
        // +inf is a legal "never" sentinel; it must sort after every
        // finite event instead of corrupting the calendar.
        let mut q = EventQueue::new();
        q.schedule_at(f64::INFINITY, "never");
        q.schedule_at(1.0, "a");
        q.schedule_at(2.0, "b");
        let order: Vec<_> = std::iter::from_fn(|| q.next()).map(|(_, e)| e).collect();
        assert_eq!(order, vec!["a", "b", "never"]);
    }

    #[test]
    fn slightly_past_times_clamp_to_now() {
        // A latency chain rounding an epsilon behind the clock must not
        // reverse time: the event fires at `now`, after anything already
        // scheduled there, and the clock stays monotone.
        let mut q = EventQueue::new();
        q.schedule_at(5.0, "tick");
        q.next();
        assert_eq!(q.now(), 5.0);
        q.schedule_at(4.9999999, "late");
        let (t, e) = q.next().unwrap();
        assert_eq!(t, 5.0);
        assert_eq!(e, "late");
        assert_eq!(q.now(), 5.0);
    }

    #[test]
    fn exactly_now_timestamps_fire_at_now() {
        let mut q = EventQueue::new();
        q.schedule_at(2.0, "tick");
        q.next();
        q.schedule_at(2.0, "again");
        q.schedule_at(3.0, "later");
        let (t, e) = q.next().unwrap();
        assert_eq!((t, e), (2.0, "again"));
        let (t, e) = q.next().unwrap();
        assert_eq!((t, e), (3.0, "later"));
    }

    #[test]
    fn peek_time_sees_the_next_event_without_advancing() {
        let mut q = EventQueue::new();
        assert_eq!(q.peek_time(), None);
        q.schedule_at(2.0, "b");
        q.schedule_at(1.0, "a");
        assert_eq!(q.peek_time(), Some(1.0));
        // Peeking is pure: no clock movement, no fired count, no pop.
        assert_eq!(q.now(), 0.0);
        assert_eq!(q.fired(), 0);
        assert_eq!(q.len(), 2);
        let (t, e) = q.next().unwrap();
        assert_eq!((t, e), (1.0, "a"));
        assert_eq!(q.peek_time(), Some(2.0));
        q.next();
        assert_eq!(q.peek_time(), None);
    }

    #[test]
    fn schedule_during_drain_works() {
        let mut q = EventQueue::new();
        q.schedule_at(1.0, 0u32);
        let mut seen = 0;
        while let Some((_, e)) = q.next() {
            seen += 1;
            if e < 3 {
                q.schedule_in(1.0, e + 1);
            }
        }
        assert_eq!(seen, 4);
        assert_eq!(q.now(), 4.0);
    }

    #[test]
    fn empty_refill_cycles_respan_cleanly() {
        // Drain the wheel completely, then schedule again: each refill
        // must respan and keep ordering, across very different scales.
        let mut q = EventQueue::new();
        for round in 0..5u32 {
            let base = q.now();
            let scale = 10f64.powi(round as i32 * 2) * 1e-3;
            for i in (0..20).rev() {
                q.schedule_at(base + i as f64 * scale, (round, i));
            }
            for i in 0..20 {
                let (_, e) = q.next().unwrap();
                assert_eq!(e, (round, i), "round {round}");
            }
            assert!(q.is_empty());
        }
    }

    #[test]
    fn far_future_events_pass_through_the_overflow_rung() {
        // A bimodal schedule: a near cluster inside the wheel and a far
        // tail beyond any horizon the first respan could build.
        let mut q = EventQueue::new();
        for i in 0..50u64 {
            q.schedule_at(1e6 + i as f64, 1000 + i); // far tail first
        }
        for i in 0..50u64 {
            q.schedule_at(i as f64 * 0.01, i); // near cluster
        }
        let order: Vec<_> = std::iter::from_fn(|| q.next()).map(|(_, e)| e).collect();
        let expect: Vec<u64> = (0..50).chain(1000..1050).collect();
        assert_eq!(order, expect);
    }

    #[test]
    fn interleaved_pops_and_schedules_keep_global_order() {
        // Steady-state DES shape: every pop schedules a follow-up a
        // short distance ahead; (time, seq) order must hold throughout.
        let mut q = EventQueue::new();
        for i in 0..8u64 {
            q.schedule_at(i as f64 * 0.125, i);
        }
        let mut last_t = 0.0f64;
        let mut popped = 0u64;
        let mut scheduled = 8u64;
        while let Some((t, e)) = q.next() {
            assert!(t >= last_t, "time went backwards: {t} < {last_t}");
            last_t = t;
            popped += 1;
            if scheduled < 200 {
                q.schedule_in(0.1 + (e % 7) as f64 * 0.03, e + 8);
                scheduled += 1;
            }
        }
        assert_eq!(popped, 200);
        assert_eq!(q.fired(), 200);
    }

    #[test]
    fn point_span_respan_handles_identical_times() {
        // All overflow events at one instant: span = 0 forces the
        // degenerate-width path; FIFO order must survive.
        let mut q = EventQueue::new();
        for i in 0..10u64 {
            q.schedule_at(42.0, i);
        }
        let order: Vec<_> = std::iter::from_fn(|| q.next()).map(|(_, e)| e).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
        assert_eq!(q.now(), 42.0);
    }

    #[test]
    fn finite_events_scheduled_after_an_infinite_pop_stay_ordered() {
        // Once a "never" event fires, now == +inf; later schedules
        // clamp to +inf and drain in insertion order, like the heap did.
        let mut q = EventQueue::new();
        q.schedule_at(f64::INFINITY, "first-never");
        q.schedule_at(1.0, "finite");
        assert_eq!(q.next().unwrap().1, "finite");
        assert_eq!(q.next().unwrap().1, "first-never");
        assert_eq!(q.now(), f64::INFINITY);
        q.schedule_at(5.0, "late-a");
        q.schedule_at(7.0, "late-b");
        assert_eq!(q.next().unwrap().1, "late-a");
        assert_eq!(q.next().unwrap().1, "late-b");
    }
}
