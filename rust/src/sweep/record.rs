//! One sweep result row, serializable for CSV/JSON export.

use crate::model::{LatencyBreakdown, Perf};

/// A fully-evaluated sweep cell.
#[derive(Debug, Clone, PartialEq)]
pub struct Record {
    /// Model name.
    pub model: String,
    /// Chip name.
    pub chip: String,
    /// System label (chip-TPx[-PPy]).
    pub system: String,
    /// Tensor-parallel degree.
    pub tp: u64,
    /// Pipeline-parallel degree.
    pub pp: u64,
    /// Batch size evaluated (`None` when the cell is unservable).
    pub batch: Option<u64>,
    /// Context length, tokens.
    pub context: u64,
    /// Per-user tokens/second (`None` when unservable).
    pub utps: Option<f64>,
    /// System tokens/second.
    pub stps: Option<f64>,
    /// System tokens/second/watt.
    pub stps_per_watt: Option<f64>,
    /// Total system power, watts.
    pub watts: Option<f64>,
    /// Full latency breakdown for servable cells.
    pub lat: Option<LatencyBreakdown>,
    /// Capacity required, bytes.
    pub capacity_bytes: Option<f64>,
}

impl Record {
    /// An unservable cell (dash in the paper's tables).
    pub fn unservable(model: &str, system: &str, tp: u64, pp: u64, context: u64) -> Record {
        Record {
            model: model.into(),
            chip: system.split("-TP").next().unwrap_or(system).into(),
            system: system.into(),
            tp,
            pp,
            batch: None,
            context,
            utps: None,
            stps: None,
            stps_per_watt: None,
            watts: None,
            lat: None,
            capacity_bytes: None,
        }
    }

    /// Build from an evaluation.
    pub fn from_perf(
        model: &str,
        sys: &crate::hw::SystemConfig,
        perf: &Perf,
        watts: f64,
    ) -> Record {
        Record {
            model: model.into(),
            chip: sys.chip.name.clone(),
            system: sys.label(),
            tp: sys.tp,
            pp: sys.pp,
            batch: Some(perf.point.batch),
            context: perf.point.context,
            utps: Some(perf.utps),
            stps: Some(perf.stps),
            stps_per_watt: Some(perf.stps / watts),
            watts: Some(watts),
            lat: Some(perf.lat),
            capacity_bytes: Some(perf.capacity_bytes),
        }
    }

    /// True when this cell could be served.
    pub fn servable(&self) -> bool {
        self.utps.is_some()
    }
}
