//! Cluster-dimension sweeps: instance count x router policy, the
//! scale-out axes the single-system `Grid` cannot express.
//!
//! Each cell runs the full cluster DES
//! ([`serve_cluster`](crate::coordinator::serve_cluster)) instead of a
//! closed-form evaluation, so a cell's record carries dynamic
//! quantities — SLO tails, shed counts, scale-out efficiency — that
//! only the event-driven simulator can produce. The `cluster-scaling`
//! experiment and the `sweep` CLI both drive this.

use crate::coordinator::{serve_cluster, ClusterJob, RouterPolicy};
use crate::util::json::Json;
use crate::Result;

/// A cluster sweep: run the base job at every `(instances, router)`
/// combination.
#[derive(Debug, Clone)]
pub struct ClusterGrid {
    /// Base job; `instances` and `router` are overridden per cell.
    pub base: ClusterJob,
    /// Instance counts to sweep (e.g. `[1, 2, 4, 8]`).
    pub instance_counts: Vec<usize>,
    /// Router policies to sweep.
    pub routers: Vec<RouterPolicy>,
    /// Scale the offered load with the instance count (arrival rate and
    /// request count multiply by `n`), so each cell sees the same
    /// per-instance pressure — the configuration that isolates scale-out
    /// efficiency. `false` holds the workload fixed (capacity studies).
    pub scale_load: bool,
}

/// One cluster sweep cell, flattened for CSV/JSON export.
#[derive(Debug, Clone)]
pub struct ClusterRecord {
    /// Instances in the cell.
    pub instances: usize,
    /// Router policy name (as reported by the router).
    pub router: String,
    /// Mode string (`colocated x4`, `disaggregated 2P+2D`, …).
    pub mode: String,
    /// Offered arrival rate, requests/second.
    pub rate: f64,
    /// Requests completed.
    pub completed: u64,
    /// Requests shed by admission control.
    pub shed: u64,
    /// DES events applied by the cell's simulation run.
    pub events: u64,
    /// Aggregate system tokens/second.
    pub stps: f64,
    /// Scale-out efficiency: tokens/second/instance.
    pub stps_per_instance: f64,
    /// TTFT p99, seconds.
    pub ttft_p99: f64,
    /// TPOT p99, seconds.
    pub tpot_p99: f64,
    /// E2E p99, seconds.
    pub e2e_p99: f64,
}

impl ClusterRecord {
    /// Machine-readable form for experiment artifacts.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("instances", Json::Num(self.instances as f64)),
            ("router", Json::Str(self.router.clone())),
            ("mode", Json::Str(self.mode.clone())),
            ("rate", Json::Num(self.rate)),
            ("completed", Json::Num(self.completed as f64)),
            ("shed", Json::Num(self.shed as f64)),
            ("events", Json::Num(self.events as f64)),
            ("stps", Json::Num(self.stps)),
            ("stps_per_instance", Json::Num(self.stps_per_instance)),
            ("ttft_p99_s", Json::Num(self.ttft_p99)),
            ("tpot_p99_s", Json::Num(self.tpot_p99)),
            ("e2e_p99_s", Json::Num(self.e2e_p99)),
        ])
    }
}

/// Run every `(instances, router)` cell of the grid, in declaration
/// order (instances outer, routers inner). Cells run sequentially: each
/// is itself a full DES over hundreds of requests, and deterministic
/// ordering matters more here than wall-clock.
pub fn run_cluster_grid(grid: &ClusterGrid) -> Result<Vec<ClusterRecord>> {
    let mut out = Vec::new();
    for &n in &grid.instance_counts {
        for &policy in &grid.routers {
            let mut job = grid.base.clone();
            job.instances = n;
            job.router = policy;
            if grid.scale_load {
                job.workload.arrival_rate *= n as f64;
                job.workload.n_requests *= n as u64;
            }
            if job.prefill_instances > 0 {
                anyhow::ensure!(
                    job.prefill_instances < n,
                    "disaggregated grid cell {n} instances cannot host {} prefill",
                    job.prefill_instances
                );
            }
            let rep = serve_cluster(&job)?;
            out.push(ClusterRecord {
                instances: n,
                router: rep.router.clone(),
                mode: rep.mode.clone(),
                rate: job.workload.arrival_rate,
                completed: rep.cluster.completed,
                shed: rep.shed,
                events: rep.events,
                stps: rep.cluster.stps,
                stps_per_instance: rep.stps_per_instance(),
                ttft_p99: rep.cluster.ttft.p99,
                tpot_p99: rep.cluster.tpot.p99,
                e2e_p99: rep.cluster.e2e.p99,
            });
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::default_cluster_job;
    use crate::hw::{presets, SystemConfig};

    fn small_grid() -> ClusterGrid {
        let sys = SystemConfig::new(presets::hbm3(), 8, 1);
        let mut base = default_cluster_job("llama3-70b", sys);
        base.max_batch = 8;
        base.prefill_chunk = 512;
        base.workload.arrival_rate = 20.0;
        base.workload.n_requests = 10;
        base.workload.context = (512, 1024);
        base.workload.gen = (16, 32);
        ClusterGrid {
            base,
            instance_counts: vec![1, 2],
            routers: vec![RouterPolicy::RoundRobin, RouterPolicy::LeastTokens],
            scale_load: true,
        }
    }

    #[test]
    fn grid_runs_every_cell_in_order() {
        let recs = run_cluster_grid(&small_grid()).unwrap();
        assert_eq!(recs.len(), 4);
        assert_eq!(
            recs.iter().map(|r| r.instances).collect::<Vec<_>>(),
            vec![1, 1, 2, 2]
        );
        assert_eq!(recs[0].router, "round-robin");
        assert_eq!(recs[1].router, "least-tokens");
        // scale_load doubled the 2-instance cells' offered load.
        assert_eq!(recs[0].completed, 10);
        assert_eq!(recs[2].completed, 20);
        assert!((recs[2].rate - 40.0).abs() < 1e-12);
        assert!(recs.iter().all(|r| r.stps > 0.0));
    }

    #[test]
    fn records_export_json() {
        let recs = run_cluster_grid(&ClusterGrid {
            instance_counts: vec![1],
            routers: vec![RouterPolicy::RoundRobin],
            ..small_grid()
        })
        .unwrap();
        let j = Json::parse(&recs[0].to_json().to_string()).unwrap();
        assert_eq!(j.get("instances").unwrap().as_u64(), Some(1));
        assert_eq!(j.get("router").unwrap().as_str(), Some("round-robin"));
        assert!(j.get("ttft_p99_s").unwrap().as_f64().is_some());
    }
}
