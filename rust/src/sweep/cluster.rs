//! Cluster-dimension sweeps: instance count x router policy, the
//! scale-out axes the single-system `Grid` cannot express.
//!
//! Each cell runs the full cluster DES
//! ([`serve_cluster`](crate::coordinator::serve_cluster)) instead of a
//! closed-form evaluation, so a cell's record carries dynamic
//! quantities — SLO tails, shed counts, scale-out efficiency — that
//! only the event-driven simulator can produce. The `cluster-scaling`
//! experiment and the `sweep` CLI both drive this.

use crate::cluster::AutoscalePolicy;
use crate::coordinator::{serve_cluster, ClusterJob, RouterPolicy};
use crate::util::json::Json;
use crate::util::par::parallel_map;
use crate::Result;

/// A cluster sweep: run the base job at every
/// `(instances, router, autoscale, priority mix)` combination.
#[derive(Debug, Clone)]
pub struct ClusterGrid {
    /// Base job; `instances`, `router`, `autoscale`, and the workload's
    /// `priority_mix` are overridden per cell.
    pub base: ClusterJob,
    /// Instance counts to sweep (e.g. `[1, 2, 4, 8]`).
    pub instance_counts: Vec<usize>,
    /// Router policies to sweep.
    pub routers: Vec<RouterPolicy>,
    /// Fleet elasticity axis: `None` cells run the fixed fleet,
    /// `Some(policy)` cells autoscale from the cell's instance count
    /// (use `vec![None]` for a classic fixed-fleet sweep).
    pub autoscale: Vec<Option<AutoscalePolicy>>,
    /// Priority-class axis: each entry is a weighted class mix applied
    /// to the cell's workload (an empty mix = the single-class
    /// baseline; use `vec![Vec::new()]` for a classic sweep). The
    /// base job's preemption policy applies unchanged to every cell,
    /// so mixing this axis with an enabled policy compares FIFO
    /// against priority+preemption on otherwise identical cells.
    pub priority_mixes: Vec<Vec<(u8, f64)>>,
    /// Scale the offered load with the instance count (arrival rate and
    /// request count multiply by `n`), so each cell sees the same
    /// per-instance pressure — the configuration that isolates scale-out
    /// efficiency. `false` holds the workload fixed (capacity studies).
    pub scale_load: bool,
}

/// One cluster sweep cell, flattened for CSV/JSON export.
#[derive(Debug, Clone)]
pub struct ClusterRecord {
    /// Instances in the cell.
    pub instances: usize,
    /// Router policy name (as reported by the router).
    pub router: String,
    /// Mode string (`colocated x4`, `disaggregated 2P+2D`, …).
    pub mode: String,
    /// Offered arrival rate, requests/second.
    pub rate: f64,
    /// Requests completed.
    pub completed: u64,
    /// Requests shed by admission control.
    pub shed: u64,
    /// DES events applied by the cell's simulation run.
    pub events: u64,
    /// Simulated span of the cell's run, seconds.
    pub span: f64,
    /// Aggregate system tokens/second.
    pub stps: f64,
    /// Scale-out efficiency: tokens/second/instance.
    pub stps_per_instance: f64,
    /// TTFT p99, seconds.
    pub ttft_p99: f64,
    /// TPOT p99, seconds.
    pub tpot_p99: f64,
    /// E2E p99, seconds.
    pub e2e_p99: f64,
    /// Whether the cell ran an elastic fleet.
    pub autoscaled: bool,
    /// Billed instance-seconds (spawn through retirement/end of run,
    /// warm-up included).
    pub instance_seconds: f64,
    /// Priority classes offered by the cell's workload (1 = the
    /// single-class baseline).
    pub priority_classes: usize,
    /// KV evictions across the cell's run (0 with preemption disabled).
    pub preemptions: u64,
    /// Evicted-request restores across the cell's run.
    pub restores: u64,
}

impl ClusterRecord {
    /// Machine-readable form for experiment artifacts.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("instances", Json::Num(self.instances as f64)),
            ("router", Json::Str(self.router.clone())),
            ("mode", Json::Str(self.mode.clone())),
            ("rate", Json::Num(self.rate)),
            ("completed", Json::Num(self.completed as f64)),
            ("shed", Json::Num(self.shed as f64)),
            ("events", Json::Num(self.events as f64)),
            ("span_s", Json::Num(self.span)),
            ("stps", Json::Num(self.stps)),
            ("stps_per_instance", Json::Num(self.stps_per_instance)),
            ("ttft_p99_s", Json::Num(self.ttft_p99)),
            ("tpot_p99_s", Json::Num(self.tpot_p99)),
            ("e2e_p99_s", Json::Num(self.e2e_p99)),
            ("autoscaled", Json::Bool(self.autoscaled)),
            ("instance_seconds", Json::Num(self.instance_seconds)),
            ("priority_classes", Json::Num(self.priority_classes as f64)),
            ("preemptions", Json::Num(self.preemptions as f64)),
            ("restores", Json::Num(self.restores as f64)),
        ])
    }
}

/// Materialize every `(instances, router, autoscale, priority mix)`
/// cell of the grid as a ready-to-run job, in declaration order
/// (instances outer, then routers, then autoscale, then the mix axis
/// innermost).
fn grid_cells(grid: &ClusterGrid) -> Vec<ClusterJob> {
    let mut cells = Vec::with_capacity(
        grid.instance_counts.len()
            * grid.routers.len()
            * grid.autoscale.len()
            * grid.priority_mixes.len(),
    );
    for &n in &grid.instance_counts {
        for &policy in &grid.routers {
            for elastic in &grid.autoscale {
                for mix in &grid.priority_mixes {
                    let mut job = grid.base.clone();
                    job.instances = n;
                    job.router = policy;
                    job.autoscale = elastic.clone();
                    job.workload.priority_mix = mix.clone();
                    if grid.scale_load {
                        job.workload.arrival_rate *= n as f64;
                        job.workload.n_requests *= n as u64;
                    }
                    cells.push(job);
                }
            }
        }
    }
    cells
}

/// Run every `(instances, router)` cell of the grid, in declaration
/// order (instances outer, routers inner).
///
/// The whole grid is validated **before any cell runs**, and every
/// invalid cell is named in one error — a per-cell check mid-grid
/// would burn the earlier cells' simulation time only to abort, and
/// would make the failure depend on cell order. Valid grids fan out
/// over [`parallel_map`]: cells are independent DES runs (sharing
/// nothing but the immutable base job), and the map is
/// order-preserving, so the records come back exactly as the serial
/// loop produced them.
pub fn run_cluster_grid(grid: &ClusterGrid) -> Result<Vec<ClusterRecord>> {
    let cells = grid_cells(grid);
    let invalid: Vec<String> = cells
        .iter()
        .filter_map(|job| {
            if job.instances == 0 {
                Some("cell with 0 instances".to_string())
            } else if job.prefill_instances > 0
                && job.prefill_instances >= job.instances
            {
                Some(format!(
                    "cell with {} instances cannot host {} dedicated prefill",
                    job.instances, job.prefill_instances
                ))
            } else if let Some(p) = job
                .autoscale
                .as_ref()
                .filter(|p| p.min_instances == 0 || p.min_instances > p.max_instances)
            {
                Some(format!(
                    "cell with autoscale bounds {}..{} (need 1 <= min <= max)",
                    p.min_instances, p.max_instances
                ))
            } else if let Some(&(class, w)) = job
                .workload
                .priority_mix
                .iter()
                .find(|&&(_, w)| !w.is_finite() || w <= 0.0)
            {
                // Caught here so a bad mix is one named error upfront,
                // not a generator panic mid-grid.
                Some(format!(
                    "cell with priority class {class} at non-positive \
                     weight {w}"
                ))
            } else {
                None
            }
        })
        .collect();
    anyhow::ensure!(
        invalid.is_empty(),
        "invalid cluster grid: {}",
        invalid.join("; ")
    );
    parallel_map(cells, |job| -> Result<ClusterRecord> {
        let rep = serve_cluster(job)?;
        Ok(ClusterRecord {
            instances: job.instances,
            router: rep.router.clone(),
            mode: rep.mode.clone(),
            rate: job.workload.arrival_rate,
            completed: rep.cluster.completed,
            shed: rep.shed,
            events: rep.events,
            span: rep.cluster.span,
            stps: rep.cluster.stps,
            stps_per_instance: rep.stps_per_instance(),
            ttft_p99: rep.cluster.ttft.p99,
            tpot_p99: rep.cluster.tpot.p99,
            e2e_p99: rep.cluster.e2e.p99,
            autoscaled: job.autoscale.is_some(),
            instance_seconds: rep.instance_seconds,
            priority_classes: job.workload.priority_mix.len().max(1),
            preemptions: rep.cluster.preemptions,
            restores: rep.cluster.restores,
        })
    })
    .into_iter()
    .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::default_cluster_job;
    use crate::hw::{presets, SystemConfig};

    fn small_grid() -> ClusterGrid {
        let sys = SystemConfig::new(presets::hbm3(), 8, 1);
        let mut base = default_cluster_job("llama3-70b", sys);
        base.max_batch = 8;
        base.prefill_chunk = 512;
        base.workload.arrival_rate = 20.0;
        base.workload.n_requests = 10;
        base.workload.context = (512, 1024);
        base.workload.gen = (16, 32);
        ClusterGrid {
            base,
            instance_counts: vec![1, 2],
            routers: vec![RouterPolicy::RoundRobin, RouterPolicy::LeastTokens],
            autoscale: vec![None],
            priority_mixes: vec![Vec::new()],
            scale_load: true,
        }
    }

    #[test]
    fn grid_runs_every_cell_in_order() {
        let recs = run_cluster_grid(&small_grid()).unwrap();
        assert_eq!(recs.len(), 4);
        assert_eq!(
            recs.iter().map(|r| r.instances).collect::<Vec<_>>(),
            vec![1, 1, 2, 2]
        );
        assert_eq!(recs[0].router, "round-robin");
        assert_eq!(recs[1].router, "least-tokens");
        // scale_load doubled the 2-instance cells' offered load.
        assert_eq!(recs[0].completed, 10);
        assert_eq!(recs[2].completed, 20);
        assert!((recs[2].rate - 40.0).abs() < 1e-12);
        assert!(recs.iter().all(|r| r.stps > 0.0));
    }

    #[test]
    fn invalid_cells_are_all_reported_before_any_cell_runs() {
        // A disaggregated base over counts [1, 2, 4] has two invalid
        // cells (1 and 2 instances cannot host 2 prefill); both must be
        // named in one error, and nothing may have run (order-dependent
        // partial failure is exactly the bug this replaces).
        let mut grid = small_grid();
        grid.base.prefill_instances = 2;
        grid.instance_counts = vec![1, 2, 4];
        grid.routers = vec![RouterPolicy::RoundRobin];
        let err = run_cluster_grid(&grid).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("invalid cluster grid"), "{msg}");
        assert!(
            msg.contains("1 instances cannot host 2"),
            "first invalid cell missing: {msg}"
        );
        assert!(
            msg.contains("2 instances cannot host 2"),
            "second invalid cell missing: {msg}"
        );
        // Zero-instance cells are caught upfront too.
        let mut grid = small_grid();
        grid.instance_counts = vec![0, 1];
        let err = run_cluster_grid(&grid).unwrap_err();
        assert!(format!("{err:#}").contains("0 instances"));
    }

    #[test]
    fn parallel_fanout_matches_the_serial_loop() {
        // The fan-out must be observationally identical to running
        // serve_cluster over the cells one by one, record for record.
        let grid = small_grid();
        let par = run_cluster_grid(&grid).unwrap();
        let serial: Vec<ClusterRecord> = grid_cells(&grid)
            .iter()
            .map(|job| {
                let rep = serve_cluster(job).unwrap();
                ClusterRecord {
                    instances: job.instances,
                    router: rep.router.clone(),
                    mode: rep.mode.clone(),
                    rate: job.workload.arrival_rate,
                    completed: rep.cluster.completed,
                    shed: rep.shed,
                    events: rep.events,
                    span: rep.cluster.span,
                    stps: rep.cluster.stps,
                    stps_per_instance: rep.stps_per_instance(),
                    ttft_p99: rep.cluster.ttft.p99,
                    tpot_p99: rep.cluster.tpot.p99,
                    e2e_p99: rep.cluster.e2e.p99,
                    autoscaled: job.autoscale.is_some(),
                    instance_seconds: rep.instance_seconds,
                    priority_classes: job.workload.priority_mix.len().max(1),
                    preemptions: rep.cluster.preemptions,
                    restores: rep.cluster.restores,
                }
            })
            .collect();
        assert_eq!(par.len(), serial.len());
        for (p, s) in par.iter().zip(&serial) {
            assert_eq!(p.instances, s.instances);
            assert_eq!(p.router, s.router);
            assert_eq!(p.completed, s.completed);
            assert_eq!(p.events, s.events);
            assert_eq!(p.stps.to_bits(), s.stps.to_bits());
            assert_eq!(p.ttft_p99.to_bits(), s.ttft_p99.to_bits());
            assert_eq!(p.e2e_p99.to_bits(), s.e2e_p99.to_bits());
        }
    }

    #[test]
    fn records_export_json() {
        let recs = run_cluster_grid(&ClusterGrid {
            instance_counts: vec![1],
            routers: vec![RouterPolicy::RoundRobin],
            ..small_grid()
        })
        .unwrap();
        let j = Json::parse(&recs[0].to_json().to_string()).unwrap();
        assert_eq!(j.get("instances").unwrap().as_u64(), Some(1));
        assert_eq!(j.get("router").unwrap().as_str(), Some("round-robin"));
        assert!(j.get("ttft_p99_s").unwrap().as_f64().is_some());
        assert_eq!(j.get("autoscaled"), Some(&Json::Bool(false)));
        assert!(j.get("instance_seconds").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn autoscale_axis_fans_out_fixed_and_elastic_cells() {
        let grid = ClusterGrid {
            instance_counts: vec![1],
            routers: vec![RouterPolicy::RoundRobin],
            autoscale: vec![
                None,
                Some(AutoscalePolicy {
                    max_instances: 4,
                    ..AutoscalePolicy::default()
                }),
            ],
            ..small_grid()
        };
        let recs = run_cluster_grid(&grid).unwrap();
        assert_eq!(recs.len(), 2);
        assert!(!recs[0].autoscaled);
        assert!(recs[1].autoscaled);
        // The fixed cell bills its one instance from t = 0 through the
        // end of the run, which covers at least the first-arrival ->
        // last-completion span; both cells serve the same 10-request
        // workload.
        assert!(
            recs[0].instance_seconds >= recs[0].span,
            "fixed 1-instance cell: {} vs span {}",
            recs[0].instance_seconds,
            recs[0].span
        );
        assert!(recs[1].instance_seconds > 0.0);
        assert_eq!(recs[0].completed, 10);
        assert_eq!(recs[1].completed, 10);
        assert!(recs[1].mode.contains("autoscaled"), "{}", recs[1].mode);
    }

    #[test]
    fn priority_mix_axis_fans_out_per_mix_cells() {
        let grid = ClusterGrid {
            instance_counts: vec![1],
            routers: vec![RouterPolicy::RoundRobin],
            priority_mixes: vec![Vec::new(), vec![(0, 3.0), (2, 1.0)]],
            ..small_grid()
        };
        let recs = run_cluster_grid(&grid).unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].priority_classes, 1);
        assert_eq!(recs[1].priority_classes, 2);
        // Both cells serve the full workload; the class draw lands
        // after the length draws, so arrivals (and thus rates) match.
        assert_eq!(recs[0].completed, 10);
        assert_eq!(recs[1].completed, 10);
        // Preemption stays disabled in the base job: counters are zero
        // on every cell and the JSON carries them.
        assert_eq!(recs[1].preemptions, 0);
        assert_eq!(recs[1].restores, 0);
        let j = Json::parse(&recs[1].to_json().to_string()).unwrap();
        assert_eq!(j.get("priority_classes").unwrap().as_u64(), Some(2));
        assert_eq!(j.get("preemptions").unwrap().as_u64(), Some(0));
    }

    #[test]
    fn invalid_priority_mixes_are_caught_before_any_cell_runs() {
        let grid = ClusterGrid {
            priority_mixes: vec![vec![(1, 0.0)]],
            ..small_grid()
        };
        let err = run_cluster_grid(&grid).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("priority class 1"), "{msg}");
        assert!(msg.contains("non-positive"), "{msg}");
    }

    #[test]
    fn invalid_autoscale_bounds_are_caught_before_any_cell_runs() {
        let grid = ClusterGrid {
            autoscale: vec![Some(AutoscalePolicy {
                min_instances: 4,
                max_instances: 2,
                ..AutoscalePolicy::default()
            })],
            ..small_grid()
        };
        let err = run_cluster_grid(&grid).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("autoscale bounds 4..2"), "{msg}");
    }
}
