//! Sweep grid definition: the cross product of models, chips, TP degrees,
//! contexts, and batch policies.

use crate::hw::Chip;

/// How the batch dimension of a sweep is chosen.
#[derive(Debug, Clone, PartialEq)]
pub enum BatchSpec {
    /// Fixed batch sizes.
    Fixed(Vec<u64>),
    /// The largest batch that fits each (system, context) cell — the
    /// paper's max-STPS policy.
    MaxFit,
    /// Both batch 1 and the max-fit batch (UTPS + STPS in one sweep).
    OneAndMaxFit,
}

/// A sweep grid. Each axis is explicit so records are self-describing.
#[derive(Debug, Clone)]
pub struct Grid {
    /// Model names resolved against the registry at run time.
    pub models: Vec<String>,
    /// Chips to evaluate.
    pub chips: Vec<Chip>,
    /// Tensor-parallel degrees.
    pub tps: Vec<u64>,
    /// Context lengths, tokens.
    pub contexts: Vec<u64>,
    /// Batch policy.
    pub batch: BatchSpec,
    /// Grow PP to fit (true for capacity-starved chips like SRAM/COWS);
    /// when false, cells that do not fit are recorded as unservable.
    pub fit_pp: bool,
}

impl Grid {
    /// A grid over the paper's three models with one chip.
    pub fn paper_models(chip: Chip) -> Grid {
        Grid {
            models: vec![
                "llama3-70b".into(),
                "llama3-405b".into(),
                "deepseek-v3".into(),
            ],
            chips: vec![chip],
            tps: vec![8, 32, 128],
            contexts: super::TABLE_CONTEXTS.to_vec(),
            batch: BatchSpec::OneAndMaxFit,
            fit_pp: false,
        }
    }

    /// Number of (model, chip, tp, context) cells (batch expansion is
    /// policy-dependent and happens in the runner).
    pub fn n_cells(&self) -> usize {
        self.models.len() * self.chips.len() * self.tps.len() * self.contexts.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::presets;

    #[test]
    fn cell_count_is_product_of_axes() {
        let g = Grid::paper_models(presets::hbm3());
        assert_eq!(g.n_cells(), 3 * 1 * 3 * 6);
    }
}
