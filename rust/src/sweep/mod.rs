//! Parameter-sweep engine: the systematic `application x hardware`
//! exploration the paper positions as LIMINAL's key advantage over
//! silicon measurements and point studies.
//!
//! Two sweep families: the closed-form [`Grid`] (model x chip x TP x
//! context, evaluated analytically) and the event-driven
//! [`ClusterGrid`] (instance count x router policy, each cell a full
//! cluster DES run producing SLO tails and scale-out efficiency).

mod cluster;
mod grid;
mod record;
mod runner;

pub use cluster::{run_cluster_grid, ClusterGrid, ClusterRecord};
pub use grid::{BatchSpec, Grid};
pub use record::Record;
pub use runner::SweepRunner;

/// Context lengths used throughout the paper's evaluation (1K..128K).
pub const PAPER_CONTEXTS: [u64; 8] =
    [1024, 2048, 4096, 8192, 16384, 32768, 65536, 131072];

/// The subset of contexts the appendix tables report (4K..128K).
pub const TABLE_CONTEXTS: [u64; 6] = [4096, 8192, 16384, 32768, 65536, 131072];

/// TP degrees highlighted in Table 2/5/6.
pub const PAPER_TPS: [u64; 3] = [8, 32, 128];
