//! Rayon-parallel sweep execution.

use crate::apps::{Application, DecodePoint, Registry};
use crate::hw::{Chip, SystemConfig};
use crate::model::{evaluate, max_batch_for_system, EvalOptions};
use crate::parallel::{fit_system, FitRequest};
use crate::power::PowerModel;

use super::{BatchSpec, Grid, Record};

/// Executes sweep grids against the analytical model.
#[derive(Clone)]
pub struct SweepRunner {
    /// Model registry used to resolve grid model names.
    pub registry: Registry,
    /// Evaluation options shared by all cells.
    pub opts: EvalOptions,
    /// Power model for STPS/W columns.
    pub power: PowerModel,
}

impl Default for SweepRunner {
    fn default() -> Self {
        SweepRunner {
            registry: Registry::builtin(),
            opts: EvalOptions::default(),
            power: PowerModel::default(),
        }
    }
}

impl SweepRunner {
    /// Run the grid, producing one record per (cell, batch) pair, in a
    /// deterministic order (axes iterate in declaration order).
    pub fn run(&self, grid: &Grid) -> Vec<Record> {
        // Cells are independent: fan out across threads, preserving order.
        let cells: Vec<(String, Chip, u64, u64)> = grid
            .models
            .iter()
            .flat_map(|m| {
                grid.chips.iter().flat_map(move |c| {
                    grid.tps.iter().flat_map(move |&tp| {
                        grid.contexts
                            .iter()
                            .map(move |&ctx| (m.clone(), c.clone(), tp, ctx))
                    })
                })
            })
            .collect();

        crate::util::par::parallel_map(cells, |(model, chip, tp, ctx)| {
            self.run_cell(grid, model, chip, *tp, *ctx)
        })
        .into_iter()
        .flatten()
        .collect()
    }

    /// Evaluate one (model, chip, tp, context) cell under the grid's
    /// batch policy.
    fn run_cell(
        &self,
        grid: &Grid,
        model: &str,
        chip: &Chip,
        tp: u64,
        context: u64,
    ) -> Vec<Record> {
        let Some(app) = self.registry.app(model) else {
            // pp = 0 is the "no system was sized" sentinel, matching the
            // fit-failure path below (this used to pass 1 here and 0
            // there, so unservable rows disagreed about their shape).
            return vec![Record::unservable(model, &format!("{}-TP{tp}", chip.name), tp, 0, context)];
        };
        let app: &dyn Application = app.as_ref();

        let batches: Vec<Option<u64>> = match &grid.batch {
            BatchSpec::Fixed(bs) => bs.iter().map(|&b| Some(b)).collect(),
            BatchSpec::MaxFit => vec![None],
            BatchSpec::OneAndMaxFit => vec![Some(1), None],
        };

        batches
            .into_iter()
            .map(|b| self.eval_one(grid, app, model, chip, tp, context, b))
            .collect()
    }

    /// Evaluate one batch choice; `batch = None` means "max that fits".
    fn eval_one(
        &self,
        grid: &Grid,
        app: &dyn Application,
        model: &str,
        chip: &Chip,
        tp: u64,
        context: u64,
        batch: Option<u64>,
    ) -> Record {
        // Size the system: PP grows to fit (SRAM/COWS) or is pinned to 1.
        let probe = DecodePoint { batch: batch.unwrap_or(1), context };
        let sys = if grid.fit_pp {
            match fit_system(app, &FitRequest { tp: Some(tp), ..FitRequest::new(chip.clone(), probe) }) {
                Ok(s) => s,
                Err(_) => {
                    return Record::unservable(
                        model,
                        &format!("{}-TP{tp}", chip.name),
                        tp,
                        0,
                        context,
                    )
                }
            }
        } else {
            SystemConfig::new(chip.clone(), tp, 1)
        };

        let b = match batch {
            Some(b) => b,
            None => match max_batch_for_system(app, &sys, context) {
                Some(b) => b,
                None => {
                    return Record::unservable(model, &sys.label(), sys.tp, sys.pp, context)
                }
            },
        };

        let pt = DecodePoint { batch: b, context };
        match evaluate(app, &sys, &pt, &self.opts) {
            Ok(perf) => {
                let watts = self.power.system_power(&sys).total_watts;
                Record::from_perf(model, &sys, &perf, watts)
            }
            Err(_) => Record::unservable(model, &sys.label(), sys.tp, sys.pp, context),
        }
    }

    /// Convenience: evaluate a single fully-specified point.
    pub fn eval_point(
        &self,
        model: &str,
        sys: &SystemConfig,
        pt: &DecodePoint,
    ) -> Option<Record> {
        let app = self.registry.app(model)?;
        match evaluate(app.as_ref(), sys, pt, &self.opts) {
            Ok(perf) => {
                let watts = self.power.system_power(sys).total_watts;
                Some(Record::from_perf(model, sys, &perf, watts))
            }
            Err(_) => Some(Record::unservable(model, &sys.label(), sys.tp, sys.pp, pt.context)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::presets;

    #[test]
    fn paper_grid_produces_two_records_per_cell() {
        let runner = SweepRunner::default();
        let grid = Grid::paper_models(presets::hbm3());
        let recs = runner.run(&grid);
        assert_eq!(recs.len(), grid.n_cells() * 2);
    }

    #[test]
    fn order_is_deterministic() {
        let runner = SweepRunner::default();
        let mut grid = Grid::paper_models(presets::hbm3());
        grid.contexts = vec![4096];
        let a = runner.run(&grid);
        let b = runner.run(&grid);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.system, y.system);
            assert_eq!(x.utps, y.utps);
        }
    }

    #[test]
    fn unservable_cells_use_the_same_pp_sentinel() {
        let runner = SweepRunner::default();
        let grid = Grid {
            models: vec!["not-a-model".into()],
            chips: vec![presets::hbm3()],
            tps: vec![8],
            contexts: vec![4096],
            batch: BatchSpec::Fixed(vec![1]),
            fit_pp: false,
        };
        let recs = runner.run(&grid);
        assert_eq!(recs.len(), 1);
        assert!(!recs[0].servable());
        // pp = 0 marks "no system sized", consistent with fit failures.
        assert_eq!(recs[0].pp, 0);
    }

    #[test]
    fn unservable_cells_become_dashes() {
        let runner = SweepRunner::default();
        let grid = Grid {
            models: vec!["deepseek-v3".into()],
            chips: vec![presets::hbm3()],
            tps: vec![2], // 192 GiB — cannot hold 625 GiB of weights
            contexts: vec![4096],
            batch: BatchSpec::Fixed(vec![1]),
            fit_pp: false,
        };
        let recs = runner.run(&grid);
        assert_eq!(recs.len(), 1);
        assert!(!recs[0].servable());
    }
}
