//! The cluster simulator: N [`Instance`]s multiplexed on one event
//! calendar, behind a pluggable router, in colocated or disaggregated
//! prefill/decode mode.
//!
//! Every instance is the same state machine the single-instance
//! simulator drives ([`crate::serving::Instance`]): its own batcher
//! (admission queue + KV budget + chunk planner) and step engine. All
//! instances share a single [`EventQueue`](crate::des::EventQueue) of
//! [`InstanceEvent`]s keyed by instance id, so cross-instance causality
//! (arrival routing, KV shipment) is ordered by one total-order clock
//! and seeded runs replay exactly. All request state lives in one
//! [`RequestArena`] owned by the simulator; the calendar, the router,
//! and every batcher move dense [`ReqId`] handles only, so steady-state
//! stepping allocates nothing — no event carries a `Request`, and
//! retirement pushes 4-byte ids, not clones.
//!
//! # Disaggregated semantics
//!
//! In [`ClusterMode::Disaggregated`] the prefill pool runs chunked
//! prefill *only*: a routed request is truncated to a pure-ingestion
//! sub-request; when its last chunk lands, the prompt's KV cache —
//! `context_len * kv_bytes_per_token` bytes — ships to the
//! least-loaded decode instance over the configured link
//! ([`ClusterSpec::kv_link_bw`]), and the transfer latency
//! (`bytes / link_bw`) is paid **before decode admission**. The first
//! output token is then produced by the decode pool's first step, so
//! TTFT honestly includes queueing, prefill chunking, the shipment
//! stall, and decode admission. Decode instances run the paper's
//! decode-only pricing (prefill chunk 0 — their steps never carry
//! prefill tokens); the prefill pool's per-instance reports measure
//! ingestion, not token generation.

use crate::des::EventQueue;
use crate::serving::{
    Batcher, Instance, InstanceEvent, KvBudget, NoopObserver, ReqId, Request,
    RequestArena, ServingReport, SimConfig, SimObserver, StepEngine, StepStats,
};

use super::report::{ClusterReport, PoolStats};
use super::router::{argmin, InstanceLoad, Role, Router};

/// How the cluster's instances divide the request lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterMode {
    /// Every instance serves the full lifecycle (chunked prefill +
    /// decode), like N independent copies of the serving simulator.
    Colocated,
    /// The first `prefill` instances only ingest prompts; the remaining
    /// instances only decode, fed by KV shipped over the interconnect.
    Disaggregated {
        /// Number of dedicated prefill instances (at least 1, and at
        /// least one instance must remain for the decode pool).
        prefill: usize,
    },
}

/// Cluster-wide configuration.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    /// Lifecycle split across instances.
    pub mode: ClusterMode,
    /// Max concurrent sequences per instance.
    pub max_batch: usize,
    /// Prefill chunk tokens per step on prefill-capable instances.
    pub prefill_chunk: u64,
    /// Interconnect bandwidth for shipping KV prefill -> decode,
    /// bytes/s. `f64::INFINITY` models an ideal (free) link — the
    /// paper's decode-only idealization. Production entry points
    /// ([`crate::coordinator::serve_cluster`]) default this to
    /// [`crate::hw::SystemConfig::interconnect_bw`], which aggregates
    /// [`crate::hw::DEFAULT_XFER_BW_PER_CHIP`] over the instance's TP
    /// domain.
    pub kv_link_bw: f64,
    /// Global step/time limits (steps count across all instances).
    pub sim: SimConfig,
}

impl Default for ClusterSpec {
    fn default() -> Self {
        ClusterSpec {
            mode: ClusterMode::Colocated,
            max_batch: 32,
            prefill_chunk: crate::model::DEFAULT_PREFILL_CHUNK,
            kv_link_bw: crate::hw::DEFAULT_XFER_BW_PER_CHIP,
            sim: SimConfig::default(),
        }
    }
}

/// The cluster simulator. Build with [`ClusterSim::new`], then
/// [`ClusterSim::run`] a workload to get a [`ClusterReport`].
pub struct ClusterSim {
    instances: Vec<Instance<'static>>,
    roles: Vec<Role>,
    /// Front-door candidate indices (roles are fixed at construction).
    front_door: Vec<usize>,
    /// Decode-side KV footprint committed to in-flight shipments, per
    /// instance (so placement sees transfers that have not landed yet).
    in_transit_kv: Vec<f64>,
    router: Box<dyn Router>,
    spec: ClusterSpec,
    kv_bytes_per_token: f64,
    /// All request state of the run, addressed by dense [`ReqId`]s.
    arena: RequestArena,
    /// Disaggregated bookkeeping, indexed by arena slot: a prefill
    /// pool's ingestion sub-request maps back to the original request
    /// it was cloned from (which parks in the arena, full `gen_len`
    /// intact, until the sub-request's KV ships to the decode pool).
    /// Replaces the old `HashMap<u64, u64>` of parked generation
    /// lengths with a direct `Vec` lookup.
    origin: Vec<Option<ReqId>>,
    /// Router snapshot buffer, reused across arrivals so routing
    /// allocates nothing in steady state.
    loads_buf: Vec<InstanceLoad>,
    /// KV bytes shipped prefill -> decode so far.
    kv_shipped_bytes: f64,
    /// Sum of shipment latencies, seconds.
    kv_transfer_total: f64,
    /// Number of shipments.
    kv_transfers: u64,
}

impl ClusterSim {
    /// Build a cluster of `engines.len()` instances. Every instance gets
    /// a clone of `kv` as its KV budget; in disaggregated mode the first
    /// `prefill` engines form the prefill pool and the rest the decode
    /// pool (decode instances run with prefill chunk 0: prompts arrive
    /// already in KV, the paper's disaggregated assumption).
    ///
    /// Panics on an empty engine list, a non-positive `kv_link_bw`, or a
    /// disaggregated split that leaves either pool empty.
    pub fn new(
        engines: Vec<Box<dyn StepEngine>>,
        kv: KvBudget,
        router: Box<dyn Router>,
        spec: ClusterSpec,
    ) -> Self {
        assert!(!engines.is_empty(), "cluster needs at least one instance");
        assert!(spec.kv_link_bw > 0.0, "kv_link_bw must be positive");
        if let ClusterMode::Disaggregated { prefill } = spec.mode {
            assert!(
                prefill >= 1 && prefill < engines.len(),
                "disaggregated split {prefill}P needs 1..{} prefill instances",
                engines.len()
            );
            assert!(
                spec.prefill_chunk > 0,
                "disaggregated mode needs a nonzero prefill chunk"
            );
        }
        let kv_bytes_per_token = kv.bytes_per_token;
        let n = engines.len();
        let mut roles = Vec::with_capacity(n);
        let instances: Vec<Instance<'static>> = engines
            .into_iter()
            .enumerate()
            .map(|(i, engine)| {
                let role = match spec.mode {
                    ClusterMode::Colocated => Role::Colocated,
                    ClusterMode::Disaggregated { prefill } => {
                        if i < prefill {
                            Role::Prefill
                        } else {
                            Role::Decode
                        }
                    }
                };
                roles.push(role);
                let batcher = match role {
                    Role::Decode => Batcher::new(spec.max_batch, kv.clone()),
                    _ => Batcher::with_prefill(
                        spec.max_batch,
                        kv.clone(),
                        spec.prefill_chunk,
                    ),
                };
                Instance::new(batcher, engine)
            })
            .collect();
        let front_door = roles
            .iter()
            .enumerate()
            .filter(|(_, r)| matches!(r, Role::Colocated | Role::Prefill))
            .map(|(i, _)| i)
            .collect();
        ClusterSim {
            instances,
            roles,
            front_door,
            in_transit_kv: vec![0.0; n],
            router,
            spec,
            kv_bytes_per_token,
            arena: RequestArena::new(),
            origin: Vec::new(),
            loads_buf: Vec::with_capacity(n),
            kv_shipped_bytes: 0.0,
            kv_transfer_total: 0.0,
            kv_transfers: 0,
        }
    }

    /// Human-readable mode string, e.g. `colocated x8` or
    /// `disaggregated 3P+5D`.
    fn mode_label(&self) -> String {
        match self.spec.mode {
            ClusterMode::Colocated => format!("colocated x{}", self.instances.len()),
            ClusterMode::Disaggregated { prefill } => format!(
                "disaggregated {}P+{}D",
                prefill,
                self.instances.len() - prefill
            ),
        }
    }

    /// Refresh the router's load snapshot into the reusable buffer
    /// (`loads_buf`), so per-arrival routing allocates nothing.
    fn refresh_loads(&mut self) {
        self.loads_buf.clear();
        let arena = &self.arena;
        for (inst, &role) in self.instances.iter().zip(&self.roles) {
            self.loads_buf.push(InstanceLoad {
                role,
                queued: inst.queued_len(),
                active: inst.active_len(),
                max_batch: inst.max_batch(),
                outstanding_kv_bytes: inst.outstanding_kv_bytes(),
                outstanding_gen_tokens: inst.outstanding_gen_tokens(),
                pending_prefill_tokens: inst.pending_prefill_tokens(),
                pending_prefill_prompts: inst.pending_prefill_prompts(arena),
                ewma_step_latency: inst.ewma_step(),
                prefill_chunk: inst.prefill_chunk(),
            });
        }
    }

    /// Hand a routed request to instance `i`. On a prefill instance the
    /// request is cloned into a pure-ingestion sub-request (`gen_len`
    /// 1: the batcher retires it the moment its last chunk lands) and
    /// `origin` maps the sub-request's arena slot back to the original,
    /// which parks untouched — full `gen_len` intact — until the KV
    /// ships to the decode pool. Returns the sub-request's id when one
    /// was minted (so observers can track the orig -> sub lineage).
    fn assign(&mut self, i: usize, id: ReqId) -> Option<ReqId> {
        if self.roles[i] == Role::Prefill {
            let mut sub = self.arena[id].clone();
            sub.gen_len = 1;
            let sub_id = self.arena.alloc(sub);
            if self.origin.len() <= sub_id.index() {
                self.origin.resize(sub_id.index() + 1, None);
            }
            self.origin[sub_id.index()] = Some(id);
            self.instances[i].enqueue(sub_id, &self.arena);
            Some(sub_id)
        } else {
            self.instances[i].enqueue(id, &self.arena);
            None
        }
    }

    /// A KV shipment landed at decode instance `i`: settle the
    /// in-transit accounting and admit the original request. A shipment
    /// addressed to a request that already completed its lifecycle (a
    /// stale transfer) still settles the accounting but must be a
    /// no-op for admission — re-enqueueing a dead request would
    /// double-count its generation.
    fn kv_arrive(&mut self, i: usize, id: ReqId) {
        let (bytes, dead) = {
            let r = &self.arena[id];
            (
                (r.context_len + r.gen_len) as f64 * self.kv_bytes_per_token,
                r.completed_at.is_some(),
            )
        };
        self.in_transit_kv[i] = (self.in_transit_kv[i] - bytes).max(0.0);
        if dead {
            return;
        }
        self.instances[i].enqueue(id, &self.arena);
    }

    /// Decode-pool placement for a prefilled request: least committed
    /// KV bytes (landed + in transit), lowest index on ties
    /// (deterministic). The front-door router chooses who prefills; KV
    /// shipment always balances on capacity, the binding constraint of
    /// the decode pool.
    fn pick_decode(&self) -> usize {
        argmin(
            self.instances
                .iter()
                .enumerate()
                .filter(|(i, _)| self.roles[*i] == Role::Decode)
                .map(|(i, inst)| {
                    (i, inst.outstanding_kv_bytes() + self.in_transit_kv[i])
                }),
        )
        .map(|(i, _)| i)
        .expect("disaggregated cluster has a decode pool")
    }

    /// Run the workload to completion (or a configured limit).
    pub fn run(self, workload: Vec<Request>) -> ClusterReport {
        // The no-op observer monomorphizes every hook away, so this is
        // exactly the pre-observer event loop.
        self.run_with(workload, &mut NoopObserver)
    }

    /// [`ClusterSim::run`] with a [`SimObserver`] watching every applied
    /// event, routing decision, and retirement — the deterministic
    /// simulation-testing harness ([`crate::dst`]) hooks its invariant
    /// checker in here.
    pub fn run_with<O: SimObserver>(
        mut self,
        workload: Vec<Request>,
        obs: &mut O,
    ) -> ClusterReport {
        let mut q: EventQueue<InstanceEvent> = EventQueue::new();
        let offered = workload.len() as u64;
        self.arena = RequestArena::with_capacity(workload.len());
        for r in workload {
            let at = r.arrival;
            let id = self.arena.alloc(r);
            q.schedule_at(at, InstanceEvent::Arrival(id));
        }

        // Full request lifecycles (prefill + decode merged) for the
        // cluster-level SLO report, as arena handles.
        let mut finished: Vec<ReqId> = Vec::new();
        // Reused copy of each step's retirements, so we can route them
        // (ship / finish) without holding the batcher's buffer borrow.
        let mut retired_scratch: Vec<ReqId> = Vec::new();
        let mut shed: u64 = 0;
        let mut steps_total: u64 = 0;
        let mut deadline_hit = false;

        while let Some(t) = q.peek_time() {
            if t > self.spec.sim.max_time {
                deadline_hit = true;
                break; // clamp at the boundary, like the single sim
            }
            let (now, ev) = q.next().expect("peeked event is still queued");
            match ev {
                InstanceEvent::Arrival(id) => {
                    self.refresh_loads();
                    let pick = {
                        let r = &self.arena[id];
                        self.router.route(r, &self.front_door, &self.loads_buf)
                    };
                    match pick {
                        Some(i) => {
                            obs.on_route(now, id, i);
                            if let Some(sub) = self.assign(i, id) {
                                obs.on_sub_request(now, id, sub);
                            }
                        }
                        None => {
                            obs.on_shed(now, id);
                            shed += 1;
                        }
                    }
                }
                InstanceEvent::StepDone(i) => {
                    let retired = self.instances[i].step_done(now, &mut self.arena);
                    retired_scratch.clear();
                    retired_scratch.extend_from_slice(retired);
                    steps_total += 1;
                    let lifecycle_done = self.roles[i] != Role::Prefill;
                    for &id in &retired_scratch {
                        obs.on_retire(now, i, id, lifecycle_done, &self.arena);
                        if lifecycle_done {
                            finished.push(id);
                        } else {
                            self.ship(id, &mut q);
                        }
                    }
                }
                InstanceEvent::KvArrive(i, id) => self.kv_arrive(i, id),
            }
            if steps_total >= self.spec.sim.max_steps {
                break;
            }
            for (i, inst) in self.instances.iter_mut().enumerate() {
                if let Some(dt) = inst.kick(now, &mut self.arena) {
                    q.schedule_in(dt, InstanceEvent::StepDone(i));
                }
            }
            obs.post_event(now, &ev, &self.instances, &self.arena);
        }

        let events = q.fired();
        let end_time = if deadline_hit {
            self.spec.sim.max_time
        } else {
            q.now().min(self.spec.sim.max_time)
        };
        obs.on_done(end_time, &self.instances, &self.arena);
        self.into_report(finished, offered, shed, end_time, events)
    }

    /// A prompt finished ingesting on a prefill instance: ship its KV
    /// cache (`context_len * kv_bytes_per_token` bytes) to the least-
    /// loaded decode instance; the transfer latency lands *before*
    /// decode admission. The original request (parked in the arena with
    /// its full `gen_len` and untouched token state) inherits the
    /// sub-request's prefill progress and admission stamp, so the
    /// decode pool produces every output token (including the first)
    /// and the lifecycle metrics see the stall. `admitted_at` survives
    /// the hop (the decode batcher keeps an existing stamp), so queue
    /// delay and residence stay lifecycle quantities.
    fn ship(&mut self, sub: ReqId, q: &mut EventQueue<InstanceEvent>) {
        // `take`, not a copy: the sub-request is fully retired once its
        // KV ships, so its side-table entry must die with it. Leaving
        // the entry behind would let a replayed retirement ship (and
        // double-count) the original a second time.
        let orig = self.origin[sub.index()]
            .take()
            .expect("prefill pool retired a request it never ingested");
        let (ctx, prefilled, scheduled, admitted) = {
            let s = &self.arena[sub];
            (s.context_len, s.prefilled, s.scheduled_prefill, s.admitted_at)
        };
        let full_gen = {
            let r = &mut self.arena[orig];
            r.prefilled = prefilled;
            r.scheduled_prefill = scheduled;
            r.admitted_at = admitted;
            r.gen_len
        };
        let ship_bytes = ctx as f64 * self.kv_bytes_per_token;
        let dest = self.pick_decode();
        self.in_transit_kv[dest] +=
            (ctx + full_gen) as f64 * self.kv_bytes_per_token;
        let dt = ship_bytes / self.spec.kv_link_bw;
        self.kv_shipped_bytes += ship_bytes;
        self.kv_transfer_total += dt;
        self.kv_transfers += 1;
        q.schedule_in(dt, InstanceEvent::KvArrive(dest, orig));
    }

    /// Assemble the cluster report: per-instance reports, the merged
    /// lifecycle report (percentiles over the pooled raw samples), and
    /// per-pool utilization.
    fn into_report(
        self,
        finished: Vec<ReqId>,
        offered: u64,
        shed: u64,
        end_time: f64,
        events: u64,
    ) -> ClusterReport {
        let router_name = self.router.name();
        let mode = self.mode_label();
        let mut agg = StepStats { end_time, ..Default::default() };
        let mut per_instance: Vec<ServingReport> = Vec::new();
        for (i, inst) in self.instances.iter().enumerate() {
            let st = inst.stats(end_time);
            agg.steps += st.steps;
            agg.batch_time_integral += st.batch_time_integral;
            agg.busy_time += st.busy_time;
            agg.prefill_tokens += st.prefill_tokens;
            let name =
                format!("i{i}:{}:{}", self.roles[i].tag(), inst.engine_name());
            per_instance.push(inst.report(name, end_time, &self.arena));
        }
        let cluster = ServingReport::from_refs(
            format!("{router_name} / {mode}"),
            finished.iter().map(|&id| &self.arena[id]),
            &agg,
        );
        let pools = self.pool_stats(end_time);

        ClusterReport {
            router: router_name,
            mode,
            offered,
            shed,
            events,
            cluster,
            per_instance,
            pools,
            kv_shipped_bytes: self.kv_shipped_bytes,
            kv_transfer_mean: if self.kv_transfers > 0 {
                self.kv_transfer_total / self.kv_transfers as f64
            } else {
                0.0
            },
        }
    }

    /// Per-pool utilization, grouped by role. Pool token counts are
    /// output tokens generated *at* the pool: the decode pool produces
    /// every output token of a disaggregated request, and the prefill
    /// pool none (its sub-requests are pure ingestion), so on a drained
    /// run the pool sums equal cluster tokens in both modes.
    fn pool_stats(&self, end_time: f64) -> Vec<PoolStats> {
        let mut pools: Vec<PoolStats> = Vec::new();
        for role in [Role::Colocated, Role::Prefill, Role::Decode] {
            let mut n = 0usize;
            let mut steps = 0u64;
            let mut busy = 0.0f64;
            let mut lane_seconds = 0.0f64;
            let mut tokens = 0u64;
            for (inst, _) in self
                .instances
                .iter()
                .zip(&self.roles)
                .filter(|(_, &r)| r == role)
            {
                n += 1;
                let st = inst.stats(end_time);
                steps += st.steps;
                busy += st.busy_time;
                lane_seconds += st.batch_time_integral;
                if role != Role::Prefill {
                    tokens += inst
                        .finished()
                        .iter()
                        .map(|&id| self.arena[id].generated)
                        .sum::<u64>();
                }
            }
            if n == 0 {
                continue;
            }
            pools.push(PoolStats {
                label: role.tag().to_string(),
                instances: n,
                steps,
                busy_frac: busy / (n as f64 * end_time.max(1e-12)),
                mean_batch: if busy > 0.0 { lane_seconds / busy } else { 0.0 },
                tokens,
            });
        }
        pools
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::router::{LeastOutstandingTokens, RoundRobin};
    use crate::serving::testutil::{mk_req, open_budget, FixedEngine};

    fn engines(n: usize, dt: f64) -> Vec<Box<dyn StepEngine>> {
        (0..n)
            .map(|_| Box::new(FixedEngine(dt)) as Box<dyn StepEngine>)
            .collect()
    }

    fn colo_spec(max_batch: usize, chunk: u64) -> ClusterSpec {
        ClusterSpec {
            mode: ClusterMode::Colocated,
            max_batch,
            prefill_chunk: chunk,
            ..Default::default()
        }
    }

    fn disagg_spec(prefill: usize, chunk: u64, link_bw: f64) -> ClusterSpec {
        ClusterSpec {
            mode: ClusterMode::Disaggregated { prefill },
            max_batch: 4,
            prefill_chunk: chunk,
            kv_link_bw: link_bw,
            ..Default::default()
        }
    }

    #[test]
    fn colocated_round_robin_spreads_and_completes() {
        let sim = ClusterSim::new(
            engines(2, 0.01),
            open_budget(),
            Box::new(RoundRobin::new()),
            colo_spec(4, 0),
        );
        let wl: Vec<Request> =
            (0..10).map(|i| mk_req(i, 0.001 * i as f64, 8, 4)).collect();
        let rep = sim.run(wl);
        assert_eq!(rep.offered, 10);
        assert_eq!(rep.shed, 0);
        assert_eq!(rep.cluster.completed, 10);
        assert_eq!(rep.cluster.tokens, 40);
        // Round-robin: both instances served requests.
        assert_eq!(rep.per_instance.len(), 2);
        assert!(rep.per_instance.iter().all(|r| r.completed == 5));
        assert_eq!(rep.pools.len(), 1);
        assert_eq!(rep.pools[0].label, "colo");
        assert_eq!(rep.pools[0].tokens, 40);
        assert_eq!(rep.kv_shipped_bytes, 0.0);
    }

    #[test]
    fn four_instances_reach_4x_aggregate_throughput() {
        // The scaling acceptance pin, deterministic under a
        // fixed-latency engine: 256 identical decode-only requests
        // (gen 32) saturate the cluster from t=0. Round-robin splits
        // them 64/64/64/64; the only scale-out losses are each
        // instance's cold-start step and its drain tail, giving a
        // 3.99x aggregate-throughput ratio — over the >= 3.5x
        // acceptance bar with margin. Spans are exact step counts
        // times 0.01 s (10.25 s vs 2.57 s), pinned below.
        let run = |n: usize| {
            let sim = ClusterSim::new(
                engines(n, 0.01),
                open_budget(),
                Box::new(RoundRobin::new()),
                colo_spec(8, 0),
            );
            let wl: Vec<Request> =
                (0..256).map(|i| mk_req(i, 0.0, 8, 32)).collect();
            sim.run(wl)
        };
        let one = run(1);
        let four = run(4);
        assert_eq!(one.cluster.completed, 256);
        assert_eq!(four.cluster.completed, 256);
        assert_eq!(one.cluster.tokens, 256 * 32);
        assert_eq!(four.cluster.tokens, 256 * 32);
        assert!((one.cluster.span - 10.25).abs() < 1e-6, "{}", one.cluster.span);
        assert!((four.cluster.span - 2.57).abs() < 1e-6, "{}", four.cluster.span);
        assert!(
            four.cluster.stps >= one.cluster.stps * 3.5,
            "4 instances: {} vs 1: {}",
            four.cluster.stps,
            one.cluster.stps
        );
    }

    #[test]
    fn disaggregated_lifecycle_ships_kv_and_prices_the_transfer() {
        // 1 prefill + 1 decode instance, 0.1 s steps, ctx 8 / gen 3,
        // chunk 8, transfer of 8 bytes at 80 B/s = 0.1 s.
        let sim = ClusterSim::new(
            engines(2, 0.1),
            open_budget(),
            Box::new(RoundRobin::new()),
            disagg_spec(1, 8, 80.0),
        );
        let rep = sim.run(vec![mk_req(0, 0.0, 8, 3)]);
        assert_eq!(rep.cluster.completed, 1);
        assert_eq!(rep.cluster.tokens, 3);
        // Prefill chunk lands at 0.1; KV ships until 0.2; decode steps
        // at 0.3 / 0.4 / 0.5. The first token comes from the decode
        // pool, so TTFT includes the shipment stall.
        assert!((rep.cluster.ttft.p50 - 0.3).abs() < 1e-9, "{}", rep.cluster.ttft.p50);
        assert!((rep.cluster.e2e.p50 - 0.5).abs() < 1e-9, "{}", rep.cluster.e2e.p50);
        // TPOT is pure decode cadence: (0.5 - 0.3) / 2.
        assert!((rep.cluster.tpot.p50 - 0.1).abs() < 1e-9);
        // Admission happened at t=0 on the prefill instance and the
        // stamp survives the hop: queue delay stays zero, residence
        // spans the whole lifecycle (3 tokens / 0.5 s).
        assert!(rep.cluster.queue_delay_mean.abs() < 1e-9);
        assert!((rep.cluster.utps_mean - 3.0 / 0.5).abs() < 1e-9);
        assert!((rep.kv_shipped_bytes - 8.0).abs() < 1e-12);
        assert!((rep.kv_transfer_mean - 0.1).abs() < 1e-12);
        // Pool accounting: ingestion at the prefill pool (no output
        // tokens), all three tokens at the decode pool.
        let prefill = rep.pools.iter().find(|p| p.label == "prefill").unwrap();
        let decode = rep.pools.iter().find(|p| p.label == "decode").unwrap();
        assert_eq!(prefill.tokens, 0);
        assert_eq!(decode.tokens, 3);
        assert_eq!(rep.cluster.prefill_tokens, 8);
    }

    #[test]
    fn finite_link_strictly_inflates_ttft_over_ideal() {
        // The disaggregation acceptance pin: with a finite KV link the
        // transfer stall must push TTFT strictly past the
        // infinite-bandwidth case, and decode-pool steps must carry no
        // prefill chunks.
        let run = |link_bw: f64| {
            let sim = ClusterSim::new(
                engines(2, 0.1),
                open_budget(),
                Box::new(RoundRobin::new()),
                disagg_spec(1, 8, link_bw),
            );
            sim.run(vec![mk_req(0, 0.0, 8, 3), mk_req(1, 0.05, 8, 2)])
        };
        let ideal = run(f64::INFINITY);
        let finite = run(80.0);
        assert_eq!(ideal.cluster.completed, 2);
        assert_eq!(finite.cluster.completed, 2);
        assert!(
            finite.cluster.ttft.mean > ideal.cluster.ttft.mean,
            "finite-link TTFT {} must exceed ideal-link {}",
            finite.cluster.ttft.mean,
            ideal.cluster.ttft.mean
        );
        assert!(finite.cluster.e2e.p99 > ideal.cluster.e2e.p99);
        assert_eq!(ideal.kv_transfer_mean, 0.0);
        assert!(finite.kv_transfer_mean > 0.0);
        // Decode instances run the paper's decode-only pricing: zero
        // prefill tokens ever processed there.
        for rep in [&ideal, &finite] {
            for inst in &rep.per_instance {
                if inst.engine.contains("decode") {
                    assert_eq!(inst.prefill_tokens, 0);
                }
            }
            let prefill_pool_tokens: u64 = rep
                .per_instance
                .iter()
                .filter(|r| r.engine.contains(":prefill:"))
                .map(|r| r.prefill_tokens)
                .sum();
            assert_eq!(prefill_pool_tokens, rep.cluster.prefill_tokens);
        }
    }

    #[test]
    fn decode_pool_balances_on_committed_kv() {
        // 1 prefill + 2 decode instances; two long-decode requests must
        // land on different decode instances even though the second KV
        // shipment departs while the first is still in transit.
        let sim = ClusterSim::new(
            engines(3, 0.1),
            open_budget(),
            Box::new(LeastOutstandingTokens),
            disagg_spec(1, 16, 160.0),
        );
        let rep = sim.run(vec![mk_req(0, 0.0, 16, 40), mk_req(1, 0.0, 16, 40)]);
        assert_eq!(rep.cluster.completed, 2);
        let decode_reps: Vec<_> = rep
            .per_instance
            .iter()
            .filter(|r| r.engine.contains("decode"))
            .collect();
        assert_eq!(decode_reps.len(), 2);
        assert!(
            decode_reps.iter().all(|r| r.completed == 1),
            "KV shipment must spread across the decode pool"
        );
    }

    #[test]
    fn global_step_limit_is_exact() {
        let spec = ClusterSpec {
            sim: SimConfig { max_steps: 7, ..Default::default() },
            ..colo_spec(2, 0)
        };
        let sim = ClusterSim::new(
            engines(2, 0.01),
            open_budget(),
            Box::new(RoundRobin::new()),
            spec,
        );
        let wl: Vec<Request> = (0..50).map(|i| mk_req(i, 0.0, 8, 20)).collect();
        let rep = sim.run(wl);
        let steps: u64 = rep.pools.iter().map(|p| p.steps).sum();
        assert_eq!(steps, 7);
        assert_eq!(rep.cluster.steps, 7);
    }

    #[test]
    fn max_time_clamps_the_cluster_at_the_boundary() {
        let spec = ClusterSpec {
            sim: SimConfig { max_time: 0.25, ..Default::default() },
            ..colo_spec(4, 0)
        };
        let sim = ClusterSim::new(
            engines(2, 0.1),
            open_budget(),
            Box::new(RoundRobin::new()),
            spec,
        );
        let rep = sim.run(vec![mk_req(0, 0.0, 0, 5), mk_req(1, 0.0, 0, 5)]);
        // Each instance completes steps at 0.1 and 0.2; the 0.3 steps
        // are past the deadline and never applied.
        assert_eq!(rep.cluster.steps, 4);
        assert_eq!(rep.cluster.completed, 0);
        assert!((rep.cluster.span - 0.25).abs() < 1e-12);
    }

    #[test]
    fn single_token_requests_flow_through_the_decode_pool() {
        // Even a gen_len=1 request decodes at the decode pool (the
        // prefill pool never emits user tokens), paying its shipment.
        let sim = ClusterSim::new(
            engines(2, 0.1),
            open_budget(),
            Box::new(RoundRobin::new()),
            disagg_spec(1, 8, 80.0),
        );
        let rep = sim.run(vec![mk_req(0, 0.0, 8, 1)]);
        assert_eq!(rep.cluster.completed, 1);
        assert_eq!(rep.cluster.tokens, 1);
        assert!((rep.kv_shipped_bytes - 8.0).abs() < 1e-12);
        // prefill 0.1 + ship 0.1 + decode 0.1.
        assert!((rep.cluster.e2e.p50 - 0.3).abs() < 1e-9);
        let prefill = rep.pools.iter().find(|p| p.label == "prefill").unwrap();
        assert_eq!(prefill.tokens, 0);
    }

    #[test]
    #[should_panic(expected = "prefill instances")]
    fn disaggregation_needs_a_decode_pool() {
        ClusterSim::new(
            engines(2, 0.1),
            open_budget(),
            Box::new(RoundRobin::new()),
            disagg_spec(2, 8, 80.0),
        );
    }

    #[test]
    #[should_panic(expected = "kv_link_bw")]
    fn nonpositive_link_bandwidth_is_rejected() {
        ClusterSim::new(
            engines(2, 0.1),
            open_budget(),
            Box::new(RoundRobin::new()),
            disagg_spec(1, 8, 0.0),
        );
    }

    #[test]
    fn kv_arrive_for_a_dead_request_is_a_noop() {
        // A KV shipment addressed to a request whose lifecycle already
        // completed must settle the in-transit accounting but never
        // re-admit the request (which would double-count its decode).
        let mut sim = ClusterSim::new(
            engines(2, 0.1),
            open_budget(),
            Box::new(RoundRobin::new()),
            disagg_spec(1, 8, 80.0),
        );
        let mut r = mk_req(0, 0.0, 8, 3);
        r.completed_at = Some(1.0);
        let id = sim.arena.alloc(r);
        sim.in_transit_kv[1] = 100.0;
        sim.kv_arrive(1, id);
        assert!(
            sim.in_transit_kv[1] < 100.0,
            "in-transit accounting must still settle"
        );
        assert_eq!(sim.instances[1].queued_len(), 0);
        assert_eq!(sim.instances[1].active_len(), 0);
    }

    #[test]
    fn shipping_consumes_the_origin_entry() {
        // Regression (DST audit): `ship` used to read the origin
        // side-table without clearing it, leaving a stale entry mapping
        // the retired sub-request to its original forever.
        let mut sim = ClusterSim::new(
            engines(2, 0.1),
            open_budget(),
            Box::new(RoundRobin::new()),
            disagg_spec(1, 8, 80.0),
        );
        let id = sim.arena.alloc(mk_req(0, 0.0, 8, 2));
        let sub = sim.assign(0, id).expect("prefill role mints a sub-request");
        assert_eq!(sim.origin[sub.index()], Some(id));
        let mut q: EventQueue<InstanceEvent> = EventQueue::new();
        sim.ship(sub, &mut q);
        assert_eq!(sim.origin[sub.index()], None, "stale origin entry leaks");
        assert_eq!(q.len(), 1, "exactly one KvArrive scheduled");
    }
}
