//! The cluster simulator: N [`Instance`]s multiplexed on one event
//! calendar, behind a pluggable router, in colocated or disaggregated
//! prefill/decode mode.
//!
//! Every instance is the same state machine the single-instance
//! simulator drives ([`crate::serving::Instance`]): its own batcher
//! (admission queue + KV budget + chunk planner) and step engine. All
//! instances share a single [`EventQueue`](crate::des::EventQueue) of
//! [`InstanceEvent`]s keyed by instance id, so cross-instance causality
//! (arrival routing, KV shipment) is ordered by one total-order clock
//! and seeded runs replay exactly. All request state lives in one
//! [`RequestArena`] owned by the simulator; the calendar, the router,
//! and every batcher move dense [`ReqId`] handles only, so steady-state
//! stepping allocates nothing — no event carries a `Request`, and
//! retirement pushes 4-byte ids, not clones.
//!
//! # Disaggregated semantics
//!
//! In [`ClusterMode::Disaggregated`] the prefill pool runs chunked
//! prefill *only*: a routed request is truncated to a pure-ingestion
//! sub-request; when its last chunk lands, the prompt's KV cache —
//! `context_len * kv_bytes_per_token` bytes — ships to the
//! least-loaded decode instance over the configured link
//! ([`ClusterSpec::kv_link_bw`]), and the transfer latency
//! (`bytes / link_bw`) is paid **before decode admission**. The first
//! output token is then produced by the decode pool's first step, so
//! TTFT honestly includes queueing, prefill chunking, the shipment
//! stall, and decode admission. Decode instances run the paper's
//! decode-only pricing (prefill chunk 0 — their steps never carry
//! prefill tokens); the prefill pool's per-instance reports measure
//! ingestion, not token generation.

use crate::des::EventQueue;
use crate::serving::{
    Batcher, Instance, InstanceEvent, KvBudget, NoopObserver, PreemptionConfig,
    ReqId, Request, RequestArena, SchedAction, ServingReport, SimConfig,
    SimObserver, StepEngine, StepStats,
};

use super::autoscale::{AutoscalePolicy, EngineFactory, InstanceState};
use super::report::{ClusterReport, PoolStats};
use super::router::{argmin, peer_ewma, InstanceLoad, Role, Router};

/// How the cluster's instances divide the request lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterMode {
    /// Every instance serves the full lifecycle (chunked prefill +
    /// decode), like N independent copies of the serving simulator.
    Colocated,
    /// The first `prefill` instances only ingest prompts; the remaining
    /// instances only decode, fed by KV shipped over the interconnect.
    Disaggregated {
        /// Number of dedicated prefill instances (at least 1, and at
        /// least one instance must remain for the decode pool).
        prefill: usize,
    },
}

/// Cluster-wide configuration.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    /// Lifecycle split across instances.
    pub mode: ClusterMode,
    /// Max concurrent sequences per instance.
    pub max_batch: usize,
    /// Prefill chunk tokens per step on prefill-capable instances.
    pub prefill_chunk: u64,
    /// Interconnect bandwidth for shipping KV prefill -> decode,
    /// bytes/s. `f64::INFINITY` models an ideal (free) link — the
    /// paper's decode-only idealization. Production entry points
    /// ([`crate::coordinator::serve_cluster`]) default this to
    /// [`crate::hw::SystemConfig::interconnect_bw`], which aggregates
    /// [`crate::hw::DEFAULT_XFER_BW_PER_CHIP`] over the instance's TP
    /// domain.
    pub kv_link_bw: f64,
    /// Elastic pools: grow on SLO pressure, shrink on sustained idle,
    /// with a warm-up delay (see [`AutoscalePolicy`]). `None` (the
    /// default) keeps the fleet fixed. A cluster with a policy must be
    /// built via [`ClusterSim::with_factory`] so scale-ups can mint
    /// engines.
    pub autoscale: Option<AutoscalePolicy>,
    /// Global step/time limits (steps count across all instances).
    pub sim: SimConfig,
}

impl Default for ClusterSpec {
    fn default() -> Self {
        ClusterSpec {
            mode: ClusterMode::Colocated,
            max_batch: 32,
            prefill_chunk: crate::model::DEFAULT_PREFILL_CHUNK,
            kv_link_bw: crate::hw::DEFAULT_XFER_BW_PER_CHIP,
            autoscale: None,
            sim: SimConfig::default(),
        }
    }
}

/// The cluster simulator. Build with [`ClusterSim::new`], then
/// [`ClusterSim::run`] a workload to get a [`ClusterReport`].
pub struct ClusterSim {
    instances: Vec<Instance<'static>>,
    roles: Vec<Role>,
    /// Membership state per instance (all `Active` in a fixed fleet;
    /// autoscaled instances pass through `Warming` and may end
    /// `Retired`).
    states: Vec<InstanceState>,
    /// Front-door candidate indices, kept sorted by instance id as
    /// instances join (post-warm-up) and leave (retirement), so
    /// id-ordered policies (round-robin, argmin tie-breaks) stay
    /// deterministic across membership changes.
    front_door: Vec<usize>,
    /// Decode-side KV footprint committed to in-flight shipments, per
    /// instance (so placement sees transfers that have not landed yet).
    in_transit_kv: Vec<f64>,
    router: Box<dyn Router>,
    spec: ClusterSpec,
    kv_bytes_per_token: f64,
    /// All request state of the run, addressed by dense [`ReqId`]s.
    arena: RequestArena,
    /// Disaggregated bookkeeping, indexed by arena slot: a prefill
    /// pool's ingestion sub-request maps back to the original request
    /// it was cloned from (which parks in the arena, full `gen_len`
    /// intact, until the sub-request's KV ships to the decode pool).
    /// Replaces the old `HashMap<u64, u64>` of parked generation
    /// lengths with a direct `Vec` lookup.
    origin: Vec<Option<ReqId>>,
    /// Router snapshot buffer, reused across arrivals so routing
    /// allocates nothing in steady state.
    loads_buf: Vec<InstanceLoad>,
    /// KV bytes shipped prefill -> decode so far.
    kv_shipped_bytes: f64,
    /// Sum of shipment latencies, seconds.
    kv_transfer_total: f64,
    /// Number of shipments.
    kv_transfers: u64,
    /// Per-role engine mint for autoscaled spawns (`None` in a fixed
    /// fleet).
    factory: Option<EngineFactory>,
    /// Prototype KV budget cloned into every spawned instance's
    /// batcher (the same budget construction-time instances got).
    kv_proto: KvBudget,
    /// Sim time each instance was provisioned (0 for the initial
    /// fleet) — the start of its billed span.
    spawn_time: Vec<f64>,
    /// Sim time each instance was retired, if it was.
    retired_at: Vec<Option<f64>>,
    /// Sim time since when each instance has been completely idle
    /// (no queued/active work, no step in flight, no inbound KV);
    /// `INFINITY` while occupied. Input to the idle-shrink rule.
    idle_since: Vec<f64>,
    /// Exact count of KV shipments currently in flight toward each
    /// instance. `in_transit_kv` tracks *bytes* for placement and can
    /// accumulate float residue as overlapping transfers settle; the
    /// shrink rule needs the exact "nothing inbound" predicate so a
    /// retired instance can never receive a shipment.
    inbound_shipments: Vec<u32>,
    /// Preemption policy applied to every instance's batcher (existing
    /// and autoscale-spawned). Default disabled: the FIFO-exact path.
    preempt: PreemptionConfig,
    /// Scale actions taken, for the report.
    scale_ups: u64,
    scale_downs: u64,
    /// Sim time of the last scale action (cooldown gate).
    last_scale: f64,
    /// Arrivals / sheds observed since the last scale-up evaluation
    /// (the shed-rate trigger's window).
    arrivals_window: u64,
    shed_window: u64,
}

impl ClusterSim {
    /// Build a cluster of `engines.len()` instances. Every instance gets
    /// a clone of `kv` as its KV budget; in disaggregated mode the first
    /// `prefill` engines form the prefill pool and the rest the decode
    /// pool (decode instances run with prefill chunk 0: prompts arrive
    /// already in KV, the paper's disaggregated assumption).
    ///
    /// Panics on an empty engine list, a non-positive `kv_link_bw`, a
    /// disaggregated split that leaves either pool empty, or a spec
    /// with an autoscale policy (which needs an engine factory — use
    /// [`ClusterSim::with_factory`]).
    pub fn new(
        engines: Vec<Box<dyn StepEngine>>,
        kv: KvBudget,
        router: Box<dyn Router>,
        spec: ClusterSpec,
    ) -> Self {
        assert!(
            spec.autoscale.is_none(),
            "an autoscaling cluster needs an engine factory; \
             build it with ClusterSim::with_factory"
        );
        Self::build(engines, kv, router, spec, None)
    }

    /// [`ClusterSim::new`] plus a per-role [`EngineFactory`] the
    /// autoscaler mints spawned instances' engines from — the hook for
    /// heterogeneous pools (compute-heavy prefill engines,
    /// bandwidth-heavy decode engines). Required when
    /// [`ClusterSpec::autoscale`] is set.
    pub fn with_factory(
        engines: Vec<Box<dyn StepEngine>>,
        kv: KvBudget,
        router: Box<dyn Router>,
        spec: ClusterSpec,
        factory: EngineFactory,
    ) -> Self {
        Self::build(engines, kv, router, spec, Some(factory))
    }

    fn build(
        engines: Vec<Box<dyn StepEngine>>,
        kv: KvBudget,
        router: Box<dyn Router>,
        spec: ClusterSpec,
        factory: Option<EngineFactory>,
    ) -> Self {
        assert!(!engines.is_empty(), "cluster needs at least one instance");
        assert!(spec.kv_link_bw > 0.0, "kv_link_bw must be positive");
        if let Some(policy) = &spec.autoscale {
            policy.validate();
        }
        if let ClusterMode::Disaggregated { prefill } = spec.mode {
            assert!(
                prefill >= 1 && prefill < engines.len(),
                "disaggregated split {prefill}P needs 1..{} prefill instances",
                engines.len()
            );
            assert!(
                spec.prefill_chunk > 0,
                "disaggregated mode needs a nonzero prefill chunk"
            );
        }
        let kv_bytes_per_token = kv.bytes_per_token;
        let n = engines.len();
        let mut roles = Vec::with_capacity(n);
        let instances: Vec<Instance<'static>> = engines
            .into_iter()
            .enumerate()
            .map(|(i, engine)| {
                let role = match spec.mode {
                    ClusterMode::Colocated => Role::Colocated,
                    ClusterMode::Disaggregated { prefill } => {
                        if i < prefill {
                            Role::Prefill
                        } else {
                            Role::Decode
                        }
                    }
                };
                roles.push(role);
                let batcher = match role {
                    Role::Decode => Batcher::new(spec.max_batch, kv.clone()),
                    _ => Batcher::with_prefill(
                        spec.max_batch,
                        kv.clone(),
                        spec.prefill_chunk,
                    ),
                };
                Instance::new(batcher, engine)
            })
            .collect();
        let front_door = roles
            .iter()
            .enumerate()
            .filter(|(_, r)| matches!(r, Role::Colocated | Role::Prefill))
            .map(|(i, _)| i)
            .collect();
        ClusterSim {
            instances,
            roles,
            states: vec![InstanceState::Active; n],
            front_door,
            in_transit_kv: vec![0.0; n],
            router,
            spec,
            kv_bytes_per_token,
            arena: RequestArena::new(),
            origin: Vec::new(),
            loads_buf: Vec::with_capacity(n),
            kv_shipped_bytes: 0.0,
            kv_transfer_total: 0.0,
            kv_transfers: 0,
            factory,
            kv_proto: kv,
            // The initial fleet is provisioned (and idle) from t=0.
            spawn_time: vec![0.0; n],
            retired_at: vec![None; n],
            idle_since: vec![0.0; n],
            inbound_shipments: vec![0; n],
            preempt: PreemptionConfig::default(),
            scale_ups: 0,
            scale_downs: 0,
            last_scale: f64::NEG_INFINITY,
            arrivals_window: 0,
            shed_window: 0,
        }
    }

    /// Set the preemption policy on every instance in the fleet;
    /// instances the autoscaler spawns later inherit it too. Call
    /// before [`ClusterSim::run`] — the default (disabled) keeps the
    /// batchers on the FIFO-exact path.
    pub fn set_preemption(&mut self, cfg: PreemptionConfig) {
        self.preempt = cfg;
        for inst in &mut self.instances {
            inst.set_preemption(cfg);
        }
    }

    /// Human-readable mode string, e.g. `colocated x8` or
    /// `disaggregated 3P+5D`. Counted from the role table, so an
    /// autoscaled run reports the fleet it actually provisioned (its
    /// peak), with an `autoscaled` marker.
    fn mode_label(&self) -> String {
        let prefill =
            self.roles.iter().filter(|&&r| r == Role::Prefill).count();
        let base = match self.spec.mode {
            ClusterMode::Colocated => {
                format!("colocated x{}", self.instances.len())
            }
            ClusterMode::Disaggregated { .. } => format!(
                "disaggregated {}P+{}D",
                prefill,
                self.instances.len() - prefill
            ),
        };
        if self.spec.autoscale.is_some() {
            format!("{base} autoscaled")
        } else {
            base
        }
    }

    /// Refresh the router's load snapshot into the reusable buffer
    /// (`loads_buf`), so per-arrival routing allocates nothing.
    fn refresh_loads(&mut self) {
        self.loads_buf.clear();
        let arena = &self.arena;
        for (i, inst) in self.instances.iter().enumerate() {
            self.loads_buf.push(InstanceLoad {
                role: self.roles[i],
                placeable: self.states[i] == InstanceState::Active,
                queued: inst.queued_len(),
                active: inst.active_len(),
                max_batch: inst.max_batch(),
                // Landed + in-transit footprint. Regression: the
                // snapshot used to omit `in_transit_kv`, so routers
                // saw less decode-pool load than `pick_decode` did for
                // the same instant — an in-flight shipment was
                // invisible to every routing decision.
                outstanding_kv_bytes: inst.outstanding_kv_bytes()
                    + self.in_transit_kv[i],
                outstanding_gen_tokens: inst.outstanding_gen_tokens(),
                pending_prefill_tokens: inst.pending_prefill_tokens(),
                pending_prefill_prompts: inst.pending_prefill_prompts(arena),
                ewma_step_latency: inst.ewma_step(),
                prefill_chunk: inst.prefill_chunk(),
            });
        }
    }

    /// Hand a routed request to instance `i`. On a prefill instance the
    /// request is cloned into a pure-ingestion sub-request (`gen_len`
    /// 1: the batcher retires it the moment its last chunk lands) and
    /// `origin` maps the sub-request's arena slot back to the original,
    /// which parks untouched — full `gen_len` intact — until the KV
    /// ships to the decode pool. Returns the sub-request's id when one
    /// was minted (so observers can track the orig -> sub lineage).
    fn assign(&mut self, i: usize, id: ReqId) -> Option<ReqId> {
        if self.roles[i] == Role::Prefill {
            let mut sub = self.arena[id].clone();
            sub.gen_len = 1;
            let sub_id = self.arena.alloc(sub);
            if self.origin.len() <= sub_id.index() {
                self.origin.resize(sub_id.index() + 1, None);
            }
            self.origin[sub_id.index()] = Some(id);
            self.instances[i].enqueue(sub_id, &self.arena);
            Some(sub_id)
        } else {
            self.instances[i].enqueue(id, &self.arena);
            None
        }
    }

    /// A KV shipment landed at decode instance `i`: settle the
    /// in-transit accounting and admit the original request. A shipment
    /// addressed to a request that already completed its lifecycle (a
    /// stale transfer) still settles the accounting but must be a
    /// no-op for admission — re-enqueueing a dead request would
    /// double-count its generation.
    fn kv_arrive(&mut self, i: usize, id: ReqId) {
        let (bytes, dead) = {
            let r = &self.arena[id];
            (
                (r.context_len + r.gen_len) as f64 * self.kv_bytes_per_token,
                r.completed_at.is_some(),
            )
        };
        self.in_transit_kv[i] = (self.in_transit_kv[i] - bytes).max(0.0);
        self.inbound_shipments[i] = self.inbound_shipments[i].saturating_sub(1);
        if dead {
            return;
        }
        self.instances[i].enqueue(id, &self.arena);
    }

    /// Decode-pool placement for a prefilled request: least committed
    /// KV bytes (landed + in transit), lowest index on ties
    /// (deterministic). The front-door router chooses who prefills; KV
    /// shipment always balances on capacity, the binding constraint of
    /// the decode pool.
    fn pick_decode(&self) -> usize {
        argmin(
            self.instances
                .iter()
                .enumerate()
                .filter(|(i, _)| {
                    self.roles[*i] == Role::Decode
                        && self.states[*i] == InstanceState::Active
                })
                .map(|(i, inst)| {
                    (i, inst.outstanding_kv_bytes() + self.in_transit_kv[i])
                }),
        )
        .map(|(i, _)| i)
        .expect("disaggregated cluster has a decode pool")
    }

    // ---- autoscaling ---------------------------------------------------

    /// Update the per-instance idle spans (autoscaled runs only). An
    /// instance is idle only when it is `Active` with no queued or
    /// active work, no step in flight, and no KV shipment inbound —
    /// warming and retired instances are pinned at `INFINITY` so the
    /// shrink rule never considers them, and an activating instance
    /// starts its idle clock at its warm-up event.
    fn track_idle(&mut self, now: f64) {
        for (i, inst) in self.instances.iter().enumerate() {
            let idle = self.states[i] == InstanceState::Active
                && !inst.busy()
                && inst.queued_len() == 0
                && inst.active_len() == 0
                && self.inbound_shipments[i] == 0;
            if !idle {
                self.idle_since[i] = f64::INFINITY;
            } else if self.idle_since[i].is_infinite() {
                self.idle_since[i] = now;
            }
        }
    }

    /// Pool sizes for `role`: `(provisioned, active)`. Warming
    /// instances count as provisioned capacity (they gate the ceiling)
    /// but not as active (they cannot absorb the shrink floor).
    fn pool_sizes(&self, role: Role) -> (usize, usize) {
        let mut provisioned = 0;
        let mut active = 0;
        for (i, &r) in self.roles.iter().enumerate() {
            if r != role {
                continue;
            }
            match self.states[i] {
                InstanceState::Retired => {}
                InstanceState::Warming => provisioned += 1,
                InstanceState::Active => {
                    provisioned += 1;
                    active += 1;
                }
            }
        }
        (provisioned, active)
    }

    /// Which pool a scale-up grows. Colocated clusters have one pool;
    /// disaggregated clusters grow the pool whose *least-loaded* active
    /// member predicts the larger TTFT contribution (ties break to
    /// prefill, deterministically), falling back to the other pool when
    /// the chosen one is at its ceiling. `None` when nothing can grow.
    /// Reads the load snapshot the caller just refreshed.
    fn pick_grow_role(&self, policy: &AutoscalePolicy) -> Option<Role> {
        let role = match self.spec.mode {
            ClusterMode::Colocated => Role::Colocated,
            ClusterMode::Disaggregated { .. } => {
                let peer = peer_ewma(&self.loads_buf);
                let pool_pressure = |role: Role| {
                    self.loads_buf
                        .iter()
                        .filter(|l| l.placeable && l.role == role)
                        .map(|l| l.predicted_ttft_seeded(0, peer))
                        .fold(f64::INFINITY, f64::min)
                };
                if pool_pressure(Role::Decode) > pool_pressure(Role::Prefill) {
                    Role::Decode
                } else {
                    Role::Prefill
                }
            }
        };
        let (provisioned, _) = self.pool_sizes(role);
        if provisioned < policy.max_instances {
            return Some(role);
        }
        if let ClusterMode::Disaggregated { .. } = self.spec.mode {
            let other = if role == Role::Decode {
                Role::Prefill
            } else {
                Role::Decode
            };
            let (p, _) = self.pool_sizes(other);
            if p < policy.max_instances {
                return Some(other);
            }
        }
        None
    }

    /// Evaluate the scale policy. Every input is observed simulation
    /// state — the shed/arrival window, the load snapshot, per-instance
    /// idle spans, and the DES clock — never the wall clock, so seeded
    /// runs replay their scale decisions bit-identically. At most one
    /// scale action per call, and the cooldown gates action frequency.
    fn maybe_scale<O: SimObserver>(
        &mut self,
        now: f64,
        q: &mut EventQueue<InstanceEvent>,
        obs: &mut O,
    ) {
        let Some(policy) = self.spec.autoscale.clone() else {
            return;
        };
        if now < self.last_scale + policy.cooldown {
            return;
        }
        // Scale up: once a decision window of arrivals has accumulated,
        // trigger on the window's shed fraction or on predicted-TTFT
        // headroom (the *best* front-door instance already predicts
        // past the threshold — pressure visible before anything sheds).
        if self.arrivals_window >= policy.decision_window {
            let shed_frac =
                self.shed_window as f64 / self.arrivals_window as f64;
            self.arrivals_window = 0;
            self.shed_window = 0;
            self.refresh_loads();
            let peer = peer_ewma(&self.loads_buf);
            let best_ttft = self
                .front_door
                .iter()
                .map(|&i| self.loads_buf[i].predicted_ttft_seeded(0, peer))
                .fold(f64::INFINITY, f64::min);
            if shed_frac > policy.shed_rate_up
                || best_ttft > policy.ttft_headroom
            {
                if let Some(role) = self.pick_grow_role(&policy) {
                    self.spawn_instance(now, role, policy.warmup_delay, q, obs);
                    self.last_scale = now;
                    return;
                }
            }
        }
        // Scale down: retire the newest active instance that has sat
        // completely idle past the threshold, honoring the pool floor.
        // Only a fully idle instance is ever retired, so retirement
        // never strands work (the conservation invariant the DST
        // checker audits across pool-size changes).
        for i in (0..self.instances.len()).rev() {
            if self.states[i] != InstanceState::Active {
                continue;
            }
            if !(self.idle_since[i].is_finite()
                && now - self.idle_since[i] >= policy.idle_shrink_after)
            {
                continue;
            }
            let (_, active) = self.pool_sizes(self.roles[i]);
            if active <= policy.min_instances {
                continue;
            }
            self.states[i] = InstanceState::Retired;
            self.retired_at[i] = Some(now);
            self.idle_since[i] = f64::INFINITY;
            self.front_door.retain(|&j| j != i);
            self.scale_downs += 1;
            self.last_scale = now;
            obs.on_scale_down(now, i);
            return;
        }
    }

    /// Provision one instance of `role`: mint an engine from the
    /// factory, push it in [`InstanceState::Warming`] (no placement, no
    /// work), and schedule its [`InstanceEvent::WarmupDone`] on the
    /// shared calendar `warmup` seconds out.
    fn spawn_instance<O: SimObserver>(
        &mut self,
        now: f64,
        role: Role,
        warmup: f64,
        q: &mut EventQueue<InstanceEvent>,
        obs: &mut O,
    ) {
        let engine = (self
            .factory
            .as_mut()
            .expect("autoscaling cluster was built without an engine factory"))(
            role,
        );
        let mut batcher = match role {
            Role::Decode => {
                Batcher::new(self.spec.max_batch, self.kv_proto.clone())
            }
            _ => Batcher::with_prefill(
                self.spec.max_batch,
                self.kv_proto.clone(),
                self.spec.prefill_chunk,
            ),
        };
        batcher.set_preemption(self.preempt);
        self.instances.push(Instance::new(batcher, engine));
        self.roles.push(role);
        self.states.push(InstanceState::Warming);
        self.in_transit_kv.push(0.0);
        self.inbound_shipments.push(0);
        self.spawn_time.push(now);
        self.retired_at.push(None);
        self.idle_since.push(f64::INFINITY);
        self.scale_ups += 1;
        let i = self.instances.len() - 1;
        obs.on_scale_up(now, i);
        q.schedule_in(warmup, InstanceEvent::WarmupDone(i));
    }

    /// Run the workload to completion (or a configured limit).
    pub fn run(self, workload: Vec<Request>) -> ClusterReport {
        // The no-op observer monomorphizes every hook away, so this is
        // exactly the pre-observer event loop.
        self.run_with(workload, &mut NoopObserver)
    }

    /// [`ClusterSim::run`] with a [`SimObserver`] watching every applied
    /// event, routing decision, and retirement — the deterministic
    /// simulation-testing harness ([`crate::dst`]) hooks its invariant
    /// checker in here.
    pub fn run_with<O: SimObserver>(
        mut self,
        workload: Vec<Request>,
        obs: &mut O,
    ) -> ClusterReport {
        let mut q: EventQueue<InstanceEvent> = EventQueue::new();
        let offered = workload.len() as u64;
        self.arena = RequestArena::with_capacity(workload.len());
        for r in workload {
            let at = r.arrival;
            let id = self.arena.alloc(r);
            q.schedule_at(at, InstanceEvent::Arrival(id));
        }

        // Full request lifecycles (prefill + decode merged) for the
        // cluster-level SLO report, as arena handles.
        let mut finished: Vec<ReqId> = Vec::new();
        // Reused copy of each step's retirements, so we can route them
        // (ship / finish) without holding the batcher's buffer borrow.
        let mut retired_scratch: Vec<ReqId> = Vec::new();
        let mut shed: u64 = 0;
        let mut steps_total: u64 = 0;
        // Reusable buffer for preempt/restore actions logged by each
        // batcher during admission; drained after every kick.
        let mut sched: Vec<(ReqId, SchedAction)> = Vec::new();
        let mut deadline_hit = false;

        while let Some(t) = q.peek_time() {
            if t > self.spec.sim.max_time {
                deadline_hit = true;
                break; // clamp at the boundary, like the single sim
            }
            let (now, ev) = q.next().expect("peeked event is still queued");
            match ev {
                InstanceEvent::Arrival(id) => {
                    self.refresh_loads();
                    let pick = {
                        let r = &self.arena[id];
                        self.router.route(r, &self.front_door, &self.loads_buf)
                    };
                    self.arrivals_window += 1;
                    match pick {
                        Some(i) => {
                            obs.on_route(now, id, i);
                            if let Some(sub) = self.assign(i, id) {
                                obs.on_sub_request(now, id, sub);
                            }
                        }
                        None => {
                            obs.on_shed(now, id);
                            self.shed_window += 1;
                            shed += 1;
                        }
                    }
                }
                InstanceEvent::StepDone(i) => {
                    let retired = self.instances[i].step_done(now, &mut self.arena);
                    retired_scratch.clear();
                    retired_scratch.extend_from_slice(retired);
                    steps_total += 1;
                    let lifecycle_done = self.roles[i] != Role::Prefill;
                    for &id in &retired_scratch {
                        obs.on_retire(now, i, id, lifecycle_done, &self.arena);
                        if lifecycle_done {
                            finished.push(id);
                        } else {
                            self.ship(id, &mut q);
                        }
                    }
                }
                InstanceEvent::KvArrive(i, id) => self.kv_arrive(i, id),
                InstanceEvent::WarmupDone(i) => {
                    if self.states[i] == InstanceState::Warming {
                        self.states[i] = InstanceState::Active;
                        if matches!(
                            self.roles[i],
                            Role::Colocated | Role::Prefill
                        ) {
                            // Keep the front door sorted by id so
                            // id-ordered policies stay deterministic.
                            if let Err(pos) = self.front_door.binary_search(&i)
                            {
                                self.front_door.insert(pos, i);
                            }
                        }
                        obs.on_warmup_done(now, i);
                    }
                }
            }
            if steps_total >= self.spec.sim.max_steps {
                break;
            }
            for (i, inst) in self.instances.iter_mut().enumerate() {
                // Warming instances hold no work by construction and
                // retired ones drained before retirement; skipping
                // them keeps the no-op kick off the scaled fleet's
                // hot path.
                if self.states[i] != InstanceState::Active {
                    continue;
                }
                if let Some(dt) = inst.kick(now, &mut self.arena) {
                    q.schedule_in(dt, InstanceEvent::StepDone(i));
                }
                inst.drain_sched_log(&mut sched);
                for &(id, act) in &sched {
                    match act {
                        SchedAction::Preempt => obs.on_preempt(now, i, id),
                        SchedAction::Restore => obs.on_restore(now, i, id),
                    }
                }
            }
            if self.spec.autoscale.is_some() {
                self.track_idle(now);
                self.maybe_scale(now, &mut q, obs);
            }
            obs.post_event(now, &ev, &self.instances, &self.arena);
        }

        let events = q.fired();
        let end_time = if deadline_hit {
            self.spec.sim.max_time
        } else {
            q.now().min(self.spec.sim.max_time)
        };
        obs.on_done(end_time, &self.instances, &self.arena);
        self.into_report(finished, offered, shed, end_time, events)
    }

    /// A prompt finished ingesting on a prefill instance: ship its KV
    /// cache (`context_len * kv_bytes_per_token` bytes) to the least-
    /// loaded decode instance; the transfer latency lands *before*
    /// decode admission. The original request (parked in the arena with
    /// its full `gen_len` and untouched token state) inherits the
    /// sub-request's prefill progress and admission stamp, so the
    /// decode pool produces every output token (including the first)
    /// and the lifecycle metrics see the stall. `admitted_at` survives
    /// the hop (the decode batcher keeps an existing stamp), so queue
    /// delay and residence stay lifecycle quantities.
    fn ship(&mut self, sub: ReqId, q: &mut EventQueue<InstanceEvent>) {
        // `take`, not a copy: the sub-request is fully retired once its
        // KV ships, so its side-table entry must die with it. Leaving
        // the entry behind would let a replayed retirement ship (and
        // double-count) the original a second time.
        let orig = self.origin[sub.index()]
            .take()
            .expect("prefill pool retired a request it never ingested");
        let (ctx, prefilled, scheduled, admitted) = {
            let s = &self.arena[sub];
            (s.context_len, s.prefilled, s.scheduled_prefill, s.admitted_at)
        };
        let full_gen = {
            let r = &mut self.arena[orig];
            r.prefilled = prefilled;
            r.scheduled_prefill = scheduled;
            r.admitted_at = admitted;
            r.gen_len
        };
        let ship_bytes = ctx as f64 * self.kv_bytes_per_token;
        let dest = self.pick_decode();
        self.in_transit_kv[dest] +=
            (ctx + full_gen) as f64 * self.kv_bytes_per_token;
        self.inbound_shipments[dest] += 1;
        let dt = ship_bytes / self.spec.kv_link_bw;
        self.kv_shipped_bytes += ship_bytes;
        self.kv_transfer_total += dt;
        self.kv_transfers += 1;
        q.schedule_in(dt, InstanceEvent::KvArrive(dest, orig));
    }

    /// Assemble the cluster report: per-instance reports, the merged
    /// lifecycle report (percentiles over the pooled raw samples), and
    /// per-pool utilization.
    fn into_report(
        self,
        finished: Vec<ReqId>,
        offered: u64,
        shed: u64,
        end_time: f64,
        events: u64,
    ) -> ClusterReport {
        let router_name = self.router.name();
        let mode = self.mode_label();
        let mut agg = StepStats { end_time, ..Default::default() };
        let mut per_instance: Vec<ServingReport> = Vec::new();
        for (i, inst) in self.instances.iter().enumerate() {
            let st = inst.stats(end_time);
            agg.steps += st.steps;
            agg.batch_time_integral += st.batch_time_integral;
            agg.busy_time += st.busy_time;
            agg.prefill_tokens += st.prefill_tokens;
            agg.preemptions += st.preemptions;
            agg.restores += st.restores;
            let name =
                format!("i{i}:{}:{}", self.roles[i].tag(), inst.engine_name());
            per_instance.push(inst.report(name, end_time, &self.arena));
        }
        let cluster = ServingReport::from_refs(
            format!("{router_name} / {mode}"),
            finished.iter().map(|&id| &self.arena[id]),
            &agg,
        );
        let pools = self.pool_stats(end_time);
        // Billed capacity: every instance costs from the moment it is
        // provisioned (warm-up time is paid for, not free) until it is
        // retired or the run ends. The fixed-vs-autoscaled experiment
        // compares fleets on exactly this quantity.
        let instance_seconds: f64 = self
            .spawn_time
            .iter()
            .zip(&self.retired_at)
            .map(|(&spawned, &retired)| {
                (retired.unwrap_or(end_time) - spawned).max(0.0)
            })
            .sum();

        ClusterReport {
            router: router_name,
            mode,
            offered,
            shed,
            events,
            cluster,
            per_instance,
            pools,
            kv_shipped_bytes: self.kv_shipped_bytes,
            kv_transfer_mean: if self.kv_transfers > 0 {
                self.kv_transfer_total / self.kv_transfers as f64
            } else {
                0.0
            },
            instance_seconds,
            scale_ups: self.scale_ups,
            scale_downs: self.scale_downs,
        }
    }

    /// Per-pool utilization, grouped by role. Pool token counts are
    /// output tokens generated *at* the pool: the decode pool produces
    /// every output token of a disaggregated request, and the prefill
    /// pool none (its sub-requests are pure ingestion), so on a drained
    /// run the pool sums equal cluster tokens in both modes.
    fn pool_stats(&self, end_time: f64) -> Vec<PoolStats> {
        let mut pools: Vec<PoolStats> = Vec::new();
        for role in [Role::Colocated, Role::Prefill, Role::Decode] {
            let mut n = 0usize;
            let mut steps = 0u64;
            let mut busy = 0.0f64;
            let mut lane_seconds = 0.0f64;
            let mut tokens = 0u64;
            for (inst, _) in self
                .instances
                .iter()
                .zip(&self.roles)
                .filter(|(_, &r)| r == role)
            {
                n += 1;
                let st = inst.stats(end_time);
                steps += st.steps;
                busy += st.busy_time;
                lane_seconds += st.batch_time_integral;
                if role != Role::Prefill {
                    tokens += inst
                        .finished()
                        .iter()
                        .map(|&id| self.arena[id].generated)
                        .sum::<u64>();
                }
            }
            if n == 0 {
                continue;
            }
            pools.push(PoolStats {
                label: role.tag().to_string(),
                instances: n,
                steps,
                busy_frac: busy / (n as f64 * end_time.max(1e-12)),
                mean_batch: if busy > 0.0 { lane_seconds / busy } else { 0.0 },
                tokens,
            });
        }
        pools
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::router::{LeastOutstandingTokens, RoundRobin};
    use crate::serving::testutil::{mk_req, open_budget, FixedEngine};

    fn engines(n: usize, dt: f64) -> Vec<Box<dyn StepEngine>> {
        (0..n)
            .map(|_| Box::new(FixedEngine(dt)) as Box<dyn StepEngine>)
            .collect()
    }

    fn colo_spec(max_batch: usize, chunk: u64) -> ClusterSpec {
        ClusterSpec {
            mode: ClusterMode::Colocated,
            max_batch,
            prefill_chunk: chunk,
            ..Default::default()
        }
    }

    fn disagg_spec(prefill: usize, chunk: u64, link_bw: f64) -> ClusterSpec {
        ClusterSpec {
            mode: ClusterMode::Disaggregated { prefill },
            max_batch: 4,
            prefill_chunk: chunk,
            kv_link_bw: link_bw,
            ..Default::default()
        }
    }

    #[test]
    fn colocated_round_robin_spreads_and_completes() {
        let sim = ClusterSim::new(
            engines(2, 0.01),
            open_budget(),
            Box::new(RoundRobin::new()),
            colo_spec(4, 0),
        );
        let wl: Vec<Request> =
            (0..10).map(|i| mk_req(i, 0.001 * i as f64, 8, 4)).collect();
        let rep = sim.run(wl);
        assert_eq!(rep.offered, 10);
        assert_eq!(rep.shed, 0);
        assert_eq!(rep.cluster.completed, 10);
        assert_eq!(rep.cluster.tokens, 40);
        // Round-robin: both instances served requests.
        assert_eq!(rep.per_instance.len(), 2);
        assert!(rep.per_instance.iter().all(|r| r.completed == 5));
        assert_eq!(rep.pools.len(), 1);
        assert_eq!(rep.pools[0].label, "colo");
        assert_eq!(rep.pools[0].tokens, 40);
        assert_eq!(rep.kv_shipped_bytes, 0.0);
    }

    #[test]
    fn four_instances_reach_4x_aggregate_throughput() {
        // The scaling acceptance pin, deterministic under a
        // fixed-latency engine: 256 identical decode-only requests
        // (gen 32) saturate the cluster from t=0. Round-robin splits
        // them 64/64/64/64; the only scale-out losses are each
        // instance's cold-start step and its drain tail, giving a
        // 3.99x aggregate-throughput ratio — over the >= 3.5x
        // acceptance bar with margin. Spans are exact step counts
        // times 0.01 s (10.25 s vs 2.57 s), pinned below.
        let run = |n: usize| {
            let sim = ClusterSim::new(
                engines(n, 0.01),
                open_budget(),
                Box::new(RoundRobin::new()),
                colo_spec(8, 0),
            );
            let wl: Vec<Request> =
                (0..256).map(|i| mk_req(i, 0.0, 8, 32)).collect();
            sim.run(wl)
        };
        let one = run(1);
        let four = run(4);
        assert_eq!(one.cluster.completed, 256);
        assert_eq!(four.cluster.completed, 256);
        assert_eq!(one.cluster.tokens, 256 * 32);
        assert_eq!(four.cluster.tokens, 256 * 32);
        assert!((one.cluster.span - 10.25).abs() < 1e-6, "{}", one.cluster.span);
        assert!((four.cluster.span - 2.57).abs() < 1e-6, "{}", four.cluster.span);
        assert!(
            four.cluster.stps >= one.cluster.stps * 3.5,
            "4 instances: {} vs 1: {}",
            four.cluster.stps,
            one.cluster.stps
        );
    }

    #[test]
    fn disaggregated_lifecycle_ships_kv_and_prices_the_transfer() {
        // 1 prefill + 1 decode instance, 0.1 s steps, ctx 8 / gen 3,
        // chunk 8, transfer of 8 bytes at 80 B/s = 0.1 s.
        let sim = ClusterSim::new(
            engines(2, 0.1),
            open_budget(),
            Box::new(RoundRobin::new()),
            disagg_spec(1, 8, 80.0),
        );
        let rep = sim.run(vec![mk_req(0, 0.0, 8, 3)]);
        assert_eq!(rep.cluster.completed, 1);
        assert_eq!(rep.cluster.tokens, 3);
        // Prefill chunk lands at 0.1; KV ships until 0.2; decode steps
        // at 0.3 / 0.4 / 0.5. The first token comes from the decode
        // pool, so TTFT includes the shipment stall.
        assert!((rep.cluster.ttft.p50 - 0.3).abs() < 1e-9, "{}", rep.cluster.ttft.p50);
        assert!((rep.cluster.e2e.p50 - 0.5).abs() < 1e-9, "{}", rep.cluster.e2e.p50);
        // TPOT is pure decode cadence: (0.5 - 0.3) / 2.
        assert!((rep.cluster.tpot.p50 - 0.1).abs() < 1e-9);
        // Admission happened at t=0 on the prefill instance and the
        // stamp survives the hop: queue delay stays zero, residence
        // spans the whole lifecycle (3 tokens / 0.5 s).
        assert!(rep.cluster.queue_delay_mean.abs() < 1e-9);
        assert!((rep.cluster.utps_mean - 3.0 / 0.5).abs() < 1e-9);
        assert!((rep.kv_shipped_bytes - 8.0).abs() < 1e-12);
        assert!((rep.kv_transfer_mean - 0.1).abs() < 1e-12);
        // Pool accounting: ingestion at the prefill pool (no output
        // tokens), all three tokens at the decode pool.
        let prefill = rep.pools.iter().find(|p| p.label == "prefill").unwrap();
        let decode = rep.pools.iter().find(|p| p.label == "decode").unwrap();
        assert_eq!(prefill.tokens, 0);
        assert_eq!(decode.tokens, 3);
        assert_eq!(rep.cluster.prefill_tokens, 8);
    }

    #[test]
    fn finite_link_strictly_inflates_ttft_over_ideal() {
        // The disaggregation acceptance pin: with a finite KV link the
        // transfer stall must push TTFT strictly past the
        // infinite-bandwidth case, and decode-pool steps must carry no
        // prefill chunks.
        let run = |link_bw: f64| {
            let sim = ClusterSim::new(
                engines(2, 0.1),
                open_budget(),
                Box::new(RoundRobin::new()),
                disagg_spec(1, 8, link_bw),
            );
            sim.run(vec![mk_req(0, 0.0, 8, 3), mk_req(1, 0.05, 8, 2)])
        };
        let ideal = run(f64::INFINITY);
        let finite = run(80.0);
        assert_eq!(ideal.cluster.completed, 2);
        assert_eq!(finite.cluster.completed, 2);
        assert!(
            finite.cluster.ttft.mean > ideal.cluster.ttft.mean,
            "finite-link TTFT {} must exceed ideal-link {}",
            finite.cluster.ttft.mean,
            ideal.cluster.ttft.mean
        );
        assert!(finite.cluster.e2e.p99 > ideal.cluster.e2e.p99);
        assert_eq!(ideal.kv_transfer_mean, 0.0);
        assert!(finite.kv_transfer_mean > 0.0);
        // Decode instances run the paper's decode-only pricing: zero
        // prefill tokens ever processed there.
        for rep in [&ideal, &finite] {
            for inst in &rep.per_instance {
                if inst.engine.contains("decode") {
                    assert_eq!(inst.prefill_tokens, 0);
                }
            }
            let prefill_pool_tokens: u64 = rep
                .per_instance
                .iter()
                .filter(|r| r.engine.contains(":prefill:"))
                .map(|r| r.prefill_tokens)
                .sum();
            assert_eq!(prefill_pool_tokens, rep.cluster.prefill_tokens);
        }
    }

    #[test]
    fn decode_pool_balances_on_committed_kv() {
        // 1 prefill + 2 decode instances; two long-decode requests must
        // land on different decode instances even though the second KV
        // shipment departs while the first is still in transit.
        let sim = ClusterSim::new(
            engines(3, 0.1),
            open_budget(),
            Box::new(LeastOutstandingTokens),
            disagg_spec(1, 16, 160.0),
        );
        let rep = sim.run(vec![mk_req(0, 0.0, 16, 40), mk_req(1, 0.0, 16, 40)]);
        assert_eq!(rep.cluster.completed, 2);
        let decode_reps: Vec<_> = rep
            .per_instance
            .iter()
            .filter(|r| r.engine.contains("decode"))
            .collect();
        assert_eq!(decode_reps.len(), 2);
        assert!(
            decode_reps.iter().all(|r| r.completed == 1),
            "KV shipment must spread across the decode pool"
        );
    }

    #[test]
    fn global_step_limit_is_exact() {
        let spec = ClusterSpec {
            sim: SimConfig { max_steps: 7, ..Default::default() },
            ..colo_spec(2, 0)
        };
        let sim = ClusterSim::new(
            engines(2, 0.01),
            open_budget(),
            Box::new(RoundRobin::new()),
            spec,
        );
        let wl: Vec<Request> = (0..50).map(|i| mk_req(i, 0.0, 8, 20)).collect();
        let rep = sim.run(wl);
        let steps: u64 = rep.pools.iter().map(|p| p.steps).sum();
        assert_eq!(steps, 7);
        assert_eq!(rep.cluster.steps, 7);
    }

    #[test]
    fn max_time_clamps_the_cluster_at_the_boundary() {
        let spec = ClusterSpec {
            sim: SimConfig { max_time: 0.25, ..Default::default() },
            ..colo_spec(4, 0)
        };
        let sim = ClusterSim::new(
            engines(2, 0.1),
            open_budget(),
            Box::new(RoundRobin::new()),
            spec,
        );
        let rep = sim.run(vec![mk_req(0, 0.0, 0, 5), mk_req(1, 0.0, 0, 5)]);
        // Each instance completes steps at 0.1 and 0.2; the 0.3 steps
        // are past the deadline and never applied.
        assert_eq!(rep.cluster.steps, 4);
        assert_eq!(rep.cluster.completed, 0);
        assert!((rep.cluster.span - 0.25).abs() < 1e-12);
    }

    #[test]
    fn single_token_requests_flow_through_the_decode_pool() {
        // Even a gen_len=1 request decodes at the decode pool (the
        // prefill pool never emits user tokens), paying its shipment.
        let sim = ClusterSim::new(
            engines(2, 0.1),
            open_budget(),
            Box::new(RoundRobin::new()),
            disagg_spec(1, 8, 80.0),
        );
        let rep = sim.run(vec![mk_req(0, 0.0, 8, 1)]);
        assert_eq!(rep.cluster.completed, 1);
        assert_eq!(rep.cluster.tokens, 1);
        assert!((rep.kv_shipped_bytes - 8.0).abs() < 1e-12);
        // prefill 0.1 + ship 0.1 + decode 0.1.
        assert!((rep.cluster.e2e.p50 - 0.3).abs() < 1e-9);
        let prefill = rep.pools.iter().find(|p| p.label == "prefill").unwrap();
        assert_eq!(prefill.tokens, 0);
    }

    #[test]
    #[should_panic(expected = "prefill instances")]
    fn disaggregation_needs_a_decode_pool() {
        ClusterSim::new(
            engines(2, 0.1),
            open_budget(),
            Box::new(RoundRobin::new()),
            disagg_spec(2, 8, 80.0),
        );
    }

    #[test]
    #[should_panic(expected = "kv_link_bw")]
    fn nonpositive_link_bandwidth_is_rejected() {
        ClusterSim::new(
            engines(2, 0.1),
            open_budget(),
            Box::new(RoundRobin::new()),
            disagg_spec(1, 8, 0.0),
        );
    }

    #[test]
    fn kv_arrive_for_a_dead_request_is_a_noop() {
        // A KV shipment addressed to a request whose lifecycle already
        // completed must settle the in-transit accounting but never
        // re-admit the request (which would double-count its decode).
        let mut sim = ClusterSim::new(
            engines(2, 0.1),
            open_budget(),
            Box::new(RoundRobin::new()),
            disagg_spec(1, 8, 80.0),
        );
        let mut r = mk_req(0, 0.0, 8, 3);
        r.completed_at = Some(1.0);
        let id = sim.arena.alloc(r);
        sim.in_transit_kv[1] = 100.0;
        sim.kv_arrive(1, id);
        assert!(
            sim.in_transit_kv[1] < 100.0,
            "in-transit accounting must still settle"
        );
        assert_eq!(sim.instances[1].queued_len(), 0);
        assert_eq!(sim.instances[1].active_len(), 0);
    }

    #[test]
    fn shipping_consumes_the_origin_entry() {
        // Regression (DST audit): `ship` used to read the origin
        // side-table without clearing it, leaving a stale entry mapping
        // the retired sub-request to its original forever.
        let mut sim = ClusterSim::new(
            engines(2, 0.1),
            open_budget(),
            Box::new(RoundRobin::new()),
            disagg_spec(1, 8, 80.0),
        );
        let id = sim.arena.alloc(mk_req(0, 0.0, 8, 2));
        let sub = sim.assign(0, id).expect("prefill role mints a sub-request");
        assert_eq!(sim.origin[sub.index()], Some(id));
        let mut q: EventQueue<InstanceEvent> = EventQueue::new();
        sim.ship(sub, &mut q);
        assert_eq!(sim.origin[sub.index()], None, "stale origin entry leaks");
        assert_eq!(q.len(), 1, "exactly one KvArrive scheduled");
    }

    /// Test-only router: least committed KV bytes, lowest index on
    /// ties. Exists to observe exactly what the load snapshot reports.
    #[derive(Debug)]
    struct LeastKv;

    impl Router for LeastKv {
        fn route(
            &mut self,
            _r: &Request,
            candidates: &[usize],
            loads: &[InstanceLoad],
        ) -> Option<usize> {
            argmin(
                candidates
                    .iter()
                    .map(|&i| (i, loads[i].outstanding_kv_bytes)),
            )
            .map(|(i, _)| i)
        }

        fn name(&self) -> String {
            "least-kv".into()
        }
    }

    #[test]
    fn in_flight_shipments_change_routing_decisions() {
        // Regression: `refresh_loads` used to report only *landed* KV
        // (`inst.outstanding_kv_bytes()`), omitting `in_transit_kv` —
        // so a router balancing on KV footprint couldn't see bytes
        // already committed to an instance by an in-flight shipment,
        // and kept routing toward the instance a transfer was about to
        // fill. With the fix, the same snapshot `pick_decode` uses
        // reaches the router.
        let mut sim = ClusterSim::new(
            engines(2, 0.1),
            open_budget(),
            Box::new(LeastKv),
            colo_spec(4, 0),
        );
        sim.in_transit_kv[0] = 64.0;
        sim.refresh_loads();
        assert_eq!(sim.loads_buf[0].outstanding_kv_bytes, 64.0);
        assert_eq!(sim.loads_buf[1].outstanding_kv_bytes, 0.0);
        let r = mk_req(0, 0.0, 8, 2);
        let pick = sim.router.route(&r, &[0, 1], &sim.loads_buf);
        // Old snapshots showed 0 KV on both instances and the tie broke
        // to instance 0 — straight into the in-flight shipment.
        assert_eq!(pick, Some(1));
    }

    // ---- autoscaling ---------------------------------------------------

    /// Records every scale-lifecycle hook with its firing time.
    #[derive(Default)]
    struct ScaleLog {
        routed: Vec<(f64, usize)>,
        scaled_up: Vec<(f64, usize)>,
        warmed: Vec<(f64, usize)>,
        scaled_down: Vec<(f64, usize)>,
    }

    impl SimObserver for ScaleLog {
        fn on_route(&mut self, now: f64, _id: ReqId, instance: usize) {
            self.routed.push((now, instance));
        }
        fn on_scale_up(&mut self, now: f64, instance: usize) {
            self.scaled_up.push((now, instance));
        }
        fn on_warmup_done(&mut self, now: f64, instance: usize) {
            self.warmed.push((now, instance));
        }
        fn on_scale_down(&mut self, now: f64, instance: usize) {
            self.scaled_down.push((now, instance));
        }
    }

    fn fixed_factory(dt: f64) -> EngineFactory {
        Box::new(move |_role| Box::new(FixedEngine(dt)) as Box<dyn StepEngine>)
    }

    #[test]
    #[should_panic(expected = "engine factory")]
    fn autoscaling_spec_requires_a_factory() {
        let spec = ClusterSpec {
            autoscale: Some(AutoscalePolicy::default()),
            ..colo_spec(4, 0)
        };
        ClusterSim::new(
            engines(1, 0.1),
            open_budget(),
            Box::new(RoundRobin::new()),
            spec,
        );
    }

    #[test]
    fn overload_spawns_an_instance_that_serves_only_after_warmup() {
        // One overloaded instance (max_batch 1), arrivals every 50 ms.
        // The predicted-TTFT trigger fires once the decision window
        // fills; the spawned instance warms for 0.5 s and must receive
        // zero requests before its warm-up event, then share the load.
        let policy = AutoscalePolicy {
            decision_window: 4,
            ttft_headroom: 0.05,
            warmup_delay: 0.5,
            cooldown: 10.0,
            idle_shrink_after: 1000.0,
            max_instances: 2,
            ..Default::default()
        };
        let spec =
            ClusterSpec { autoscale: Some(policy), ..colo_spec(1, 0) };
        let sim = ClusterSim::with_factory(
            engines(1, 0.1),
            open_budget(),
            Box::new(RoundRobin::new()),
            spec,
            fixed_factory(0.1),
        );
        let wl: Vec<Request> =
            (0..40).map(|i| mk_req(i, 0.05 * i as f64, 0, 5)).collect();
        let mut log = ScaleLog::default();
        let rep = sim.run_with(wl, &mut log);
        assert_eq!(rep.cluster.completed, 40);
        assert_eq!(rep.scale_ups, 1, "ceiling caps the fleet at 2");
        assert_eq!(rep.scale_downs, 0);
        assert_eq!(rep.per_instance.len(), 2);
        assert!(rep.mode.contains("autoscaled"), "{}", rep.mode);
        let (spawned_at, spawned) = log.scaled_up[0];
        let (warm_at, warmed) = log.warmed[0];
        assert_eq!(spawned, 1);
        assert_eq!(warmed, 1);
        assert!((warm_at - (spawned_at + 0.5)).abs() < 1e-9);
        // Warming instances take no placement...
        assert!(
            log.routed.iter().all(|&(t, i)| i != 1 || t >= warm_at),
            "routed to instance 1 before its warm-up completed"
        );
        // ...but serve once active.
        assert!(log.routed.iter().any(|&(_, i)| i == 1));
        // Billed from spawn (warm-up included), not from t=0.
        assert!(rep.instance_seconds > rep.cluster.span);
        assert!(rep.instance_seconds < 2.0 * rep.cluster.span);
    }

    #[test]
    fn warmup_event_at_exactly_max_time_still_applies() {
        // Exact binary arithmetic throughout (steps of 0.125 s): one
        // request at t=0 seeds the EWMA, a burst at t=0.5 fills the
        // decision window at its third arrival, so the spawn lands at
        // exactly 0.5 and the warm-up event at exactly 1.0 == max_time.
        // The deadline clamp is peek-first (`t > max_time` breaks), so
        // the boundary event must apply — a `>=` off-by-one would drop
        // the activation and this test's warm log would be empty.
        let policy = AutoscalePolicy {
            decision_window: 4,
            ttft_headroom: 0.05,
            warmup_delay: 0.5,
            cooldown: 10.0,
            idle_shrink_after: 1000.0,
            max_instances: 2,
            ..Default::default()
        };
        let spec = ClusterSpec {
            autoscale: Some(policy),
            sim: SimConfig { max_time: 1.0, ..Default::default() },
            ..colo_spec(1, 0)
        };
        let sim = ClusterSim::with_factory(
            engines(1, 0.125),
            open_budget(),
            Box::new(RoundRobin::new()),
            spec,
            fixed_factory(0.125),
        );
        let mut wl = vec![mk_req(0, 0.0, 0, 1)];
        wl.extend((1..=4).map(|i| mk_req(i, 0.5, 0, 20)));
        let mut log = ScaleLog::default();
        let rep = sim.run_with(wl, &mut log);
        assert_eq!(log.scaled_up.len(), 1);
        assert!((log.scaled_up[0].0 - 0.5).abs() < 1e-12);
        assert_eq!(log.warmed.len(), 1, "boundary warm-up event dropped");
        assert!((log.warmed[0].0 - 1.0).abs() < 1e-12);
        assert_eq!(log.warmed[0].1, 1);
        assert!((rep.cluster.span - 1.0).abs() < 1e-12);
        // Billing: instance 0 the whole second, instance 1 from 0.5.
        assert!((rep.instance_seconds - 1.5).abs() < 1e-9);
    }

    #[test]
    fn cluster_preemption_evicts_restores_and_reports_counters() {
        use crate::serving::testutil::budget;

        // One instance whose KV (55 tokens) is hogged by a long class-0
        // request; the class-1 arrival must evict it, finish first, and
        // the evicted request must still complete. Both counters land
        // in the merged cluster report.
        let mut sim = ClusterSim::new(
            engines(1, 0.05),
            budget(55),
            Box::new(RoundRobin::new()),
            colo_spec(4, 0),
        );
        sim.set_preemption(PreemptionConfig {
            enabled: true,
            evict_cost: 0.01,
            restore_cost: 0.01,
        });
        let lo = mk_req(0, 0.0, 10, 40); // 50 KV tokens
        let mut hi = mk_req(1, 0.1, 10, 5); // 15 KV tokens
        hi.priority = 1;
        let rep = sim.run(vec![lo, hi]);
        assert_eq!(rep.cluster.completed, 2);
        assert_eq!(rep.cluster.tokens, 45);
        assert_eq!(rep.cluster.preemptions, 1);
        assert_eq!(rep.cluster.restores, 1);
        assert_eq!(rep.per_instance[0].preemptions, 1);
        assert_eq!(rep.per_instance[0].restores, 1);
    }

    #[test]
    fn sustained_idle_shrinks_to_the_pool_floor_and_no_further() {
        // Two instances, one early request: the idle peer retires once
        // its idle span crosses the threshold, but the last instance
        // never does (min_instances floor) — it must still be there to
        // serve the late arrival.
        let policy = AutoscalePolicy {
            idle_shrink_after: 0.2,
            cooldown: 0.0,
            min_instances: 1,
            ..Default::default()
        };
        let spec =
            ClusterSpec { autoscale: Some(policy), ..colo_spec(1, 0) };
        let sim = ClusterSim::with_factory(
            engines(2, 0.1),
            open_budget(),
            Box::new(RoundRobin::new()),
            spec,
            fixed_factory(0.1),
        );
        let wl = vec![mk_req(0, 0.0, 0, 2), mk_req(1, 2.0, 0, 2)];
        let mut log = ScaleLog::default();
        let rep = sim.run_with(wl, &mut log);
        assert_eq!(rep.cluster.completed, 2);
        assert_eq!(rep.scale_ups, 0);
        assert_eq!(rep.scale_downs, 1);
        // Instance 1 is idle from t=0; the r0 step event at t=0.2
        // crosses the threshold and retires it. Instance 0 survives on
        // the floor despite idling from 0.2 to 2.0.
        assert_eq!(log.scaled_down.len(), 1);
        let (retired_at, retired) = log.scaled_down[0];
        assert_eq!(retired, 1);
        assert!((retired_at - 0.2).abs() < 1e-9);
        // Nothing ever routed to the retired instance (round-robin
        // starts at candidates[0] and instance 1 left the front door).
        assert!(log.routed.iter().all(|&(_, i)| i == 0));
        // Billing: instance 0 for the full span (2.2 s), instance 1
        // until retirement (0.2 s).
        assert!((rep.cluster.span - 2.2).abs() < 1e-9);
        assert!((rep.instance_seconds - 2.4).abs() < 1e-9);
    }
}
