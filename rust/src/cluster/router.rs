//! Routing policies: which instance admits an arriving request.
//!
//! The router sees a point-in-time [`InstanceLoad`] snapshot of every
//! instance and picks one of the *front-door* candidates (the colocated
//! pool, or the prefill pool in disaggregated mode) — or sheds the
//! request entirely (SLO-aware admission control). Policies must be
//! deterministic: ties break toward the lowest instance index so seeded
//! runs replay exactly.

use crate::serving::Request;

/// Role of one instance inside the cluster.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// Full lifecycle: chunked prefill + decode on the same instance.
    Colocated,
    /// Dedicated prompt-ingestion instance (disaggregated mode): runs
    /// chunked prefill only, then ships the prompt's KV to the decode
    /// pool.
    Prefill,
    /// Dedicated decode instance fed by shipped KV (disaggregated
    /// mode); runs the paper's decode-only pricing — its steps never
    /// carry prefill chunks.
    Decode,
}

impl Role {
    /// Short display tag (`colo` / `prefill` / `decode`).
    pub fn tag(&self) -> &'static str {
        match self {
            Role::Colocated => "colo",
            Role::Prefill => "prefill",
            Role::Decode => "decode",
        }
    }
}

/// A point-in-time load snapshot of one instance, handed to routing
/// policies by the cluster simulator.
#[derive(Debug, Clone, Copy)]
pub struct InstanceLoad {
    /// The instance's role.
    pub role: Role,
    /// Whether the instance currently participates in placement.
    /// False for instances an autoscaler has spawned but not yet
    /// warmed up, and for retired ones — policies reading non-candidate
    /// loads (e.g. decode-pool pressure) must skip those, since they
    /// hold no work and would fake an idle pool member.
    pub placeable: bool,
    /// Requests queued at the instance (not yet admitted).
    pub queued: usize,
    /// Requests active on the instance (prefilling or decoding).
    pub active: usize,
    /// The instance's batch cap (admission stalls once `active` hits it).
    pub max_batch: usize,
    /// KV bytes committed to the instance: the full footprint of every
    /// request routed there and not yet retired (queued or active).
    pub outstanding_kv_bytes: f64,
    /// Generation tokens committed to the instance: the `gen_len` sum of
    /// everything routed there and not yet retired (the decode backlog
    /// that keeps batch slots occupied).
    pub outstanding_gen_tokens: u64,
    /// Prompt tokens routed to the instance that it has not yet
    /// prefilled.
    pub pending_prefill_tokens: u64,
    /// Prompts routed to the instance that are not yet fully ingested
    /// (each needs at least one step: chunks never span prompts).
    pub pending_prefill_prompts: u64,
    /// Exponentially-weighted mean of the instance's recent step
    /// latencies, seconds (0 until its first step is priced).
    pub ewma_step_latency: f64,
    /// The instance's prefill chunk size (0 = decode-only).
    pub prefill_chunk: u64,
}

impl InstanceLoad {
    /// Total outstanding work in tokens: prompt tokens still to ingest
    /// plus the generation backlog. The "least-outstanding-tokens"
    /// routing key — a size-aware analog of least-outstanding-requests
    /// that sees a 128K-prompt request as the load it actually is.
    pub fn outstanding_tokens(&self) -> u64 {
        self.pending_prefill_tokens + self.outstanding_gen_tokens
    }

    /// Crude TTFT prediction for a request with a `context_len`-token
    /// prompt landing on this instance now, in steps costed at the
    /// instance's recent step cadence:
    ///
    /// * **Chunk backlog** — prefill steps ahead of this prompt plus
    ///   its own chunks. The planner runs one chunk for one prompt per
    ///   step, so the backlog needs at least `pending_tokens / chunk`
    ///   steps *and* at least one step per pending prompt; the estimate
    ///   takes the larger bound (exact unless prompt remainders mix),
    ///   costed at the cadence EWMA, which is the looser approximation.
    /// * **Slot wait** — once `queued + active` exceeds the batch cap,
    ///   a new request cannot even start prefilling until earlier
    ///   admissions decode to completion. Approximated from the decode
    ///   backlog: `overflow * mean_gen / max_batch` steps, i.e. the
    ///   tokens the instance must drain (at one token per lane per
    ///   step) before enough slots free up. This is the term that makes
    ///   admission control see decode-slot congestion — the dominant
    ///   TTFT contribution at overload — not just prompt backlog.
    pub fn predicted_ttft(&self, context_len: u64) -> f64 {
        self.predicted_ttft_seeded(context_len, 0.0)
    }

    /// [`InstanceLoad::predicted_ttft`] with a fallback step cadence
    /// for cold instances. When this instance has priced no step yet
    /// (`ewma_step_latency == 0`), the backlog is costed at
    /// `peer_ewma` — typically the mean cadence of the cluster's warm
    /// instances — instead of 0. Regression: pricing a cold instance's
    /// backlog at 0 predicted a 0 TTFT *regardless of backlog*, so a
    /// freshly scaled-up instance absorbed an unbounded admission
    /// flood. With no warm peer either (`peer_ewma == 0`), the
    /// prediction is still 0: a completely cold cluster has to admit
    /// something to bootstrap its cadence estimate.
    pub fn predicted_ttft_seeded(&self, context_len: u64, peer_ewma: f64) -> f64 {
        let cadence = if self.ewma_step_latency > 0.0 {
            self.ewma_step_latency
        } else {
            peer_ewma
        };
        cadence * self.ttft_steps(context_len) as f64
    }

    /// The step-count part of the TTFT prediction (see
    /// [`InstanceLoad::predicted_ttft`] for the model).
    fn ttft_steps(&self, context_len: u64) -> u64 {
        let chunk_steps = if self.prefill_chunk > 0 {
            let chunk = self.prefill_chunk;
            self.pending_prefill_tokens
                .div_ceil(chunk)
                .max(self.pending_prefill_prompts)
                + context_len.max(1).div_ceil(chunk)
        } else {
            // Decode-only front door: first token one step after the
            // queue ahead drains into the batch.
            self.queued as u64 + 1
        };
        let in_system = self.queued + self.active;
        let overflow = (in_system + 1).saturating_sub(self.max_batch.max(1));
        let slot_steps = if overflow > 0 && in_system > 0 {
            let mean_gen = self.outstanding_gen_tokens as f64 / in_system as f64;
            (overflow as f64 * mean_gen / self.max_batch.max(1) as f64).ceil()
                as u64
        } else {
            0
        };
        chunk_steps + slot_steps
    }
}

/// Mean step cadence of the instances that have priced at least one
/// step — the fallback [`SloAdmission`] seeds cold instances'
/// predictions with. 0 when the whole cluster is cold.
pub(crate) fn peer_ewma(loads: &[InstanceLoad]) -> f64 {
    let (sum, n) = loads
        .iter()
        .filter(|l| l.ewma_step_latency > 0.0)
        .fold((0.0f64, 0u32), |(s, n), l| (s + l.ewma_step_latency, n + 1));
    if n > 0 {
        sum / n as f64
    } else {
        0.0
    }
}

/// Lowest-index argmin over `(index, key)` pairs; `None` on an empty
/// iterator. The shared selection kernel for every "least-X" placement
/// decision (front-door routing and decode-pool placement), so the
/// deterministic tie-break lives in exactly one place.
pub(crate) fn argmin(pairs: impl Iterator<Item = (usize, f64)>) -> Option<(usize, f64)> {
    let mut best: Option<(usize, f64)> = None;
    for (i, v) in pairs {
        if best.map(|(_, bv)| v < bv).unwrap_or(true) {
            best = Some((i, v));
        }
    }
    best
}

/// A routing policy: picks the instance that admits each arriving
/// request.
pub trait Router {
    /// Choose an instance among `candidates` (indices into `loads`) for
    /// request `r`, or return `None` to shed it. `loads` covers every
    /// instance in the cluster, candidates or not, so policies may
    /// account for downstream (decode-pool) pressure too.
    fn route(
        &mut self,
        r: &Request,
        candidates: &[usize],
        loads: &[InstanceLoad],
    ) -> Option<usize>;

    /// Policy name for reports.
    fn name(&self) -> String;
}

/// Cycle through the candidate instances in order. With a single
/// instance this is the pass-through router (every request goes to
/// instance 0), which is what the N=1 equivalence test exercises.
///
/// The cursor is the *last-picked instance id*, not a raw counter:
/// each pick takes the first candidate with a larger id, wrapping to
/// the smallest. Regression: a raw `count % candidates.len()` cursor
/// desynchronizes whenever the candidate set changes size (inevitable
/// under autoscaling), double-serving some instances and skipping
/// others.
#[derive(Debug, Default)]
pub struct RoundRobin {
    last: Option<usize>,
}

impl RoundRobin {
    /// New round-robin router starting at the first candidate.
    pub fn new() -> RoundRobin {
        RoundRobin { last: None }
    }
}

impl Router for RoundRobin {
    fn route(
        &mut self,
        _r: &Request,
        candidates: &[usize],
        _loads: &[InstanceLoad],
    ) -> Option<usize> {
        if candidates.is_empty() {
            return None;
        }
        // Candidate lists are sorted by instance id (the cluster keeps
        // the front door sorted as instances join and leave), so "the
        // first id past the last pick, wrapping" continues the cycle
        // no matter how membership changed since.
        let i = match self.last {
            Some(last) => candidates
                .iter()
                .copied()
                .find(|&c| c > last)
                .unwrap_or(candidates[0]),
            None => candidates[0],
        };
        self.last = Some(i);
        Some(i)
    }

    fn name(&self) -> String {
        "round-robin".into()
    }
}

/// Send each request to the candidate with the fewest outstanding
/// tokens ([`InstanceLoad::outstanding_tokens`]: pending prompt tokens
/// + generation backlog). Under skewed request sizes this beats
/// round-robin, which counts requests and happily stacks two 128K
/// prompts on the same instance.
#[derive(Debug, Default)]
pub struct LeastOutstandingTokens;

impl Router for LeastOutstandingTokens {
    fn route(
        &mut self,
        _r: &Request,
        candidates: &[usize],
        loads: &[InstanceLoad],
    ) -> Option<usize> {
        argmin(
            candidates
                .iter()
                .map(|&i| (i, loads[i].outstanding_tokens() as f64)),
        )
        .map(|(i, _)| i)
    }

    fn name(&self) -> String {
        "least-tokens".into()
    }
}

/// SLO-aware admission: route to the candidate with the lowest predicted
/// TTFT ([`InstanceLoad::predicted_ttft`]), and shed the request when
/// even that best prediction exceeds the target — bounding the TTFT tail
/// by refusing work the cluster cannot serve in time instead of queueing
/// it into a violation.
///
/// Shedding is priority-aware: a request of class `p` is held to an
/// effective target of `ttft_target * (p + 1)`, so under pressure the
/// best-effort class sheds first while urgent classes keep flowing.
/// Class 0 sees exactly `ttft_target` — single-class workloads behave
/// identically to the pre-priority router.
#[derive(Debug)]
pub struct SloAdmission {
    /// Admission threshold on predicted TTFT for class-0 requests,
    /// seconds (class `p` is admitted up to `(p + 1)` times this).
    pub ttft_target: f64,
}

impl SloAdmission {
    /// New SLO-aware admission router with the given TTFT target.
    pub fn new(ttft_target: f64) -> SloAdmission {
        SloAdmission { ttft_target }
    }
}

impl Router for SloAdmission {
    fn route(
        &mut self,
        r: &Request,
        candidates: &[usize],
        loads: &[InstanceLoad],
    ) -> Option<usize> {
        // Cold instances (no step history yet — freshly autoscaled,
        // or simply never stepped) predict at the mean cadence of the
        // warm peers instead of 0, so a huge backlog on a cold
        // instance is still priced as the wait it is.
        let peer = peer_ewma(loads);
        let (i, mut predicted) = argmin(
            candidates
                .iter()
                .map(|&i| (i, loads[i].predicted_ttft_seeded(r.context_len, peer))),
        )?;
        if loads[i].role == Role::Prefill {
            // Disaggregated front door: the first token comes from the
            // decode pool, so the prediction must include downstream
            // pressure — the least-loaded decode instance's queue and
            // slot backlog (the KV shipment itself is not visible to
            // the router and is left out; it only tightens admission
            // further when modeled). Ignoring this term let a shallow
            // prefill pool admit into a clogged decode pool and blow
            // the target unbounded. Warming/retired decode instances
            // are skipped — they take no placement, so their empty
            // queues would fake an idle pool member.
            if let Some((_, d)) = argmin(
                loads
                    .iter()
                    .enumerate()
                    .filter(|(_, l)| l.placeable && l.role == Role::Decode)
                    .map(|(j, l)| (j, l.predicted_ttft_seeded(0, peer))),
            ) {
                predicted += d;
            }
        }
        // Higher classes tolerate proportionally more predicted wait
        // before shedding; class 0 keeps the exact base target.
        if predicted > self.ttft_target * (r.priority as f64 + 1.0) {
            None
        } else {
            Some(i)
        }
    }

    fn name(&self) -> String {
        format!("slo-admission({} ms)", self.ttft_target * 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::testutil::mk_req;

    fn req(id: u64, ctx: u64) -> Request {
        mk_req(id, 0.0, ctx, 8)
    }

    fn load(gen_backlog: u64, pending: u64, ewma: f64) -> InstanceLoad {
        InstanceLoad {
            role: Role::Colocated,
            placeable: true,
            queued: 0,
            active: 0,
            max_batch: 16,
            outstanding_kv_bytes: 0.0,
            outstanding_gen_tokens: gen_backlog,
            pending_prefill_tokens: pending,
            pending_prefill_prompts: if pending > 0 { 1 } else { 0 },
            ewma_step_latency: ewma,
            prefill_chunk: 256,
        }
    }

    #[test]
    fn round_robin_cycles_candidates() {
        let mut r = RoundRobin::new();
        let loads = vec![load(0, 0, 0.0); 3];
        let cands = [0usize, 1, 2];
        let picks: Vec<usize> = (0..6)
            .map(|i| r.route(&req(i, 100), &cands, &loads).unwrap())
            .collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn round_robin_stays_fair_when_the_candidate_set_changes() {
        // Regression: the raw `count % len` cursor desynchronized when
        // the candidate set changed size (instances joining/leaving
        // under autoscaling), double-serving some instances. The
        // cursor is the last-picked id, so the cycle continues from
        // there across any membership change.
        let mut r = RoundRobin::new();
        let loads = vec![load(0, 0, 0.0); 4];
        assert_eq!(r.route(&req(0, 1), &[0, 1, 2], &loads), Some(0));
        assert_eq!(r.route(&req(1, 1), &[0, 1, 2], &loads), Some(1));
        // Instance 3 joins: the cycle continues past the last pick.
        assert_eq!(r.route(&req(2, 1), &[0, 1, 2, 3], &loads), Some(2));
        // Instances 1 and 3 leave. Instance 2 was just served, so the
        // cycle must wrap to 0 — the old cursor (3 % 2 = 1) would have
        // served instance 2 twice in a row.
        assert_eq!(r.route(&req(3, 1), &[0, 2], &loads), Some(0));
        assert_eq!(r.route(&req(4, 1), &[0, 2], &loads), Some(2));
        assert_eq!(r.route(&req(5, 1), &[0, 2], &loads), Some(0));
        // A shrink below the cursor wraps cleanly too.
        assert_eq!(r.route(&req(6, 1), &[2, 3], &loads), Some(2));
        assert_eq!(r.route(&req(7, 1), &[0, 1], &loads), Some(0));
    }

    #[test]
    fn least_tokens_picks_emptiest_with_deterministic_ties() {
        let mut r = LeastOutstandingTokens;
        // Outstanding work = pending prefill + gen backlog.
        let loads = vec![load(500, 100, 0.0), load(40, 20, 0.0), load(60, 0, 0.0)];
        assert_eq!(r.route(&req(0, 100), &[0, 1, 2], &loads), Some(1));
        // Restricting candidates is honored.
        assert_eq!(r.route(&req(0, 100), &[0, 2], &loads), Some(2));
        // Ties break to the lowest index.
        let tied = vec![load(10, 0, 0.0), load(10, 0, 0.0)];
        assert_eq!(r.route(&req(0, 100), &[0, 1], &tied), Some(0));
    }

    #[test]
    fn slo_admission_sheds_when_backlog_exceeds_target() {
        let mut r = SloAdmission::new(0.050);
        // 10 pending chunks at 10 ms/step -> predicted TTFT > 100 ms.
        let busy = load(0, 2560, 0.010);
        let idle = load(0, 0, 0.010);
        assert_eq!(r.route(&req(0, 256), &[0], &[busy]), None);
        // An idle candidate absorbs it (1 chunk * 10 ms <= 50 ms).
        assert_eq!(r.route(&req(0, 256), &[0, 1], &[busy, idle]), Some(1));
        // A completely cold cluster (no step history anywhere) has no
        // cadence to price with: predictions are 0 and the cluster
        // bootstraps by admitting.
        let cold = load(0, 99_999, 0.0);
        assert_eq!(r.route(&req(0, 256), &[0], &[cold]), Some(0));
    }

    #[test]
    fn slo_admission_sheds_low_priority_first() {
        let mut r = SloAdmission::new(0.050);
        // 6 pending chunks + own chunk at 10 ms/step: predicted TTFT
        // 70 ms — past the class-0 target but inside class 1's
        // doubled allowance.
        let busy = load(0, 1400, 0.010);
        let lo = req(0, 256);
        let mut hi = req(1, 256);
        hi.priority = 1;
        assert_eq!(r.route(&lo, &[0], &[busy]), None, "class 0 sheds");
        assert_eq!(r.route(&hi, &[0], &[busy]), Some(0), "class 1 rides");
        // Far enough past every allowance, both shed.
        let slammed = load(0, 25_600, 0.010);
        assert_eq!(r.route(&hi, &[0], &[slammed]), None);
    }

    #[test]
    fn slo_admission_prices_cold_instances_at_the_peer_cadence() {
        // Regression: a cold instance (ewma 0) used to predict a TTFT
        // of 0 regardless of backlog, so a freshly scaled-up instance
        // absorbed an unbounded flood. With a warm peer in the
        // cluster, the cold instance's backlog must be priced at the
        // peer cadence instead.
        let mut r = SloAdmission::new(0.050);
        let cold_backlogged = load(0, 99_999, 0.0);
        let warm_peer = load(0, 0, 0.010);
        // The cold instance is the only candidate: ~391 backlog chunks
        // at the peer's 10 ms cadence blows the 50 ms target -> shed.
        assert_eq!(
            r.route(&req(0, 256), &[0], &[cold_backlogged, warm_peer]),
            None,
            "cold backlog must be priced at the peer EWMA, not 0"
        );
        // With both as candidates the warm idle peer absorbs it.
        assert_eq!(
            r.route(&req(0, 256), &[0, 1], &[cold_backlogged, warm_peer]),
            Some(1)
        );
        // A cold *idle* instance among warm peers is still admissible:
        // one chunk at the peer cadence is within target.
        let cold_idle = load(0, 0, 0.0);
        assert_eq!(r.route(&req(0, 256), &[0], &[cold_idle, warm_peer]), Some(0));
    }

    #[test]
    fn slo_admission_sees_decode_pool_congestion_behind_a_prefill_door() {
        // Disaggregated: the candidate prefill instance is idle, but
        // the decode pool is clogged. The prediction must include the
        // downstream backlog — the first token comes from the decode
        // pool — so the request is shed; with an idle decode pool it
        // is admitted.
        let mut r = SloAdmission::new(0.050);
        let mut door = load(0, 0, 0.010);
        door.role = Role::Prefill;
        let mut clogged = load(0, 0, 0.010);
        clogged.role = Role::Decode;
        clogged.prefill_chunk = 0;
        clogged.queued = 8;
        clogged.active = 16;
        clogged.outstanding_gen_tokens = 24 * 32;
        let mut idle_decode = clogged;
        idle_decode.queued = 0;
        idle_decode.active = 0;
        idle_decode.outstanding_gen_tokens = 0;
        // door alone predicts 1 chunk = 10 ms; clogged decode adds
        // (8 + 1 + 18) * 10 ms, far past the 50 ms target.
        assert_eq!(r.route(&req(0, 256), &[0], &[door, clogged]), None);
        assert_eq!(r.route(&req(0, 256), &[0], &[door, idle_decode]), Some(0));
    }

    #[test]
    fn predicted_ttft_counts_chunks_exactly() {
        let l = load(0, 300, 0.010); // 2 pending chunks of 256
        // own prompt of 513 tokens -> 3 chunks; total 5 steps at 10 ms.
        assert!((l.predicted_ttft(513) - 0.050).abs() < 1e-12);
        let mut decode_only = l;
        decode_only.prefill_chunk = 0;
        decode_only.queued = 4;
        assert!((decode_only.predicted_ttft(513) - 0.050).abs() < 1e-12);
    }

    #[test]
    fn predicted_ttft_counts_small_prompts_per_step() {
        // 10 tiny pending prompts (32 tokens each): token pooling alone
        // would predict ceil(320/256) = 2 steps, but each prompt needs
        // its own step — the prompt-count bound must win.
        let mut l = load(0, 320, 0.010);
        l.pending_prefill_prompts = 10;
        // 10 backlog steps + 1 own chunk.
        assert!((l.predicted_ttft(100) - 0.110).abs() < 1e-12);
    }

    #[test]
    fn predicted_ttft_sees_decode_slot_congestion() {
        // 16 slots all full, 8 more queued, each holding ~32 gen tokens:
        // the next request waits for (25 - 16) * 32 / 16 = 18 drain
        // steps on top of its single chunk.
        let mut l = load(0, 0, 0.010);
        l.queued = 8;
        l.active = 16;
        l.outstanding_gen_tokens = 24 * 32;
        let expected = 0.010 * (1.0 + 18.0);
        assert!(
            (l.predicted_ttft(256) - expected).abs() < 1e-12,
            "{} vs {expected}",
            l.predicted_ttft(256)
        );
        // Below the batch cap there is no slot wait.
        l.active = 4;
        l.queued = 0;
        l.outstanding_gen_tokens = 0;
        assert!((l.predicted_ttft(256) - 0.010).abs() < 1e-12);
    }
}
