//! Routing policies: which instance admits an arriving request.
//!
//! The router sees a point-in-time [`InstanceLoad`] snapshot of every
//! instance and picks one of the *front-door* candidates (the colocated
//! pool, or the prefill pool in disaggregated mode) — or sheds the
//! request entirely (SLO-aware admission control). Policies must be
//! deterministic: ties break toward the lowest instance index so seeded
//! runs replay exactly.

use crate::serving::Request;

/// Role of one instance inside the cluster.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// Full lifecycle: chunked prefill + decode on the same instance.
    Colocated,
    /// Dedicated prompt-ingestion instance (disaggregated mode): runs
    /// chunked prefill only, then ships the prompt's KV to the decode
    /// pool.
    Prefill,
    /// Dedicated decode instance fed by shipped KV (disaggregated
    /// mode); runs the paper's decode-only pricing — its steps never
    /// carry prefill chunks.
    Decode,
}

impl Role {
    /// Short display tag (`colo` / `prefill` / `decode`).
    pub fn tag(&self) -> &'static str {
        match self {
            Role::Colocated => "colo",
            Role::Prefill => "prefill",
            Role::Decode => "decode",
        }
    }
}

/// A point-in-time load snapshot of one instance, handed to routing
/// policies by the cluster simulator.
#[derive(Debug, Clone, Copy)]
pub struct InstanceLoad {
    /// The instance's role.
    pub role: Role,
    /// Requests queued at the instance (not yet admitted).
    pub queued: usize,
    /// Requests active on the instance (prefilling or decoding).
    pub active: usize,
    /// The instance's batch cap (admission stalls once `active` hits it).
    pub max_batch: usize,
    /// KV bytes committed to the instance: the full footprint of every
    /// request routed there and not yet retired (queued or active).
    pub outstanding_kv_bytes: f64,
    /// Generation tokens committed to the instance: the `gen_len` sum of
    /// everything routed there and not yet retired (the decode backlog
    /// that keeps batch slots occupied).
    pub outstanding_gen_tokens: u64,
    /// Prompt tokens routed to the instance that it has not yet
    /// prefilled.
    pub pending_prefill_tokens: u64,
    /// Prompts routed to the instance that are not yet fully ingested
    /// (each needs at least one step: chunks never span prompts).
    pub pending_prefill_prompts: u64,
    /// Exponentially-weighted mean of the instance's recent step
    /// latencies, seconds (0 until its first step is priced).
    pub ewma_step_latency: f64,
    /// The instance's prefill chunk size (0 = decode-only).
    pub prefill_chunk: u64,
}

impl InstanceLoad {
    /// Total outstanding work in tokens: prompt tokens still to ingest
    /// plus the generation backlog. The "least-outstanding-tokens"
    /// routing key — a size-aware analog of least-outstanding-requests
    /// that sees a 128K-prompt request as the load it actually is.
    pub fn outstanding_tokens(&self) -> u64 {
        self.pending_prefill_tokens + self.outstanding_gen_tokens
    }

    /// Crude TTFT prediction for a request with a `context_len`-token
    /// prompt landing on this instance now, in steps costed at the
    /// instance's recent step cadence:
    ///
    /// * **Chunk backlog** — prefill steps ahead of this prompt plus
    ///   its own chunks. The planner runs one chunk for one prompt per
    ///   step, so the backlog needs at least `pending_tokens / chunk`
    ///   steps *and* at least one step per pending prompt; the estimate
    ///   takes the larger bound (exact unless prompt remainders mix),
    ///   costed at the cadence EWMA, which is the looser approximation.
    /// * **Slot wait** — once `queued + active` exceeds the batch cap,
    ///   a new request cannot even start prefilling until earlier
    ///   admissions decode to completion. Approximated from the decode
    ///   backlog: `overflow * mean_gen / max_batch` steps, i.e. the
    ///   tokens the instance must drain (at one token per lane per
    ///   step) before enough slots free up. This is the term that makes
    ///   admission control see decode-slot congestion — the dominant
    ///   TTFT contribution at overload — not just prompt backlog.
    pub fn predicted_ttft(&self, context_len: u64) -> f64 {
        let chunk_steps = if self.prefill_chunk > 0 {
            let chunk = self.prefill_chunk;
            self.pending_prefill_tokens
                .div_ceil(chunk)
                .max(self.pending_prefill_prompts)
                + context_len.max(1).div_ceil(chunk)
        } else {
            // Decode-only front door: first token one step after the
            // queue ahead drains into the batch.
            self.queued as u64 + 1
        };
        let in_system = self.queued + self.active;
        let overflow = (in_system + 1).saturating_sub(self.max_batch.max(1));
        let slot_steps = if overflow > 0 && in_system > 0 {
            let mean_gen = self.outstanding_gen_tokens as f64 / in_system as f64;
            (overflow as f64 * mean_gen / self.max_batch.max(1) as f64).ceil()
                as u64
        } else {
            0
        };
        self.ewma_step_latency * (chunk_steps + slot_steps) as f64
    }
}

/// Lowest-index argmin over `(index, key)` pairs; `None` on an empty
/// iterator. The shared selection kernel for every "least-X" placement
/// decision (front-door routing and decode-pool placement), so the
/// deterministic tie-break lives in exactly one place.
pub(crate) fn argmin(pairs: impl Iterator<Item = (usize, f64)>) -> Option<(usize, f64)> {
    let mut best: Option<(usize, f64)> = None;
    for (i, v) in pairs {
        if best.map(|(_, bv)| v < bv).unwrap_or(true) {
            best = Some((i, v));
        }
    }
    best
}

/// A routing policy: picks the instance that admits each arriving
/// request.
pub trait Router {
    /// Choose an instance among `candidates` (indices into `loads`) for
    /// request `r`, or return `None` to shed it. `loads` covers every
    /// instance in the cluster, candidates or not, so policies may
    /// account for downstream (decode-pool) pressure too.
    fn route(
        &mut self,
        r: &Request,
        candidates: &[usize],
        loads: &[InstanceLoad],
    ) -> Option<usize>;

    /// Policy name for reports.
    fn name(&self) -> String;
}

/// Cycle through the candidate instances in order. With a single
/// instance this is the pass-through router (every request goes to
/// instance 0), which is what the N=1 equivalence test exercises.
#[derive(Debug, Default)]
pub struct RoundRobin {
    next: usize,
}

impl RoundRobin {
    /// New round-robin router starting at the first candidate.
    pub fn new() -> RoundRobin {
        RoundRobin { next: 0 }
    }
}

impl Router for RoundRobin {
    fn route(
        &mut self,
        _r: &Request,
        candidates: &[usize],
        _loads: &[InstanceLoad],
    ) -> Option<usize> {
        if candidates.is_empty() {
            return None;
        }
        let i = candidates[self.next % candidates.len()];
        self.next = self.next.wrapping_add(1);
        Some(i)
    }

    fn name(&self) -> String {
        "round-robin".into()
    }
}

/// Send each request to the candidate with the fewest outstanding
/// tokens ([`InstanceLoad::outstanding_tokens`]: pending prompt tokens
/// + generation backlog). Under skewed request sizes this beats
/// round-robin, which counts requests and happily stacks two 128K
/// prompts on the same instance.
#[derive(Debug, Default)]
pub struct LeastOutstandingTokens;

impl Router for LeastOutstandingTokens {
    fn route(
        &mut self,
        _r: &Request,
        candidates: &[usize],
        loads: &[InstanceLoad],
    ) -> Option<usize> {
        argmin(
            candidates
                .iter()
                .map(|&i| (i, loads[i].outstanding_tokens() as f64)),
        )
        .map(|(i, _)| i)
    }

    fn name(&self) -> String {
        "least-tokens".into()
    }
}

/// SLO-aware admission: route to the candidate with the lowest predicted
/// TTFT ([`InstanceLoad::predicted_ttft`]), and shed the request when
/// even that best prediction exceeds the target — bounding the TTFT tail
/// by refusing work the cluster cannot serve in time instead of queueing
/// it into a violation.
#[derive(Debug)]
pub struct SloAdmission {
    /// Admission threshold on predicted TTFT, seconds.
    pub ttft_target: f64,
}

impl SloAdmission {
    /// New SLO-aware admission router with the given TTFT target.
    pub fn new(ttft_target: f64) -> SloAdmission {
        SloAdmission { ttft_target }
    }
}

impl Router for SloAdmission {
    fn route(
        &mut self,
        r: &Request,
        candidates: &[usize],
        loads: &[InstanceLoad],
    ) -> Option<usize> {
        let (i, mut predicted) = argmin(
            candidates
                .iter()
                .map(|&i| (i, loads[i].predicted_ttft(r.context_len))),
        )?;
        if loads[i].role == Role::Prefill {
            // Disaggregated front door: the first token comes from the
            // decode pool, so the prediction must include downstream
            // pressure — the least-loaded decode instance's queue and
            // slot backlog (the KV shipment itself is not visible to
            // the router and is left out; it only tightens admission
            // further when modeled). Ignoring this term let a shallow
            // prefill pool admit into a clogged decode pool and blow
            // the target unbounded.
            if let Some((_, d)) = argmin(
                loads
                    .iter()
                    .enumerate()
                    .filter(|(_, l)| l.role == Role::Decode)
                    .map(|(j, l)| (j, l.predicted_ttft(0))),
            ) {
                predicted += d;
            }
        }
        if predicted > self.ttft_target {
            None
        } else {
            Some(i)
        }
    }

    fn name(&self) -> String {
        format!("slo-admission({} ms)", self.ttft_target * 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::testutil::mk_req;

    fn req(id: u64, ctx: u64) -> Request {
        mk_req(id, 0.0, ctx, 8)
    }

    fn load(gen_backlog: u64, pending: u64, ewma: f64) -> InstanceLoad {
        InstanceLoad {
            role: Role::Colocated,
            queued: 0,
            active: 0,
            max_batch: 16,
            outstanding_kv_bytes: 0.0,
            outstanding_gen_tokens: gen_backlog,
            pending_prefill_tokens: pending,
            pending_prefill_prompts: if pending > 0 { 1 } else { 0 },
            ewma_step_latency: ewma,
            prefill_chunk: 256,
        }
    }

    #[test]
    fn round_robin_cycles_candidates() {
        let mut r = RoundRobin::new();
        let loads = vec![load(0, 0, 0.0); 3];
        let cands = [0usize, 1, 2];
        let picks: Vec<usize> = (0..6)
            .map(|i| r.route(&req(i, 100), &cands, &loads).unwrap())
            .collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_tokens_picks_emptiest_with_deterministic_ties() {
        let mut r = LeastOutstandingTokens;
        // Outstanding work = pending prefill + gen backlog.
        let loads = vec![load(500, 100, 0.0), load(40, 20, 0.0), load(60, 0, 0.0)];
        assert_eq!(r.route(&req(0, 100), &[0, 1, 2], &loads), Some(1));
        // Restricting candidates is honored.
        assert_eq!(r.route(&req(0, 100), &[0, 2], &loads), Some(2));
        // Ties break to the lowest index.
        let tied = vec![load(10, 0, 0.0), load(10, 0, 0.0)];
        assert_eq!(r.route(&req(0, 100), &[0, 1], &tied), Some(0));
    }

    #[test]
    fn slo_admission_sheds_when_backlog_exceeds_target() {
        let mut r = SloAdmission::new(0.050);
        // 10 pending chunks at 10 ms/step -> predicted TTFT > 100 ms.
        let busy = load(0, 2560, 0.010);
        let idle = load(0, 0, 0.010);
        assert_eq!(r.route(&req(0, 256), &[0], &[busy]), None);
        // An idle candidate absorbs it (1 chunk * 10 ms <= 50 ms).
        assert_eq!(r.route(&req(0, 256), &[0, 1], &[busy, idle]), Some(1));
        // No step history yet: predictions are 0, always admit.
        let cold = load(0, 99_999, 0.0);
        assert_eq!(r.route(&req(0, 256), &[0], &[cold]), Some(0));
    }

    #[test]
    fn slo_admission_sees_decode_pool_congestion_behind_a_prefill_door() {
        // Disaggregated: the candidate prefill instance is idle, but
        // the decode pool is clogged. The prediction must include the
        // downstream backlog — the first token comes from the decode
        // pool — so the request is shed; with an idle decode pool it
        // is admitted.
        let mut r = SloAdmission::new(0.050);
        let mut door = load(0, 0, 0.010);
        door.role = Role::Prefill;
        let mut clogged = load(0, 0, 0.010);
        clogged.role = Role::Decode;
        clogged.prefill_chunk = 0;
        clogged.queued = 8;
        clogged.active = 16;
        clogged.outstanding_gen_tokens = 24 * 32;
        let mut idle_decode = clogged;
        idle_decode.queued = 0;
        idle_decode.active = 0;
        idle_decode.outstanding_gen_tokens = 0;
        // door alone predicts 1 chunk = 10 ms; clogged decode adds
        // (8 + 1 + 18) * 10 ms, far past the 50 ms target.
        assert_eq!(r.route(&req(0, 256), &[0], &[door, clogged]), None);
        assert_eq!(r.route(&req(0, 256), &[0], &[door, idle_decode]), Some(0));
    }

    #[test]
    fn predicted_ttft_counts_chunks_exactly() {
        let l = load(0, 300, 0.010); // 2 pending chunks of 256
        // own prompt of 513 tokens -> 3 chunks; total 5 steps at 10 ms.
        assert!((l.predicted_ttft(513) - 0.050).abs() < 1e-12);
        let mut decode_only = l;
        decode_only.prefill_chunk = 0;
        decode_only.queued = 4;
        assert!((decode_only.predicted_ttft(513) - 0.050).abs() < 1e-12);
    }

    #[test]
    fn predicted_ttft_counts_small_prompts_per_step() {
        // 10 tiny pending prompts (32 tokens each): token pooling alone
        // would predict ceil(320/256) = 2 steps, but each prompt needs
        // its own step — the prompt-count bound must win.
        let mut l = load(0, 320, 0.010);
        l.pending_prefill_prompts = 10;
        // 10 backlog steps + 1 own chunk.
        assert!((l.predicted_ttft(100) - 0.110).abs() < 1e-12);
    }

    #[test]
    fn predicted_ttft_sees_decode_slot_congestion() {
        // 16 slots all full, 8 more queued, each holding ~32 gen tokens:
        // the next request waits for (25 - 16) * 32 / 16 = 18 drain
        // steps on top of its single chunk.
        let mut l = load(0, 0, 0.010);
        l.queued = 8;
        l.active = 16;
        l.outstanding_gen_tokens = 24 * 32;
        let expected = 0.010 * (1.0 + 18.0);
        assert!(
            (l.predicted_ttft(256) - expected).abs() < 1e-12,
            "{} vs {expected}",
            l.predicted_ttft(256)
        );
        // Below the batch cap there is no slot wait.
        l.active = 4;
        l.queued = 0;
        l.outstanding_gen_tokens = 0;
        assert!((l.predicted_ttft(256) - 0.010).abs() < 1e-12);
    }
}
