//! Cluster-level aggregation of per-instance serving reports.

use crate::serving::{LatencyStats, ServingReport};
use crate::util::json::Json;

/// Utilization summary of one pool (colocated / prefill / decode).
#[derive(Debug, Clone)]
pub struct PoolStats {
    /// Pool label (`colo`, `prefill`, or `decode`).
    pub label: String,
    /// Instances in the pool.
    pub instances: usize,
    /// Steps executed across the pool.
    pub steps: u64,
    /// Mean fraction of the run each pool instance spent with a step in
    /// flight (busy seconds over run seconds, averaged over instances).
    pub busy_frac: f64,
    /// Duration-weighted mean lanes per step across the pool.
    pub mean_batch: f64,
    /// Output tokens generated at the pool (0 for a prefill pool: its
    /// instances ingest prompts, the decode pool emits every token).
    pub tokens: u64,
}

/// Aggregated results of one cluster-simulation run.
#[derive(Debug, Clone)]
pub struct ClusterReport {
    /// Routing policy name.
    pub router: String,
    /// Mode string, e.g. `colocated x8` or `disaggregated 3P+5D`.
    pub mode: String,
    /// Requests offered to the router.
    pub offered: u64,
    /// Requests shed by admission control (never served).
    pub shed: u64,
    /// DES events applied during the run (arrivals, step completions,
    /// KV landings). With wall-clock time around the simulation this
    /// yields events/second — the simulator's throughput figure the
    /// perf suite tracks.
    pub events: u64,
    /// Cluster-level aggregate over full request lifecycles: the
    /// percentiles are recomputed from the pooled per-request samples
    /// (never averaged across instances), and TTFT / TPOT / E2E are
    /// measured arrival-to-completion even when a request hops from a
    /// prefill to a decode instance — the KV-transfer stall lands in
    /// TTFT, where a user would feel it.
    pub cluster: ServingReport,
    /// One report per instance, over the sub-requests it retired (a
    /// prefill instance's report measures prompt ingestion).
    pub per_instance: Vec<ServingReport>,
    /// Per-pool utilization summaries.
    pub pools: Vec<PoolStats>,
    /// KV bytes shipped prefill -> decode (0 in colocated mode).
    pub kv_shipped_bytes: f64,
    /// Mean KV shipment latency, seconds (0 when nothing shipped).
    pub kv_transfer_mean: f64,
    /// Billed capacity: the sum over instances of provisioned seconds
    /// (spawn — including warm-up — to retirement or run end). A fixed
    /// fleet's value is `instances * span`; an autoscaled fleet is
    /// cheaper exactly when this is smaller at equal SLO attainment.
    pub instance_seconds: f64,
    /// Instances the autoscaler provisioned during the run.
    pub scale_ups: u64,
    /// Instances the autoscaler retired during the run.
    pub scale_downs: u64,
}

impl ClusterReport {
    /// Scale-out efficiency: cluster tokens/second per instance. Perfect
    /// scaling keeps this flat as instances are added; router imbalance
    /// and pool mis-sizing show up as decay.
    pub fn stps_per_instance(&self) -> f64 {
        self.cluster.stps / self.per_instance.len().max(1) as f64
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "[{} | {}] {}/{} reqs ({} shed), {} tok in {:.2}s -> STPS {:.1} \
             ({:.1}/instance), TTFT p99 {:.3}s, TPOT p99 {:.1}ms",
            self.router,
            self.mode,
            self.cluster.completed,
            self.offered,
            self.shed,
            self.cluster.tokens,
            self.cluster.span,
            self.cluster.stps,
            self.stps_per_instance(),
            self.cluster.ttft.p99,
            self.cluster.tpot.p99 * 1e3,
        )
    }

    /// Multi-line per-pool utilization summary.
    pub fn pool_summary(&self) -> String {
        let mut out = String::new();
        for p in &self.pools {
            out.push_str(&format!(
                "pool {:<8} x{}  busy {:>5.1}%  mean batch {:>5.1}  \
                 steps {:>7}  tokens {}\n",
                p.label,
                p.instances,
                p.busy_frac * 100.0,
                p.mean_batch,
                p.steps,
                p.tokens,
            ));
        }
        if self.kv_shipped_bytes > 0.0 {
            out.push_str(&format!(
                "kv shipped {:.2} GiB, mean transfer {:.3} ms\n",
                self.kv_shipped_bytes / crate::GIB,
                self.kv_transfer_mean * 1e3,
            ));
        }
        if self.cluster.preemptions > 0 {
            out.push_str(&format!(
                "preemption: {} evictions / {} restores\n",
                self.cluster.preemptions, self.cluster.restores,
            ));
        }
        if self.scale_ups + self.scale_downs > 0 {
            out.push_str(&format!(
                "autoscale: +{} spawned / -{} retired, {:.1} instance-s billed\n",
                self.scale_ups, self.scale_downs, self.instance_seconds,
            ));
        }
        out
    }

    /// Cluster-level SLO percentiles (delegates to the merged report).
    pub fn slo_summary(&self) -> String {
        self.cluster.slo_summary()
    }

    /// Machine-readable form (the `cluster-scaling` experiment writes
    /// one of these per router policy as a JSON artifact).
    pub fn to_json(&self) -> Json {
        fn lat(s: &LatencyStats) -> Json {
            Json::obj(vec![
                ("mean", Json::Num(s.mean)),
                ("p50", Json::Num(s.p50)),
                ("p90", Json::Num(s.p90)),
                ("p99", Json::Num(s.p99)),
            ])
        }
        Json::obj(vec![
            ("router", Json::Str(self.router.clone())),
            ("mode", Json::Str(self.mode.clone())),
            ("offered", Json::Num(self.offered as f64)),
            ("shed", Json::Num(self.shed as f64)),
            ("events", Json::Num(self.events as f64)),
            ("completed", Json::Num(self.cluster.completed as f64)),
            ("tokens", Json::Num(self.cluster.tokens as f64)),
            ("span_s", Json::Num(self.cluster.span)),
            ("stps", Json::Num(self.cluster.stps)),
            ("stps_per_instance", Json::Num(self.stps_per_instance())),
            ("instances", Json::Num(self.per_instance.len() as f64)),
            ("preemptions", Json::Num(self.cluster.preemptions as f64)),
            ("restores", Json::Num(self.cluster.restores as f64)),
            ("ttft_s", lat(&self.cluster.ttft)),
            ("tpot_s", lat(&self.cluster.tpot)),
            ("e2e_s", lat(&self.cluster.e2e)),
            ("kv_shipped_bytes", Json::Num(self.kv_shipped_bytes)),
            ("kv_transfer_mean_s", Json::Num(self.kv_transfer_mean)),
            ("instance_seconds", Json::Num(self.instance_seconds)),
            ("scale_ups", Json::Num(self.scale_ups as f64)),
            ("scale_downs", Json::Num(self.scale_downs as f64)),
            (
                "pools",
                Json::Arr(
                    self.pools
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("label", Json::Str(p.label.clone())),
                                ("instances", Json::Num(p.instances as f64)),
                                ("steps", Json::Num(p.steps as f64)),
                                ("busy_frac", Json::Num(p.busy_frac)),
                                ("mean_batch", Json::Num(p.mean_batch)),
                                ("tokens", Json::Num(p.tokens as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::{ServingReport, StepStats};

    fn empty_rep(name: &str) -> ServingReport {
        ServingReport::from_requests(name.into(), &[], &StepStats::default())
    }

    fn sample() -> ClusterReport {
        ClusterReport {
            router: "round-robin".into(),
            mode: "disaggregated 1P+1D".into(),
            offered: 10,
            shed: 2,
            events: 42,
            cluster: empty_rep("cluster"),
            per_instance: vec![empty_rep("i0"), empty_rep("i1")],
            pools: vec![PoolStats {
                label: "prefill".into(),
                instances: 1,
                steps: 5,
                busy_frac: 0.5,
                mean_batch: 2.0,
                tokens: 0,
            }],
            kv_shipped_bytes: 2.0 * crate::GIB,
            kv_transfer_mean: 0.001,
            instance_seconds: 20.0,
            scale_ups: 1,
            scale_downs: 1,
        }
    }

    #[test]
    fn summaries_render() {
        let rep = sample();
        assert!(rep.summary().contains("round-robin"));
        assert!(rep.summary().contains("2 shed"));
        assert!(rep.pool_summary().contains("prefill"));
        assert!(rep.pool_summary().contains("kv shipped"));
        assert!(rep.pool_summary().contains("autoscale: +1"));
        assert!(rep.slo_summary().contains("TTFT"));
        assert_eq!(rep.stps_per_instance(), 0.0);
    }

    #[test]
    fn json_round_trips_the_headline_numbers() {
        let rep = sample();
        let j = Json::parse(&rep.to_json().to_string()).unwrap();
        assert_eq!(j.get("router").unwrap().as_str(), Some("round-robin"));
        assert_eq!(j.get("shed").unwrap().as_u64(), Some(2));
        assert_eq!(j.get("events").unwrap().as_u64(), Some(42));
        assert_eq!(j.get("instances").unwrap().as_u64(), Some(2));
        let pools = j.get("pools").unwrap().as_arr().unwrap();
        assert_eq!(pools.len(), 1);
        assert_eq!(pools[0].get("label").unwrap().as_str(), Some("prefill"));
        assert!(j.get("ttft_s").unwrap().get("p99").is_some());
        assert_eq!(j.get("preemptions").unwrap().as_u64(), Some(0));
        assert_eq!(j.get("restores").unwrap().as_u64(), Some(0));
        assert_eq!(j.get("scale_ups").unwrap().as_u64(), Some(1));
        assert_eq!(j.get("scale_downs").unwrap().as_u64(), Some(1));
        assert_eq!(j.get("instance_seconds").unwrap().as_u64(), Some(20));
    }
}
