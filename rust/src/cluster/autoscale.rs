//! Autoscaling: elastic pools that grow on SLO pressure and shrink on
//! sustained idleness, with a warm-up delay so scaling is never free.
//!
//! The policy is evaluated inside the cluster's event loop from
//! *observed simulation state only* — shed/arrival counters, load
//! snapshots, per-instance idle spans, and the DES clock. No wall
//! clock, no randomness: a seeded run replays its scale decisions
//! bit-identically.
//!
//! # Lifecycle of a scaled instance
//!
//! A scale-up decision mints a fresh engine from the cluster's
//! [`EngineFactory`] (per-role, so heterogeneous pools can give the
//! prefill pool compute-heavy engines and the decode pool
//! bandwidth-heavy ones) and pushes the instance in
//! [`InstanceState::Warming`]. A warming instance holds no work and is
//! invisible to placement; it only joins the front door (or decode
//! placement) when its `WarmupDone` event — scheduled
//! [`AutoscalePolicy::warmup_delay`] seconds out on the shared
//! calendar — fires and flips it to [`InstanceState::Active`].
//! Scale-down only ever retires an instance that is *completely* idle
//! (no queued or active requests, no step in flight, no KV shipment
//! inbound), flipping it to [`InstanceState::Retired`] immediately, so
//! request conservation across pool-size changes is trivial: warming
//! and retired instances hold zero requests by construction, and the
//! DST invariant checker audits exactly that.

use super::router::Role;
use crate::serving::StepEngine;

/// Mints the [`StepEngine`] for a newly spawned instance of the given
/// role. This is where heterogeneous pools live: the factory can hand
/// [`Role::Prefill`] a compute-heavy system and [`Role::Decode`] a
/// bandwidth-heavy one.
pub type EngineFactory = Box<dyn FnMut(Role) -> Box<dyn StepEngine>>;

/// Membership state of one cluster instance (always `Active` in a
/// fixed fleet).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InstanceState {
    /// Spawned but still warming up: holds no work, receives no
    /// placement, joins the fleet when its `WarmupDone` event fires.
    Warming,
    /// Serving member of its pool.
    Active,
    /// Scaled down. Retirement only happens to a completely idle
    /// instance, so a retired instance never holds requests.
    Retired,
}

/// When and how the cluster grows or shrinks its pools. All thresholds
/// are read against simulated state; see the module docs.
#[derive(Debug, Clone, PartialEq)]
pub struct AutoscalePolicy {
    /// Grow when the shed fraction over the last decision window
    /// exceeds this (e.g. 0.05 = more than 5% of arrivals shed).
    pub shed_rate_up: f64,
    /// Grow when even the *best* front-door instance predicts a TTFT
    /// above this many seconds — pressure visible before the router
    /// sheds anything. `f64::INFINITY` disables the headroom trigger.
    pub ttft_headroom: f64,
    /// Retire an idle instance only after it has sat completely idle
    /// (no queued/active work, no step in flight, no inbound KV) for
    /// this many seconds.
    pub idle_shrink_after: f64,
    /// Seconds between a spawn decision and the instance joining
    /// placement (its `WarmupDone` event on the shared calendar).
    pub warmup_delay: f64,
    /// Minimum seconds between consecutive scale actions, so one burst
    /// does not fire a spawn per event.
    pub cooldown: f64,
    /// Arrivals that must accumulate before the shed-rate trigger is
    /// evaluated (the shed fraction needs a denominator).
    pub decision_window: u64,
    /// Per-pool floor: never shrink a pool below this many active
    /// instances (must be at least 1).
    pub min_instances: usize,
    /// Per-pool ceiling: never grow a pool (warming + active) past
    /// this.
    pub max_instances: usize,
}

impl Default for AutoscalePolicy {
    fn default() -> Self {
        AutoscalePolicy {
            shed_rate_up: 0.05,
            ttft_headroom: 0.5,
            idle_shrink_after: 2.0,
            warmup_delay: 5.0,
            cooldown: 1.0,
            decision_window: 16,
            min_instances: 1,
            max_instances: 8,
        }
    }
}

impl AutoscalePolicy {
    /// Panics on a self-contradictory policy (called at cluster build).
    pub(crate) fn validate(&self) {
        assert!(self.min_instances >= 1, "autoscale min_instances must be >= 1");
        assert!(
            self.max_instances >= self.min_instances,
            "autoscale max_instances {} below min_instances {}",
            self.max_instances,
            self.min_instances
        );
        assert!(self.decision_window >= 1, "autoscale decision_window must be >= 1");
        assert!(
            self.warmup_delay >= 0.0 && self.warmup_delay.is_finite(),
            "autoscale warmup_delay must be finite and non-negative"
        );
        assert!(
            self.cooldown >= 0.0 && self.cooldown.is_finite(),
            "autoscale cooldown must be finite and non-negative"
        );
        assert!(
            self.idle_shrink_after > 0.0,
            "autoscale idle_shrink_after must be positive"
        );
        assert!(self.shed_rate_up >= 0.0, "autoscale shed_rate_up must be >= 0");
        assert!(self.ttft_headroom > 0.0, "autoscale ttft_headroom must be positive");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_policy_validates() {
        AutoscalePolicy::default().validate();
    }

    #[test]
    #[should_panic(expected = "min_instances")]
    fn zero_min_is_rejected() {
        AutoscalePolicy { min_instances: 0, ..Default::default() }.validate();
    }

    #[test]
    #[should_panic(expected = "max_instances")]
    fn inverted_bounds_are_rejected() {
        AutoscalePolicy { min_instances: 4, max_instances: 2, ..Default::default() }
            .validate();
    }

    #[test]
    #[should_panic(expected = "warmup_delay")]
    fn negative_warmup_is_rejected() {
        AutoscalePolicy { warmup_delay: -1.0, ..Default::default() }.validate();
    }
}
