//! Cluster-scale serving: N model instances behind a router, colocated
//! or disaggregated into prefill/decode pools.
//!
//! # Why a cluster layer, per the paper
//!
//! LIMINAL's limit study ends where scale-up ends: past ~10k tokens/s
//! per user the binding constraints are **collective communication**
//! (the tiered all-reduce latency that flattens decode scaling beyond
//! 16-chip TP domains) and **capacity** (KV cache competing with
//! weights). Neither constraint yields to a bigger box — the paper caps
//! TP at 128 chips outright — so production systems attack them by
//! scaling *out*: many model instances behind a router, each instance
//! staying inside the sync-latency sweet spot, with cluster throughput
//! multiplying in instances instead of dividing into collectives.
//! Disaggregating prefill from decode is the same argument applied to
//! the roofline: prefill is compute-bound, decode is bandwidth-bound,
//! and a fused step must run both at whichever roofline is slower. A
//! dedicated prefill pool feeds a decode pool over the scale-out
//! interconnect — KV bytes at [`ClusterSpec::kv_link_bw`], paid before
//! decode admission — trading a per-request shipment stall for keeping
//! every pool at its own roofline, with the decode pool reverting to
//! the paper's decode-only pricing. The `cluster-scaling` experiment
//! measures both sides of that trade.
//!
//! # Pools
//!
//! Instances are grouped into *pools* by [`Role`]: one colocated pool,
//! or (disaggregated) a prefill pool feeding a decode pool. Pools are
//! heterogeneous by construction — [`ClusterSim`] takes per-instance
//! engines, and an autoscaling cluster carries an `EngineFactory`
//! minting per-role engines — so the prefill pool can run
//! compute-heavy systems while the decode pool runs bandwidth-heavy
//! ones, each at its own roofline.
//!
//! # Priority and preemption
//!
//! Requests carry a scheduling class
//! ([`Request::priority`](crate::serving::Request::priority), higher =
//! more urgent) end to end: workload generators and traces tag them,
//! the router sees them, and every instance's batcher admits by class
//! (FIFO within a class — single-class workloads reproduce the FIFO
//! cluster bit for bit, which is what keeps the N=1 equivalence test
//! passing unmodified). The pieces:
//!
//! * **Admission** ([`SloAdmission`]) sheds the best-effort class
//!   first: class `p` is admitted up to `(p + 1) *` the TTFT target,
//!   so under pressure low classes absorb the shedding while urgent
//!   traffic keeps flowing.
//! * **Preemption**
//!   ([`ClusterSim::set_preemption`],
//!   [`PreemptionConfig`](crate::serving::PreemptionConfig)): under KV
//!   pressure a higher-class arrival may evict the lowest-class active
//!   request on its instance. The victim's KV is released immediately
//!   (budget freed for the newcomer), it resumes from the queue front
//!   once capacity frees, and the configured evict/restore costs are
//!   priced into engine-step time — the stall lands in TTFT/TPOT, it
//!   is never free. Autoscale-spawned instances inherit the policy.
//! * **Auditing**: evict/restore actions flow to
//!   [`SimObserver::on_preempt`](crate::serving::SimObserver::on_preempt)
//!   / `on_restore`; the DST preemption family checks the evicted
//!   lifecycle (zero reserved KV while evicted, never double-evicted,
//!   exact KV conservation through evict/restore) on every event, and
//!   [`ClusterReport`] carries cluster-wide eviction/restore counters.
//!
//! # Autoscaling
//!
//! With an [`AutoscalePolicy`] in the [`ClusterSpec`]
//! (built via [`ClusterSim::with_factory`]), pools are *elastic*:
//!
//! * **Grow** when the SLO pressure crosses a threshold — the shed
//!   fraction over the last decision window, or the predicted-TTFT
//!   headroom of even the best front-door instance.
//! * **Warm up** before serving: a spawned instance is provisioned
//!   (and billed) immediately but joins `front_door`/decode placement
//!   only when its `WarmupDone` event fires on the shared
//!   [`EventQueue`](crate::des::EventQueue), `warmup_delay` seconds
//!   later — scaling is never free.
//! * **Shrink** on sustained idleness: only an instance that is
//!   completely idle (no queued/active work, no step in flight, no KV
//!   shipment inbound) past `idle_shrink_after` is retired, so
//!   request conservation across pool-size changes is trivial and the
//!   DST invariant checker audits it every event.
//!
//! Scale decisions are pure functions of observed simulation state
//! (counters, load snapshots, the DES clock) — never the wall clock —
//! so seeded runs replay their scale decisions bit-identically.
//! Reports bill `instance_seconds` from spawn to retirement; the
//! `autoscale-fleet` experiment compares a fixed fleet sized for peak
//! against an elastic fleet on exactly that quantity under a
//! diurnal+bursty arrival process.
//!
//! # Structure
//!
//! * [`ClusterSim`] — N [`Instance`](crate::serving::Instance)s (each a
//!   batcher + engine + KV budget, the exact state machine
//!   [`ServingSim`](crate::serving::ServingSim) drives alone)
//!   multiplexed on one [`EventQueue`](crate::des::EventQueue) of
//!   [`InstanceEvent`](crate::serving::InstanceEvent)s keyed by
//!   instance id, so cross-instance causality is totally ordered and
//!   seeded runs replay exactly. All request state lives in one
//!   [`RequestArena`](crate::serving::RequestArena) owned by the
//!   simulator; events, routers, and batchers carry dense
//!   [`ReqId`](crate::serving::ReqId) handles, so the hot path moves
//!   4-byte ids instead of cloning `Request` structs and steady-state
//!   stepping allocates nothing.
//! * [`Router`] — pluggable front-door policy: [`RoundRobin`] (cursor
//!   on the last-picked id, so it stays fair as instances join and
//!   leave), [`LeastOutstandingTokens`], or [`SloAdmission`] (sheds
//!   requests whose predicted TTFT exceeds the target; cold instances
//!   are priced at the warm peers' mean cadence, never at 0).
//! * [`ClusterMode::Disaggregated`] — dedicated prefill instances
//!   ingest prompts, then ship each request's KV
//!   (`context_len * kv_bytes_per_token` bytes) to the least-committed
//!   decode instance; every output token (including the first) comes
//!   from the decode pool, so the transfer stall lands in TTFT. Load
//!   snapshots fold in-transit KV into `outstanding_kv_bytes`, so
//!   routers and `pick_decode` see the same committed footprint.
//! * [`ClusterReport`] — per-instance
//!   [`ServingReport`](crate::serving::ServingReport)s plus a merged
//!   cluster report whose percentiles are recomputed over the pooled
//!   per-request samples, per-pool utilization, scale-out efficiency
//!   (tokens/s/instance), billed instance-seconds and scale-action
//!   counts, and JSON export for experiment artifacts.
//!
//! A one-instance colocated cluster behind a pass-through router is
//! step-for-step identical to [`ServingSim`](crate::serving::ServingSim)
//! — the equivalence test in `tests/integration_cluster.rs` anchors the
//! whole layer to the validated single-instance simulator.

mod autoscale;
mod report;
mod router;
mod sim;

pub use autoscale::{AutoscalePolicy, EngineFactory, InstanceState};
pub use report::{ClusterReport, PoolStats};
pub use router::{
    InstanceLoad, LeastOutstandingTokens, Role, RoundRobin, Router,
    SloAdmission,
};
pub use sim::{ClusterMode, ClusterSim, ClusterSpec};
