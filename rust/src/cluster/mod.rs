//! Cluster-scale serving: N model instances behind a router, colocated
//! or disaggregated into prefill/decode pools.
//!
//! # Why a cluster layer, per the paper
//!
//! LIMINAL's limit study ends where scale-up ends: past ~10k tokens/s
//! per user the binding constraints are **collective communication**
//! (the tiered all-reduce latency that flattens decode scaling beyond
//! 16-chip TP domains) and **capacity** (KV cache competing with
//! weights). Neither constraint yields to a bigger box — the paper caps
//! TP at 128 chips outright — so production systems attack them by
//! scaling *out*: many model instances behind a router, each instance
//! staying inside the sync-latency sweet spot, with cluster throughput
//! multiplying in instances instead of dividing into collectives.
//! Disaggregating prefill from decode is the same argument applied to
//! the roofline: prefill is compute-bound, decode is bandwidth-bound,
//! and a fused step must run both at whichever roofline is slower. A
//! dedicated prefill pool feeds a decode pool over the scale-out
//! interconnect — KV bytes at [`ClusterSpec::kv_link_bw`], paid before
//! decode admission — trading a per-request shipment stall for keeping
//! every pool at its own roofline, with the decode pool reverting to
//! the paper's decode-only pricing. The `cluster-scaling` experiment
//! measures both sides of that trade.
//!
//! # Structure
//!
//! * [`ClusterSim`] — N [`Instance`](crate::serving::Instance)s (each a
//!   batcher + engine + KV budget, the exact state machine
//!   [`ServingSim`](crate::serving::ServingSim) drives alone)
//!   multiplexed on one [`EventQueue`](crate::des::EventQueue) of
//!   [`InstanceEvent`](crate::serving::InstanceEvent)s keyed by
//!   instance id, so cross-instance causality is totally ordered and
//!   seeded runs replay exactly. All request state lives in one
//!   [`RequestArena`](crate::serving::RequestArena) owned by the
//!   simulator; events, routers, and batchers carry dense
//!   [`ReqId`](crate::serving::ReqId) handles, so the hot path moves
//!   4-byte ids instead of cloning `Request` structs and steady-state
//!   stepping allocates nothing.
//! * [`Router`] — pluggable front-door policy: [`RoundRobin`],
//!   [`LeastOutstandingTokens`], or [`SloAdmission`] (sheds requests
//!   whose predicted TTFT exceeds the target).
//! * [`ClusterMode::Disaggregated`] — dedicated prefill instances
//!   ingest prompts, then ship each request's KV
//!   (`context_len * kv_bytes_per_token` bytes) to the least-committed
//!   decode instance; every output token (including the first) comes
//!   from the decode pool, so the transfer stall lands in TTFT.
//! * [`ClusterReport`] — per-instance
//!   [`ServingReport`](crate::serving::ServingReport)s plus a merged
//!   cluster report whose percentiles are recomputed over the pooled
//!   per-request samples, per-pool utilization, scale-out efficiency
//!   (tokens/s/instance), and JSON export for experiment artifacts.
//!
//! A one-instance colocated cluster behind a pass-through router is
//! step-for-step identical to [`ServingSim`](crate::serving::ServingSim)
//! — the equivalence test in `tests/integration_cluster.rs` anchors the
//! whole layer to the validated single-instance simulator.

mod report;
mod router;
mod sim;

pub use report::{ClusterReport, PoolStats};
pub use router::{
    InstanceLoad, LeastOutstandingTokens, Role, RoundRobin, Router,
    SloAdmission,
};
pub use sim::{ClusterMode, ClusterSim, ClusterSpec};
