//! Architecture hyper-parameters (paper Table 3).

/// Multi-head Latent Attention hyper-parameters (DeepSeekV3 only).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MlaSpec {
    /// `F` — query latent (LoRA) dimension.
    pub q_latent: u64,
    /// `G` — KV latent dimension (what gets cached per token).
    pub kv_latent: u64,
    /// `R` — decoupled rotary position embedding dimension.
    pub rope_dim: u64,
}

/// Mixture-of-Experts hyper-parameters (DeepSeekV3 only).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MoeSpec {
    /// `MD` — per-expert projection (intermediate) dimension.
    pub proj_dim: u64,
    /// `MS` — number of always-active shared experts.
    pub shared_experts: u64,
    /// `MR` — number of routed experts.
    pub routed_experts: u64,
    /// `MA` — number of routed experts activated per token.
    pub activated_experts: u64,
}

/// Hyper-parameters of one LLM architecture (paper Table 3).
///
/// All three studied models are expressible with this one struct: the
/// Llama models leave `mla`/`moe` as `None`, DeepSeekV3 sets both.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    /// Canonical name, e.g. `llama3-405b`.
    pub name: String,
    /// `L` — number of transformer layers.
    pub num_layers: u64,
    /// Number of leading layers that use a dense FFN instead of MoE
    /// (equals `num_layers` for dense models; 3 for DeepSeekV3).
    pub num_dense_layers: u64,
    /// `D` — embedding (model) dimension.
    pub embed_dim: u64,
    /// `H` — number of attention (query) heads.
    pub heads: u64,
    /// `K` — number of KV heads (grouped-query attention).
    pub kv_heads: u64,
    /// `E` — head dimension.
    pub head_dim: u64,
    /// `V` — FFN intermediate dimension.
    pub intermediate_dim: u64,
    /// Vocabulary size (for embedding + LM-head weight accounting).
    pub vocab: u64,
    /// Bytes per weight/activation element (1.0 = FP8, the paper's
    /// default; 0.5 models FP4 as in the Appendix E validation).
    pub elem_bytes: f64,
    /// Multi-head latent attention parameters, if the model uses MLA.
    pub mla: Option<MlaSpec>,
    /// Mixture-of-experts parameters, if the model uses MoE.
    pub moe: Option<MoeSpec>,
}

impl ModelSpec {
    /// Number of MoE layers (`L - num_dense_layers` when MoE is present).
    pub fn num_moe_layers(&self) -> u64 {
        if self.moe.is_some() {
            self.num_layers - self.num_dense_layers
        } else {
            0
        }
    }

    /// Llama3-70B (Table 3, column 1).
    pub fn llama3_70b() -> Self {
        ModelSpec {
            name: "llama3-70b".into(),
            num_layers: 80,
            num_dense_layers: 80,
            embed_dim: 8192,
            heads: 64,
            kv_heads: 8,
            head_dim: 128,
            intermediate_dim: 28672,
            vocab: 128_256,
            elem_bytes: 1.0,
            mla: None,
            moe: None,
        }
    }

    /// Llama3-405B (Table 3, column 2).
    pub fn llama3_405b() -> Self {
        ModelSpec {
            name: "llama3-405b".into(),
            num_layers: 126,
            num_dense_layers: 126,
            embed_dim: 16384,
            heads: 128,
            kv_heads: 8,
            head_dim: 128,
            intermediate_dim: 53248,
            vocab: 128_256,
            elem_bytes: 1.0,
            mla: None,
            moe: None,
        }
    }

    /// DeepSeekV3-671B (Table 3, column 3).
    pub fn deepseek_v3() -> Self {
        ModelSpec {
            name: "deepseek-v3".into(),
            num_layers: 61,
            num_dense_layers: 3,
            embed_dim: 7168,
            heads: 128,
            kv_heads: 128,
            head_dim: 128,
            intermediate_dim: 18432,
            vocab: 129_280,
            elem_bytes: 1.0,
            mla: Some(MlaSpec {
                q_latent: 1536,
                kv_latent: 512,
                rope_dim: 64,
            }),
            moe: Some(MoeSpec {
                proj_dim: 2048,
                shared_experts: 1,
                routed_experts: 256,
                activated_experts: 8,
            }),
        }
    }
}
