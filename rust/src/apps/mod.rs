//! Application layer: LLM architectures abstracted as op counts, data
//! volumes, and synchronization requirements (paper §2.2 + Appendix A).
//!
//! An [`Application`] turns a decode-step working point (batch size `B`,
//! context length `T`, output length `S = 1`) into a [`Workload`]: total
//! tensor FLOPs, scalar FLOPs, bytes read from backing memory, and the
//! number of collective operations per layer. The analytical model in
//! [`crate::model`] combines a `Workload` with a hardware description to
//! produce latency and throughput.

mod deepseek;
mod llama;
mod registry;
mod spec;
mod workload;

pub use deepseek::DeepSeekV3;
pub use llama::Llama3;
pub use registry::Registry;
pub use spec::{MlaSpec, ModelSpec, MoeSpec};
pub use workload::{MoeLatencyInputs, OpCounts, Traffic, Workload};

/// Number of scalar FLOPs charged per softmax element (exp, subtract-max,
/// running max, sum, divide). The scalar term is orders of magnitude below
/// the tensor/memory terms for every configuration in the paper, so the
/// exact constant is immaterial to reproduction; see `model::latency`.
pub const SOFTMAX_OPS_PER_ELEM: f64 = 5.0;

/// Scalar FLOPs charged per normalized element (square, accumulate,
/// rsqrt-apply, scale) for RMSNorm.
pub const NORM_FLOPS_PER_ELEM: f64 = 4.0;

/// A decode-phase working point: `B` users each generating one token
/// conditioned on `T` tokens of context.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DecodePoint {
    /// Mini-batch size (number of simultaneous users).
    pub batch: u64,
    /// Per-user context length in tokens (every user at the same length,
    /// as in all of the paper's experiments).
    pub context: u64,
}

/// A prefill-phase working point: `B` sequences each ingesting
/// `new_tokens` prompt tokens on top of `past_tokens` already resident
/// in the KV cache (`past_tokens > 0` models a later chunk of a chunked
/// prefill; `0` is the first chunk of a fresh prompt).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PrefillPoint {
    /// Sequences prefilling together.
    pub batch: u64,
    /// New prompt tokens ingested per sequence this step.
    pub new_tokens: u64,
    /// Tokens already in the KV cache per sequence (earlier chunks).
    pub past_tokens: u64,
}

/// Total attended key positions for `new` causally-masked query tokens
/// appended after `past` cached tokens:
/// `sum_{i=1..new} (past + i) = new*past + new*(new+1)/2`.
///
/// This is the exact quantity that makes chunked prefill conserve
/// attention FLOPs: splitting a prompt into chunks leaves the sum over
/// chunks identical to the one-shot value.
pub fn causal_attended(past: u64, new: u64) -> f64 {
    let (p, n) = (past as f64, new as f64);
    n * p + n * (n + 1.0) / 2.0
}

/// An LLM architecture the model can analyze.
///
/// Implementations translate the architecture hyper-parameters (paper
/// Table 3) into the FLOP and byte-traffic equations of Appendix A.
pub trait Application: Send + Sync {
    /// Architecture hyper-parameters.
    fn spec(&self) -> &ModelSpec;

    /// Canonical lower-case identifier (e.g. `llama3-405b`).
    fn name(&self) -> &str {
        &self.spec().name
    }

    /// Total model weight bytes (all layers + embeddings + LM head).
    fn weight_bytes(&self) -> f64;

    /// KV-cache bytes appended per token per layer (the quantity the
    /// paper calls `kv_elem_per_tok * elem_bytes`).
    fn kv_bytes_per_token_layer(&self) -> f64;

    /// KV-cache bytes per token across all layers.
    fn kv_bytes_per_token(&self) -> f64 {
        self.kv_bytes_per_token_layer() * self.spec().num_layers as f64
    }

    /// Tensor + scalar op counts for one decode step at `pt`.
    fn op_counts(&self, pt: &DecodePoint) -> OpCounts;

    /// Memory traffic for one decode step at `pt`.
    fn traffic(&self, pt: &DecodePoint) -> Traffic;

    /// Tensor + scalar op counts for one prefill chunk at `pt`: the
    /// full projection/FFN matmuls for every new prompt token plus
    /// causally-masked attention over `past + new` positions.
    fn prefill_op_counts(&self, pt: &PrefillPoint) -> OpCounts;

    /// Memory traffic for one prefill chunk at `pt`. Weights stream
    /// once per chunk (which is exactly the cost chunked prefill trades
    /// against step-latency isolation); the chunk's KV is written back
    /// and earlier chunks' KV is re-read for attention.
    fn prefill_traffic(&self, pt: &PrefillPoint) -> Traffic;

    /// Complete workload description for one prefill chunk.
    fn prefill_workload(&self, pt: &PrefillPoint) -> Workload {
        Workload {
            ops: self.prefill_op_counts(pt),
            traffic: self.prefill_traffic(pt),
            sync_ops_per_layer: 3.0,
            num_layers: self.spec().num_layers,
            num_moe_layers: self.spec().num_moe_layers(),
            moe: None,
        }
    }

    /// Complete workload description for one decode step.
    fn workload(&self, pt: &DecodePoint) -> Workload {
        Workload {
            ops: self.op_counts(pt),
            traffic: self.traffic(pt),
            sync_ops_per_layer: 3.0,
            num_layers: self.spec().num_layers,
            num_moe_layers: self.spec().num_moe_layers(),
            moe: None,
        }
    }

    /// Total memory capacity required (weights + KV cache) in bytes.
    fn capacity_bytes(&self, pt: &DecodePoint) -> f64 {
        self.weight_bytes()
            + pt.batch as f64 * pt.context as f64 * self.kv_bytes_per_token()
    }

    /// Arithmetic intensity in FLOPs/byte for one decode step, as defined
    /// for Table 4 (total tensor ops over total bytes read).
    fn arithmetic_intensity(&self, pt: &DecodePoint) -> f64 {
        let ops = self.op_counts(pt);
        let traffic = self.traffic(pt);
        ops.tensor / traffic.total_rd_bytes()
    }
}
