//! Model registry: name -> [`Application`] lookup for the CLI, config
//! system, and experiment harness.

use std::collections::BTreeMap;
use std::sync::Arc;

use super::{Application, DeepSeekV3, Llama3, ModelSpec};

/// A registry of known applications keyed by canonical name.
#[derive(Clone)]
pub struct Registry {
    apps: BTreeMap<String, Arc<dyn Application>>,
}

impl Registry {
    /// Empty registry.
    pub fn new() -> Self {
        Registry { apps: BTreeMap::new() }
    }

    /// Registry pre-populated with the paper's three models.
    pub fn builtin() -> Self {
        let mut r = Registry::new();
        r.register(Arc::new(Llama3::llama3_70b()));
        r.register(Arc::new(Llama3::llama3_405b()));
        r.register(Arc::new(DeepSeekV3::v3()));
        r
    }

    /// Register an application under its spec name. Replaces any existing
    /// entry with the same name.
    pub fn register(&mut self, app: Arc<dyn Application>) {
        self.apps.insert(app.name().to_string(), app);
    }

    /// Register a model from a bare spec, dispatching on whether it has
    /// MLA/MoE parameters.
    pub fn register_spec(&mut self, spec: ModelSpec) {
        if spec.mla.is_some() && spec.moe.is_some() {
            self.register(Arc::new(DeepSeekV3::new(spec)));
        } else {
            self.register(Arc::new(Llama3::new(spec)));
        }
    }

    /// Look up an application by name (case-insensitive).
    pub fn app(&self, name: &str) -> Option<Arc<dyn Application>> {
        self.apps.get(&name.to_ascii_lowercase()).cloned()
    }

    /// All registered application names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.apps.keys().cloned().collect()
    }

    /// All registered applications, sorted by name.
    pub fn all(&self) -> Vec<Arc<dyn Application>> {
        self.apps.values().cloned().collect()
    }
}

impl Default for Registry {
    fn default() -> Self {
        Registry::builtin()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_has_three_models() {
        let r = Registry::builtin();
        assert_eq!(
            r.names(),
            vec!["deepseek-v3", "llama3-405b", "llama3-70b"]
        );
    }

    #[test]
    fn lookup_is_case_insensitive() {
        let r = Registry::builtin();
        assert!(r.app("Llama3-70B").is_some());
        assert!(r.app("no-such-model").is_none());
    }

    #[test]
    fn register_spec_dispatches_on_architecture() {
        let mut r = Registry::new();
        r.register_spec(ModelSpec::llama3_70b());
        r.register_spec(ModelSpec::deepseek_v3());
        assert_eq!(r.all().len(), 2);
    }
}
