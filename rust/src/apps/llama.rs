//! Llama 3 workload equations (paper Appendix A.1).
//!
//! A standard dense transformer with grouped-query attention: per layer a
//! QKV projection, attention over the cached context, an output
//! projection, and a SwiGLU FFN (gate/up/down). At decode, `S = 1`.

use super::{
    causal_attended, Application, DecodePoint, ModelSpec, OpCounts, PrefillPoint,
    Traffic, NORM_FLOPS_PER_ELEM, SOFTMAX_OPS_PER_ELEM,
};

/// A Llama-3-family dense model (70B or 405B in the paper).
#[derive(Debug, Clone)]
pub struct Llama3 {
    spec: ModelSpec,
}

impl Llama3 {
    /// Wrap a dense `ModelSpec`. Panics if the spec carries MLA/MoE
    /// parameters (those belong to [`super::DeepSeekV3`]).
    pub fn new(spec: ModelSpec) -> Self {
        assert!(
            spec.mla.is_none() && spec.moe.is_none(),
            "Llama3 is a dense GQA model; got MLA/MoE parameters"
        );
        Llama3 { spec }
    }

    /// The 70-billion-parameter configuration.
    pub fn llama3_70b() -> Self {
        Llama3::new(ModelSpec::llama3_70b())
    }

    /// The 405-billion-parameter configuration.
    pub fn llama3_405b() -> Self {
        Llama3::new(ModelSpec::llama3_405b())
    }

    /// Weight *elements* in one transformer layer: Q/K/V/O projections
    /// plus the three SwiGLU FFN matrices.
    fn layer_weight_elems(&self) -> f64 {
        let s = &self.spec;
        let (d, h, k, e, v) = (
            s.embed_dim as f64,
            s.heads as f64,
            s.kv_heads as f64,
            s.head_dim as f64,
            s.intermediate_dim as f64,
        );
        let wq = d * h * e;
        let wk = d * k * e;
        let wv = d * k * e;
        let wo = h * e * d;
        let ffn = 3.0 * d * v; // gate + up + down
        wq + wk + wv + wo + ffn
    }
}

impl Application for Llama3 {
    fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    /// Total weights: `L` layers plus untied input embedding and LM head
    /// (`2 * vocab * D`). Reproduces the official parameter counts:
    /// 70.55e9 for Llama3-70B and 405.85e9 for Llama3-405B.
    fn weight_bytes(&self) -> f64 {
        let s = &self.spec;
        let elems = self.layer_weight_elems() * s.num_layers as f64
            + 2.0 * s.vocab as f64 * s.embed_dim as f64;
        elems * s.elem_bytes
    }

    /// GQA caches `K` and `V` per KV head: `2 * K * E` elements/token/layer.
    fn kv_bytes_per_token_layer(&self) -> f64 {
        let s = &self.spec;
        2.0 * s.kv_heads as f64 * s.head_dim as f64 * s.elem_bytes
    }

    fn op_counts(&self, pt: &DecodePoint) -> OpCounts {
        let s = &self.spec;
        let b = pt.batch as f64;
        let t = pt.context as f64;
        let sq = 1.0; // S: output tokens per step
        let (d, h, k, e, v) = (
            s.embed_dim as f64,
            s.heads as f64,
            s.kv_heads as f64,
            s.head_dim as f64,
            s.intermediate_dim as f64,
        );

        // Appendix A.1, verbatim.
        let q_flops = b * h * sq * d * e * 2.0;
        let k_flops = b * k * sq * d * e * 2.0;
        let v_flops = b * k * sq * d * e * 2.0;
        let qkv_flops = q_flops + k_flops + v_flops;

        let qk_flops = b * h * t * e * sq * 2.0;
        let av_flops = b * h * t * e * sq * 2.0;
        let out_flops = b * sq * (h * e) * d * 2.0;
        let attn_flops = qk_flops + av_flops + out_flops;

        let gate_flops = b * sq * d * v * 2.0;
        let up_flops = b * sq * d * v * 2.0;
        let down_flops = b * sq * d * v * 2.0;
        let ffn_flops = gate_flops + up_flops + down_flops;

        let softmax_scalar = b * h * t * sq * SOFTMAX_OPS_PER_ELEM;
        let r1_scalar = b * sq * d * NORM_FLOPS_PER_ELEM;
        let r2_scalar = b * sq * d * NORM_FLOPS_PER_ELEM;

        let layers = s.num_layers as f64;
        OpCounts {
            tensor: (qkv_flops + attn_flops + ffn_flops) * layers,
            scalar: (softmax_scalar + r1_scalar + r2_scalar) * layers,
        }
    }

    fn traffic(&self, pt: &DecodePoint) -> Traffic {
        let s = &self.spec;
        let b = pt.batch as f64;
        let t = pt.context as f64;
        let per_tok_layer = self.kv_bytes_per_token_layer();
        let layers = s.num_layers as f64;
        Traffic {
            weight_rd_bytes: self.weight_bytes(),
            kv_rd_bytes: b * t * per_tok_layer * layers,
            kv_wr_bytes: b * 1.0 * per_tok_layer * layers,
        }
    }

    /// Prefill: the same per-layer operators as decode but with `P` new
    /// tokens per sequence, and causally-masked attention over the
    /// already-cached prefix plus the chunk itself.
    fn prefill_op_counts(&self, pt: &PrefillPoint) -> OpCounts {
        let s = &self.spec;
        let b = pt.batch as f64;
        let p = pt.new_tokens as f64;
        let attended = causal_attended(pt.past_tokens, pt.new_tokens);
        let (d, h, k, e, v) = (
            s.embed_dim as f64,
            s.heads as f64,
            s.kv_heads as f64,
            s.head_dim as f64,
            s.intermediate_dim as f64,
        );

        // Projections and FFN scale with the new tokens (A.1 with S = P).
        let qkv_flops = b * p * (h + 2.0 * k) * d * e * 2.0;
        let out_flops = b * p * (h * e) * d * 2.0;
        let ffn_flops = 3.0 * b * p * d * v * 2.0;

        // QK^T and AV scale with attended key positions per head.
        let qk_flops = b * h * attended * e * 2.0;
        let av_flops = b * h * attended * e * 2.0;

        let softmax_scalar = b * h * attended * SOFTMAX_OPS_PER_ELEM;
        let norm_scalar = 2.0 * b * p * d * NORM_FLOPS_PER_ELEM;

        let layers = s.num_layers as f64;
        OpCounts {
            tensor: (qkv_flops + qk_flops + av_flops + out_flops + ffn_flops) * layers,
            scalar: (softmax_scalar + norm_scalar) * layers,
        }
    }

    /// Prefill traffic: one pass over the weights for the chunk, the
    /// cached prefix re-read for attention, and the chunk's KV written.
    /// The chunk's own K/V is consumed out of on-chip storage by the
    /// fused attention kernel (limit-study idealization).
    fn prefill_traffic(&self, pt: &PrefillPoint) -> Traffic {
        let s = &self.spec;
        let b = pt.batch as f64;
        let per_tok_layer = self.kv_bytes_per_token_layer();
        let layers = s.num_layers as f64;
        Traffic {
            weight_rd_bytes: self.weight_bytes(),
            kv_rd_bytes: b * pt.past_tokens as f64 * per_tok_layer * layers,
            kv_wr_bytes: b * pt.new_tokens as f64 * per_tok_layer * layers,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weight_bytes_match_official_param_counts() {
        // FP8: one byte per parameter, so bytes == parameter count.
        let m70 = Llama3::llama3_70b();
        let m405 = Llama3::llama3_405b();
        assert!((m70.weight_bytes() - 70.55e9).abs() / 70.55e9 < 0.005);
        assert!((m405.weight_bytes() - 405.85e9).abs() / 405.85e9 < 0.005);
    }

    #[test]
    fn kv_cache_matches_paper_intro_example() {
        // Paper §1: "A *single* user at 64K context consumes 15.75 GB of
        // KV-cache" for Llama3-405B (GB == GiB in the paper's tables).
        let m = Llama3::llama3_405b();
        let bytes = 65536.0 * m.kv_bytes_per_token();
        assert!((bytes / crate::GIB - 15.75).abs() < 0.05);
    }

    #[test]
    fn capacity_matches_table4_b1() {
        // Table 4, B=1, T=1K: 65 GB (70B) and 377 GB (405B).
        let pt = DecodePoint { batch: 1, context: 1024 };
        let c70 = Llama3::llama3_70b().capacity_bytes(&pt) / crate::GIB;
        let c405 = Llama3::llama3_405b().capacity_bytes(&pt) / crate::GIB;
        assert!((c70 - 65.0).abs() < 1.0, "got {c70}");
        assert!((c405 - 377.0).abs() < 1.5, "got {c405}");
    }

    #[test]
    fn capacity_matches_table4_b32_64k() {
        // Table 4, B=32, T=64K: 385 GB (70B), 881 GB (405B).
        let pt = DecodePoint { batch: 32, context: 65536 };
        let c70 = Llama3::llama3_70b().capacity_bytes(&pt) / crate::GIB;
        let c405 = Llama3::llama3_405b().capacity_bytes(&pt) / crate::GIB;
        assert!((c70 - 385.0).abs() < 2.0, "got {c70}");
        assert!((c405 - 881.0).abs() < 3.0, "got {c405}");
    }

    #[test]
    fn ami_matches_table4() {
        // Table 4 AMI: Llama3-70B B=1/T=1K -> 1.99; B=32/T=128K -> 20.31.
        let m = Llama3::llama3_70b();
        let a = m.arithmetic_intensity(&DecodePoint { batch: 1, context: 1024 });
        assert!((a - 1.99).abs() < 0.05, "got {a}");
        let a = m.arithmetic_intensity(&DecodePoint { batch: 32, context: 131072 });
        assert!((a - 20.31).abs() / 20.31 < 0.03, "got {a}");

        // Llama3-405B B=32/T=4K -> 61.04.
        let m = Llama3::llama3_405b();
        let a = m.arithmetic_intensity(&DecodePoint { batch: 32, context: 4096 });
        assert!((a - 61.04).abs() / 61.04 < 0.03, "got {a}");
    }

    #[test]
    fn ami_converges_to_attention_asymptote() {
        // Appendix A.3: Llama3-405B AMI converges to 32 FLOPs/byte as T
        // grows (attention dominates; 2*2*H*E*T flops over 2*K*E*T bytes
        // read = 2*H/K = 32).
        let m = Llama3::llama3_405b();
        let a = m.arithmetic_intensity(&DecodePoint { batch: 32, context: 1 << 24 });
        assert!((a - 32.0).abs() < 1.0, "got {a}");
    }

    #[test]
    fn flops_scale_linearly_in_batch() {
        let m = Llama3::llama3_70b();
        let o1 = m.op_counts(&DecodePoint { batch: 1, context: 8192 });
        let o4 = m.op_counts(&DecodePoint { batch: 4, context: 8192 });
        assert!((o4.tensor / o1.tensor - 4.0).abs() < 1e-9);
        assert!((o4.scalar / o1.scalar - 4.0).abs() < 1e-9);
    }

    #[test]
    fn chunked_prefill_conserves_flops() {
        // Splitting a 4K prompt into two 2K chunks must cost exactly the
        // same tensor FLOPs as the one-shot prefill (causal attention
        // over the prefix is what the second chunk re-pays in reads, not
        // in math).
        let m = Llama3::llama3_70b();
        let whole = m.prefill_op_counts(&PrefillPoint {
            batch: 1,
            new_tokens: 4096,
            past_tokens: 0,
        });
        let c1 = m.prefill_op_counts(&PrefillPoint {
            batch: 1,
            new_tokens: 2048,
            past_tokens: 0,
        });
        let c2 = m.prefill_op_counts(&PrefillPoint {
            batch: 1,
            new_tokens: 2048,
            past_tokens: 2048,
        });
        let split = c1.add(c2);
        assert!((whole.tensor - split.tensor).abs() / whole.tensor < 1e-12);
        assert!((whole.scalar - split.scalar).abs() / whole.scalar < 1e-12);
    }

    #[test]
    fn prefill_flops_dwarf_decode_flops_per_step() {
        // A 1K-token prefill chunk performs ~1000x the matmul work of a
        // single decode token — the reason prefill steps go compute
        // bound while decode stays memory bound.
        let m = Llama3::llama3_70b();
        let pre = m.prefill_op_counts(&PrefillPoint {
            batch: 1,
            new_tokens: 1024,
            past_tokens: 0,
        });
        let dec = m.op_counts(&DecodePoint { batch: 1, context: 1024 });
        assert!(pre.tensor > 900.0 * dec.tensor, "{} vs {}", pre.tensor, dec.tensor);
    }

    #[test]
    fn prefill_traffic_writes_chunk_and_rereads_prefix() {
        let m = Llama3::llama3_70b();
        let t = m.prefill_traffic(&PrefillPoint {
            batch: 2,
            new_tokens: 512,
            past_tokens: 1024,
        });
        let per_tok = m.kv_bytes_per_token();
        assert_eq!(t.kv_wr_bytes, 2.0 * 512.0 * per_tok);
        assert_eq!(t.kv_rd_bytes, 2.0 * 1024.0 * per_tok);
        assert_eq!(t.weight_rd_bytes, m.weight_bytes());
    }
}
