//! DeepSeekV3 workload equations (paper Appendix A.2).
//!
//! Two architectural departures from Llama matter to the limit study:
//!
//! * **Multi-head Latent Attention (MLA):** queries/keys/values are
//!   projected through low-rank latents; only the `(G + R)`-dimensional
//!   latent is cached per token, shrinking the KV cache by ~28x versus
//!   GQA at these dimensions. Attention math runs in the *absorbed*
//!   latent form, so QK/AV cost scales with `(G + R)` per head.
//! * **Mixture of Experts (MoE):** 58 of 61 layers replace the FFN with
//!   256 routed experts (8 activated per token) + 1 shared expert. The
//!   learned router's statistical imbalance exposes tail latency, modeled
//!   via the Monte-Carlo imbalance factor `MI` (see [`crate::moe`]).

use super::{
    causal_attended, Application, DecodePoint, MoeLatencyInputs, ModelSpec, OpCounts,
    PrefillPoint, Traffic, Workload, NORM_FLOPS_PER_ELEM, SOFTMAX_OPS_PER_ELEM,
};

/// The DeepSeekV3-671B MLA + MoE model.
#[derive(Debug, Clone)]
pub struct DeepSeekV3 {
    spec: ModelSpec,
}

impl DeepSeekV3 {
    /// Wrap an MLA + MoE `ModelSpec`. Panics if MLA/MoE parameters are
    /// missing.
    pub fn new(spec: ModelSpec) -> Self {
        assert!(
            spec.mla.is_some() && spec.moe.is_some(),
            "DeepSeekV3 requires MLA and MoE parameters"
        );
        DeepSeekV3 { spec }
    }

    /// The published 671-billion-parameter configuration.
    pub fn v3() -> Self {
        DeepSeekV3::new(ModelSpec::deepseek_v3())
    }

    /// `moe_per_token_flops = 2 * D * MD * 2` (paper A.2: two projections,
    /// down to `MD` and back up to `D`, two FLOPs per MAC).
    pub fn moe_per_token_flops(&self) -> f64 {
        let moe = self.spec.moe.unwrap();
        2.0 * self.spec.embed_dim as f64 * moe.proj_dim as f64 * 2.0
    }

    /// `max(B * S * MA / MR, 1)` — mean tokens routed to each expert.
    pub fn moe_avg_tok_per_routed_expert(&self, batch: u64) -> f64 {
        let moe = self.spec.moe.unwrap();
        f64::max(
            batch as f64 * moe.activated_experts as f64 / moe.routed_experts as f64,
            1.0,
        )
    }

    /// Attention weight elements per layer (MLA: down-projections to the
    /// latents, up-projections per head, output projection).
    fn attn_weight_elems(&self) -> f64 {
        let s = &self.spec;
        let mla = s.mla.unwrap();
        let (d, h, e) = (s.embed_dim as f64, s.heads as f64, s.head_dim as f64);
        let (f, g, r) = (mla.q_latent as f64, mla.kv_latent as f64, mla.rope_dim as f64);
        let w_dq = d * f; // query down-projection
        let w_uq = f * h * (e + r); // query up-projection (nope + rope)
        let w_dkv = d * (g + r); // KV down-projection + decoupled K rope
        let w_uk = g * h * e; // key up-projection
        let w_uv = g * h * e; // value up-projection
        let w_o = h * e * d; // output projection
        w_dq + w_uq + w_dkv + w_uk + w_uv + w_o
    }

    /// One expert MLP holds three `D x MD` matrices (gate/up/down), the
    /// real DeepSeekV3 structure — this is what makes the byte count land
    /// on the official 671e9 parameters (Table 4's 625 GiB). Note the
    /// paper's *FLOP* equation charges two projections per expert; we
    /// follow the paper for FLOPs and the real structure for bytes.
    fn expert_weight_elems(&self) -> f64 {
        let moe = self.spec.moe.unwrap();
        3.0 * self.spec.embed_dim as f64 * moe.proj_dim as f64
    }
}

impl Application for DeepSeekV3 {
    fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    fn weight_bytes(&self) -> f64 {
        let s = &self.spec;
        let moe = s.moe.unwrap();
        let (d, v) = (s.embed_dim as f64, s.intermediate_dim as f64);
        let embed = 2.0 * s.vocab as f64 * d;
        let attn = self.attn_weight_elems() * s.num_layers as f64;
        let dense_ffn = 3.0 * d * v * s.num_dense_layers as f64;
        let moe_layers = s.num_moe_layers() as f64;
        let experts = (moe.routed_experts + moe.shared_experts) as f64
            * self.expert_weight_elems();
        let router = d * moe.routed_experts as f64;
        let moe_w = (experts + router) * moe_layers;
        (embed + attn + dense_ffn + moe_w) * s.elem_bytes
    }

    /// MLA caches only the `(G + R)`-dim latent per token per layer.
    fn kv_bytes_per_token_layer(&self) -> f64 {
        let mla = self.spec.mla.unwrap();
        (mla.kv_latent + mla.rope_dim) as f64 * self.spec.elem_bytes
    }

    fn op_counts(&self, pt: &DecodePoint) -> OpCounts {
        let s = &self.spec;
        let mla = s.mla.unwrap();
        let moe = s.moe.unwrap();
        let b = pt.batch as f64;
        let t = pt.context as f64;
        let sq = 1.0;
        let (d, h, v) = (
            s.embed_dim as f64,
            s.heads as f64,
            s.intermediate_dim as f64,
        );
        let (f, g, r) = (mla.q_latent as f64, mla.kv_latent as f64, mla.rope_dim as f64);
        let (ms, mr) = (moe.shared_experts as f64, moe.routed_experts as f64);

        // Appendix A.2, verbatim. UV/UK up-projections are absorbed into
        // the query and output projections (cost 0).
        let dq_flops = b * sq * f * d * 2.0;
        let dkv_flops = b * sq * g * d * 2.0;
        let kr_flops = b * sq * r * d * 2.0;
        let uq_flops = b * sq * f * h * g * 2.0;
        let qr_flops = b * sq * f * h * r * 2.0;
        let qkv_flops = dq_flops + dkv_flops + kr_flops + uq_flops + qr_flops;

        let qk_flops = b * h * t * (g + r) * sq * 2.0;
        let av_flops = b * h * t * (g + r) * sq * 2.0;
        let out_flops = b * sq * (h * g) * d * 2.0;
        let attn_flops = qk_flops + av_flops + out_flops;

        let ffn_flops = 3.0 * (b * sq * d * v * 2.0);

        let moe_per_token_flops = self.moe_per_token_flops();
        let moe_shared = ms * b * sq * moe_per_token_flops;
        let moe_router = b * sq * d * mr * 2.0;
        let moe_avg_tok = self.moe_avg_tok_per_routed_expert(pt.batch);
        let moe_avg_routed = mr * moe_avg_tok * moe_per_token_flops;
        let moe_flops = moe_router + moe_shared + moe_avg_routed;

        let softmax_scalar = b * h * t * sq * SOFTMAX_OPS_PER_ELEM;
        let norm_scalar = 2.0 * b * sq * d * NORM_FLOPS_PER_ELEM;
        let layer_scalar = softmax_scalar + norm_scalar;

        // NOTE: the paper's A.2 pseudocode writes `dense_layer_flops =
        // qkv + attn + out + ffn`, double-counting `out_flops` (A.1 keeps
        // it inside attn_flops). We follow the A.1 convention; this only
        // matters in the deeply compute-bound large-batch corner and moves
        // Table 2's DeepSeek STPS UTPS from 18 to 14 if included.
        let dense_layer = qkv_flops + attn_flops + ffn_flops;
        let moe_layer = qkv_flops + attn_flops + moe_flops;

        let nd = s.num_dense_layers as f64;
        let nm = s.num_moe_layers() as f64;
        OpCounts {
            tensor: dense_layer * nd + moe_layer * nm,
            scalar: layer_scalar * (nd + nm),
        }
    }

    fn traffic(&self, pt: &DecodePoint) -> Traffic {
        let s = &self.spec;
        let b = pt.batch as f64;
        let t = pt.context as f64;
        let per_tok_layer = self.kv_bytes_per_token_layer();
        let layers = s.num_layers as f64;
        Traffic {
            weight_rd_bytes: self.weight_bytes(),
            kv_rd_bytes: b * t * per_tok_layer * layers,
            kv_wr_bytes: b * 1.0 * per_tok_layer * layers,
        }
    }

    /// Prefill: A.2's per-token projection/FFN/MoE math applied to `P`
    /// new tokens per sequence, with absorbed-latent attention over the
    /// causally attended prefix + chunk.
    fn prefill_op_counts(&self, pt: &PrefillPoint) -> OpCounts {
        let s = &self.spec;
        let mla = s.mla.unwrap();
        let moe = s.moe.unwrap();
        let b = pt.batch as f64;
        let p = pt.new_tokens as f64;
        let attended = causal_attended(pt.past_tokens, pt.new_tokens);
        let (d, h, v) = (
            s.embed_dim as f64,
            s.heads as f64,
            s.intermediate_dim as f64,
        );
        let (f, g, r) = (mla.q_latent as f64, mla.kv_latent as f64, mla.rope_dim as f64);
        let (ms, mr, ma) = (
            moe.shared_experts as f64,
            moe.routed_experts as f64,
            moe.activated_experts as f64,
        );

        // Latent projections, per new token (A.2 with S = P).
        let proj_flops = b * p * (f * d + g * d + r * d + f * h * g + f * h * r) * 2.0;

        // Absorbed attention over attended positions + output projection.
        let qk_flops = b * h * attended * (g + r) * 2.0;
        let av_flops = b * h * attended * (g + r) * 2.0;
        let out_flops = b * p * (h * g) * d * 2.0;
        let attn_flops = qk_flops + av_flops + out_flops;

        let ffn_flops = 3.0 * (b * p * d * v * 2.0);

        // MoE: in prefill tokens are plentiful, so routed-expert work is
        // `tokens * MA` expert-passes, floored at one pass per routed
        // expert (the same per-expert minimum as decode).
        let moe_per_token_flops = self.moe_per_token_flops();
        let moe_router = b * p * d * mr * 2.0;
        let moe_shared = ms * b * p * moe_per_token_flops;
        let moe_routed = f64::max(b * p * ma, mr) * moe_per_token_flops;
        let moe_flops = moe_router + moe_shared + moe_routed;

        let softmax_scalar = b * h * attended * SOFTMAX_OPS_PER_ELEM;
        let norm_scalar = 2.0 * b * p * d * NORM_FLOPS_PER_ELEM;

        let dense_layer = proj_flops + attn_flops + ffn_flops;
        let moe_layer = proj_flops + attn_flops + moe_flops;
        let nd = s.num_dense_layers as f64;
        let nm = s.num_moe_layers() as f64;
        OpCounts {
            tensor: dense_layer * nd + moe_layer * nm,
            scalar: (softmax_scalar + norm_scalar) * (nd + nm),
        }
    }

    /// Prefill traffic: weights once per chunk, the cached latent prefix
    /// re-read, and the chunk's `(G + R)`-dim latents written back.
    fn prefill_traffic(&self, pt: &PrefillPoint) -> Traffic {
        let s = &self.spec;
        let b = pt.batch as f64;
        let per_tok_layer = self.kv_bytes_per_token_layer();
        let layers = s.num_layers as f64;
        Traffic {
            weight_rd_bytes: self.weight_bytes(),
            kv_rd_bytes: b * pt.past_tokens as f64 * per_tok_layer * layers,
            kv_wr_bytes: b * pt.new_tokens as f64 * per_tok_layer * layers,
        }
    }

    fn workload(&self, pt: &DecodePoint) -> Workload {
        let moe = self.spec.moe.unwrap();
        Workload {
            ops: self.op_counts(pt),
            traffic: self.traffic(pt),
            sync_ops_per_layer: 3.0,
            num_layers: self.spec.num_layers,
            num_moe_layers: self.spec.num_moe_layers(),
            moe: Some(MoeLatencyInputs {
                avg_tok_per_routed_expert: self.moe_avg_tok_per_routed_expert(pt.batch),
                routed_experts: moe.routed_experts,
                activated_experts: moe.activated_experts,
                per_token_flops: self.moe_per_token_flops(),
                batch: pt.batch,
            }),
        }
    }

    fn prefill_workload(&self, pt: &PrefillPoint) -> Workload {
        // Prefill routes `B * P` tokens at once, so the imbalance model
        // sees the chunk's full token count as its "batch".
        let moe = self.spec.moe.unwrap();
        let tokens = pt.batch.saturating_mul(pt.new_tokens).max(1);
        Workload {
            ops: self.prefill_op_counts(pt),
            traffic: self.prefill_traffic(pt),
            sync_ops_per_layer: 3.0,
            num_layers: self.spec.num_layers,
            num_moe_layers: self.spec.num_moe_layers(),
            moe: Some(MoeLatencyInputs {
                avg_tok_per_routed_expert: f64::max(
                    tokens as f64 * moe.activated_experts as f64
                        / moe.routed_experts as f64,
                    1.0,
                ),
                routed_experts: moe.routed_experts,
                activated_experts: moe.activated_experts,
                per_token_flops: self.moe_per_token_flops(),
                batch: tokens,
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weight_bytes_match_official_param_count() {
        let m = DeepSeekV3::v3();
        assert!(
            (m.weight_bytes() - 671.0e9).abs() / 671.0e9 < 0.005,
            "got {}",
            m.weight_bytes()
        );
    }

    #[test]
    fn capacity_matches_table4() {
        // Table 4: B=1/T=1K -> 625 GB; B=32/T=128K -> 762 GB.
        let m = DeepSeekV3::v3();
        let c = m.capacity_bytes(&DecodePoint { batch: 1, context: 1024 }) / crate::GIB;
        assert!((c - 625.0).abs() < 2.0, "got {c}");
        let c =
            m.capacity_bytes(&DecodePoint { batch: 32, context: 131072 }) / crate::GIB;
        assert!((c - 762.0).abs() < 3.0, "got {c}");
    }

    #[test]
    fn kv_cache_is_latent_sized() {
        // (G + R) = 576 bytes/token/layer, 61 layers.
        let m = DeepSeekV3::v3();
        assert_eq!(m.kv_bytes_per_token_layer(), 576.0);
        assert_eq!(m.kv_bytes_per_token(), 576.0 * 61.0);
    }

    #[test]
    fn ami_matches_table4() {
        // Table 4 AMI: B=1/T=1K -> 1.37; B=32/T=128K -> 89.83.
        let m = DeepSeekV3::v3();
        // The paper's A.2 pseudocode is ambiguous about out_flops (it is
        // both inside attn_flops and added separately); we follow the A.1
        // convention, which lands within ~8% of the printed AMI cells.
        let a = m.arithmetic_intensity(&DecodePoint { batch: 1, context: 1024 });
        assert!((a - 1.37).abs() / 1.37 < 0.20, "got {a}");
        let a = m.arithmetic_intensity(&DecodePoint { batch: 32, context: 131072 });
        assert!((a - 89.83).abs() / 89.83 < 0.20, "got {a}");
    }

    #[test]
    fn avg_tokens_per_expert_floors_at_one() {
        let m = DeepSeekV3::v3();
        assert_eq!(m.moe_avg_tok_per_routed_expert(1), 1.0);
        assert_eq!(m.moe_avg_tok_per_routed_expert(32), 1.0);
        assert_eq!(m.moe_avg_tok_per_routed_expert(64), 2.0);
        assert_eq!(m.moe_avg_tok_per_routed_expert(1024), 32.0);
    }

    #[test]
    fn chunked_prefill_conserves_attention_flops() {
        // Projection/FFN terms are linear in the chunk size and the
        // causal-attention term telescopes, so splitting a prompt into
        // chunks conserves everything except the routed-expert floor
        // (small chunks can under-fill the 256 experts).
        let m = DeepSeekV3::v3();
        let whole = m.prefill_op_counts(&PrefillPoint {
            batch: 1,
            new_tokens: 2048,
            past_tokens: 0,
        });
        let c1 = m.prefill_op_counts(&PrefillPoint {
            batch: 1,
            new_tokens: 1024,
            past_tokens: 0,
        });
        let c2 = m.prefill_op_counts(&PrefillPoint {
            batch: 1,
            new_tokens: 1024,
            past_tokens: 1024,
        });
        let split = c1.add(c2);
        // 1024 tokens * 8 activations >> 256 experts, so the floor never
        // binds here and the counts match exactly.
        assert!((whole.tensor - split.tensor).abs() / whole.tensor < 1e-12);
        assert!((whole.scalar - split.scalar).abs() / whole.scalar < 1e-12);
    }

    #[test]
    fn prefill_workload_routes_chunk_tokens_through_moe() {
        let m = DeepSeekV3::v3();
        let wl = m.prefill_workload(&PrefillPoint {
            batch: 1,
            new_tokens: 1024,
            past_tokens: 0,
        });
        let moe = wl.moe.unwrap();
        assert_eq!(moe.batch, 1024);
        // 1024 tokens * 8 active / 256 experts = 32 tokens per expert.
        assert!((moe.avg_tok_per_routed_expert - 32.0).abs() < 1e-12);
    }

    #[test]
    fn moe_flops_grow_sublinearly_below_saturation() {
        // Below B = MR/MA = 32, routed-expert FLOPs are constant (each
        // expert is charged at least one token) — the "expert utilization"
        // reuse effect of Key Finding 7.
        let m = DeepSeekV3::v3();
        let o8 = m.op_counts(&DecodePoint { batch: 8, context: 4096 });
        let o16 = m.op_counts(&DecodePoint { batch: 16, context: 4096 });
        let ratio = o16.tensor / o8.tensor;
        assert!(ratio < 1.9, "expected sublinear growth, got {ratio}");
    }
}
