//! Workload abstraction: the paper's key insight is that an application
//! is fully characterized, for limit-study purposes, by the volume of
//! data it moves, the amount of compute it performs, and its need for
//! synchronization when parallelized (§2).

/// Operation counts for one decode step of a whole batch.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct OpCounts {
    /// FLOPs executed on the tensor (matrix) engine.
    pub tensor: f64,
    /// FLOPs executed on the scalar/vector engine (softmax, norms).
    pub scalar: f64,
}

impl OpCounts {
    /// Element-wise sum of two op-count sets.
    pub fn add(self, other: OpCounts) -> OpCounts {
        OpCounts {
            tensor: self.tensor + other.tensor,
            scalar: self.scalar + other.scalar,
        }
    }

    /// Scale both engines' counts by `k` (e.g. per-layer -> per-model).
    pub fn scale(self, k: f64) -> OpCounts {
        OpCounts {
            tensor: self.tensor * k,
            scalar: self.scalar * k,
        }
    }
}

/// Memory traffic for one decode step of a whole batch.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Traffic {
    /// Model weight bytes read (weights are read once per step; batching
    /// amortizes them — that is the paper's "weight reuse").
    pub weight_rd_bytes: f64,
    /// KV-cache bytes read across the batch (`B * T * kv_per_tok`).
    pub kv_rd_bytes: f64,
    /// KV-cache bytes written (`B * S * kv_per_tok`, i.e. one new token).
    pub kv_wr_bytes: f64,
}

impl Traffic {
    /// Total bytes read, the numerator of `T_mem` (paper §2.2: *Batch KV
    /// Bytes + Model Bytes*). Writes ride along with reads; following the
    /// paper's `batch_rd_bytes` we charge KV reads + writes + weights.
    pub fn total_rd_bytes(&self) -> f64 {
        self.weight_rd_bytes + self.kv_rd_bytes + self.kv_wr_bytes
    }

    /// Combine the traffic of two workloads fused into a single engine
    /// step (e.g. chunked prefill riding along with decode): KV streams
    /// add, but the weights stream only once — every lane of the fused
    /// step shares the same pass over the parameters.
    pub fn fuse(self, other: Traffic) -> Traffic {
        Traffic {
            weight_rd_bytes: self.weight_rd_bytes.max(other.weight_rd_bytes),
            kv_rd_bytes: self.kv_rd_bytes + other.kv_rd_bytes,
            kv_wr_bytes: self.kv_wr_bytes + other.kv_wr_bytes,
        }
    }
}

/// Inputs the latency model needs to expose MoE routing + imbalance
/// latency (paper Appendix A.2, "Modeling MoE Imbalance").
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MoeLatencyInputs {
    /// `moe_avg_tok_per_routed_expert = max(B*S*MA/MR, 1)`.
    pub avg_tok_per_routed_expert: f64,
    /// `MR` — number of routed experts.
    pub routed_experts: u64,
    /// `MA` — number of routed experts activated per token.
    pub activated_experts: u64,
    /// `moe_per_token_flops = 2 * D * MD * 2`.
    pub per_token_flops: f64,
    /// Batch size (drives the Monte-Carlo imbalance factor `MI`).
    pub batch: u64,
}

/// Everything the analytical model needs to know about one decode step.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Workload {
    /// Tensor + scalar FLOPs.
    pub ops: OpCounts,
    /// Bytes moved to/from backing memory.
    pub traffic: Traffic,
    /// Collective operations per transformer layer when tensor-parallel
    /// (the paper assumes 3: context-, head-, and FFN-parallel syncs).
    pub sync_ops_per_layer: f64,
    /// Number of transformer layers (sync ops scale with this).
    pub num_layers: u64,
    /// Number of MoE layers (0 for dense models); each contributes MoE
    /// routing latency and potential imbalance exposure.
    pub num_moe_layers: u64,
    /// MoE latency-model inputs (None for dense models).
    pub moe: Option<MoeLatencyInputs>,
}
