//! Minimal JSON: a value model, a writer, and a recursive-descent parser.
//!
//! Used for sweep-record export, experiment reports, and the AOT artifact
//! manifest (`artifacts/manifest.json`) produced by `python/compile/aot.py`.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects preserve insertion order via `BTreeMap` keys
/// being sorted — deterministic output matters more to us than order
/// fidelity.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any number (stored as f64; integers are printed without a dot).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Get an object field.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// As f64, if numeric.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// As u64, if numeric and integral.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && *n >= 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    /// As string slice.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    /// Byte offset of the error.
    pub pos: usize,
    /// What went wrong.
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u escape"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| self.err("invalid codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a":[1,2.5,-3],"b":{"c":"x\ny","d":null,"e":true}}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1].as_f64(), Some(2.5));
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn integers_print_without_dot() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(42.5).to_string(), "42.5");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_escapes_parse() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn deep_structures_parse() {
        let mut s = String::new();
        for _ in 0..64 {
            s.push('[');
        }
        s.push('1');
        for _ in 0..64 {
            s.push(']');
        }
        assert!(Json::parse(&s).is_ok());
    }
}
