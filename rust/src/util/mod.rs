//! In-tree substrate: small, dependency-free building blocks.
//!
//! This workspace builds fully offline, so everything beyond the xla
//! PJRT bindings is implemented here rather than pulled from crates.io:
//! a JSON value model + parser ([`json`]), a deterministic counter-based
//! RNG ([`rng`]), a scoped-thread parallel map ([`par`]), a
//! micro-benchmark harness ([`bench`]), and a CLI argument parser
//! ([`cli`]). Each is deliberately minimal, documented, and tested.

pub mod bench;
pub mod cli;
pub mod json;
pub mod par;
pub mod rng;
