//! Deterministic pseudo-random numbers for the Monte-Carlo models.
//!
//! PCG32 (O'Neill 2014, `pcg32_xsh_rr`) seeded through SplitMix64 —
//! small, fast, statistically solid for simulation workloads, and fully
//! reproducible across platforms. Bounded sampling uses Lemire's
//! nearly-divisionless rejection method (no modulo bias).

/// PCG32: 64-bit state, 32-bit output, period 2^64.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Seed via SplitMix64 so similar seeds diverge immediately.
    pub fn seed_from(seed: u64) -> Pcg32 {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let mut rng = Pcg32 { state: 0, inc: next() | 1 };
        rng.state = next();
        rng.next_u32();
        rng
    }

    /// Next 32 uniformly random bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 uniformly random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform integer in `[0, bound)` (Lemire rejection; `bound > 0`).
    #[inline]
    pub fn below(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u32();
            let m = (x as u64) * (bound as u64);
            let lo = m as u32;
            if lo >= bound {
                return (m >> 32) as u32;
            }
            // Slow path: exact threshold check.
            let t = bound.wrapping_neg() % bound;
            if lo >= t {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: u32, hi: u32) -> u32 {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in `[0, 1)` (53-bit mantissa).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Exponentially distributed f64 with the given rate.
    #[inline]
    pub fn exp(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        -(1.0 - self.f64()).ln() / rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Pcg32::seed_from(7);
        let mut b = Pcg32::seed_from(7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg32::seed_from(1);
        let mut b = Pcg32::seed_from(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 2);
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut rng = Pcg32::seed_from(42);
        let mut counts = [0u32; 10];
        let n = 100_000;
        for _ in 0..n {
            counts[rng.below(10) as usize] += 1;
        }
        for &c in &counts {
            // Expect 10_000 +- ~5 sigma (~500).
            assert!((c as i64 - 10_000).abs() < 600, "counts {counts:?}");
        }
    }

    #[test]
    fn f64_is_unit_interval() {
        let mut rng = Pcg32::seed_from(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn exp_has_expected_mean() {
        let mut rng = Pcg32::seed_from(9);
        let rate = 4.0;
        let mean =
            (0..20_000).map(|_| rng.exp(rate)).sum::<f64>() / 20_000.0;
        assert!((mean - 0.25).abs() < 0.02, "mean {mean}");
    }
}
