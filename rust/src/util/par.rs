//! Scoped-thread parallel map: the sweep engine's fan-out primitive.
//!
//! Items are split into `available_parallelism` contiguous chunks and
//! mapped on scoped threads; output order matches input order. For the
//! analytical sweeps each item costs microseconds, so chunking (rather
//! than work-stealing) keeps overhead negligible while still saturating
//! the machine on paper-sized grids.

use std::num::NonZeroUsize;

/// The worker count [`parallel_map`] uses: `available_parallelism`,
/// with a fallback of 1 when the platform cannot say.
pub fn default_jobs() -> usize {
    std::thread::available_parallelism()
        .map(NonZeroUsize::get)
        .unwrap_or(1)
}

/// Parallel, order-preserving map over `default_jobs()` workers.
pub fn parallel_map<T, R, F>(items: Vec<T>, f: F) -> Vec<R>
where
    T: Send + Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    parallel_map_jobs(items, default_jobs(), f)
}

/// Parallel, order-preserving map over an explicit worker count
/// (`jobs == 1` runs inline on the caller's thread; `jobs == 0` is
/// treated as 1). The output is identical to the sequential map for
/// every worker count — only wall-clock changes.
pub fn parallel_map_jobs<T, R, F>(items: Vec<T>, jobs: usize, f: F) -> Vec<R>
where
    T: Send + Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = jobs.max(1).min(n);
    if workers <= 1 || n < 4 {
        return items.iter().map(|t| f(t)).collect();
    }

    let chunk = n.div_ceil(workers);
    let mut out: Vec<Option<R>> = Vec::with_capacity(n);
    out.resize_with(n, || None);

    std::thread::scope(|scope| {
        let f = &f;
        let mut rest_items: &[T] = &items;
        let mut rest_out: &mut [Option<R>] = &mut out;
        while !rest_items.is_empty() {
            let take = chunk.min(rest_items.len());
            let (chunk_items, next_items) = rest_items.split_at(take);
            let (chunk_out, next_out) = rest_out.split_at_mut(take);
            rest_items = next_items;
            rest_out = next_out;
            scope.spawn(move || {
                for (slot, item) in chunk_out.iter_mut().zip(chunk_items) {
                    *slot = Some(f(item));
                }
            });
        }
    });

    out.into_iter().map(|r| r.expect("worker filled every slot")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = parallel_map(items, |&x| x * 2);
        assert_eq!(out.len(), 1000);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as u64 * 2);
        }
    }

    #[test]
    fn handles_empty_and_tiny_inputs() {
        assert!(parallel_map(Vec::<u32>::new(), |&x| x).is_empty());
        assert_eq!(parallel_map(vec![5], |&x| x + 1), vec![6]);
        assert_eq!(parallel_map(vec![1, 2, 3], |&x| x), vec![1, 2, 3]);
    }

    #[test]
    #[should_panic(expected = "boom at 37")]
    fn worker_panics_propagate_to_the_caller() {
        // A mapper panic must not be swallowed by the worker thread: the
        // scope join re-raises it (with its payload) on the calling
        // thread. 64 items forces the threaded path on multi-core
        // machines; the sequential fallback panics identically.
        let items: Vec<u32> = (0..64).collect();
        parallel_map(items, |&x| {
            if x == 37 {
                panic!("boom at 37");
            }
            x
        });
    }

    #[test]
    fn sizes_around_the_worker_count_preserve_order() {
        // The chunking math has its edge cases exactly around the
        // worker count: n just below it leaves threads idle, n equal
        // gives chunk size 1, n just above forces one uneven chunk.
        let w = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1);
        for n in [w.saturating_sub(1), w, w + 1, 2 * w + 1] {
            let items: Vec<usize> = (0..n).collect();
            let out = parallel_map(items, |&x| x + 1);
            assert_eq!(out.len(), n, "n={n}");
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i + 1, "n={n} slot {i}");
            }
        }
    }

    #[test]
    fn property_output_order_always_matches_input_order() {
        // Randomized sizes (seeded, so reproducible): for any n the
        // output must be the input mapped in place — the threaded and
        // sequential paths are indistinguishable to the caller.
        let mut rng = crate::util::rng::Pcg32::seed_from(0xC0FFEE);
        for round in 0..50 {
            let n = rng.below(200) as usize;
            let items: Vec<u32> = (0..n as u32).collect();
            let out = parallel_map(items, |&x| x.wrapping_mul(2654435761));
            assert_eq!(out.len(), n, "round {round}");
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, (i as u32).wrapping_mul(2654435761), "round {round}");
            }
        }
    }

    #[test]
    fn every_job_count_produces_the_sequential_result() {
        // Sharding is an implementation detail: 1 worker, an odd
        // worker count, more workers than items, and the default must
        // all return the same ordered output.
        let items: Vec<u64> = (0..97).collect();
        let expect: Vec<u64> = items.iter().map(|&x| x * 3 + 1).collect();
        for jobs in [0, 1, 2, 3, 7, 97, 200] {
            let out = parallel_map_jobs(items.clone(), jobs, |&x| x * 3 + 1);
            assert_eq!(out, expect, "jobs={jobs}");
        }
    }

    #[test]
    fn actually_runs_concurrently_when_possible() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let peak = AtomicUsize::new(0);
        let live = AtomicUsize::new(0);
        let items: Vec<u32> = (0..64).collect();
        parallel_map(items, |_| {
            let now = live.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(now, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(2));
            live.fetch_sub(1, Ordering::SeqCst);
        });
        if std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1) > 1 {
            assert!(peak.load(Ordering::SeqCst) > 1);
        }
    }
}
