//! Tiny CLI argument parser for the `liminal` launcher.
//!
//! Supports `subcommand positional... --key value --key=value --flag`.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Positional arguments in order (the first is the subcommand).
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options; bare `--flag` maps to "true".
    pub options: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.options.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process's own arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// The subcommand (first positional), if any.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    /// String option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Typed option with default; exits with a message on parse failure.
    pub fn get_parsed<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        match self.get(key) {
            None => default,
            Some(s) => s.parse().unwrap_or_else(|_| {
                eprintln!("error: --{key} expects a {}", std::any::type_name::<T>());
                std::process::exit(2);
            }),
        }
    }

    /// Boolean flag (present or `--key true/false`).
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("eval llama3-70b --tp 8 --context=4096 --json");
        assert_eq!(a.subcommand(), Some("eval"));
        assert_eq!(a.positional[1], "llama3-70b");
        assert_eq!(a.get("tp"), Some("8"));
        assert_eq!(a.get("context"), Some("4096"));
        assert!(a.flag("json"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_defaults() {
        let a = parse("eval --batch 4");
        assert_eq!(a.get_parsed("batch", 1u64), 4);
        assert_eq!(a.get_parsed("tp", 128u64), 128);
    }

    #[test]
    fn negative_numbers_are_values_not_flags() {
        let a = parse("x --offset -3");
        assert_eq!(a.get("offset"), Some("-3"));
    }
}
