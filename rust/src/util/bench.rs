//! Micro-benchmark harness for `cargo bench` (harness = false targets).
//!
//! Auto-calibrates iteration counts, reports min/median/mean per
//! iteration, and honors the standard `cargo bench <filter>` argument so
//! individual benches can be run in isolation. Results are also appended
//! as JSON lines to `target/liminal-bench.jsonl` for the perf log.

use std::hint::black_box;
use std::io::Write;
use std::time::{Duration, Instant};

use super::json::Json;

/// A bench suite: owns the CLI filter and the output sink.
pub struct Suite {
    filter: Option<String>,
    sink: Option<std::fs::File>,
}

impl Suite {
    /// Parse `cargo bench` style args (`--bench` is passed through by
    /// cargo; a bare positional is the name filter).
    pub fn from_args() -> Suite {
        let filter = std::env::args()
            .skip(1)
            .find(|a| !a.starts_with('-'));
        let sink = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open("target/liminal-bench.jsonl")
            .ok();
        Suite { filter, sink }
    }

    /// Run one benchmark: calls `f` repeatedly, auto-scaling iterations,
    /// and prints a one-line summary. Use [`black_box`] inside `f` on
    /// inputs/outputs to defeat constant folding.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) {
        if let Some(filter) = &self.filter {
            if !name.contains(filter.as_str()) {
                return;
            }
        }
        // Warm up + calibrate: find an iteration count that runs >= 20 ms.
        let mut iters: u64 = 1;
        let per_iter = loop {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            let dt = t0.elapsed();
            if dt >= Duration::from_millis(20) || iters >= (1 << 30) {
                break dt.as_secs_f64() / iters as f64;
            }
            let scale = (0.025 / dt.as_secs_f64().max(1e-9)).clamp(2.0, 100.0);
            iters = ((iters as f64) * scale) as u64;
        };

        // Measure: 11 samples of the calibrated batch.
        let mut samples: Vec<f64> = (0..11)
            .map(|_| {
                let t0 = Instant::now();
                for _ in 0..iters {
                    f();
                }
                t0.elapsed().as_secs_f64() / iters as f64
            })
            .collect();
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let min = samples[0];
        let median = samples[samples.len() / 2];
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let _ = per_iter; // calibration value; superseded by samples

        println!(
            "bench {name:<44} min {:>12} median {:>12} mean {:>12} ({iters} iters/sample)",
            fmt_dur(min),
            fmt_dur(median),
            fmt_dur(mean)
        );
        if let Some(sink) = &mut self.sink {
            let row = Json::obj(vec![
                ("name", Json::Str(name.into())),
                ("min_s", Json::Num(min)),
                ("median_s", Json::Num(median)),
                ("mean_s", Json::Num(mean)),
                ("iters", Json::Num(iters as f64)),
                (
                    "unix_ms",
                    Json::Num(
                        std::time::SystemTime::now()
                            .duration_since(std::time::UNIX_EPOCH)
                            .map(|d| d.as_millis() as f64)
                            .unwrap_or(0.0),
                    ),
                ),
            ]);
            let _ = writeln!(sink, "{row}");
        }
    }

    /// Run a benchmark whose result must not be optimized away: `f`
    /// returns a value which is black-boxed.
    pub fn bench_val<R, F: FnMut() -> R>(&mut self, name: &str, mut f: F) {
        self.bench(name, || {
            black_box(f());
        });
    }
}

/// Human-format a duration in seconds.
pub fn fmt_dur(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_dur_picks_sane_units() {
        assert_eq!(fmt_dur(2.5), "2.500 s");
        assert_eq!(fmt_dur(2.5e-3), "2.500 ms");
        assert_eq!(fmt_dur(2.5e-6), "2.500 µs");
        assert_eq!(fmt_dur(25e-9), "25.0 ns");
    }
}
