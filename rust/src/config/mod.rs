//! Config system: JSON-definable chips and models, so users can explore
//! hypothetical hardware without recompiling (one of the paper's stated
//! goals: "the ability to explore hypothetical scenarios like future
//! hardware"). Parsed with the in-tree JSON parser ([`crate::util::json`]).
//!
//! Example (`liminal.json`):
//!
//! ```json
//! {
//!   "chips": [{
//!     "name": "my-xpu", "mem_bw_tbps": 10.0, "tensor_pflops": 4.0,
//!     "scalar_pflops": 0.4, "mem_capacity_gib": 128.0,
//!     "tp_sync_flat_ns": 500.0
//!   }],
//!   "models": [{
//!     "name": "tiny", "num_layers": 4, "embed_dim": 1024, "heads": 8,
//!     "kv_heads": 2, "head_dim": 128, "intermediate_dim": 4096,
//!     "vocab": 32000
//!   }]
//! }
//! ```

use std::path::Path;

use anyhow::{bail, Context};

use crate::apps::{MlaSpec, ModelSpec, MoeSpec, Registry};
use crate::hw::{Chip, SyncModel};
use crate::util::json::Json;
use crate::{Result, GIB, PFLOPS, TBPS};

/// Parsed top-level config: extra chips and models.
#[derive(Debug, Clone, Default)]
pub struct ConfigFile {
    /// Additional chips, in internal SI units.
    pub chips: Vec<Chip>,
    /// Additional model specs.
    pub models: Vec<ModelSpec>,
}

fn num(obj: &Json, key: &str) -> Option<f64> {
    obj.get(key).and_then(Json::as_f64)
}

fn num_or(obj: &Json, key: &str, default: f64) -> f64 {
    num(obj, key).unwrap_or(default)
}

fn req_num(obj: &Json, key: &str, what: &str) -> Result<f64> {
    num(obj, key).with_context(|| format!("{what}: missing numeric field '{key}'"))
}

fn req_int(obj: &Json, key: &str, what: &str) -> Result<u64> {
    obj.get(key)
        .and_then(Json::as_u64)
        .with_context(|| format!("{what}: missing integer field '{key}'"))
}

fn req_str(obj: &Json, key: &str, what: &str) -> Result<String> {
    Ok(obj
        .get(key)
        .and_then(Json::as_str)
        .with_context(|| format!("{what}: missing string field '{key}'"))?
        .to_string())
}

/// Parse one chip definition (user-friendly units: TB/s, PFLOPS, GiB, ns).
fn parse_chip(j: &Json) -> Result<Chip> {
    let name = req_str(j, "name", "chip")?;
    let what = format!("chip '{name}'");
    let sync = match num(j, "tp_sync_flat_ns") {
        Some(ns) => SyncModel::Flat(ns * 1e-9),
        None => SyncModel::Tiered {
            le16: num_or(j, "tp_sync_le16_ns", 200.0) * 1e-9,
            gt16: num_or(j, "tp_sync_gt16_ns", 1500.0) * 1e-9,
        },
    };
    Ok(Chip {
        mem_bw: req_num(j, "mem_bw_tbps", &what)? * TBPS,
        tensor_flops: req_num(j, "tensor_pflops", &what)? * PFLOPS,
        scalar_flops: req_num(j, "scalar_pflops", &what)? * PFLOPS,
        mem_capacity: req_num(j, "mem_capacity_gib", &what)? * GIB,
        sync,
        pp_sync: num_or(j, "pp_sync_ns", 100.0) * 1e-9,
        die_area_mm2: num_or(j, "die_area_mm2", 800.0),
        mem_pj_per_bit: num_or(j, "mem_pj_per_bit", 0.0),
        notes: j
            .get("notes")
            .and_then(Json::as_str)
            .unwrap_or_default()
            .to_string(),
        name,
    })
}

/// Parse one model definition. MLA/MoE sub-objects are optional.
fn parse_model(j: &Json) -> Result<ModelSpec> {
    let name = req_str(j, "name", "model")?;
    let what = format!("model '{name}'");
    let num_layers = req_int(j, "num_layers", &what)?;
    let mla = match j.get("mla") {
        None | Some(Json::Null) => None,
        Some(m) => Some(MlaSpec {
            q_latent: req_int(m, "q_latent", &what)?,
            kv_latent: req_int(m, "kv_latent", &what)?,
            rope_dim: req_int(m, "rope_dim", &what)?,
        }),
    };
    let moe = match j.get("moe") {
        None | Some(Json::Null) => None,
        Some(m) => Some(MoeSpec {
            proj_dim: req_int(m, "proj_dim", &what)?,
            shared_experts: req_int(m, "shared_experts", &what)?,
            routed_experts: req_int(m, "routed_experts", &what)?,
            activated_experts: req_int(m, "activated_experts", &what)?,
        }),
    };
    if mla.is_some() != moe.is_some() {
        bail!("{what}: mla and moe must be specified together (DeepSeek-style) or not at all");
    }
    Ok(ModelSpec {
        num_dense_layers: j
            .get("num_dense_layers")
            .and_then(Json::as_u64)
            .unwrap_or(num_layers),
        num_layers,
        embed_dim: req_int(j, "embed_dim", &what)?,
        heads: req_int(j, "heads", &what)?,
        kv_heads: req_int(j, "kv_heads", &what)?,
        head_dim: req_int(j, "head_dim", &what)?,
        intermediate_dim: req_int(j, "intermediate_dim", &what)?,
        vocab: req_int(j, "vocab", &what)?,
        elem_bytes: num_or(j, "elem_bytes", 1.0),
        mla,
        moe,
        name,
    })
}

impl ConfigFile {
    /// Parse a JSON config document.
    pub fn from_json(s: &str) -> Result<ConfigFile> {
        let root = Json::parse(s).context("config is not valid JSON")?;
        let mut cfg = ConfigFile::default();
        if let Some(chips) = root.get("chips").and_then(Json::as_arr) {
            for c in chips {
                cfg.chips.push(parse_chip(c)?);
            }
        }
        if let Some(models) = root.get("models").and_then(Json::as_arr) {
            for m in models {
                cfg.models.push(parse_model(m)?);
            }
        }
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn load(path: &Path) -> Result<ConfigFile> {
        Self::from_json(
            &std::fs::read_to_string(path)
                .with_context(|| format!("reading config {}", path.display()))?,
        )
    }

    /// Resolve a chip by name: user-defined first, then presets.
    pub fn chip(&self, name: &str) -> Option<Chip> {
        self.chips
            .iter()
            .find(|c| c.name.eq_ignore_ascii_case(name))
            .cloned()
            .or_else(|| crate::hw::presets::by_name(name))
    }

    /// Build a registry containing builtin + user models.
    pub fn registry(&self) -> Registry {
        let mut r = Registry::builtin();
        for spec in &self.models {
            r.register_spec(spec.clone());
        }
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chip_json_roundtrips_units() {
        let cfg = ConfigFile::from_json(
            r#"{"chips":[{"name":"my-xpu","mem_bw_tbps":10.0,
                 "tensor_pflops":4.0,"scalar_pflops":0.4,
                 "mem_capacity_gib":128.0,"tp_sync_flat_ns":500.0}]}"#,
        )
        .unwrap();
        let chip = cfg.chip("my-xpu").unwrap();
        assert_eq!(chip.mem_bw, 10.0 * TBPS);
        assert_eq!(chip.mem_capacity, 128.0 * GIB);
        assert!((chip.tp_sync(128) - 500e-9).abs() < 1e-15);
        assert!((chip.pp_sync - 100e-9).abs() < 1e-15); // default
    }

    #[test]
    fn presets_resolve_through_config() {
        let cfg = ConfigFile::default();
        assert!(cfg.chip("hbm3").is_some());
        assert!(cfg.chip("xPU-COWS").is_some());
    }

    #[test]
    fn user_models_extend_registry() {
        let cfg = ConfigFile::from_json(
            r#"{"models":[{"name":"tiny-llama","num_layers":4,
                 "embed_dim":1024,"heads":8,"kv_heads":2,"head_dim":128,
                 "intermediate_dim":4096,"vocab":32000}]}"#,
        )
        .unwrap();
        let reg = cfg.registry();
        let app = reg.app("tiny-llama").unwrap();
        assert_eq!(app.spec().num_dense_layers, 4);
    }

    #[test]
    fn mla_without_moe_is_rejected() {
        let err = ConfigFile::from_json(
            r#"{"models":[{"name":"bad","num_layers":4,"embed_dim":1024,
                 "heads":8,"kv_heads":2,"head_dim":128,
                 "intermediate_dim":4096,"vocab":32000,
                 "mla":{"q_latent":1,"kv_latent":1,"rope_dim":1}}]}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("together"));
    }

    #[test]
    fn missing_fields_produce_helpful_errors() {
        let err =
            ConfigFile::from_json(r#"{"chips":[{"name":"x"}]}"#).unwrap_err();
        assert!(err.to_string().contains("mem_bw_tbps"), "{err}");
    }
}
