//! Normalization helpers for figure reproduction (the paper's figures
//! report values normalized to a baseline: Fig. 2 to HBM3-TP128, Fig. 4
//! to the 4K-context point, Fig. 5 to HBM3's STPS/W).

use super::Series;

/// Divide every y by the first point's y (Fig. 4 style: normalize a
/// context sweep to its 4K entry). No-op on empty series; panics on a
/// zero baseline.
pub fn normalize_to_first(series: &mut Series) {
    let Some(&(_, base)) = series.points.first() else { return };
    assert!(base != 0.0, "cannot normalize to a zero baseline");
    for (_, y) in &mut series.points {
        *y /= base;
    }
}

/// Divide every y by an external baseline value (Fig. 2/5 style).
pub fn normalize_series(series: &mut Series, baseline: f64) {
    assert!(baseline != 0.0, "cannot normalize to a zero baseline");
    for (_, y) in &mut series.points {
        *y /= baseline;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalize_to_first_sets_baseline_to_one() {
        let mut s = Series::new("s", "x", "y");
        s.points = vec![(0.0, 4.0), (1.0, 8.0)];
        normalize_to_first(&mut s);
        assert_eq!(s.points, vec![(0.0, 1.0), (1.0, 2.0)]);
    }

    #[test]
    fn normalize_series_uses_external_baseline() {
        let mut s = Series::new("s", "x", "y");
        s.points = vec![(0.0, 4.0), (1.0, 8.0)];
        normalize_series(&mut s, 2.0);
        assert_eq!(s.points, vec![(0.0, 2.0), (1.0, 4.0)]);
    }
}
