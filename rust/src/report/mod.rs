//! Report emitters: render experiment output as markdown tables, CSV, or
//! JSON, plus the normalization helpers the paper's figures use.

mod normalize;
mod table;

pub use normalize::{normalize_series, normalize_to_first};
pub use table::{Series, Table};

/// A complete experiment report: any number of tables plus figure series.
#[derive(Debug, Clone, Default)]
pub struct Report {
    /// Experiment identifier (e.g. `table2`, `fig3`).
    pub id: String,
    /// Human title.
    pub title: String,
    /// Free-form commentary (what the paper's artifact shows).
    pub notes: Vec<String>,
    /// Rendered tables.
    pub tables: Vec<Table>,
    /// Figure series (x/y point lists keyed by label).
    pub series: Vec<Series>,
}

impl Report {
    /// New empty report.
    pub fn new(id: &str, title: &str) -> Report {
        Report { id: id.into(), title: title.into(), ..Default::default() }
    }

    /// Render as a JSON document (tables + series, machine-readable).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("id", Json::Str(self.id.clone())),
            ("title", Json::Str(self.title.clone())),
            ("notes", Json::Arr(self.notes.iter().cloned().map(Json::Str).collect())),
            (
                "tables",
                Json::Arr(
                    self.tables
                        .iter()
                        .map(|t| {
                            Json::obj(vec![
                                ("title", Json::Str(t.title.clone())),
                                (
                                    "headers",
                                    Json::Arr(t.headers.iter().cloned().map(Json::Str).collect()),
                                ),
                                (
                                    "rows",
                                    Json::Arr(
                                        t.rows
                                            .iter()
                                            .map(|r| {
                                                Json::Arr(
                                                    r.iter().cloned().map(Json::Str).collect(),
                                                )
                                            })
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "series",
                Json::Arr(
                    self.series
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("label", Json::Str(s.label.clone())),
                                ("x", Json::Str(s.x_name.clone())),
                                ("y", Json::Str(s.y_name.clone())),
                                (
                                    "points",
                                    Json::Arr(
                                        s.points
                                            .iter()
                                            .map(|&(x, y)| {
                                                Json::Arr(vec![Json::Num(x), Json::Num(y)])
                                            })
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Render everything as a single markdown document.
    pub fn to_markdown(&self) -> String {
        let mut out = format!("# {} — {}\n\n", self.id, self.title);
        for n in &self.notes {
            out.push_str(&format!("> {n}\n"));
        }
        if !self.notes.is_empty() {
            out.push('\n');
        }
        for t in &self.tables {
            out.push_str(&t.to_markdown());
            out.push('\n');
        }
        for s in &self.series {
            out.push_str(&s.to_markdown());
            out.push('\n');
        }
        out
    }
}

/// Format a throughput the way the paper's tables do: 3 significant-ish
/// digits with K/M suffixes (`2.1K`, `337K`, `1.5M`, `86`).
pub fn fmt_tps(v: f64) -> String {
    if v >= 1e6 {
        format!("{:.1}M", v / 1e6)
    } else if v >= 10_000.0 {
        format!("{:.0}K", v / 1e3)
    } else if v >= 1000.0 {
        format!("{:.1}K", v / 1e3)
    } else if v >= 10.0 {
        format!("{:.0}", v)
    } else {
        format!("{:.1}", v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_to_json_roundtrips() {
        let mut r = Report::new("x", "t");
        let mut tbl = Table::new("tt", &["a"]);
        tbl.push_row(vec!["1".into()]);
        r.tables.push(tbl);
        let mut s = Series::new("s", "x", "y");
        s.points.push((1.0, 2.0));
        r.series.push(s);
        let j = r.to_json().to_string();
        let back = crate::util::json::Json::parse(&j).unwrap();
        assert_eq!(back.get("id").unwrap().as_str(), Some("x"));
        assert_eq!(
            back.get("tables").unwrap().as_arr().unwrap().len(),
            1
        );
    }

    #[test]
    fn fmt_tps_matches_paper_style() {
        assert_eq!(fmt_tps(2_056.0), "2.1K");
        assert_eq!(fmt_tps(337_000.0), "337K");
        assert_eq!(fmt_tps(1_500_000.0), "1.5M");
        assert_eq!(fmt_tps(86.0), "86");
        assert_eq!(fmt_tps(2.3), "2.3");
    }
}
