//! Table and series primitives.

/// A rendered table: headers plus string rows (values are pre-formatted
/// so the emitter stays dumb and the experiment controls precision).
#[derive(Debug, Clone, Default)]
pub struct Table {
    /// Table caption.
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Row-major cells.
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with headers.
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (pads/truncates to the header width).
    pub fn push_row(&mut self, cells: Vec<String>) {
        let mut cells = cells;
        cells.resize(self.headers.len(), String::new());
        self.rows.push(cells);
    }

    /// GitHub-flavored markdown rendering.
    pub fn to_markdown(&self) -> String {
        let mut out = format!("## {}\n\n", self.title);
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!(
            "|{}\n",
            self.headers.iter().map(|_| "---|").collect::<String>()
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }

    /// CSV rendering (RFC-4180-ish; cells containing commas are quoted).
    pub fn to_csv(&self) -> String {
        let quote = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self
            .headers
            .iter()
            .map(|h| quote(h))
            .collect::<Vec<_>>()
            .join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| quote(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// A figure series: labeled (x, y) points.
#[derive(Debug, Clone, Default)]
pub struct Series {
    /// Series label (legend entry).
    pub label: String,
    /// X-axis name.
    pub x_name: String,
    /// Y-axis name.
    pub y_name: String,
    /// Data points.
    pub points: Vec<(f64, f64)>,
}

impl Series {
    /// New empty series.
    pub fn new(label: &str, x_name: &str, y_name: &str) -> Series {
        Series {
            label: label.into(),
            x_name: x_name.into(),
            y_name: y_name.into(),
            points: Vec::new(),
        }
    }

    /// Render as a small markdown table (figures are data, plots are the
    /// reader's business).
    pub fn to_markdown(&self) -> String {
        let mut out = format!(
            "### series: {} ({} vs {})\n\n| {} | {} |\n|---|---|\n",
            self.label, self.y_name, self.x_name, self.x_name, self.y_name
        );
        for (x, y) in &self.points {
            out.push_str(&format!("| {x:.6} | {y:.6} |\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_table_shape() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.push_row(vec!["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| 1 | 2 |"));
    }

    #[test]
    fn csv_quotes_commas() {
        let mut t = Table::new("demo", &["a"]);
        t.push_row(vec!["x,y".into()]);
        assert!(t.to_csv().contains("\"x,y\""));
    }

    #[test]
    fn rows_are_padded_to_header_width() {
        let mut t = Table::new("demo", &["a", "b", "c"]);
        t.push_row(vec!["1".into()]);
        assert_eq!(t.rows[0].len(), 3);
    }
}
