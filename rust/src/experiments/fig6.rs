//! Figure 6 (Appendix B): the Figure 3 sync-latency study for all three
//! models.

use crate::apps::Registry;
use crate::report::Report;
use crate::Result;

use super::fig3::series_for_model;

/// Regenerate Figure 6: TP8 vs TP128 sync sweeps, all models, 128K.
pub fn run() -> Result<Report> {
    let registry = Registry::builtin();
    let mut report = Report::new(
        "fig6",
        "TP8 vs TP128 at varying sync latency, all models (128K, B=1)",
    );
    for model in ["llama3-70b", "llama3-405b", "deepseek-v3"] {
        let app = registry.app(model).unwrap();
        for mut s in series_for_model(app.as_ref(), 131072) {
            s.label = format!("{model} {}", s.label);
            report.series.push(s);
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    #[test]
    fn all_models_produce_six_series_each() {
        let r = super::run().unwrap();
        // 3 models x 3 technologies x (TP128 + TP8 ref) = 18 series.
        assert_eq!(r.series.len(), 18);
        // Every TP128 series decreases with sync latency.
        for s in r.series.iter().filter(|s| s.label.contains("TP128")) {
            let first = s.points.first().unwrap().1;
            let last = s.points.last().unwrap().1;
            assert!(first > last, "{}: {first} !> {last}", s.label);
        }
    }
}
