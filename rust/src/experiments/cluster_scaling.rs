//! Cluster-scaling study: scale-out efficiency, router policies under
//! skewed load, and colocated vs. disaggregated prefill/decode pools.
//!
//! LIMINAL's limit study stops at one model instance because collective
//! latency caps useful TP at 128 chips; everything past that point is
//! scale-*out*. This experiment prices the scale-out layer with the
//! cluster simulator:
//!
//! 1. **Efficiency** — aggregate tokens/s and tokens/s/instance as the
//!    cluster grows 1 -> 2 -> 4 -> 8 under proportional load
//!    (round-robin, colocated), via the [`ClusterGrid`] sweep. Ideal
//!    scale-out keeps the per-instance curve flat: instances share
//!    nothing, so the only losses are routing imbalance and
//!    per-instance queueing noise.
//! 2. **Routers under skewed load** — request sizes spanning 32x in
//!    prompt and generation length at overload. Round-robin counts
//!    requests and stacks giants on the same instance;
//!    least-outstanding-tokens balances actual work; SLO-aware
//!    admission sheds what no instance can serve in time, the only
//!    policy that bounds the TTFT tail past saturation. One JSON
//!    artifact per policy lands in `<artifacts>/cluster_scaling/`.
//! 3. **Disaggregation** — dedicated prefill pool vs. colocated at
//!    rising load, with KV shipped at the hardware interconnect rate.
//!    Decode-pool steps never carry prefill chunks (pure decode
//!    cadence), at the price of a per-request KV shipment stall that
//!    lands in TTFT.

use std::path::Path;

use crate::coordinator::{default_cluster_job, serve_cluster, ClusterJob, RouterPolicy};
use crate::hw::{presets, SystemConfig};
use crate::report::{Report, Table};
use crate::serving::WorkloadSpec;
use crate::sweep::{run_cluster_grid, ClusterGrid};
use crate::Result;

/// Per-instance request rate used by the efficiency sweep (light enough
/// that one instance is unsaturated, so the per-instance curve isolates
/// routing effects).
const EFFICIENCY_RATE_PER_INSTANCE: f64 = 8.0;

/// Cluster-wide arrival rate for the overload studies (parts 2 and 3):
/// well past the colocated capacity of the 8-instance study cluster.
pub const OVERLOAD_RATE: f64 = 300.0;

/// Admission TTFT target for the SLO-aware router rows: an interactive
/// 200 ms first-token budget, tight enough that overload backlogs (and
/// the largest prompts) trip the shedding path.
const SLO_TTFT_TARGET: f64 = 0.2;

/// The skewed study workload: prompts and generations each spanning a
/// 32x range, so equal request *counts* are far from equal work.
fn skewed_workload(rate: f64, n_requests: u64, seed: u64) -> WorkloadSpec {
    WorkloadSpec {
        arrival_rate: rate,
        n_requests,
        context: (256, 8192),
        gen: (16, 512),
        priority_mix: Vec::new(),
        seed,
    }
}

/// Base job: llama3-70b instances on HBM3-TP8, 16 lanes, 512-token
/// chunks, skewed workload.
fn base_job(instances: usize, prefill_instances: usize, rate: f64) -> ClusterJob {
    let sys = SystemConfig::new(presets::hbm3(), 8, 1);
    let mut job = default_cluster_job("llama3-70b", sys);
    job.instances = instances;
    job.prefill_instances = prefill_instances;
    job.max_batch = 16;
    job.prefill_chunk = 512;
    job.workload = skewed_workload(rate, 240, 17);
    job
}

/// Run the three-policy comparison at overload on 8 colocated
/// instances; returns `(policy, report)` pairs. Public so the
/// acceptance tests pin shedding/conservation without re-deriving the
/// configuration.
pub fn router_comparison() -> Result<Vec<(RouterPolicy, crate::cluster::ClusterReport)>> {
    let mut out = Vec::new();
    for policy in [
        RouterPolicy::RoundRobin,
        RouterPolicy::LeastTokens,
        RouterPolicy::SloAware,
    ] {
        let mut job = base_job(8, 0, OVERLOAD_RATE);
        job.router = policy;
        job.ttft_target = SLO_TTFT_TARGET;
        out.push((policy, serve_cluster(&job)?));
    }
    Ok(out)
}

/// A filesystem-safe artifact stem for a policy.
fn policy_stem(policy: RouterPolicy) -> &'static str {
    match policy {
        RouterPolicy::RoundRobin => "round-robin",
        RouterPolicy::LeastTokens => "least-tokens",
        RouterPolicy::SloAware => "slo-aware",
    }
}

/// Run the cluster-scaling experiment; per-policy JSON artifacts land
/// in `<artifact_dir>/cluster_scaling/`.
pub fn run(artifact_dir: &Path) -> Result<Report> {
    let mut report = Report::new(
        "cluster-scaling",
        "Scale-out: instances x router x colocated-vs-disaggregated",
    );
    report.notes.push(
        "Study cluster: llama3-70b instances on xPU-HBM3 TP8 (16 lanes, \
         512-token prefill chunks); skewed workload with prompts 256-8192 \
         tokens and 16-512 generated tokens."
            .into(),
    );

    // --- 1. Scale-out efficiency (via the cluster sweep) --------------
    let mut base = base_job(1, 0, EFFICIENCY_RATE_PER_INSTANCE);
    base.workload.n_requests = 40;
    let grid = ClusterGrid {
        base,
        instance_counts: vec![1, 2, 4, 8],
        routers: vec![RouterPolicy::RoundRobin],
        autoscale: vec![None],
        priority_mixes: vec![Vec::new()],
        scale_load: true,
    };
    let mut eff = Table::new(
        "Scale-out efficiency (round-robin, colocated, proportional load)",
        &["instances", "rate req/s", "STPS", "STPS/instance", "TTFT p99"],
    );
    for rec in run_cluster_grid(&grid)? {
        eff.push_row(vec![
            rec.instances.to_string(),
            format!("{:.0}", rec.rate),
            format!("{:.0}", rec.stps),
            format!("{:.0}", rec.stps_per_instance),
            format!("{:.3} s", rec.ttft_p99),
        ]);
    }
    report.tables.push(eff);

    // --- 2. Router policies under skewed overload ---------------------
    let out_dir = artifact_dir.join("cluster_scaling");
    std::fs::create_dir_all(&out_dir)?;
    let mut routers = Table::new(
        "Router policies at skewed overload (8 colocated instances)",
        &["router", "completed", "shed", "TTFT p99", "E2E p99", "STPS"],
    );
    for (policy, rep) in router_comparison()? {
        routers.push_row(vec![
            rep.router.clone(),
            rep.cluster.completed.to_string(),
            rep.shed.to_string(),
            format!("{:.3} s", rep.cluster.ttft.p99),
            format!("{:.3} s", rep.cluster.e2e.p99),
            format!("{:.0}", rep.cluster.stps),
        ]);
        let path = out_dir.join(format!("{}.json", policy_stem(policy)));
        std::fs::write(&path, rep.to_json().to_string())?;
        report
            .notes
            .push(format!("wrote router artifact {}", path.display()));
    }
    report.tables.push(routers);

    // --- 3. Colocated vs disaggregated -------------------------------
    let mut disagg_t = Table::new(
        "Colocated x8 vs disaggregated 4P+4D (round-robin)",
        &[
            "rate req/s",
            "mode",
            "TTFT p50",
            "TTFT p99",
            "TPOT p99",
            "E2E p99",
            "STPS",
            "mean KV ship",
        ],
    );
    for rate in [75.0, 150.0, OVERLOAD_RATE] {
        let colo = serve_cluster(&base_job(8, 0, rate))?;
        let disagg = serve_cluster(&base_job(8, 4, rate))?;
        for rep in [&colo, &disagg] {
            disagg_t.push_row(vec![
                format!("{rate:.0}"),
                rep.mode.clone(),
                format!("{:.3} s", rep.cluster.ttft.p50),
                format!("{:.3} s", rep.cluster.ttft.p99),
                format!("{:.1} ms", rep.cluster.tpot.p99 * 1e3),
                format!("{:.3} s", rep.cluster.e2e.p99),
                format!("{:.0}", rep.cluster.stps),
                format!("{:.3} ms", rep.kv_transfer_mean * 1e3),
            ]);
        }
    }
    report.tables.push(disagg_t);
    report.notes.push(
        "Disaggregation buys the decode pool a pure decode cadence (its \
         steps never share a roofline with prefill chunks) and isolates \
         prompt ingestion from decode-slot congestion, at the price of a \
         per-request KV shipment stall that lands in TTFT; sizing the \
         pools against the prefill:decode compute ratio is the \
         operator's knob."
            .into(),
    );
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slo_router_sheds_at_overload_and_conserves_requests() {
        let reps = router_comparison().unwrap();
        let rr = &reps[0].1;
        let slo = &reps[2].1;
        assert_eq!(rr.shed, 0, "round-robin never sheds");
        assert_eq!(rr.cluster.completed, 240);
        assert!(slo.shed > 0, "skewed overload must trigger shedding");
        assert_eq!(
            slo.cluster.completed + slo.shed,
            slo.offered,
            "every offered request is either served or shed"
        );
        // Shedding bounds the tail relative to admit-everything.
        assert!(slo.cluster.ttft.p99 <= rr.cluster.ttft.p99);
    }

    #[test]
    fn disaggregated_overload_run_ships_kv_and_completes() {
        let colo = serve_cluster(&base_job(8, 0, OVERLOAD_RATE)).unwrap();
        let disagg = serve_cluster(&base_job(8, 4, OVERLOAD_RATE)).unwrap();
        assert_eq!(colo.cluster.completed, 240);
        assert_eq!(disagg.cluster.completed, 240);
        assert!(disagg.kv_shipped_bytes > 0.0);
        assert!(disagg.kv_transfer_mean > 0.0);
        assert_eq!(colo.kv_shipped_bytes, 0.0);
        // Decode-pool instances never run prefill chunks.
        for inst in &disagg.per_instance {
            if inst.engine.contains(":decode:") {
                assert_eq!(inst.prefill_tokens, 0);
            }
        }
        // All prefill happened at the prefill pool.
        assert!(disagg.cluster.prefill_tokens > 0);
        assert_eq!(
            disagg.cluster.prefill_tokens,
            colo.cluster.prefill_tokens,
            "both modes ingest the same prompts"
        );
    }

    #[test]
    fn report_renders_and_emits_per_policy_artifacts() {
        let dir = std::env::temp_dir().join(format!(
            "liminal-cluster-scaling-{}",
            std::process::id()
        ));
        let r = run(&dir).unwrap();
        assert_eq!(r.tables.len(), 3);
        assert!(r.to_markdown().contains("disaggregated"));
        for stem in ["round-robin", "least-tokens", "slo-aware"] {
            let path = dir.join("cluster_scaling").join(format!("{stem}.json"));
            let text = std::fs::read_to_string(&path)
                .unwrap_or_else(|e| panic!("missing artifact {}: {e}", path.display()));
            let j = crate::util::json::Json::parse(&text).unwrap();
            assert!(j.get("router").is_some());
            assert!(j.get("stps").unwrap().as_f64().unwrap() > 0.0);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
