//! Tables 5 and 6 (Appendix B): max user TPS and max system TPS across
//! all context lengths, including the CENT-TP/PP comparator rows.

use crate::apps::{Application, DecodePoint, Registry};
use crate::hw::{presets, SystemConfig};
use crate::model::{evaluate, max_batch_for_system, EvalOptions};
use crate::report::{fmt_tps, Report, Table};
use crate::sweep::{Record, TABLE_CONTEXTS};
use crate::Result;

use super::{cent_pp_record, cent_tp_record};

const MODELS: [&str; 3] = ["llama3-70b", "llama3-405b", "deepseek-v3"];

fn xpu_record(app: &dyn Application, tp: u64, context: u64, max_batch: bool) -> Record {
    let sys = SystemConfig::new(presets::hbm3(), tp, 1);
    let opts = EvalOptions::default();
    let batch = if max_batch {
        match max_batch_for_system(app, &sys, context) {
            Some(b) => b,
            None => return Record::unservable(app.name(), &sys.label(), tp, 1, context),
        }
    } else {
        1
    };
    let pt = DecodePoint { batch, context };
    match evaluate(app, &sys, &pt, &opts) {
        Ok(perf) => Record::from_perf(app.name(), &sys, &perf, 1.0),
        Err(_) => Record::unservable(app.name(), &sys.label(), tp, 1, context),
    }
}

/// All rows of one appendix table: per model, TP8/32/128 + CENT-TP/PP.
fn rows(max_batch: bool) -> Vec<(String, String, Vec<Record>)> {
    let registry = Registry::builtin();
    let mut out = Vec::new();
    for model in MODELS {
        let app = registry.app(model).unwrap();
        for tp in [8u64, 32, 128] {
            let recs = TABLE_CONTEXTS
                .iter()
                .map(|&ctx| xpu_record(app.as_ref(), tp, ctx, max_batch))
                .collect();
            out.push((model.to_string(), format!("xPU-HBM3-TP{tp}"), recs));
        }
        // CENT rows (batch fixed at 1 in both mappings; see cent.rs).
        let tp_recs = TABLE_CONTEXTS
            .iter()
            .map(|&ctx| cent_tp_record(app.as_ref(), ctx))
            .collect();
        out.push((model.to_string(), "CENT-TP".into(), tp_recs));
        let pp_recs = TABLE_CONTEXTS
            .iter()
            .map(|&ctx| cent_pp_record(app.as_ref(), ctx))
            .collect();
        out.push((model.to_string(), "CENT-PP".into(), pp_recs));
    }
    out
}

fn headers() -> Vec<&'static str> {
    vec!["Model", "System", "4K", "8K", "16K", "32K", "64K", "128K"]
}

/// Table 5: max user TPS (batch = 1).
pub fn run_table5() -> Result<Report> {
    let mut report = Report::new("table5", "Max user TPS (B=1), all contexts");
    let mut t = Table::new("Table 5", &headers());
    for (model, system, recs) in rows(false) {
        let mut row = vec![model, system];
        row.extend(recs.iter().map(|r| {
            r.utps.map(fmt_tps).unwrap_or_else(|| "-".into())
        }));
        t.push_row(row);
    }
    report.tables.push(t);
    Ok(report)
}

/// Table 6: max system TPS with the per-user TPS in parentheses.
pub fn run_table6() -> Result<Report> {
    let mut report = Report::new(
        "table6",
        "Max system TPS (batch = capacity max; UTPS in parentheses)",
    );
    let mut t = Table::new("Table 6", &headers());
    for (model, system, recs) in rows(true) {
        let mut row = vec![model, system];
        row.extend(recs.iter().map(|r| match (r.stps, r.utps) {
            (Some(s), Some(u)) => format!("{} ({})", fmt_tps(s), fmt_tps(u)),
            _ => "- (-)".into(),
        }));
        t.push_row(row);
    }
    report.tables.push(t);
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table5_has_15_rows_with_dashes_for_deepseek_cent() {
        let r = run_table5().unwrap();
        let t = &r.tables[0];
        assert_eq!(t.rows.len(), 15);
        let ds_cent: Vec<_> = t
            .rows
            .iter()
            .filter(|row| row[0] == "deepseek-v3" && row[1].starts_with("CENT"))
            .collect();
        assert_eq!(ds_cent.len(), 2);
        for row in ds_cent {
            assert!(row[2..].iter().all(|c| c == "-"), "{row:?}");
        }
    }

    /// Golden: Table 5's xPU rows at a few contexts.
    #[test]
    fn table5_xpu_cells_match_paper() {
        let registry = Registry::builtin();
        // (model, tp, context, paper UTPS)
        let cases: &[(&str, u64, u64, f64)] = &[
            ("llama3-70b", 8, 16384, 473.0),
            ("llama3-70b", 32, 65536, 1100.0),
            ("llama3-405b", 128, 32768, 768.0),
            ("deepseek-v3", 32, 8192, 196.0),
        ];
        for &(m, tp, ctx, want) in cases {
            let app = registry.app(m).unwrap();
            let got = xpu_record(app.as_ref(), tp, ctx, false).utps.unwrap();
            assert!(
                (got - want).abs() / want < 0.05,
                "{m} TP{tp} T={ctx}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn table6_utps_saturates_near_42() {
        // The paper's striking observation: at capacity-max batch the
        // per-user rate converges to ~41-43 across systems (KV streaming
        // dominates). Check a few cells.
        let registry = Registry::builtin();
        for (m, tp, ctx) in [
            ("llama3-70b", 8u64, 65536u64),
            ("llama3-405b", 32, 65536),
            ("deepseek-v3", 8, 65536),
        ] {
            let app = registry.app(m).unwrap();
            let r = xpu_record(app.as_ref(), tp, ctx, true);
            let u = r.utps.unwrap();
            assert!((u - 42.5).abs() < 2.5, "{m} TP{tp}: utps {u}");
        }
    }
}
