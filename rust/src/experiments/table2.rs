//! Table 2: max user TPS and max system TPS for xPU-HBM3 at TP 8/32/128,
//! contexts 4K and 128K, all three models.

use crate::apps::{Application, DecodePoint, Registry};
use crate::hw::{presets, SystemConfig};
use crate::model::{evaluate, max_batch_for_system, EvalOptions};
use crate::report::{fmt_tps, Report, Table};
use crate::Result;

/// Paper models in table order.
pub const MODELS: [&str; 3] = ["llama3-70b", "llama3-405b", "deepseek-v3"];

/// Evaluate one cell: `(max_utps, max_stps, stps_utps)`; None = dash.
fn cell(
    app: &dyn Application,
    tp: u64,
    context: u64,
) -> (Option<f64>, Option<(f64, f64)>) {
    let sys = SystemConfig::new(presets::hbm3(), tp, 1);
    let opts = EvalOptions::default();
    let utps = evaluate(app, &sys, &DecodePoint { batch: 1, context }, &opts)
        .ok()
        .map(|p| p.utps);
    let stps = max_batch_for_system(app, &sys, context).and_then(|b| {
        evaluate(app, &sys, &DecodePoint { batch: b, context }, &opts)
            .ok()
            .map(|p| (p.stps, p.utps))
    });
    (utps, stps)
}

/// Regenerate Table 2.
pub fn run() -> Result<Report> {
    let registry = Registry::builtin();
    let mut report = Report::new(
        "table2",
        "Max user TPS and max system TPS, xPU-HBM3, 4K vs 128K context",
    );
    report.notes.push(
        "Max STPS batch = largest that fits in aggregate memory (paper §4.3); \
         parenthesized value is the per-user TPS at that batch."
            .into(),
    );
    let mut t = Table::new(
        "Table 2",
        &[
            "Model", "System", "MaxUTPS@4K", "MaxUTPS@128K",
            "MaxSTPS@4K (UTPS)", "MaxSTPS@128K (UTPS)",
        ],
    );
    for model in MODELS {
        let app = registry.app(model).unwrap();
        for tp in [8u64, 32, 128] {
            let (u4, s4) = cell(app.as_ref(), tp, 4096);
            let (u128, s128) = cell(app.as_ref(), tp, 131072);
            let fmt_s = |s: Option<(f64, f64)>| match s {
                Some((stps, utps)) => format!("{} ({})", fmt_tps(stps), fmt_tps(utps)),
                None => "-".into(),
            };
            let fmt_u = |u: Option<f64>| u.map(fmt_tps).unwrap_or_else(|| "-".into());
            t.push_row(vec![
                model.into(),
                format!("xPU-HBM3-TP{tp}"),
                fmt_u(u4),
                fmt_u(u128),
                fmt_s(s4),
                fmt_s(s128),
            ]);
        }
    }
    report.tables.push(t);
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::Registry;

    /// Golden check against the paper's STPS cells (the UTPS cells are
    /// asserted in model::latency tests).
    #[test]
    fn stps_cells_match_paper() {
        let registry = Registry::builtin();
        // (model, tp, context, paper STPS, paper UTPS-at-max-batch)
        let cases: &[(&str, u64, u64, f64, f64)] = &[
            ("llama3-70b", 8, 4096, 48_000.0, 43.0),
            ("llama3-70b", 32, 4096, 202_000.0, 42.0),
            ("llama3-70b", 8, 131072, 1_500.0, 43.0),
            ("llama3-405b", 128, 4096, 337_000.0, 28.0),
            ("llama3-405b", 32, 131072, 3_600.0, 42.0),
            ("deepseek-v3", 8, 131072, 1_400.0, 42.0),
        ];
        for &(m, tp, ctx, want_stps, want_utps) in cases {
            let app = registry.app(m).unwrap();
            let (_, s) = cell(app.as_ref(), tp, ctx);
            let (stps, utps) = s.unwrap();
            assert!(
                (stps - want_stps).abs() / want_stps < 0.08,
                "{m} TP{tp} T={ctx}: stps {stps} vs paper {want_stps}"
            );
            assert!(
                (utps - want_utps).abs() / want_utps < 0.08,
                "{m} TP{tp} T={ctx}: utps {utps} vs paper {want_utps}"
            );
        }
    }

    #[test]
    fn deepseek_tp128_stps_is_compute_bound_and_1_5m() {
        let registry = Registry::builtin();
        let app = registry.app("deepseek-v3").unwrap();
        let (_, s) = cell(app.as_ref(), 128, 4096);
        let (stps, utps) = s.unwrap();
        assert!((stps - 1.5e6).abs() / 1.5e6 < 0.12, "stps {stps}");
        assert!((utps - 17.0).abs() < 2.0, "utps {utps}");
    }

    #[test]
    fn renders_nine_rows() {
        let r = run().unwrap();
        assert_eq!(r.tables[0].rows.len(), 9);
    }
}
