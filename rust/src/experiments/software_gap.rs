//! Appendix E analog, run through the serving simulator: how much
//! per-step software overhead explains the gap between LIMINAL's limit
//! numbers and executed systems.
//!
//! The paper validates LIMINAL against real executions and finds the
//! limit study is an upper bound: ~2.3x optimistic against its
//! commercial-chip simulator and ~5x on the H100 GEMV microbenchmark
//! (Appendix E), with a 7.6% mean absolute error once software effects
//! are modeled. This experiment closes the loop inside the repo: we
//! sweep a per-step software overhead through the *dynamic* serving
//! simulator and check the DES-measured throughput degradation against
//! the closed-form prediction `(t_ideal + overhead) / t_ideal` — then
//! report which overhead reproduces the paper's observed gaps, and what
//! that overhead does to the TTFT/TPOT SLOs of a prefill-aware run.

use std::sync::Arc;

use crate::apps::{DecodePoint, Registry};
use crate::hw::{presets, SystemConfig};
use crate::model::{evaluate, EvalOptions, DEFAULT_PREFILL_CHUNK};
use crate::report::{Report, Table};
use crate::serving::{
    AnalyticEngine, Batcher, KvBudget, ServingReport, ServingSim, SimConfig,
    WorkloadGen, WorkloadSpec,
};
use crate::Result;

/// Paper-reported gap of LIMINAL vs. the anonymized commercial-chip
/// simulator (Appendix E).
pub const PAPER_COMMERCIAL_GAP: f64 = 2.3;

/// Paper-reported gap of LIMINAL vs. the measured H100 GEMV.
pub const PAPER_H100_GEMV_GAP: f64 = 5.0;

fn run_sim(overhead: f64, prefill_chunk: u64) -> ServingReport {
    let registry = Registry::builtin();
    let app = registry.app("llama3-70b").unwrap();
    let sys = SystemConfig::new(presets::hbm3(), 8, 1);
    let kv = KvBudget::new(
        sys.total_capacity(),
        app.weight_bytes(),
        app.kv_bytes_per_token(),
    );
    // Single-lane service at a trickle arrival rate: residence time is
    // then pure step latency, which is what the closed form predicts.
    // (prefill_chunk = 0 degrades to the decode-only batcher.)
    let batcher = Batcher::with_prefill(1, kv, prefill_chunk);
    let mut engine = AnalyticEngine::new(Arc::clone(&app), sys);
    engine.opts.software_overhead = overhead;
    let workload = WorkloadGen::new(WorkloadSpec {
        arrival_rate: 0.5,
        n_requests: 20,
        context: (4096, 4097),
        gen: (64, 65),
        priority_mix: Vec::new(),
        seed: 11,
    })
    .generate();
    ServingSim::new(batcher, &mut engine, SimConfig::default()).run(workload)
}

/// Ideal (zero-overhead) per-token decode latency for the study system.
fn ideal_step_latency() -> f64 {
    let registry = Registry::builtin();
    let app = registry.app("llama3-70b").unwrap();
    let sys = SystemConfig::new(presets::hbm3(), 8, 1);
    evaluate(
        app.as_ref(),
        &sys,
        &DecodePoint { batch: 1, context: 4096 },
        &EvalOptions::default(),
    )
    .unwrap()
    .lat
    .t_batch
}

/// Run the software-gap study; returns the Appendix E analog report.
pub fn run() -> Result<Report> {
    let mut report = Report::new(
        "software-gap",
        "Software overhead vs. the paper's Appendix E validation gaps",
    );
    let t_ideal = ideal_step_latency();
    report.notes.push(format!(
        "Study system: Llama3-70B on xPU-HBM3 TP8; ideal decode step \
         {:.3} ms (paper Table 2: ~486 UTPS).",
        t_ideal * 1e3
    ));
    report.notes.push(format!(
        "Paper Appendix E: LIMINAL is ~{PAPER_COMMERCIAL_GAP}x optimistic vs. a \
         commercial-chip simulator and ~{PAPER_H100_GEMV_GAP}x vs. the measured \
         H100 GEMV; overheads of {:.2} ms and {:.2} ms per step reproduce \
         those gaps on this system.",
        (PAPER_COMMERCIAL_GAP - 1.0) * t_ideal * 1e3,
        (PAPER_H100_GEMV_GAP - 1.0) * t_ideal * 1e3,
    ));

    let mut t = Table::new(
        "Decode throughput degradation vs. per-step software overhead",
        &[
            "overhead/step",
            "predicted slowdown",
            "DES slowdown",
            "DES UTPS mean",
        ],
    );
    let ideal_utps = run_sim(0.0, 0).utps_mean;
    for gap in [1.0, 1.5, PAPER_COMMERCIAL_GAP, PAPER_H100_GEMV_GAP] {
        let overhead = (gap - 1.0) * t_ideal;
        let rep = run_sim(overhead, 0);
        let des_gap = ideal_utps / rep.utps_mean;
        t.push_row(vec![
            format!("{:.3} ms", overhead * 1e3),
            format!("{gap:.2}x"),
            format!("{des_gap:.2}x"),
            format!("{:.1}", rep.utps_mean),
        ]);
    }
    report.tables.push(t);

    let mut slo = Table::new(
        "SLO impact (prefill-aware run, chunk 1024)",
        &["overhead/step", "TTFT p50", "TTFT p99", "TPOT p50", "E2E p50"],
    );
    for gap in [1.0, PAPER_COMMERCIAL_GAP] {
        let overhead = (gap - 1.0) * t_ideal;
        let rep = run_sim(overhead, DEFAULT_PREFILL_CHUNK);
        slo.push_row(vec![
            format!("{:.3} ms", overhead * 1e3),
            format!("{:.1} ms", rep.ttft.p50 * 1e3),
            format!("{:.1} ms", rep.ttft.p99 * 1e3),
            format!("{:.2} ms", rep.tpot.p50 * 1e3),
            format!("{:.3} s", rep.e2e.p50),
        ]);
    }
    report.tables.push(slo);
    report.notes.push(
        "The DES slowdown tracks the closed form because under trickle \
         load the simulator degenerates to steady-state stepping — the \
         dynamic machinery adds queueing and batching effects only when \
         load does."
            .into(),
    );
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn des_degradation_matches_closed_form() {
        // The dynamic simulator must reproduce the analytical slowdown
        // under single-lane trickle load: this validates the DES
        // against the model it wraps (and would catch free-token /
        // mispriced-step fidelity bugs immediately).
        let t_ideal = ideal_step_latency();
        let ideal = run_sim(0.0, 0).utps_mean;
        for gap in [1.5, PAPER_COMMERCIAL_GAP] {
            let rep = run_sim((gap - 1.0) * t_ideal, 0);
            let des_gap = ideal / rep.utps_mean;
            assert!(
                (des_gap - gap).abs() / gap < 0.05,
                "gap {gap}: DES says {des_gap}"
            );
        }
    }

    #[test]
    fn overhead_inflates_ttft_and_tpot() {
        let t_ideal = ideal_step_latency();
        let clean = run_sim(0.0, DEFAULT_PREFILL_CHUNK);
        let slow = run_sim((PAPER_COMMERCIAL_GAP - 1.0) * t_ideal, DEFAULT_PREFILL_CHUNK);
        assert!(slow.ttft.p50 > clean.ttft.p50);
        assert!(slow.tpot.p50 > clean.tpot.p50 * 1.5);
        assert!(clean.ttft.p50 > 0.0);
    }

    #[test]
    fn report_renders_with_both_tables() {
        let r = run().unwrap();
        assert_eq!(r.tables.len(), 2);
        assert!(r.to_markdown().contains("Appendix E") || !r.notes.is_empty());
    }
}
