//! Figure 5: UTPS vs normalized STPS/W across the five hardware
//! technologies, for each model at 4K and 128K context (paper §4.7).
//!
//! Each technology traces a batch-sweep curve: small batches buy high
//! UTPS at poor efficiency, large batches the reverse. Capacity-starved
//! technologies (SRAM, COWS) need many chips/wafers, which crushes their
//! STPS/W at low UTPS — the "elasticity" the paper says they lack.

use crate::apps::{Application, DecodePoint, Registry};
use crate::hw::{presets, Chip};
use crate::model::{evaluate, max_batch_for_system, EvalOptions};
use crate::parallel::{fit_system, FitRequest};
use crate::power::PowerModel;
use crate::report::{Report, Series};
use crate::Result;

/// One (UTPS, STPS/W) point of a technology's batch sweep.
#[derive(Debug, Clone, Copy)]
#[allow(dead_code)] // batch is part of the public record shape
pub struct SweepPoint {
    /// Batch size.
    pub batch: u64,
    /// Per-user tokens/second.
    pub utps: f64,
    /// System tokens/second/watt (absolute; normalize downstream).
    pub stps_per_watt: f64,
}

/// Batch-sweep one technology for one (model, context).
pub fn tech_sweep(app: &dyn Application, chip: &Chip, context: u64) -> Vec<SweepPoint> {
    let power = PowerModel::default();
    let opts = EvalOptions::default();
    let mut out = Vec::new();
    let mut batch = 1u64;
    loop {
        let pt = DecodePoint { batch, context };
        // Size the system for this batch (PP grows for SRAM/COWS).
        let Ok(sys) = fit_system(app, &FitRequest {
            tp: Some(128),
            ..FitRequest::new(chip.clone(), pt)
        }) else {
            break;
        };
        let Ok(perf) = evaluate(app, &sys, &pt, &opts) else { break };
        let watts = power.system_power(&sys).total_watts;
        out.push(SweepPoint {
            batch,
            utps: perf.utps,
            stps_per_watt: perf.stps / watts,
        });
        // Stop when per-user rate collapses below interactive levels.
        if perf.utps < 20.0 || batch >= (1 << 20) {
            break;
        }
        batch *= 2;
    }
    out
}

/// Baseline for normalization: HBM3's best STPS/W at this (model,
/// context) — its capacity-max batch on a fixed TP128 system.
pub fn hbm3_baseline(app: &dyn Application, context: u64) -> Option<f64> {
    let sys = crate::hw::SystemConfig::new(presets::hbm3(), 128, 1);
    let b = max_batch_for_system(app, &sys, context)?;
    let perf = evaluate(
        app,
        &sys,
        &DecodePoint { batch: b, context },
        &EvalOptions::default(),
    )
    .ok()?;
    let watts = PowerModel::default().system_power(&sys).total_watts;
    Some(perf.stps / watts)
}

/// Regenerate Figure 5.
pub fn run() -> Result<Report> {
    let registry = Registry::builtin();
    let mut report = Report::new(
        "fig5",
        "UTPS vs STPS/W across technologies (normalized to HBM3's best \
         STPS/W per model+context; y is log-scale in the paper)",
    );
    report.notes.push(
        "Key Finding 9: DRAM's capacity+bandwidth flexibility wins the \
         efficiency race; SRAM/COWS buy peak UTPS at an order of magnitude \
         worse STPS/W at low batch, and cannot serve large contexts at all."
            .into(),
    );
    for model in ["llama3-70b", "llama3-405b", "deepseek-v3"] {
        let app = registry.app(model).unwrap();
        for ctx in [4096u64, 131072] {
            let Some(base) = hbm3_baseline(app.as_ref(), ctx) else { continue };
            for chip in presets::table1() {
                let pts = tech_sweep(app.as_ref(), &chip, ctx);
                if pts.is_empty() {
                    report.notes.push(format!(
                        "{} cannot serve {model} at {}K (capacity)",
                        chip.name,
                        ctx / 1024
                    ));
                    continue;
                }
                let mut s = Series::new(
                    &format!("{model} T={}K {}", ctx / 1024, chip.name),
                    "utps",
                    "stps_per_watt_norm",
                );
                for p in pts {
                    s.points.push((p.utps, p.stps_per_watt / base));
                }
                report.series.push(s);
            }
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::Registry;

    fn app70() -> std::sync::Arc<dyn crate::apps::Application> {
        Registry::builtin().app("llama3-70b").unwrap()
    }

    #[test]
    fn cows_peaks_utps_but_costs_efficiency_at_low_batch() {
        // §4.7: COWS gives ~1.6x the UTPS of HBM3 on Llama3-70B @ 4K and
        // is ~10x less cost-effective at low UTPS / low batch.
        let a = app70();
        let hbm3 = tech_sweep(a.as_ref(), &presets::hbm3(), 4096);
        let cows = tech_sweep(a.as_ref(), &presets::cows(), 4096);
        let u_hbm3 = hbm3[0].utps;
        let u_cows = cows[0].utps;
        assert!(u_cows / u_hbm3 > 1.3, "{u_cows} vs {u_hbm3}");
        // At batch 1 COWS burns far more W per token than HBM3 at its
        // efficient point.
        let base = hbm3_baseline(a.as_ref(), 4096).unwrap();
        assert!(cows[0].stps_per_watt / base < 0.2);
    }

    #[test]
    fn sram_like_techs_cannot_serve_70b_at_128k_cheaply() {
        // Large context kills SRAM/COWS capacity (paper: "incapable of
        // serving them" within sane system sizes). With PP growth they
        // technically fit but at enormous chip counts; check the chip
        // count explodes past 1000.
        let a = app70();
        let pt = DecodePoint { batch: 32, context: 131072 };
        let sys = fit_system(a.as_ref(), &FitRequest {
            tp: Some(128),
            ..FitRequest::new(presets::sram(), pt)
        })
        .unwrap();
        assert!(sys.n_chips() > 1000, "chips {}", sys.n_chips());
    }

    #[test]
    fn dram_techs_show_elasticity_sram_does_not() {
        // Batch sweep on HBM3 spans >20x in STPS/W; SRAM's span is
        // narrower at 4K because added batches keep adding chips.
        let a = app70();
        let span = |pts: &[SweepPoint]| {
            let lo = pts.iter().map(|p| p.stps_per_watt).fold(f64::MAX, f64::min);
            let hi = pts.iter().map(|p| p.stps_per_watt).fold(0.0, f64::max);
            hi / lo
        };
        let hbm3 = tech_sweep(a.as_ref(), &presets::hbm3(), 4096);
        let sram = tech_sweep(a.as_ref(), &presets::sram(), 4096);
        assert!(span(&hbm3) > 20.0, "hbm3 span {}", span(&hbm3));
        assert!(span(&sram) < span(&hbm3));
    }

    #[test]
    fn hbm4_and_dram3d_double_405b_utps() {
        // §4.7: "the benefits of HBM4 and 3D-DRAM are more pronounced"
        // for Llama3-405B — roughly a doubling of UTPS over HBM3.
        let a = Registry::builtin().app("llama3-405b").unwrap();
        let u = |chip: &Chip| tech_sweep(a.as_ref(), chip, 131072)[0].utps;
        let base = u(&presets::hbm3());
        assert!(u(&presets::hbm4()) / base > 1.6);
        assert!(u(&presets::dram3d()) / base > 1.7);
    }
}
