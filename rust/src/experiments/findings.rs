//! The paper's ten Key Findings as executable assertions.
//!
//! Each finding is re-derived from the model; `liminal findings` prints
//! a pass/fail table so a reader can see the claims hold in this
//! implementation, not just in prose.

use crate::apps::{Application, DecodePoint, Registry};
use crate::hw::{presets, SystemConfig};
use crate::model::{evaluate, max_batch_for_system, EvalOptions};
use crate::report::{Report, Table};
use crate::{Result, GIB};

struct Finding {
    id: &'static str,
    claim: &'static str,
    check: Box<dyn Fn() -> (bool, String)>,
}

fn eval1(app: &dyn Application, sys: &SystemConfig, b: u64, t: u64) -> crate::model::Perf {
    evaluate(
        app,
        sys,
        &DecodePoint { batch: b, context: t },
        &EvalOptions { enforce_capacity: false, ..Default::default() },
    )
    .unwrap()
}

fn findings() -> Vec<Finding> {
    let reg = Registry::builtin();
    let l70 = reg.app("llama3-70b").unwrap();
    let l405 = reg.app("llama3-405b").unwrap();
    let ds = reg.app("deepseek-v3").unwrap();

    vec![
        Finding {
            id: "KF1",
            claim: "Serving the big models needs >=629 GiB; 32 users of \
                    Llama3-405B at 128K need ~1.4 TB",
            check: {
                let l405 = l405.clone();
                let ds = ds.clone();
                Box::new(move || {
                    let ds_min = ds.capacity_bytes(&DecodePoint { batch: 1, context: 4096 }) / GIB;
                    let l405_32 = l405
                        .capacity_bytes(&DecodePoint { batch: 32, context: 131072 })
                        / GIB;
                    (
                        ds_min > 620.0 && l405_32 > 1350.0 && l405_32 < 1450.0,
                        format!("DeepSeek min {ds_min:.0} GiB; 405B/32u/128K {l405_32:.0} GiB"),
                    )
                })
            },
        },
        Finding {
            id: "KF2",
            claim: "128 HBM3 chips reach 600+ UTPS on all three models",
            check: {
                let apps = [l70.clone(), l405.clone(), ds.clone()];
                Box::new(move || {
                    let sys = SystemConfig::new(presets::hbm3(), 128, 1);
                    let us: Vec<f64> = apps
                        .iter()
                        .map(|a| eval1(a.as_ref(), &sys, 1, 131072).utps)
                        .collect();
                    (us.iter().all(|&u| u > 600.0), format!("UTPS {us:?}"))
                })
            },
        },
        Finding {
            id: "KF3",
            claim: "No HBM3 system reaches 1000 UTPS on 405B/DeepSeek at \
                    large context",
            check: {
                let apps = [l405.clone(), ds.clone()];
                Box::new(move || {
                    let sys = SystemConfig::new(presets::hbm3(), 128, 1);
                    let us: Vec<f64> = apps
                        .iter()
                        .map(|a| eval1(a.as_ref(), &sys, 1, 131072).utps)
                        .collect();
                    (us.iter().all(|&u| u < 1000.0), format!("UTPS {us:?}"))
                })
            },
        },
        Finding {
            id: "KF4",
            claim: "Aggregated capacity serves larger models AND raises \
                    STPS for all models",
            check: {
                let l70 = l70.clone();
                let ds = ds.clone();
                Box::new(move || {
                    let small = SystemConfig::new(presets::hbm3(), 8, 1);
                    let large = SystemConfig::new(presets::hbm3(), 128, 1);
                    let ds_small = max_batch_for_system(ds.as_ref(), &small, 131072);
                    let ds_large = max_batch_for_system(ds.as_ref(), &large, 131072);
                    let b_small =
                        max_batch_for_system(l70.as_ref(), &small, 4096).unwrap();
                    let b_large =
                        max_batch_for_system(l70.as_ref(), &large, 4096).unwrap();
                    let s_small = eval1(l70.as_ref(), &small, b_small, 4096).stps;
                    let s_large = eval1(l70.as_ref(), &large, b_large, 4096).stps;
                    (
                        ds_large.unwrap_or(0) > ds_small.unwrap_or(0)
                            && s_large > 4.0 * s_small,
                        format!("70B STPS {s_small:.0} -> {s_large:.0}"),
                    )
                })
            },
        },
        Finding {
            id: "KF5",
            claim: "2-4x bandwidth over HBM3 helps a lot; beyond that \
                    returns diminish",
            check: {
                let l405 = l405.clone();
                Box::new(move || {
                    let u = |bw: f64| {
                        let sys = SystemConfig::new(presets::bw_point(bw), 128, 1);
                        eval1(l405.as_ref(), &sys, 1, 131072).utps
                    };
                    let (u4, u16, u120) = (u(4.4), u(17.6), u(120.0));
                    // 4x bandwidth must convert near-proportionally
                    // (>60% efficiency); the further 6.8x must convert at
                    // under half efficiency (diminishing returns).
                    (
                        u16 / u4 > 2.5 && u120 / u16 < 0.5 * (120.0 / 17.6),
                        format!("4x gain {:.2}, further 6.8x gain {:.2}", u16 / u4, u120 / u16),
                    )
                })
            },
        },
        Finding {
            id: "KF6",
            claim: "At 10x+ bandwidth, sub-us sync across 128 chips is \
                    first-order",
            check: {
                let l405 = l405.clone();
                Box::new(move || {
                    let u = |sync: f64| {
                        super::fig3::utps_at_sync(
                            l405.as_ref(),
                            &presets::sram(),
                            128,
                            sync,
                            131072,
                        )
                        .unwrap()
                    };
                    let gain = u(200e-9) / u(2.5e-6);
                    (gain > 3.0, format!("SRAM 2.5us->200ns gain {gain:.2}x"))
                })
            },
        },
        Finding {
            id: "KF7",
            claim: "Reuse drives efficiency: batching buys ~30x STPS/W for \
                    70B at 4K for ~10% UTPS",
            check: {
                let l70 = l70.clone();
                Box::new(move || {
                    let sys = SystemConfig::new(presets::hbm3(), 128, 1);
                    let p1 = eval1(l70.as_ref(), &sys, 1, 4096);
                    let p31 = eval1(l70.as_ref(), &sys, 31, 4096);
                    let gain = p31.stps / p1.stps;
                    let drop = 1.0 - p31.utps / p1.utps;
                    (
                        gain > 25.0 && drop < 0.12,
                        format!("STPS gain {gain:.1}x for {:.1}% UTPS drop", drop * 100.0),
                    )
                })
            },
        },
        Finding {
            id: "KF8",
            claim: "Model heterogeneity: DeepSeek is sync/capacity hungry, \
                    Llama bandwidth hungry",
            check: {
                let l70 = l70.clone();
                let ds = ds.clone();
                Box::new(move || {
                    // DeepSeek's exposed fraction at TP128 is much larger
                    // than Llama-70B's memory fraction profile.
                    let sys = SystemConfig::new(presets::hbm3(), 128, 1);
                    let p_ds = eval1(ds.as_ref(), &sys, 1, 4096);
                    let p_l70 = eval1(l70.as_ref(), &sys, 1, 4096);
                    let f_ds = p_ds.lat.t_exposed / p_ds.lat.t_batch;
                    let f_l70 = p_l70.lat.t_exposed / p_l70.lat.t_batch;
                    (
                        // Different bottleneck mixes across models.
                        (f_ds - f_l70).abs() > 0.05,
                        format!("exposed fraction: DSv3 {f_ds:.2} vs 70B {f_l70:.2}"),
                    )
                })
            },
        },
        Finding {
            id: "KF9",
            claim: "DRAM-based designs deliver the best STPS/W at serving \
                    batch sizes",
            check: {
                let l70 = l70.clone();
                Box::new(move || {
                    let spw = |chip: crate::hw::Chip| {
                        let pts = super::fig5::tech_sweep(l70.as_ref(), &chip, 4096);
                        pts.iter().map(|p| p.stps_per_watt).fold(0.0, f64::max)
                    };
                    let hbm4 = spw(presets::hbm4());
                    let sram = spw(presets::sram());
                    let cows = spw(presets::cows());
                    (
                        hbm4 > sram && hbm4 > cows,
                        format!("best STPS/W: HBM4 {hbm4:.2} vs SRAM {sram:.2} vs COWS {cows:.2}"),
                    )
                })
            },
        },
        Finding {
            id: "KF10",
            claim: "10,000+ UTPS is out of reach for current models even \
                    with extreme hardware (needs algorithmic change)",
            check: {
                let l405 = l405.clone();
                let l70 = l70.clone();
                Box::new(move || {
                    // Best case: COWS with its fast collectives.
                    let sys = SystemConfig::new(presets::cows(), 128, 1);
                    let u405 = eval1(l405.as_ref(), &sys, 1, 131072).utps;
                    let u70 = eval1(l70.as_ref(), &sys, 1, 131072).utps;
                    (
                        u405 < 10_000.0 && u70 < 10_000.0,
                        format!("COWS-TP128 UTPS: 405B {u405:.0}, 70B {u70:.0}"),
                    )
                })
            },
        },
    ]
}

/// Run every finding; report pass/fail with evidence.
pub fn run_findings() -> Result<Report> {
    let mut report = Report::new("findings", "Key Findings 1-10, re-derived");
    let mut t = Table::new("Findings", &["ID", "Claim", "Status", "Evidence"]);
    let mut all_pass = true;
    for f in findings() {
        let (ok, evidence) = (f.check)();
        all_pass &= ok;
        t.push_row(vec![
            f.id.into(),
            f.claim.into(),
            if ok { "PASS".into() } else { "FAIL".into() },
            evidence,
        ]);
    }
    report.tables.push(t);
    report
        .notes
        .push(format!("overall: {}", if all_pass { "ALL PASS" } else { "FAILURES" }));
    Ok(report)
}

#[cfg(test)]
mod tests {
    #[test]
    fn all_key_findings_hold() {
        let r = super::run_findings().unwrap();
        let failures: Vec<_> = r.tables[0]
            .rows
            .iter()
            .filter(|row| row[2] != "PASS")
            .map(|row| format!("{}: {}", row[0], row[3]))
            .collect();
        assert!(failures.is_empty(), "failing findings: {failures:?}");
    }

}
