//! Autoscaled-fleet study: fixed-for-peak vs. SLO-driven elastic
//! provisioning under a diurnal + bursty workload.
//!
//! A fixed fleet must be sized for the worst minute it will ever see;
//! every off-peak second of that provisioning is billed but idle. The
//! autoscaler instead tracks the load signal the SLO router already
//! computes — shed pressure and predicted-TTFT headroom — growing the
//! pool when either crosses its threshold and retiring instances that
//! sit fully idle, with spawned instances joining the front door only
//! after a warm-up delay. This experiment prices both strategies on the
//! same non-homogeneous Poisson workload ([`DiurnalGen`]: sinusoidal
//! diurnal swing plus burst episodes) and reports the billed
//! instance-seconds each needed to hold the TTFT SLO.
//!
//! Artifacts land in `<artifacts>/autoscale/`: the full cluster report
//! for each fleet (`fixed.json`, `autoscaled.json`) and a side-by-side
//! `summary.json` with the instance-hour savings.

use std::path::Path;

use crate::cluster::AutoscalePolicy;
use crate::coordinator::{build_cluster_sim, default_cluster_job, ClusterJob, RouterPolicy};
use crate::hw::{presets, SystemConfig};
use crate::report::{Report, Table};
use crate::serving::{DiurnalGen, DiurnalSpec, Request};
use crate::util::json::Json;
use crate::Result;

/// Admission TTFT target both fleets serve under (SLO-aware router).
const TTFT_TARGET: f64 = 0.5;

/// Peak provisioning: the fixed fleet's size, and the elastic fleet's
/// ceiling.
const PEAK_INSTANCES: usize = 6;

/// The elastic fleet's floor (and starting size).
const MIN_INSTANCES: usize = 2;

/// The diurnal + bursty workload both fleets serve: full-swing
/// sinusoid (the trough is quiet enough to drain the pool idle) with
/// 2.5x burst episodes layered on top.
fn diurnal_workload() -> Vec<Request> {
    DiurnalGen::new(DiurnalSpec {
        base_rate: 30.0,
        amplitude: 1.0,
        period: 12.0,
        burst_every: 10.0,
        burst_duration: 1.5,
        burst_boost: 2.5,
        n_requests: 800,
        context: (512, 4096),
        gen: (32, 256),
        priority_mix: Vec::new(),
        seed: 11,
    })
    .generate()
}

/// Study job: llama3-70b on HBM3-TP8 instances behind the SLO router.
fn base_job(instances: usize) -> ClusterJob {
    let sys = SystemConfig::new(presets::hbm3(), 8, 1);
    let mut job = default_cluster_job("llama3-70b", sys);
    job.instances = instances;
    job.max_batch = 16;
    job.prefill_chunk = 512;
    job.router = RouterPolicy::SloAware;
    job.ttft_target = TTFT_TARGET;
    job
}

/// The elastic policy under study: grow on shed pressure or once the
/// best predicted TTFT eats half the admission budget; retire after a
/// sustained idle spell; 1 s warm-up before a spawn serves.
fn elastic_policy() -> AutoscalePolicy {
    AutoscalePolicy {
        shed_rate_up: 0.05,
        ttft_headroom: TTFT_TARGET / 2.0,
        idle_shrink_after: 1.5,
        warmup_delay: 1.0,
        cooldown: 1.0,
        decision_window: 16,
        min_instances: MIN_INSTANCES,
        max_instances: PEAK_INSTANCES,
    }
}

/// Run both fleets on the shared workload; returns
/// `(fixed, autoscaled)` reports. Public so the acceptance test pins
/// the instance-hour savings without re-deriving the configuration.
pub fn fleet_comparison(
) -> Result<(crate::cluster::ClusterReport, crate::cluster::ClusterReport)> {
    let workload = diurnal_workload();
    let fixed = build_cluster_sim(&base_job(PEAK_INSTANCES))?.run(workload.clone());
    let mut job = base_job(MIN_INSTANCES);
    job.autoscale = Some(elastic_policy());
    let auto = build_cluster_sim(&job)?.run(workload);
    Ok((fixed, auto))
}

/// One comparison row: fleet label + its report.
fn fleet_row(label: &str, rep: &crate::cluster::ClusterReport) -> Vec<String> {
    vec![
        label.to_string(),
        rep.per_instance.len().to_string(),
        format!("+{} / -{}", rep.scale_ups, rep.scale_downs),
        format!("{:.1}", rep.instance_seconds),
        format!("{:.3} s", rep.cluster.ttft.p50),
        format!("{:.3} s", rep.cluster.ttft.p99),
        rep.shed.to_string(),
        format!("{:.0}", rep.cluster.stps),
    ]
}

/// JSON summary of one fleet for the artifact.
fn fleet_json(rep: &crate::cluster::ClusterReport) -> Json {
    Json::obj(vec![
        ("instances_provisioned", Json::Num(rep.per_instance.len() as f64)),
        ("instance_seconds", Json::Num(rep.instance_seconds)),
        ("scale_ups", Json::Num(rep.scale_ups as f64)),
        ("scale_downs", Json::Num(rep.scale_downs as f64)),
        ("completed", Json::Num(rep.cluster.completed as f64)),
        ("shed", Json::Num(rep.shed as f64)),
        ("ttft_p50_s", Json::Num(rep.cluster.ttft.p50)),
        ("ttft_p99_s", Json::Num(rep.cluster.ttft.p99)),
        ("span_s", Json::Num(rep.cluster.span)),
        ("stps", Json::Num(rep.cluster.stps)),
    ])
}

/// Run the autoscaled-fleet experiment; artifacts land in
/// `<artifact_dir>/autoscale/`.
pub fn run(artifact_dir: &Path) -> Result<Report> {
    let mut report = Report::new(
        "autoscale-fleet",
        "Fixed-for-peak vs. SLO-driven autoscaled fleet on a diurnal + bursty workload",
    );
    report.notes.push(format!(
        "Study cluster: llama3-70b on xPU-HBM3 TP8, SLO router at a \
         {TTFT_TARGET} s TTFT target. Fixed fleet: {PEAK_INSTANCES} \
         instances. Elastic fleet: {MIN_INSTANCES}..{PEAK_INSTANCES} \
         instances, 1 s warm-up (billed), grow on shed pressure or \
         predicted-TTFT headroom, shrink after 1.5 s fully idle."
    ));

    let (fixed, auto) = fleet_comparison()?;
    let mut t = Table::new(
        "Fleet provisioning under the diurnal + bursty workload",
        &[
            "fleet",
            "instances",
            "scale +/-",
            "instance-s billed",
            "TTFT p50",
            "TTFT p99",
            "shed",
            "STPS",
        ],
    );
    t.push_row(fleet_row("fixed-for-peak", &fixed));
    t.push_row(fleet_row("autoscaled", &auto));
    report.tables.push(t);

    let saved = 1.0 - auto.instance_seconds / fixed.instance_seconds;
    report.notes.push(format!(
        "Autoscaling held the TTFT SLO on {:.1} instance-s vs {:.1} \
         fixed ({:.0}% fewer instance-hours).",
        auto.instance_seconds,
        fixed.instance_seconds,
        saved * 100.0
    ));

    let out_dir = artifact_dir.join("autoscale");
    std::fs::create_dir_all(&out_dir)?;
    std::fs::write(out_dir.join("fixed.json"), fixed.to_json().to_string())?;
    std::fs::write(
        out_dir.join("autoscaled.json"),
        auto.to_json().to_string(),
    )?;
    let summary = Json::obj(vec![
        ("ttft_target_s", Json::Num(TTFT_TARGET)),
        ("fixed", fleet_json(&fixed)),
        ("autoscaled", fleet_json(&auto)),
        ("instance_seconds_saved_frac", Json::Num(saved)),
    ]);
    let path = out_dir.join("summary.json");
    std::fs::write(&path, summary.to_string())?;
    report.notes.push(format!("wrote fleet artifact {}", path.display()));
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn autoscaled_fleet_bills_fewer_instance_seconds_at_the_slo() {
        let (fixed, auto) = fleet_comparison().unwrap();
        // The fixed fleet is provisioned (and billed) for peak the
        // whole run; the elastic one starts at the floor and pays for
        // capacity only after demand shows up.
        assert!(auto.scale_ups > 0, "diurnal peak must trigger growth");
        assert!(
            auto.instance_seconds < fixed.instance_seconds,
            "autoscaled {} vs fixed {}",
            auto.instance_seconds,
            fixed.instance_seconds
        );
        assert!(auto.per_instance.len() <= PEAK_INSTANCES);
        // Both fleets hold the admission SLO for what they serve.
        assert!(fixed.cluster.ttft.p50 <= TTFT_TARGET);
        assert!(auto.cluster.ttft.p50 <= TTFT_TARGET);
        // Conservation on both sides of the comparison.
        assert_eq!(fixed.cluster.completed + fixed.shed, fixed.offered);
        assert_eq!(auto.cluster.completed + auto.shed, auto.offered);
    }

    #[test]
    fn report_renders_and_emits_the_fleet_artifacts() {
        let dir = std::env::temp_dir()
            .join(format!("liminal-autoscale-{}", std::process::id()));
        let r = run(&dir).unwrap();
        assert_eq!(r.tables.len(), 1);
        assert!(r.to_markdown().contains("autoscaled"));
        let text =
            std::fs::read_to_string(dir.join("autoscale").join("summary.json"))
                .unwrap();
        let j = Json::parse(&text).unwrap();
        let fixed = j.get("fixed").unwrap();
        let auto = j.get("autoscaled").unwrap();
        assert!(
            auto.get("instance_seconds").unwrap().as_f64().unwrap()
                < fixed.get("instance_seconds").unwrap().as_f64().unwrap()
        );
        assert!(auto.get("scale_ups").unwrap().as_f64().unwrap() > 0.0);
        for stem in ["fixed", "autoscaled"] {
            let p = dir.join("autoscale").join(format!("{stem}.json"));
            assert!(p.exists(), "missing artifact {}", p.display());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
