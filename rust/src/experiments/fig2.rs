//! Figure 2: throughput sensitivity to memory bandwidth.
//!
//! Method (paper §4.4): take xPU-HBM3-TP128, pin `T_TPSync` to 200 ns to
//! isolate bandwidth, sweep per-chip bandwidth 4 -> 120 TB/s, and plot
//! UTPS normalized to the HBM3 baseline. Three contexts x three models.

use crate::apps::{Application, DecodePoint, Registry};
use crate::hw::{presets, SystemConfig};
use crate::model::{evaluate, EvalOptions};
use crate::report::{Report, Series};
use crate::Result;

/// Bandwidth sweep points, TB/s.
pub const BW_POINTS: [f64; 9] = [4.0, 8.0, 12.0, 18.0, 30.0, 45.0, 60.0, 90.0, 120.0];

/// Contexts plotted.
pub const CONTEXTS: [u64; 3] = [4096, 32768, 131072];

/// UTPS at one bandwidth point (TP128, 200 ns flat sync).
pub fn utps_at_bw(app: &dyn Application, tbps: f64, context: u64) -> f64 {
    let sys = SystemConfig::new(presets::bw_point(tbps), 128, 1);
    let opts = EvalOptions { enforce_capacity: false, ..Default::default() };
    evaluate(app, &sys, &DecodePoint { batch: 1, context }, &opts)
        .map(|p| p.utps)
        .unwrap_or(0.0)
}

/// Regenerate Figure 2's data series.
pub fn run() -> Result<Report> {
    let registry = Registry::builtin();
    let mut report = Report::new(
        "fig2",
        "UTPS vs memory bandwidth (normalized to HBM3-TP128 @ 200ns sync)",
    );
    report.notes.push(
        "Key Finding 5: doubling/quadrupling bandwidth over HBM3 gives large \
         gains; beyond that, synchronization latency dominates and returns \
         diminish."
            .into(),
    );
    for model in ["llama3-70b", "llama3-405b", "deepseek-v3"] {
        let app = registry.app(model).unwrap();
        for &ctx in CONTEXTS.iter() {
            let base = utps_at_bw(app.as_ref(), BW_POINTS[0], ctx);
            let mut s = Series::new(
                &format!("{model} T={}K", ctx / 1024),
                "mem_bw_tbps",
                "utps_normalized",
            );
            for &bw in BW_POINTS.iter() {
                s.points.push((bw, utps_at_bw(app.as_ref(), bw, ctx) / base));
            }
            report.series.push(s);
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::Registry;

    #[test]
    fn curve_is_monotonic_with_diminishing_returns() {
        let registry = Registry::builtin();
        let app = registry.app("llama3-405b").unwrap();
        let us: Vec<f64> = BW_POINTS
            .iter()
            .map(|&bw| utps_at_bw(app.as_ref(), bw, 131072))
            .collect();
        for w in us.windows(2) {
            assert!(w[1] > w[0]);
        }
        // Diminishing returns: first doubling gains more than the last.
        let first_gain = us[1] / us[0];
        let last_gain = us[8] / us[7];
        assert!(first_gain > last_gain);
    }

    #[test]
    fn asymptote_is_sync_limited() {
        // At 120 TB/s x 128 chips, T_mem for 405B @128K is ~29 us while
        // exposed sync is 75.6 us: >70% of time is synchronization, the
        // "hidden gatekeeper" (Key Finding 3 / 5).
        let registry = Registry::builtin();
        let app = registry.app("llama3-405b").unwrap();
        let sys = SystemConfig::new(presets::bw_point(120.0), 128, 1);
        let p = evaluate(
            app.as_ref(),
            &sys,
            &DecodePoint { batch: 1, context: 131072 },
            &EvalOptions { enforce_capacity: false, ..Default::default() },
        )
        .unwrap();
        assert!(p.lat.t_exposed > 2.0 * p.lat.t_mem);
    }

    #[test]
    fn total_uplift_is_asymptotic_not_linear() {
        // 30x the bandwidth must buy far less than 30x the throughput.
        let registry = Registry::builtin();
        let app = registry.app("llama3-70b").unwrap();
        let lo = utps_at_bw(app.as_ref(), 4.0, 131072);
        let hi = utps_at_bw(app.as_ref(), 120.0, 131072);
        assert!(hi / lo > 3.0, "uplift {}", hi / lo);
        assert!(hi / lo < 15.0, "uplift {}", hi / lo);
    }
}
