//! Table 4 (Appendix A.3): memory capacity required (GiB) and arithmetic
//! intensity (FLOPs/byte) per model, batch in {1, 32}, context 1K..128K.

use crate::apps::{DecodePoint, Registry};
use crate::report::{Report, Table};
use crate::sweep::PAPER_CONTEXTS;
use crate::{Result, GIB};

/// Regenerate Table 4.
pub fn run() -> Result<Report> {
    let registry = Registry::builtin();
    let mut report = Report::new(
        "table4",
        "Capacity required (GiB) and arithmetic intensity (FLOPs/byte)",
    );
    report.notes.push(
        "Key Finding 1 derives from this table: >= 629 GiB to serve the \
         largest models at all; 1.4 TB to serve 32 users of Llama3-405B at \
         128K."
            .into(),
    );

    let mut cap = Table::new(
        "Capacity (GiB)",
        &["T", "70B B=1", "70B B=32", "405B B=1", "405B B=32", "DSv3 B=1", "DSv3 B=32"],
    );
    let mut ami = Table::new(
        "Arithmetic intensity (FLOPs/byte)",
        &["T", "70B B=1", "70B B=32", "405B B=1", "405B B=32", "DSv3 B=1", "DSv3 B=32"],
    );
    let models = ["llama3-70b", "llama3-405b", "deepseek-v3"];
    for &t in PAPER_CONTEXTS.iter() {
        let mut cap_row = vec![fmt_ctx(t)];
        let mut ami_row = vec![fmt_ctx(t)];
        for model in models {
            let app = registry.app(model).unwrap();
            for b in [1u64, 32] {
                let pt = DecodePoint { batch: b, context: t };
                cap_row.push(format!("{:.0}", app.capacity_bytes(&pt) / GIB));
                ami_row.push(format!("{:.2}", app.arithmetic_intensity(&pt)));
            }
        }
        cap.push_row(cap_row);
        ami.push_row(ami_row);
    }
    report.tables.push(cap);
    report.tables.push(ami);
    Ok(report)
}

fn fmt_ctx(t: u64) -> String {
    format!("{}K", t / 1024)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Golden: spot-check cells against the paper's Table 4.
    #[test]
    fn cells_match_paper() {
        let registry = Registry::builtin();
        // (model, B, T, capacity GiB, AMI)
        let cases: &[(&str, u64, u64, f64, f64)] = &[
            ("llama3-70b", 1, 1024, 65.0, 1.99),
            ("llama3-70b", 32, 131072, 705.0, 20.31),
            ("llama3-405b", 32, 65536, 881.0, 45.47),
            ("llama3-405b", 1, 131072, 409.0, 4.30),
            ("deepseek-v3", 1, 1024, 625.0, 1.37),
            ("deepseek-v3", 32, 131072, 762.0, 89.83),
            ("deepseek-v3", 32, 4096, 629.0, 10.05),
        ];
        for &(m, b, t, want_cap, want_ami) in cases {
            let app = registry.app(m).unwrap();
            let pt = DecodePoint { batch: b, context: t };
            let cap = app.capacity_bytes(&pt) / GIB;
            let ami = app.arithmetic_intensity(&pt);
            assert!(
                (cap - want_cap).abs() / want_cap < 0.02,
                "{m} B={b} T={t}: cap {cap} vs {want_cap}"
            );
            // Llama AMI matches within 3%. DeepSeek's printed AMI
            // implies a ~750 GB byte denominator that contradicts the
            // paper's own 625 GiB capacity column (and its A.2 pseudo-
            // code double-counts out_flops); we keep the self-consistent
            // accounting and accept ~15% deviation there. See
            // EXPERIMENTS.md "Known deviations".
            let tol = if m == "deepseek-v3" { 0.20 } else { 0.05 };
            assert!(
                (ami - want_ami).abs() / want_ami < tol,
                "{m} B={b} T={t}: ami {ami} vs {want_ami}"
            );
        }
    }

    #[test]
    fn renders_eight_contexts() {
        let r = run().unwrap();
        assert_eq!(r.tables[0].rows.len(), 8);
        assert_eq!(r.tables[1].rows.len(), 8);
    }
}
