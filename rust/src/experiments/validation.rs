//! Table 7 / Appendix E analog: validate LIMINAL against *executed*
//! silicon — our CPU PJRT substrate standing in for the paper's
//! anonymized commercial chip and H100.
//!
//! Two studies, mirroring the appendix:
//!
//! 1. **GEMV microbenchmark** — LIMINAL predicts a memory-bound latency
//!    of `bytes / stream_bw`; we execute the AOT GEMV through PJRT and
//!    report the measured/predicted gap (the paper saw ~5x on H100 from
//!    launch overhead and imperfect prefetch).
//! 2. **Decode steps** — LIMINAL models the small executable transformer
//!    as an application on a "CPU chip" (stream bandwidth measured with
//!    a copy benchmark, tensor peak measured with the AOT GEMM); we run
//!    real decode steps through the PJRT engine and compare tokens/sec.

use std::path::PathBuf;

use anyhow::Context;

use crate::apps::{DecodePoint, ModelSpec};
use crate::hw::{Chip, SyncModel, SystemConfig};
use crate::model::{evaluate, EvalOptions};
use crate::report::{Report, Table};
use crate::runtime::Runtime;
use crate::serving::PjrtEngine;
use crate::Result;

/// Options for the validation run.
#[derive(Debug, Clone)]
pub struct ValidationOptions {
    /// Where `manifest.json` lives.
    pub artifact_dir: PathBuf,
    /// Timed repetitions per measurement (median taken).
    pub reps: usize,
    /// Decode steps per batch point.
    pub decode_steps: usize,
}

impl Default for ValidationOptions {
    fn default() -> Self {
        ValidationOptions {
            artifact_dir: PathBuf::from("artifacts"),
            reps: 20,
            decode_steps: 24,
        }
    }
}

fn median(samples: &mut Vec<f64>) -> f64 {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[samples.len() / 2]
}

/// The "CPU chip" LIMINAL models the executable substrate as.
fn cpu_chip(stream_bw: f64, tensor_flops: f64) -> Chip {
    Chip {
        name: "CPU-PJRT".into(),
        mem_bw: stream_bw,
        tensor_flops,
        scalar_flops: tensor_flops / 8.0,
        mem_capacity: 64.0 * crate::GIB,
        sync: SyncModel::paper_default(),
        pp_sync: 0.0,
        die_area_mm2: 0.0,
        mem_pj_per_bit: 0.0,
        notes: "calibrated from stream + GEMM microbenchmarks".into(),
    }
}

/// Build a `ModelSpec` for the executable decode model (fp32 elements).
fn decode_model_spec(engine: &PjrtEngine, rt: &Runtime) -> Result<ModelSpec> {
    let entry = rt.manifest().decode_bucket(engine.batch)?;
    let g = |k: &str| {
        entry
            .config_num(k)
            .with_context(|| format!("decode entry missing config.{k}"))
    };
    Ok(ModelSpec {
        name: format!("tiny-decode-b{}", engine.batch),
        num_layers: g("num_layers")? as u64,
        num_dense_layers: g("num_layers")? as u64,
        embed_dim: g("embed_dim")? as u64,
        heads: g("heads")? as u64,
        kv_heads: g("kv_heads")? as u64,
        head_dim: g("head_dim")? as u64,
        intermediate_dim: g("intermediate_dim")? as u64,
        vocab: g("vocab")? as u64,
        elem_bytes: 4.0, // the executable model runs fp32
        mla: None,
        moe: None,
    })
}

/// Run the full validation; returns the Table 7 analog.
pub fn run_validation(opts: &ValidationOptions) -> Result<Report> {
    let mut report = Report::new(
        "table7",
        "Validation: LIMINAL prediction vs executed PJRT substrate",
    );
    if !opts.artifact_dir.join("manifest.json").exists() {
        report.notes.push(format!(
            "SKIPPED: no artifacts at {} (run `make artifacts`)",
            opts.artifact_dir.display()
        ));
        return Ok(report);
    }

    let mut rt = Runtime::new(&opts.artifact_dir)?;

    // --- Calibration ----------------------------------------------------
    let stream_bw = Runtime::measure_stream_bandwidth();
    let gemm = rt.load("gemm")?;
    let gemm_args = rt.zero_inputs("gemm")?;
    let mut times: Vec<f64> = (0..opts.reps)
        .map(|_| gemm.execute_timed(&gemm_args))
        .collect::<Result<_>>()?;
    let gemm_time = median(&mut times);
    let gemm_flops = gemm.entry.num("flops").context("gemm flops")?;
    let tensor_peak = gemm_flops / gemm_time;
    report.notes.push(format!(
        "calibration: stream {:.2} GB/s, GEMM {:.2} GFLOP/s",
        stream_bw / 1e9,
        tensor_peak / 1e9
    ));

    let mut t = Table::new(
        "Table 7 (analog)",
        &["Workload", "LIMINAL", "Measured", "Ratio (LIMINAL/measured)"],
    );

    // --- Study 1: GEMV --------------------------------------------------
    let gemv = rt.load("gemv")?;
    let gemv_args = rt.zero_inputs("gemv")?;
    let mut times: Vec<f64> = (0..opts.reps)
        .map(|_| gemv.execute_timed(&gemv_args))
        .collect::<Result<_>>()?;
    let gemv_measured = median(&mut times);
    let gemv_bytes = gemv.entry.num("bytes").context("gemv bytes")?;
    let gemv_predicted = gemv_bytes / stream_bw;
    t.push_row(vec![
        format!("GEMV 1x{}x{}", gemv.entry.num("m").unwrap_or(0.0), gemv.entry.num("n").unwrap_or(0.0)),
        format!("{:.1} µs", gemv_predicted * 1e6),
        format!("{:.1} µs", gemv_measured * 1e6),
        format!("{:.2}x faster than real", gemv_measured / gemv_predicted),
    ]);

    // --- Study 2: decode steps -------------------------------------------
    let chip = cpu_chip(stream_bw, tensor_peak);
    let sys = SystemConfig::new(chip, 1, 1);
    for batch in [1u64, 8] {
        let mut engine = PjrtEngine::new(&mut rt, batch)?;
        engine.randomize_params(7)?;
        // Warm the executable, then measure steps mid-context.
        let tokens = vec![1i32; engine.batch as usize];
        let mut lats = Vec::new();
        for i in 0..opts.decode_steps {
            if engine.pos >= engine.context {
                engine.reset()?;
            }
            let (_, dt) = engine.step(&tokens)?;
            if i >= 4 {
                lats.push(dt);
            }
        }
        let measured = median(&mut lats);
        let measured_stps = engine.batch as f64 / measured;

        let spec = decode_model_spec(&engine, &rt)?;
        let app = crate::apps::Llama3::new(spec);
        let mean_ctx = (engine.context / 2).max(1);
        let perf = evaluate(
            &app,
            &sys,
            &DecodePoint { batch: engine.batch, context: mean_ctx },
            &EvalOptions::default(),
        )?;
        let predicted_stps = engine.batch as f64 * perf.utps;
        t.push_row(vec![
            format!("decode B={} (T/2={})", engine.batch, mean_ctx),
            format!("{:.0} tok/s", predicted_stps),
            format!("{:.0} tok/s", measured_stps),
            format!("{:.2}x", predicted_stps / measured_stps),
        ]);
    }
    report.notes.push(
        "As in the paper's Appendix E, LIMINAL is an upper bound: the \
         measured substrate pays dispatch, host-sync, and cache-refill \
         costs the limit study idealizes away (paper's gap: ~2.3x on its \
         commercial simulator, ~5x on the H100 GEMV)."
            .into(),
    );
    report.tables.push(t);
    Ok(report)
}
