//! Figure 3: TP8 (fast sync) vs TP128 at varying synchronization
//! latency, for HBM3 / 3D-DRAM / SRAM memory technologies.
//! Llama3-405B, 128K context, batch 1 (paper §4.5).

use crate::apps::{Application, DecodePoint, Registry};
use crate::hw::{presets, Chip};
use crate::model::{evaluate, EvalOptions};
use crate::parallel::{fit_system, FitRequest};
use crate::report::{Report, Series};
use crate::Result;

/// Sync-latency sweep, seconds (200 ns .. 10 µs).
pub const SYNC_POINTS: [f64; 9] = [
    200e-9, 400e-9, 800e-9, 1.5e-6, 2.5e-6, 4e-6, 5e-6, 7.5e-6, 10e-6,
];

/// The three memory technologies compared.
pub fn techs() -> Vec<Chip> {
    vec![presets::hbm3(), presets::dram3d(), presets::sram()]
}

/// UTPS for a TP-`tp` system of `chip` with `T_TPSync` forced to `sync`.
/// PP grows to fit capacity-starved chips (SRAM).
pub fn utps_at_sync(
    app: &dyn Application,
    chip: &Chip,
    tp: u64,
    sync: f64,
    context: u64,
) -> Option<f64> {
    let forced = chip.with_flat_sync(sync);
    let pt = DecodePoint { batch: 1, context };
    let sys = fit_system(app, &FitRequest {
        tp: Some(tp),
        ..FitRequest::new(forced, pt)
    })
    .ok()?;
    evaluate(app, &sys, &pt, &EvalOptions::default())
        .ok()
        .map(|p| p.utps)
}

/// Build the figure's series for one model.
pub fn series_for_model(app: &dyn Application, context: u64) -> Vec<Series> {
    let mut out = Vec::new();
    for chip in techs() {
        // TP128 with swept sync latency.
        let mut s = Series::new(
            &format!("{} TP128", chip.name),
            "tp_sync_s",
            "utps",
        );
        for &sync in SYNC_POINTS.iter() {
            if let Some(u) = utps_at_sync(app, &chip, 128, sync, context) {
                s.points.push((sync, u));
            }
        }
        out.push(s);
        // TP8 reference at a fixed fast 200 ns (the dashed line).
        let mut r = Series::new(
            &format!("{} TP8 (200ns ref)", chip.name),
            "tp_sync_s",
            "utps",
        );
        if let Some(u) = utps_at_sync(app, &chip, 8, 200e-9, context) {
            for &sync in SYNC_POINTS.iter() {
                r.points.push((sync, u));
            }
        }
        out.push(r);
    }
    out
}

/// Regenerate Figure 3 (Llama3-405B only; Figure 6 covers all models).
pub fn run() -> Result<Report> {
    let registry = Registry::builtin();
    let app = registry.app("llama3-405b").unwrap();
    let mut report = Report::new(
        "fig3",
        "TP8 vs TP128 under varying sync latency (Llama3-405B, 128K, B=1)",
    );
    report.notes.push(
        "Key Finding 6: with an order of magnitude more bandwidth than \
         HBM3, sync latency becomes the first-order determinant of \
         performance; sub-2.5µs collectives across 128 chips beat small \
         fast TP domains."
            .into(),
    );
    report.series = series_for_model(app.as_ref(), 131072);
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::Registry;

    fn app() -> std::sync::Arc<dyn crate::apps::Application> {
        Registry::builtin().app("llama3-405b").unwrap()
    }

    #[test]
    fn tp128_beats_tp8_even_at_10us_sync_on_hbm3() {
        // The paper's "challenging conventional wisdom" observation.
        let a = app();
        let chip = presets::hbm3();
        let tp128_slow = utps_at_sync(a.as_ref(), &chip, 128, 10e-6, 131072).unwrap();
        let tp8_fast = utps_at_sync(a.as_ref(), &chip, 8, 200e-9, 131072).unwrap();
        assert!(
            tp128_slow > tp8_fast,
            "tp128@10us {tp128_slow} vs tp8@200ns {tp8_fast}"
        );
    }

    #[test]
    fn sync_sensitivity_grows_with_bandwidth() {
        // Gain from 2.5us -> 200ns should be largest for SRAM (Key
        // Finding 6).
        let a = app();
        let gain = |chip: &Chip| {
            utps_at_sync(a.as_ref(), chip, 128, 200e-9, 131072).unwrap()
                / utps_at_sync(a.as_ref(), chip, 128, 2.5e-6, 131072).unwrap()
        };
        let g_hbm3 = gain(&presets::hbm3());
        let g_dram3d = gain(&presets::dram3d());
        let g_sram = gain(&presets::sram());
        assert!(g_sram > g_dram3d && g_dram3d > g_hbm3,
            "gains {g_hbm3} {g_dram3d} {g_sram}");
    }

    #[test]
    fn sram_reaches_paper_range_at_default_sync() {
        // §4.7: 3D-DRAM/SRAM sustain ~1500-2800 UTPS at 128K context.
        let a = app();
        let u = utps_at_sync(a.as_ref(), &presets::sram(), 128, 1.5e-6, 131072).unwrap();
        assert!(u > 1400.0 && u < 3000.0, "got {u}");
        let d = utps_at_sync(a.as_ref(), &presets::dram3d(), 128, 1.5e-6, 131072).unwrap();
        assert!(d > 1200.0 && d < 2000.0, "got {d}");
    }
}
