//! Experiment registry: one module per table/figure in the paper's
//! evaluation section, each regenerating its artifact from the
//! analytical core (see DESIGN.md "Per-experiment index").

mod autoscale;
mod cent;
mod cluster_scaling;
mod compute_role;
mod fig2;
mod fig3;
mod fig4;
mod fig5;
mod fig6;
mod findings;
mod preemption;
mod software_gap;
mod table1;
mod table2;
mod table4;
mod tables56;
mod validation;

pub use autoscale::{fleet_comparison, run as run_autoscale};
pub use cent::{cent_pp_record, cent_tp_record};
pub use cluster_scaling::{
    router_comparison, run as run_cluster_scaling, OVERLOAD_RATE,
};
pub use findings::run_findings;
pub use preemption::{policy_comparison, run as run_preemption, PolicyComparison};
pub use software_gap::{
    run as run_software_gap, PAPER_COMMERCIAL_GAP, PAPER_H100_GEMV_GAP,
};
pub use validation::{run_validation, ValidationOptions};

use crate::report::Report;
use crate::Result;

/// All experiment ids, in paper order.
pub const ALL: &[&str] = &[
    "table1", "table2", "table4", "table5", "table6", "table7",
    "fig2", "fig3", "fig4", "fig5", "fig6", "findings", "moe-imbalance",
    "compute-role", "software-gap", "cluster-scaling", "autoscale-fleet",
    "preemption",
];

/// Run one experiment by id. `artifact_dir` is used by experiments that
/// execute AOT artifacts (table7) or emit their own artifacts
/// (cluster-scaling writes per-policy JSON there); the purely analytic
/// experiments ignore it.
pub fn run(id: &str, artifact_dir: &std::path::Path) -> Result<Report> {
    match id {
        "table1" => table1::run(),
        "table2" => table2::run(),
        "table4" => table4::run(),
        "table5" => tables56::run_table5(),
        "table6" => tables56::run_table6(),
        "table7" => validation::run_validation(&ValidationOptions {
            artifact_dir: artifact_dir.to_path_buf(),
            ..Default::default()
        }),
        "compute-role" => compute_role::run(),
        "fig2" => fig2::run(),
        "fig3" => fig3::run(),
        "fig4" => fig4::run(),
        "fig5" => fig5::run(),
        "fig6" => fig6::run(),
        "findings" => findings::run_findings(),
        "software-gap" => software_gap::run(),
        "cluster-scaling" => cluster_scaling::run(artifact_dir),
        "autoscale-fleet" => autoscale::run(artifact_dir),
        "preemption" => preemption::run(artifact_dir),
        "moe-imbalance" => moe_imbalance(),
        _ => anyhow::bail!(
            "unknown experiment '{id}' (known: {})",
            ALL.join(", ")
        ),
    }
}

/// Appendix A.2's imbalance-factor table: MI(B) for DeepSeekV3.
fn moe_imbalance() -> Result<Report> {
    use crate::moe::imbalance_factor;
    use crate::report::Table;
    let mut report = Report::new(
        "moe-imbalance",
        "MoE imbalance factor MI(B) for DeepSeekV3 (MR=256, MA=8)",
    );
    report.notes.push(
        "Paper A.2: MI ~= 3x at B=64; approaches 1 as batch grows.".into(),
    );
    let mut t = Table::new("MI by batch size", &["B", "MI"]);
    for b in [1u64, 2, 4, 8, 16, 32, 64, 128, 256, 1024, 4096] {
        t.push_row(vec![b.to_string(), format!("{:.3}", imbalance_factor(256, 8, b))]);
    }
    report.tables.push(t);
    Ok(report)
}
