//! Priority-preemption study: FIFO run-to-completion vs. priority
//! admission + KV preemption on the same KV-starved instance at the
//! same offered load.
//!
//! The paper's capacity finding (KV cache competing with weights for
//! HBM) means a production instance often runs with far less KV
//! headroom than concurrency budget: admission blocks on KV, not on
//! lanes. Under FIFO, a latency-critical request arriving behind a deep
//! best-effort backlog waits for the whole line. Priority scheduling
//! admits it first, and preemption goes further: when the KV budget is
//! full of best-effort work, the urgent arrival evicts the
//! lowest-class active request (its KV is dropped and re-materialized
//! later, both priced into step time) instead of waiting for a natural
//! completion. This experiment prices that trade: high-priority tail
//! TTFT collapses, best-effort E2E pays for it, total throughput stays
//! within noise.
//!
//! Artifacts land in `<artifacts>/preemption/`: the full cluster report
//! for each policy (`fifo.json`, `preempt.json`) and a side-by-side
//! `summary.json` with the per-class TTFT tails.

use std::path::Path;

use crate::apps::Registry;
use crate::cluster::{ClusterMode, ClusterReport, ClusterSim, ClusterSpec, RoundRobin};
use crate::hw::{presets, SystemConfig};
use crate::report::{Report, Table};
use crate::serving::{
    percentile, AnalyticEngine, KvBudget, PreemptionConfig, ReqId, Request,
    RequestArena, SimConfig, SimObserver, StepEngine, WorkloadGen,
    WorkloadSpec,
};
use crate::util::json::Json;
use crate::Result;

/// The urgent class in the study mix (class 0 is best-effort).
const HI_CLASS: u8 = 2;

/// KV capacity in tokens: a few concurrent requests' worth, so
/// admission blocks on KV long before it blocks on lanes.
const KV_BUDGET_TOKENS: f64 = 8192.0;

/// Step-time cost of dropping a victim's KV (seconds).
const EVICT_COST: f64 = 0.002;

/// Step-time cost of re-materializing an evicted request's KV.
const RESTORE_COST: f64 = 0.005;

/// The shared workload: one best-effort-dominated stream with an
/// urgent minority class, offered faster than the KV-starved instance
/// drains so a backlog builds and stays.
fn study_workload() -> Vec<Request> {
    WorkloadGen::new(WorkloadSpec {
        arrival_rate: 4.0,
        n_requests: 120,
        context: (1024, 4096),
        gen: (64, 256),
        priority_mix: vec![(0, 4.0), (HI_CLASS, 1.0)],
        seed: 17,
    })
    .generate()
}

/// Build the study instance: llama3-70b pricing on HBM3 TP8 with a
/// deliberately small KV budget (weights own the HBM), behind a
/// pass-through router.
fn study_sim(preempt: PreemptionConfig) -> ClusterSim {
    let registry = Registry::builtin();
    let app = registry.app("llama3-70b").expect("registry model");
    let sys = SystemConfig::new(presets::hbm3(), 8, 1);
    let bpt = app.kv_bytes_per_token();
    let engines: Vec<Box<dyn StepEngine>> =
        vec![Box::new(AnalyticEngine::new(app, sys))];
    let mut sim = ClusterSim::new(
        engines,
        KvBudget::new(KV_BUDGET_TOKENS * bpt, 0.0, bpt),
        Box::new(RoundRobin::new()),
        ClusterSpec {
            mode: ClusterMode::Colocated,
            max_batch: 16,
            prefill_chunk: 512,
            kv_link_bw: f64::INFINITY,
            autoscale: None,
            sim: SimConfig::default(),
        },
    );
    sim.set_preemption(preempt);
    sim
}

/// Observer recording each finished request's TTFT by arena slot, so
/// the two runs' latencies can be classified by the *shared* workload's
/// priorities (the FIFO baseline runs the same arrivals stripped to a
/// single class).
#[derive(Default)]
struct TtftBySlot {
    ttfts: Vec<Option<f64>>,
}

impl SimObserver for TtftBySlot {
    fn on_retire(
        &mut self,
        _now: f64,
        _instance: usize,
        id: ReqId,
        lifecycle_done: bool,
        arena: &RequestArena,
    ) {
        if !lifecycle_done {
            return;
        }
        if self.ttfts.len() <= id.index() {
            self.ttfts.resize(id.index() + 1, None);
        }
        self.ttfts[id.index()] = arena[id].ttft();
    }
}

/// Split a run's recorded TTFTs into `(best_effort, urgent)` samples
/// using the shared workload's class tags (requests are allocated into
/// the arena in workload order, so slot `i` is `workload[i]`).
fn split_by_class(workload: &[Request], obs: &TtftBySlot) -> (Vec<f64>, Vec<f64>) {
    let mut lo = Vec::new();
    let mut hi = Vec::new();
    for (i, r) in workload.iter().enumerate() {
        let Some(Some(t)) = obs.ttfts.get(i) else { continue };
        if r.priority == HI_CLASS {
            hi.push(*t);
        } else {
            lo.push(*t);
        }
    }
    (lo, hi)
}

/// Both policy runs over the shared workload, with per-class TTFT
/// tails. Public so the acceptance test pins the comparison without
/// re-deriving the configuration.
pub struct PolicyComparison {
    /// FIFO run-to-completion baseline (single class, no preemption).
    pub fifo: ClusterReport,
    /// Priority admission + KV preemption on the same arrivals.
    pub preempt: ClusterReport,
    /// Urgent-class TTFT p99 under FIFO.
    pub fifo_hi_ttft_p99: f64,
    /// Urgent-class TTFT p99 under priority + preemption.
    pub preempt_hi_ttft_p99: f64,
    /// Best-effort TTFT p99 under FIFO.
    pub fifo_lo_ttft_p99: f64,
    /// Best-effort TTFT p99 under priority + preemption.
    pub preempt_lo_ttft_p99: f64,
}

/// Run the comparison: the same arrival stream through the same
/// KV-starved instance, once FIFO (classes stripped), once with
/// priority admission + preemption.
pub fn policy_comparison() -> PolicyComparison {
    let workload = study_workload();

    // FIFO baseline: identical arrivals and lengths, single class,
    // preemption disabled — the historical batcher bit for bit.
    let mut fifo_workload = workload.clone();
    for r in &mut fifo_workload {
        r.priority = 0;
    }
    let mut fifo_obs = TtftBySlot::default();
    let fifo = study_sim(PreemptionConfig::default())
        .run_with(fifo_workload, &mut fifo_obs);

    let mut pre_obs = TtftBySlot::default();
    let pre = study_sim(PreemptionConfig {
        enabled: true,
        evict_cost: EVICT_COST,
        restore_cost: RESTORE_COST,
    })
    .run_with(workload.clone(), &mut pre_obs);

    let (mut fifo_lo, mut fifo_hi) = split_by_class(&workload, &fifo_obs);
    let (mut pre_lo, mut pre_hi) = split_by_class(&workload, &pre_obs);
    PolicyComparison {
        fifo_hi_ttft_p99: percentile(&mut fifo_hi, 99.0),
        preempt_hi_ttft_p99: percentile(&mut pre_hi, 99.0),
        fifo_lo_ttft_p99: percentile(&mut fifo_lo, 99.0),
        preempt_lo_ttft_p99: percentile(&mut pre_lo, 99.0),
        fifo,
        preempt: pre,
    }
}

/// One policy row for the comparison table.
fn policy_row(label: &str, rep: &ClusterReport, hi_p99: f64, lo_p99: f64) -> Vec<String> {
    vec![
        label.to_string(),
        rep.cluster.completed.to_string(),
        format!("{} / {}", rep.cluster.preemptions, rep.cluster.restores),
        format!("{:.3} s", hi_p99),
        format!("{:.3} s", lo_p99),
        format!("{:.3} s", rep.cluster.e2e.p99),
        format!("{:.0}", rep.cluster.stps),
    ]
}

/// JSON summary of one policy run for the artifact.
fn policy_json(rep: &ClusterReport, hi_p99: f64, lo_p99: f64) -> Json {
    Json::obj(vec![
        ("completed", Json::Num(rep.cluster.completed as f64)),
        ("preemptions", Json::Num(rep.cluster.preemptions as f64)),
        ("restores", Json::Num(rep.cluster.restores as f64)),
        ("hi_ttft_p99_s", Json::Num(hi_p99)),
        ("lo_ttft_p99_s", Json::Num(lo_p99)),
        ("ttft_p99_s", Json::Num(rep.cluster.ttft.p99)),
        ("e2e_p99_s", Json::Num(rep.cluster.e2e.p99)),
        ("span_s", Json::Num(rep.cluster.span)),
        ("stps", Json::Num(rep.cluster.stps)),
    ])
}

/// Run the preemption experiment; artifacts land in
/// `<artifact_dir>/preemption/`.
pub fn run(artifact_dir: &Path) -> Result<Report> {
    let mut report = Report::new(
        "preemption",
        "FIFO vs. priority admission + KV preemption on a KV-starved instance",
    );
    report.notes.push(format!(
        "Study instance: llama3-70b on xPU-HBM3 TP8, KV budget clamped \
         to {KV_BUDGET_TOKENS:.0} tokens (weights own the HBM), 16 \
         lanes, 512-token prefill chunks. Workload: 120 requests at 4 \
         req/s, 80% best-effort / 20% urgent (class {HI_CLASS}); evict \
         {EVICT_COST} s, restore {RESTORE_COST} s priced into step time."
    ));

    let c = policy_comparison();
    let mut t = Table::new(
        "Per-class TTFT tails at the same offered load",
        &[
            "policy",
            "completed",
            "evict/restore",
            "urgent TTFT p99",
            "best-effort TTFT p99",
            "E2E p99",
            "STPS",
        ],
    );
    t.push_row(policy_row(
        "fifo",
        &c.fifo,
        c.fifo_hi_ttft_p99,
        c.fifo_lo_ttft_p99,
    ));
    t.push_row(policy_row(
        "priority+preempt",
        &c.preempt,
        c.preempt_hi_ttft_p99,
        c.preempt_lo_ttft_p99,
    ));
    report.tables.push(t);

    report.notes.push(format!(
        "Urgent-class TTFT p99: {:.3} s FIFO -> {:.3} s with priority + \
         preemption ({:.0}x lower); best-effort pays the eviction bill \
         ({:.3} s -> {:.3} s p99).",
        c.fifo_hi_ttft_p99,
        c.preempt_hi_ttft_p99,
        c.fifo_hi_ttft_p99 / c.preempt_hi_ttft_p99.max(1e-9),
        c.fifo_lo_ttft_p99,
        c.preempt_lo_ttft_p99,
    ));

    let out_dir = artifact_dir.join("preemption");
    std::fs::create_dir_all(&out_dir)?;
    std::fs::write(out_dir.join("fifo.json"), c.fifo.to_json().to_string())?;
    std::fs::write(
        out_dir.join("preempt.json"),
        c.preempt.to_json().to_string(),
    )?;
    let summary = Json::obj(vec![
        ("hi_class", Json::Num(HI_CLASS as f64)),
        ("kv_budget_tokens", Json::Num(KV_BUDGET_TOKENS)),
        (
            "fifo",
            policy_json(&c.fifo, c.fifo_hi_ttft_p99, c.fifo_lo_ttft_p99),
        ),
        (
            "preempt",
            policy_json(&c.preempt, c.preempt_hi_ttft_p99, c.preempt_lo_ttft_p99),
        ),
        (
            "hi_ttft_p99_speedup",
            Json::Num(c.fifo_hi_ttft_p99 / c.preempt_hi_ttft_p99.max(1e-9)),
        ),
    ]);
    let path = out_dir.join("summary.json");
    std::fs::write(&path, summary.to_string())?;
    report
        .notes
        .push(format!("wrote preemption artifact {}", path.display()));
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preemption_collapses_the_urgent_tail_at_the_same_load() {
        let c = policy_comparison();
        // Both runs drain the same 120 arrivals.
        assert_eq!(c.fifo.cluster.completed, 120);
        assert_eq!(c.preempt.cluster.completed, 120);
        // The KV-starved backlog really forces evictions, and every
        // eviction is eventually restored on the drained run.
        assert!(c.preempt.cluster.preemptions > 0, "no evictions happened");
        assert_eq!(
            c.preempt.cluster.preemptions,
            c.preempt.cluster.restores
        );
        assert_eq!(c.fifo.cluster.preemptions, 0);
        // The acceptance bar: priority + preemption strictly improves
        // the urgent class's tail TTFT over FIFO at the same offered
        // load.
        assert!(
            c.preempt_hi_ttft_p99 < c.fifo_hi_ttft_p99,
            "urgent p99 {} not below FIFO {}",
            c.preempt_hi_ttft_p99,
            c.fifo_hi_ttft_p99
        );
        // And the improvement is paid for by best-effort, not magic:
        // the favored class cannot also make everyone faster.
        assert!(c.preempt_lo_ttft_p99 >= c.fifo_lo_ttft_p99 * 0.5);
    }

    #[test]
    fn report_renders_and_emits_the_policy_artifacts() {
        let dir = std::env::temp_dir()
            .join(format!("liminal-preemption-{}", std::process::id()));
        let r = run(&dir).unwrap();
        assert_eq!(r.tables.len(), 1);
        assert!(r.to_markdown().contains("priority+preempt"));
        let text = std::fs::read_to_string(
            dir.join("preemption").join("summary.json"),
        )
        .unwrap();
        let j = Json::parse(&text).unwrap();
        let fifo = j.get("fifo").unwrap();
        let pre = j.get("preempt").unwrap();
        assert!(
            pre.get("hi_ttft_p99_s").unwrap().as_f64().unwrap()
                < fifo.get("hi_ttft_p99_s").unwrap().as_f64().unwrap()
        );
        assert!(pre.get("preemptions").unwrap().as_f64().unwrap() > 0.0);
        assert!(
            j.get("hi_ttft_p99_speedup").unwrap().as_f64().unwrap() > 1.0
        );
        for stem in ["fifo", "preempt"] {
            let p = dir.join("preemption").join(format!("{stem}.json"));
            assert!(p.exists(), "missing artifact {}", p.display());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
