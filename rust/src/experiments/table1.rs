//! Table 1: chip configurations (the hardware design space).

use crate::hw::presets;
use crate::report::{Report, Table};
use crate::{Result, GIB, PFLOPS, TBPS};

/// Render Table 1 from the presets (single source of truth: `hw::presets`).
pub fn run() -> Result<Report> {
    let mut report = Report::new("table1", "Chip configurations");
    report.notes.push(
        "Bandwidths are the calibrated streaming values that reproduce the \
         paper's tables; Table 1 in the paper rounds HBM3 to 4 TB/s (see \
         hw::presets docs)."
            .into(),
    );
    let mut t = Table::new(
        "Chip configurations",
        &["Configuration", "Mem BW (TB/s)", "Compute (PFLOPS)", "Mem Capacity", "Notes"],
    );
    for chip in presets::table1() {
        let cap = if chip.mem_capacity >= GIB {
            format!("{:.0}GB", chip.mem_capacity / GIB)
        } else {
            format!("{:.0}MB", chip.mem_capacity / (1024.0 * 1024.0))
        };
        t.push_row(vec![
            chip.name.clone(),
            format!("{:.1}", chip.mem_bw / TBPS),
            format!("{:.2}", chip.tensor_flops / PFLOPS),
            cap,
            chip.notes.clone(),
        ]);
    }
    report.tables.push(t);
    Ok(report)
}

#[cfg(test)]
mod tests {
    #[test]
    fn table1_renders_five_rows() {
        let r = super::run().unwrap();
        assert_eq!(r.tables[0].rows.len(), 5);
        assert!(r.to_markdown().contains("xPU-COWS"));
    }
}
