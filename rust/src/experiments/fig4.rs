//! Figure 4: normalized STPS/W for xPU-HBM3 across context lengths
//! (paper §4.6) — the reuse/efficiency story.

use crate::apps::{Application, DecodePoint, Registry};
use crate::hw::{presets, SystemConfig};
use crate::model::{evaluate, max_batch_for_system, EvalOptions};
use crate::power::PowerModel;
use crate::report::{normalize_to_first, Report, Series};
use crate::sweep::PAPER_CONTEXTS;
use crate::Result;

/// STPS/W at max-fit batch for one (model, context) on HBM3-TP128.
pub fn stps_per_watt(app: &dyn Application, context: u64) -> Option<(f64, f64)> {
    let sys = SystemConfig::new(presets::hbm3(), 128, 1);
    let b = max_batch_for_system(app, &sys, context)?;
    let perf = evaluate(
        app,
        &sys,
        &DecodePoint { batch: b, context },
        &EvalOptions::default(),
    )
    .ok()?;
    let watts = PowerModel::default().system_power(&sys).total_watts;
    Some((perf.stps / watts, perf.utps))
}

/// Regenerate Figure 4.
pub fn run() -> Result<Report> {
    let registry = Registry::builtin();
    let mut report = Report::new(
        "fig4",
        "Normalized STPS/W vs context (xPU-HBM3-TP128, max-fit batch; \
         normalized to the 4K point)",
    );
    report.notes.push(
        "Key Finding 7: efficiency is driven by reuse — weight reuse for \
         dense models, expert utilization for MoE — and decays with \
         context as KV traffic swamps the reusable bytes."
            .into(),
    );
    for model in ["llama3-70b", "llama3-405b", "deepseek-v3"] {
        let app = registry.app(model).unwrap();
        let mut s = Series::new(model, "context", "stps_per_watt_norm");
        // Anchor the normalization at 4K like the paper.
        let contexts: Vec<u64> = PAPER_CONTEXTS
            .iter()
            .copied()
            .filter(|&c| c >= 4096)
            .collect();
        for ctx in contexts {
            if let Some((spw, _)) = stps_per_watt(app.as_ref(), ctx) {
                s.points.push((ctx as f64, spw));
            }
        }
        normalize_to_first(&mut s);
        report.series.push(s);
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::Registry;

    #[test]
    fn efficiency_decays_with_context_for_all_models() {
        let r = run().unwrap();
        assert_eq!(r.series.len(), 3);
        for s in &r.series {
            assert_eq!(s.points[0].1, 1.0);
            let last = s.points.last().unwrap().1;
            assert!(last < 0.25, "{}: 128K point {last}", s.label);
        }
    }

    #[test]
    fn batch_sweep_tradeoff_matches_paper_text() {
        // §4.6: for Llama3-70B at 4K, giving up ~10% UTPS (2059 -> ~1913)
        // buys ~30x STPS/W.
        let registry = Registry::builtin();
        let app = registry.app("llama3-70b").unwrap();
        let sys = SystemConfig::new(presets::hbm3(), 128, 1);
        let opts = EvalOptions::default();
        let watts = PowerModel::default().system_power(&sys).total_watts;
        let p1 = evaluate(app.as_ref(), &sys, &DecodePoint { batch: 1, context: 4096 }, &opts)
            .unwrap();
        let p31 = evaluate(app.as_ref(), &sys, &DecodePoint { batch: 31, context: 4096 }, &opts)
            .unwrap();
        assert!((p1.utps - 2056.0).abs() / 2056.0 < 0.02, "{}", p1.utps);
        assert!((p31.utps - 1913.0).abs() / 1913.0 < 0.03, "{}", p31.utps);
        let gain = (p31.stps / watts) / (p1.stps / watts);
        assert!(gain > 25.0 && gain < 35.0, "gain {gain}");
    }

    #[test]
    fn moe_expert_reuse_degrades_utps_gently() {
        // §4.6: for DeepSeekV3, increasing batch only slightly degrades
        // user responsiveness while massively increasing STPS/W.
        let registry = Registry::builtin();
        let app = registry.app("deepseek-v3").unwrap();
        let sys = SystemConfig::new(presets::hbm3(), 128, 1);
        let opts = EvalOptions::default();
        let p1 = evaluate(app.as_ref(), &sys, &DecodePoint { batch: 1, context: 4096 }, &opts)
            .unwrap();
        let p64 = evaluate(app.as_ref(), &sys, &DecodePoint { batch: 64, context: 4096 }, &opts)
            .unwrap();
        // 64x the users for < 35% UTPS loss.
        assert!(p64.utps > 0.65 * p1.utps, "{} vs {}", p64.utps, p1.utps);
        assert!(p64.stps > 40.0 * p1.stps);
    }
}
