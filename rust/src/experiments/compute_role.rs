//! §4.8 "Role of Compute on System Performance" as a table.
//!
//! The section makes three quantitative claims without a figure:
//! (i) at low batch, tensor utilization is <= 1% for both DRAM and SRAM
//! designs; (ii) at the max supported batch a small number of cases are
//! compute bound — e.g. DeepSeekV3 at large batch / small context on all
//! three DRAM designs; (iii) the effect fades as context grows. This
//! experiment materializes the full utilization/boundedness grid so the
//! claims are inspectable (and asserted in tests).

use crate::apps::{Application, DecodePoint, Registry};
use crate::hw::{presets, Chip, SystemConfig};
use crate::model::{evaluate, max_batch_for_system, Boundedness, EvalOptions};
use crate::report::{Report, Table};
use crate::Result;

/// Evaluate one (model, chip, context) cell at B=1 and B=max.
fn cell(
    app: &dyn Application,
    chip: &Chip,
    context: u64,
) -> Option<(f64, f64, Boundedness)> {
    let sys = SystemConfig::new(chip.clone(), 128, 1);
    let opts = EvalOptions::default();
    let p1 = evaluate(app, &sys, &DecodePoint { batch: 1, context }, &opts).ok()?;
    let bmax = max_batch_for_system(app, &sys, context)?;
    let pmax = evaluate(app, &sys, &DecodePoint { batch: bmax, context }, &opts).ok()?;
    Some((p1.tensor_utilization, pmax.tensor_utilization, pmax.lat.bound))
}

/// Chips §4.8 discusses: the three DRAM designs plus SRAM.
pub fn chips() -> Vec<Chip> {
    vec![presets::hbm3(), presets::hbm4(), presets::dram3d(), presets::sram()]
}

/// Regenerate the §4.8 grid.
pub fn run() -> Result<Report> {
    let registry = Registry::builtin();
    let mut report = Report::new(
        "compute-role",
        "Tensor utilization and boundedness (§4.8), TP128 systems",
    );
    let mut t = Table::new(
        "Tensor utilization: B=1 / B=max (bound at max)",
        &["Model", "Chip", "4K", "32K", "128K"],
    );
    for model in ["llama3-70b", "llama3-405b", "deepseek-v3"] {
        let app = registry.app(model).unwrap();
        for chip in chips() {
            let mut row = vec![model.to_string(), chip.name.clone()];
            for ctx in [4096u64, 32768, 131072] {
                row.push(match cell(app.as_ref(), &chip, ctx) {
                    Some((u1, umax, bound)) => format!(
                        "{:.2}% / {:.0}% ({})",
                        u1 * 100.0,
                        umax * 100.0,
                        match bound {
                            Boundedness::Compute => "C",
                            Boundedness::Memory => "M",
                        }
                    ),
                    None => "-".into(),
                });
            }
            t.push_row(row);
        }
    }
    report.tables.push(t);
    report.notes.push(
        "C = compute bound at max batch, M = memory bound. §4.8: low-batch \
         utilization <=1% everywhere; DeepSeek at large batch + small \
         context flips the DRAM designs compute-bound; the effect fades \
         with context."
            .into(),
    );
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::Registry;

    #[test]
    fn low_batch_utilization_is_below_one_percent() {
        // §4.8 claim (i), all models x all four designs at 4K and 128K.
        let registry = Registry::builtin();
        for model in ["llama3-70b", "llama3-405b", "deepseek-v3"] {
            let app = registry.app(model).unwrap();
            for chip in chips() {
                for ctx in [4096u64, 131072] {
                    if let Some((u1, _, _)) = cell(app.as_ref(), &chip, ctx) {
                        // DeepSeek at B=1 charges all 256 experts (the
                        // paper's avg-token floor), so its utilization
                        // creeps to ~2.4% on 3D-DRAM at long context;
                        // dense models stay under 1% everywhere.
                        let bound = if model == "deepseek-v3" { 0.025 } else { 0.01 };
                        assert!(
                            u1 <= bound,
                            "{model} on {} @{ctx}: B=1 util {u1}",
                            chip.name
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn deepseek_large_batch_small_context_is_compute_bound_on_dram() {
        // §4.8 claim (ii): all three DRAM designs.
        let registry = Registry::builtin();
        let app = registry.app("deepseek-v3").unwrap();
        for chip in [presets::hbm3(), presets::hbm4(), presets::dram3d()] {
            let (_, _, bound) = cell(app.as_ref(), &chip, 4096).unwrap();
            assert_eq!(bound, Boundedness::Compute, "{}", chip.name);
        }
    }

    #[test]
    fn compute_boundedness_fades_with_context() {
        // §4.8 claim (iii): "this becomes less pronounced as context
        // grows" — Llama3-405B on HBM4 flips from compute-bound at 4K to
        // memory-bound at 128K. (DeepSeek's MLA cache is so small that
        // it stays compute-bound at max batch even at 128K — its max-
        // batch utilization still *drops* with context, the same trend.)
        let registry = Registry::builtin();
        let app = registry.app("llama3-405b").unwrap();
        let (_, _, b4k) = cell(app.as_ref(), &presets::hbm4(), 4096).unwrap();
        let (_, _, b128k) = cell(app.as_ref(), &presets::hbm4(), 131072).unwrap();
        assert_eq!(b4k, Boundedness::Compute);
        assert_eq!(b128k, Boundedness::Memory);
        let ds = registry.app("deepseek-v3").unwrap();
        let (_, u4k, _) = cell(ds.as_ref(), &presets::hbm3(), 4096).unwrap();
        let (_, u128k, _) = cell(ds.as_ref(), &presets::hbm3(), 131072).unwrap();
        assert!(u128k <= u4k + 0.02, "{u4k} -> {u128k}");
    }
}
