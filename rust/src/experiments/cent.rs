//! CENT PIM comparator records (paper Appendix C).

use crate::apps::{Application, DecodePoint};
use crate::hw::{presets, SystemConfig};
use crate::model::{evaluate, EvalOptions};
use crate::sweep::Record;

/// CENT-TP: weights tensor-parallel across all 32 devices, but the
/// attention mechanism (KV traffic) pinned to a single device — the
/// mapping restriction that "considerably reduces the effective
/// bandwidth that the attention mechanism can achieve" (Appendix C).
pub fn cent_tp_record(app: &dyn Application, context: u64) -> Record {
    let chip = presets::cent_device();
    let single_dev_bw = chip.mem_bw;
    let mut sys = SystemConfig::new(chip, presets::CENT_DEVICES, 1);
    sys.kv_bw_override = Some(single_dev_bw);
    let pt = DecodePoint { batch: 1, context };
    match evaluate(app, &sys, &pt, &EvalOptions::default()) {
        Ok(perf) => {
            let watts = presets::cent_system_watts_for(&sys);
            let mut r = Record::from_perf(app.name(), &sys, &perf, watts);
            r.system = "CENT-TP".into();
            r
        }
        Err(_) => Record::unservable(app.name(), "CENT-TP", sys.tp, sys.pp, context),
    }
}

/// CENT-PP: pipeline across all 32 devices, one microbatch per stage
/// (the per-device PIM buffering limits each stage to a single
/// sequence, which is why CENT-PP's UTPS is so low while its STPS is
/// PP-fold higher).
pub fn cent_pp_record(app: &dyn Application, context: u64) -> Record {
    let chip = presets::cent_device();
    let sys = SystemConfig::new(chip, 1, presets::CENT_DEVICES);
    let pt = DecodePoint { batch: 1, context };
    match evaluate(app, &sys, &pt, &EvalOptions::default()) {
        Ok(perf) => {
            let watts = presets::cent_system_watts_for(&sys);
            let mut r = Record::from_perf(app.name(), &sys, &perf, watts);
            r.system = "CENT-PP".into();
            r
        }
        Err(_) => Record::unservable(app.name(), "CENT-PP", sys.tp, sys.pp, context),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::Registry;

    #[test]
    fn cent_tp_decays_sharply_with_context() {
        // Appendix C / Table 5: Llama3-70B CENT-TP: ~289 @ 4K falling to
        // ~38 @ 128K — an order of magnitude, because KV streams through
        // one device. We reproduce the shape (>5x decay).
        let registry = Registry::builtin();
        let app = registry.app("llama3-70b").unwrap();
        let r4 = cent_tp_record(app.as_ref(), 4096);
        let r128 = cent_tp_record(app.as_ref(), 131072);
        let (u4, u128) = (r4.utps.unwrap(), r128.utps.unwrap());
        assert!(u4 > 200.0 && u4 < 400.0, "4K utps {u4}");
        assert!(u128 < 60.0, "128K utps {u128}");
        assert!(u4 / u128 > 5.0);
    }

    #[test]
    fn cent_pp_has_low_utps_but_32x_stps() {
        let registry = Registry::builtin();
        let app = registry.app("llama3-70b").unwrap();
        let r = cent_pp_record(app.as_ref(), 4096);
        let utps = r.utps.unwrap();
        // Paper: 12 UTPS, 371 STPS. Shape: UTPS ~= 10-20, STPS = 32x.
        assert!(utps > 8.0 && utps < 25.0, "utps {utps}");
        assert!((r.stps.unwrap() / utps - 32.0).abs() < 0.5);
    }

    #[test]
    fn cent_cannot_serve_deepseek() {
        let registry = Registry::builtin();
        let app = registry.app("deepseek-v3").unwrap();
        assert!(!cent_tp_record(app.as_ref(), 4096).servable());
        assert!(!cent_pp_record(app.as_ref(), 4096).servable());
    }

    #[test]
    fn cent_405b_tp_serves_at_low_rate() {
        // Table 5: CENT-TP 405B ~55 @ 4K down to ~11 @ 128K.
        let registry = Registry::builtin();
        let app = registry.app("llama3-405b").unwrap();
        let u4 = cent_tp_record(app.as_ref(), 4096).utps.unwrap();
        let u128 = cent_tp_record(app.as_ref(), 131072).utps.unwrap();
        assert!(u4 > 30.0 && u4 < 90.0, "got {u4}");
        assert!(u128 < 30.0 && u4 / u128 > 2.5, "got {u128}");
    }
}
