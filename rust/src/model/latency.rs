//! Latency + throughput evaluation — the heart of LIMINAL.

use crate::apps::{Application, DecodePoint, Workload};
use crate::hw::SystemConfig;
use crate::moe::imbalance_factor;

/// Options controlling secondary terms of the model.
#[derive(Debug, Clone, PartialEq)]
pub struct EvalOptions {
    /// Model MoE router imbalance as exposed tail latency (paper A.2).
    pub moe_imbalance: bool,
    /// Per-MoE-layer routing/dispatch latency, seconds (paper: 800 ns).
    pub moe_routing_latency: f64,
    /// Additional exposed latency per token for software overhead
    /// (kernel launches, drivers, runtime). The paper's limit study sets
    /// this to zero; the Appendix E validation shows real systems pay a
    /// large multiple of it — our serving simulator measures it.
    pub software_overhead: f64,
    /// Enforce that the system's memory capacity can hold weights + KV.
    pub enforce_capacity: bool,
}

impl Default for EvalOptions {
    fn default() -> Self {
        EvalOptions {
            moe_imbalance: true,
            moe_routing_latency: 800e-9,
            software_overhead: 0.0,
            enforce_capacity: true,
        }
    }
}

/// Which fundamental resource bounds `max(T_compute, T_mem)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Boundedness {
    /// `T_mem >= T_compute`: the step streams bytes faster than it math-s.
    Memory,
    /// `T_compute > T_mem`: the tensor/scalar engines are the bottleneck.
    Compute,
}

/// Fully itemized per-token latency, seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyBreakdown {
    /// Tensor-engine time.
    pub t_tensor: f64,
    /// Scalar-engine time (softmax, norms).
    pub t_scalar: f64,
    /// `t_tensor + t_scalar`.
    pub t_compute: f64,
    /// Weight-streaming time.
    pub t_mem_weights: f64,
    /// KV-cache read+write streaming time.
    pub t_mem_kv: f64,
    /// `t_mem_weights + t_mem_kv`.
    pub t_mem: f64,
    /// Tensor-parallel collective exposure: `tp_sync * 3 * L`.
    pub t_tp_sync: f64,
    /// Pipeline forwarding exposure: `pp_sync * PP`.
    pub t_pp_sync: f64,
    /// Per-layer MoE routing/dispatch exposure.
    pub t_moe_routing: f64,
    /// MoE load-imbalance tail exposure.
    pub t_moe_imbalance: f64,
    /// Configured software overhead (0 in the limit study).
    pub t_software: f64,
    /// Sum of all exposed terms.
    pub t_exposed: f64,
    /// `max(t_compute, t_mem) + t_exposed` — seconds per token.
    pub t_batch: f64,
    /// Which resource wins the max.
    pub bound: Boundedness,
}

/// Evaluation result: latency breakdown plus throughput.
#[derive(Debug, Clone, PartialEq)]
pub struct Perf {
    /// Per-token latency breakdown.
    pub lat: LatencyBreakdown,
    /// Per-user tokens/second (`1 / t_batch`).
    pub utps: f64,
    /// System tokens/second across all users (`PP * B / t_batch`).
    pub stps: f64,
    /// The working point evaluated.
    pub point: DecodePoint,
    /// Batch capacity actually required, bytes.
    pub capacity_bytes: f64,
    /// Fraction of peak tensor compute utilized (`t_tensor / t_batch`).
    pub tensor_utilization: f64,
}

/// Evaluate one working point of `app` on `sys`.
///
/// Fails if the system's aggregate memory cannot hold the weights plus
/// the batch's KV cache (and `opts.enforce_capacity` is set).
pub fn evaluate(
    app: &dyn Application,
    sys: &SystemConfig,
    pt: &DecodePoint,
    opts: &EvalOptions,
) -> Result<Perf, super::CapacityError> {
    let needed = app.capacity_bytes(pt);
    if opts.enforce_capacity && needed > sys.total_capacity() {
        return Err(super::CapacityError {
            required_bytes: needed,
            available_bytes: sys.total_capacity(),
            system: sys.label(),
            point: *pt,
        });
    }
    let wl = app.workload(pt);
    Ok(evaluate_workload(&wl, sys, pt, opts, needed))
}

/// Evaluate a pre-computed workload (lets sweeps reuse op counts).
pub fn evaluate_workload(
    wl: &Workload,
    sys: &SystemConfig,
    pt: &DecodePoint,
    opts: &EvalOptions,
    capacity_bytes: f64,
) -> Perf {
    // --- Compute latency -------------------------------------------------
    let t_tensor = wl.ops.tensor / sys.stage_tensor_flops();
    let t_scalar = wl.ops.scalar / sys.stage_scalar_flops();
    let t_compute = t_tensor + t_scalar;

    // --- Memory latency ---------------------------------------------------
    let t_mem_weights = wl.traffic.weight_rd_bytes / sys.stage_mem_bw();
    let t_mem_kv = (wl.traffic.kv_rd_bytes + wl.traffic.kv_wr_bytes) / sys.kv_mem_bw();
    let t_mem = t_mem_weights + t_mem_kv;

    // --- Exposed latency --------------------------------------------------
    // TP collectives only exist when the stage actually spans >1 chip.
    let t_tp_sync = if sys.tp > 1 {
        sys.tp_sync() * wl.sync_ops_per_layer * wl.num_layers as f64
    } else {
        0.0
    };
    let t_pp_sync = sys.pp_sync() * sys.pp as f64;

    let (t_moe_routing, t_moe_imbalance) = match (&wl.moe, wl.num_moe_layers) {
        (Some(moe), n) if n > 0 => {
            let routing = opts.moe_routing_latency * n as f64;
            let imbalance = if opts.moe_imbalance {
                let mi = imbalance_factor(
                    moe.routed_experts as u32,
                    moe.activated_experts as u32,
                    moe.batch,
                );
                // exposed = (max-loaded - average) expert compute, per MoE
                // layer (paper A.2, "Modeling MoE Imbalance").
                let avg_layer_flops = moe.routed_experts as f64
                    * moe.avg_tok_per_routed_expert
                    * moe.per_token_flops;
                (mi - 1.0) * avg_layer_flops * n as f64 / sys.stage_tensor_flops()
            } else {
                0.0
            };
            (routing, imbalance)
        }
        _ => (0.0, 0.0),
    };

    let t_exposed =
        t_tp_sync + t_pp_sync + t_moe_routing + t_moe_imbalance + opts.software_overhead;

    let (t_roof, bound) = if t_compute > t_mem {
        (t_compute, Boundedness::Compute)
    } else {
        (t_mem, Boundedness::Memory)
    };
    let t_batch = t_roof + t_exposed;

    let lat = LatencyBreakdown {
        t_tensor,
        t_scalar,
        t_compute,
        t_mem_weights,
        t_mem_kv,
        t_mem,
        t_tp_sync,
        t_pp_sync,
        t_moe_routing,
        t_moe_imbalance,
        t_software: opts.software_overhead,
        t_exposed,
        t_batch,
        bound,
    };
    Perf {
        lat,
        utps: 1.0 / t_batch,
        stps: sys.pp as f64 * pt.batch as f64 / t_batch,
        point: *pt,
        capacity_bytes,
        tensor_utilization: t_tensor / t_batch,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{DeepSeekV3, Llama3};
    use crate::hw::{presets, SystemConfig};

    fn eval(
        app: &dyn Application,
        chip: crate::hw::Chip,
        tp: u64,
        batch: u64,
        context: u64,
    ) -> Perf {
        let sys = SystemConfig::new(chip, tp, 1);
        evaluate(
            app,
            &sys,
            &DecodePoint { batch, context },
            &EvalOptions::default(),
        )
        .unwrap()
    }

    /// Table 2 max-UTPS entries (batch = 1), all 18 cells.
    #[test]
    fn table2_max_utps_reproduces() {
        let cases: &[(&str, u64, u64, f64)] = &[
            // (model, tp, context, paper UTPS)
            ("70b", 8, 4096, 486.0),
            ("70b", 8, 131072, 378.0),
            ("70b", 32, 4096, 1200.0),
            ("70b", 32, 131072, 990.0),
            ("70b", 128, 4096, 2100.0),
            ("70b", 128, 131072, 1900.0),
            ("405b", 8, 4096, 86.0),
            ("405b", 8, 131072, 80.0),
            ("405b", 32, 4096, 290.0),
            ("405b", 32, 131072, 271.0),
            ("405b", 128, 4096, 776.0),
            ("405b", 128, 131072, 743.0),
            ("dsv3", 8, 4096, 52.0),
            ("dsv3", 8, 131072, 52.0),
            ("dsv3", 32, 4096, 196.0),
            ("dsv3", 32, 131072, 195.0),
            ("dsv3", 128, 4096, 661.0),
            ("dsv3", 128, 131072, 657.0),
        ];
        let l70 = Llama3::llama3_70b();
        let l405 = Llama3::llama3_405b();
        let ds = DeepSeekV3::v3();
        for &(m, tp, ctx, want) in cases {
            let app: &dyn Application = match m {
                "70b" => &l70,
                "405b" => &l405,
                _ => &ds,
            };
            let got = eval(app, presets::hbm3(), tp, 1, ctx).utps;
            // Paper rounds >=1K values to 2-3 significant digits.
            let tol = if want >= 1000.0 { 0.05 } else { 0.02 };
            assert!(
                (got - want).abs() / want < tol,
                "{m} TP{tp} T={ctx}: got {got:.1}, paper {want}"
            );
        }
    }

    #[test]
    fn low_batch_decode_is_memory_bound() {
        // §4.8: at low batch, tensor utilization <= 1% for DRAM designs.
        let p = eval(&Llama3::llama3_405b(), presets::hbm3(), 128, 1, 131072);
        assert_eq!(p.lat.bound, Boundedness::Memory);
        assert!(p.tensor_utilization <= 0.01, "{}", p.tensor_utilization);
    }

    #[test]
    fn huge_batch_flips_compute_bound() {
        // §4.3/§4.8: Llama3-405B at TP128/4K with the capacity-max batch
        // becomes compute bound (paper Table 2: STPS 337K @ UTPS 28).
        let sys = SystemConfig::new(presets::hbm3(), 128, 1);
        let app = Llama3::llama3_405b();
        let b = crate::model::max_batch_for_system(&app, &sys, 4096).unwrap();
        let p = evaluate(
            &app,
            &sys,
            &DecodePoint { batch: b, context: 4096 },
            &EvalOptions::default(),
        )
        .unwrap();
        assert_eq!(p.lat.bound, Boundedness::Compute);
        assert!((p.utps - 28.0).abs() < 1.5, "utps {}", p.utps);
        assert!((p.stps - 337e3).abs() / 337e3 < 0.05, "stps {}", p.stps);
    }

    #[test]
    fn capacity_violation_is_an_error() {
        let sys = SystemConfig::new(presets::sram(), 8, 1); // 4 GiB total
        let r = evaluate(
            &Llama3::llama3_70b(),
            &sys,
            &DecodePoint { batch: 1, context: 4096 },
            &EvalOptions::default(),
        );
        assert!(r.is_err());
    }

    #[test]
    fn tp1_pays_no_collective_latency() {
        let sys = SystemConfig::new(presets::hbm3(), 1, 1);
        let p = evaluate(
            &Llama3::llama3_70b(),
            &sys,
            &DecodePoint { batch: 1, context: 4096 },
            &EvalOptions::default(),
        )
        .unwrap();
        assert_eq!(p.lat.t_tp_sync, 0.0);
    }

    #[test]
    fn moe_exposure_present_only_for_moe_models() {
        let p = eval(&Llama3::llama3_70b(), presets::hbm3(), 8, 1, 4096);
        assert_eq!(p.lat.t_moe_routing, 0.0);
        let p = eval(&DeepSeekV3::v3(), presets::hbm3(), 8, 1, 4096);
        assert!((p.lat.t_moe_routing - 58.0 * 800e-9).abs() < 1e-12);
        assert_eq!(p.lat.t_moe_imbalance, 0.0); // B=1: MI == 1
    }

    #[test]
    fn software_overhead_adds_directly_to_exposed() {
        let sys = SystemConfig::new(presets::hbm3(), 8, 1);
        let app = Llama3::llama3_70b();
        let pt = DecodePoint { batch: 1, context: 4096 };
        let base = evaluate(&app, &sys, &pt, &EvalOptions::default()).unwrap();
        let slow = evaluate(
            &app,
            &sys,
            &pt,
            &EvalOptions { software_overhead: 1e-3, ..Default::default() },
        )
        .unwrap();
        assert!((slow.lat.t_batch - base.lat.t_batch - 1e-3).abs() < 1e-9);
    }

    #[test]
    fn stps_scales_with_pp() {
        let app = Llama3::llama3_70b();
        let pt = DecodePoint { batch: 4, context: 4096 };
        let s1 = SystemConfig::new(presets::hbm3(), 8, 1);
        let s4 = SystemConfig::new(presets::hbm3(), 8, 4);
        let p1 = evaluate(&app, &s1, &pt, &EvalOptions::default()).unwrap();
        let p4 = evaluate(&app, &s4, &pt, &EvalOptions::default()).unwrap();
        // Same per-token latency up to the PP hop exposure...
        assert!((p4.lat.t_mem - p1.lat.t_mem).abs() < 1e-12);
        // ...but 4x the system throughput (modulo the tiny hop latency).
        assert!(p4.stps / p1.stps > 3.9);
    }
}
