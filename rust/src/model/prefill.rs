//! Prefill latency model: the compute-bound counterpart of the decode
//! limit study.
//!
//! Decode moves the whole model past a handful of tokens, so it lives
//! on the memory roofline; prefill pushes hundreds of prompt tokens
//! through every matmul at once, re-using each streamed weight `P`
//! times, so it lives on the tensor roofline. Both phases share the
//! same machinery: an [`Application`] renders a
//! [`Workload`](crate::apps::Workload) (ops + traffic + sync needs) and
//! [`evaluate_workload`] prices it as
//! `max(T_compute, T_mem) + T_exposed`.
//!
//! Chunked prefill ([`chunked_prefill`]) splits a prompt into fixed-size
//! chunks, the standard serving-engine trick (vLLM/Sarathi) that bounds
//! how long a prefill can stall co-scheduled decode lanes. Chunking
//! conserves attention FLOPs exactly (see
//! [`causal_attended`](crate::apps::causal_attended)) but re-streams the
//! weights once per chunk — the model makes that trade measurable.

use crate::apps::{Application, DecodePoint, PrefillPoint};
use crate::hw::SystemConfig;

use super::{evaluate_workload, Boundedness, EvalOptions, LatencyBreakdown};

/// Default prefill chunk size in tokens, in the range production
/// serving engines use (512–2048): large enough that chunks are
/// compute-bound on every DRAM preset, small enough to bound the step
/// latency seen by co-scheduled decode lanes.
pub const DEFAULT_PREFILL_CHUNK: u64 = 1024;

/// Evaluation of a single prefill chunk.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PrefillPerf {
    /// Itemized chunk latency (same roofline decomposition as decode).
    pub lat: LatencyBreakdown,
    /// The working point evaluated.
    pub point: PrefillPoint,
    /// Prompt tokens ingested per second during this chunk.
    pub tokens_per_s: f64,
}

/// Evaluate one prefill chunk of `app` on `sys`.
pub fn evaluate_prefill(
    app: &dyn Application,
    sys: &SystemConfig,
    pt: &PrefillPoint,
    opts: &EvalOptions,
) -> PrefillPerf {
    let wl = app.prefill_workload(pt);
    let dp = DecodePoint {
        batch: pt.batch.max(1),
        context: pt.past_tokens + pt.new_tokens,
    };
    let perf = evaluate_workload(&wl, sys, &dp, opts, 0.0);
    let tokens = (pt.batch.max(1) * pt.new_tokens) as f64;
    PrefillPerf {
        lat: perf.lat,
        point: *pt,
        tokens_per_s: tokens / perf.lat.t_batch,
    }
}

/// Aggregate cost of prefilling a full prompt in fixed-size chunks.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PrefillEstimate {
    /// Total prompt tokens ingested per sequence.
    pub prompt_tokens: u64,
    /// Chunk size used.
    pub chunk_tokens: u64,
    /// Number of chunks executed.
    pub chunks: u64,
    /// Chunks whose roofline was the tensor engine (vs memory).
    pub compute_bound_chunks: u64,
    /// End-to-end prefill seconds (lower-bounds TTFT under no load).
    pub total_s: f64,
    /// Aggregate prompt tokens per second.
    pub tokens_per_s: f64,
}

/// Price a chunked prefill of `prompt_tokens` tokens per sequence
/// (`batch` sequences prefilling together) in chunks of `chunk_tokens`.
pub fn chunked_prefill(
    app: &dyn Application,
    sys: &SystemConfig,
    batch: u64,
    prompt_tokens: u64,
    chunk_tokens: u64,
    opts: &EvalOptions,
) -> PrefillEstimate {
    assert!(chunk_tokens >= 1, "prefill chunk must be >= 1 token");
    let mut past = 0u64;
    let mut total_s = 0.0;
    let mut chunks = 0u64;
    let mut compute_bound = 0u64;
    while past < prompt_tokens {
        let take = chunk_tokens.min(prompt_tokens - past);
        let perf = evaluate_prefill(
            app,
            sys,
            &PrefillPoint { batch, new_tokens: take, past_tokens: past },
            opts,
        );
        total_s += perf.lat.t_batch;
        chunks += 1;
        if perf.lat.bound == Boundedness::Compute {
            compute_bound += 1;
        }
        past += take;
    }
    let tokens = (batch.max(1) * prompt_tokens) as f64;
    PrefillEstimate {
        prompt_tokens,
        chunk_tokens,
        chunks,
        compute_bound_chunks: compute_bound,
        total_s,
        tokens_per_s: if total_s > 0.0 { tokens / total_s } else { 0.0 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::Registry;
    use crate::hw::presets;
    use crate::model::evaluate;

    fn hbm3_tp8() -> SystemConfig {
        SystemConfig::new(presets::hbm3(), 8, 1)
    }

    /// Acceptance: prefill chunks are compute-bound (tensor-dominated)
    /// while decode steps stay memory-bound on the HBM3 preset.
    #[test]
    fn prefill_compute_bound_decode_memory_bound_on_hbm3() {
        let reg = Registry::builtin();
        let sys = hbm3_tp8();
        let opts = EvalOptions::default();
        for name in ["llama3-70b", "llama3-405b"] {
            let app = reg.app(name).unwrap();
            let pre = evaluate_prefill(
                app.as_ref(),
                &sys,
                &PrefillPoint {
                    batch: 1,
                    new_tokens: DEFAULT_PREFILL_CHUNK,
                    past_tokens: 0,
                },
                &opts,
            );
            assert_eq!(pre.lat.bound, Boundedness::Compute, "{name} prefill");
            // Tensor engine dominates the chunk.
            assert!(
                pre.lat.t_tensor / pre.lat.t_batch > 0.5,
                "{name}: tensor fraction {}",
                pre.lat.t_tensor / pre.lat.t_batch
            );

            for batch in [1u64, 8, 64] {
                let dec = evaluate(
                    app.as_ref(),
                    &sys,
                    &DecodePoint { batch, context: 4096 },
                    &crate::model::EvalOptions {
                        enforce_capacity: false,
                        ..Default::default()
                    },
                )
                .unwrap();
                assert_eq!(dec.lat.bound, Boundedness::Memory, "{name} decode B{batch}");
            }
        }
    }

    #[test]
    fn tiny_chunks_pay_weight_restreaming() {
        // 32 chunks of 128 tokens re-stream the weights 32x; on HBM3
        // that pushes each chunk memory-bound and costs well over the
        // one-shot prefill.
        let reg = Registry::builtin();
        let app = reg.app("llama3-70b").unwrap();
        let sys = hbm3_tp8();
        let opts = EvalOptions::default();
        let tiny = chunked_prefill(app.as_ref(), &sys, 1, 4096, 128, &opts);
        let whole = chunked_prefill(app.as_ref(), &sys, 1, 4096, 4096, &opts);
        assert_eq!(tiny.chunks, 32);
        assert_eq!(whole.chunks, 1);
        assert!(
            tiny.total_s > 1.5 * whole.total_s,
            "tiny {} vs whole {}",
            tiny.total_s,
            whole.total_s
        );
        assert_eq!(whole.compute_bound_chunks, 1);
    }

    #[test]
    fn prefill_rate_is_far_above_decode_rate() {
        // A single HBM3-TP8 instance prefills Llama3-70B prompts at
        // hundreds of thousands of tokens/s, vs ~486 decode tokens/s.
        let reg = Registry::builtin();
        let app = reg.app("llama3-70b").unwrap();
        let est = chunked_prefill(
            app.as_ref(),
            &hbm3_tp8(),
            1,
            8192,
            DEFAULT_PREFILL_CHUNK,
            &EvalOptions::default(),
        );
        assert!(est.tokens_per_s > 50_000.0, "{}", est.tokens_per_s);
        assert!(est.total_s > 0.0);
    }

    #[test]
    fn deepseek_prefill_evaluates_with_moe_exposure() {
        let reg = Registry::builtin();
        let app = reg.app("deepseek-v3").unwrap();
        let pre = evaluate_prefill(
            app.as_ref(),
            &hbm3_tp8(),
            &PrefillPoint { batch: 1, new_tokens: 1024, past_tokens: 0 },
            &EvalOptions::default(),
        );
        // 58 MoE layers at 800 ns routing each are charged.
        assert!(pre.lat.t_moe_routing > 0.0);
        assert!(pre.tokens_per_s > 0.0);
    }
}
