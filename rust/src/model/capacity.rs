//! Capacity accounting: "Memory capacity is the first challenge" (§1).

use std::fmt;

use crate::apps::{Application, DecodePoint};
use crate::hw::SystemConfig;

/// Error returned when a working point does not fit in a system's memory.
#[derive(Debug, Clone, PartialEq)]
pub struct CapacityError {
    /// Bytes the working point needs (weights + batch KV).
    pub required_bytes: f64,
    /// Bytes the system offers.
    pub available_bytes: f64,
    /// System label, for diagnostics.
    pub system: String,
    /// The offending working point.
    pub point: DecodePoint,
}

impl fmt::Display for CapacityError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}: B={} T={} needs {:.1} GiB but only {:.1} GiB available",
            self.system,
            self.point.batch,
            self.point.context,
            self.required_bytes / crate::GIB,
            self.available_bytes / crate::GIB,
        )
    }
}

impl std::error::Error for CapacityError {}

/// Largest batch size that fits on `sys` at context length `context`
/// (the paper's max-STPS search: "we keep increasing batch-size until the
/// memory capacity limit is reached", §4.3). Returns `None` when even
/// batch 1 does not fit.
pub fn max_batch_for_system(
    app: &dyn Application,
    sys: &SystemConfig,
    context: u64,
) -> Option<u64> {
    let spare = sys.total_capacity() - app.weight_bytes();
    if spare < 0.0 {
        return None;
    }
    let per_user = context as f64 * app.kv_bytes_per_token();
    let b = (spare / per_user).floor() as u64;
    if b == 0 {
        None
    } else {
        Some(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{DeepSeekV3, Llama3};
    use crate::hw::{presets, SystemConfig};

    #[test]
    fn max_batch_matches_table2_derivation() {
        // Llama3-70B on HBM3-TP8 at 4K: the paper's 48K STPS @ 43 UTPS
        // implies B ~= 1116; our closed form gives the same ballpark.
        let sys = SystemConfig::new(presets::hbm3(), 8, 1);
        let b = max_batch_for_system(&Llama3::llama3_70b(), &sys, 4096).unwrap();
        assert!((b as f64 - 1120.0).abs() < 15.0, "got {b}");
    }

    #[test]
    fn max_batch_at_128k_is_35_for_70b_tp8() {
        // Table 2: 70B TP8 128K STPS 1.5K @ 43 UTPS -> B = 35.
        let sys = SystemConfig::new(presets::hbm3(), 8, 1);
        let b = max_batch_for_system(&Llama3::llama3_70b(), &sys, 131072).unwrap();
        assert_eq!(b, 35);
    }

    #[test]
    fn deepseek_does_not_fit_tiny_systems() {
        let sys = SystemConfig::new(presets::hbm3(), 4, 1); // 384 GiB
        assert_eq!(max_batch_for_system(&DeepSeekV3::v3(), &sys, 4096), None);
    }

    #[test]
    fn error_formats_human_readably() {
        let e = CapacityError {
            required_bytes: 700.0 * crate::GIB,
            available_bytes: 384.0 * crate::GIB,
            system: "xPU-HBM3-TP4".into(),
            point: DecodePoint { batch: 1, context: 4096 },
        };
        let s = e.to_string();
        assert!(s.contains("700.0 GiB"));
        assert!(s.contains("384.0 GiB"));
    }
}
