//! The analytical performance model (paper §2.2).
//!
//! Combines an application [`Workload`](crate::apps::Workload) with a
//! [`SystemConfig`](crate::hw::SystemConfig) into per-token latency and
//! user/system throughput:
//!
//! ```text
//! T_compute = tensor_ops / stage_tensor_flops + scalar_ops / stage_scalar_flops
//! T_mem     = (batch KV bytes + model bytes) / stage_mem_bw
//! T_exposed = T_TPSync * sync_ops_per_layer * N_layers + T_PPSync * N_PP
//!           + MoE routing + MoE imbalance            (DeepSeek only)
//! T_batch   = max(T_compute, T_mem) + T_exposed
//! UTPS      = 1 / T_batch          STPS = N_PP * B / T_batch
//! ```
//!
//! The same roofline prices prefill ([`evaluate_prefill`],
//! [`chunked_prefill`]): a prompt chunk re-uses each streamed weight
//! once per new token, so prefill is compute-bound where decode is
//! memory-bound — the asymmetry the serving simulator's TTFT/TPOT
//! split measures.

mod capacity;
mod latency;
mod prefill;

pub use capacity::{max_batch_for_system, CapacityError};
pub use latency::{evaluate, evaluate_workload, Boundedness, EvalOptions, LatencyBreakdown, Perf};
pub use prefill::{
    chunked_prefill, evaluate_prefill, PrefillEstimate, PrefillPerf,
    DEFAULT_PREFILL_CHUNK,
};

/// A decode working point; alias of [`crate::apps::DecodePoint`].
pub type EvalPoint = crate::apps::DecodePoint;

/// A prefill working point; alias of [`crate::apps::PrefillPoint`].
pub type PrefillEvalPoint = crate::apps::PrefillPoint;
