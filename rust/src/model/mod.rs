//! The analytical performance model (paper §2.2).
//!
//! Combines an application [`Workload`](crate::apps::Workload) with a
//! [`SystemConfig`](crate::hw::SystemConfig) into per-token latency and
//! user/system throughput:
//!
//! ```text
//! T_compute = tensor_ops / stage_tensor_flops + scalar_ops / stage_scalar_flops
//! T_mem     = (batch KV bytes + model bytes) / stage_mem_bw
//! T_exposed = T_TPSync * sync_ops_per_layer * N_layers + T_PPSync * N_PP
//!           + MoE routing + MoE imbalance            (DeepSeek only)
//! T_batch   = max(T_compute, T_mem) + T_exposed
//! UTPS      = 1 / T_batch          STPS = N_PP * B / T_batch
//! ```

mod capacity;
mod latency;

pub use capacity::{max_batch_for_system, CapacityError};
pub use latency::{evaluate, evaluate_workload, Boundedness, EvalOptions, LatencyBreakdown, Perf};

/// A decode working point; alias of [`crate::apps::DecodePoint`].
pub type EvalPoint = crate::apps::DecodePoint;
