//! Artifact manifest: what `python/compile/aot.py` produced.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context};

use crate::util::json::Json;
use crate::Result;

/// Shape + dtype of one positional input.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    /// Dimension sizes (row-major).
    pub shape: Vec<usize>,
    /// JAX dtype string (`float32`, `int32`, ...).
    pub dtype: String,
}

impl TensorSpec {
    /// Total element count.
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT entry point.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    /// Entry name (e.g. `decode_b1`).
    pub name: String,
    /// HLO text file path (absolute).
    pub path: PathBuf,
    /// Positional input specs, in HLO parameter order.
    pub inputs: Vec<TensorSpec>,
    /// Entry kind (`decode_step`, `grid_eval`, `gemv`, `gemm`).
    pub kind: String,
    /// Raw manifest record for kind-specific fields (batch, config, ...).
    pub raw: Json,
}

impl ArtifactEntry {
    /// Kind-specific numeric field (e.g. `batch`, `flops`).
    pub fn num(&self, key: &str) -> Option<f64> {
        self.raw.get(key).and_then(Json::as_f64)
    }

    /// Nested decode-step config field.
    pub fn config_num(&self, key: &str) -> Option<f64> {
        self.raw.get("config")?.get(key)?.as_f64()
    }
}

/// The parsed `manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Directory the manifest lives in.
    pub dir: PathBuf,
    /// Entries by name.
    pub entries: BTreeMap<String, ArtifactEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(dir, &text)
    }

    /// Parse manifest JSON given its directory.
    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let root = Json::parse(text).context("manifest is not valid JSON")?;
        let entries_json = root
            .get("entries")
            .ok_or_else(|| anyhow!("manifest missing 'entries'"))?;
        let Json::Obj(map) = entries_json else {
            return Err(anyhow!("manifest 'entries' must be an object"));
        };
        let mut entries = BTreeMap::new();
        for (name, rec) in map {
            let file = rec
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("entry {name}: missing 'file'"))?;
            let inputs = rec
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("entry {name}: missing 'inputs'"))?
                .iter()
                .map(|i| {
                    let shape = i
                        .get("shape")
                        .and_then(Json::as_arr)
                        .ok_or_else(|| anyhow!("entry {name}: bad input shape"))?
                        .iter()
                        .map(|d| d.as_u64().map(|d| d as usize))
                        .collect::<Option<Vec<_>>>()
                        .ok_or_else(|| anyhow!("entry {name}: bad dims"))?;
                    let dtype = i
                        .get("dtype")
                        .and_then(Json::as_str)
                        .unwrap_or("float32")
                        .to_string();
                    Ok(TensorSpec { shape, dtype })
                })
                .collect::<Result<Vec<_>>>()?;
            entries.insert(
                name.clone(),
                ArtifactEntry {
                    name: name.clone(),
                    path: dir.join(file),
                    inputs,
                    kind: rec
                        .get("kind")
                        .and_then(Json::as_str)
                        .unwrap_or("unknown")
                        .to_string(),
                    raw: rec.clone(),
                },
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    /// Look up an entry.
    pub fn entry(&self, name: &str) -> Result<&ArtifactEntry> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow!("no artifact entry named '{name}'"))
    }

    /// The decode entry whose batch bucket is the smallest `>= batch`
    /// (serving engines round up to a compiled bucket).
    pub fn decode_bucket(&self, batch: u64) -> Result<&ArtifactEntry> {
        self.entries
            .values()
            .filter(|e| e.kind == "decode_step")
            .filter(|e| e.num("batch").map_or(false, |b| b as u64 >= batch))
            .min_by_key(|e| e.num("batch").unwrap_or(f64::MAX) as u64)
            .ok_or_else(|| anyhow!("no decode bucket holds batch {batch}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "entries": {
        "decode_b1": {"file": "decode_b1.hlo.txt", "kind": "decode_step",
          "batch": 1,
          "inputs": [{"shape": [1], "dtype": "int32"}],
          "config": {"context": 128}},
        "decode_b4": {"file": "decode_b4.hlo.txt", "kind": "decode_step",
          "batch": 4, "inputs": []},
        "gemv": {"file": "gemv.hlo.txt", "kind": "gemv",
          "bytes": 1024, "inputs": [{"shape": [1, 16], "dtype": "float32"}]}
      }
    }"#;

    #[test]
    fn parses_entries_and_specs() {
        let m = Manifest::parse(Path::new("/tmp/x"), SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 3);
        let d = m.entry("decode_b1").unwrap();
        assert_eq!(d.inputs[0].shape, vec![1]);
        assert_eq!(d.inputs[0].dtype, "int32");
        assert_eq!(d.config_num("context"), Some(128.0));
        assert_eq!(m.entry("gemv").unwrap().num("bytes"), Some(1024.0));
    }

    #[test]
    fn bucket_rounds_up() {
        let m = Manifest::parse(Path::new("/tmp/x"), SAMPLE).unwrap();
        assert_eq!(m.decode_bucket(1).unwrap().name, "decode_b1");
        assert_eq!(m.decode_bucket(2).unwrap().name, "decode_b4");
        assert_eq!(m.decode_bucket(4).unwrap().name, "decode_b4");
        assert!(m.decode_bucket(5).is_err());
    }

    #[test]
    fn missing_entry_is_an_error() {
        let m = Manifest::parse(Path::new("/tmp/x"), SAMPLE).unwrap();
        assert!(m.entry("nope").is_err());
    }
}
