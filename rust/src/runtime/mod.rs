//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them
//! from the Rust request path (Python never runs at serving time).
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` ->
//! `HloModuleProto::from_text_file` -> `client.compile` -> `execute`.
//! Artifacts are described by `artifacts/manifest.json`, written by
//! `python/compile/aot.py`.

mod artifact;
mod client;

pub use artifact::{ArtifactEntry, Manifest, TensorSpec};
pub use client::{Loaded, Runtime};
