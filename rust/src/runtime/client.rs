//! PJRT client wrapper: compile HLO text once, execute many times.

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use anyhow::Context;

use super::artifact::{ArtifactEntry, Manifest};
use crate::Result;

/// A compiled entry point, ready to execute.
pub struct Loaded {
    /// The manifest record this was compiled from.
    pub entry: ArtifactEntry,
    exe: xla::PjRtLoadedExecutable,
}

impl Loaded {
    /// Execute with host literals; returns the flattened output tuple.
    pub fn execute(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let out = self.exe.execute::<xla::Literal>(args)?;
        let lit = out[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Execute with device-resident buffers (no host copies); returns
    /// the raw output buffers so callers can feed them back in — the
    /// serving engine threads KV caches through steps this way.
    pub fn execute_buffers(
        &self,
        args: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::PjRtBuffer>> {
        let mut out = self.exe.execute_b(args)?;
        Ok(out.remove(0))
    }

    /// Execute and return only the wall-clock seconds (used by the
    /// Appendix-E-style validation and perf benches).
    pub fn execute_timed(&self, args: &[xla::Literal]) -> Result<f64> {
        let t0 = Instant::now();
        let out = self.exe.execute::<xla::Literal>(args)?;
        // Force completion by syncing the first output to host.
        let _ = out[0][0].to_literal_sync()?;
        Ok(t0.elapsed().as_secs_f64())
    }
}

/// The PJRT runtime: one CPU client plus a compile cache.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, std::sync::Arc<Loaded>>,
}

impl Runtime {
    /// Create a CPU PJRT client over the artifact directory.
    pub fn new(artifact_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, manifest, cache: HashMap::new() })
    }

    /// Platform string (e.g. `cpu`), for logs.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// The artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Access the underlying client (buffer creation etc.).
    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Load + compile an entry (cached).
    pub fn load(&mut self, name: &str) -> Result<std::sync::Arc<Loaded>> {
        if let Some(l) = self.cache.get(name) {
            return Ok(l.clone());
        }
        let entry = self.manifest.entry(name)?.clone();
        let proto = xla::HloModuleProto::from_text_file(
            entry.path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", entry.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact '{name}'"))?;
        let loaded = std::sync::Arc::new(Loaded { entry, exe });
        self.cache.insert(name.to_string(), loaded.clone());
        Ok(loaded)
    }

    /// Build zero-filled literals matching an entry's input specs
    /// (useful for smoke tests and timing runs where values don't
    /// matter).
    pub fn zero_inputs(&self, name: &str) -> Result<Vec<xla::Literal>> {
        let entry = self.manifest.entry(name)?;
        entry
            .inputs
            .iter()
            .map(|spec| {
                let ty = match spec.dtype.as_str() {
                    "float32" => xla::PrimitiveType::F32,
                    "int32" => xla::PrimitiveType::S32,
                    "int64" => xla::PrimitiveType::S64,
                    "float64" => xla::PrimitiveType::F64,
                    other => anyhow::bail!("unsupported artifact dtype {other}"),
                };
                Ok(xla::Literal::create_from_shape(ty, &spec.shape))
            })
            .collect()
    }

    /// Measure sustained host memory stream bandwidth (bytes/s) with a
    /// large copy — the `mem_bw` of the "CPU chip" LIMINAL uses in the
    /// Appendix-E-style validation.
    pub fn measure_stream_bandwidth() -> f64 {
        const BYTES: usize = 256 << 20; // 256 MiB
        let src = vec![1u8; BYTES];
        let mut dst = vec![0u8; BYTES];
        // Warm up once, then take the best of 3 (peak streaming rate).
        let mut best = f64::MAX;
        for _ in 0..4 {
            let t0 = Instant::now();
            dst.copy_from_slice(&src);
            std::hint::black_box(&dst);
            best = best.min(t0.elapsed().as_secs_f64());
        }
        // A copy moves 2x the buffer (read + write).
        (2 * BYTES) as f64 / best
    }
}
