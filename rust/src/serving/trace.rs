//! Trace-driven workloads: replay recorded request streams instead of
//! the synthetic Poisson/uniform [`WorkloadGen`](super::WorkloadGen).
//!
//! Public serving traces (Azure LLM inference, BurstGPT, …) boil down
//! to one record per request — arrival time plus prompt and generation
//! lengths — which is exactly what the simulator needs and all this
//! reader ingests. Two formats are accepted, auto-detected per file:
//!
//! * **JSONL** — one object per line:
//!   `{"arrival": 0.041, "context_len": 1024, "gen_len": 128}`, with
//!   an optional `"priority"` field (scheduling class 0-255, default
//!   0; see [`Request::priority`]).
//! * **CSV** — `arrival,context_len,gen_len` columns plus an optional
//!   fourth `priority` column, with an optional header line.
//!
//! Records may arrive unsorted; the reader stably sorts by arrival time
//! and assigns request ids in that order, so a trace replays on the
//! simulator's total-order calendar exactly like a generated workload.

use std::path::Path;

use anyhow::Context;

use crate::util::json::Json;
use crate::Result;

use super::request::Request;

/// Reader for recorded request traces.
pub struct WorkloadTrace;

impl WorkloadTrace {
    /// Load a trace file (JSONL or CSV, auto-detected) into simulator
    /// requests, sorted by arrival with ids assigned in arrival order.
    pub fn load(path: &Path) -> Result<Vec<Request>> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading trace {}", path.display()))?;
        Self::parse(&text)
            .with_context(|| format!("parsing trace {}", path.display()))
    }

    /// Parse trace text. The first non-empty line decides the format:
    /// `{`-prefixed means JSONL, anything else CSV.
    pub fn parse(text: &str) -> Result<Vec<Request>> {
        // Tolerate a UTF-8 byte-order mark (Excel-exported CSV, some
        // JSONL writers): without stripping it the sniffer saw
        // `\u{feff}{` instead of `{` and misparsed JSONL as CSV, and a
        // BOM'd CSV header failed the literal `arrival` match.
        let text = text.strip_prefix('\u{feff}').unwrap_or(text);
        let first = text.lines().map(str::trim).find(|l| !l.is_empty());
        let mut records = match first {
            None => anyhow::bail!("trace contains no records"),
            Some(l) if l.starts_with('{') => Self::parse_jsonl(text)?,
            Some(_) => Self::parse_csv(text)?,
        };
        if records.is_empty() {
            anyhow::bail!("trace contains no records");
        }
        // Stable sort: simultaneous arrivals keep file order, so replay
        // is deterministic.
        records.sort_by(|a, b| a.0.total_cmp(&b.0));
        Ok(records
            .into_iter()
            .enumerate()
            .map(|(id, (arrival, context_len, gen_len, priority))| Request {
                id: id as u64,
                arrival,
                context_len,
                gen_len,
                priority,
                generated: 0,
                prefilled: 0,
                scheduled_prefill: 0,
                admitted_at: None,
                first_token_at: None,
                completed_at: None,
            })
            .collect())
    }

    fn check(
        line_no: usize,
        arrival: f64,
        gen_len: u64,
    ) -> Result<()> {
        anyhow::ensure!(
            arrival.is_finite() && arrival >= 0.0,
            "line {line_no}: arrival must be a finite non-negative time, got {arrival}"
        );
        anyhow::ensure!(
            gen_len >= 1,
            "line {line_no}: gen_len must be at least 1"
        );
        Ok(())
    }

    fn check_priority(line_no: usize, priority: f64) -> Result<u8> {
        anyhow::ensure!(
            priority.fract() == 0.0 && (0.0..=255.0).contains(&priority),
            "line {line_no}: priority must be an integer class in 0..=255, got {priority}"
        );
        Ok(priority as u8)
    }

    fn parse_jsonl(text: &str) -> Result<Vec<(f64, u64, u64, u8)>> {
        let mut out = Vec::new();
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let line_no = i + 1;
            let v = Json::parse(line)
                .map_err(|e| anyhow::anyhow!("line {line_no}: {e}"))?;
            let field = |k: &str| -> Result<f64> {
                v.get(k)
                    .and_then(Json::as_f64)
                    .ok_or_else(|| {
                        anyhow::anyhow!("line {line_no}: missing numeric field '{k}'")
                    })
            };
            let arrival = field("arrival")?;
            let ctx = field("context_len")?;
            let gen = field("gen_len")?;
            anyhow::ensure!(
                ctx >= 0.0 && ctx.fract() == 0.0 && gen >= 0.0 && gen.fract() == 0.0,
                "line {line_no}: context_len/gen_len must be non-negative integers"
            );
            Self::check(line_no, arrival, gen as u64)?;
            let priority = match v.get("priority") {
                None => 0,
                Some(p) => {
                    let p = p.as_f64().ok_or_else(|| {
                        anyhow::anyhow!(
                            "line {line_no}: 'priority' must be numeric"
                        )
                    })?;
                    Self::check_priority(line_no, p)?
                }
            };
            out.push((arrival, ctx as u64, gen as u64, priority));
        }
        Ok(out)
    }

    fn parse_csv(text: &str) -> Result<Vec<(f64, u64, u64, u8)>> {
        let mut out = Vec::new();
        let mut seen_line = false;
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let line_no = i + 1;
            let cols: Vec<&str> = line.split(',').map(str::trim).collect();
            // Only the first non-empty line may be a header, and only
            // the documented one — anything else non-numeric there is a
            // corrupt record and must error, not silently drop.
            if !seen_line && cols[0].eq_ignore_ascii_case("arrival") {
                seen_line = true;
                continue;
            }
            seen_line = true;
            anyhow::ensure!(
                cols.len() == 3 || cols.len() == 4,
                "line {line_no}: expected 3 or 4 columns \
                 (arrival,context_len,gen_len[,priority]), got {}",
                cols.len()
            );
            let arrival: f64 = cols[0]
                .parse()
                .with_context(|| format!("line {line_no}: bad arrival '{}'", cols[0]))?;
            let ctx: u64 = cols[1]
                .parse()
                .with_context(|| format!("line {line_no}: bad context_len '{}'", cols[1]))?;
            let gen: u64 = cols[2]
                .parse()
                .with_context(|| format!("line {line_no}: bad gen_len '{}'", cols[2]))?;
            Self::check(line_no, arrival, gen)?;
            let priority = if cols.len() == 4 {
                let p: f64 = cols[3].parse().with_context(|| {
                    format!("line {line_no}: bad priority '{}'", cols[3])
                })?;
                Self::check_priority(line_no, p)?
            } else {
                0
            };
            out.push((arrival, ctx, gen, priority));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The checked-in 20-request sample trace (also exercised by the
    /// `--trace` CLI path).
    const SAMPLE: &str = include_str!(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/data/sample_trace.jsonl"
    ));

    #[test]
    fn sample_jsonl_trace_parses() {
        let reqs = WorkloadTrace::parse(SAMPLE).unwrap();
        assert_eq!(reqs.len(), 20);
        // Sorted by arrival, ids in arrival order.
        for (i, w) in reqs.windows(2).enumerate() {
            assert!(w[0].arrival <= w[1].arrival, "record {i} out of order");
        }
        assert_eq!(reqs[0].id, 0);
        assert_eq!(reqs[0].context_len, 512);
        assert_eq!(reqs[0].gen_len, 64);
        let last = reqs.last().unwrap();
        assert_eq!(last.id, 19);
        assert!((last.arrival - 1.366).abs() < 1e-12);
        assert_eq!(last.context_len, 1152);
        // Lifecycle fields start zeroed.
        assert!(reqs.iter().all(|r| r.generated == 0 && r.prefilled == 0));
    }

    #[test]
    fn csv_with_header_parses_and_sorts() {
        let text = "arrival,context_len,gen_len\n\
                    0.5, 2048, 128\n\
                    0.1, 512, 32\n\
                    0.3, 1024, 64\n";
        let reqs = WorkloadTrace::parse(text).unwrap();
        assert_eq!(reqs.len(), 3);
        // Unsorted input is sorted; ids follow arrival order.
        assert_eq!(reqs[0].context_len, 512);
        assert_eq!(reqs[1].context_len, 1024);
        assert_eq!(reqs[2].context_len, 2048);
        assert_eq!(
            reqs.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![0, 1, 2]
        );
    }

    #[test]
    fn csv_without_header_parses() {
        let reqs = WorkloadTrace::parse("0.0,100,10\n1.0,200,20\n").unwrap();
        assert_eq!(reqs.len(), 2);
        assert_eq!(reqs[1].gen_len, 20);
    }

    #[test]
    fn malformed_lines_report_their_line_number() {
        let err = WorkloadTrace::parse("0.0,100,10\n0.1,oops,10\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("line 2"), "{err}");

        let err = WorkloadTrace::parse("{\"arrival\": 0.0}\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("context_len"), "{err}");
    }

    #[test]
    fn invalid_values_are_rejected() {
        assert!(WorkloadTrace::parse("-1.0,100,10\n").is_err(), "negative arrival");
        assert!(WorkloadTrace::parse("0.0,100,0\n").is_err(), "zero gen_len");
        assert!(WorkloadTrace::parse("").is_err(), "empty trace");
        assert!(WorkloadTrace::parse("arrival,context_len,gen_len\n").is_err());
    }

    #[test]
    fn duplicate_timestamps_keep_file_order() {
        // Simultaneous arrivals must replay in file order (stable sort),
        // so a trace with tied timestamps is still deterministic.
        let text = "0.5,111,10\n0.5,222,10\n0.0,333,10\n0.5,444,10\n";
        let reqs = WorkloadTrace::parse(text).unwrap();
        assert_eq!(
            reqs.iter().map(|r| r.context_len).collect::<Vec<_>>(),
            vec![333, 111, 222, 444]
        );
        assert_eq!(
            reqs.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![0, 1, 2, 3]
        );
    }

    #[test]
    fn trailing_newlines_and_crlf_parse() {
        // CRLF line endings and trailing blank lines (the usual state
        // of an exported CSV) must not add phantom records or errors.
        let text = "arrival,context_len,gen_len\r\n0.1,512,32\r\n0.2,1024,64\r\n\r\n\n";
        let reqs = WorkloadTrace::parse(text).unwrap();
        assert_eq!(reqs.len(), 2);
        assert_eq!(reqs[1].context_len, 1024);
        let jsonl = "{\"arrival\": 0.0, \"context_len\": 8, \"gen_len\": 2}\n\n";
        assert_eq!(WorkloadTrace::parse(jsonl).unwrap().len(), 1);
    }

    #[test]
    fn utf8_bom_is_stripped_before_sniffing() {
        // Regression (DST trace fuzzing): a BOM'd JSONL trace was
        // sniffed as CSV (first char != '{') and a BOM'd CSV header
        // failed the literal `arrival` match — both erred on line 1.
        let jsonl =
            "\u{feff}{\"arrival\": 0.0, \"context_len\": 8, \"gen_len\": 2}\n";
        let reqs = WorkloadTrace::parse(jsonl).unwrap();
        assert_eq!(reqs.len(), 1);
        assert_eq!(reqs[0].context_len, 8);
        let csv = "\u{feff}arrival,context_len,gen_len\n0.1,512,32\n";
        let reqs = WorkloadTrace::parse(csv).unwrap();
        assert_eq!(reqs.len(), 1);
        assert_eq!(reqs[0].gen_len, 32);
    }

    #[test]
    fn zero_token_rows_error_with_their_line_number() {
        let err = WorkloadTrace::parse("0.0,100,10\n0.1,100,0\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("line 2"), "{err}");
        assert!(err.contains("gen_len"), "{err}");
        // Zero-length *prompts* are legal (decode-only requests).
        let reqs = WorkloadTrace::parse("0.0,0,10\n").unwrap();
        assert_eq!(reqs[0].context_len, 0);
    }

    #[test]
    fn priority_column_parses_and_defaults_to_zero() {
        // CSV fourth column.
        let reqs =
            WorkloadTrace::parse("0.0,100,10,2\n0.1,200,20\n").unwrap();
        assert_eq!(reqs[0].priority, 2);
        assert_eq!(reqs[1].priority, 0, "3-column rows default to class 0");
        // JSONL optional field.
        let jsonl = "{\"arrival\": 0.0, \"context_len\": 8, \"gen_len\": 2, \
                     \"priority\": 3}\n\
                     {\"arrival\": 0.1, \"context_len\": 8, \"gen_len\": 2}\n";
        let reqs = WorkloadTrace::parse(jsonl).unwrap();
        assert_eq!(reqs[0].priority, 3);
        assert_eq!(reqs[1].priority, 0);
    }

    #[test]
    fn invalid_priorities_error_with_their_line_number() {
        let err = WorkloadTrace::parse("0.0,100,10,1\n0.1,100,10,300\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("line 2"), "{err}");
        assert!(err.contains("priority"), "{err}");
        assert!(
            WorkloadTrace::parse("0.0,100,10,1.5\n").is_err(),
            "fractional priority"
        );
        assert!(
            WorkloadTrace::parse(
                "{\"arrival\": 0.0, \"context_len\": 8, \"gen_len\": 2, \
                 \"priority\": -1}\n"
            )
            .is_err(),
            "negative priority"
        );
    }

    #[test]
    fn corrupt_first_record_is_an_error_not_a_header() {
        // Only the literal documented header may be skipped: a mangled
        // first data row (`O.5` with a letter O) must fail loudly, not
        // silently shrink the workload.
        let err = WorkloadTrace::parse("O.5,2048,128\n0.1,512,32\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("line 1"), "{err}");
    }
}
