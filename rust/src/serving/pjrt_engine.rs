//! PJRT-backed step engine: executes the AOT-compiled decode step and
//! reports *measured* wall-clock per step.
//!
//! This is the repository's "real silicon" analog for Appendix E: where
//! LIMINAL idealizes software away, this path pays every cost — PJRT
//! dispatch, host-device literal copies, tuple re-materialization — and
//! the gap between its tokens/sec and LIMINAL's prediction is exactly
//! the paper's reported validation gap, reproduced in `experiments::
//! validation`.

use std::sync::Arc;

use anyhow::Context;

use crate::runtime::{Loaded, Runtime, TensorSpec};
use crate::Result;

use super::engine::StepEngine;

/// Executable decode engine over one compiled batch bucket.
pub struct PjrtEngine {
    loaded: Arc<Loaded>,
    /// Flattened inputs: params (constant), token ids, caches, pos.
    inputs: Vec<xla::Literal>,
    token_idx: usize,
    kc_idx: usize,
    vc_idx: usize,
    pos_idx: usize,
    /// Compiled batch bucket size.
    pub batch: u64,
    /// Cache context length T.
    pub context: u64,
    /// Vocabulary size (for greedy sampling).
    pub vocab: u64,
    /// Current cache fill position.
    pub pos: u64,
    steps_executed: u64,
}

impl PjrtEngine {
    /// Load the decode bucket that can hold `batch` sequences.
    pub fn new(rt: &mut Runtime, batch: u64) -> Result<PjrtEngine> {
        let name = rt.manifest().decode_bucket(batch)?.name.clone();
        let loaded = rt.load(&name)?;
        let entry = &loaded.entry;
        let b = entry.num("batch").context("decode entry missing batch")? as u64;
        let context = entry.config_num("context").context("missing context")? as u64;
        let vocab = entry.config_num("vocab").context("missing vocab")? as u64;

        // Identify the positional role of each input by shape/dtype:
        // token ids = int32 [B]; pos = int32 []; caches = the two
        // 5-D float32 arrays; everything else is a parameter.
        let find = |pred: &dyn Fn(&TensorSpec) -> bool| -> Vec<usize> {
            entry
                .inputs
                .iter()
                .enumerate()
                .filter(|(_, s)| pred(s))
                .map(|(i, _)| i)
                .collect()
        };
        let token_idx = *find(&|s| s.dtype == "int32" && s.shape.len() == 1)
            .first()
            .context("no token input")?;
        let pos_idx = *find(&|s| s.dtype == "int32" && s.shape.is_empty())
            .first()
            .context("no pos input")?;
        let caches = find(&|s| s.dtype == "float32" && s.shape.len() == 5);
        anyhow::ensure!(caches.len() == 2, "expected 2 KV cache inputs");
        let (kc_idx, vc_idx) = (caches[0], caches[1]);

        let inputs = rt.zero_inputs(&name)?;
        Ok(PjrtEngine {
            loaded,
            inputs,
            token_idx,
            kc_idx,
            vc_idx,
            pos_idx,
            batch: b,
            context,
            vocab,
            pos: 0,
            steps_executed: 0,
        })
    }

    /// Randomize the parameters (zero weights make degenerate logits).
    /// Deterministic given `seed`; cheap enough to run once at startup.
    pub fn randomize_params(&mut self, seed: u64) -> Result<()> {
        let mut rng = crate::util::rng::Pcg32::seed_from(seed);
        for (i, lit) in self.inputs.iter_mut().enumerate() {
            if i == self.token_idx || i == self.pos_idx || i == self.kc_idx || i == self.vc_idx {
                continue;
            }
            let n = lit.element_count();
            let scale = 1.0 / (n as f64).sqrt().max(1.0) as f32;
            let data: Vec<f32> = (0..n)
                .map(|_| (rng.f64() as f32 - 0.5) * 4.0 * scale)
                .collect();
            lit.copy_raw_from(&data)?;
        }
        Ok(())
    }

    /// Reset the KV cache and position (new conversation batch).
    pub fn reset(&mut self) -> Result<()> {
        for idx in [self.kc_idx, self.vc_idx] {
            let n = self.inputs[idx].element_count();
            self.inputs[idx].copy_raw_from(&vec![0f32; n])?;
        }
        self.pos = 0;
        Ok(())
    }

    /// Execute one decode step with the given current tokens (length ==
    /// `batch`); returns `(next_tokens, wall_seconds)`. Greedy argmax
    /// sampling on the host, caches threaded to the next step.
    pub fn step(&mut self, tokens: &[i32]) -> Result<(Vec<i32>, f64)> {
        anyhow::ensure!(tokens.len() as u64 == self.batch, "token count != batch");
        anyhow::ensure!(self.pos < self.context, "KV cache full");
        self.inputs[self.token_idx].copy_raw_from(tokens)?;
        self.inputs[self.pos_idx].copy_raw_from(&[self.pos as i32])?;

        let t0 = std::time::Instant::now();
        let mut out = self.loaded.execute(&self.inputs)?;
        let dt = t0.elapsed().as_secs_f64();
        anyhow::ensure!(out.len() == 3, "decode step must return 3 outputs");

        // Thread caches back (out[1] = k, out[2] = v).
        let vc = out.pop().unwrap();
        let kc = out.pop().unwrap();
        let logits = out.pop().unwrap();
        self.inputs[self.kc_idx] = kc;
        self.inputs[self.vc_idx] = vc;
        self.pos += 1;
        self.steps_executed += 1;

        // Greedy argmax per sequence.
        let flat: Vec<f32> = logits.to_vec()?;
        let v = self.vocab as usize;
        let next = (0..self.batch as usize)
            .map(|b| {
                let row = &flat[b * v..(b + 1) * v];
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i as i32)
                    .unwrap_or(0)
            })
            .collect();
        Ok((next, dt))
    }

    /// Steps executed since creation.
    pub fn steps_executed(&self) -> u64 {
        self.steps_executed
    }
}

impl StepEngine for PjrtEngine {
    fn step_latency(&mut self, batch: u64, _max_context: u64) -> f64 {
        if batch == 0 {
            return 0.0;
        }
        if self.pos >= self.context {
            // Rolling window: restart the cache (simulator semantics).
            let _ = self.reset();
        }
        let tokens = vec![1i32; self.batch as usize];
        match self.step(&tokens) {
            Ok((_, dt)) => dt,
            Err(_) => f64::INFINITY,
        }
    }

    fn name(&self) -> String {
        format!("pjrt(decode_b{} T={})", self.batch, self.context)
    }
}
