//! The single-instance serving simulator: a DES loop over arrivals and
//! engine steps (mixed prefill + decode), driving one [`Instance`].
//!
//! The event loop owns a [`des::EventQueue`](crate::des::EventQueue) of
//! [`InstanceEvent`]s keyed by instance id (always 0 here) plus the
//! [`RequestArena`] holding all request state; events and batcher
//! queues carry dense [`ReqId`](super::ReqId)s only. All per-step
//! mechanics — admission, planning, pricing, completion, occupancy
//! accounting — live in [`Instance`], the same state machine
//! [`crate::cluster::ClusterSim`] multiplexes N of on one calendar.
//!
//! Step semantics (fidelity rules the regression tests pin down):
//!
//! * **Admission only at step boundaries.** A request arriving while a
//!   step is in flight is enqueued and waits for the next `StepDone`;
//!   it can never join a step it was not priced into (which would mint
//!   free tokens and under-count its latency).
//! * **Steps are planned, then priced, then completed.** At each
//!   boundary the batcher plans a [`StepBatch`](super::StepBatch)
//!   (decode lanes + a prefill chunk), the engine prices it, and the
//!   completion event applies exactly that plan.
//! * **Occupancy statistics are duration-weighted.** `mean_batch`
//!   integrates lanes over busy time, so engines with batch-dependent
//!   step latency (the analytic backend) don't bias the mean.
//! * **Limits are exact.** `max_steps = N` prices exactly N steps, and
//!   `max_time = T` clamps at the boundary: an event scheduled past `T`
//!   is never applied (the step it would have completed is not counted
//!   in `steps` or `finished`) and the reported span ends at `T`.

use crate::des::EventQueue;

use super::arena::RequestArena;
use super::batcher::{Batcher, SchedAction};
use super::engine::StepEngine;
use super::instance::{Instance, InstanceEvent};
use super::metrics::ServingReport;
use super::observe::{NoopObserver, SimObserver};
use super::request::Request;

/// Simulation parameters.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Hard stop on simulated seconds (safety valve; `f64::INFINITY` to
    /// run to drain). Enforced at the boundary: events past the deadline
    /// never apply and the reported span is clamped to it.
    pub max_time: f64,
    /// Hard stop on steps (enforced exactly).
    pub max_steps: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig { max_time: f64::INFINITY, max_steps: 10_000_000 }
    }
}

/// The serving simulator: continuous batching over a step engine.
pub struct ServingSim<'a> {
    batcher: Batcher,
    engine: &'a mut dyn StepEngine,
    cfg: SimConfig,
}

impl<'a> ServingSim<'a> {
    /// Build a simulator.
    pub fn new(batcher: Batcher, engine: &'a mut dyn StepEngine, cfg: SimConfig) -> Self {
        ServingSim { batcher, engine, cfg }
    }

    /// Run the given workload to completion (or a configured limit) and
    /// report. The engine is stepped whenever requests are active; a new
    /// step is scheduled at `now + mixed_step_latency(plan)`.
    ///
    /// Internally the workload is moved into a [`RequestArena`] once and
    /// dense ids flow through the calendar and the instance, so the
    /// event loop allocates nothing in steady state.
    pub fn run(self, workload: Vec<Request>) -> ServingReport {
        // The no-op observer monomorphizes every hook away, so this is
        // exactly the pre-observer event loop.
        self.run_with(workload, &mut NoopObserver)
    }

    /// [`ServingSim::run`] with a [`SimObserver`] watching every applied
    /// event and retirement — the deterministic simulation-testing
    /// harness ([`crate::dst`]) hooks its invariant checker in here.
    pub fn run_with<O: SimObserver>(
        self,
        workload: Vec<Request>,
        obs: &mut O,
    ) -> ServingReport {
        let ServingSim { batcher, engine, cfg } = self;
        let mut q: EventQueue<InstanceEvent> = EventQueue::new();
        let mut arena = RequestArena::with_capacity(workload.len());
        for r in workload {
            let at = r.arrival;
            let id = arena.alloc(r);
            q.schedule_at(at, InstanceEvent::Arrival(id));
        }

        let mut inst = Instance::new(batcher, Box::new(engine));
        // Reusable buffer for preempt/restore actions logged by the
        // batcher during admission; drained after every kick.
        let mut sched = Vec::new();
        // Peek before popping: an event past the deadline is left on the
        // calendar (it never applies), and the reported span clamps to
        // the deadline.
        let mut deadline_hit = false;
        while let Some(t) = q.peek_time() {
            if t > cfg.max_time {
                deadline_hit = true;
                break;
            }
            let (now, ev) = q.next().expect("peeked event is still queued");
            match ev {
                InstanceEvent::Arrival(id) => {
                    // The lone instance is the whole front door.
                    obs.on_route(now, id, 0);
                    inst.enqueue(id, &arena);
                }
                InstanceEvent::KvArrive(_, id) => inst.enqueue(id, &arena),
                // The lone instance never autoscales.
                InstanceEvent::WarmupDone(_) => {}
                InstanceEvent::StepDone(_) => {
                    let retired = inst.step_done(now, &mut arena);
                    for &id in retired {
                        obs.on_retire(now, 0, id, true, &arena);
                    }
                }
            }
            if inst.steps() >= cfg.max_steps {
                break;
            }
            // Step boundary (or idle): admit, plan, and price one step.
            // While a step is in flight, arrivals above only enqueue.
            if let Some(dt) = inst.kick(now, &mut arena) {
                q.schedule_in(dt, InstanceEvent::StepDone(0));
            }
            inst.drain_sched_log(&mut sched);
            for &(id, act) in &sched {
                match act {
                    SchedAction::Preempt => obs.on_preempt(now, 0, id),
                    SchedAction::Restore => obs.on_restore(now, 0, id),
                }
            }
            obs.post_event(now, &ev, std::slice::from_ref(&inst), &arena);
        }

        let name = inst.engine_name();
        // With peek-first clamping the clock never advances past the
        // deadline, so a clamped run's span must end at `max_time`
        // itself (exactly what the pop-and-discard loop reported).
        let end_time =
            if deadline_hit { cfg.max_time } else { q.now().min(cfg.max_time) };
        obs.on_done(end_time, std::slice::from_ref(&inst), &arena);
        inst.report(name, end_time, &arena)
    }
}

#[cfg(test)]
mod tests {
    use super::super::batcher::PreemptionConfig;
    use super::super::testutil::{
        budget, mk_req, open_budget, BatchProportionalEngine, FixedEngine,
    };
    use super::*;
    use crate::serving::request::{WorkloadGen, WorkloadSpec};

    fn small_workload(n: u64) -> Vec<Request> {
        WorkloadGen::new(WorkloadSpec {
            arrival_rate: 1000.0,
            n_requests: n,
            context: (8, 16),
            gen: (4, 8),
            priority_mix: Vec::new(),
            seed: 1,
        })
        .generate()
    }

    #[test]
    fn completes_all_requests() {
        let batcher = Batcher::new(8, open_budget());
        let mut eng = FixedEngine(0.01);
        let rep = ServingSim::new(batcher, &mut eng, SimConfig::default())
            .run(small_workload(50));
        assert_eq!(rep.completed, 50);
        assert!(rep.tokens >= 50 * 4);
        assert!(rep.stps > 0.0);
    }

    #[test]
    fn batching_raises_system_throughput() {
        let run = |max_batch| {
            let batcher = Batcher::new(max_batch, open_budget());
            let mut eng = FixedEngine(0.01);
            ServingSim::new(batcher, &mut eng, SimConfig::default())
                .run(small_workload(100))
        };
        let b1 = run(1);
        let b8 = run(8);
        assert!(
            b8.stps > b1.stps * 3.0,
            "b1 {} b8 {}",
            b1.stps,
            b8.stps
        );
        assert!(b8.mean_batch > b1.mean_batch);
    }

    #[test]
    fn queue_delay_appears_under_load() {
        let batcher = Batcher::new(1, open_budget()); // serialize everything
        let mut eng = FixedEngine(0.05);
        let rep = ServingSim::new(batcher, &mut eng, SimConfig::default())
            .run(small_workload(20));
        assert!(rep.queue_delay_mean > 0.0);
    }

    #[test]
    fn respects_step_limit_exactly() {
        let batcher = Batcher::new(8, open_budget());
        let mut eng = FixedEngine(0.01);
        let rep = ServingSim::new(
            batcher,
            &mut eng,
            SimConfig { max_steps: 5, ..Default::default() },
        )
        .run(small_workload(1000));
        // Regression: the limit used to be enforced off-by-one, letting
        // a 6th step run (the old test even asserted `<= 6`).
        assert_eq!(rep.steps, 5);
    }

    #[test]
    fn max_time_clamps_at_the_boundary() {
        // One request decoding 5 tokens at 0.1 s/step: completions land
        // at 0.1..0.5. With max_time = 0.25 the step finishing at 0.3
        // must NOT be applied. Regression: the deadline used to be
        // checked *after* applying the event, so that step was still
        // counted in `steps` (3 instead of 2) and the span ran to 0.3.
        let batcher = Batcher::new(4, open_budget());
        let mut eng = FixedEngine(0.1);
        let rep = ServingSim::new(
            batcher,
            &mut eng,
            SimConfig { max_time: 0.25, ..Default::default() },
        )
        .run(vec![mk_req(0, 0.0, 0, 5)]);
        assert_eq!(rep.steps, 2, "step past the deadline was counted");
        assert_eq!(rep.completed, 0);
        assert!((rep.span - 0.25).abs() < 1e-12, "span {}", rep.span);
        // Only completed steps are charged: busy 0.2s over 2 steps of
        // one lane each.
        assert!((rep.mean_batch - 1.0).abs() < 1e-12);
    }

    #[test]
    fn max_time_does_not_disturb_drained_runs() {
        let run = |max_time: f64| {
            let batcher = Batcher::new(8, open_budget());
            let mut eng = FixedEngine(0.01);
            ServingSim::new(
                batcher,
                &mut eng,
                SimConfig { max_time, ..Default::default() },
            )
            .run(small_workload(20))
        };
        let free = run(f64::INFINITY);
        let capped = run(1e9);
        assert_eq!(free.completed, capped.completed);
        assert_eq!(free.steps, capped.steps);
        assert!((free.span - capped.span).abs() < 1e-12);
    }

    /// The tentpole's single-instance equivalence pin: the refactored
    /// (instance-based) simulator must reproduce the pre-refactor
    /// report exactly on a fixed workload. The expected values are the
    /// pre-refactor loop's output (independently derived by an exact
    /// mirror of the old event loop), so any drift the extraction of
    /// [`Instance`] introduced — admission points, charge timing,
    /// retirement order — fails this test.
    #[test]
    fn refactor_reproduces_the_prerefactor_report() {
        let wl = vec![
            mk_req(0, 0.00, 24, 3),
            mk_req(1, 0.02, 16, 2),
            mk_req(2, 0.03, 0, 4),
            mk_req(3, 0.30, 40, 1),
            mk_req(4, 0.31, 8, 5),
        ];
        let batcher = Batcher::with_prefill(3, open_budget(), 16);
        let mut eng = FixedEngine(0.05);
        let rep =
            ServingSim::new(batcher, &mut eng, SimConfig::default()).run(wl);
        assert_eq!(rep.completed, 5);
        assert_eq!(rep.tokens, 15);
        assert_eq!(rep.prefill_tokens, 88);
        assert_eq!(rep.steps, 13);
        let close = |a: f64, b: f64, what: &str| {
            assert!((a - b).abs() < 1e-9, "{what}: {a} vs pre-refactor {b}");
        };
        close(rep.span, 0.7, "span");
        close(rep.stps, 15.0 / 0.7, "stps");
        close(rep.mean_batch, 0.9 / 0.65, "mean_batch");
        close(rep.ttft.mean, 0.128, "ttft.mean");
        close(rep.tpot.mean, 0.05, "tpot.mean");
        close(rep.queue_delay_mean, 0.018, "queue_delay_mean");
    }

    #[test]
    fn arrivals_mid_step_wait_for_the_boundary() {
        // r0 arrives at 0 and runs a 0.1 s step alone. r1 and r2 arrive
        // at 0.05, while that step is in flight: they must be admitted
        // at 0.1 and complete at 0.2 — never credited a token from the
        // step that was priced for r0 alone (the seed behavior, which
        // finished everything by 0.1).
        let batcher = Batcher::new(8, open_budget());
        let mut eng = FixedEngine(0.1);
        let rep = ServingSim::new(batcher, &mut eng, SimConfig::default()).run(vec![
            mk_req(0, 0.0, 8, 1),
            mk_req(1, 0.05, 8, 1),
            mk_req(2, 0.05, 8, 1),
        ]);
        assert_eq!(rep.completed, 3);
        assert_eq!(rep.steps, 2);
        assert!((rep.span - 0.2).abs() < 1e-9, "span {}", rep.span);
        // r1/r2 queued for 0.05 s each.
        assert!((rep.queue_delay_mean - 0.1 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn mean_batch_is_duration_weighted() {
        // Step 1: one lane for 0.1 s. Step 2: two lanes for 0.2 s.
        // Duration-weighted occupancy = (1*0.1 + 2*0.2) / 0.3 = 5/3;
        // the seed's per-step average said 1.5.
        let batcher = Batcher::new(8, open_budget());
        let mut eng = BatchProportionalEngine(0.1);
        let rep = ServingSim::new(batcher, &mut eng, SimConfig::default()).run(vec![
            mk_req(0, 0.0, 8, 1),
            mk_req(1, 0.05, 8, 1),
            mk_req(2, 0.05, 8, 1),
        ]);
        assert_eq!(rep.steps, 2);
        assert!(
            (rep.mean_batch - 5.0 / 3.0).abs() < 1e-9,
            "mean_batch {}",
            rep.mean_batch
        );
    }

    #[test]
    fn ttft_positive_and_prefill_accounted() {
        // 100-token prompts at 30 tokens/chunk: 4 prefill steps before
        // the first token, then decode. TTFT must be strictly positive
        // and larger than a decode-only TPOT.
        let batcher = Batcher::with_prefill(8, open_budget(), 30);
        let mut eng = FixedEngine(0.01);
        let rep = ServingSim::new(batcher, &mut eng, SimConfig::default()).run(vec![
            mk_req(0, 0.0, 100, 5),
            mk_req(1, 0.0, 100, 5),
        ]);
        assert_eq!(rep.completed, 2);
        assert_eq!(rep.prefill_tokens, 200);
        assert!(rep.ttft.p50 > 0.0);
        // r0's prompt drains one chunk per step over steps 1-4 (TTFT
        // 0.04); r1's chunks then run during r0's decode steps 5-8
        // (TTFT 0.08).
        assert!((rep.ttft.mean - 0.06).abs() < 1e-9, "ttft {}", rep.ttft.mean);
        assert!((rep.tpot.p50 - 0.01).abs() < 1e-9, "tpot {}", rep.tpot.p50);
        assert!(rep.e2e.p99 > rep.ttft.p99);
    }

    /// The tentpole's disabled-path pin, pressure edition: enabling
    /// preemption changes nothing for a single-class workload, even
    /// under real KV pressure (one class means there is never a valid
    /// victim). Every report field must match the FIFO batcher's bit
    /// for bit — `to_bits` equality, not tolerance.
    #[test]
    fn enabled_preemption_with_a_single_class_is_bit_identical_to_fifo() {
        let run = |preempt: Option<PreemptionConfig>| {
            // budget(60) fits only ~2-4 of the 12-24-token footprints,
            // so admission stalls on KV throughout the run.
            let mut batcher = Batcher::with_prefill(8, budget(60), 16);
            if let Some(cfg) = preempt {
                batcher.set_preemption(cfg);
            }
            let mut eng = FixedEngine(0.02);
            ServingSim::new(batcher, &mut eng, SimConfig::default())
                .run(small_workload(60))
        };
        let fifo = run(None);
        let pre = run(Some(PreemptionConfig {
            enabled: true,
            evict_cost: 0.5,
            restore_cost: 0.5,
        }));
        assert!(fifo.queue_delay_mean > 0.0, "want real KV pressure");
        assert_eq!(pre.preemptions, 0);
        assert_eq!(pre.restores, 0);
        assert_eq!(fifo.completed, pre.completed);
        assert_eq!(fifo.tokens, pre.tokens);
        assert_eq!(fifo.prefill_tokens, pre.prefill_tokens);
        assert_eq!(fifo.steps, pre.steps);
        assert_eq!(fifo.span.to_bits(), pre.span.to_bits());
        assert_eq!(fifo.stps.to_bits(), pre.stps.to_bits());
        assert_eq!(fifo.mean_batch.to_bits(), pre.mean_batch.to_bits());
        assert_eq!(fifo.ttft.mean.to_bits(), pre.ttft.mean.to_bits());
        assert_eq!(fifo.ttft.p99.to_bits(), pre.ttft.p99.to_bits());
        assert_eq!(fifo.tpot.mean.to_bits(), pre.tpot.mean.to_bits());
        assert_eq!(fifo.e2e.p99.to_bits(), pre.e2e.p99.to_bits());
        assert_eq!(
            fifo.queue_delay_mean.to_bits(),
            pre.queue_delay_mean.to_bits()
        );
    }

    #[test]
    fn preemption_speeds_high_priority_under_kv_pressure() {
        // A long class-0 request hogs the KV budget; the class-1
        // arrival behind it must wait for it to drain under FIFO but
        // evicts it immediately with preemption on.
        let wl = || {
            let lo = mk_req(0, 0.0, 10, 40); // 50 KV tokens
            let mut hi = mk_req(1, 0.1, 10, 5); // 15 KV tokens
            hi.priority = 1;
            vec![lo, hi]
        };
        let run = |enabled| {
            let mut batcher = Batcher::new(4, budget(55));
            batcher.set_preemption(PreemptionConfig {
                enabled,
                evict_cost: 0.01,
                restore_cost: 0.01,
            });
            let mut eng = FixedEngine(0.05);
            ServingSim::new(batcher, &mut eng, SimConfig::default()).run(wl())
        };
        let fifo = run(false);
        let pre = run(true);
        assert_eq!(fifo.preemptions, 0);
        assert_eq!(pre.preemptions, 1);
        assert_eq!(pre.restores, 1);
        assert_eq!(fifo.completed, 2);
        assert_eq!(pre.completed, 2, "the victim still finishes");
        assert_eq!(fifo.tokens, pre.tokens);
        // The high-priority request's TTFT (the tail of two samples)
        // collapses from ~1.95 s behind the hog to one step.
        assert!(
            pre.ttft.p99 < fifo.ttft.p99 * 0.5,
            "preempt ttft p99 {} vs fifo {}",
            pre.ttft.p99,
            fifo.ttft.p99
        );
        // The evict/restore stalls are priced, not free: the victim's
        // end-to-end latency includes them.
        assert!(pre.e2e.p99 >= fifo.e2e.p99 - 1e-12);
    }

    #[test]
    fn decode_only_mode_reports_zero_prefill() {
        let batcher = Batcher::new(8, open_budget());
        let mut eng = FixedEngine(0.01);
        let rep = ServingSim::new(batcher, &mut eng, SimConfig::default())
            .run(small_workload(10));
        assert_eq!(rep.prefill_tokens, 0);
        assert!(rep.ttft.p50 > 0.0); // first decode step still takes time
    }
}
