//! The serving simulation driver: DES loop over arrivals + decode steps.

use crate::des::EventQueue;

use super::batcher::Batcher;
use super::engine::StepEngine;
use super::metrics::ServingReport;
use super::request::Request;

/// Simulation parameters.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Hard stop on simulated seconds (safety valve; `f64::INFINITY` to
    /// run to drain).
    pub max_time: f64,
    /// Hard stop on steps.
    pub max_steps: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig { max_time: f64::INFINITY, max_steps: 10_000_000 }
    }
}

enum Event {
    Arrival(Request),
    StepDone,
}

/// The serving simulator: continuous batching over a step engine.
pub struct ServingSim<'a> {
    batcher: Batcher,
    engine: &'a mut dyn StepEngine,
    cfg: SimConfig,
}

impl<'a> ServingSim<'a> {
    /// Build a simulator.
    pub fn new(batcher: Batcher, engine: &'a mut dyn StepEngine, cfg: SimConfig) -> Self {
        ServingSim { batcher, engine, cfg }
    }

    /// Run the given workload to completion (or a configured limit) and
    /// report. The engine is stepped whenever requests are active; a new
    /// step is scheduled at `now + step_latency(batch, max_ctx)`.
    pub fn run(mut self, workload: Vec<Request>) -> ServingReport {
        let mut q: EventQueue<Event> = EventQueue::new();
        for r in workload {
            q.schedule_at(r.arrival, Event::Arrival(r));
        }

        let mut finished: Vec<Request> = Vec::new();
        let mut steps: u64 = 0;
        let mut batch_integral: f64 = 0.0;
        let mut step_in_flight = false;

        while let Some((now, ev)) = q.next() {
            match ev {
                Event::Arrival(r) => {
                    self.batcher.enqueue(r);
                }
                Event::StepDone => {
                    step_in_flight = false;
                    finished.extend(self.batcher.step_complete(now));
                    steps += 1;
                }
            }
            if now > self.cfg.max_time || steps > self.cfg.max_steps {
                break;
            }
            // At every event boundary: admit, then (re)start the engine.
            self.batcher.admit(now);
            if !step_in_flight && self.batcher.active_len() > 0 {
                let b = self.batcher.active_len() as u64;
                let ctx = self.batcher.max_seq_len();
                let dt = self.engine.step_latency(b, ctx);
                batch_integral += b as f64;
                q.schedule_in(dt, Event::StepDone);
                step_in_flight = true;
            }
        }

        let end = q.now();
        ServingReport::from_requests(
            self.engine.name(),
            &finished,
            steps,
            batch_integral,
            end,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::batcher::KvBudget;
    use crate::serving::request::{WorkloadGen, WorkloadSpec};

    /// A constant-latency engine for deterministic tests.
    struct FixedEngine(f64);
    impl StepEngine for FixedEngine {
        fn step_latency(&mut self, batch: u64, _ctx: u64) -> f64 {
            if batch == 0 {
                0.0
            } else {
                self.0
            }
        }
        fn name(&self) -> String {
            "fixed".into()
        }
    }

    fn small_workload(n: u64) -> Vec<Request> {
        WorkloadGen::new(WorkloadSpec {
            arrival_rate: 1000.0,
            n_requests: n,
            context: (8, 16),
            gen: (4, 8),
            seed: 1,
        })
        .generate()
    }

    #[test]
    fn completes_all_requests() {
        let kv = KvBudget::new(1e9, 0.0, 1.0);
        let batcher = Batcher::new(8, kv);
        let mut eng = FixedEngine(0.01);
        let rep = ServingSim::new(batcher, &mut eng, SimConfig::default())
            .run(small_workload(50));
        assert_eq!(rep.completed, 50);
        assert!(rep.tokens >= 50 * 4);
        assert!(rep.stps > 0.0);
    }

    #[test]
    fn batching_raises_system_throughput() {
        let run = |max_batch| {
            let kv = KvBudget::new(1e9, 0.0, 1.0);
            let batcher = Batcher::new(max_batch, kv);
            let mut eng = FixedEngine(0.01);
            ServingSim::new(batcher, &mut eng, SimConfig::default())
                .run(small_workload(100))
        };
        let b1 = run(1);
        let b8 = run(8);
        assert!(
            b8.stps > b1.stps * 3.0,
            "b1 {} b8 {}",
            b1.stps,
            b8.stps
        );
        assert!(b8.mean_batch > b1.mean_batch);
    }

    #[test]
    fn queue_delay_appears_under_load() {
        let kv = KvBudget::new(1e9, 0.0, 1.0);
        let batcher = Batcher::new(1, kv); // serialize everything
        let mut eng = FixedEngine(0.05);
        let rep = ServingSim::new(batcher, &mut eng, SimConfig::default())
            .run(small_workload(20));
        assert!(rep.queue_delay_mean > 0.0);
    }

    #[test]
    fn respects_step_limit() {
        let kv = KvBudget::new(1e9, 0.0, 1.0);
        let batcher = Batcher::new(8, kv);
        let mut eng = FixedEngine(0.01);
        let rep = ServingSim::new(
            batcher,
            &mut eng,
            SimConfig { max_steps: 5, ..Default::default() },
        )
        .run(small_workload(1000));
        assert!(rep.steps <= 6);
    }
}
