//! The serving simulation driver: DES loop over arrivals and engine
//! steps (mixed prefill + decode).
//!
//! Step semantics (fidelity rules the regression tests pin down):
//!
//! * **Admission only at step boundaries.** A request arriving while a
//!   step is in flight is enqueued and waits for the next `StepDone`;
//!   it can never join a step it was not priced into (which would mint
//!   free tokens and under-count its latency).
//! * **Steps are planned, then priced, then completed.** At each
//!   boundary the batcher plans a [`StepBatch`](super::StepBatch)
//!   (decode lanes + a prefill chunk), the engine prices it, and the
//!   completion event applies exactly that plan.
//! * **Occupancy statistics are duration-weighted.** `mean_batch`
//!   integrates lanes over busy time, so engines with batch-dependent
//!   step latency (the analytic backend) don't bias the mean.
//! * **Limits are exact.** `max_steps = N` prices exactly N steps.

use crate::des::EventQueue;

use super::batcher::Batcher;
use super::engine::StepEngine;
use super::metrics::{ServingReport, StepStats};
use super::request::Request;

/// Simulation parameters.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Hard stop on simulated seconds (safety valve; `f64::INFINITY` to
    /// run to drain).
    pub max_time: f64,
    /// Hard stop on steps (enforced exactly).
    pub max_steps: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig { max_time: f64::INFINITY, max_steps: 10_000_000 }
    }
}

enum Event {
    Arrival(Request),
    StepDone,
}

/// The serving simulator: continuous batching over a step engine.
pub struct ServingSim<'a> {
    batcher: Batcher,
    engine: &'a mut dyn StepEngine,
    cfg: SimConfig,
}

impl<'a> ServingSim<'a> {
    /// Build a simulator.
    pub fn new(batcher: Batcher, engine: &'a mut dyn StepEngine, cfg: SimConfig) -> Self {
        ServingSim { batcher, engine, cfg }
    }

    /// Run the given workload to completion (or a configured limit) and
    /// report. The engine is stepped whenever requests are active; a new
    /// step is scheduled at `now + mixed_step_latency(plan)`.
    pub fn run(mut self, workload: Vec<Request>) -> ServingReport {
        let mut q: EventQueue<Event> = EventQueue::new();
        for r in workload {
            q.schedule_at(r.arrival, Event::Arrival(r));
        }

        let mut finished: Vec<Request> = Vec::new();
        let mut steps: u64 = 0;
        let mut batch_time_integral: f64 = 0.0;
        let mut busy_time: f64 = 0.0;
        let mut step_in_flight = false;

        while let Some((now, ev)) = q.next() {
            match ev {
                Event::Arrival(r) => {
                    self.batcher.enqueue(r);
                }
                Event::StepDone => {
                    step_in_flight = false;
                    finished.extend(self.batcher.step_complete(now));
                    steps += 1;
                }
            }
            if now > self.cfg.max_time || steps >= self.cfg.max_steps {
                break;
            }
            // Step boundary (or idle): admit, plan, and price one step.
            // While a step is in flight, arrivals above only enqueue.
            if !step_in_flight {
                self.batcher.admit(now);
                let plan = self.batcher.plan_step();
                if !plan.is_empty() {
                    let dt = self.engine.mixed_step_latency(&plan);
                    batch_time_integral += plan.lanes() as f64 * dt;
                    busy_time += dt;
                    q.schedule_in(dt, Event::StepDone);
                    step_in_flight = true;
                }
            }
        }

        let stats = StepStats {
            steps,
            batch_time_integral,
            busy_time,
            prefill_tokens: self.batcher.prefill_tokens_processed(),
            end_time: q.now(),
        };
        ServingReport::from_requests(self.engine.name(), &finished, &stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::batcher::KvBudget;
    use crate::serving::request::{WorkloadGen, WorkloadSpec};

    /// A constant-latency engine for deterministic tests.
    struct FixedEngine(f64);
    impl StepEngine for FixedEngine {
        fn step_latency(&mut self, batch: u64, _ctx: u64) -> f64 {
            if batch == 0 {
                0.0
            } else {
                self.0
            }
        }
        fn name(&self) -> String {
            "fixed".into()
        }
    }

    /// Step latency proportional to the lane count — the shape that
    /// exposes per-step-averaged (instead of duration-weighted) batch
    /// statistics.
    struct BatchProportionalEngine(f64);
    impl StepEngine for BatchProportionalEngine {
        fn step_latency(&mut self, batch: u64, _ctx: u64) -> f64 {
            self.0 * batch as f64
        }
        fn name(&self) -> String {
            "batch-proportional".into()
        }
    }

    fn small_workload(n: u64) -> Vec<Request> {
        WorkloadGen::new(WorkloadSpec {
            arrival_rate: 1000.0,
            n_requests: n,
            context: (8, 16),
            gen: (4, 8),
            seed: 1,
        })
        .generate()
    }

    fn mk_req(id: u64, arrival: f64, ctx: u64, gen: u64) -> Request {
        Request {
            id,
            arrival,
            context_len: ctx,
            gen_len: gen,
            generated: 0,
            prefilled: 0,
            scheduled_prefill: 0,
            admitted_at: None,
            first_token_at: None,
            completed_at: None,
        }
    }

    fn open_budget() -> KvBudget {
        KvBudget::new(1e9, 0.0, 1.0)
    }

    #[test]
    fn completes_all_requests() {
        let batcher = Batcher::new(8, open_budget());
        let mut eng = FixedEngine(0.01);
        let rep = ServingSim::new(batcher, &mut eng, SimConfig::default())
            .run(small_workload(50));
        assert_eq!(rep.completed, 50);
        assert!(rep.tokens >= 50 * 4);
        assert!(rep.stps > 0.0);
    }

    #[test]
    fn batching_raises_system_throughput() {
        let run = |max_batch| {
            let batcher = Batcher::new(max_batch, open_budget());
            let mut eng = FixedEngine(0.01);
            ServingSim::new(batcher, &mut eng, SimConfig::default())
                .run(small_workload(100))
        };
        let b1 = run(1);
        let b8 = run(8);
        assert!(
            b8.stps > b1.stps * 3.0,
            "b1 {} b8 {}",
            b1.stps,
            b8.stps
        );
        assert!(b8.mean_batch > b1.mean_batch);
    }

    #[test]
    fn queue_delay_appears_under_load() {
        let batcher = Batcher::new(1, open_budget()); // serialize everything
        let mut eng = FixedEngine(0.05);
        let rep = ServingSim::new(batcher, &mut eng, SimConfig::default())
            .run(small_workload(20));
        assert!(rep.queue_delay_mean > 0.0);
    }

    #[test]
    fn respects_step_limit_exactly() {
        let batcher = Batcher::new(8, open_budget());
        let mut eng = FixedEngine(0.01);
        let rep = ServingSim::new(
            batcher,
            &mut eng,
            SimConfig { max_steps: 5, ..Default::default() },
        )
        .run(small_workload(1000));
        // Regression: the limit used to be enforced off-by-one, letting
        // a 6th step run (the old test even asserted `<= 6`).
        assert_eq!(rep.steps, 5);
    }

    #[test]
    fn arrivals_mid_step_wait_for_the_boundary() {
        // r0 arrives at 0 and runs a 0.1 s step alone. r1 and r2 arrive
        // at 0.05, while that step is in flight: they must be admitted
        // at 0.1 and complete at 0.2 — never credited a token from the
        // step that was priced for r0 alone (the seed behavior, which
        // finished everything by 0.1).
        let batcher = Batcher::new(8, open_budget());
        let mut eng = FixedEngine(0.1);
        let rep = ServingSim::new(batcher, &mut eng, SimConfig::default()).run(vec![
            mk_req(0, 0.0, 8, 1),
            mk_req(1, 0.05, 8, 1),
            mk_req(2, 0.05, 8, 1),
        ]);
        assert_eq!(rep.completed, 3);
        assert_eq!(rep.steps, 2);
        assert!((rep.span - 0.2).abs() < 1e-9, "span {}", rep.span);
        // r1/r2 queued for 0.05 s each.
        assert!((rep.queue_delay_mean - 0.1 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn mean_batch_is_duration_weighted() {
        // Step 1: one lane for 0.1 s. Step 2: two lanes for 0.2 s.
        // Duration-weighted occupancy = (1*0.1 + 2*0.2) / 0.3 = 5/3;
        // the seed's per-step average said 1.5.
        let batcher = Batcher::new(8, open_budget());
        let mut eng = BatchProportionalEngine(0.1);
        let rep = ServingSim::new(batcher, &mut eng, SimConfig::default()).run(vec![
            mk_req(0, 0.0, 8, 1),
            mk_req(1, 0.05, 8, 1),
            mk_req(2, 0.05, 8, 1),
        ]);
        assert_eq!(rep.steps, 2);
        assert!(
            (rep.mean_batch - 5.0 / 3.0).abs() < 1e-9,
            "mean_batch {}",
            rep.mean_batch
        );
    }

    #[test]
    fn ttft_positive_and_prefill_accounted() {
        // 100-token prompts at 30 tokens/chunk: 4 prefill steps before
        // the first token, then decode. TTFT must be strictly positive
        // and larger than a decode-only TPOT.
        let batcher = Batcher::with_prefill(8, open_budget(), 30);
        let mut eng = FixedEngine(0.01);
        let rep = ServingSim::new(batcher, &mut eng, SimConfig::default()).run(vec![
            mk_req(0, 0.0, 100, 5),
            mk_req(1, 0.0, 100, 5),
        ]);
        assert_eq!(rep.completed, 2);
        assert_eq!(rep.prefill_tokens, 200);
        assert!(rep.ttft.p50 > 0.0);
        // r0's prompt drains one chunk per step over steps 1-4 (TTFT
        // 0.04); r1's chunks then run during r0's decode steps 5-8
        // (TTFT 0.08).
        assert!((rep.ttft.mean - 0.06).abs() < 1e-9, "ttft {}", rep.ttft.mean);
        assert!((rep.tpot.p50 - 0.01).abs() < 1e-9, "tpot {}", rep.tpot.p50);
        assert!(rep.e2e.p99 > rep.ttft.p99);
    }

    #[test]
    fn decode_only_mode_reports_zero_prefill() {
        let batcher = Batcher::new(8, open_budget());
        let mut eng = FixedEngine(0.01);
        let rep = ServingSim::new(batcher, &mut eng, SimConfig::default())
            .run(small_workload(10));
        assert_eq!(rep.prefill_tokens, 0);
        assert!(rep.ttft.p50 > 0.0); // first decode step still takes time
    }
}
