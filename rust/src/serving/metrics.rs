//! Serving metrics: per-request SLO statistics and system totals.
//!
//! SLO metric definitions (the quantities per-token pricing cannot see
//! and the serving simulator exists to measure):
//!
//! * **TTFT** — time to first token, `first_token_at - arrival`. The
//!   final prefill chunk's forward pass emits the first token, so TTFT
//!   includes queueing, prefill chunking, and any decode lanes sharing
//!   those steps.
//! * **TPOT** — time per output token after the first,
//!   `(completed - first_token) / (generated - 1)`; the steady-state
//!   decode cadence a user experiences.
//! * **E2E** — end-to-end request latency, `completed - arrival`.

use super::request::Request;

/// Percentile of a sorted-or-not sample set (nearest-rank). Sorts the
/// slice in place; returns NaN for an empty sample set.
pub fn percentile(samples: &mut [f64], p: f64) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    samples.sort_by(|a, b| a.total_cmp(b));
    let rank = ((p / 100.0) * (samples.len() as f64 - 1.0)).round() as usize;
    samples[rank.min(samples.len() - 1)]
}

/// Mean + tail percentiles of one latency distribution, seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyStats {
    /// Arithmetic mean.
    pub mean: f64,
    /// Median.
    pub p50: f64,
    /// 90th percentile.
    pub p90: f64,
    /// 99th percentile (tail SLO).
    pub p99: f64,
}

impl LatencyStats {
    /// All-zero stats (no samples).
    pub fn zero() -> LatencyStats {
        LatencyStats { mean: 0.0, p50: 0.0, p90: 0.0, p99: 0.0 }
    }

    /// Compute from samples (sorts in place; zeros when empty).
    pub fn from_samples(samples: &mut [f64]) -> LatencyStats {
        if samples.is_empty() {
            return LatencyStats::zero();
        }
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        LatencyStats {
            mean,
            p50: percentile(samples, 50.0),
            p90: percentile(samples, 90.0),
            p99: percentile(samples, 99.0),
        }
    }
}

/// Per-step accounting the simulator hands to the report.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct StepStats {
    /// Steps executed (priced by the engine).
    pub steps: u64,
    /// Integral of active lanes over step duration (lane-seconds).
    pub batch_time_integral: f64,
    /// Total time with a step in flight, seconds.
    pub busy_time: f64,
    /// Total prompt tokens prefilled.
    pub prefill_tokens: u64,
    /// KV evictions under capacity pressure (0 with preemption off).
    pub preemptions: u64,
    /// Evicted requests re-admitted (restores trail preemptions by the
    /// evictions still awaiting re-admission when the run ends).
    pub restores: u64,
    /// Simulated clock at the end of the run.
    pub end_time: f64,
}

/// Aggregated results of one serving-simulation run.
#[derive(Debug, Clone)]
pub struct ServingReport {
    /// Engine backend name.
    pub engine: String,
    /// Requests completed.
    pub completed: u64,
    /// Total tokens generated.
    pub tokens: u64,
    /// Total prompt tokens prefilled (0 in decode-only mode).
    pub prefill_tokens: u64,
    /// Wall/simulated span from first arrival to last completion, s.
    pub span: f64,
    /// System tokens/second over the span.
    pub stps: f64,
    /// Mean per-user decode throughput (tokens / residence time).
    pub utps_mean: f64,
    /// p50 per-user throughput.
    pub utps_p50: f64,
    /// p99 per-user throughput (worst users).
    pub utps_p99_low: f64,
    /// Mean queueing delay (arrival -> admission), s.
    pub queue_delay_mean: f64,
    /// Time-to-first-token SLO distribution.
    pub ttft: LatencyStats,
    /// Time-per-output-token SLO distribution.
    pub tpot: LatencyStats,
    /// End-to-end latency SLO distribution.
    pub e2e: LatencyStats,
    /// Steps executed.
    pub steps: u64,
    /// Mean batch occupancy, weighted by step duration (lane-seconds
    /// over busy seconds — a per-step average would bias the mean when
    /// step latencies vary with batch size).
    pub mean_batch: f64,
    /// KV evictions under capacity pressure (0 with preemption off).
    /// The evict/restore stall is priced as extra step time, so it
    /// surfaces in the TTFT/TPOT distributions of whatever was active
    /// or waiting while the traffic ran.
    pub preemptions: u64,
    /// Evicted requests re-admitted and restored.
    pub restores: u64,
}

impl ServingReport {
    /// Build from a slice of requests + step accounting. Thin wrapper
    /// over [`ServingReport::from_refs`] for callers that own their
    /// requests contiguously (tests, trace tooling).
    pub fn from_requests(
        engine: String,
        reqs: &[Request],
        stats: &StepStats,
    ) -> ServingReport {
        ServingReport::from_refs(engine, reqs.iter(), stats)
    }

    /// Build from any re-iterable stream of request references + step
    /// accounting. This is the arena-friendly entry point: simulators
    /// keep dense ids and resolve them against their
    /// [`RequestArena`](super::RequestArena) here, without materializing
    /// a `Vec<Request>` first.
    pub fn from_refs<'a, I>(
        engine: String,
        reqs: I,
        stats: &StepStats,
    ) -> ServingReport
    where
        I: Iterator<Item = &'a Request> + Clone,
    {
        let completed: Vec<&Request> =
            reqs.clone().filter(|r| r.completed_at.is_some()).collect();
        let tokens: u64 = completed.iter().map(|r| r.generated).sum();
        let first = reqs.map(|r| r.arrival).fold(f64::INFINITY, f64::min);
        // Regression (DST seed 1088): with no requests at all the fold
        // leaves `first` at its sentinel and `end_time - first` used to
        // collapse the span to the 1e-12 floor; an empty report's span
        // is the simulated span itself.
        let span = if first.is_finite() {
            (stats.end_time - first).max(1e-12)
        } else {
            stats.end_time.max(1e-12)
        };

        let mut utps: Vec<f64> = completed
            .iter()
            .filter_map(|r| {
                let t = r.completed_at? - r.admitted_at?;
                (t > 0.0).then_some(r.generated as f64 / t)
            })
            .collect();
        let utps_mean = if utps.is_empty() {
            0.0
        } else {
            utps.iter().sum::<f64>() / utps.len() as f64
        };
        let delays: Vec<f64> = completed
            .iter()
            .filter_map(|r| Some(r.admitted_at? - r.arrival))
            .collect();
        let queue_delay_mean = if delays.is_empty() {
            0.0
        } else {
            delays.iter().sum::<f64>() / delays.len() as f64
        };

        let mut ttft: Vec<f64> = completed.iter().filter_map(|r| r.ttft()).collect();
        let mut tpot: Vec<f64> = completed.iter().filter_map(|r| r.tpot()).collect();
        let mut e2e: Vec<f64> = completed.iter().filter_map(|r| r.e2e()).collect();

        ServingReport {
            engine,
            completed: completed.len() as u64,
            tokens,
            prefill_tokens: stats.prefill_tokens,
            span,
            stps: tokens as f64 / span,
            utps_mean,
            // Regression (DST seed 1088): `percentile` of zero samples
            // is NaN, which used to leak into every report with no
            // completions (e.g. a deadline before the first arrival).
            // Zero, matching `utps_mean` and `LatencyStats::zero()`.
            utps_p50: if utps.is_empty() {
                0.0
            } else {
                percentile(&mut utps, 50.0)
            },
            utps_p99_low: if utps.is_empty() {
                0.0
            } else {
                percentile(&mut utps, 1.0)
            },
            queue_delay_mean,
            ttft: LatencyStats::from_samples(&mut ttft),
            tpot: LatencyStats::from_samples(&mut tpot),
            e2e: LatencyStats::from_samples(&mut e2e),
            steps: stats.steps,
            mean_batch: if stats.busy_time > 0.0 {
                stats.batch_time_integral / stats.busy_time
            } else {
                0.0
            },
            preemptions: stats.preemptions,
            restores: stats.restores,
        }
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "{}: {} reqs, {} tokens (+{} prefill) in {:.2}s -> STPS {:.1}, \
             UTPS mean {:.1} / p50 {:.1}, queue delay {:.3}s, mean batch {:.1}",
            self.engine,
            self.completed,
            self.tokens,
            self.prefill_tokens,
            self.span,
            self.stps,
            self.utps_mean,
            self.utps_p50,
            self.queue_delay_mean,
            self.mean_batch
        )
    }

    /// Multi-line SLO summary: TTFT / TPOT / E2E percentiles.
    pub fn slo_summary(&self) -> String {
        fn row(name: &str, s: &LatencyStats, scale: f64, unit: &str) -> String {
            format!(
                "{name:<5} mean {:.3}{unit}  p50 {:.3}{unit}  p90 {:.3}{unit}  p99 {:.3}{unit}",
                s.mean * scale,
                s.p50 * scale,
                s.p90 * scale,
                s.p99 * scale
            )
        }
        format!(
            "{}\n{}\n{}",
            row("TTFT", &self.ttft, 1.0, "s"),
            row("TPOT", &self.tpot, 1e3, "ms"),
            row("E2E", &self.e2e, 1.0, "s")
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let mut v = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&mut v, 50.0), 3.0);
        assert_eq!(percentile(&mut v, 0.0), 1.0);
        assert_eq!(percentile(&mut v, 100.0), 5.0);
        let mut empty: Vec<f64> = vec![];
        assert!(percentile(&mut empty, 50.0).is_nan());
    }

    #[test]
    fn percentile_single_sample_is_that_sample_at_every_p() {
        for p in [0.0, 1.0, 50.0, 90.0, 99.0, 100.0] {
            let mut one = [0.37f64];
            assert_eq!(percentile(&mut one, p), 0.37);
        }
    }

    #[test]
    fn percentile_of_two_samples_rounds_to_nearest_rank() {
        // Nearest-rank on n=2: rank = round(p/100 * 1). p99 (and p90)
        // land on the larger sample; p49 and below on the smaller.
        let mut two = [2.0f64, 1.0];
        assert_eq!(percentile(&mut two, 99.0), 2.0);
        assert_eq!(percentile(&mut two, 90.0), 2.0);
        assert_eq!(percentile(&mut two, 49.0), 1.0);
        // p50 rounds half away from zero: the upper sample.
        assert_eq!(percentile(&mut two, 50.0), 2.0);
    }

    #[test]
    fn merged_report_percentiles_equal_pooled_raw_samples() {
        // The cluster report aggregates by pooling every instance's
        // completed requests and recomputing percentiles — which must
        // equal percentile() over the union of the per-instance raw
        // samples (NOT any average of per-instance percentiles).
        let mk = |id: u64, first: f64, done: f64| Request {
            id,
            arrival: 0.0,
            context_len: 10,
            gen_len: 5,
            priority: 0,
            generated: 5,
            prefilled: 10,
            scheduled_prefill: 0,
            admitted_at: Some(0.0),
            first_token_at: Some(first),
            completed_at: Some(done),
        };
        let inst_a: Vec<Request> =
            vec![mk(0, 0.1, 1.0), mk(1, 0.2, 2.0), mk(2, 0.9, 3.0)];
        let inst_b: Vec<Request> = vec![mk(3, 0.3, 1.5), mk(4, 0.6, 2.5)];
        let pooled: Vec<Request> =
            inst_a.iter().chain(&inst_b).cloned().collect();
        let rep = ServingReport::from_requests(
            "merged".into(),
            &pooled,
            &StepStats { end_time: 3.0, ..Default::default() },
        );
        let mut ttft_raw: Vec<f64> =
            pooled.iter().filter_map(|r| r.ttft()).collect();
        assert_eq!(rep.ttft.p50, percentile(&mut ttft_raw, 50.0));
        assert_eq!(rep.ttft.p90, percentile(&mut ttft_raw, 90.0));
        assert_eq!(rep.ttft.p99, percentile(&mut ttft_raw, 99.0));
        // n=5 nearest-rank: p50 is the middle sample, p90/p99 the max.
        assert_eq!(rep.ttft.p50, 0.3);
        assert_eq!(rep.ttft.p99, 0.9);
        // A per-instance average would get this wrong: each instance's
        // p99 is its own max (0.9 and 0.6), and no average of those
        // reproduces the pooled tail for asymmetric instance loads.
        let mut a: Vec<f64> = inst_a.iter().filter_map(|r| r.ttft()).collect();
        let mut b: Vec<f64> = inst_b.iter().filter_map(|r| r.ttft()).collect();
        let avg = (percentile(&mut a, 50.0) + percentile(&mut b, 50.0)) / 2.0;
        assert_ne!(rep.ttft.p50, avg);
    }

    fn one_request() -> Request {
        Request {
            id: 0,
            arrival: 0.0,
            context_len: 10,
            gen_len: 10,
            priority: 0,
            generated: 10,
            prefilled: 10,
            scheduled_prefill: 0,
            admitted_at: Some(0.0),
            first_token_at: Some(0.2),
            completed_at: Some(2.0),
        }
    }

    #[test]
    fn report_computes_throughputs_and_slos() {
        let reqs = vec![one_request()];
        let stats = StepStats {
            steps: 10,
            batch_time_integral: 2.0,
            busy_time: 2.0,
            prefill_tokens: 10,
            end_time: 2.0,
            ..Default::default()
        };
        let rep = ServingReport::from_requests("t".into(), &reqs, &stats);
        assert_eq!(rep.completed, 1);
        assert_eq!(rep.tokens, 10);
        assert_eq!(rep.prefill_tokens, 10);
        assert!((rep.stps - 5.0).abs() < 1e-9);
        assert!((rep.utps_mean - 5.0).abs() < 1e-9);
        assert_eq!(rep.mean_batch, 1.0);
        assert!((rep.ttft.p50 - 0.2).abs() < 1e-12);
        assert!((rep.tpot.p50 - 0.2).abs() < 1e-12); // (2.0 - 0.2) / 9
        assert!((rep.e2e.p99 - 2.0).abs() < 1e-12);
    }

    #[test]
    fn mean_batch_uses_busy_time_not_step_count() {
        let stats = StepStats {
            steps: 2,
            batch_time_integral: 1.0 * 0.1 + 2.0 * 0.2,
            busy_time: 0.3,
            end_time: 0.3,
            ..Default::default()
        };
        let rep = ServingReport::from_requests("t".into(), &[one_request()], &stats);
        assert!((rep.mean_batch - 5.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn empty_report_is_finite_with_zero_throughputs() {
        // Regression (DST seed 1088, a deadline before the first
        // arrival): a run with zero completions used to report NaN
        // utps_p50/utps_p99_low (percentile of no samples) and, with no
        // requests at all, a 1e-12 span (the f64::MAX arrival sentinel
        // leaked into `end_time - first`).
        let rep = ServingReport::from_requests(
            "empty".into(),
            &[],
            &StepStats { end_time: 2.5, ..Default::default() },
        );
        assert_eq!(rep.completed, 0);
        assert_eq!(rep.tokens, 0);
        assert_eq!(rep.utps_p50, 0.0);
        assert_eq!(rep.utps_p99_low, 0.0);
        assert!((rep.span - 2.5).abs() < 1e-12, "span {}", rep.span);
        assert_eq!(rep.stps, 0.0);
        for v in [
            rep.span,
            rep.stps,
            rep.utps_mean,
            rep.utps_p50,
            rep.utps_p99_low,
            rep.queue_delay_mean,
            rep.mean_batch,
            rep.ttft.mean,
            rep.tpot.p99,
            rep.e2e.p50,
        ] {
            assert!(v.is_finite(), "NaN/inf leaked into the empty report");
        }
    }

    #[test]
    fn uncompleted_requests_anchor_the_span_but_not_the_stats() {
        // One offered-but-never-completed request: span still runs from
        // its arrival (the load existed), while every latency stat stays
        // finite and zero-sampled.
        let mut r = one_request();
        r.completed_at = None;
        r.first_token_at = None;
        r.arrival = 0.5;
        let rep = ServingReport::from_requests(
            "t".into(),
            &[r],
            &StepStats { end_time: 2.5, ..Default::default() },
        );
        assert_eq!(rep.completed, 0);
        assert!((rep.span - 2.0).abs() < 1e-12);
        assert_eq!(rep.utps_p50, 0.0);
        assert_eq!(rep.ttft, LatencyStats::zero());
    }

    #[test]
    fn preemption_counters_flow_into_the_report_and_stay_nan_free() {
        // A run where every admitted request was evicted and never
        // restored before the clock ran out: zero completions, non-zero
        // preemption counters. Every float stat must stay finite (the
        // same guards as the zero-completion regression) and the
        // counters must land in the report verbatim.
        let mut r = one_request();
        r.completed_at = None;
        r.first_token_at = None;
        r.generated = 3; // partial progress, evicted mid-decode
        let stats = StepStats {
            steps: 4,
            busy_time: 0.4,
            batch_time_integral: 0.4,
            preemptions: 2,
            restores: 1,
            end_time: 1.0,
            ..Default::default()
        };
        let rep = ServingReport::from_requests("t".into(), &[r], &stats);
        assert_eq!(rep.completed, 0);
        assert_eq!(rep.preemptions, 2);
        assert_eq!(rep.restores, 1);
        for v in [
            rep.span,
            rep.stps,
            rep.utps_mean,
            rep.utps_p50,
            rep.utps_p99_low,
            rep.queue_delay_mean,
            rep.mean_batch,
            rep.ttft.mean,
            rep.ttft.p99,
            rep.tpot.mean,
            rep.tpot.p99,
            rep.e2e.mean,
            rep.e2e.p99,
        ] {
            assert!(v.is_finite(), "NaN/inf in the all-preempted report");
        }
    }

    #[test]
    fn latency_stats_handle_empty_and_render() {
        let mut empty: Vec<f64> = vec![];
        assert_eq!(LatencyStats::from_samples(&mut empty), LatencyStats::zero());
        let s = LatencyStats::from_samples(&mut [0.1, 0.2, 0.3]);
        assert!((s.mean - 0.2).abs() < 1e-12);
        assert_eq!(s.p50, 0.2);
        let rep = ServingReport::from_requests(
            "t".into(),
            &[one_request()],
            &StepStats::default(),
        );
        let slo = rep.slo_summary();
        assert!(slo.contains("TTFT"));
        assert!(slo.contains("TPOT"));
        assert!(slo.contains("E2E"));
    }
}
