//! Serving metrics: per-request latency statistics and system totals.

use super::request::Request;

/// Percentile of a sorted-or-not sample set (nearest-rank).
pub fn percentile(samples: &mut Vec<f64>, p: f64) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (samples.len() as f64 - 1.0)).round() as usize;
    samples[rank.min(samples.len() - 1)]
}

/// Aggregated results of one serving-simulation run.
#[derive(Debug, Clone)]
pub struct ServingReport {
    /// Engine backend name.
    pub engine: String,
    /// Requests completed.
    pub completed: u64,
    /// Total tokens generated.
    pub tokens: u64,
    /// Wall/simulated span from first arrival to last completion, s.
    pub span: f64,
    /// System tokens/second over the span.
    pub stps: f64,
    /// Mean per-user decode throughput (tokens / residence time).
    pub utps_mean: f64,
    /// p50 per-user throughput.
    pub utps_p50: f64,
    /// p99 per-user throughput (worst users).
    pub utps_p99_low: f64,
    /// Mean queueing delay (arrival -> admission), s.
    pub queue_delay_mean: f64,
    /// Steps executed.
    pub steps: u64,
    /// Mean batch occupancy across steps.
    pub mean_batch: f64,
}

impl ServingReport {
    /// Build from completed requests + step accounting.
    pub fn from_requests(
        engine: String,
        reqs: &[Request],
        steps: u64,
        batch_integral: f64,
        end_time: f64,
    ) -> ServingReport {
        let completed: Vec<&Request> =
            reqs.iter().filter(|r| r.completed_at.is_some()).collect();
        let tokens: u64 = completed.iter().map(|r| r.generated).sum();
        let first = reqs.iter().map(|r| r.arrival).fold(f64::MAX, f64::min);
        let span = (end_time - first).max(1e-12);

        let mut utps: Vec<f64> = completed
            .iter()
            .filter_map(|r| {
                let t = r.completed_at? - r.admitted_at?;
                (t > 0.0).then_some(r.generated as f64 / t)
            })
            .collect();
        let utps_mean = if utps.is_empty() {
            0.0
        } else {
            utps.iter().sum::<f64>() / utps.len() as f64
        };
        let mut delays: Vec<f64> = completed
            .iter()
            .filter_map(|r| Some(r.admitted_at? - r.arrival))
            .collect();
        let queue_delay_mean = if delays.is_empty() {
            0.0
        } else {
            delays.iter().sum::<f64>() / delays.len() as f64
        };
        delays.clear();

        ServingReport {
            engine,
            completed: completed.len() as u64,
            tokens,
            span,
            stps: tokens as f64 / span,
            utps_mean,
            utps_p50: percentile(&mut utps, 50.0),
            utps_p99_low: percentile(&mut utps, 1.0),
            queue_delay_mean,
            steps,
            mean_batch: if steps == 0 { 0.0 } else { batch_integral / steps as f64 },
        }
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "{}: {} reqs, {} tokens in {:.2}s -> STPS {:.1}, UTPS mean {:.1} / p50 {:.1}, \
             queue delay {:.3}s, mean batch {:.1}",
            self.engine,
            self.completed,
            self.tokens,
            self.span,
            self.stps,
            self.utps_mean,
            self.utps_p50,
            self.queue_delay_mean,
            self.mean_batch
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let mut v = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&mut v, 50.0), 3.0);
        assert_eq!(percentile(&mut v, 0.0), 1.0);
        assert_eq!(percentile(&mut v, 100.0), 5.0);
        let mut empty: Vec<f64> = vec![];
        assert!(percentile(&mut empty, 50.0).is_nan());
    }

    #[test]
    fn report_computes_throughputs() {
        let reqs = vec![Request {
            id: 0,
            arrival: 0.0,
            context_len: 10,
            gen_len: 10,
            generated: 10,
            admitted_at: Some(0.0),
            completed_at: Some(2.0),
        }];
        let rep = ServingReport::from_requests("t".into(), &reqs, 10, 10.0, 2.0);
        assert_eq!(rep.completed, 1);
        assert_eq!(rep.tokens, 10);
        assert!((rep.stps - 5.0).abs() < 1e-9);
        assert!((rep.utps_mean - 5.0).abs() < 1e-9);
        assert_eq!(rep.mean_batch, 1.0);
    }
}
