//! Step engines: pluggable per-step latency sources for the simulator.

use std::sync::Arc;

use crate::apps::{Application, DecodePoint};
use crate::hw::SystemConfig;
use crate::model::{evaluate, EvalOptions};

/// Something that can price one decode step of a whole batch.
pub trait StepEngine {
    /// Seconds to execute one step with `batch` active sequences whose
    /// longest context is `max_context` tokens.
    fn step_latency(&mut self, batch: u64, max_context: u64) -> f64;

    /// Human-readable backend name (for reports).
    fn name(&self) -> String;
}

/// LIMINAL-priced engine: each step costs the analytical `T_batch` for
/// the *current* batch size and context — the dynamic counterpart of the
/// paper's steady-state tables.
pub struct AnalyticEngine {
    /// Application being served.
    pub app: Arc<dyn Application>,
    /// System serving it.
    pub sys: SystemConfig,
    /// Model options.
    pub opts: EvalOptions,
}

impl AnalyticEngine {
    /// New engine; capacity enforcement is disabled here because the
    /// batcher's KV budget already gates admission (double-gating would
    /// make transient over-admission a hard error instead of pressure).
    pub fn new(app: Arc<dyn Application>, sys: SystemConfig) -> Self {
        let opts = EvalOptions { enforce_capacity: false, ..Default::default() };
        AnalyticEngine { app, sys, opts }
    }
}

impl StepEngine for AnalyticEngine {
    fn step_latency(&mut self, batch: u64, max_context: u64) -> f64 {
        if batch == 0 {
            return 0.0;
        }
        let pt = DecodePoint { batch, context: max_context.max(1) };
        evaluate(self.app.as_ref(), &self.sys, &pt, &self.opts)
            .map(|p| p.lat.t_batch)
            .unwrap_or(f64::INFINITY)
    }

    fn name(&self) -> String {
        format!("analytic({} on {})", self.app.name(), self.sys.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::Registry;
    use crate::hw::presets;

    #[test]
    fn analytic_step_latency_matches_model() {
        let app = Registry::builtin().app("llama3-70b").unwrap();
        let sys = SystemConfig::new(presets::hbm3(), 8, 1);
        let mut eng = AnalyticEngine::new(app.clone(), sys.clone());
        let lat = eng.step_latency(1, 4096);
        // Table 2: 486 UTPS -> ~2.06 ms/token.
        assert!((1.0 / lat - 486.0).abs() < 10.0, "utps {}", 1.0 / lat);
        // Larger batch, longer step.
        assert!(eng.step_latency(32, 4096) > lat);
        // Idle batch costs nothing.
        assert_eq!(eng.step_latency(0, 4096), 0.0);
    }
}
