//! Step engines: pluggable per-step latency sources for the simulator.

use std::sync::Arc;

use crate::apps::{Application, DecodePoint, PrefillPoint};
use crate::hw::SystemConfig;
use crate::model::{evaluate, evaluate_workload, EvalOptions};

/// Composition of one engine step: decode lanes each emitting one
/// token, plus (optionally) a chunk of prompt tokens being prefilled in
/// the same fused step — the chunked-prefill mixing production engines
/// do to keep decode latency bounded while prompts are ingested.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct StepBatch {
    /// Sequences in decode this step (one token each).
    pub decode_batch: u64,
    /// Longest decode sequence's KV length (drives attention cost).
    pub max_context: u64,
    /// Sequences receiving prefill work this step (the planner
    /// schedules at most one prefill chunk per step, so 0 or 1).
    pub prefill_seqs: u64,
    /// New prompt tokens prefilled this step.
    pub prefill_tokens: u64,
    /// Already-cached prefix of the prefilling sequence (earlier chunks
    /// the attention must re-read).
    pub prefill_past: u64,
}

impl StepBatch {
    /// A pure decode step (the legacy path).
    pub fn decode_only(batch: u64, max_context: u64) -> StepBatch {
        StepBatch { decode_batch: batch, max_context, ..Default::default() }
    }

    /// Active lanes this step (decode + prefilling sequences), the
    /// occupancy the batch-size statistics track.
    pub fn lanes(&self) -> u64 {
        self.decode_batch + self.prefill_seqs
    }

    /// Whether the step has no work at all.
    pub fn is_empty(&self) -> bool {
        self.decode_batch == 0 && self.prefill_tokens == 0
    }
}

/// Something that can price one step of a whole batch.
pub trait StepEngine {
    /// Seconds to execute one pure-decode step with `batch` active
    /// sequences whose longest context is `max_context` tokens.
    fn step_latency(&mut self, batch: u64, max_context: u64) -> f64;

    /// Seconds to execute a mixed prefill + decode step.
    ///
    /// The default covers engines without a prefill model (fixed-cost
    /// test engines, the PJRT executor): prefilling sequences are priced
    /// as additional decode lanes at the deepest cache depth in the
    /// step. Engines with a real prefill model (the analytic backend)
    /// override this.
    fn mixed_step_latency(&mut self, step: &StepBatch) -> f64 {
        if step.prefill_tokens == 0 {
            self.step_latency(step.decode_batch, step.max_context)
        } else {
            self.step_latency(
                step.decode_batch + step.prefill_seqs,
                step.max_context.max(step.prefill_past + 1),
            )
        }
    }

    /// Human-readable backend name (for reports).
    fn name(&self) -> String;
}

/// Forwarding impl so a simulator can drive a *borrowed* engine through
/// the same `Box<dyn StepEngine + '_>` storage an owned engine uses
/// ([`ServingSim`](super::ServingSim) borrows its engine, the cluster
/// owns one per instance). `mixed_step_latency` is forwarded explicitly:
/// relying on the trait default here would silently bypass an engine's
/// own override (the analytic backend's fused prefill+decode pricing).
impl<E: StepEngine + ?Sized> StepEngine for &mut E {
    fn step_latency(&mut self, batch: u64, max_context: u64) -> f64 {
        (**self).step_latency(batch, max_context)
    }

    fn mixed_step_latency(&mut self, step: &StepBatch) -> f64 {
        (**self).mixed_step_latency(step)
    }

    fn name(&self) -> String {
        (**self).name()
    }
}

/// LIMINAL-priced engine: each step costs the analytical `T_batch` for
/// the *current* batch size and context — the dynamic counterpart of the
/// paper's steady-state tables.
pub struct AnalyticEngine {
    /// Application being served.
    pub app: Arc<dyn Application>,
    /// System serving it.
    pub sys: SystemConfig,
    /// Model options.
    pub opts: EvalOptions,
}

impl AnalyticEngine {
    /// New engine; capacity enforcement is disabled here because the
    /// batcher's KV budget already gates admission (double-gating would
    /// make transient over-admission a hard error instead of pressure).
    pub fn new(app: Arc<dyn Application>, sys: SystemConfig) -> Self {
        let opts = EvalOptions { enforce_capacity: false, ..Default::default() };
        AnalyticEngine { app, sys, opts }
    }
}

impl StepEngine for AnalyticEngine {
    fn step_latency(&mut self, batch: u64, max_context: u64) -> f64 {
        if batch == 0 {
            return 0.0;
        }
        let pt = DecodePoint { batch, context: max_context.max(1) };
        evaluate(self.app.as_ref(), &self.sys, &pt, &self.opts)
            .map(|p| p.lat.t_batch)
            .unwrap_or(f64::INFINITY)
    }

    /// Fused pricing: the prefill chunk's ops and traffic are added to
    /// the decode batch's, weights stream once for the whole fused step,
    /// and the roofline + exposure is taken over the combined workload.
    /// The chunk is one prompt's token stream (`batch = 1`, total
    /// tokens at `prefill_past` depth) — exact, because the planner
    /// schedules at most one prefill chunk per step.
    fn mixed_step_latency(&mut self, step: &StepBatch) -> f64 {
        if step.is_empty() {
            return 0.0;
        }
        if step.prefill_tokens == 0 {
            return self.step_latency(step.decode_batch, step.max_context);
        }
        let ppt = PrefillPoint {
            batch: 1,
            new_tokens: step.prefill_tokens,
            past_tokens: step.prefill_past,
        };
        let mut wl = self.app.prefill_workload(&ppt);
        if step.decode_batch > 0 {
            let dp = DecodePoint {
                batch: step.decode_batch,
                context: step.max_context.max(1),
            };
            let dwl = self.app.workload(&dp);
            wl.ops = wl.ops.add(dwl.ops);
            wl.traffic = wl.traffic.fuse(dwl.traffic);
        }
        let dp = DecodePoint {
            batch: step.lanes().max(1),
            context: step.max_context.max(step.prefill_past + step.prefill_tokens),
        };
        evaluate_workload(&wl, &self.sys, &dp, &self.opts, 0.0).lat.t_batch
    }

    fn name(&self) -> String {
        format!("analytic({} on {})", self.app.name(), self.sys.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::Registry;
    use crate::hw::presets;

    #[test]
    fn analytic_step_latency_matches_model() {
        let app = Registry::builtin().app("llama3-70b").unwrap();
        let sys = SystemConfig::new(presets::hbm3(), 8, 1);
        let mut eng = AnalyticEngine::new(app.clone(), sys.clone());
        let lat = eng.step_latency(1, 4096);
        // Table 2: 486 UTPS -> ~2.06 ms/token.
        assert!((1.0 / lat - 486.0).abs() < 10.0, "utps {}", 1.0 / lat);
        // Larger batch, longer step.
        assert!(eng.step_latency(32, 4096) > lat);
        // Idle batch costs nothing.
        assert_eq!(eng.step_latency(0, 4096), 0.0);
    }

    #[test]
    fn mixed_step_prices_prefill_on_top_of_decode() {
        let app = Registry::builtin().app("llama3-70b").unwrap();
        let sys = SystemConfig::new(presets::hbm3(), 8, 1);
        let mut eng = AnalyticEngine::new(app, sys);

        let decode_only = eng.mixed_step_latency(&StepBatch::decode_only(4, 4096));
        assert_eq!(decode_only, eng.step_latency(4, 4096));

        let mixed = eng.mixed_step_latency(&StepBatch {
            decode_batch: 4,
            max_context: 4096,
            prefill_seqs: 1,
            prefill_tokens: 1024,
            prefill_past: 0,
        });
        // A 1K-token chunk is heavy compute: the fused step costs
        // clearly more than decode alone, but less than pricing the
        // chunk as 1024 separate decode steps would.
        assert!(mixed > decode_only * 1.5, "{mixed} vs {decode_only}");
        assert!(mixed < decode_only * 100.0);

        // Pure prefill step works too.
        let pure = eng.mixed_step_latency(&StepBatch {
            decode_batch: 0,
            max_context: 0,
            prefill_seqs: 1,
            prefill_tokens: 1024,
            prefill_past: 0,
        });
        assert!(pure > 0.0 && pure.is_finite());

        // Empty step is free.
        assert_eq!(eng.mixed_step_latency(&StepBatch::default()), 0.0);
    }

    /// A constant-latency engine exercising the default mixed pricing.
    struct Fixed(f64);
    impl StepEngine for Fixed {
        fn step_latency(&mut self, batch: u64, _ctx: u64) -> f64 {
            if batch == 0 {
                0.0
            } else {
                self.0
            }
        }
        fn name(&self) -> String {
            "fixed".into()
        }
    }

    #[test]
    fn borrowed_engines_forward_the_mixed_override() {
        // `&mut AnalyticEngine` must price mixed steps through the
        // analytic override, not the trait default (which would treat
        // the chunk as extra decode lanes and grossly underprice it).
        let app = Registry::builtin().app("llama3-70b").unwrap();
        let sys = SystemConfig::new(presets::hbm3(), 8, 1);
        let mut eng = AnalyticEngine::new(app, sys);
        let step = StepBatch {
            decode_batch: 4,
            max_context: 4096,
            prefill_seqs: 1,
            prefill_tokens: 1024,
            prefill_past: 0,
        };
        let direct = eng.mixed_step_latency(&step);
        let direct_name = eng.name();
        let borrowed: &mut dyn StepEngine = &mut eng;
        let mut boxed: Box<dyn StepEngine + '_> = Box::new(borrowed);
        assert_eq!(boxed.mixed_step_latency(&step), direct);
        assert_eq!(boxed.name(), direct_name);
    }

    #[test]
    fn default_mixed_latency_treats_prefill_as_extra_lanes() {
        let mut eng = Fixed(0.25);
        let dt = eng.mixed_step_latency(&StepBatch {
            decode_batch: 0,
            max_context: 0,
            prefill_seqs: 2,
            prefill_tokens: 64,
            prefill_past: 0,
        });
        assert_eq!(dt, 0.25);
        assert_eq!(eng.mixed_step_latency(&StepBatch::decode_only(3, 100)), 0.25);
    }
}
