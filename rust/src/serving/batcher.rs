//! Continuous batcher: admission queue + KV-capacity gate.
//!
//! The admission policy mirrors the paper's capacity story: a request is
//! admitted only if its KV cache (context + full generation budget) fits
//! in the remaining memory after weights, and the active batch stays
//! under the configured cap. FIFO order; no preemption (requests run to
//! completion, as in the paper's steady-state analysis).

use std::collections::VecDeque;

use super::request::Request;

/// KV-capacity accounting for one model instance on one system.
#[derive(Debug, Clone)]
pub struct KvBudget {
    /// Bytes available for KV cache (system capacity - weights).
    pub budget_bytes: f64,
    /// KV bytes per token (all layers).
    pub bytes_per_token: f64,
    used_bytes: f64,
}

impl KvBudget {
    /// New budget; panics if the weights alone exceed capacity.
    pub fn new(total_capacity: f64, weight_bytes: f64, bytes_per_token: f64) -> Self {
        assert!(
            total_capacity >= weight_bytes,
            "weights ({:.1} GiB) exceed capacity ({:.1} GiB)",
            weight_bytes / crate::GIB,
            total_capacity / crate::GIB
        );
        KvBudget {
            budget_bytes: total_capacity - weight_bytes,
            bytes_per_token,
            used_bytes: 0.0,
        }
    }

    /// Bytes a request will occupy at its maximum sequence length.
    pub fn bytes_for(&self, r: &Request) -> f64 {
        (r.context_len + r.gen_len) as f64 * self.bytes_per_token
    }

    /// Try to reserve space for a request.
    pub fn reserve(&mut self, r: &Request) -> bool {
        let need = self.bytes_for(r);
        if self.used_bytes + need <= self.budget_bytes {
            self.used_bytes += need;
            true
        } else {
            false
        }
    }

    /// Release a completed request's reservation.
    pub fn release(&mut self, r: &Request) {
        self.used_bytes = (self.used_bytes - self.bytes_for(r)).max(0.0);
    }

    /// Current utilization fraction.
    pub fn utilization(&self) -> f64 {
        if self.budget_bytes == 0.0 {
            1.0
        } else {
            self.used_bytes / self.budget_bytes
        }
    }
}

/// FIFO continuous batcher.
pub struct Batcher {
    /// Maximum concurrent sequences (compiled bucket size or policy cap).
    pub max_batch: usize,
    queue: VecDeque<Request>,
    active: Vec<Request>,
    kv: KvBudget,
}

impl Batcher {
    /// New batcher over a KV budget.
    pub fn new(max_batch: usize, kv: KvBudget) -> Self {
        assert!(max_batch >= 1);
        Batcher { max_batch, queue: VecDeque::new(), active: Vec::new(), kv }
    }

    /// Enqueue an arriving request.
    pub fn enqueue(&mut self, r: Request) {
        self.queue.push_back(r);
    }

    /// Admit as many queued requests as fit (called at step boundaries).
    /// Returns how many were admitted; sets their `admitted_at`.
    pub fn admit(&mut self, now: f64) -> usize {
        let mut n = 0;
        while self.active.len() < self.max_batch {
            let Some(front) = self.queue.front() else { break };
            if !self.kv.reserve(front) {
                break; // FIFO head-of-line: preserve arrival order
            }
            let mut r = self.queue.pop_front().unwrap();
            r.admitted_at = Some(now);
            self.active.push(r);
            n += 1;
        }
        n
    }

    /// One generation step for the whole active batch: every active
    /// request yields a token; completed ones are retired. Returns the
    /// retired requests (stamped with `completed_at`).
    pub fn step_complete(&mut self, now: f64) -> Vec<Request> {
        let mut done = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            self.active[i].generated += 1;
            if self.active[i].done() {
                let mut r = self.active.swap_remove(i);
                r.completed_at = Some(now);
                self.kv.release(&r);
                done.push(r);
            } else {
                i += 1;
            }
        }
        done
    }

    /// Active batch size.
    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    /// Queued (not yet admitted) requests.
    pub fn queued_len(&self) -> usize {
        self.queue.len()
    }

    /// Longest active sequence length (drives attention cost).
    pub fn max_seq_len(&self) -> u64 {
        self.active.iter().map(|r| r.seq_len()).max().unwrap_or(0)
    }

    /// Mean active sequence length.
    pub fn mean_seq_len(&self) -> f64 {
        if self.active.is_empty() {
            0.0
        } else {
            self.active.iter().map(|r| r.seq_len()).sum::<u64>() as f64
                / self.active.len() as f64
        }
    }

    /// KV budget utilization.
    pub fn kv_utilization(&self) -> f64 {
        self.kv.utilization()
    }

    /// Whether everything is drained.
    pub fn idle(&self) -> bool {
        self.queue.is_empty() && self.active.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, ctx: u64, gen: u64) -> Request {
        Request {
            id,
            arrival: 0.0,
            context_len: ctx,
            gen_len: gen,
            generated: 0,
            admitted_at: None,
            completed_at: None,
        }
    }

    fn budget(tokens: u64) -> KvBudget {
        KvBudget::new(tokens as f64, 0.0, 1.0)
    }

    #[test]
    fn admits_up_to_batch_cap() {
        let mut b = Batcher::new(2, budget(1_000_000));
        for i in 0..5 {
            b.enqueue(req(i, 10, 5));
        }
        assert_eq!(b.admit(0.0), 2);
        assert_eq!(b.active_len(), 2);
        assert_eq!(b.queued_len(), 3);
    }

    #[test]
    fn kv_budget_gates_admission() {
        // Budget holds one request of (10 ctx + 5 gen) = 15 tokens.
        let mut b = Batcher::new(8, budget(20));
        b.enqueue(req(0, 10, 5));
        b.enqueue(req(1, 10, 5));
        assert_eq!(b.admit(0.0), 1);
        // Retire the first; second then fits.
        for _ in 0..5 {
            b.step_complete(1.0);
        }
        assert_eq!(b.admit(1.0), 1);
    }

    #[test]
    fn steps_retire_completed_requests() {
        let mut b = Batcher::new(4, budget(1000));
        b.enqueue(req(0, 10, 2));
        b.enqueue(req(1, 10, 3));
        b.admit(0.0);
        assert!(b.step_complete(0.1).is_empty());
        let done = b.step_complete(0.2);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, 0);
        let done = b.step_complete(0.3);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, 1);
        assert!(b.idle());
    }

    #[test]
    fn kv_is_released_on_completion() {
        let mut b = Batcher::new(4, budget(15));
        b.enqueue(req(0, 10, 2));
        b.admit(0.0);
        assert!(b.kv_utilization() > 0.7);
        b.step_complete(0.1);
        b.step_complete(0.2);
        assert_eq!(b.kv_utilization(), 0.0);
    }

    #[test]
    #[should_panic(expected = "exceed capacity")]
    fn weights_larger_than_capacity_panic() {
        KvBudget::new(10.0, 20.0, 1.0);
    }
}
