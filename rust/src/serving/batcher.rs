//! Continuous batcher: priority admission queue, KV-capacity gate,
//! preemption under capacity pressure, and the prefill-chunk planner.
//!
//! The admission policy mirrors the paper's capacity story: a request is
//! admitted only if its KV cache (context + full generation budget) fits
//! in the remaining memory after weights, and the active batch stays
//! under the configured cap. Admission is by **priority class**
//! ([`Request::priority`], higher first), with FIFO order inside a
//! class; a single-class workload therefore degrades to exactly the
//! historical FIFO batcher (the regression pins rely on this). Head-of-
//! line semantics are preserved per selection: if the chosen request's
//! KV does not fit, admission stalls — the batcher never skips past it
//! to a smaller request.
//!
//! With preemption enabled ([`PreemptionConfig`]), a selected request
//! whose KV does not fit may instead **evict** active victims of a
//! strictly lower class: the victim's KV reservation is released
//! immediately (its decode/prefill progress is kept), it re-enters the
//! queue at the front, and the configured evict cost is charged to the
//! next engine step. When an evicted request is later re-admitted, its
//! KV must be re-materialized, charging the restore cost the same way.
//! Victims are the lowest class first, most recently admitted first
//! within a class, and eviction only proceeds when enough strictly-
//! lower-class KV exists to actually fit the candidate (no fruitless
//! churn). With a single class — or preemption disabled, the default —
//! no eviction can ever trigger and the step-time penalty is exactly
//! `0.0`, so the disabled path is bit-identical to the FIFO batcher.
//!
//! With a prefill chunk configured ([`Batcher::with_prefill`]), an
//! admitted request first has its prompt ingested in chunks of at most
//! `prefill_chunk` tokens per engine step ([`Batcher::plan_step`]),
//! sharing steps with decode-ready lanes; the final chunk's forward
//! pass emits the first output token. With the chunk set to 0 (legacy
//! mode) prompts are assumed prefilled elsewhere — the paper's
//! disaggregated decode-only focus — and requests enter decode
//! directly.
//!
//! Request state lives in the caller's [`RequestArena`]; the batcher's
//! queue, active set, and retirement buffer hold dense [`ReqId`]s only,
//! so admitting, planning, and completing steps never move or clone a
//! `Request`. The retirement buffer is reused across steps
//! ([`Batcher::step_complete`] returns a borrowed slice), keeping
//! steady-state stepping allocation-free.

use std::collections::VecDeque;

use super::arena::{ReqId, RequestArena};
use super::engine::StepBatch;
use super::request::Request;

/// KV-capacity accounting for one model instance on one system.
#[derive(Debug, Clone)]
pub struct KvBudget {
    /// Bytes available for KV cache (system capacity - weights).
    pub budget_bytes: f64,
    /// KV bytes per token (all layers).
    pub bytes_per_token: f64,
    used_bytes: f64,
}

impl KvBudget {
    /// New budget; panics if the weights alone exceed capacity.
    pub fn new(total_capacity: f64, weight_bytes: f64, bytes_per_token: f64) -> Self {
        assert!(
            total_capacity >= weight_bytes,
            "weights ({:.1} GiB) exceed capacity ({:.1} GiB)",
            weight_bytes / crate::GIB,
            total_capacity / crate::GIB
        );
        KvBudget {
            budget_bytes: total_capacity - weight_bytes,
            bytes_per_token,
            used_bytes: 0.0,
        }
    }

    /// Bytes a request will occupy at its maximum sequence length.
    pub fn bytes_for(&self, r: &Request) -> f64 {
        (r.context_len + r.gen_len) as f64 * self.bytes_per_token
    }

    /// Try to reserve space for a request.
    pub fn reserve(&mut self, r: &Request) -> bool {
        let need = self.bytes_for(r);
        if self.used_bytes + need <= self.budget_bytes {
            self.used_bytes += need;
            true
        } else {
            false
        }
    }

    /// Release a completed request's reservation.
    pub fn release(&mut self, r: &Request) {
        self.used_bytes = (self.used_bytes - self.bytes_for(r)).max(0.0);
    }

    /// KV bytes currently reserved by admitted requests.
    pub fn used_bytes(&self) -> f64 {
        self.used_bytes
    }

    /// Current utilization fraction.
    pub fn utilization(&self) -> f64 {
        if self.budget_bytes == 0.0 {
            1.0
        } else {
            self.used_bytes / self.budget_bytes
        }
    }
}

/// Preemption policy for a [`Batcher`]: whether a higher-priority
/// request may evict a lower one's KV under capacity pressure, and
/// what the KV traffic costs in engine-step seconds. The default is
/// disabled with zero costs, which is bit-identical to the
/// run-to-completion batcher.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PreemptionConfig {
    /// Allow KV eviction of strictly-lower-priority active requests.
    pub enabled: bool,
    /// Seconds of step time charged per eviction (writing the victim's
    /// KV out / dropping and bookkeeping it).
    pub evict_cost: f64,
    /// Seconds of step time charged when an evicted request is
    /// re-admitted (re-materializing its KV).
    pub restore_cost: f64,
}

impl Default for PreemptionConfig {
    fn default() -> Self {
        PreemptionConfig { enabled: false, evict_cost: 0.0, restore_cost: 0.0 }
    }
}

/// A scheduling action the batcher logged during admission, drained by
/// the simulator after each step boundary and forwarded to its
/// [`SimObserver`](super::SimObserver) — the DST invariant checker
/// audits the preempted lifecycle through these.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedAction {
    /// The request's KV was evicted; it re-entered the queue.
    Preempt,
    /// A previously evicted request was re-admitted.
    Restore,
}

/// Priority continuous batcher over arena-resident requests (FIFO
/// within a class; single-class workloads degrade to exact FIFO).
pub struct Batcher {
    /// Maximum concurrent sequences (compiled bucket size or policy cap).
    pub max_batch: usize,
    queue: VecDeque<ReqId>,
    active: Vec<ReqId>,
    kv: KvBudget,
    /// Max prefill tokens ingested per engine step (0 = prefill served
    /// elsewhere; requests enter decode directly).
    prefill_chunk: u64,
    /// Total prompt tokens this batcher has prefilled.
    prefill_processed: u64,
    /// Retirement buffer, reused across steps so completing a step
    /// allocates nothing in steady state.
    retired: Vec<ReqId>,
    /// Preemption policy (default: disabled, the FIFO-exact path).
    preempt: PreemptionConfig,
    /// Queued requests with a non-zero priority class. While 0 the
    /// selection fast path is the plain FIFO front, so all-class-0
    /// workloads pay no scan.
    queued_hi: usize,
    /// Requests whose KV was evicted and not yet re-admitted. Small by
    /// construction (bounded by evictions in flight), so membership is
    /// a linear scan.
    evicted_pending: Vec<ReqId>,
    /// Step-time penalty accumulated by evictions/restores since the
    /// last priced step; [`Batcher::take_step_penalty`] drains it into
    /// the next step's latency. Exactly 0.0 unless preemption fired.
    step_penalty: f64,
    /// Total evictions.
    preemptions: u64,
    /// Total re-admissions of evicted requests.
    restores: u64,
    /// Preempt/restore actions since the simulator last drained them.
    sched_log: Vec<(ReqId, SchedAction)>,
}

impl Batcher {
    /// New decode-only batcher over a KV budget (prompts are assumed
    /// prefilled elsewhere, the paper's disaggregated assumption).
    pub fn new(max_batch: usize, kv: KvBudget) -> Self {
        assert!(max_batch >= 1);
        Batcher {
            max_batch,
            queue: VecDeque::new(),
            active: Vec::new(),
            kv,
            prefill_chunk: 0,
            prefill_processed: 0,
            retired: Vec::new(),
            preempt: PreemptionConfig::default(),
            queued_hi: 0,
            evicted_pending: Vec::new(),
            step_penalty: 0.0,
            preemptions: 0,
            restores: 0,
            sched_log: Vec::new(),
        }
    }

    /// New prefill-aware batcher: admitted prompts are ingested in
    /// chunks of at most `chunk_tokens` per step before decoding.
    /// `chunk_tokens = 0` degrades to the decode-only mode of
    /// [`Batcher::new`], so callers can thread a single configuration
    /// value through.
    pub fn with_prefill(max_batch: usize, kv: KvBudget, chunk_tokens: u64) -> Self {
        let mut b = Batcher::new(max_batch, kv);
        b.prefill_chunk = chunk_tokens;
        b
    }

    /// Set the preemption policy (builder-style; see
    /// [`PreemptionConfig`]). The cluster simulator threads one config
    /// to every instance it builds or spawns.
    pub fn set_preemption(&mut self, cfg: PreemptionConfig) {
        self.preempt = cfg;
    }

    /// The active preemption policy.
    pub fn preemption(&self) -> PreemptionConfig {
        self.preempt
    }

    /// Enqueue an arriving request by id. The arena reference lets the
    /// batcher note the request's priority class, keeping the all-
    /// class-0 selection on the O(1) FIFO fast path.
    pub fn enqueue(&mut self, id: ReqId, arena: &RequestArena) {
        if arena[id].priority > 0 {
            self.queued_hi += 1;
        }
        self.queue.push_back(id);
    }

    /// The queue position the next admission should take: the highest
    /// priority class, earliest-queued within the class. With no
    /// non-zero class queued this is the plain FIFO front (O(1)); the
    /// scan only runs for genuinely mixed queues.
    fn next_admission(&self, arena: &RequestArena) -> Option<usize> {
        if self.queue.is_empty() {
            return None;
        }
        if self.queued_hi == 0 {
            return Some(0);
        }
        let mut best: Option<(usize, u8)> = None;
        for (i, &id) in self.queue.iter().enumerate() {
            let p = arena[id].priority;
            match best {
                // Strictly-greater keeps the earliest index on ties:
                // FIFO within a class.
                Some((_, bp)) if bp >= p => {}
                _ => best = Some((i, p)),
            }
        }
        best.map(|(i, _)| i)
    }

    /// Evict active victims of a strictly lower class than
    /// `cand_priority` until `need` KV bytes fit, lowest class first
    /// and most recently admitted first within a class. Victims keep
    /// their token progress; their reservation is released immediately,
    /// they re-enter the queue front (so they resume before same-class
    /// arrivals still waiting), and each eviction charges
    /// `evict_cost` to the next step. Returns how many victims were
    /// pushed onto the queue front — 0 when eviction could not free
    /// enough (in which case nothing is evicted at all: no fruitless
    /// churn).
    fn preempt_for(
        &mut self,
        cand_priority: u8,
        need: f64,
        arena: &mut RequestArena,
    ) -> usize {
        let evictable: f64 = self
            .active
            .iter()
            .filter(|&&v| arena[v].priority < cand_priority)
            .map(|&v| self.kv.bytes_for(&arena[v]))
            .sum();
        if self.kv.used_bytes() - evictable + need > self.kv.budget_bytes {
            return 0;
        }
        let mut evicted = 0;
        while self.kv.used_bytes() + need > self.kv.budget_bytes {
            let mut victim: Option<(usize, u8)> = None;
            for (i, &v) in self.active.iter().enumerate() {
                let p = arena[v].priority;
                if p >= cand_priority {
                    continue;
                }
                match victim {
                    // `<` updates on ties too: the most recently
                    // admitted of the lowest class goes first, so the
                    // oldest within-class work is disturbed last.
                    Some((_, vp)) if vp < p => {}
                    _ => victim = Some((i, p)),
                }
            }
            let Some((vi, _)) = victim else { break };
            // `remove`, not `swap_remove`: the active list's order is
            // the admission FIFO the prefill planner relies on.
            let vid = self.active.remove(vi);
            self.kv.release(&arena[vid]);
            arena[vid].scheduled_prefill = 0;
            if arena[vid].priority > 0 {
                self.queued_hi += 1;
            }
            self.queue.push_front(vid);
            self.evicted_pending.push(vid);
            self.step_penalty += self.preempt.evict_cost;
            self.preemptions += 1;
            self.sched_log.push((vid, SchedAction::Preempt));
            evicted += 1;
        }
        evicted
    }

    /// Admit as many queued requests as fit, highest priority class
    /// first (FIFO within a class — with a single class this is the
    /// exact historical FIFO admission). The simulator calls this
    /// only at step boundaries: a request arriving mid-step must wait
    /// for the in-flight step to finish before it can join (it never
    /// rides a step it was not priced into).
    ///
    /// If the selected request's KV does not fit, admission stalls
    /// (head-of-line, never skipping to a smaller request) — unless
    /// preemption is enabled and enough strictly-lower-class KV is
    /// active, in which case victims are evicted via
    /// [`Batcher::preempt_for`] and the admission proceeds.
    ///
    /// Returns how many were admitted; sets their `admitted_at` unless
    /// an earlier admission already stamped it (a disaggregated request
    /// re-admitted at the decode pool keeps its first admission, so
    /// queue-delay and residence metrics span the whole lifecycle).
    pub fn admit(&mut self, now: f64, arena: &mut RequestArena) -> usize {
        let mut n = 0;
        while self.active.len() < self.max_batch {
            let Some(mut pos) = self.next_admission(arena) else { break };
            let id = self.queue[pos];
            if !self.kv.reserve(&arena[id]) {
                if !self.preempt.enabled {
                    break; // head-of-line: stall for the selection
                }
                let need = self.kv.bytes_for(&arena[id]);
                let evicted =
                    self.preempt_for(arena[id].priority, need, arena);
                if evicted == 0 || !self.kv.reserve(&arena[id]) {
                    break;
                }
                // Victims were pushed onto the queue front, shifting
                // the candidate's position.
                pos += evicted;
            }
            self.queue.remove(pos);
            if arena[id].priority > 0 {
                self.queued_hi -= 1;
            }
            if let Some(i) = self.evicted_pending.iter().position(|&e| e == id)
            {
                // Re-admitting an evicted request re-materializes its
                // KV: charge the restore cost to the next step.
                self.evicted_pending.swap_remove(i);
                self.step_penalty += self.preempt.restore_cost;
                self.restores += 1;
                self.sched_log.push((id, SchedAction::Restore));
            }
            let r = &mut arena[id];
            if r.admitted_at.is_none() {
                r.admitted_at = Some(now);
            }
            if self.prefill_chunk == 0 {
                // Legacy decode-only mode: the prompt is already in the
                // KV cache when the request reaches us.
                r.prefilled = r.context_len;
            }
            self.active.push(id);
            n += 1;
        }
        n
    }

    /// Drain the evict/restore step-time penalty accumulated since the
    /// last priced step. Exactly `0.0` unless preemption fired, so
    /// adding it to an engine latency is a bitwise no-op on the
    /// disabled path.
    pub fn take_step_penalty(&mut self) -> f64 {
        std::mem::take(&mut self.step_penalty)
    }

    /// Move the preempt/restore actions logged since the last drain
    /// into `out` (cleared first). The simulators forward these to
    /// their observer after each step boundary.
    pub fn drain_sched_log(&mut self, out: &mut Vec<(ReqId, SchedAction)>) {
        out.clear();
        out.append(&mut self.sched_log);
    }

    /// Plan the next engine step: every decode-ready lane emits one
    /// token, and the *oldest* prefilling request (admission FIFO)
    /// receives one chunk of up to `prefill_chunk` prompt tokens —
    /// Sarathi-style, at most one prefill chunk per step. Restricting a
    /// step to a single prompt's chunk keeps the engine's
    /// `(prefill_tokens, prefill_past)` description of the chunk exact
    /// (mixing two prompts' chunks would conflate their attention
    /// depths).
    pub fn plan_step(&mut self, arena: &mut RequestArena) -> StepBatch {
        let mut step = StepBatch::default();
        let mut budget = self.prefill_chunk;
        for &id in &self.active {
            let r = &mut arena[id];
            if r.in_prefill() {
                let take = r.prefill_remaining().min(budget);
                r.scheduled_prefill = take;
                if take > 0 {
                    budget = 0; // one prefill chunk per step
                    step.prefill_seqs += 1;
                    step.prefill_tokens += take;
                    step.prefill_past = r.prefilled;
                }
            } else {
                r.scheduled_prefill = 0;
                step.decode_batch += 1;
                step.max_context = step.max_context.max(r.seq_len());
            }
        }
        step
    }

    /// Complete the step planned by [`Batcher::plan_step`]: prefilling
    /// lanes advance by their scheduled chunk (the final chunk emits the
    /// first output token); decode lanes each gain one token; finished
    /// requests are retired. Returns the retired ids (their requests
    /// are stamped with `completed_at` in the arena); the slice borrows
    /// the batcher's reusable retirement buffer and is valid until the
    /// next `step_complete` call.
    pub fn step_complete(&mut self, now: f64, arena: &mut RequestArena) -> &[ReqId] {
        self.retired.clear();
        let mut i = 0;
        while i < self.active.len() {
            let id = self.active[i];
            let done = {
                let r = &mut arena[id];
                if r.scheduled_prefill > 0 {
                    self.prefill_processed += r.scheduled_prefill;
                    r.prefilled += r.scheduled_prefill;
                    r.scheduled_prefill = 0;
                    if !r.in_prefill() {
                        // The last prefill chunk's forward pass produces
                        // the first generated token.
                        r.generated += 1;
                        r.first_token_at = Some(now);
                    }
                } else if !r.in_prefill() {
                    r.generated += 1;
                    if r.first_token_at.is_none() {
                        r.first_token_at = Some(now);
                    }
                }
                // else: prefilling but received no budget this step — waits.
                if r.done() {
                    r.completed_at = Some(now);
                    true
                } else {
                    false
                }
            };
            if done {
                // `remove`, not `swap_remove`: the active list's order is
                // the admission FIFO that plan_step's prefill scheduling
                // relies on (it's a memmove of 4-byte ids, not requests).
                self.active.remove(i);
                self.kv.release(&arena[id]);
                self.retired.push(id);
            } else {
                i += 1;
            }
        }
        &self.retired
    }

    /// Active batch size (decode + prefilling lanes).
    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    /// Queued (not yet admitted) requests.
    pub fn queued_len(&self) -> usize {
        self.queue.len()
    }

    /// Requests whose prompts are not yet fully ingested: everything
    /// still queued plus active lanes in prefill. Since the planner
    /// issues at most one chunk to one prompt per step, this is a lower
    /// bound on the steps needed to drain the prompt backlog.
    pub fn prefill_backlog(&self, arena: &RequestArena) -> usize {
        self.queue.len()
            + self.active.iter().filter(|&&id| arena[id].in_prefill()).count()
    }

    /// Longest active sequence length (drives attention cost).
    pub fn max_seq_len(&self, arena: &RequestArena) -> u64 {
        self.active.iter().map(|&id| arena[id].seq_len()).max().unwrap_or(0)
    }

    /// Mean active sequence length.
    pub fn mean_seq_len(&self, arena: &RequestArena) -> f64 {
        if self.active.is_empty() {
            0.0
        } else {
            self.active.iter().map(|&id| arena[id].seq_len()).sum::<u64>() as f64
                / self.active.len() as f64
        }
    }

    /// KV budget utilization.
    pub fn kv_utilization(&self) -> f64 {
        self.kv.utilization()
    }

    /// KV bytes per token of the underlying budget (drives the
    /// routed-footprint accounting and KV-shipment sizing).
    pub fn kv_bytes_per_token(&self) -> f64 {
        self.kv.bytes_per_token
    }

    /// KV bytes currently reserved by the active batch.
    pub fn kv_used_bytes(&self) -> f64 {
        self.kv.used_bytes()
    }

    /// Total KV bytes the budget may reserve.
    pub fn kv_budget_bytes(&self) -> f64 {
        self.kv.budget_bytes
    }

    /// Configured prefill chunk (0 = decode-only mode).
    pub fn prefill_chunk(&self) -> u64 {
        self.prefill_chunk
    }

    /// Total prompt tokens prefilled so far.
    pub fn prefill_tokens_processed(&self) -> u64 {
        self.prefill_processed
    }

    /// Total evictions performed so far.
    pub fn preemptions(&self) -> u64 {
        self.preemptions
    }

    /// Total re-admissions of previously evicted requests.
    pub fn restores(&self) -> u64 {
        self.restores
    }

    /// Requests currently evicted and awaiting re-admission.
    pub fn evicted_pending_len(&self) -> usize {
        self.evicted_pending.len()
    }

    /// Sum of the KV footprints of the active batch. Because a
    /// request's footprint is constant over its lifetime, this must
    /// always equal [`Batcher::kv_used_bytes`] — the DST invariant
    /// checker cross-checks the two to catch conservation bugs in the
    /// evict/restore path.
    pub fn active_kv_bytes(&self, arena: &RequestArena) -> f64 {
        self.active.iter().map(|&id| self.kv.bytes_for(&arena[id])).sum()
    }

    /// Whether everything is drained.
    pub fn idle(&self) -> bool {
        self.queue.is_empty() && self.active.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{budget, mk_req};
    use super::*;

    fn req(arena: &mut RequestArena, id: u64, ctx: u64, gen: u64) -> ReqId {
        arena.alloc(mk_req(id, 0.0, ctx, gen))
    }

    #[test]
    fn admits_up_to_batch_cap() {
        let mut a = RequestArena::new();
        let mut b = Batcher::new(2, budget(1_000_000));
        for i in 0..5 {
            let id = req(&mut a, i, 10, 5);
            b.enqueue(id, &a);
        }
        assert_eq!(b.admit(0.0, &mut a), 2);
        assert_eq!(b.active_len(), 2);
        assert_eq!(b.queued_len(), 3);
    }

    #[test]
    fn kv_budget_gates_admission() {
        // Budget holds one request of (10 ctx + 5 gen) = 15 tokens.
        let mut a = RequestArena::new();
        let mut b = Batcher::new(8, budget(20));
        let r0 = req(&mut a, 0, 10, 5);
        let r1 = req(&mut a, 1, 10, 5);
        b.enqueue(r0, &a);
        b.enqueue(r1, &a);
        assert_eq!(b.admit(0.0, &mut a), 1);
        // Retire the first; second then fits.
        for _ in 0..5 {
            b.step_complete(1.0, &mut a);
        }
        assert_eq!(b.admit(1.0, &mut a), 1);
    }

    #[test]
    fn steps_retire_completed_requests() {
        let mut a = RequestArena::new();
        let mut b = Batcher::new(4, budget(1000));
        let r0 = req(&mut a, 0, 10, 2);
        let r1 = req(&mut a, 1, 10, 3);
        b.enqueue(r0, &a);
        b.enqueue(r1, &a);
        b.admit(0.0, &mut a);
        assert!(b.step_complete(0.1, &mut a).is_empty());
        let done = b.step_complete(0.2, &mut a);
        assert_eq!(done.len(), 1);
        assert_eq!(a[done[0]].id, 0);
        let done = b.step_complete(0.3, &mut a);
        assert_eq!(done.len(), 1);
        assert_eq!(a[done[0]].id, 1);
        assert!(b.idle());
    }

    #[test]
    fn kv_is_released_on_completion() {
        let mut a = RequestArena::new();
        let mut b = Batcher::new(4, budget(15));
        let r0 = req(&mut a, 0, 10, 2);
        b.enqueue(r0, &a);
        b.admit(0.0, &mut a);
        assert!(b.kv_utilization() > 0.7);
        b.step_complete(0.1, &mut a);
        b.step_complete(0.2, &mut a);
        assert_eq!(b.kv_utilization(), 0.0);
    }

    #[test]
    #[should_panic(expected = "exceed capacity")]
    fn weights_larger_than_capacity_panic() {
        KvBudget::new(10.0, 20.0, 1.0);
    }

    #[test]
    fn decode_only_mode_skips_prefill() {
        let mut a = RequestArena::new();
        let mut b = Batcher::new(4, budget(1000));
        let r0 = req(&mut a, 0, 100, 2);
        b.enqueue(r0, &a);
        b.admit(0.0, &mut a);
        let plan = b.plan_step(&mut a);
        assert_eq!(plan.decode_batch, 1);
        assert_eq!(plan.prefill_tokens, 0);
        let done = b.step_complete(0.1, &mut a);
        assert!(done.is_empty());
        assert_eq!(b.step_complete(0.2, &mut a).len(), 1);
        assert_eq!(b.prefill_tokens_processed(), 0);
    }

    #[test]
    fn prefill_chunks_run_before_decode() {
        let mut a = RequestArena::new();
        let mut b = Batcher::with_prefill(4, budget(1000), 30);
        let r0 = req(&mut a, 0, 100, 2);
        b.enqueue(r0, &a);
        b.admit(0.0, &mut a);

        // 100-token prompt at 30 tokens/step: 3 full chunks + 10.
        for (i, expect) in [30u64, 30, 30, 10].iter().enumerate() {
            let plan = b.plan_step(&mut a);
            assert_eq!(plan.decode_batch, 0, "step {i}");
            assert_eq!(plan.prefill_tokens, *expect, "step {i}");
            assert_eq!(plan.prefill_past, 30 * i as u64, "step {i}");
            let t = 0.1 * (i as f64 + 1.0);
            assert!(b.step_complete(t, &mut a).is_empty());
        }

        // The final chunk emitted the first token; one decode step left.
        let plan = b.plan_step(&mut a);
        assert_eq!(plan.decode_batch, 1);
        assert_eq!(plan.max_context, 101);
        let done = b.step_complete(0.5, &mut a);
        assert_eq!(done.len(), 1);
        let r = &a[done[0]];
        assert_eq!(r.prefilled, 100);
        assert_eq!(r.generated, 2);
        assert!((r.first_token_at.unwrap() - 0.4).abs() < 1e-12);
        assert!((r.completed_at.unwrap() - 0.5).abs() < 1e-12);
        assert_eq!(b.prefill_tokens_processed(), 100);
    }

    #[test]
    fn one_prefill_chunk_per_step_fifo() {
        let mut a = RequestArena::new();
        let mut b = Batcher::with_prefill(4, budget(1000), 8);
        let r0 = req(&mut a, 0, 6, 1);
        let r1 = req(&mut a, 1, 6, 1);
        b.enqueue(r0, &a);
        b.enqueue(r1, &a);
        b.admit(0.0, &mut a);
        // First step: only the oldest prompt gets a chunk, even though
        // 2 tokens of budget are nominally left over.
        let plan = b.plan_step(&mut a);
        assert_eq!(plan.prefill_seqs, 1);
        assert_eq!(plan.prefill_tokens, 6);
        assert_eq!(plan.prefill_past, 0);
        b.step_complete(0.1, &mut a);
        // Request 0 is decode-done (gen 1 emitted by its final chunk,
        // gen_len 1 -> retired); request 1's whole prompt goes next.
        let plan = b.plan_step(&mut a);
        assert_eq!(plan.decode_batch, 0); // r0 retired at 0.1 (gen_len 1)
        assert_eq!(plan.prefill_seqs, 1);
        assert_eq!(plan.prefill_tokens, 6);
        let done = b.step_complete(0.2, &mut a);
        assert_eq!(done.len(), 1);
        assert_eq!(a[done[0]].id, 1);
    }

    #[test]
    fn retirement_preserves_admission_order_for_prefill() {
        // r0 (short) retires first; the prefill budget must then go to
        // r1, not to a later-admitted request (a swap_remove-based
        // retirement used to reorder the active list).
        let mut a = RequestArena::new();
        let mut b = Batcher::with_prefill(4, budget(1000), 10);
        for (id, ctx) in [(0, 5), (1, 20), (2, 20)] {
            let rid = req(&mut a, id, ctx, 1);
            b.enqueue(rid, &a);
        }
        b.admit(0.0, &mut a);
        b.plan_step(&mut a); // r0's 5-token prompt
        b.step_complete(0.1, &mut a); // r0 retires (gen_len 1)
        // The next two chunks must go to r1 (admitted before r2).
        let plan = b.plan_step(&mut a);
        assert_eq!(plan.prefill_tokens, 10);
        assert!(b.step_complete(0.2, &mut a).is_empty());
        let plan = b.plan_step(&mut a);
        assert_eq!(plan.prefill_past, 10);
        let done = b.step_complete(0.3, &mut a);
        assert_eq!(done.len(), 1);
        assert_eq!(a[done[0]].id, 1, "r1 must finish before r2");
    }

    #[test]
    fn zero_length_prompts_enter_decode_directly() {
        let mut a = RequestArena::new();
        let mut b = Batcher::with_prefill(4, budget(1000), 16);
        let r0 = req(&mut a, 0, 0, 1);
        b.enqueue(r0, &a);
        b.admit(0.0, &mut a);
        let plan = b.plan_step(&mut a);
        assert_eq!(plan.decode_batch, 1);
        assert_eq!(plan.prefill_tokens, 0);
        assert_eq!(b.step_complete(0.1, &mut a).len(), 1);
    }

    #[test]
    fn admission_keeps_an_earlier_stamp() {
        // A disaggregated request re-admitted at the decode pool must
        // keep its prefill-side admission time: queue delay is a
        // lifecycle quantity, not a per-pool one.
        let mut a = RequestArena::new();
        let mut b = Batcher::new(4, budget(1000));
        let r0 = req(&mut a, 0, 10, 2);
        a[r0].admitted_at = Some(0.25);
        b.enqueue(r0, &a);
        let r1 = req(&mut a, 1, 10, 2);
        b.enqueue(r1, &a);
        b.admit(1.0, &mut a);
        for t in [1.1, 1.2] {
            let done = b.step_complete(t, &mut a);
            for &d in done {
                match a[d].id {
                    0 => assert_eq!(a[d].admitted_at, Some(0.25)),
                    _ => assert_eq!(a[d].admitted_at, Some(1.0)),
                }
            }
        }
    }

    #[test]
    fn prefill_backlog_counts_queued_and_prefilling() {
        let mut a = RequestArena::new();
        let mut b = Batcher::with_prefill(2, budget(1000), 8);
        for id in 0..3 {
            let rid = req(&mut a, id, 16, 1);
            b.enqueue(rid, &a);
        }
        assert_eq!(b.prefill_backlog(&a), 3); // all queued
        b.admit(0.0, &mut a);
        assert_eq!(b.prefill_backlog(&a), 3); // 2 prefilling + 1 queued
        b.plan_step(&mut a);
        b.step_complete(0.1, &mut a); // r0: 8 of 16 tokens in
        assert_eq!(b.prefill_backlog(&a), 3);
        b.plan_step(&mut a);
        b.step_complete(0.2, &mut a); // r0 fully prefilled (emits first token)
        assert_eq!(b.prefill_backlog(&a), 2);
    }

    fn preq(arena: &mut RequestArena, id: u64, ctx: u64, gen: u64, prio: u8) -> ReqId {
        let rid = arena.alloc(mk_req(id, 0.0, ctx, gen));
        arena[rid].priority = prio;
        rid
    }

    #[test]
    fn admission_is_by_priority_class_then_fifo() {
        let mut a = RequestArena::new();
        let mut b = Batcher::new(1, budget(1000));
        let r0 = preq(&mut a, 0, 10, 1, 0);
        let r1 = preq(&mut a, 1, 10, 1, 1);
        let r2 = preq(&mut a, 2, 10, 1, 1);
        for id in [r0, r1, r2] {
            b.enqueue(id, &a);
        }
        let mut order = Vec::new();
        let mut t = 0.0;
        while !b.idle() {
            b.admit(t, &mut a);
            t += 0.1;
            for &d in b.step_complete(t, &mut a) {
                order.push(a[d].id);
            }
        }
        // Class 1 first in arrival order, then the class-0 request.
        assert_eq!(order, vec![1, 2, 0]);
    }

    #[test]
    fn high_priority_arrival_evicts_the_most_recent_low_victim() {
        let mut a = RequestArena::new();
        // Budget fits exactly two 15-token requests.
        let mut b = Batcher::new(8, budget(30));
        b.set_preemption(PreemptionConfig {
            enabled: true,
            evict_cost: 0.01,
            restore_cost: 0.02,
        });
        let r0 = preq(&mut a, 0, 10, 5, 0);
        let r1 = preq(&mut a, 1, 10, 5, 0);
        b.enqueue(r0, &a);
        b.enqueue(r1, &a);
        assert_eq!(b.admit(0.0, &mut a), 2);
        b.step_complete(0.1, &mut a); // both gain one token
        let hi = preq(&mut a, 2, 10, 5, 1);
        b.enqueue(hi, &a);
        assert_eq!(b.admit(0.2, &mut a), 1);
        // The most recently admitted class-0 request (r1) was evicted;
        // it kept its decode progress and waits at the queue front.
        assert_eq!(b.preemptions(), 1);
        assert_eq!(b.evicted_pending_len(), 1);
        assert_eq!(b.active_len(), 2);
        assert_eq!(b.queued_len(), 1);
        assert_eq!(a[r1].generated, 1);
        // Eviction charged the next step exactly once.
        assert_eq!(b.take_step_penalty(), 0.01);
        assert_eq!(b.take_step_penalty(), 0.0);
        let mut log = Vec::new();
        b.drain_sched_log(&mut log);
        assert_eq!(log, vec![(r1, SchedAction::Preempt)]);
        // Drain: once a slot frees, r1 is restored (restore cost
        // charged) and runs to completion.
        let mut t = 0.3;
        while !b.idle() {
            b.admit(t, &mut a);
            t += 0.1;
            b.step_complete(t, &mut a);
        }
        assert_eq!(b.restores(), 1);
        assert_eq!(b.evicted_pending_len(), 0);
        assert_eq!(b.take_step_penalty(), 0.02);
        assert!(a[r1].completed_at.is_some());
        assert_eq!(b.kv_used_bytes(), 0.0);
    }

    #[test]
    fn infeasible_preemption_evicts_nothing() {
        let mut a = RequestArena::new();
        let mut b = Batcher::new(8, budget(30));
        b.set_preemption(PreemptionConfig {
            enabled: true,
            evict_cost: 0.01,
            restore_cost: 0.01,
        });
        let lo = preq(&mut a, 0, 10, 5, 0);
        let hi = preq(&mut a, 1, 10, 5, 2);
        b.enqueue(lo, &a);
        b.enqueue(hi, &a);
        assert_eq!(b.admit(0.0, &mut a), 2);
        // A 20-token class-1 arrival cannot fit even with `lo` gone
        // (30 - 15 + 20 > 30): nothing may be disturbed.
        let mid = preq(&mut a, 2, 15, 5, 1);
        b.enqueue(mid, &a);
        assert_eq!(b.admit(0.1, &mut a), 0);
        assert_eq!(b.preemptions(), 0);
        assert_eq!(b.active_len(), 2);
        assert_eq!(b.take_step_penalty(), 0.0);
    }

    #[test]
    fn single_class_never_preempts_even_when_enabled() {
        let mut a = RequestArena::new();
        let mut b = Batcher::new(8, budget(20));
        b.set_preemption(PreemptionConfig {
            enabled: true,
            evict_cost: 0.5,
            restore_cost: 0.5,
        });
        let r0 = preq(&mut a, 0, 10, 5, 3);
        let r1 = preq(&mut a, 1, 10, 5, 3);
        b.enqueue(r0, &a);
        b.enqueue(r1, &a);
        // Only one fits, and an equal class is never a victim.
        assert_eq!(b.admit(0.0, &mut a), 1);
        assert_eq!(b.preemptions(), 0);
        assert_eq!(b.take_step_penalty(), 0.0);
    }

    #[test]
    fn evicted_prefilling_request_resumes_its_prompt() {
        let mut a = RequestArena::new();
        let mut b = Batcher::with_prefill(8, budget(30), 8);
        b.set_preemption(PreemptionConfig {
            enabled: true,
            evict_cost: 0.0,
            restore_cost: 0.0,
        });
        let lo = preq(&mut a, 0, 16, 1, 0); // 17 KV tokens
        b.enqueue(lo, &a);
        b.admit(0.0, &mut a);
        b.plan_step(&mut a);
        b.step_complete(0.1, &mut a); // 8 of 16 prompt tokens in
        assert_eq!(a[lo].prefilled, 8);
        let hi = preq(&mut a, 1, 10, 5, 1); // 15 KV tokens: 17+15 > 30
        b.enqueue(hi, &a);
        b.admit(0.2, &mut a);
        assert_eq!(b.preemptions(), 1);
        assert_eq!(a[lo].prefilled, 8); // prompt progress kept
        let mut t = 0.3;
        while !b.idle() {
            b.admit(t, &mut a);
            b.plan_step(&mut a);
            t += 0.1;
            b.step_complete(t, &mut a);
        }
        assert_eq!(b.restores(), 1);
        assert_eq!(a[lo].prefilled, 16);
        assert!(a[lo].completed_at.is_some());
        assert_eq!(b.prefill_tokens_processed(), 16 + 10);
    }

    #[test]
    fn retirement_buffer_is_reused_not_grown() {
        // Consecutive step_complete calls return slices from the same
        // reusable buffer; a later empty step yields an empty slice, not
        // stale retirees.
        let mut a = RequestArena::new();
        let mut b = Batcher::new(4, budget(1000));
        let r0 = req(&mut a, 0, 10, 1);
        b.enqueue(r0, &a);
        b.admit(0.0, &mut a);
        assert_eq!(b.step_complete(0.1, &mut a).len(), 1);
        assert!(b.step_complete(0.2, &mut a).is_empty());
    }
}
