//! Continuous batcher: admission queue, KV-capacity gate, and the
//! prefill-chunk planner.
//!
//! The admission policy mirrors the paper's capacity story: a request is
//! admitted only if its KV cache (context + full generation budget) fits
//! in the remaining memory after weights, and the active batch stays
//! under the configured cap. FIFO order; no preemption (requests run to
//! completion, as in the paper's steady-state analysis).
//!
//! With a prefill chunk configured ([`Batcher::with_prefill`]), an
//! admitted request first has its prompt ingested in chunks of at most
//! `prefill_chunk` tokens per engine step ([`Batcher::plan_step`]),
//! sharing steps with decode-ready lanes; the final chunk's forward
//! pass emits the first output token. With the chunk set to 0 (legacy
//! mode) prompts are assumed prefilled elsewhere — the paper's
//! disaggregated decode-only focus — and requests enter decode
//! directly.
//!
//! Request state lives in the caller's [`RequestArena`]; the batcher's
//! queue, active set, and retirement buffer hold dense [`ReqId`]s only,
//! so admitting, planning, and completing steps never move or clone a
//! `Request`. The retirement buffer is reused across steps
//! ([`Batcher::step_complete`] returns a borrowed slice), keeping
//! steady-state stepping allocation-free.

use std::collections::VecDeque;

use super::arena::{ReqId, RequestArena};
use super::engine::StepBatch;
use super::request::Request;

/// KV-capacity accounting for one model instance on one system.
#[derive(Debug, Clone)]
pub struct KvBudget {
    /// Bytes available for KV cache (system capacity - weights).
    pub budget_bytes: f64,
    /// KV bytes per token (all layers).
    pub bytes_per_token: f64,
    used_bytes: f64,
}

impl KvBudget {
    /// New budget; panics if the weights alone exceed capacity.
    pub fn new(total_capacity: f64, weight_bytes: f64, bytes_per_token: f64) -> Self {
        assert!(
            total_capacity >= weight_bytes,
            "weights ({:.1} GiB) exceed capacity ({:.1} GiB)",
            weight_bytes / crate::GIB,
            total_capacity / crate::GIB
        );
        KvBudget {
            budget_bytes: total_capacity - weight_bytes,
            bytes_per_token,
            used_bytes: 0.0,
        }
    }

    /// Bytes a request will occupy at its maximum sequence length.
    pub fn bytes_for(&self, r: &Request) -> f64 {
        (r.context_len + r.gen_len) as f64 * self.bytes_per_token
    }

    /// Try to reserve space for a request.
    pub fn reserve(&mut self, r: &Request) -> bool {
        let need = self.bytes_for(r);
        if self.used_bytes + need <= self.budget_bytes {
            self.used_bytes += need;
            true
        } else {
            false
        }
    }

    /// Release a completed request's reservation.
    pub fn release(&mut self, r: &Request) {
        self.used_bytes = (self.used_bytes - self.bytes_for(r)).max(0.0);
    }

    /// KV bytes currently reserved by admitted requests.
    pub fn used_bytes(&self) -> f64 {
        self.used_bytes
    }

    /// Current utilization fraction.
    pub fn utilization(&self) -> f64 {
        if self.budget_bytes == 0.0 {
            1.0
        } else {
            self.used_bytes / self.budget_bytes
        }
    }
}

/// FIFO continuous batcher over arena-resident requests.
pub struct Batcher {
    /// Maximum concurrent sequences (compiled bucket size or policy cap).
    pub max_batch: usize,
    queue: VecDeque<ReqId>,
    active: Vec<ReqId>,
    kv: KvBudget,
    /// Max prefill tokens ingested per engine step (0 = prefill served
    /// elsewhere; requests enter decode directly).
    prefill_chunk: u64,
    /// Total prompt tokens this batcher has prefilled.
    prefill_processed: u64,
    /// Retirement buffer, reused across steps so completing a step
    /// allocates nothing in steady state.
    retired: Vec<ReqId>,
}

impl Batcher {
    /// New decode-only batcher over a KV budget (prompts are assumed
    /// prefilled elsewhere, the paper's disaggregated assumption).
    pub fn new(max_batch: usize, kv: KvBudget) -> Self {
        assert!(max_batch >= 1);
        Batcher {
            max_batch,
            queue: VecDeque::new(),
            active: Vec::new(),
            kv,
            prefill_chunk: 0,
            prefill_processed: 0,
            retired: Vec::new(),
        }
    }

    /// New prefill-aware batcher: admitted prompts are ingested in
    /// chunks of at most `chunk_tokens` per step before decoding.
    /// `chunk_tokens = 0` degrades to the decode-only mode of
    /// [`Batcher::new`], so callers can thread a single configuration
    /// value through.
    pub fn with_prefill(max_batch: usize, kv: KvBudget, chunk_tokens: u64) -> Self {
        let mut b = Batcher::new(max_batch, kv);
        b.prefill_chunk = chunk_tokens;
        b
    }

    /// Enqueue an arriving request by id.
    pub fn enqueue(&mut self, id: ReqId) {
        self.queue.push_back(id);
    }

    /// Admit as many queued requests as fit. The simulator calls this
    /// only at step boundaries: a request arriving mid-step must wait
    /// for the in-flight step to finish before it can join (it never
    /// rides a step it was not priced into).
    /// Returns how many were admitted; sets their `admitted_at` unless
    /// an earlier admission already stamped it (a disaggregated request
    /// re-admitted at the decode pool keeps its first admission, so
    /// queue-delay and residence metrics span the whole lifecycle).
    pub fn admit(&mut self, now: f64, arena: &mut RequestArena) -> usize {
        let mut n = 0;
        while self.active.len() < self.max_batch {
            let Some(&front) = self.queue.front() else { break };
            if !self.kv.reserve(&arena[front]) {
                break; // FIFO head-of-line: preserve arrival order
            }
            self.queue.pop_front();
            let r = &mut arena[front];
            if r.admitted_at.is_none() {
                r.admitted_at = Some(now);
            }
            if self.prefill_chunk == 0 {
                // Legacy decode-only mode: the prompt is already in the
                // KV cache when the request reaches us.
                r.prefilled = r.context_len;
            }
            self.active.push(front);
            n += 1;
        }
        n
    }

    /// Plan the next engine step: every decode-ready lane emits one
    /// token, and the *oldest* prefilling request (admission FIFO)
    /// receives one chunk of up to `prefill_chunk` prompt tokens —
    /// Sarathi-style, at most one prefill chunk per step. Restricting a
    /// step to a single prompt's chunk keeps the engine's
    /// `(prefill_tokens, prefill_past)` description of the chunk exact
    /// (mixing two prompts' chunks would conflate their attention
    /// depths).
    pub fn plan_step(&mut self, arena: &mut RequestArena) -> StepBatch {
        let mut step = StepBatch::default();
        let mut budget = self.prefill_chunk;
        for &id in &self.active {
            let r = &mut arena[id];
            if r.in_prefill() {
                let take = r.prefill_remaining().min(budget);
                r.scheduled_prefill = take;
                if take > 0 {
                    budget = 0; // one prefill chunk per step
                    step.prefill_seqs += 1;
                    step.prefill_tokens += take;
                    step.prefill_past = r.prefilled;
                }
            } else {
                r.scheduled_prefill = 0;
                step.decode_batch += 1;
                step.max_context = step.max_context.max(r.seq_len());
            }
        }
        step
    }

    /// Complete the step planned by [`Batcher::plan_step`]: prefilling
    /// lanes advance by their scheduled chunk (the final chunk emits the
    /// first output token); decode lanes each gain one token; finished
    /// requests are retired. Returns the retired ids (their requests
    /// are stamped with `completed_at` in the arena); the slice borrows
    /// the batcher's reusable retirement buffer and is valid until the
    /// next `step_complete` call.
    pub fn step_complete(&mut self, now: f64, arena: &mut RequestArena) -> &[ReqId] {
        self.retired.clear();
        let mut i = 0;
        while i < self.active.len() {
            let id = self.active[i];
            let done = {
                let r = &mut arena[id];
                if r.scheduled_prefill > 0 {
                    self.prefill_processed += r.scheduled_prefill;
                    r.prefilled += r.scheduled_prefill;
                    r.scheduled_prefill = 0;
                    if !r.in_prefill() {
                        // The last prefill chunk's forward pass produces
                        // the first generated token.
                        r.generated += 1;
                        r.first_token_at = Some(now);
                    }
                } else if !r.in_prefill() {
                    r.generated += 1;
                    if r.first_token_at.is_none() {
                        r.first_token_at = Some(now);
                    }
                }
                // else: prefilling but received no budget this step — waits.
                if r.done() {
                    r.completed_at = Some(now);
                    true
                } else {
                    false
                }
            };
            if done {
                // `remove`, not `swap_remove`: the active list's order is
                // the admission FIFO that plan_step's prefill scheduling
                // relies on (it's a memmove of 4-byte ids, not requests).
                self.active.remove(i);
                self.kv.release(&arena[id]);
                self.retired.push(id);
            } else {
                i += 1;
            }
        }
        &self.retired
    }

    /// Active batch size (decode + prefilling lanes).
    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    /// Queued (not yet admitted) requests.
    pub fn queued_len(&self) -> usize {
        self.queue.len()
    }

    /// Requests whose prompts are not yet fully ingested: everything
    /// still queued plus active lanes in prefill. Since the planner
    /// issues at most one chunk to one prompt per step, this is a lower
    /// bound on the steps needed to drain the prompt backlog.
    pub fn prefill_backlog(&self, arena: &RequestArena) -> usize {
        self.queue.len()
            + self.active.iter().filter(|&&id| arena[id].in_prefill()).count()
    }

    /// Longest active sequence length (drives attention cost).
    pub fn max_seq_len(&self, arena: &RequestArena) -> u64 {
        self.active.iter().map(|&id| arena[id].seq_len()).max().unwrap_or(0)
    }

    /// Mean active sequence length.
    pub fn mean_seq_len(&self, arena: &RequestArena) -> f64 {
        if self.active.is_empty() {
            0.0
        } else {
            self.active.iter().map(|&id| arena[id].seq_len()).sum::<u64>() as f64
                / self.active.len() as f64
        }
    }

    /// KV budget utilization.
    pub fn kv_utilization(&self) -> f64 {
        self.kv.utilization()
    }

    /// KV bytes per token of the underlying budget (drives the
    /// routed-footprint accounting and KV-shipment sizing).
    pub fn kv_bytes_per_token(&self) -> f64 {
        self.kv.bytes_per_token
    }

    /// KV bytes currently reserved by the active batch.
    pub fn kv_used_bytes(&self) -> f64 {
        self.kv.used_bytes()
    }

    /// Total KV bytes the budget may reserve.
    pub fn kv_budget_bytes(&self) -> f64 {
        self.kv.budget_bytes
    }

    /// Configured prefill chunk (0 = decode-only mode).
    pub fn prefill_chunk(&self) -> u64 {
        self.prefill_chunk
    }

    /// Total prompt tokens prefilled so far.
    pub fn prefill_tokens_processed(&self) -> u64 {
        self.prefill_processed
    }

    /// Whether everything is drained.
    pub fn idle(&self) -> bool {
        self.queue.is_empty() && self.active.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{budget, mk_req};
    use super::*;

    fn req(arena: &mut RequestArena, id: u64, ctx: u64, gen: u64) -> ReqId {
        arena.alloc(mk_req(id, 0.0, ctx, gen))
    }

    #[test]
    fn admits_up_to_batch_cap() {
        let mut a = RequestArena::new();
        let mut b = Batcher::new(2, budget(1_000_000));
        for i in 0..5 {
            let id = req(&mut a, i, 10, 5);
            b.enqueue(id);
        }
        assert_eq!(b.admit(0.0, &mut a), 2);
        assert_eq!(b.active_len(), 2);
        assert_eq!(b.queued_len(), 3);
    }

    #[test]
    fn kv_budget_gates_admission() {
        // Budget holds one request of (10 ctx + 5 gen) = 15 tokens.
        let mut a = RequestArena::new();
        let mut b = Batcher::new(8, budget(20));
        let r0 = req(&mut a, 0, 10, 5);
        let r1 = req(&mut a, 1, 10, 5);
        b.enqueue(r0);
        b.enqueue(r1);
        assert_eq!(b.admit(0.0, &mut a), 1);
        // Retire the first; second then fits.
        for _ in 0..5 {
            b.step_complete(1.0, &mut a);
        }
        assert_eq!(b.admit(1.0, &mut a), 1);
    }

    #[test]
    fn steps_retire_completed_requests() {
        let mut a = RequestArena::new();
        let mut b = Batcher::new(4, budget(1000));
        let r0 = req(&mut a, 0, 10, 2);
        let r1 = req(&mut a, 1, 10, 3);
        b.enqueue(r0);
        b.enqueue(r1);
        b.admit(0.0, &mut a);
        assert!(b.step_complete(0.1, &mut a).is_empty());
        let done = b.step_complete(0.2, &mut a);
        assert_eq!(done.len(), 1);
        assert_eq!(a[done[0]].id, 0);
        let done = b.step_complete(0.3, &mut a);
        assert_eq!(done.len(), 1);
        assert_eq!(a[done[0]].id, 1);
        assert!(b.idle());
    }

    #[test]
    fn kv_is_released_on_completion() {
        let mut a = RequestArena::new();
        let mut b = Batcher::new(4, budget(15));
        let r0 = req(&mut a, 0, 10, 2);
        b.enqueue(r0);
        b.admit(0.0, &mut a);
        assert!(b.kv_utilization() > 0.7);
        b.step_complete(0.1, &mut a);
        b.step_complete(0.2, &mut a);
        assert_eq!(b.kv_utilization(), 0.0);
    }

    #[test]
    #[should_panic(expected = "exceed capacity")]
    fn weights_larger_than_capacity_panic() {
        KvBudget::new(10.0, 20.0, 1.0);
    }

    #[test]
    fn decode_only_mode_skips_prefill() {
        let mut a = RequestArena::new();
        let mut b = Batcher::new(4, budget(1000));
        let r0 = req(&mut a, 0, 100, 2);
        b.enqueue(r0);
        b.admit(0.0, &mut a);
        let plan = b.plan_step(&mut a);
        assert_eq!(plan.decode_batch, 1);
        assert_eq!(plan.prefill_tokens, 0);
        let done = b.step_complete(0.1, &mut a);
        assert!(done.is_empty());
        assert_eq!(b.step_complete(0.2, &mut a).len(), 1);
        assert_eq!(b.prefill_tokens_processed(), 0);
    }

    #[test]
    fn prefill_chunks_run_before_decode() {
        let mut a = RequestArena::new();
        let mut b = Batcher::with_prefill(4, budget(1000), 30);
        let r0 = req(&mut a, 0, 100, 2);
        b.enqueue(r0);
        b.admit(0.0, &mut a);

        // 100-token prompt at 30 tokens/step: 3 full chunks + 10.
        for (i, expect) in [30u64, 30, 30, 10].iter().enumerate() {
            let plan = b.plan_step(&mut a);
            assert_eq!(plan.decode_batch, 0, "step {i}");
            assert_eq!(plan.prefill_tokens, *expect, "step {i}");
            assert_eq!(plan.prefill_past, 30 * i as u64, "step {i}");
            let t = 0.1 * (i as f64 + 1.0);
            assert!(b.step_complete(t, &mut a).is_empty());
        }

        // The final chunk emitted the first token; one decode step left.
        let plan = b.plan_step(&mut a);
        assert_eq!(plan.decode_batch, 1);
        assert_eq!(plan.max_context, 101);
        let done = b.step_complete(0.5, &mut a);
        assert_eq!(done.len(), 1);
        let r = &a[done[0]];
        assert_eq!(r.prefilled, 100);
        assert_eq!(r.generated, 2);
        assert!((r.first_token_at.unwrap() - 0.4).abs() < 1e-12);
        assert!((r.completed_at.unwrap() - 0.5).abs() < 1e-12);
        assert_eq!(b.prefill_tokens_processed(), 100);
    }

    #[test]
    fn one_prefill_chunk_per_step_fifo() {
        let mut a = RequestArena::new();
        let mut b = Batcher::with_prefill(4, budget(1000), 8);
        let r0 = req(&mut a, 0, 6, 1);
        let r1 = req(&mut a, 1, 6, 1);
        b.enqueue(r0);
        b.enqueue(r1);
        b.admit(0.0, &mut a);
        // First step: only the oldest prompt gets a chunk, even though
        // 2 tokens of budget are nominally left over.
        let plan = b.plan_step(&mut a);
        assert_eq!(plan.prefill_seqs, 1);
        assert_eq!(plan.prefill_tokens, 6);
        assert_eq!(plan.prefill_past, 0);
        b.step_complete(0.1, &mut a);
        // Request 0 is decode-done (gen 1 emitted by its final chunk,
        // gen_len 1 -> retired); request 1's whole prompt goes next.
        let plan = b.plan_step(&mut a);
        assert_eq!(plan.decode_batch, 0); // r0 retired at 0.1 (gen_len 1)
        assert_eq!(plan.prefill_seqs, 1);
        assert_eq!(plan.prefill_tokens, 6);
        let done = b.step_complete(0.2, &mut a);
        assert_eq!(done.len(), 1);
        assert_eq!(a[done[0]].id, 1);
    }

    #[test]
    fn retirement_preserves_admission_order_for_prefill() {
        // r0 (short) retires first; the prefill budget must then go to
        // r1, not to a later-admitted request (a swap_remove-based
        // retirement used to reorder the active list).
        let mut a = RequestArena::new();
        let mut b = Batcher::with_prefill(4, budget(1000), 10);
        for (id, ctx) in [(0, 5), (1, 20), (2, 20)] {
            let rid = req(&mut a, id, ctx, 1);
            b.enqueue(rid);
        }
        b.admit(0.0, &mut a);
        b.plan_step(&mut a); // r0's 5-token prompt
        b.step_complete(0.1, &mut a); // r0 retires (gen_len 1)
        // The next two chunks must go to r1 (admitted before r2).
        let plan = b.plan_step(&mut a);
        assert_eq!(plan.prefill_tokens, 10);
        assert!(b.step_complete(0.2, &mut a).is_empty());
        let plan = b.plan_step(&mut a);
        assert_eq!(plan.prefill_past, 10);
        let done = b.step_complete(0.3, &mut a);
        assert_eq!(done.len(), 1);
        assert_eq!(a[done[0]].id, 1, "r1 must finish before r2");
    }

    #[test]
    fn zero_length_prompts_enter_decode_directly() {
        let mut a = RequestArena::new();
        let mut b = Batcher::with_prefill(4, budget(1000), 16);
        let r0 = req(&mut a, 0, 0, 1);
        b.enqueue(r0);
        b.admit(0.0, &mut a);
        let plan = b.plan_step(&mut a);
        assert_eq!(plan.decode_batch, 1);
        assert_eq!(plan.prefill_tokens, 0);
        assert_eq!(b.step_complete(0.1, &mut a).len(), 1);
    }

    #[test]
    fn admission_keeps_an_earlier_stamp() {
        // A disaggregated request re-admitted at the decode pool must
        // keep its prefill-side admission time: queue delay is a
        // lifecycle quantity, not a per-pool one.
        let mut a = RequestArena::new();
        let mut b = Batcher::new(4, budget(1000));
        let r0 = req(&mut a, 0, 10, 2);
        a[r0].admitted_at = Some(0.25);
        b.enqueue(r0);
        let r1 = req(&mut a, 1, 10, 2);
        b.enqueue(r1);
        b.admit(1.0, &mut a);
        for t in [1.1, 1.2] {
            let done = b.step_complete(t, &mut a);
            for &d in done {
                match a[d].id {
                    0 => assert_eq!(a[d].admitted_at, Some(0.25)),
                    _ => assert_eq!(a[d].admitted_at, Some(1.0)),
                }
            }
        }
    }

    #[test]
    fn prefill_backlog_counts_queued_and_prefilling() {
        let mut a = RequestArena::new();
        let mut b = Batcher::with_prefill(2, budget(1000), 8);
        for id in 0..3 {
            let rid = req(&mut a, id, 16, 1);
            b.enqueue(rid);
        }
        assert_eq!(b.prefill_backlog(&a), 3); // all queued
        b.admit(0.0, &mut a);
        assert_eq!(b.prefill_backlog(&a), 3); // 2 prefilling + 1 queued
        b.plan_step(&mut a);
        b.step_complete(0.1, &mut a); // r0: 8 of 16 tokens in
        assert_eq!(b.prefill_backlog(&a), 3);
        b.plan_step(&mut a);
        b.step_complete(0.2, &mut a); // r0 fully prefilled (emits first token)
        assert_eq!(b.prefill_backlog(&a), 2);
    }

    #[test]
    fn retirement_buffer_is_reused_not_grown() {
        // Consecutive step_complete calls return slices from the same
        // reusable buffer; a later empty step yields an empty slice, not
        // stale retirees.
        let mut a = RequestArena::new();
        let mut b = Batcher::new(4, budget(1000));
        let r0 = req(&mut a, 0, 10, 1);
        b.enqueue(r0);
        b.admit(0.0, &mut a);
        assert_eq!(b.step_complete(0.1, &mut a).len(), 1);
        assert!(b.step_complete(0.2, &mut a).is_empty());
    }
}
