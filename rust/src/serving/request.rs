//! Requests and synthetic workload generation.

use crate::util::rng::Pcg32;

/// One request's full lifecycle: it arrives with a `context_len`-token
/// prompt that must be prefilled into the KV cache, then decodes
/// `gen_len` new tokens. In the legacy decode-only mode (prefill chunk
/// = 0, the paper's §2.1 disaggregated assumption) the batcher admits
/// requests with the prefill already marked complete.
#[derive(Debug, Clone)]
pub struct Request {
    /// Unique id (assigned by the generator).
    pub id: u64,
    /// Arrival time, seconds.
    pub arrival: f64,
    /// Prompt (context) length in tokens.
    pub context_len: u64,
    /// Tokens to generate.
    pub gen_len: u64,
    /// Scheduling class: higher is more urgent (0 = best-effort, the
    /// default). The batcher admits the highest class first and, with
    /// preemption enabled, a higher class may evict a lower one's KV
    /// under capacity pressure; the SLO router sheds lower classes
    /// first.
    pub priority: u8,
    /// Tokens generated so far (mutated by the simulator).
    pub generated: u64,
    /// Prompt tokens prefilled into the KV cache so far. Equals
    /// `context_len` once the request is decode-ready.
    pub prefilled: u64,
    /// Prefill tokens assigned to the engine step currently in flight
    /// (simulator-internal; consumed by `Batcher::step_complete`).
    pub scheduled_prefill: u64,
    /// Admission time (None while queued).
    pub admitted_at: Option<f64>,
    /// Time the first output token was emitted (the final prefill
    /// chunk's forward pass produces it).
    pub first_token_at: Option<f64>,
    /// Completion time.
    pub completed_at: Option<f64>,
}

impl Request {
    /// Current total sequence length (context + generated) — the KV
    /// footprint the request will reach, which drives attention cost.
    pub fn seq_len(&self) -> u64 {
        self.context_len + self.generated
    }

    /// Whether generation is finished.
    pub fn done(&self) -> bool {
        self.generated >= self.gen_len
    }

    /// Whether prompt ingestion is still in progress.
    pub fn in_prefill(&self) -> bool {
        self.prefilled < self.context_len
    }

    /// Prompt tokens still to prefill.
    pub fn prefill_remaining(&self) -> u64 {
        self.context_len.saturating_sub(self.prefilled)
    }

    /// Time to first token: arrival -> first emitted token.
    pub fn ttft(&self) -> Option<f64> {
        Some(self.first_token_at? - self.arrival)
    }

    /// Steady-state time per output token after the first (None for
    /// single-token generations).
    pub fn tpot(&self) -> Option<f64> {
        if self.generated < 2 {
            return None;
        }
        Some((self.completed_at? - self.first_token_at?) / (self.generated - 1) as f64)
    }

    /// End-to-end latency: arrival -> completion.
    pub fn e2e(&self) -> Option<f64> {
        Some(self.completed_at? - self.arrival)
    }
}

/// Synthetic workload description.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    /// Mean request arrival rate, requests/second (Poisson process).
    pub arrival_rate: f64,
    /// Number of requests to generate.
    pub n_requests: u64,
    /// Context length range `[lo, hi)` (uniform).
    pub context: (u64, u64),
    /// Generation length range `[lo, hi)` (uniform).
    pub gen: (u64, u64),
    /// Priority-class mix as `(class, weight)` pairs; each request
    /// draws its class with probability proportional to the weight. An
    /// empty mix assigns class 0 everywhere **and draws nothing from
    /// the RNG**, so pre-existing seeded workloads replay
    /// byte-identically.
    pub priority_mix: Vec<(u8, f64)>,
    /// RNG seed.
    pub seed: u64,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec {
            arrival_rate: 10.0,
            n_requests: 100,
            context: (1024, 8192),
            gen: (64, 256),
            priority_mix: Vec::new(),
            seed: 7,
        }
    }
}

/// Draw a priority class from a weighted mix (one `f64` draw per call;
/// callers skip the call entirely for an empty mix so the RNG stream is
/// untouched by the default configuration).
fn draw_priority(rng: &mut Pcg32, mix: &[(u8, f64)]) -> u8 {
    debug_assert!(!mix.is_empty());
    let total: f64 = mix.iter().map(|&(_, w)| w).sum();
    let mut x = rng.f64() * total;
    for &(class, w) in mix {
        x -= w;
        if x < 0.0 {
            return class;
        }
    }
    mix.last().map(|&(class, _)| class).unwrap_or(0)
}

/// Validate a priority mix (shared by both generators): weights must be
/// positive and finite so the weighted draw is well defined.
fn validate_mix(mix: &[(u8, f64)]) {
    for &(class, w) in mix {
        assert!(
            w.is_finite() && w > 0.0,
            "priority class {class} has non-positive weight {w}"
        );
    }
}

/// Deterministic synthetic workload generator.
pub struct WorkloadGen {
    spec: WorkloadSpec,
    rng: Pcg32,
    next_id: u64,
    clock: f64,
}

impl WorkloadGen {
    /// New generator for a spec.
    pub fn new(spec: WorkloadSpec) -> Self {
        validate_mix(&spec.priority_mix);
        let rng = Pcg32::seed_from(spec.seed);
        WorkloadGen { spec, rng, next_id: 0, clock: 0.0 }
    }

    /// Generate all requests up front (arrival times are a Poisson
    /// process; lengths uniform in their ranges).
    pub fn generate(mut self) -> Vec<Request> {
        let mut out = Vec::with_capacity(self.spec.n_requests as usize);
        for _ in 0..self.spec.n_requests {
            self.clock += self.rng.exp(self.spec.arrival_rate);
            let (clo, chi) = self.spec.context;
            let (glo, ghi) = self.spec.gen;
            out.push(Request {
                id: self.next_id,
                arrival: self.clock,
                context_len: if chi > clo {
                    clo + self.rng.below((chi - clo) as u32) as u64
                } else {
                    clo
                },
                gen_len: if ghi > glo {
                    (glo + self.rng.below((ghi - glo) as u32) as u64).max(1)
                } else {
                    glo.max(1)
                },
                priority: if self.spec.priority_mix.is_empty() {
                    0
                } else {
                    draw_priority(&mut self.rng, &self.spec.priority_mix)
                },
                generated: 0,
                prefilled: 0,
                scheduled_prefill: 0,
                admitted_at: None,
                first_token_at: None,
                completed_at: None,
            });
            self.next_id += 1;
        }
        out
    }
}

/// Non-homogeneous Poisson workload: a sinusoidal diurnal swing around
/// a baseline rate, with exponentially spaced burst episodes
/// superimposed. This is the canonical stress for elastic fleets — a
/// fixed fleet must be provisioned for the peak, while an autoscaled
/// one can track the swing (see the `autoscale-fleet` experiment).
#[derive(Debug, Clone)]
pub struct DiurnalSpec {
    /// Baseline mean arrival rate, requests/second.
    pub base_rate: f64,
    /// Relative swing of the diurnal sinusoid, in `[0, 1]`: the
    /// instantaneous rate oscillates between `base_rate * (1 - a)` and
    /// `base_rate * (1 + a)`.
    pub amplitude: f64,
    /// Diurnal period, seconds (the sinusoid starts rising at t = 0).
    pub period: f64,
    /// Mean quiet time between burst episodes, seconds (exponential);
    /// `f64::INFINITY` disables bursts.
    pub burst_every: f64,
    /// Length of each burst episode, seconds.
    pub burst_duration: f64,
    /// Rate multiplier while a burst episode is active (>= 1).
    pub burst_boost: f64,
    /// Number of requests to generate.
    pub n_requests: u64,
    /// Context length range `[lo, hi)` (uniform).
    pub context: (u64, u64),
    /// Generation length range `[lo, hi)` (uniform).
    pub gen: (u64, u64),
    /// Priority-class mix as `(class, weight)` pairs (empty = all
    /// class 0, no extra RNG draw; see [`WorkloadSpec::priority_mix`]).
    pub priority_mix: Vec<(u8, f64)>,
    /// RNG seed.
    pub seed: u64,
}

impl Default for DiurnalSpec {
    fn default() -> Self {
        DiurnalSpec {
            base_rate: 10.0,
            amplitude: 0.6,
            period: 60.0,
            burst_every: 20.0,
            burst_duration: 2.0,
            burst_boost: 3.0,
            n_requests: 100,
            context: (1024, 8192),
            gen: (64, 256),
            priority_mix: Vec::new(),
            seed: 7,
        }
    }
}

/// Deterministic non-homogeneous Poisson generator for [`DiurnalSpec`],
/// via thinning: candidate arrivals are drawn at the envelope rate
/// `base * (1 + amplitude) * burst_boost` and accepted with probability
/// `lambda(t) / envelope`, which yields exactly the target
/// time-varying intensity.
pub struct DiurnalGen {
    spec: DiurnalSpec,
    rng: Pcg32,
}

impl DiurnalGen {
    /// New generator for a spec (validates the rate shape).
    pub fn new(spec: DiurnalSpec) -> Self {
        assert!(spec.base_rate > 0.0, "base_rate must be positive");
        assert!(
            (0.0..=1.0).contains(&spec.amplitude),
            "amplitude must be in [0, 1]"
        );
        assert!(spec.period > 0.0, "period must be positive");
        assert!(spec.burst_boost >= 1.0, "burst_boost must be >= 1");
        assert!(spec.burst_duration >= 0.0, "burst_duration must be >= 0");
        validate_mix(&spec.priority_mix);
        let rng = Pcg32::seed_from(spec.seed);
        DiurnalGen { spec, rng }
    }

    /// Instantaneous arrival rate at time `t`.
    fn rate_at(&self, t: f64, in_burst: bool) -> f64 {
        let phase = t / self.spec.period * std::f64::consts::TAU;
        let diurnal =
            self.spec.base_rate * (1.0 + self.spec.amplitude * phase.sin());
        if in_burst {
            diurnal * self.spec.burst_boost
        } else {
            diurnal
        }
    }

    /// Generate all requests up front (arrival times strictly
    /// non-decreasing; lengths uniform in their ranges).
    pub fn generate(mut self) -> Vec<Request> {
        let envelope = self.spec.base_rate
            * (1.0 + self.spec.amplitude)
            * self.spec.burst_boost;
        let bursty = self.spec.burst_every.is_finite();
        // The next burst episode's window [start, end); re-drawn lazily
        // once the clock passes it, so the draw order is deterministic.
        let (mut burst_start, mut burst_end) = if bursty {
            let s = self.rng.exp(1.0 / self.spec.burst_every);
            (s, s + self.spec.burst_duration)
        } else {
            (f64::INFINITY, f64::INFINITY)
        };
        let mut out = Vec::with_capacity(self.spec.n_requests as usize);
        let mut t = 0.0;
        while (out.len() as u64) < self.spec.n_requests {
            t += self.rng.exp(envelope);
            while bursty && t >= burst_end {
                burst_start = burst_end + self.rng.exp(1.0 / self.spec.burst_every);
                burst_end = burst_start + self.spec.burst_duration;
            }
            let in_burst = t >= burst_start && t < burst_end;
            // Thinning: accept with probability lambda(t) / envelope.
            if self.rng.f64() * envelope > self.rate_at(t, in_burst) {
                continue;
            }
            let (clo, chi) = self.spec.context;
            let (glo, ghi) = self.spec.gen;
            out.push(Request {
                id: out.len() as u64,
                arrival: t,
                context_len: if chi > clo {
                    clo + self.rng.below((chi - clo) as u32) as u64
                } else {
                    clo
                },
                gen_len: if ghi > glo {
                    (glo + self.rng.below((ghi - glo) as u32) as u64).max(1)
                } else {
                    glo.max(1)
                },
                priority: if self.spec.priority_mix.is_empty() {
                    0
                } else {
                    draw_priority(&mut self.rng, &self.spec.priority_mix)
                },
                generated: 0,
                prefilled: 0,
                scheduled_prefill: 0,
                admitted_at: None,
                first_token_at: None,
                completed_at: None,
            });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let a = WorkloadGen::new(WorkloadSpec::default()).generate();
        let b = WorkloadGen::new(WorkloadSpec::default()).generate();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.context_len, y.context_len);
            assert_eq!(x.gen_len, y.gen_len);
        }
    }

    #[test]
    fn arrivals_are_increasing_and_rate_is_close() {
        let spec = WorkloadSpec { arrival_rate: 50.0, n_requests: 2000, ..Default::default() };
        let reqs = WorkloadGen::new(spec).generate();
        for w in reqs.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        let span = reqs.last().unwrap().arrival;
        let rate = 2000.0 / span;
        assert!((rate - 50.0).abs() / 50.0 < 0.1, "rate {rate}");
    }

    #[test]
    fn slo_helpers_compute_ttft_tpot_e2e() {
        let r = Request {
            id: 0,
            arrival: 1.0,
            context_len: 100,
            gen_len: 5,
            priority: 0,
            generated: 5,
            prefilled: 100,
            scheduled_prefill: 0,
            admitted_at: Some(1.1),
            first_token_at: Some(1.5),
            completed_at: Some(2.3),
        };
        assert!(!r.in_prefill());
        assert!((r.ttft().unwrap() - 0.5).abs() < 1e-12);
        assert!((r.tpot().unwrap() - 0.2).abs() < 1e-12);
        assert!((r.e2e().unwrap() - 1.3).abs() < 1e-12);

        let single = Request { gen_len: 1, generated: 1, ..r.clone() };
        assert!(single.tpot().is_none());
        let mid = Request { prefilled: 40, first_token_at: None, ..r };
        assert!(mid.in_prefill());
        assert_eq!(mid.prefill_remaining(), 60);
        assert!(mid.ttft().is_none());
    }

    #[test]
    fn diurnal_generation_is_deterministic_and_ordered() {
        let a = DiurnalGen::new(DiurnalSpec::default()).generate();
        let b = DiurnalGen::new(DiurnalSpec::default()).generate();
        assert_eq!(a.len(), 100);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.context_len, y.context_len);
            assert_eq!(x.gen_len, y.gen_len);
        }
        for w in a.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        for r in &a {
            assert!((1024..8192).contains(&r.context_len));
            assert!((64..256).contains(&r.gen_len));
        }
    }

    #[test]
    fn diurnal_peak_half_outpaces_the_trough_half() {
        let spec = DiurnalSpec {
            base_rate: 50.0,
            amplitude: 1.0,
            period: 10.0,
            burst_every: f64::INFINITY,
            n_requests: 4000,
            ..Default::default()
        };
        let period = spec.period;
        let reqs = DiurnalGen::new(spec).generate();
        // sin is positive over the first half of each period: arrivals
        // should pile up there.
        let peak = reqs
            .iter()
            .filter(|r| (r.arrival % period) < period / 2.0)
            .count();
        let trough = reqs.len() - peak;
        assert!(peak > 2 * trough, "peak {peak} vs trough {trough}");
    }

    #[test]
    fn bursts_raise_the_realized_rate_above_baseline() {
        let quiet = DiurnalSpec {
            base_rate: 50.0,
            amplitude: 0.0,
            burst_every: f64::INFINITY,
            n_requests: 4000,
            ..Default::default()
        };
        let reqs = DiurnalGen::new(quiet.clone()).generate();
        let rate = reqs.len() as f64 / reqs.last().unwrap().arrival;
        // No swing, no bursts: an ordinary Poisson process at base_rate.
        assert!((rate - 50.0).abs() / 50.0 < 0.1, "rate {rate}");

        let bursty = DiurnalSpec {
            burst_every: 1.0,
            burst_duration: 1.0,
            burst_boost: 4.0,
            ..quiet
        };
        let reqs = DiurnalGen::new(bursty).generate();
        let rate = reqs.len() as f64 / reqs.last().unwrap().arrival;
        // Roughly half the span runs 4x: the realized mean rate must
        // land well above baseline.
        assert!(rate > 75.0, "bursty rate {rate}");
    }

    #[test]
    fn empty_priority_mix_draws_nothing_and_defaults_to_class_zero() {
        // The mix-less spec must replay byte-identically to the
        // pre-priority generator: same arrivals, same lengths, and
        // every request in class 0.
        let base = WorkloadSpec {
            arrival_rate: 25.0,
            n_requests: 200,
            context: (16, 64),
            gen: (4, 32),
            priority_mix: Vec::new(),
            seed: 11,
        };
        let plain = WorkloadGen::new(base.clone()).generate();
        assert!(plain.iter().all(|r| r.priority == 0));

        let mixed = WorkloadGen::new(WorkloadSpec {
            priority_mix: vec![(0, 1.0), (2, 1.0)],
            ..base
        })
        .generate();
        // The per-request class draw lands *after* the length draws, so
        // the first request's arrival and lengths are untouched even
        // with a mix configured.
        assert_eq!(plain[0].arrival, mixed[0].arrival);
        assert_eq!(plain[0].context_len, mixed[0].context_len);
        assert_eq!(plain[0].gen_len, mixed[0].gen_len);
        // Both classes actually appear, roughly at their weights.
        let hi = mixed.iter().filter(|r| r.priority == 2).count();
        assert!(hi > 50 && hi < 150, "class-2 count {hi}");
        assert!(mixed.iter().all(|r| r.priority == 0 || r.priority == 2));
    }

    #[test]
    #[should_panic(expected = "non-positive weight")]
    fn zero_weight_priority_class_is_rejected() {
        WorkloadGen::new(WorkloadSpec {
            priority_mix: vec![(1, 0.0)],
            ..Default::default()
        });
    }

    #[test]
    fn diurnal_priority_mix_tags_requests() {
        let reqs = DiurnalGen::new(DiurnalSpec {
            priority_mix: vec![(1, 3.0), (3, 1.0)],
            n_requests: 400,
            ..Default::default()
        })
        .generate();
        assert!(reqs.iter().all(|r| r.priority == 1 || r.priority == 3));
        let urgent = reqs.iter().filter(|r| r.priority == 3).count();
        assert!(urgent > 40 && urgent < 200, "class-3 count {urgent}");
    }

    #[test]
    fn lengths_respect_ranges() {
        let spec = WorkloadSpec {
            context: (100, 200),
            gen: (10, 20),
            n_requests: 500,
            ..Default::default()
        };
        for r in WorkloadGen::new(spec).generate() {
            assert!((100..200).contains(&r.context_len));
            assert!((10..20).contains(&r.gen_len));
        }
    }
}
