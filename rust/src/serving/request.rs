//! Requests and synthetic workload generation.

use crate::util::rng::Pcg32;

/// One decode request: arrives with a prefilled context of
/// `context_len` tokens and wants `gen_len` new tokens (prefill is
/// served elsewhere, as in disaggregated deployments — the paper's
/// decode-only focus, §2.1).
#[derive(Debug, Clone)]
pub struct Request {
    /// Unique id (assigned by the generator).
    pub id: u64,
    /// Arrival time, seconds.
    pub arrival: f64,
    /// Context length already in the KV cache at admission.
    pub context_len: u64,
    /// Tokens to generate.
    pub gen_len: u64,
    /// Tokens generated so far (mutated by the simulator).
    pub generated: u64,
    /// Admission time (None while queued).
    pub admitted_at: Option<f64>,
    /// Completion time.
    pub completed_at: Option<f64>,
}

impl Request {
    /// Current total sequence length (context + generated).
    pub fn seq_len(&self) -> u64 {
        self.context_len + self.generated
    }

    /// Whether generation is finished.
    pub fn done(&self) -> bool {
        self.generated >= self.gen_len
    }
}

/// Synthetic workload description.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    /// Mean request arrival rate, requests/second (Poisson process).
    pub arrival_rate: f64,
    /// Number of requests to generate.
    pub n_requests: u64,
    /// Context length range `[lo, hi)` (uniform).
    pub context: (u64, u64),
    /// Generation length range `[lo, hi)` (uniform).
    pub gen: (u64, u64),
    /// RNG seed.
    pub seed: u64,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec {
            arrival_rate: 10.0,
            n_requests: 100,
            context: (1024, 8192),
            gen: (64, 256),
            seed: 7,
        }
    }
}

/// Deterministic synthetic workload generator.
pub struct WorkloadGen {
    spec: WorkloadSpec,
    rng: Pcg32,
    next_id: u64,
    clock: f64,
}

impl WorkloadGen {
    /// New generator for a spec.
    pub fn new(spec: WorkloadSpec) -> Self {
        let rng = Pcg32::seed_from(spec.seed);
        WorkloadGen { spec, rng, next_id: 0, clock: 0.0 }
    }

    /// Generate all requests up front (arrival times are a Poisson
    /// process; lengths uniform in their ranges).
    pub fn generate(mut self) -> Vec<Request> {
        let mut out = Vec::with_capacity(self.spec.n_requests as usize);
        for _ in 0..self.spec.n_requests {
            self.clock += self.rng.exp(self.spec.arrival_rate);
            let (clo, chi) = self.spec.context;
            let (glo, ghi) = self.spec.gen;
            out.push(Request {
                id: self.next_id,
                arrival: self.clock,
                context_len: if chi > clo {
                    clo + self.rng.below((chi - clo) as u32) as u64
                } else {
                    clo
                },
                gen_len: if ghi > glo {
                    (glo + self.rng.below((ghi - glo) as u32) as u64).max(1)
                } else {
                    glo.max(1)
                },
                generated: 0,
                admitted_at: None,
                completed_at: None,
            });
            self.next_id += 1;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let a = WorkloadGen::new(WorkloadSpec::default()).generate();
        let b = WorkloadGen::new(WorkloadSpec::default()).generate();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.context_len, y.context_len);
            assert_eq!(x.gen_len, y.gen_len);
        }
    }

    #[test]
    fn arrivals_are_increasing_and_rate_is_close() {
        let spec = WorkloadSpec { arrival_rate: 50.0, n_requests: 2000, ..Default::default() };
        let reqs = WorkloadGen::new(spec).generate();
        for w in reqs.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        let span = reqs.last().unwrap().arrival;
        let rate = 2000.0 / span;
        assert!((rate - 50.0).abs() / 50.0 < 0.1, "rate {rate}");
    }

    #[test]
    fn lengths_respect_ranges() {
        let spec = WorkloadSpec {
            context: (100, 200),
            gen: (10, 20),
            n_requests: 500,
            ..Default::default()
        };
        for r in WorkloadGen::new(spec).generate() {
            assert!((100..200).contains(&r.context_len));
            assert!((10..20).contains(&r.gen_len));
        }
    }
}
