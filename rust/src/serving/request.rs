//! Requests and synthetic workload generation.

use crate::util::rng::Pcg32;

/// One request's full lifecycle: it arrives with a `context_len`-token
/// prompt that must be prefilled into the KV cache, then decodes
/// `gen_len` new tokens. In the legacy decode-only mode (prefill chunk
/// = 0, the paper's §2.1 disaggregated assumption) the batcher admits
/// requests with the prefill already marked complete.
#[derive(Debug, Clone)]
pub struct Request {
    /// Unique id (assigned by the generator).
    pub id: u64,
    /// Arrival time, seconds.
    pub arrival: f64,
    /// Prompt (context) length in tokens.
    pub context_len: u64,
    /// Tokens to generate.
    pub gen_len: u64,
    /// Tokens generated so far (mutated by the simulator).
    pub generated: u64,
    /// Prompt tokens prefilled into the KV cache so far. Equals
    /// `context_len` once the request is decode-ready.
    pub prefilled: u64,
    /// Prefill tokens assigned to the engine step currently in flight
    /// (simulator-internal; consumed by `Batcher::step_complete`).
    pub scheduled_prefill: u64,
    /// Admission time (None while queued).
    pub admitted_at: Option<f64>,
    /// Time the first output token was emitted (the final prefill
    /// chunk's forward pass produces it).
    pub first_token_at: Option<f64>,
    /// Completion time.
    pub completed_at: Option<f64>,
}

impl Request {
    /// Current total sequence length (context + generated) — the KV
    /// footprint the request will reach, which drives attention cost.
    pub fn seq_len(&self) -> u64 {
        self.context_len + self.generated
    }

    /// Whether generation is finished.
    pub fn done(&self) -> bool {
        self.generated >= self.gen_len
    }

    /// Whether prompt ingestion is still in progress.
    pub fn in_prefill(&self) -> bool {
        self.prefilled < self.context_len
    }

    /// Prompt tokens still to prefill.
    pub fn prefill_remaining(&self) -> u64 {
        self.context_len.saturating_sub(self.prefilled)
    }

    /// Time to first token: arrival -> first emitted token.
    pub fn ttft(&self) -> Option<f64> {
        Some(self.first_token_at? - self.arrival)
    }

    /// Steady-state time per output token after the first (None for
    /// single-token generations).
    pub fn tpot(&self) -> Option<f64> {
        if self.generated < 2 {
            return None;
        }
        Some((self.completed_at? - self.first_token_at?) / (self.generated - 1) as f64)
    }

    /// End-to-end latency: arrival -> completion.
    pub fn e2e(&self) -> Option<f64> {
        Some(self.completed_at? - self.arrival)
    }
}

/// Synthetic workload description.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    /// Mean request arrival rate, requests/second (Poisson process).
    pub arrival_rate: f64,
    /// Number of requests to generate.
    pub n_requests: u64,
    /// Context length range `[lo, hi)` (uniform).
    pub context: (u64, u64),
    /// Generation length range `[lo, hi)` (uniform).
    pub gen: (u64, u64),
    /// RNG seed.
    pub seed: u64,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec {
            arrival_rate: 10.0,
            n_requests: 100,
            context: (1024, 8192),
            gen: (64, 256),
            seed: 7,
        }
    }
}

/// Deterministic synthetic workload generator.
pub struct WorkloadGen {
    spec: WorkloadSpec,
    rng: Pcg32,
    next_id: u64,
    clock: f64,
}

impl WorkloadGen {
    /// New generator for a spec.
    pub fn new(spec: WorkloadSpec) -> Self {
        let rng = Pcg32::seed_from(spec.seed);
        WorkloadGen { spec, rng, next_id: 0, clock: 0.0 }
    }

    /// Generate all requests up front (arrival times are a Poisson
    /// process; lengths uniform in their ranges).
    pub fn generate(mut self) -> Vec<Request> {
        let mut out = Vec::with_capacity(self.spec.n_requests as usize);
        for _ in 0..self.spec.n_requests {
            self.clock += self.rng.exp(self.spec.arrival_rate);
            let (clo, chi) = self.spec.context;
            let (glo, ghi) = self.spec.gen;
            out.push(Request {
                id: self.next_id,
                arrival: self.clock,
                context_len: if chi > clo {
                    clo + self.rng.below((chi - clo) as u32) as u64
                } else {
                    clo
                },
                gen_len: if ghi > glo {
                    (glo + self.rng.below((ghi - glo) as u32) as u64).max(1)
                } else {
                    glo.max(1)
                },
                generated: 0,
                prefilled: 0,
                scheduled_prefill: 0,
                admitted_at: None,
                first_token_at: None,
                completed_at: None,
            });
            self.next_id += 1;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let a = WorkloadGen::new(WorkloadSpec::default()).generate();
        let b = WorkloadGen::new(WorkloadSpec::default()).generate();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.context_len, y.context_len);
            assert_eq!(x.gen_len, y.gen_len);
        }
    }

    #[test]
    fn arrivals_are_increasing_and_rate_is_close() {
        let spec = WorkloadSpec { arrival_rate: 50.0, n_requests: 2000, ..Default::default() };
        let reqs = WorkloadGen::new(spec).generate();
        for w in reqs.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        let span = reqs.last().unwrap().arrival;
        let rate = 2000.0 / span;
        assert!((rate - 50.0).abs() / 50.0 < 0.1, "rate {rate}");
    }

    #[test]
    fn slo_helpers_compute_ttft_tpot_e2e() {
        let r = Request {
            id: 0,
            arrival: 1.0,
            context_len: 100,
            gen_len: 5,
            generated: 5,
            prefilled: 100,
            scheduled_prefill: 0,
            admitted_at: Some(1.1),
            first_token_at: Some(1.5),
            completed_at: Some(2.3),
        };
        assert!(!r.in_prefill());
        assert!((r.ttft().unwrap() - 0.5).abs() < 1e-12);
        assert!((r.tpot().unwrap() - 0.2).abs() < 1e-12);
        assert!((r.e2e().unwrap() - 1.3).abs() < 1e-12);

        let single = Request { gen_len: 1, generated: 1, ..r.clone() };
        assert!(single.tpot().is_none());
        let mid = Request { prefilled: 40, first_token_at: None, ..r };
        assert!(mid.in_prefill());
        assert_eq!(mid.prefill_remaining(), 60);
        assert!(mid.ttft().is_none());
    }

    #[test]
    fn lengths_respect_ranges() {
        let spec = WorkloadSpec {
            context: (100, 200),
            gen: (10, 20),
            n_requests: 500,
            ..Default::default()
        };
        for r in WorkloadGen::new(spec).generate() {
            assert!((100..200).contains(&r.context_len));
            assert!((10..20).contains(&r.gen_len));
        }
    }
}
