//! Request arena: every [`Request`] of a run owned in one slab, handled
//! by dense [`ReqId`] indices.
//!
//! The simulators' hot path used to move `Request` structs by value —
//! through the event calendar, the batcher's queues, and per-instance
//! `finished` lists — cloning a ~100-byte struct at every hop. The
//! arena inverts that: a simulator allocates each request into a
//! [`RequestArena`] once, and a 4-byte copyable [`ReqId`] flows through
//! [`Batcher`](super::Batcher) / [`Instance`](super::Instance) /
//! [`crate::cluster::ClusterSim`] instead. Lookups are direct `Vec`
//! indexing (no hashing), retirement moves one `u32`, and reports
//! resolve ids back to request state at the very end of the run.
//!
//! Slots are never freed individually: a run allocates monotonically
//! and drops the whole arena at once, which is exactly the lifetime of
//! a simulation. That makes ids stable for the run — safe to park in
//! events, side tables, and finished lists.

use std::ops::{Index, IndexMut};

use super::request::Request;

/// Dense handle to a [`Request`] in a [`RequestArena`]. Copyable and
/// 4 bytes wide, so events and batcher queues move ids, not structs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ReqId(u32);

impl ReqId {
    /// The arena slot this id addresses (for parallel side tables).
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// Slab of [`Request`]s with monotone allocation; see the module docs.
#[derive(Debug, Default)]
pub struct RequestArena {
    reqs: Vec<Request>,
}

impl RequestArena {
    /// Empty arena.
    pub fn new() -> RequestArena {
        RequestArena { reqs: Vec::new() }
    }

    /// Empty arena with room for `n` requests before reallocating.
    pub fn with_capacity(n: usize) -> RequestArena {
        RequestArena { reqs: Vec::with_capacity(n) }
    }

    /// Move a request into the arena, returning its id.
    pub fn alloc(&mut self, r: Request) -> ReqId {
        let idx = self.reqs.len();
        assert!(idx <= u32::MAX as usize, "request arena overflow");
        self.reqs.push(r);
        ReqId(idx as u32)
    }

    /// Number of requests allocated so far.
    pub fn len(&self) -> usize {
        self.reqs.len()
    }

    /// Whether nothing has been allocated.
    pub fn is_empty(&self) -> bool {
        self.reqs.is_empty()
    }

    /// Iterate every allocated request with its id, in allocation
    /// order (the invariant checker's token-accounting sweep).
    pub fn iter(&self) -> impl Iterator<Item = (ReqId, &Request)> {
        self.reqs.iter().enumerate().map(|(i, r)| (ReqId(i as u32), r))
    }
}

impl Index<ReqId> for RequestArena {
    type Output = Request;

    fn index(&self, id: ReqId) -> &Request {
        &self.reqs[id.index()]
    }
}

impl IndexMut<ReqId> for RequestArena {
    fn index_mut(&mut self, id: ReqId) -> &mut Request {
        &mut self.reqs[id.index()]
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::mk_req;
    use super::*;

    #[test]
    fn alloc_returns_dense_ids_and_indexing_round_trips() {
        let mut arena = RequestArena::new();
        assert!(arena.is_empty());
        let a = arena.alloc(mk_req(10, 0.0, 8, 2));
        let b = arena.alloc(mk_req(11, 0.5, 16, 4));
        assert_eq!(a.index(), 0);
        assert_eq!(b.index(), 1);
        assert_eq!(arena.len(), 2);
        assert_eq!(arena[a].id, 10);
        assert_eq!(arena[b].context_len, 16);
    }

    #[test]
    fn ids_stay_valid_across_growth_and_mutation() {
        let mut arena = RequestArena::with_capacity(1);
        let first = arena.alloc(mk_req(0, 0.0, 4, 1));
        // Grow well past the initial capacity; the dense id (an index,
        // not a pointer) must keep addressing the same request.
        for i in 1..100 {
            arena.alloc(mk_req(i, 0.0, 4, 1));
        }
        arena[first].generated = 7;
        assert_eq!(arena[first].id, 0);
        assert_eq!(arena[first].generated, 7);
        assert_eq!(arena.len(), 100);
    }

    #[test]
    fn ids_are_copy_and_comparable() {
        let mut arena = RequestArena::new();
        let a = arena.alloc(mk_req(0, 0.0, 4, 1));
        let also_a = a; // Copy
        assert_eq!(a, also_a);
        let b = arena.alloc(mk_req(1, 0.0, 4, 1));
        assert_ne!(a, b);
    }
}
