//! Shared test scaffolding for the serving and cluster test modules:
//! request builders, KV budgets, and deterministic step engines that
//! used to be copy-pasted across `sim.rs` / `batcher.rs` / `cluster`.
//!
//! Compiled only for unit tests (`#[cfg(test)]` at the declaration
//! site); integration tests under `tests/` build their own fixtures
//! because the library's test-only items are not visible there.

use super::batcher::KvBudget;
use super::engine::StepEngine;
use super::request::Request;

/// Build a request with every simulator-mutated field zeroed.
pub fn mk_req(id: u64, arrival: f64, ctx: u64, gen: u64) -> Request {
    Request {
        id,
        arrival,
        context_len: ctx,
        gen_len: gen,
        priority: 0,
        generated: 0,
        prefilled: 0,
        scheduled_prefill: 0,
        admitted_at: None,
        first_token_at: None,
        completed_at: None,
    }
}

/// A KV budget that never gates admission.
pub fn open_budget() -> KvBudget {
    KvBudget::new(1e9, 0.0, 1.0)
}

/// A KV budget holding exactly `tokens` token-slots (1 byte/token).
pub fn budget(tokens: u64) -> KvBudget {
    KvBudget::new(tokens as f64, 0.0, 1.0)
}

/// A constant-latency engine for deterministic timelines (free when the
/// batch is empty).
pub struct FixedEngine(pub f64);

impl StepEngine for FixedEngine {
    fn step_latency(&mut self, batch: u64, _ctx: u64) -> f64 {
        if batch == 0 {
            0.0
        } else {
            self.0
        }
    }
    fn name(&self) -> String {
        "fixed".into()
    }
}

/// Step latency proportional to the lane count — the shape that exposes
/// per-step-averaged (instead of duration-weighted) batch statistics.
pub struct BatchProportionalEngine(pub f64);

impl StepEngine for BatchProportionalEngine {
    fn step_latency(&mut self, batch: u64, _ctx: u64) -> f64 {
        self.0 * batch as f64
    }
    fn name(&self) -> String {
        "batch-proportional".into()
    }
}
