//! Serving simulator: a vLLM-router-style continuous-batching engine
//! over the LIMINAL substrate, covering the full request lifecycle —
//! queueing, chunked prefill, and decode.
//!
//! Two latency backends plug into the same scheduler:
//!
//! * [`AnalyticEngine`] — per-step latency from the LIMINAL model, used
//!   to explore paper-scale systems (TP128 clusters serving Llama3-405B)
//!   under dynamic load instead of the steady-state closed forms. It
//!   prices mixed prefill + decode steps by fusing both workloads onto
//!   one roofline (weights stream once per step).
//! * [`PjrtEngine`] — the real thing at small scale: executes the
//!   AOT-compiled JAX/Pallas decode step through PJRT, measuring true
//!   wall-clock including every software overhead the paper's limit
//!   study idealizes away (Appendix E's "simulated tokens/sec" analog).
//!
//! # Step semantics
//!
//! The scheduler is a discrete-event simulation ([`crate::des`]) with
//! Poisson arrivals and a FIFO admission queue gated by KV capacity.
//! The fidelity rules, each pinned by a regression test:
//!
//! * **Admission points.** Requests are admitted only at step
//!   boundaries (or while the engine is idle). A request arriving
//!   mid-step waits for the in-flight step to complete: it can never be
//!   credited a token from a step it was not priced into.
//! * **Prefill chunking.** An admitted request's prompt is ingested in
//!   chunks of at most `prefill_chunk` tokens per step
//!   ([`Batcher::with_prefill`]). At most one prompt's chunk runs per
//!   step (Sarathi-style), chosen FIFO by admission, sharing the step
//!   with decode-ready lanes (mixed steps). The final chunk's forward
//!   pass emits the first output token; only then does the request
//!   enter decode. With the chunk set to 0 the simulator reverts to the
//!   paper's decode-only assumption (prompts prefilled elsewhere, as in
//!   disaggregated serving).
//! * **SLO metrics.** [`ServingReport`] aggregates TTFT (arrival to
//!   first token), TPOT (steady-state inter-token time), and E2E
//!   latency as mean/p50/p90/p99 ([`LatencyStats`]), plus
//!   duration-weighted batch occupancy and system tokens/sec.

mod batcher;
mod engine;
mod metrics;
mod pjrt_engine;
mod request;
mod sim;

pub use batcher::{Batcher, KvBudget};
pub use engine::{AnalyticEngine, StepBatch, StepEngine};
pub use metrics::{percentile, LatencyStats, ServingReport, StepStats};
pub use pjrt_engine::PjrtEngine;
pub use request::{Request, WorkloadGen, WorkloadSpec};
pub use sim::{ServingSim, SimConfig};
