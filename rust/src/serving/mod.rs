//! Serving simulator: a vLLM-router-style continuous-batching engine
//! over the LIMINAL substrate, covering the full request lifecycle —
//! queueing, chunked prefill, and decode.
//!
//! Two latency backends plug into the same scheduler:
//!
//! * [`AnalyticEngine`] — per-step latency from the LIMINAL model, used
//!   to explore paper-scale systems (TP128 clusters serving Llama3-405B)
//!   under dynamic load instead of the steady-state closed forms. It
//!   prices mixed prefill + decode steps by fusing both workloads onto
//!   one roofline (weights stream once per step).
//! * [`PjrtEngine`] — the real thing at small scale: executes the
//!   AOT-compiled JAX/Pallas decode step through PJRT, measuring true
//!   wall-clock including every software overhead the paper's limit
//!   study idealizes away (Appendix E's "simulated tokens/sec" analog).
//!
//! # Architecture: an arena of requests, instances on a shared calendar
//!
//! Request state lives in a [`RequestArena`]: a simulator allocates
//! every workload request into the slab once and a dense, copyable
//! [`ReqId`] flows through the rest of the machinery — the event
//! calendar, the batcher's admission queue and active set, and each
//! instance's finished list all move 4-byte ids, never `Request`
//! structs. Steady-state stepping therefore allocates nothing: lookups
//! are `Vec` indexing and retirement reuses per-batcher scratch
//! buffers. Reports resolve ids back to request state only at the end
//! of a run.
//!
//! The unit of serving is an [`Instance`]: one model replica's
//! [`Batcher`] (admission queue + KV budget + chunk planner) fused to
//! one [`StepEngine`], exposing exactly two transitions — `kick` (admit,
//! plan, price a step) and `step_done` (apply the priced plan). An
//! instance never owns a clock or the arena: *simulators* own a single
//! [`des::EventQueue`](crate::des) plus the arena, and drive instances
//! with [`InstanceEvent`]s keyed by instance id. [`ServingSim`] is the
//! one-instance driver; [`crate::cluster::ClusterSim`] multiplexes N
//! instances (plus routing and KV-shipment events) on the same calendar
//! type, so cross-instance causality is totally ordered and seeded runs
//! replay exactly. A one-instance cluster behind a pass-through router
//! is step-for-step identical to [`ServingSim`] — pinned by the
//! equivalence test in `tests/integration_cluster.rs`.
//!
//! # Step semantics
//!
//! The fidelity rules, each pinned by a regression test:
//!
//! * **Admission points.** Requests are admitted only at step
//!   boundaries (or while the engine is idle). A request arriving
//!   mid-step waits for the in-flight step to complete: it can never be
//!   credited a token from a step it was not priced into.
//! * **Prefill chunking.** An admitted request's prompt is ingested in
//!   chunks of at most `prefill_chunk` tokens per step
//!   ([`Batcher::with_prefill`]). At most one prompt's chunk runs per
//!   step (Sarathi-style), chosen FIFO by admission, sharing the step
//!   with decode-ready lanes (mixed steps). The final chunk's forward
//!   pass emits the first output token; only then does the request
//!   enter decode. With the chunk set to 0 the simulator reverts to the
//!   paper's decode-only assumption (prompts prefilled elsewhere, as in
//!   disaggregated serving — which is exactly how the cluster's decode
//!   pools run).
//! * **Occupancy is duration-weighted and charged at completion.**
//!   `mean_batch` integrates lanes over busy time, and a step cut short
//!   by `max_steps`/`max_time` is never charged, so busy time cannot
//!   exceed the simulated span.
//! * **SLO metrics.** [`ServingReport`] aggregates TTFT (arrival to
//!   first token), TPOT (steady-state inter-token time), and E2E
//!   latency as mean/p50/p90/p99 ([`LatencyStats`]), plus
//!   duration-weighted batch occupancy and system tokens/sec.
//!
//! # Priority and preemption
//!
//! Every request carries a scheduling class ([`Request::priority`],
//! higher = more urgent; workload generators draw it from a configured
//! class mix, traces carry it per record). The [`Batcher`] admits by
//! class — FIFO within a class, so single-class workloads reproduce the
//! historical FIFO batcher bit for bit. With preemption enabled
//! ([`PreemptionConfig`]) a higher-priority arrival that does not fit
//! the KV budget may evict the lowest-class active request: the
//! victim's KV is released immediately, it resumes from the queue front
//! once capacity frees, and the configured evict/restore costs are
//! priced into step time (so the stalls land in TTFT/TPOT, never
//! disappear). [`SimObserver::on_preempt`]/[`SimObserver::on_restore`]
//! expose the lifecycle to observers; the DST invariant checker audits
//! it (zero reserved KV while evicted, no double eviction, exact KV
//! conservation through evict/restore).
//!
//! # Workloads
//!
//! [`WorkloadGen`] synthesizes Poisson arrivals with uniform
//! prompt/generation lengths and an optional priority-class mix;
//! [`DiurnalGen`] synthesizes a non-homogeneous Poisson process
//! (sinusoidal diurnal swing plus burst episodes, by thinning) for
//! elastic-fleet studies; [`WorkloadTrace`] replays recorded JSONL/CSV
//! traces (`arrival, context_len, gen_len[, priority]` per record) for
//! trace-driven studies (`serve --trace`).

mod arena;
mod batcher;
mod engine;
mod instance;
mod metrics;
mod observe;
mod pjrt_engine;
mod request;
mod sim;
#[cfg(test)]
pub(crate) mod testutil;
mod trace;

pub use arena::{ReqId, RequestArena};
pub use batcher::{Batcher, KvBudget, PreemptionConfig, SchedAction};
pub use engine::{AnalyticEngine, StepBatch, StepEngine};
pub use instance::{Instance, InstanceEvent};
pub use metrics::{percentile, LatencyStats, ServingReport, StepStats};
pub use observe::{NoopObserver, SimObserver};
pub use pjrt_engine::PjrtEngine;
pub use request::{DiurnalGen, DiurnalSpec, Request, WorkloadGen, WorkloadSpec};
pub use sim::{ServingSim, SimConfig};
pub use trace::WorkloadTrace;
