//! Decode serving simulator: a vLLM-router-style continuous-batching
//! engine over the LIMINAL substrate.
//!
//! Two latency backends plug into the same scheduler:
//!
//! * [`AnalyticEngine`] — per-step latency from the LIMINAL model, used
//!   to explore paper-scale systems (TP128 clusters serving Llama3-405B)
//!   under dynamic load instead of the steady-state closed forms.
//! * [`PjrtEngine`] — the real thing at small scale: executes the
//!   AOT-compiled JAX/Pallas decode step through PJRT, measuring true
//!   wall-clock including every software overhead the paper's limit
//!   study idealizes away (Appendix E's "simulated tokens/sec" analog).
//!
//! The scheduler is a discrete-event simulation ([`crate::des`]): Poisson
//! arrivals, a FIFO admission queue, KV-capacity-gated continuous
//! batching, and per-request completion tracking.

mod batcher;
mod engine;
mod metrics;
mod pjrt_engine;
mod request;
mod sim;

pub use batcher::{Batcher, KvBudget};
pub use engine::{AnalyticEngine, StepEngine};
pub use metrics::{percentile, ServingReport};
pub use pjrt_engine::PjrtEngine;
pub use request::{Request, WorkloadGen, WorkloadSpec};
pub use sim::{ServingSim, SimConfig};
