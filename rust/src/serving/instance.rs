//! One serving instance: the batcher + engine state machine that both
//! the single-instance simulator ([`ServingSim`](super::ServingSim)) and
//! the cluster simulator ([`crate::cluster::ClusterSim`]) drive from a
//! shared [`EventQueue`](crate::des::EventQueue).
//!
//! The simulator owns the event calendar and the [`RequestArena`]; the
//! instance owns everything inside one model replica — admission queue,
//! KV budget, chunk planner, the engine that prices steps, and the
//! occupancy statistics — and touches request state only through dense
//! [`ReqId`] handles the simulator passes in with the arena. The split
//! is the contract that makes multi-instance serving possible at all:
//! N instances multiplex on *one* clock by keying their
//! [`InstanceEvent::StepDone`] events with an instance id, so
//! cross-instance causality (routing, KV shipment) is totally ordered
//! and seeded runs replay exactly. Events carry ids, never `Request`
//! structs, so the calendar and the per-instance finished lists move
//! 4-byte copies.
//!
//! Step semantics are exactly the single-simulator fidelity rules:
//! admission only at step boundaries ([`Instance::kick`] admits, plans,
//! and prices atomically), plan/price/complete, and duration-weighted
//! occupancy. Occupancy integrals are charged when a step *completes*
//! ([`Instance::step_done`]), so a run truncated by `max_steps` or
//! `max_time` never counts a step that did not finish — busy time can
//! never exceed the simulated span.

use super::arena::{ReqId, RequestArena};
use super::batcher::Batcher;
use super::engine::StepEngine;
use super::metrics::{ServingReport, StepStats};

/// Events driving instances on a shared event calendar. The single-
/// instance simulator uses instance id 0 throughout; the cluster keys
/// every completion and KV shipment by the instance it lands on.
/// Carries only dense ids, so the enum is `Copy` and the calendar never
/// moves request state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InstanceEvent {
    /// A request arriving at the front door (router or lone instance).
    Arrival(ReqId),
    /// The in-flight step of instance `id` completed.
    StepDone(usize),
    /// A prefilled request's KV cache finished its interconnect
    /// transfer and lands at decode instance `id` (disaggregated mode).
    KvArrive(usize, ReqId),
    /// Instance `id`, spawned by the cluster's autoscaler, finished
    /// warming up and joins placement (cluster only). Scheduled
    /// `warmup_delay` seconds after the spawn decision, so scaling is
    /// never free.
    WarmupDone(usize),
}

/// One model instance: a [`Batcher`] + [`StepEngine`] pair plus its
/// accounting. The engine is stored as a box over any lifetime so a
/// simulator can either own its engine (`Box<dyn StepEngine>`, the
/// cluster) or borrow one (`Box<&mut dyn StepEngine>`, the
/// single-instance simulator's public API).
pub struct Instance<'e> {
    batcher: Batcher,
    engine: Box<dyn StepEngine + 'e>,
    /// The in-flight step's `(latency, lanes)`, if any.
    in_flight: Option<(f64, u64)>,
    stats: StepStats,
    /// Ids of requests retired on this instance (a disaggregated
    /// request's ingestion sub-request retires on its prefill instance,
    /// the full request on its decode instance).
    finished: Vec<ReqId>,
    /// Full KV footprint of everything routed here and not yet retired.
    outstanding_kv_bytes: f64,
    /// Generation-token backlog routed here and not yet retired.
    outstanding_gen_tokens: u64,
    /// Prompt tokens routed here (pending = this - batcher's processed).
    routed_prefill_tokens: u64,
    /// EWMA of recent step latencies (router TTFT-prediction input).
    ewma_step: f64,
}

impl<'e> Instance<'e> {
    /// Wrap a batcher and an engine into an instance.
    pub fn new(batcher: Batcher, engine: Box<dyn StepEngine + 'e>) -> Self {
        Instance {
            batcher,
            engine,
            in_flight: None,
            stats: StepStats::default(),
            finished: Vec::new(),
            outstanding_kv_bytes: 0.0,
            outstanding_gen_tokens: 0,
            routed_prefill_tokens: 0,
            ewma_step: 0.0,
        }
    }

    /// Hand a routed request to this instance's admission queue,
    /// charging the routed-load accounting the router snapshots read.
    pub fn enqueue(&mut self, id: ReqId, arena: &RequestArena) {
        let r = &arena[id];
        let bpt = self.batcher.kv_bytes_per_token();
        self.outstanding_kv_bytes += (r.context_len + r.gen_len) as f64 * bpt;
        self.outstanding_gen_tokens += r.gen_len;
        if self.batcher.prefill_chunk() > 0 {
            self.routed_prefill_tokens += r.context_len;
        }
        self.batcher.enqueue(id, arena);
    }

    /// Set the batcher's preemption policy (see
    /// [`PreemptionConfig`](super::PreemptionConfig)).
    pub fn set_preemption(&mut self, cfg: super::batcher::PreemptionConfig) {
        self.batcher.set_preemption(cfg);
    }

    /// Move the batcher's preempt/restore actions since the last drain
    /// into `out` (cleared first); the simulator forwards them to its
    /// observer.
    pub fn drain_sched_log(
        &mut self,
        out: &mut Vec<(ReqId, super::batcher::SchedAction)>,
    ) {
        self.batcher.drain_sched_log(out);
    }

    /// Total KV evictions performed by this instance's batcher.
    pub fn preemptions(&self) -> u64 {
        self.batcher.preemptions()
    }

    /// Total restores of previously evicted requests.
    pub fn restores(&self) -> u64 {
        self.batcher.restores()
    }

    /// Requests currently evicted from this instance and awaiting
    /// re-admission.
    pub fn evicted_pending_len(&self) -> usize {
        self.batcher.evicted_pending_len()
    }

    /// Sum of the active batch's per-request KV footprints (must always
    /// equal [`Instance::kv_used_bytes`]; the DST checker cross-checks).
    pub fn active_kv_bytes(&self, arena: &RequestArena) -> f64 {
        self.batcher.active_kv_bytes(arena)
    }

    /// Step boundary (or idle): admit queued requests, plan the next
    /// step, and price it. Returns the step latency to schedule a
    /// [`InstanceEvent::StepDone`] at, or `None` when a step is already
    /// in flight or there is no work.
    pub fn kick(&mut self, now: f64, arena: &mut RequestArena) -> Option<f64> {
        if self.in_flight.is_some() {
            return None;
        }
        self.batcher.admit(now, arena);
        let plan = self.batcher.plan_step(arena);
        if plan.is_empty() {
            return None;
        }
        // The evict/restore penalty is exactly 0.0 unless preemption
        // fired, so this add is a bitwise no-op on the default path.
        let dt = self.engine.mixed_step_latency(&plan) + self.batcher.take_step_penalty();
        self.ewma_step = if self.ewma_step == 0.0 {
            dt
        } else {
            0.2 * dt + 0.8 * self.ewma_step
        };
        self.in_flight = Some((dt, plan.lanes()));
        Some(dt)
    }

    /// Complete the in-flight step: charge its occupancy integral,
    /// apply the planned token movement, and retire finished requests.
    /// The retired ids are returned (for cluster-level handling — KV
    /// shipment, lifecycle merging) and also recorded in this
    /// instance's own `finished` list for its per-instance report. The
    /// returned slice borrows the batcher's reusable retirement buffer
    /// and is valid until the next step completes.
    pub fn step_done(&mut self, now: f64, arena: &mut RequestArena) -> &[ReqId] {
        if let Some((dt, lanes)) = self.in_flight.take() {
            self.stats.busy_time += dt;
            self.stats.batch_time_integral += lanes as f64 * dt;
        }
        self.stats.steps += 1;
        let bpt = self.batcher.kv_bytes_per_token();
        let retired = self.batcher.step_complete(now, arena);
        for &id in retired {
            let r = &arena[id];
            let bytes = (r.context_len + r.gen_len) as f64 * bpt;
            self.outstanding_kv_bytes = (self.outstanding_kv_bytes - bytes).max(0.0);
            self.outstanding_gen_tokens =
                self.outstanding_gen_tokens.saturating_sub(r.gen_len);
            self.finished.push(id);
        }
        retired
    }

    /// Steps completed so far.
    pub fn steps(&self) -> u64 {
        self.stats.steps
    }

    /// Whether a step is currently in flight.
    pub fn busy(&self) -> bool {
        self.in_flight.is_some()
    }

    /// Requests queued at the instance (not yet admitted).
    pub fn queued_len(&self) -> usize {
        self.batcher.queued_len()
    }

    /// Requests active on the instance (prefilling or decoding).
    pub fn active_len(&self) -> usize {
        self.batcher.active_len()
    }

    /// The instance's batch cap.
    pub fn max_batch(&self) -> usize {
        self.batcher.max_batch
    }

    /// The instance's prefill chunk size (0 = decode-only).
    pub fn prefill_chunk(&self) -> u64 {
        self.batcher.prefill_chunk()
    }

    /// KV bytes committed to the instance (queued + active footprint).
    pub fn outstanding_kv_bytes(&self) -> f64 {
        self.outstanding_kv_bytes
    }

    /// KV bytes actually reserved by the active batch (the invariant
    /// `kv_used_bytes() <= kv_budget_bytes()` must hold at all times).
    pub fn kv_used_bytes(&self) -> f64 {
        self.batcher.kv_used_bytes()
    }

    /// Total KV bytes this instance's budget may reserve.
    pub fn kv_budget_bytes(&self) -> f64 {
        self.batcher.kv_budget_bytes()
    }

    /// Generation tokens committed to the instance and not yet retired.
    pub fn outstanding_gen_tokens(&self) -> u64 {
        self.outstanding_gen_tokens
    }

    /// Prompt tokens routed here that are not yet prefilled.
    pub fn pending_prefill_tokens(&self) -> u64 {
        self.routed_prefill_tokens
            .saturating_sub(self.batcher.prefill_tokens_processed())
    }

    /// Prompts routed here that are not yet fully ingested.
    pub fn pending_prefill_prompts(&self, arena: &RequestArena) -> u64 {
        self.batcher.prefill_backlog(arena) as u64
    }

    /// Exponentially-weighted mean of recent step latencies, seconds
    /// (0 until the first step is priced).
    pub fn ewma_step(&self) -> f64 {
        self.ewma_step
    }

    /// The engine's backend name.
    pub fn engine_name(&self) -> String {
        self.engine.name()
    }

    /// Ids of requests retired on this instance so far.
    pub fn finished(&self) -> &[ReqId] {
        &self.finished
    }

    /// Step accounting with the prefill total and end time filled in.
    pub fn stats(&self, end_time: f64) -> StepStats {
        StepStats {
            prefill_tokens: self.batcher.prefill_tokens_processed(),
            preemptions: self.batcher.preemptions(),
            restores: self.batcher.restores(),
            end_time,
            ..self.stats
        }
    }

    /// Per-instance serving report over the requests retired here.
    pub fn report(
        &self,
        name: String,
        end_time: f64,
        arena: &RequestArena,
    ) -> ServingReport {
        ServingReport::from_refs(
            name,
            self.finished.iter().map(|&id| &arena[id]),
            &self.stats(end_time),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{mk_req, open_budget, FixedEngine};
    use super::*;

    #[test]
    fn kick_admits_prices_and_step_done_retires() {
        let mut a = RequestArena::new();
        let batcher = Batcher::new(4, open_budget());
        let mut inst = Instance::new(batcher, Box::new(FixedEngine(0.1)));
        assert_eq!(inst.kick(0.0, &mut a), None, "no work yet");
        let r0 = a.alloc(mk_req(0, 0.0, 8, 2));
        inst.enqueue(r0, &a);
        assert_eq!(inst.outstanding_gen_tokens(), 2);
        assert_eq!(inst.kick(0.0, &mut a), Some(0.1));
        assert!(inst.busy());
        assert_eq!(inst.kick(0.0, &mut a), None, "step already in flight");
        assert!(inst.step_done(0.1, &mut a).is_empty());
        assert_eq!(inst.kick(0.1, &mut a), Some(0.1));
        let done = inst.step_done(0.2, &mut a);
        assert_eq!(done.len(), 1);
        assert_eq!(inst.steps(), 2);
        assert_eq!(inst.outstanding_gen_tokens(), 0);
        assert_eq!(inst.finished().len(), 1);
        let rep = inst.report("t".into(), 0.2, &a);
        assert_eq!(rep.completed, 1);
        assert_eq!(rep.tokens, 2);
        assert!((rep.mean_batch - 1.0).abs() < 1e-12);
    }

    #[test]
    fn occupancy_is_charged_at_completion_not_scheduling() {
        let mut a = RequestArena::new();
        let batcher = Batcher::new(4, open_budget());
        let mut inst = Instance::new(batcher, Box::new(FixedEngine(0.1)));
        let r0 = a.alloc(mk_req(0, 0.0, 8, 1));
        inst.enqueue(r0, &a);
        inst.kick(0.0, &mut a);
        // In flight but not completed: nothing charged yet.
        assert_eq!(inst.stats(0.05).busy_time, 0.0);
        assert_eq!(inst.stats(0.05).steps, 0);
        inst.step_done(0.1, &mut a);
        let st = inst.stats(0.1);
        assert!((st.busy_time - 0.1).abs() < 1e-12);
        assert_eq!(st.steps, 1);
    }

    #[test]
    fn ewma_tracks_step_latency() {
        let mut a = RequestArena::new();
        let batcher = Batcher::new(4, open_budget());
        let mut inst = Instance::new(batcher, Box::new(FixedEngine(0.25)));
        let r0 = a.alloc(mk_req(0, 0.0, 8, 3));
        inst.enqueue(r0, &a);
        inst.kick(0.0, &mut a);
        inst.step_done(0.25, &mut a);
        assert!((inst.ewma_step() - 0.25).abs() < 1e-12);
        inst.kick(0.25, &mut a);
        inst.step_done(0.5, &mut a);
        // Constant latency: the EWMA stays put.
        assert!((inst.ewma_step() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn eviction_penalty_prices_into_the_next_step() {
        use super::super::batcher::PreemptionConfig;
        use super::super::testutil::budget;

        let mut a = RequestArena::new();
        let mut batcher = Batcher::new(8, budget(30));
        batcher.set_preemption(PreemptionConfig {
            enabled: true,
            evict_cost: 0.5,
            restore_cost: 0.25,
        });
        let mut inst = Instance::new(batcher, Box::new(FixedEngine(0.1)));
        let r0 = a.alloc(mk_req(0, 0.0, 10, 5));
        let r1 = a.alloc(mk_req(1, 0.0, 10, 5));
        inst.enqueue(r0, &a);
        inst.enqueue(r1, &a);
        assert_eq!(inst.kick(0.0, &mut a), Some(0.1));
        inst.step_done(0.1, &mut a);
        let hi = a.alloc(mk_req(2, 0.1, 10, 5));
        a[hi].priority = 1;
        inst.enqueue(hi, &a);
        // The kick that evicts prices the evict cost into its step.
        assert_eq!(inst.kick(0.1, &mut a), Some(0.6));
        assert_eq!(inst.preemptions(), 1);
        assert_eq!(inst.evicted_pending_len(), 1);
        let mut log = Vec::new();
        inst.drain_sched_log(&mut log);
        assert_eq!(log.len(), 1);
        inst.step_done(0.7, &mut a);
        // The following step carries no stale penalty.
        assert_eq!(inst.kick(0.7, &mut a), Some(0.1));
        let st = inst.stats(1.0);
        assert_eq!(st.preemptions, 1);
        assert_eq!(st.restores, 0);
        // Reservation and footprint stay consistent through eviction.
        assert!((inst.active_kv_bytes(&a) - inst.kv_used_bytes()).abs() < 1e-9);
    }
}
