//! Observation hooks for the simulators: a [`SimObserver`] is invited
//! into [`ServingSim::run_with`](super::ServingSim::run_with) and
//! [`crate::cluster::ClusterSim::run_with`] and sees every applied
//! event, routing decision, and retirement as it happens.
//!
//! The trait exists for the deterministic simulation-testing harness
//! ([`crate::dst`]): its invariant checker audits conservation, KV
//! accounting, and clock monotonicity after every event without the
//! simulators growing any test-only state. Every method has an empty
//! default body and the simulators are generic over the observer, so
//! the production entry points (`run`, which passes [`NoopObserver`])
//! monomorphize to exactly the pre-hook code — the hot path pays
//! nothing for the instrumentation.
//!
//! Hook order within one applied event: the lifecycle hooks
//! ([`SimObserver::on_route`], [`SimObserver::on_shed`],
//! [`SimObserver::on_sub_request`], [`SimObserver::on_retire`]) fire
//! while the event is being applied, and [`SimObserver::post_event`]
//! fires once at the end of the loop iteration — after the event *and*
//! the step-boundary kick (admission + planning + pricing), so the
//! observer sees the post-admission KV state the invariants constrain.

use super::arena::{ReqId, RequestArena};
use super::instance::{Instance, InstanceEvent};

/// Passive observer of a simulation run; see the module docs. All
/// methods default to no-ops so observers implement only what they
/// audit.
pub trait SimObserver {
    /// An event was applied and the step-boundary kick that followed it
    /// has run. `instances` is every instance of the simulation (one
    /// for [`ServingSim`](super::ServingSim), N for the cluster).
    fn post_event(
        &mut self,
        _now: f64,
        _ev: &InstanceEvent,
        _instances: &[Instance<'_>],
        _arena: &RequestArena,
    ) {
    }

    /// A front-door arrival was routed to `instance` (always 0 in the
    /// single-instance simulator).
    fn on_route(&mut self, _now: f64, _id: ReqId, _instance: usize) {}

    /// A front-door arrival was shed by the router (cluster only).
    fn on_shed(&mut self, _now: f64, _id: ReqId) {}

    /// A disaggregated prefill pool cloned routed request `orig` into
    /// pure-ingestion sub-request `sub` (cluster only). `orig` parks in
    /// the arena until the sub-request's KV ships.
    fn on_sub_request(&mut self, _now: f64, _orig: ReqId, _sub: ReqId) {}

    /// Request `id` retired on `instance`. `lifecycle_done` is false
    /// for a prefill pool's ingestion sub-request (the original request
    /// lives on toward the decode pool) and true when the request's
    /// full lifecycle completed.
    fn on_retire(
        &mut self,
        _now: f64,
        _instance: usize,
        _id: ReqId,
        _lifecycle_done: bool,
        _arena: &RequestArena,
    ) {
    }

    /// Request `id`'s KV was evicted from `instance` by a higher-
    /// priority admission; it re-entered the instance's queue (front)
    /// with its token progress intact and holds no KV reservation until
    /// [`SimObserver::on_restore`] fires for it.
    fn on_preempt(&mut self, _now: f64, _instance: usize, _id: ReqId) {}

    /// Previously evicted request `id` was re-admitted on `instance`
    /// and its KV reservation re-established (the restore cost was
    /// charged to the step being priced).
    fn on_restore(&mut self, _now: f64, _instance: usize, _id: ReqId) {}

    /// The cluster's autoscaler spawned `instance` (cluster only). The
    /// instance is warming: it holds no work and takes no placement
    /// until [`SimObserver::on_warmup_done`] fires for it.
    fn on_scale_up(&mut self, _now: f64, _instance: usize) {}

    /// A spawned instance's warm-up completed and it joined placement
    /// (cluster only; the matching calendar event is
    /// [`InstanceEvent::WarmupDone`]).
    fn on_warmup_done(&mut self, _now: f64, _instance: usize) {}

    /// The autoscaler retired `instance` (cluster only). Retirement
    /// only happens to a completely idle instance, so from this hook
    /// on it must never hold work again.
    fn on_scale_down(&mut self, _now: f64, _instance: usize) {}

    /// The run ended (drain, `max_steps`, or the `max_time` clamp) and
    /// `end_time` is the span the report will use.
    fn on_done(
        &mut self,
        _end_time: f64,
        _instances: &[Instance<'_>],
        _arena: &RequestArena,
    ) {
    }
}

/// The do-nothing observer the production `run` entry points pass to
/// `run_with`; monomorphizes every hook away.
#[derive(Debug, Default, Clone, Copy)]
pub struct NoopObserver;

impl SimObserver for NoopObserver {}
