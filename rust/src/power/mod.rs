//! Power / cost-efficiency model (paper Appendix D).
//!
//! * Accelerator die: 1 W/mm² (a reticle-limited 800 mm² die burns 800 W).
//! * DRAM: access energy in pJ/bit at the streamed bandwidth (HBM3e ~3.9,
//!   HBM4 ~2.8, 3D-DRAM ~1.5 — from the DRAMPower/CACTI-3DD line of
//!   models the paper cites). SRAM/COWS access energy is inside the die
//!   envelope.
//! * Host: a fixed 8 chips per server, 300 W per server.
//! * CENT: the CENT paper's reported system power is used verbatim.
//!
//! STPS/W is the paper's stand-in for both power and dollar cost.

use crate::hw::SystemConfig;

/// Parameters of the Appendix D power model.
#[derive(Debug, Clone, PartialEq)]
pub struct PowerModel {
    /// Accelerator die power density, W/mm².
    pub w_per_mm2: f64,
    /// Host-server power excluding accelerators, watts.
    pub server_watts: f64,
    /// Accelerator chips per host server.
    pub chips_per_server: u64,
    /// Fraction of peak bandwidth assumed streaming for memory power
    /// (decode saturates the memory system, so 1.0).
    pub mem_duty_cycle: f64,
}

impl Default for PowerModel {
    fn default() -> Self {
        PowerModel {
            w_per_mm2: 1.0,
            server_watts: 300.0,
            chips_per_server: 8,
            mem_duty_cycle: 1.0,
        }
    }
}

/// Itemized system power, watts.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SystemPower {
    /// All accelerator dies.
    pub die_watts: f64,
    /// All memory devices.
    pub mem_watts: f64,
    /// All host servers.
    pub server_watts: f64,
    /// Total.
    pub total_watts: f64,
}

impl PowerModel {
    /// Power of one chip's die + memory.
    pub fn chip_watts(&self, chip: &crate::hw::Chip) -> f64 {
        let die = chip.die_area_mm2 * self.w_per_mm2;
        let mem = chip.mem_pj_per_bit * 1e-12 * chip.mem_bw * 8.0 * self.mem_duty_cycle;
        die + mem
    }

    /// Itemized power for a whole system.
    pub fn system_power(&self, sys: &SystemConfig) -> SystemPower {
        // CENT models power from its paper's reported figure rather than
        // the die-area model (die_area == 0 marks such chips).
        if sys.chip.die_area_mm2 == 0.0 {
            let total = crate::hw::presets::cent_system_watts_for(sys);
            return SystemPower {
                die_watts: 0.0,
                mem_watts: 0.0,
                server_watts: 0.0,
                total_watts: total,
            };
        }
        let n = sys.n_chips() as f64;
        let die = sys.chip.die_area_mm2 * self.w_per_mm2 * n;
        let mem =
            sys.chip.mem_pj_per_bit * 1e-12 * sys.chip.mem_bw * 8.0 * self.mem_duty_cycle * n;
        let servers = (sys.n_chips() + self.chips_per_server - 1) / self.chips_per_server;
        let server = servers as f64 * self.server_watts;
        SystemPower {
            die_watts: die,
            mem_watts: mem,
            server_watts: server,
            total_watts: die + mem + server,
        }
    }

    /// System tokens/second per watt.
    pub fn stps_per_watt(&self, stps: f64, sys: &SystemConfig) -> f64 {
        stps / self.system_power(sys).total_watts
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::{presets, SystemConfig};

    #[test]
    fn reticle_die_burns_800w() {
        let m = PowerModel::default();
        let hbm3 = presets::hbm3();
        let die_only = hbm3.die_area_mm2 * m.w_per_mm2;
        assert_eq!(die_only, 800.0);
        // Memory adds a non-trivial but sub-dominant slice.
        let total = m.chip_watts(&hbm3);
        assert!(total > 800.0 && total < 1000.0, "got {total}");
    }

    #[test]
    fn system_power_counts_servers() {
        let m = PowerModel::default();
        let sys = SystemConfig::new(presets::hbm3(), 8, 1);
        let p = m.system_power(&sys);
        assert_eq!(p.server_watts, 300.0);
        let sys = SystemConfig::new(presets::hbm3(), 128, 1);
        let p = m.system_power(&sys);
        assert_eq!(p.server_watts, 16.0 * 300.0);
    }

    #[test]
    fn sram_and_cows_pay_no_separate_memory_power() {
        let m = PowerModel::default();
        assert_eq!(m.system_power(&SystemConfig::new(presets::sram(), 8, 1)).mem_watts, 0.0);
        let cows = m.system_power(&SystemConfig::new(presets::cows(), 1, 1));
        assert_eq!(cows.mem_watts, 0.0);
        // One wafer = 25 die-lets at 800 mm² each.
        assert_eq!(cows.die_watts, 25.0 * 800.0);
    }

    #[test]
    fn cent_uses_reported_power() {
        let m = PowerModel::default();
        let sys = SystemConfig::new(presets::cent_device(), 32, 1);
        let p = m.system_power(&sys);
        assert_eq!(p.total_watts, crate::hw::presets::cent_system_watts_for(&sys));
    }
}
