//! Chip presets (paper Table 1).
//!
//! Calibration note: Table 1 quotes HBM3 bandwidth as "4 TB/s", but the
//! paper's own table entries (Table 2/5/6) only reproduce exactly with a
//! per-chip streaming bandwidth of **4.4 TB/s** (e.g. Llama3-405B at
//! TP128/4K: `406.9e9 B / (128 * 4.4e12 B/s) + 3*126*1.5µs = 1.290 ms ->
//! 776 tokens/s`, the paper's value to the digit). We therefore treat
//! Table 1's bandwidth column as rounded marketing numbers and keep the
//! calibrated values here; each preset documents both. Capacities follow
//! the binary (GiB) convention that reproduces the paper's max-batch
//! figures.

use crate::{GIB, PFLOPS, TBPS};

use super::chip::{Chip, SyncModel};

pub use super::cent::{
    cent_device, cent_system_watts_for, CENT_DEVICES, CENT_SYSTEM_WATTS,
};

/// Ratio between the calibrated streaming bandwidth that reproduces the
/// paper's tables and Table 1's rounded "4 TB/s" figure.
pub const HBM3_CALIBRATION: f64 = 4.4 / 4.0;

/// Baseline xPU with HBM3e memory (Blackwell-class die).
pub fn hbm3() -> Chip {
    Chip {
        name: "xPU-HBM3".into(),
        mem_bw: 4.4 * TBPS, // Table 1: "4 TB/s" (see module docs)
        tensor_flops: 2.25 * PFLOPS,
        scalar_flops: 0.2 * PFLOPS,
        mem_capacity: 96.0 * GIB,
        sync: SyncModel::paper_default(),
        pp_sync: 100e-9,
        die_area_mm2: 800.0,
        mem_pj_per_bit: 3.9,
        notes: "Based on Blackwell GPU (HBM3e)".into(),
    }
}

/// xPU with an HBM4 memory system: 4.5x the bandwidth, 2x the capacity.
pub fn hbm4() -> Chip {
    Chip {
        name: "xPU-HBM4".into(),
        mem_bw: 18.0 * HBM3_CALIBRATION * TBPS,
        tensor_flops: 2.25 * PFLOPS,
        scalar_flops: 0.2 * PFLOPS,
        mem_capacity: 192.0 * GIB,
        sync: SyncModel::paper_default(),
        pp_sync: 100e-9,
        die_area_mm2: 800.0,
        mem_pj_per_bit: 2.8,
        notes: "HBM4".into(),
    }
}

/// xPU with advanced 3D-stacked DRAM: very high bandwidth, small capacity.
pub fn dram3d() -> Chip {
    Chip {
        name: "xPU-3D-DRAM".into(),
        mem_bw: 30.0 * HBM3_CALIBRATION * TBPS,
        tensor_flops: 2.25 * PFLOPS,
        scalar_flops: 0.2 * PFLOPS,
        mem_capacity: 36.0 * GIB,
        sync: SyncModel::paper_default(),
        pp_sync: 100e-9,
        die_area_mm2: 800.0,
        mem_pj_per_bit: 1.5,
        notes: "Advanced 3D stacked DRAM".into(),
    }
}

/// SRAM-only serving: 512 bytes/cycle x 128 tiles of on-die SRAM. Huge
/// bandwidth, tiny capacity, half the tensor engine (area traded for
/// SRAM macros).
pub fn sram() -> Chip {
    Chip {
        name: "xPU-SRAM".into(),
        mem_bw: 117.0 * TBPS,
        tensor_flops: 1.13 * PFLOPS,
        scalar_flops: 0.1 * PFLOPS,
        mem_capacity: 512.0 * 1024.0 * 1024.0,
        sync: SyncModel::paper_default(),
        pp_sync: 100e-9,
        die_area_mm2: 800.0,
        mem_pj_per_bit: 0.0, // on-die, inside the 1 W/mm^2 envelope
        notes: "Serve from SRAM: 512 Bytes/cyc x 128 tiles".into(),
    }
}

/// Collectives-optimized wafer-scale (25 SRAM die-lets on one wafer with
/// multicast partial sums; 800 ns wafer-wide all-reduce). One `Chip`
/// record models one wafer.
pub fn cows() -> Chip {
    Chip {
        name: "xPU-COWS".into(),
        mem_bw: 2250.0 * TBPS,
        tensor_flops: 28.13 * PFLOPS,
        scalar_flops: 2.5 * PFLOPS,
        mem_capacity: 11.0 * GIB,
        sync: SyncModel::Flat(800e-9),
        pp_sync: 100e-9,
        die_area_mm2: 25.0 * 800.0,
        mem_pj_per_bit: 0.0,
        notes: "Collectives-optimized wafer-scale (25 die-lets)".into(),
    }
}

/// Hypothetical chip for the Fig. 2 bandwidth sweep: an HBM3 xPU whose
/// bandwidth is replaced by `tbps` and whose sync latency is pinned to
/// 200 ns (the paper isolates bandwidth by assuming fast collectives).
pub fn bw_point(tbps: f64) -> Chip {
    let mut c = hbm3().with_mem_bw(tbps * TBPS).with_flat_sync(200e-9);
    c.name = format!("xPU-BW{tbps:.0}");
    c
}

/// All Table 1 presets, in table order.
pub fn table1() -> Vec<Chip> {
    vec![hbm3(), hbm4(), dram3d(), sram(), cows()]
}

/// Look up a preset by (case-insensitive) name; includes `cent`.
pub fn by_name(name: &str) -> Option<Chip> {
    let n = name.to_ascii_lowercase();
    let n = n.trim_start_matches("xpu-");
    match n {
        "hbm3" => Some(hbm3()),
        "hbm4" => Some(hbm4()),
        "3d-dram" | "dram3d" | "3ddram" => Some(dram3d()),
        "sram" => Some(sram()),
        "cows" => Some(cows()),
        "cent" => Some(super::cent::cent_device()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_presets_are_distinct_and_ordered() {
        let t = table1();
        assert_eq!(t.len(), 5);
        assert_eq!(t[0].name, "xPU-HBM3");
        assert_eq!(t[4].name, "xPU-COWS");
        // Bandwidth is monotonically increasing down Table 1.
        for w in t.windows(2) {
            assert!(w[1].mem_bw > w[0].mem_bw);
        }
    }

    #[test]
    fn by_name_roundtrips() {
        for chip in table1() {
            assert_eq!(by_name(&chip.name).unwrap().name, chip.name);
        }
        assert!(by_name("hbm3").is_some());
        assert!(by_name("nonsense").is_none());
    }

    #[test]
    fn sram_capacity_cannot_hold_any_studied_model_alone() {
        // Key capacity story: SRAM designs need hundreds of chips.
        assert!(sram().mem_capacity < 1.0 * GIB);
    }
}
