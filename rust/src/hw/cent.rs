//! CENT processing-in-memory comparator (paper Appendix C).
//!
//! CENT (Gu et al., ASPLOS'25) is a GPU-free, CXL-attached PIM system.
//! LIMINAL models it as 32 CXL PIM devices; two mappings bracket its
//! behaviour:
//!
//! * **CENT-PP** — pipeline parallelism across the devices. Straightforward
//!   under the standard model: `TP = 1, PP = 32`.
//! * **CENT-TP** — tensor parallelism for the weights, but the attention
//!   mechanism is restricted to run on a *single* device, so the KV cache
//!   streams at one device's bandwidth instead of the aggregate — the
//!   crushing limitation the appendix calls out.
//!
//! Device parameters are our calibration of the CENT paper's hardware
//! (per-device PIM bandwidth ~1 TB/s, ~12.5 GiB usable per device, CXL
//! sync latency ~2 µs); they reproduce the *shape* of the paper's Tables
//! 5/6 CENT rows (e.g. Llama3-70B CENT-TP decaying from ~300 TPS at 4K to
//! ~40 at 128K; DeepSeekV3 not servable at all).

use crate::{GIB, PFLOPS, TBPS};

use super::chip::{Chip, SyncModel};

/// Number of CXL PIM devices in the modeled CENT system.
pub const CENT_DEVICES: u64 = 32;

/// One CENT CXL-PIM device.
pub fn cent_device() -> Chip {
    Chip {
        name: "CENT".into(),
        mem_bw: 1.1 * TBPS,
        // PIM near-bank ALUs: modest matrix throughput per device.
        tensor_flops: 0.025 * PFLOPS,
        scalar_flops: 0.005 * PFLOPS,
        // 14 GiB/device: enough that CENT-TP serves Llama3-405B at 128K
        // (Table 5 shows 11 TPS there) while DeepSeekV3 still cannot fit.
        mem_capacity: 14.0 * GIB,
        // CXL-switch-mediated collectives.
        sync: SyncModel::Tiered { le16: 2e-6, gt16: 2e-6 },
        pp_sync: 250e-9,
        die_area_mm2: 0.0, // power comes from the CENT paper's reported W
        mem_pj_per_bit: 0.0,
        notes: "CXL-PIM device (CENT, Appendix C)".into(),
    }
}

/// Reported whole-system power for the 32-device CENT box, watts
/// (Appendix D defers to the CENT paper's reported number).
pub const CENT_SYSTEM_WATTS: f64 = 4800.0;

/// Reported CENT power scaled to however many devices a system uses.
pub fn cent_system_watts_for(sys: &super::SystemConfig) -> f64 {
    CENT_SYSTEM_WATTS * sys.n_chips() as f64 / CENT_DEVICES as f64
}

/// Which CENT mapping to evaluate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CentMapping {
    /// Tensor-parallel weights, attention pinned to one device.
    TensorParallel,
    /// Pipeline parallel across all devices.
    PipelineParallel,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cent_system_cannot_hold_deepseek() {
        // Appendix C: CENT rows for DeepSeekV3 are all dashes.
        let total = cent_device().mem_capacity * CENT_DEVICES as f64;
        assert!(total < 625.0 * crate::GIB);
    }

    #[test]
    fn cent_system_holds_llama70b() {
        let total = cent_device().mem_capacity * CENT_DEVICES as f64;
        assert!(total > 70.55e9);
    }
}
