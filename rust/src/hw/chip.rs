//! A single accelerator chip ("xPU") abstraction.

/// How the chip's interconnect prices a tensor-parallel collective.
///
/// The paper's default rule (§2.2): 200 ns when 16 or fewer chips
/// participate, 1.5 µs beyond that (CXL-class switches). Technologies
/// with collective-optimized fabrics (COWS wafers) override with a flat
/// latency; sweeps (Fig. 3/6) override with an explicit value.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SyncModel {
    /// The default two-regime rule: `le16` seconds at TP <= 16 chips,
    /// `gt16` seconds above.
    Tiered {
        /// All-reduce latency when <= 16 chips participate.
        le16: f64,
        /// All-reduce latency when > 16 chips participate.
        gt16: f64,
    },
    /// One latency regardless of the TP degree (e.g. on-wafer multicast
    /// collectives: 800 ns across 25 die-lets for COWS).
    Flat(f64),
}

impl SyncModel {
    /// The paper's default tiered model: 200 ns / 1.5 µs.
    pub fn paper_default() -> Self {
        SyncModel::Tiered { le16: 200e-9, gt16: 1.5e-6 }
    }

    /// Tensor-parallel all-reduce latency for a `tp`-chip domain.
    pub fn tp_sync(&self, tp: u64) -> f64 {
        match *self {
            SyncModel::Tiered { le16, gt16 } => {
                if tp <= 16 {
                    le16
                } else {
                    gt16
                }
            }
            SyncModel::Flat(s) => s,
        }
    }
}

/// One accelerator chip, described only by its fundamental performance
/// characteristics (paper Table 1).
#[derive(Debug, Clone, PartialEq)]
pub struct Chip {
    /// Short name, e.g. `xPU-HBM3`.
    pub name: String,
    /// Memory bandwidth in bytes/second (decimal).
    pub mem_bw: f64,
    /// Peak tensor-engine throughput in FLOP/s (FP8).
    pub tensor_flops: f64,
    /// Peak scalar/vector-engine throughput in FLOP/s.
    pub scalar_flops: f64,
    /// Memory capacity in bytes.
    pub mem_capacity: f64,
    /// Collective latency model for tensor parallelism.
    pub sync: SyncModel,
    /// Producer-consumer latency across one pipeline-stage hop, seconds.
    pub pp_sync: f64,
    /// Die area in mm^2 (drives the 1 W/mm^2 power model). A COWS entry
    /// carries the whole wafer's die-let area.
    pub die_area_mm2: f64,
    /// Memory access energy in pJ/bit for the backing store (0 for SRAM,
    /// whose access energy is inside the die power envelope).
    pub mem_pj_per_bit: f64,
    /// Free-form provenance note (mirrors Table 1's "Notes" column).
    pub notes: String,
}

impl Chip {
    /// Effective TP all-reduce latency at a given TP degree.
    pub fn tp_sync(&self, tp: u64) -> f64 {
        self.sync.tp_sync(tp)
    }

    /// Return a copy with the TP sync latency forced to `seconds`
    /// regardless of TP degree (used by the Fig. 2/3/6 sweeps).
    pub fn with_flat_sync(&self, seconds: f64) -> Chip {
        Chip { sync: SyncModel::Flat(seconds), ..self.clone() }
    }

    /// Return a copy with a different memory bandwidth (Fig. 2 sweep).
    pub fn with_mem_bw(&self, mem_bw: f64) -> Chip {
        Chip { mem_bw, ..self.clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiered_sync_switches_at_16_chips() {
        let s = SyncModel::paper_default();
        assert_eq!(s.tp_sync(1), 200e-9);
        assert_eq!(s.tp_sync(8), 200e-9);
        assert_eq!(s.tp_sync(16), 200e-9);
        assert_eq!(s.tp_sync(17), 1.5e-6);
        assert_eq!(s.tp_sync(128), 1.5e-6);
    }

    #[test]
    fn flat_sync_ignores_tp() {
        let s = SyncModel::Flat(800e-9);
        assert_eq!(s.tp_sync(1), 800e-9);
        assert_eq!(s.tp_sync(128), 800e-9);
    }
}
