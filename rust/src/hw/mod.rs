//! Hardware abstraction (paper §2.1 "Abstracting Hardware" + Table 1).
//!
//! A DL accelerator is abstracted as an **xPU**: peak tensor and scalar
//! compute throughput, memory size and bandwidth, and the latencies of
//! reductions / direct communication between chips. Chips compose into a
//! [`SystemConfig`] via tensor parallelism (strong scaling, `TP` chips
//! per layer) and pipeline parallelism (weak scaling, `PP` stages).

mod cent;
mod chip;
pub mod presets;
mod system;

pub use cent::CentMapping;
pub use chip::{Chip, SyncModel};
pub use system::{SystemConfig, DEFAULT_XFER_BW_PER_CHIP};

/// The paper's hard constraint on strong scaling: tensor parallelism may
/// span at most 128 chips ("performing reductions across a larger number
/// of chips introduces excessive latency and bandwidth constraints", §3).
pub const MAX_TP: u64 = 128;
