//! A system: `TP x PP` identical chips serving one model instance.

use super::chip::Chip;
use super::MAX_TP;

/// A distributed system built from identical chips.
///
/// * `tp` chips form one tensor-parallel (strong-scaling) domain: every
///   operator of a layer is split across them, so they aggregate memory
///   bandwidth and compute *for token latency*, at the price of
///   `sync_ops_per_layer` all-reduces per layer.
/// * `pp` stages chain tensor-parallel domains (weak scaling): capacity
///   aggregates across stages and throughput multiplies by `pp`, but a
///   single token still traverses every stage serially, so per-token
///   latency sees only one stage's bandwidth at a time.
#[derive(Debug, Clone, PartialEq)]
pub struct SystemConfig {
    /// The chip every slot is populated with.
    pub chip: Chip,
    /// Tensor-parallel degree (chips per pipeline stage), `<= MAX_TP`.
    pub tp: u64,
    /// Pipeline-parallel degree (number of stages), `>= 1`.
    pub pp: u64,
    /// If set, the KV-cache/attention traffic streams at this bandwidth
    /// (bytes/s) instead of the TP-aggregate — models mappings that pin
    /// attention to a subset of the machine, like CENT-TP (Appendix C).
    pub kv_bw_override: Option<f64>,
    /// If set, the scale-out interconnect bandwidth (bytes/s) this
    /// system can source or sink when shipping KV cache to another
    /// instance (disaggregated prefill/decode pools); `None` falls back
    /// to [`DEFAULT_XFER_BW_PER_CHIP`] aggregated over the TP domain.
    pub xfer_bw_override: Option<f64>,
}

/// Default per-chip scale-out interconnect bandwidth, bytes/s. This is a
/// CXL/NIC-class 100 GB/s lane per chip — the same fabric class whose
/// collective latency the paper's tiered sync model charges above 16
/// chips — aggregated across the TP domain for bulk KV shipment.
pub const DEFAULT_XFER_BW_PER_CHIP: f64 = 100e9;

impl SystemConfig {
    /// Build a `tp x pp` system. Panics on a zero degree or `tp > MAX_TP`.
    pub fn new(chip: Chip, tp: u64, pp: u64) -> Self {
        assert!(tp >= 1 && pp >= 1, "degenerate system {tp}x{pp}");
        assert!(tp <= MAX_TP, "TP {tp} exceeds the {MAX_TP}-chip limit");
        SystemConfig { chip, tp, pp, kv_bw_override: None, xfer_bw_override: None }
    }

    /// Total chips in the system.
    pub fn n_chips(&self) -> u64 {
        self.tp * self.pp
    }

    /// Bandwidth visible to one token as it executes a layer: the
    /// TP-domain aggregate (PP does not reduce token latency).
    pub fn stage_mem_bw(&self) -> f64 {
        self.chip.mem_bw * self.tp as f64
    }

    /// Tensor compute visible to one token within a stage.
    pub fn stage_tensor_flops(&self) -> f64 {
        self.chip.tensor_flops * self.tp as f64
    }

    /// Scalar compute visible to one token within a stage.
    pub fn stage_scalar_flops(&self) -> f64 {
        self.chip.scalar_flops * self.tp as f64
    }

    /// Total memory capacity across all chips and stages.
    pub fn total_capacity(&self) -> f64 {
        self.chip.mem_capacity * self.n_chips() as f64
    }

    /// TP all-reduce latency for this system's TP degree.
    pub fn tp_sync(&self) -> f64 {
        self.chip.tp_sync(self.tp)
    }

    /// One-hop pipeline forwarding latency.
    pub fn pp_sync(&self) -> f64 {
        self.chip.pp_sync
    }

    /// Effective bandwidth for KV/attention traffic (see
    /// [`SystemConfig::kv_bw_override`]).
    pub fn kv_mem_bw(&self) -> f64 {
        self.kv_bw_override.unwrap_or_else(|| self.stage_mem_bw())
    }

    /// Scale-out interconnect bandwidth for shipping KV cache between
    /// instances (bytes/s): the override if set, else
    /// [`DEFAULT_XFER_BW_PER_CHIP`] per chip across the TP domain. A
    /// disaggregated prefill instance hands a prompt's KV to the decode
    /// pool at this rate.
    pub fn interconnect_bw(&self) -> f64 {
        self.xfer_bw_override
            .unwrap_or(DEFAULT_XFER_BW_PER_CHIP * self.tp as f64)
    }

    /// Short display label, e.g. `xPU-HBM3-TP8` or `xPU-SRAM-TP128-PP7`.
    pub fn label(&self) -> String {
        if self.pp == 1 {
            format!("{}-TP{}", self.chip.name, self.tp)
        } else {
            format!("{}-TP{}-PP{}", self.chip.name, self.tp, self.pp)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::presets;

    #[test]
    fn aggregates_scale_with_tp_only_for_latency() {
        let sys = SystemConfig::new(presets::hbm3(), 8, 4);
        assert_eq!(sys.n_chips(), 32);
        assert_eq!(sys.stage_mem_bw(), presets::hbm3().mem_bw * 8.0);
        assert_eq!(
            sys.total_capacity(),
            presets::hbm3().mem_capacity * 32.0
        );
    }

    #[test]
    #[should_panic(expected = "exceeds")]
    fn tp_over_128_is_rejected() {
        SystemConfig::new(presets::hbm3(), 256, 1);
    }

    #[test]
    fn label_elides_pp1() {
        assert_eq!(
            SystemConfig::new(presets::hbm3(), 8, 1).label(),
            "xPU-HBM3-TP8"
        );
        assert_eq!(
            SystemConfig::new(presets::sram(), 128, 7).label(),
            "xPU-SRAM-TP128-PP7"
        );
    }

    #[test]
    fn interconnect_bw_defaults_per_chip_and_respects_override() {
        let mut sys = SystemConfig::new(presets::hbm3(), 8, 1);
        assert_eq!(sys.interconnect_bw(), DEFAULT_XFER_BW_PER_CHIP * 8.0);
        sys.xfer_bw_override = Some(1e9);
        assert_eq!(sys.interconnect_bw(), 1e9);
    }

    #[test]
    fn kv_bw_override_redirects_attention_traffic() {
        let mut sys = SystemConfig::new(presets::cent_device(), 32, 1);
        assert_eq!(sys.kv_mem_bw(), sys.stage_mem_bw());
        sys.kv_bw_override = Some(sys.chip.mem_bw);
        assert_eq!(sys.kv_mem_bw(), sys.chip.mem_bw);
    }
}
