//! # LIMINAL — LLM Inference Memory-bandwidth And Latency
//!
//! A limit-study framework for transformer LLM **auto-regressive decode**
//! performance, reproducing Davies, Crago, Sankaralingam & Kozyrakis,
//! *"Efficient LLM Inference: Bandwidth, Compute, Synchronization, and
//! Capacity are all you need"* (the LIMINAL paper).
//!
//! The framework has three layers:
//!
//! * **Analytical core** ([`apps`], [`hw`], [`model`], [`parallel`],
//!   [`power`], [`moe`]) — the paper's closed-form performance model:
//!   applications are abstracted as op counts + data volumes + sync needs,
//!   hardware as compute / bandwidth / capacity / sync latencies, and
//!   per-token latency as `max(T_compute, T_mem) + T_exposed`.
//! * **Experiment harness** ([`sweep`], [`experiments`], [`report`]) —
//!   regenerates every table and figure in the paper's evaluation section
//!   from the analytical core.
//! * **Executable substrate** ([`runtime`], [`serving`], [`des`],
//!   [`cluster`], [`coordinator`]) — a PJRT runtime that loads the
//!   AOT-compiled JAX/Pallas decode step, a discrete-event serving
//!   simulator used both as a dynamic serving testbed and as the
//!   "measured silicon" analog for the paper's Appendix E validation,
//!   and a cluster simulator (multi-instance routing + disaggregated
//!   prefill/decode pools with KV shipping) for the scale-out scenarios
//!   beyond the paper's single-box limit study. The [`dst`] module
//!   fuzzes that substrate deterministically: seeded scenario
//!   generation, per-event invariant checking, and seed replay.
//!
//! ## Quickstart
//!
//! ```no_run
//! # // no_run: rustdoc test binaries don't inherit the cargo rpath to
//! # // libxla_extension.so; the same assertion runs in unit tests.
//! use liminal::prelude::*;
//!
//! let app = Registry::builtin().app("llama3-405b").unwrap();
//! let sys = SystemConfig::new(presets::hbm3(), 128, 1);
//! let point = EvalPoint { batch: 1, context: 4096 };
//! let perf = evaluate(app.as_ref(), &sys, &point, &EvalOptions::default()).unwrap();
//! assert!((perf.utps - 776.0).abs() / 776.0 < 0.01); // paper Table 2
//! ```
#![deny(missing_docs)]

pub mod apps;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod des;
pub mod dst;
pub mod experiments;
pub mod hw;
pub mod model;
pub mod moe;
pub mod parallel;
pub mod power;
pub mod report;
pub mod runtime;
pub mod serving;
pub mod sweep;
pub mod util;

/// Convenience re-exports of the most common types.
pub mod prelude {
    pub use crate::apps::{Application, ModelSpec, Registry};
    pub use crate::hw::{presets, Chip, SystemConfig};
    pub use crate::model::{
        evaluate, Boundedness, EvalOptions, EvalPoint, LatencyBreakdown, Perf,
    };
    pub use crate::parallel::{fit_system, max_batch, FitRequest};
    pub use crate::power::{PowerModel, SystemPower};
    pub use crate::sweep::{Grid, Record, SweepRunner};
}

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Number of bytes in one GiB (the paper's tables quote "GB" with binary
/// semantics: 96 GB HBM3 chips aggregate to 824.6e9 bytes at TP8, which is
/// what reproduces the paper's max-batch figures).
pub const GIB: f64 = 1024.0 * 1024.0 * 1024.0;

/// One terabyte per second (decimal), the unit used for memory bandwidth.
pub const TBPS: f64 = 1e12;

/// One petaflop per second, the unit used for compute throughput.
pub const PFLOPS: f64 = 1e15;
