//! Regeneration time of fig3's data series.

use std::path::Path;
use liminal::util::bench::Suite;

fn main() {
    let mut suite = Suite::from_args();
    suite.bench_val("experiments/fig3", || {
        liminal::experiments::run("fig3", Path::new("artifacts")).unwrap()
    });
}
