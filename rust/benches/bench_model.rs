//! Microbenchmarks of the analytical core: the per-point evaluation that
//! every sweep and experiment sits on (perf target: < 2 µs/point, no
//! allocation in the hot path), plus the MoE Monte-Carlo.

use liminal::apps::{Application, DecodePoint, DeepSeekV3, Llama3};
use liminal::hw::{presets, SystemConfig};
use liminal::model::{evaluate, evaluate_workload, EvalOptions};
use liminal::moe::{imbalance_factor, ImbalanceEstimator};
use liminal::util::bench::Suite;

fn main() {
    let mut suite = Suite::from_args();
    let opts = EvalOptions::default();
    let sys = SystemConfig::new(presets::hbm3(), 128, 1);

    let l405 = Llama3::llama3_405b();
    let pt = DecodePoint { batch: 8, context: 65536 };
    suite.bench_val("model/evaluate_llama405b", || {
        evaluate(&l405, &sys, &pt, &opts).unwrap()
    });

    let ds = DeepSeekV3::v3();
    // Warm the MI cache so the bench measures the model, not the MC.
    let _ = evaluate(&ds, &sys, &pt, &opts);
    suite.bench_val("model/evaluate_deepseek_cached_mi", || {
        evaluate(&ds, &sys, &pt, &opts).unwrap()
    });

    let wl = l405.workload(&pt);
    let cap = l405.capacity_bytes(&pt);
    suite.bench_val("model/evaluate_workload_only", || {
        evaluate_workload(&wl, &sys, &pt, &opts, cap)
    });

    suite.bench_val("model/workload_build_llama405b", || l405.workload(&pt));

    suite.bench_val("moe/imbalance_cached", || imbalance_factor(256, 8, 64));
    let est = ImbalanceEstimator { trials: 2048, ..Default::default() };
    suite.bench_val("moe/imbalance_mc_2048trials_b64", || est.estimate(64));
}
