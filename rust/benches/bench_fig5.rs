//! Regeneration time of fig5's data series.

use std::path::Path;
use liminal::util::bench::Suite;

fn main() {
    let mut suite = Suite::from_args();
    suite.bench_val("experiments/fig5", || {
        liminal::experiments::run("fig5", Path::new("artifacts")).unwrap()
    });
}
