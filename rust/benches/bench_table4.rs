//! Regeneration time of Table 4 (capacity + AMI, 8 contexts x 6 cells).

use std::path::Path;
use liminal::util::bench::Suite;

fn main() {
    let mut suite = Suite::from_args();
    suite.bench_val("experiments/table4", || {
        liminal::experiments::run("table4", Path::new("artifacts")).unwrap()
    });
}
