//! Regeneration time of fig6's data series.

use std::path::Path;
use liminal::util::bench::Suite;

fn main() {
    let mut suite = Suite::from_args();
    suite.bench_val("experiments/fig6", || {
        liminal::experiments::run("fig6", Path::new("artifacts")).unwrap()
    });
}
