//! Regeneration time of fig2's data series.

use std::path::Path;
use liminal::util::bench::Suite;

fn main() {
    let mut suite = Suite::from_args();
    suite.bench_val("experiments/fig2", || {
        liminal::experiments::run("fig2", Path::new("artifacts")).unwrap()
    });
}
