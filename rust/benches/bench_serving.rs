//! Serving-simulator benchmarks: analytic DES throughput (steps/s of the
//! scheduler itself) and, when artifacts exist, the PJRT decode step.

use std::sync::Arc;

use liminal::apps::Registry;
use liminal::hw::{presets, SystemConfig};
use liminal::runtime::Runtime;
use liminal::serving::{
    AnalyticEngine, Batcher, KvBudget, PjrtEngine, ServingSim, SimConfig,
    WorkloadGen, WorkloadSpec,
};
use liminal::util::bench::Suite;

fn main() {
    let mut suite = Suite::from_args();
    let registry = Registry::builtin();
    let app = registry.app("llama3-70b").unwrap();

    suite.bench_val("serving/analytic_200req_sim", || {
        let sys = SystemConfig::new(presets::hbm3(), 128, 1);
        let kv = KvBudget::new(
            sys.total_capacity(),
            app.weight_bytes(),
            app.kv_bytes_per_token(),
        );
        let batcher = Batcher::new(64, kv);
        let mut engine = AnalyticEngine::new(Arc::clone(&app), sys);
        let workload = WorkloadGen::new(WorkloadSpec {
            arrival_rate: 500.0,
            n_requests: 200,
            context: (1024, 8192),
            gen: (16, 64),
            priority_mix: Vec::new(),
            seed: 3,
        })
        .generate();
        ServingSim::new(batcher, &mut engine, SimConfig::default()).run(workload)
    });

    // Prefill-aware run: same workload, prompts ingested in 1K chunks.
    // Measures the DES + chunk-planner + mixed-step-pricing overhead.
    suite.bench_val("serving/analytic_200req_prefill_sim", || {
        let sys = SystemConfig::new(presets::hbm3(), 128, 1);
        let kv = KvBudget::new(
            sys.total_capacity(),
            app.weight_bytes(),
            app.kv_bytes_per_token(),
        );
        let batcher = Batcher::with_prefill(64, kv, 1024);
        let mut engine = AnalyticEngine::new(Arc::clone(&app), sys);
        let workload = WorkloadGen::new(WorkloadSpec {
            arrival_rate: 500.0,
            n_requests: 200,
            context: (1024, 8192),
            gen: (16, 64),
            priority_mix: Vec::new(),
            seed: 3,
        })
        .generate();
        ServingSim::new(batcher, &mut engine, SimConfig::default()).run(workload)
    });

    if std::path::Path::new("artifacts/manifest.json").exists() {
        let mut rt = Runtime::new(std::path::Path::new("artifacts")).unwrap();
        for batch in [1u64, 8] {
            let mut eng = PjrtEngine::new(&mut rt, batch).unwrap();
            eng.randomize_params(1).unwrap();
            let tokens = vec![1i32; eng.batch as usize];
            suite.bench(&format!("serving/pjrt_decode_step_b{batch}"), || {
                if eng.pos >= eng.context {
                    eng.reset().unwrap();
                }
                let _ = eng.step(&tokens).unwrap();
            });
        }
    } else {
        eprintln!("(pjrt benches skipped: run `make artifacts`)");
    }
}
