//! Regeneration time of fig4's data series.

use std::path::Path;
use liminal::util::bench::Suite;

fn main() {
    let mut suite = Suite::from_args();
    suite.bench_val("experiments/fig4", || {
        liminal::experiments::run("fig4", Path::new("artifacts")).unwrap()
    });
}
