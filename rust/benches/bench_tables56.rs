//! Regeneration time of the appendix Tables 5 and 6 (15 rows x 6
//! contexts each, incl. CENT rows and max-batch search).

use std::path::Path;
use liminal::util::bench::Suite;

fn main() {
    let mut suite = Suite::from_args();
    suite.bench_val("experiments/table5", || {
        liminal::experiments::run("table5", Path::new("artifacts")).unwrap()
    });
    suite.bench_val("experiments/table6", || {
        liminal::experiments::run("table6", Path::new("artifacts")).unwrap()
    });
}
