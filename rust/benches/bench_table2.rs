//! End-to-end regeneration time of Table 2 (UTPS + capacity-max STPS,
//! 9 systems x 2 contexts).

use std::path::Path;
use liminal::util::bench::Suite;

fn main() {
    let mut suite = Suite::from_args();
    suite.bench_val("experiments/table2", || {
        liminal::experiments::run("table2", Path::new("artifacts")).unwrap()
    });
}
