//! Serving-simulator integration at paper scale: the dynamic system
//! reproduces the steady-state model's behavior under load.

use std::sync::Arc;

use liminal::apps::Registry;
use liminal::hw::{presets, SystemConfig};
use liminal::serving::{
    AnalyticEngine, Batcher, KvBudget, ServingSim, SimConfig, WorkloadGen, WorkloadSpec,
};

fn run_70b(
    tp: u64,
    max_batch: usize,
    rate: f64,
    n: u64,
) -> liminal::serving::ServingReport {
    let registry = Registry::builtin();
    let app = registry.app("llama3-70b").unwrap();
    let sys = SystemConfig::new(presets::hbm3(), tp, 1);
    let kv = KvBudget::new(
        sys.total_capacity(),
        app.weight_bytes(),
        app.kv_bytes_per_token(),
    );
    let batcher = Batcher::new(max_batch, kv);
    let mut engine = AnalyticEngine::new(Arc::clone(&app), sys);
    let workload = WorkloadGen::new(WorkloadSpec {
        arrival_rate: rate,
        n_requests: n,
        context: (2048, 8192),
        gen: (32, 128),
        seed: 99,
    })
    .generate();
    ServingSim::new(batcher, &mut engine, SimConfig::default()).run(workload)
}

#[test]
fn light_load_gives_near_single_user_latency() {
    // At trickle arrival rates, each user should see close to the
    // steady-state single-user UTPS (457-486 for TP8 at these contexts).
    let rep = run_70b(8, 32, 0.5, 20);
    assert_eq!(rep.completed, 20);
    assert!(rep.utps_mean > 350.0, "{}", rep.utps_mean);
    assert!(rep.queue_delay_mean < 0.01, "{}", rep.queue_delay_mean);
}

#[test]
fn saturation_trades_utps_for_stps() {
    let light = run_70b(8, 64, 1.0, 40);
    let heavy = run_70b(8, 64, 500.0, 40);
    // Heavy load: more throughput, worse per-user rate.
    assert!(heavy.stps > light.stps * 2.0, "{} vs {}", heavy.stps, light.stps);
    assert!(heavy.utps_mean < light.utps_mean);
    assert!(heavy.mean_batch > light.mean_batch);
}

#[test]
fn small_batch_cap_creates_queueing() {
    let capped = run_70b(8, 2, 200.0, 60);
    let open = run_70b(8, 64, 200.0, 60);
    assert!(capped.queue_delay_mean > 5.0 * open.queue_delay_mean.max(1e-6));
    assert!(capped.stps < open.stps);
}

#[test]
fn bigger_systems_serve_faster_dynamically() {
    // The Table 2 scaling story holds under dynamic load too.
    let tp8 = run_70b(8, 32, 100.0, 50);
    let tp128 = run_70b(128, 32, 100.0, 50);
    assert!(tp128.utps_mean > 2.0 * tp8.utps_mean);
}

#[test]
fn all_tokens_accounted() {
    let rep = run_70b(32, 16, 50.0, 30);
    assert_eq!(rep.completed, 30);
    // 30 requests x gen in [32, 128) -> tokens in a sane envelope.
    assert!(rep.tokens >= 30 * 32 && rep.tokens < 30 * 128);
    assert!(rep.steps as f64 >= rep.tokens as f64 / 16.0);
}
