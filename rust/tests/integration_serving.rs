//! Serving-simulator integration at paper scale: the dynamic system
//! reproduces the steady-state model's behavior under load.

use std::sync::Arc;

use liminal::apps::Registry;
use liminal::hw::{presets, SystemConfig};
use liminal::serving::{
    AnalyticEngine, Batcher, KvBudget, ServingSim, SimConfig, WorkloadGen, WorkloadSpec,
};

fn run_70b_chunked(
    tp: u64,
    max_batch: usize,
    rate: f64,
    n: u64,
    prefill_chunk: u64,
) -> liminal::serving::ServingReport {
    let registry = Registry::builtin();
    let app = registry.app("llama3-70b").unwrap();
    let sys = SystemConfig::new(presets::hbm3(), tp, 1);
    let kv = KvBudget::new(
        sys.total_capacity(),
        app.weight_bytes(),
        app.kv_bytes_per_token(),
    );
    let batcher = Batcher::with_prefill(max_batch, kv, prefill_chunk);
    let mut engine = AnalyticEngine::new(Arc::clone(&app), sys);
    let workload = WorkloadGen::new(WorkloadSpec {
        arrival_rate: rate,
        n_requests: n,
        context: (2048, 8192),
        gen: (32, 128),
        priority_mix: Vec::new(),
        seed: 99,
    })
    .generate();
    ServingSim::new(batcher, &mut engine, SimConfig::default()).run(workload)
}

fn run_70b(tp: u64, max_batch: usize, rate: f64, n: u64) -> liminal::serving::ServingReport {
    run_70b_chunked(tp, max_batch, rate, n, 0)
}

#[test]
fn light_load_gives_near_single_user_latency() {
    // At trickle arrival rates, each user should see close to the
    // steady-state single-user UTPS (457-486 for TP8 at these contexts).
    let rep = run_70b(8, 32, 0.5, 20);
    assert_eq!(rep.completed, 20);
    assert!(rep.utps_mean > 350.0, "{}", rep.utps_mean);
    assert!(rep.queue_delay_mean < 0.01, "{}", rep.queue_delay_mean);
}

#[test]
fn saturation_trades_utps_for_stps() {
    let light = run_70b(8, 64, 1.0, 40);
    let heavy = run_70b(8, 64, 500.0, 40);
    // Heavy load: more throughput, worse per-user rate.
    assert!(heavy.stps > light.stps * 2.0, "{} vs {}", heavy.stps, light.stps);
    assert!(heavy.utps_mean < light.utps_mean);
    assert!(heavy.mean_batch > light.mean_batch);
}

#[test]
fn small_batch_cap_creates_queueing() {
    let capped = run_70b(8, 2, 200.0, 60);
    let open = run_70b(8, 64, 200.0, 60);
    assert!(capped.queue_delay_mean > 5.0 * open.queue_delay_mean.max(1e-6));
    assert!(capped.stps < open.stps);
}

#[test]
fn bigger_systems_serve_faster_dynamically() {
    // The Table 2 scaling story holds under dynamic load too.
    let tp8 = run_70b(8, 32, 100.0, 50);
    let tp128 = run_70b(128, 32, 100.0, 50);
    assert!(tp128.utps_mean > 2.0 * tp8.utps_mean);
}

#[test]
fn all_tokens_accounted() {
    let rep = run_70b(32, 16, 50.0, 30);
    assert_eq!(rep.completed, 30);
    // 30 requests x gen in [32, 128) -> tokens in a sane envelope.
    assert!(rep.tokens >= 30 * 32 && rep.tokens < 30 * 128);
    assert!(rep.steps as f64 >= rep.tokens as f64 / 16.0);
}

#[test]
fn prefill_aware_run_reports_slos() {
    // Acceptance: a prefill-aware paper-scale run yields nonzero TTFT
    // for every request (prompts are 2K-8K tokens) and a TPOT near the
    // steady-state decode cadence.
    let rep = run_70b_chunked(8, 32, 20.0, 40, 1024);
    assert_eq!(rep.completed, 40);
    // Every prompt token was actually prefilled.
    assert!(rep.prefill_tokens >= 40 * 2048, "{}", rep.prefill_tokens);
    // TTFT: at least one ~8 ms chunk step, well below the e2e latency.
    assert!(rep.ttft.p50 > 0.005, "ttft p50 {}", rep.ttft.p50);
    assert!(rep.ttft.p99 >= rep.ttft.p50);
    assert!(rep.e2e.p50 > rep.ttft.p50);
    // TPOT brackets the single-user decode cadence (486 UTPS -> ~2 ms)
    // allowing for batching-induced stretch.
    assert!(rep.tpot.p50 > 0.0015 && rep.tpot.p50 < 0.05, "tpot {}", rep.tpot.p50);
}

#[test]
fn prefill_lengthens_the_run_but_completes_everything() {
    let decode_only = run_70b(8, 32, 100.0, 40);
    let chunked = run_70b_chunked(8, 32, 100.0, 40, 1024);
    assert_eq!(decode_only.completed, 40);
    assert_eq!(chunked.completed, 40);
    // Prefill is real work: the span cannot shrink, and TTFT grows.
    assert!(chunked.span >= decode_only.span * 0.99);
    assert!(chunked.ttft.p50 > decode_only.ttft.p50);
    assert_eq!(decode_only.prefill_tokens, 0);
}

#[test]
fn smaller_chunks_bound_decode_stalls_but_stretch_ttft() {
    // Chunked prefill's core trade: smaller chunks mean more steps to
    // ingest a prompt (worse TTFT under light load) but shorter
    // individual mixed steps (tighter TPOT tail for decode lanes).
    let coarse = run_70b_chunked(8, 32, 20.0, 40, 4096);
    let fine = run_70b_chunked(8, 32, 20.0, 40, 512);
    assert_eq!(coarse.completed, 40);
    assert_eq!(fine.completed, 40);
    assert!(fine.ttft.p50 > coarse.ttft.p50 * 0.9, "fine {} coarse {}", fine.ttft.p50, coarse.ttft.p50);
    // Decode lanes see shorter worst-case steps with finer chunks.
    assert!(fine.tpot.p99 <= coarse.tpot.p99 * 1.5);
}
