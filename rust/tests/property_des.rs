//! Property test pinning the calendar-queue `EventQueue` to a
//! binary-heap reference implementation.
//!
//! The reference is the pre-refactor design verbatim: a max-heap of
//! `(time, seq)` in reverse `total_cmp` order with the same
//! clamp-to-now rule. Randomized (seeded, reproducible) schedules
//! drive both side by side through the shapes a DES actually
//! produces — same-time FIFO bursts, clamp-to-now past times,
//! interleaved pop/schedule chains, far-future outliers, and full
//! empty/refill cycles — asserting identical pop sequences, clocks,
//! and `fired` counts at every step.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use liminal::des::{EventQueue, SimTime};
use liminal::util::rng::Pcg32;

/// The pre-refactor binary-heap calendar, kept as the ordering oracle.
struct RefScheduled {
    at: SimTime,
    seq: u64,
    event: u64,
}

impl PartialEq for RefScheduled {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for RefScheduled {}
impl PartialOrd for RefScheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for RefScheduled {
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .at
            .total_cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

struct RefQueue {
    heap: BinaryHeap<RefScheduled>,
    now: SimTime,
    seq: u64,
    fired: u64,
}

impl RefQueue {
    fn new() -> RefQueue {
        RefQueue { heap: BinaryHeap::new(), now: 0.0, seq: 0, fired: 0 }
    }

    fn schedule_at(&mut self, at: SimTime, event: u64) {
        assert!(!at.is_nan() && at >= 0.0);
        self.heap.push(RefScheduled {
            at: at.max(self.now),
            seq: self.seq,
            event,
        });
        self.seq += 1;
    }

    fn next(&mut self) -> Option<(SimTime, u64)> {
        let s = self.heap.pop()?;
        self.now = s.at;
        self.fired += 1;
        Some((s.at, s.event))
    }

    fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|s| s.at)
    }
}

/// Drive both queues with one random operation stream and assert they
/// are indistinguishable at every step.
fn drive(seed: u64, ops: usize) {
    let mut rng = Pcg32::seed_from(seed);
    let mut cal: EventQueue<u64> = EventQueue::new();
    let mut reference = RefQueue::new();
    let mut next_event: u64 = 0;

    for op in 0..ops {
        // Weighted op mix: schedule-heavy early, pop-heavy late, so the
        // queues cycle through growth, steady state, and full drains.
        let roll = rng.below(100);
        let schedule = roll < 55 || cal.is_empty();
        if schedule {
            let burst = match rng.below(10) {
                0 => rng.range(2, 6) as usize, // same-time FIFO burst
                _ => 1,
            };
            let at = random_time(&mut rng, cal.now());
            for _ in 0..burst {
                cal.schedule_at(at, next_event);
                reference.schedule_at(at, next_event);
                next_event += 1;
            }
        } else {
            assert_eq!(
                cal.peek_time(),
                reference.peek_time(),
                "seed {seed} op {op}: peek diverged"
            );
            let got = cal.next();
            let want = reference.next();
            match (got, want) {
                (Some((tc, ec)), Some((tr, er))) => {
                    assert_eq!(
                        tc.to_bits(),
                        tr.to_bits(),
                        "seed {seed} op {op}: time diverged ({tc} vs {tr})"
                    );
                    assert_eq!(
                        ec, er,
                        "seed {seed} op {op}: event diverged at t={tc}"
                    );
                }
                (None, None) => {}
                (got, want) => {
                    panic!("seed {seed} op {op}: {got:?} vs {want:?}")
                }
            }
        }
        assert_eq!(cal.len(), reference.heap.len(), "seed {seed} op {op}");
        assert_eq!(
            cal.now().to_bits(),
            reference.now.to_bits(),
            "seed {seed} op {op}"
        );
    }

    // Drain both completely: the tails must match element for element.
    loop {
        assert_eq!(cal.peek_time(), reference.peek_time(), "seed {seed} drain");
        match (cal.next(), reference.next()) {
            (Some((tc, ec)), Some((tr, er))) => {
                assert_eq!(tc.to_bits(), tr.to_bits(), "seed {seed} drain");
                assert_eq!(ec, er, "seed {seed} drain at t={tc}");
            }
            (None, None) => break,
            (got, want) => panic!("seed {seed} drain: {got:?} vs {want:?}"),
        }
    }
    assert_eq!(cal.fired(), reference.fired, "seed {seed}: fired count");
    assert!(cal.is_empty());
}

/// Random event times biased toward DES reality: mostly a short hop
/// past `now`, sometimes exactly `now`, sometimes slightly in the past
/// (the clamp path), occasionally a far-future outlier that must cross
/// the overflow rung.
fn random_time(rng: &mut Pcg32, now: SimTime) -> SimTime {
    match rng.below(20) {
        0 => now,                                    // exactly now
        1 => (now - rng.f64() * 1e-6).max(0.0),      // clamp-to-now path
        2 | 3 => now + rng.f64() * 1e4,              // far-future outlier
        4 => now + rng.exp(1000.0),                  // sub-millisecond hop
        _ => now + rng.f64() * 2.0,                  // typical short hop
    }
}

#[test]
fn calendar_queue_matches_the_heap_reference() {
    for seed in 0..40u64 {
        drive(seed, 400);
    }
}

#[test]
fn calendar_queue_matches_the_heap_on_long_runs() {
    // Fewer seeds, much longer op streams: many full respan cycles and
    // steady-state cursor advances.
    for seed in 100..104u64 {
        drive(seed, 6000);
    }
}

#[test]
fn same_time_bursts_pop_fifo_across_both_queues() {
    // A degenerate stream: every event at one of two times, in bursts.
    // This is pure tie-breaking — any instability shows immediately.
    let mut cal: EventQueue<u64> = EventQueue::new();
    let mut reference = RefQueue::new();
    for i in 0..200u64 {
        let at = if i % 3 == 0 { 1.0 } else { 2.0 };
        cal.schedule_at(at, i);
        reference.schedule_at(at, i);
    }
    loop {
        match (cal.next(), reference.next()) {
            (Some((tc, ec)), Some((tr, er))) => {
                assert_eq!((tc.to_bits(), ec), (tr.to_bits(), er));
            }
            (None, None) => break,
            (got, want) => panic!("{got:?} vs {want:?}"),
        }
    }
}
