//! Runtime integration: load + execute the AOT artifacts through PJRT.
//! These tests skip (pass trivially) when `make artifacts` has not run.

use std::path::Path;

use liminal::runtime::Runtime;
use liminal::serving::PjrtEngine;

fn artifacts() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    p.join("manifest.json").exists().then_some(p)
}

#[test]
fn manifest_loads_and_lists_expected_entries() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(dir).unwrap();
    for name in ["decode_b1", "decode_b8", "grid_eval", "gemv", "gemm"] {
        assert!(rt.manifest().entry(name).is_ok(), "missing {name}");
    }
}

#[test]
fn gemv_executes_and_returns_correct_shape() {
    let Some(dir) = artifacts() else { return };
    let mut rt = Runtime::new(dir).unwrap();
    let gemv = rt.load("gemv").unwrap();
    let args = rt.zero_inputs("gemv").unwrap();
    let out = gemv.execute(&args).unwrap();
    assert_eq!(out.len(), 1);
    let n = gemv.entry.num("n").unwrap() as usize;
    assert_eq!(out[0].element_count(), n);
}

#[test]
fn grid_eval_matches_rust_model_math() {
    let Some(dir) = artifacts() else { return };
    let mut rt = Runtime::new(dir).unwrap();
    let ge = rt.load("grid_eval").unwrap();
    let n = ge.entry.num("n").unwrap() as usize;

    // bytes=4e9, bw=4.4e12 -> t=909.09us; flops tiny; exposed 567us.
    let fill = |v: f32| {
        let lit = xla::Literal::vec1(&vec![v; n]);
        lit
    };
    let args = vec![
        fill(4e9),     // bytes
        fill(1e9),     // tensor flops
        fill(1e6),     // scalar flops
        fill(4.4e12),  // mem bw
        fill(2.25e15), // tensor peak
        fill(2e14),    // scalar peak
        fill(567e-6),  // exposed
    ];
    let out = ge.execute(&args).unwrap();
    assert_eq!(out.len(), 2);
    let t_batch: Vec<f32> = out[0].to_vec().unwrap();
    let utps: Vec<f32> = out[1].to_vec().unwrap();
    let want_t = 4e9f64 / 4.4e12 + 567e-6;
    assert!((t_batch[0] as f64 - want_t).abs() / want_t < 1e-5);
    assert!((utps[0] as f64 - 1.0 / want_t).abs() / (1.0 / want_t) < 1e-5);
}

#[test]
fn decode_engine_runs_deterministic_steps() {
    let Some(dir) = artifacts() else { return };
    let mut rt = Runtime::new(dir).unwrap();
    let mut eng = PjrtEngine::new(&mut rt, 1).unwrap();
    eng.randomize_params(123).unwrap();

    let (t1, _) = eng.step(&[5]).unwrap();
    let (t2, _) = eng.step(&[t1[0]]).unwrap();
    assert_eq!(eng.pos, 2);
    assert_eq!(eng.steps_executed(), 2);

    // Re-run from reset with the same params: identical token stream.
    eng.reset().unwrap();
    let (r1, _) = eng.step(&[5]).unwrap();
    let (r2, _) = eng.step(&[r1[0]]).unwrap();
    assert_eq!(t1, r1);
    assert_eq!(t2, r2);

    // Tokens are within the vocabulary.
    assert!((t1[0] as u64) < eng.vocab);
}

#[test]
fn decode_buckets_round_up() {
    let Some(dir) = artifacts() else { return };
    let mut rt = Runtime::new(dir).unwrap();
    let eng = PjrtEngine::new(&mut rt, 3).unwrap();
    assert_eq!(eng.batch, 4, "batch 3 should use the b4 bucket");
}

#[test]
fn stream_bandwidth_is_plausible() {
    // Sanity on the calibration measurement itself: a modern machine
    // streams somewhere between 1 and 1000 GB/s.
    let bw = Runtime::measure_stream_bandwidth();
    assert!(bw > 1e9 && bw < 1e12, "stream bw {bw}");
}
